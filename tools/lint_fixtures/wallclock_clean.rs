// lint-fixture: zone=kernel expect=

fn timed(run: impl FnOnce()) -> u64 {
    let t0 = std::time::Instant::now(); // lint:allow(no-wallclock): instrumentation only
    run();
    t0.elapsed().as_nanos() as u64
}
