// lint-fixture: zone=serving expect=
// The same shape written totally: typed errors in the serving code and
// panics confined to #[cfg(test)], which is exempt from every rule.

fn load(v: Option<u32>) -> Result<u32, String> {
    v.ok_or_else(|| "missing value".to_string())
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_here() {
        assert_eq!(super::load(Some(3)).unwrap(), 3);
    }
}
