// lint-fixture: zone=default expect=atomic-ordering@6

use std::sync::atomic::{AtomicU64, Ordering};

fn bump(c: &AtomicU64) -> u64 {
    c.fetch_add(1, Ordering::Relaxed)
}
