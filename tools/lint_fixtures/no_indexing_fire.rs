// lint-fixture: zone=serving expect=no-indexing@4,no-indexing@5

fn head(buf: &[u8], n: usize) -> u8 {
    let first = buf[0];
    let window = &buf[n..n + 4];
    first ^ window.len() as u8
}
