// lint-fixture: zone=serving expect=no-panic@5,no-panic@6,no-panic@7,no-panic@10
// A serving-zone fn full of panic-capable calls: each line fires once.

fn load(v: Option<u32>, r: Result<u32, String>) -> u32 {
    let a = v.unwrap();
    let b = r.expect("must parse");
    let c = if a > b { a } else { panic!("bad") };
    let _ = c;
    if a == 0 {
        todo!("unhandled zero");
    }
    a
}
