// lint-fixture: zone=serving expect=recursion-depth@3,recursion-depth@7

fn descend(n: u32) -> u32 {
    if n == 0 { 0 } else { descend(n - 1) + 1 }
}

fn ping(n: u32) -> u32 {
    if n == 0 { 0 } else { pong(n - 1) }
}

fn pong(n: u32) -> u32 {
    ping(n)
}
