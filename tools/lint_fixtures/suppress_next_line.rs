// lint-fixture: zone=serving expect=no-indexing@7,no-indexing@8

fn two(buf: &[u8]) -> u8 {
    // lint:allow(no-indexing): caller guarantees at least one byte
    let a = buf[0];
    // The next index is NOT suppressed: the allow above named only line 5.
    let b = buf[1];
    let c = buf[2]; // lint:allow(no-panic): wrong rule name — no-indexing still fires
    a ^ b ^ c
}
