// lint-fixture: zone=kernel expect=no-wallclock@4

fn stamp() -> u128 {
    std::time::Instant::now().elapsed().as_nanos()
}
