// lint-fixture: zone=kernel expect=

fn relu(v: &mut [f32]) {
    for x in v.iter_mut() {
        // Explicit select: bit-stable for NaN and -0.0 inputs.
        *x = if *x > 0.0 { *x } else { 0.0 };
    }
}

fn tile_end(rows: usize, i0: usize) -> usize {
    rows.min(i0 + 32)
}
