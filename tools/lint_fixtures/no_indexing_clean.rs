// lint-fixture: zone=serving expect=

fn head(buf: &[u8], n: usize) -> Option<u8> {
    let first = buf.get(0).copied()?;
    let window = buf.get(n..n.checked_add(4)?)?;
    let sum: u8 = window.iter().fold(first, |a, b| a ^ b);
    let fixed = [0u8; 4];
    Some(sum ^ fixed[0]) // lint:allow(no-indexing): literal index into [u8; 4]
}
