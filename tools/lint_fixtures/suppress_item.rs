// lint-fixture: zone=serving expect=no-panic@12

// lint:allow(no-indexing): every index below is bounded by the asserted len
fn checked(buf: &[u8]) -> u8 {
    assert!(buf.len() >= 4);
    let a = buf[0] ^ buf[3];
    let b = &buf[1..3];
    a ^ b.iter().fold(0, |x, y| x ^ y)
}

fn still_fires(v: Option<u32>) -> u32 {
    v.unwrap()
}
