// lint-fixture: zone=kernel expect=

use std::collections::BTreeMap;

fn sum(weights: &BTreeMap<u64, f32>) -> f32 {
    weights.values().sum()
}
