// lint-fixture: zone=default expect=

use std::sync::atomic::{AtomicU64, Ordering};

fn bump(c: &AtomicU64) -> u64 {
    // ORDERING: Relaxed — monotone counter, nothing orders against it.
    c.fetch_add(1, Ordering::Relaxed)
}

fn status(c: &AtomicU64) -> u64 {
    c.load(Ordering::Acquire) // ORDERING: Acquire pairs with the writer's Release
}
