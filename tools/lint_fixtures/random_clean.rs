// lint-fixture: zone=kernel expect=

fn jitter(seed: u64) -> u64 {
    // Deterministic splitmix64 step — the seeded testutil::Rng idiom.
    seed.wrapping_mul(0x9e3779b97f4a7c15).rotate_left(31)
}
