// lint-fixture: zone=default expect=

fn read_raw(p: *const u32) -> u32 {
    // SAFETY: caller guarantees `p` is non-null, aligned, and live.
    unsafe { *p }
}
