// lint-fixture: zone=default expect=safety-comment@4

fn read_raw(p: *const u32) -> u32 {
    unsafe { *p }
}
