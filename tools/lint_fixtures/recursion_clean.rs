// lint-fixture: zone=serving expect=

const MAX_DEPTH: usize = 64;

fn descend(n: usize, depth: usize) -> Result<usize, String> {
    if depth >= MAX_DEPTH {
        return Err("too deep".to_string());
    }
    if n == 0 { Ok(0) } else { descend(n - 1, depth + 1) }
}
