// lint-fixture: zone=kernel expect=no-randomness@4

fn jitter() -> u64 {
    let state = std::collections::hash_map::RandomState::new();
    let _ = &state;
    0
}
