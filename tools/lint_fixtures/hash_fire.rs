// lint-fixture: zone=kernel expect=no-hash-collections@3,no-hash-collections@5

use std::collections::HashMap;

fn sum(weights: &HashMap<u64, f32>) -> f32 {
    weights.values().sum()
}
