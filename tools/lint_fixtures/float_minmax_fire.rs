// lint-fixture: zone=kernel expect=float-minmax@5,float-minmax@7,float-minmax@8

fn relu(v: &mut [f32]) {
    for x in v.iter_mut() {
        *x = x.max(0.0);
    }
    let a = f32::max(1.0, 2.0);
    let b = 0.5f32.min(a);
    let _ = b;
}
