#!/usr/bin/env python3
"""pallas-lint: in-tree static invariant checker for the rust_pallas crate.

Pure stdlib (the build/CI container for this repo has no Rust toolchain,
so like `check_metrics_docs.py` this must run anywhere Python runs). It
enforces, *statically*, the invariants the repo otherwise only checks at
runtime in CI — every rule is grounded in a bug this repo actually
shipped or a standing bit-identity contract (see docs/LINTS.md for the
catalogue with motivating incidents):

  panic-freedom   (serving zone)  no `.unwrap()` / `.expect()` /
                                  `panic!` / `todo!` / `unreachable!` /
                                  `unimplemented!`; no unchecked
                                  `x[i]` / `x[i..j]` indexing
  bit-determinism (kernel zones)  no float `max`/`min` (platform-
                                  dependent NaN/−0 semantics — the PR 4
                                  ReLU bug), no `mul_add` (contracts to
                                  fused FMA on some targets), no
                                  `HashMap`/`HashSet` (iteration order),
                                  no wall clock / randomness outside
                                  annotated timing instrumentation
  unsafe hygiene  (everywhere)    every `unsafe` needs a `// SAFETY:`
                                  comment; every atomic `Ordering::*`
                                  use needs an `// ORDERING:` comment or
                                  an allowlisted module
  recursion bound (serving zone)  every (mutually) recursive function
                                  must reference a depth-cap const (the
                                  PR 8 unbounded-JSON-recursion fix,
                                  generalized)

Zones are mapped to rule sets by the manifest `tools/lint_manifest.json`.
Suppressions: `// lint:allow(rule-a, rule-b): reason` — trailing on a
line suppresses that line; on its own line it suppresses the next code
line, or the entire item (fn/impl/mod/...) when the next line opens one.
`#[cfg(test)]` / `#[test]` items are exempt from every rule.

The checker is lexical, not type-aware. The lexer understands comments
(nested block comments), string/char/byte/raw-string literals, and
lifetimes, so rules never fire inside literals or prose; but it cannot
see types, so (a) don't name your own methods `unwrap`/`expect`, and
(b) the float-minmax rule keys on float literals and `f32::`/`f64::`
paths, not inferred types.

Usage:
  python3 tools/pallas_lint.py              lint the repo (exit 1 on any hit)
  python3 tools/pallas_lint.py --self-test  run the fixture corpus
  python3 tools/pallas_lint.py --list-rules rule ids + one-line docs
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MANIFEST = Path(__file__).resolve().parent / "lint_manifest.json"
FIXTURES = Path(__file__).resolve().parent / "lint_fixtures"

# ---------------------------------------------------------------------------
# Lexer: split Rust source into a "code view" (comments and literal
# contents blanked, structure preserved) plus per-line comment text.
# ---------------------------------------------------------------------------


class Lexed:
    """Code view + comments of one source file.

    `code[i]` is line i+1 with every comment and literal body replaced by
    spaces (quote characters kept, so token boundaries survive);
    `comments[i]` is the comment text on line i+1 ('' when none).
    """

    def __init__(self, code: list[str], comments: list[str]):
        self.code = code
        self.comments = comments


def lex(src: str) -> Lexed:
    lines = src.split("\n")
    code_out: list[list[str]] = [list(" " * len(l)) for l in lines]
    comment_out: list[list[str]] = [[] for _ in lines]

    NORMAL, LINE_C, BLOCK_C, STR, RAWSTR, CHAR = range(6)
    state = NORMAL
    block_depth = 0
    raw_hashes = 0

    for ln, line in enumerate(lines):
        i, n = 0, len(line)
        if state == LINE_C:  # line comments never span lines
            state = NORMAL
        while i < n:
            c = line[i]
            nxt = line[i + 1] if i + 1 < n else ""
            if state == NORMAL:
                if c == "/" and nxt == "/":
                    state = LINE_C
                    comment_out[ln].append(line[i:])
                    i = n
                    continue
                if c == "/" and nxt == "*":
                    state = BLOCK_C
                    block_depth = 1
                    start = i
                    i += 2
                    # scan rest of line for nesting/close below
                    while i < n and block_depth > 0:
                        if line[i] == "/" and i + 1 < n and line[i + 1] == "*":
                            block_depth += 1
                            i += 2
                        elif line[i] == "*" and i + 1 < n and line[i + 1] == "/":
                            block_depth -= 1
                            i += 2
                        else:
                            i += 1
                    comment_out[ln].append(line[start:i])
                    if block_depth == 0:
                        state = NORMAL
                    continue
                if c == '"':
                    code_out[ln][i] = '"'
                    state = STR
                    i += 1
                    continue
                # raw / byte string prefixes: r"  r#"  b"  br"  br#"
                if (
                    c in "rb"
                    and (i == 0 or not (line[i - 1].isalnum() or line[i - 1] == "_"))
                    and (m2 := re.match(r'(br#*"|r#*"|b")', line[i:]))
                ):
                    tok = m2.group(1)
                    raw_hashes = tok.count("#")
                    for k in range(len(tok)):
                        code_out[ln][i + k] = tok[k]
                    i += len(tok)
                    # b"..." has normal escape processing; r/br are raw
                    state = STR if tok == 'b"' else RAWSTR
                    continue
                if c == "'":
                    # lifetime ('a, 'static) vs char literal ('x', '\n')
                    if re.match(r"'\w+(?!')", line[i:]) and not re.match(r"'\w'", line[i:]):
                        code_out[ln][i] = "'"
                        i += 1
                        continue
                    code_out[ln][i] = "'"
                    state = CHAR
                    i += 1
                    continue
                code_out[ln][i] = c
                i += 1
            elif state == BLOCK_C:
                start = i
                while i < n and block_depth > 0:
                    if line[i] == "/" and i + 1 < n and line[i + 1] == "*":
                        block_depth += 1
                        i += 2
                    elif line[i] == "*" and i + 1 < n and line[i + 1] == "/":
                        block_depth -= 1
                        i += 2
                    else:
                        i += 1
                comment_out[ln].append(line[start:i])
                if block_depth == 0:
                    state = NORMAL
            elif state == STR:
                if c == "\\":
                    i += 2
                    continue
                if c == '"':
                    code_out[ln][i] = '"'
                    state = NORMAL
                i += 1
            elif state == RAWSTR:
                end = '"' + "#" * raw_hashes
                if line.startswith(end, i):
                    for k in range(len(end)):
                        code_out[ln][i + k] = end[k]
                    i += len(end)
                    state = NORMAL
                else:
                    i += 1
            elif state == CHAR:
                if c == "\\":
                    i += 2
                    continue
                if c == "'":
                    code_out[ln][i] = "'"
                    state = NORMAL
                i += 1
        # unterminated STR/CHAR at EOL: real Rust won't do this; reset CHAR
        if state == CHAR:
            state = NORMAL

    return Lexed(
        ["".join(cs) for cs in code_out],
        ["  ".join(parts) for parts in comment_out],
    )


# ---------------------------------------------------------------------------
# Structure: brace spans, items, #[cfg(test)] regions, suppressions.
# ---------------------------------------------------------------------------

ITEM_RE = re.compile(
    r"^\s*(?:pub(?:\(\w+\))?\s+)?(?:unsafe\s+)?(?:const\s+|async\s+)?"
    r"(?:fn|mod|impl|struct|enum|trait|union)\b"
)
ALLOW_RE = re.compile(r"lint:allow\(([a-z0-9_\-,\s]+)\)")


def line_starts(code: list[str]) -> list[int]:
    starts, pos = [], 0
    for l in code:
        starts.append(pos)
        pos += len(l) + 1
    return starts


def pos_to_line(starts: list[int], pos: int) -> int:
    """0-based line index of flat position `pos`."""
    lo, hi = 0, len(starts) - 1
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if starts[mid] <= pos:
            lo = mid
        else:
            hi = mid - 1
    return lo


def matching_brace(flat: str, open_pos: int) -> int:
    depth = 0
    for i in range(open_pos, len(flat)):
        if flat[i] == "{":
            depth += 1
        elif flat[i] == "}":
            depth -= 1
            if depth == 0:
                return i
    return len(flat) - 1


def item_span(flat: str, starts: list[int], from_line: int) -> tuple[int, int] | None:
    """(first_line, last_line) 0-based of the item whose header starts at
    `from_line`: the span of the first `{...}` opening before a top-level
    `;` (semicolons nested in `[u64; 4]`-style brackets don't end the
    header; a bare `;` does — `struct Foo;`, trait method decls)."""
    begin = starts[from_line]
    nest = 0
    for i in range(begin, len(flat)):
        c = flat[i]
        if c in "([<":
            nest += 1
        elif c in ")]>":
            nest = max(0, nest - 1)
        elif c == ";" and nest == 0:
            return None
        elif c == "{":
            close = matching_brace(flat, i)
            return from_line, pos_to_line(starts, close)
    return None


class FileCtx:
    def __init__(self, rel: str, src: str):
        self.rel = rel
        self.lexed = lex(src)
        self.code = self.lexed.code
        self.comments = self.lexed.comments
        self.flat = "\n".join(self.code)
        self.starts = line_starts(self.code)
        self.test_lines = self._test_regions()
        self.suppress = self._suppressions()

    # -- #[cfg(test)] / #[test] exemption -----------------------------------
    def _test_regions(self) -> set[int]:
        exempt: set[int] = set()
        for ln, code in enumerate(self.code):
            if "#[cfg(test)]" in code or "#[test]" in code or "#[cfg(all(test" in code:
                # Skip further attribute lines, then span the next item.
                j = ln + 1
                while j < len(self.code) and (
                    not self.code[j].strip() or self.code[j].lstrip().startswith("#[")
                ):
                    j += 1
                span = item_span(self.flat, self.starts, j)
                if span:
                    exempt.update(range(span[0], span[1] + 1))
        return exempt

    # -- lint:allow(...) ----------------------------------------------------
    def _suppressions(self) -> dict[int, set[str]]:
        sup: dict[int, set[str]] = {}

        def add(lines, rules):
            for l in lines:
                sup.setdefault(l, set()).update(rules)

        for ln, comment in enumerate(self.comments):
            m = ALLOW_RE.search(comment)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            if self.code[ln].strip():  # trailing: this line only
                add([ln], rules)
                continue
            # Standalone: next code line; whole item if it opens one.
            j = ln + 1
            while j < len(self.code) and (
                not self.code[j].strip() or self.code[j].lstrip().startswith("#[")
            ):
                j += 1
            if j >= len(self.code):
                continue
            if ITEM_RE.match(self.code[j]):
                span = item_span(self.flat, self.starts, j)
                if span:
                    add(range(span[0], span[1] + 1), rules)
                    continue
            add([j], rules)
        return sup

    def active(self, ln: int, rule: str) -> bool:
        """Whether `rule` should fire on 0-based line `ln`."""
        if ln in self.test_lines:
            return False
        return rule not in self.suppress.get(ln, set())

    def comment_near(self, ln: int, tag: str, above: int = 4) -> bool:
        """A comment containing `tag` on line `ln` or within `above` lines
        up (not crossing a blank non-comment gap of code)."""
        for j in range(ln, max(-1, ln - above - 1), -1):
            if tag in self.comments[j]:
                return True
            # stop climbing once we pass a code-bearing line above ln
            if j < ln and self.code[j].strip() and not self.comments[j]:
                break
        return False

    # -- fn extraction for the recursion rule -------------------------------
    def functions(self) -> list[tuple[str, int, int, str]]:
        """(name, first_line, last_line, body) for every fn with a body."""
        out = []
        for m in re.finditer(r"\bfn\s+(\w+)", self.flat):
            ln = pos_to_line(self.starts, m.start())
            span = item_span(self.flat, self.starts, ln)
            if span is None:
                continue
            open_pos = self.flat.find("{", m.start())
            close = matching_brace(self.flat, open_pos)
            out.append((m.group(1), span[0], span[1], self.flat[open_pos : close + 1]))
        return out


# ---------------------------------------------------------------------------
# Rules.
# ---------------------------------------------------------------------------


class Violation:
    def __init__(self, rel: str, line: int, rule: str, msg: str):
        self.rel, self.line, self.rule, self.msg = rel, line, rule, msg

    def __str__(self):
        return f"{self.rel}:{self.line}: [{self.rule}] {self.msg}"


RULES: dict[str, str] = {
    "no-panic": "panic-capable call in a panic-free zone "
    "(.unwrap/.expect/panic!/unreachable!/todo!/unimplemented!)",
    "no-indexing": "unchecked x[i] / x[i..j] indexing in a panic-free zone "
    "(the PR 8 b[i..i+4] slice-panic class)",
    "recursion-depth": "recursive function without a depth-cap const "
    "(the PR 8 unbounded-JSON-recursion class)",
    "safety-comment": "unsafe without a // SAFETY: comment",
    "atomic-ordering": "atomic Ordering::* without an // ORDERING: comment "
    "(outside allowlisted modules)",
    "float-minmax": "float max/min (platform-dependent NaN/-0 semantics; "
    "the PR 4 f32::max ReLU class) — use an explicit select",
    "no-mul-add": "mul_add/fma fuses rounding steps — bit-results differ "
    "from mul-then-add",
    "no-hash-collections": "HashMap/HashSet iteration order is "
    "nondeterministic — use BTreeMap/BTreeSet or vectors",
    "no-wallclock": "wall-clock read in a deterministic kernel zone "
    "(annotate timing instrumentation with lint:allow)",
    "no-randomness": "nondeterministic randomness in a kernel zone "
    "(use the seeded testutil::Rng)",
}

PANIC_RE = re.compile(
    r"\.\s*(unwrap|expect)\s*\(|\b(panic|unreachable|todo|unimplemented)\s*!"
)
ORDERING_RE = re.compile(r"\bOrdering::(Relaxed|Acquire|Release|AcqRel|SeqCst)\b")
FLOAT_MINMAX_RE = re.compile(
    r"\bf(?:32|64)::(?:max|min)\b"  # f32::max as a fn path
    r"|\.\s*(?:max|min)\s*\(\s*-?(?:\d+\.\d*|\d+(?:f32|f64)\b|f(?:32|64)::)"  # .max(0.0)
    r"|\d\.\d*(?:f32|f64)?\s*\.\s*(?:max|min)\s*\("  # 0.0f32.max(x)
)
MUL_ADD_RE = re.compile(r"\.\s*mul_add\s*\(|\bf(?:32|64)::mul_add\b")
HASH_RE = re.compile(r"\bHash(?:Map|Set)\b")
WALLCLOCK_RE = re.compile(r"\b(?:Instant|SystemTime)::now\b")
RANDOM_RE = re.compile(r"\bthread_rng\b|\brand::|\bgetrandom\b|\bRandomState\b")
UNSAFE_RE = re.compile(r"\bunsafe\b")
DEPTH_CONST_RE = re.compile(r"\b[A-Z][A-Z0-9_]*DEPTH[A-Z0-9_]*\b|\bMAX_DEPTH\b")

# `x[`-style indexing: `[` immediately after an identifier char, `)` or
# `]` (rustfmt never separates an index from its receiver, while type
# positions like `mut [f32]` always have the space).
INDEX_RE = re.compile(r"[\w\)\]]\[")


def _scan(ctx: FileCtx, rule: str, rx: re.Pattern, msg) -> list[Violation]:
    out = []
    for ln, code in enumerate(ctx.code):
        for m in rx.finditer(code):
            if ctx.active(ln, rule):
                out.append(Violation(ctx.rel, ln + 1, rule, msg(m)))
            break  # one diagnostic per line per rule
    return out


def rule_no_panic(ctx: FileCtx) -> list[Violation]:
    return _scan(
        ctx,
        "no-panic",
        PANIC_RE,
        lambda m: f"panic-capable `{m.group(0).strip()}` reachable from the serving "
        "path — return a typed error instead",
    )


def rule_no_indexing(ctx: FileCtx) -> list[Violation]:
    out = []
    for ln, code in enumerate(ctx.code):
        if not ctx.active(ln, "no-indexing"):
            continue
        if INDEX_RE.search(code):
            out.append(
                Violation(
                    ctx.rel,
                    ln + 1,
                    "no-indexing",
                    "unchecked index/slice can panic on a hostile length — "
                    "use .get()/iterators, or prove the bound and add "
                    "`// lint:allow(no-indexing): <why in-bounds>`",
                )
            )
    return out


def rule_recursion_depth(ctx: FileCtx) -> list[Violation]:
    fns = ctx.functions()
    by_name: dict[str, list[int]] = {}
    for idx, (name, *_rest) in enumerate(fns):
        by_name.setdefault(name, []).append(idx)

    def callees(body: str) -> set[str]:
        calls = set()
        for m in re.finditer(r"(\w+)\s*\(", body):
            name = m.group(1)
            if name not in by_name:
                continue
            pre = body[: m.start(1)].rstrip()
            # Method calls on receivers other than `self`, and paths on
            # types other than `Self` (Vec::new, Arc::clone, ...), don't
            # resolve to this file's fns; `fn name(` is a definition.
            if pre.endswith(".") and not pre.endswith("self."):
                continue
            if pre.endswith("::") and not pre.endswith("Self::"):
                continue
            if re.search(r"\bfn$", pre):
                continue
            # Bare `drop(x)` is the std prelude fn, not `Drop::drop`.
            if name == "drop" and not pre.endswith(("self.", "Self::")):
                continue
            calls.add(name)
        return calls

    graph: dict[int, set[int]] = {}
    for idx, (_name, _s, _e, body) in enumerate(fns):
        graph[idx] = {j for callee in callees(body) for j in by_name[callee]}

    # Tarjan SCC, iterative.
    index, low, onstack, stack = {}, {}, set(), []
    sccs, counter = [], [0]

    def strongconnect(v):
        work = [(v, iter(sorted(graph[v])))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        onstack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    onstack.add(w)
                    work.append((w, iter(sorted(graph[w]))))
                    advanced = True
                    break
                elif w in onstack:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                low[work[-1][0]] = min(low[work[-1][0]], low[node])
            if low[node] == index[node]:
                comp = []
                while True:
                    w = stack.pop()
                    onstack.discard(w)
                    comp.append(w)
                    if w == node:
                        break
                sccs.append(comp)

    for v in graph:
        if v not in index:
            strongconnect(v)

    out = []
    for comp in sccs:
        cyclic = len(comp) > 1 or comp[0] in graph[comp[0]]
        if not cyclic:
            continue
        if any(DEPTH_CONST_RE.search(fns[i][3]) for i in comp):
            continue
        names = ", ".join(sorted({fns[i][0] for i in comp}))
        anchor = min(fns[i][1] for i in comp)
        if ctx.active(anchor, "recursion-depth"):
            out.append(
                Violation(
                    ctx.rel,
                    anchor + 1,
                    "recursion-depth",
                    f"recursive cycle [{names}] has no depth-cap const "
                    "(a SCREAMING_CASE *DEPTH* bound checked before "
                    "recursing) — hostile input can overflow the stack",
                )
            )
    return out


def rule_safety_comment(ctx: FileCtx) -> list[Violation]:
    out = []
    for ln, code in enumerate(ctx.code):
        if not UNSAFE_RE.search(code) or not ctx.active(ln, "safety-comment"):
            continue
        if ctx.comment_near(ln, "SAFETY:"):
            continue
        out.append(
            Violation(
                ctx.rel,
                ln + 1,
                "safety-comment",
                "`unsafe` without a `// SAFETY:` comment in the 4 lines "
                "above stating the invariant that makes it sound",
            )
        )
    return out


def rule_atomic_ordering(ctx: FileCtx, allowed: bool) -> list[Violation]:
    if allowed:
        return []
    out = []
    for ln, code in enumerate(ctx.code):
        m = ORDERING_RE.search(code)
        if not m or not ctx.active(ln, "atomic-ordering"):
            continue
        # `use std::sync::atomic::Ordering` import lines are fine.
        if re.match(r"\s*(?:pub\s+)?use\b", code):
            continue
        if ctx.comment_near(ln, "ORDERING:"):
            continue
        out.append(
            Violation(
                ctx.rel,
                ln + 1,
                "atomic-ordering",
                f"`{m.group(0)}` without an `// ORDERING:` comment "
                "justifying the memory-order choice (or allowlist the "
                "module in tools/lint_manifest.json)",
            )
        )
    return out


def rule_float_minmax(ctx: FileCtx) -> list[Violation]:
    return _scan(
        ctx,
        "float-minmax",
        FLOAT_MINMAX_RE,
        lambda m: f"float `{m.group(0).strip()}` has platform/NaN-dependent "
        "semantics — use an explicit `if a > b {{ a }} else {{ b }}` select "
        "(the PR 4 ReLU bug class)",
    )


def rule_no_mul_add(ctx: FileCtx) -> list[Violation]:
    return _scan(
        ctx,
        "no-mul-add",
        MUL_ADD_RE,
        lambda m: "`mul_add` fuses the rounding step — results differ "
        "bitwise from mul-then-add; kernels must round like the "
        "scalar reference",
    )


def rule_no_hash_collections(ctx: FileCtx) -> list[Violation]:
    return _scan(
        ctx,
        "no-hash-collections",
        HASH_RE,
        lambda m: f"`{m.group(0)}` iteration order is nondeterministic — "
        "accumulation over it breaks bit-identity; use BTreeMap/BTreeSet",
    )


def rule_no_wallclock(ctx: FileCtx) -> list[Violation]:
    return _scan(
        ctx,
        "no-wallclock",
        WALLCLOCK_RE,
        lambda m: f"`{m.group(0)}` in a deterministic kernel zone — if this "
        "is timing instrumentation whose value never feeds results, "
        "annotate with `// lint:allow(no-wallclock): <why>`",
    )


def rule_no_randomness(ctx: FileCtx) -> list[Violation]:
    return _scan(
        ctx,
        "no-randomness",
        RANDOM_RE,
        lambda m: f"`{m.group(0).strip()}` is nondeterministic — kernels "
        "must use the seeded testutil::Rng",
    )


# ---------------------------------------------------------------------------
# Driver.
# ---------------------------------------------------------------------------


def load_manifest(path: Path) -> dict:
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def zone_for(rel: str, manifest: dict) -> dict | None:
    for zone in manifest["zones"]:
        for prefix in zone["paths"]:
            if rel == prefix or rel.startswith(prefix):
                return zone
    return None


def lint_file(rel: str, src: str, rules: list[str], manifest: dict) -> list[Violation]:
    ctx = FileCtx(rel, src)
    ordering_ok = rel in manifest.get("ordering_allowed", [])
    out: list[Violation] = []
    dispatch = {
        "no-panic": lambda: rule_no_panic(ctx),
        "no-indexing": lambda: rule_no_indexing(ctx),
        "recursion-depth": lambda: rule_recursion_depth(ctx),
        "safety-comment": lambda: rule_safety_comment(ctx),
        "atomic-ordering": lambda: rule_atomic_ordering(ctx, ordering_ok),
        "float-minmax": lambda: rule_float_minmax(ctx),
        "no-mul-add": lambda: rule_no_mul_add(ctx),
        "no-hash-collections": lambda: rule_no_hash_collections(ctx),
        "no-wallclock": lambda: rule_no_wallclock(ctx),
        "no-randomness": lambda: rule_no_randomness(ctx),
    }
    for rule in rules:
        out.extend(dispatch[rule]())
    return out


def lint_tree(root: Path, manifest: dict) -> list[Violation]:
    out: list[Violation] = []
    seen: set[str] = set()
    for zone in manifest["zones"]:
        for prefix in zone["paths"]:
            base = root / prefix
            files = [base] if base.is_file() else sorted(base.rglob("*.rs"))
            for f in files:
                rel = f.relative_to(root).as_posix()
                if rel in seen:
                    continue
                # first matching zone wins, even for overlapping prefixes
                z = zone_for(rel, manifest)
                if z is not zone:
                    continue
                seen.add(rel)
                out.extend(
                    lint_file(rel, f.read_text(encoding="utf-8"), z["rules"], manifest)
                )
    out.sort(key=lambda v: (v.rel, v.line, v.rule))
    return out


# ---------------------------------------------------------------------------
# Fixture self-test.
# ---------------------------------------------------------------------------

FIXTURE_PRAGMA = re.compile(r"lint-fixture:\s*zone=(\w+)\s*expect=([\w\-:,@]*)")


def run_self_test(manifest: dict) -> int:
    zones = {z["name"]: z for z in manifest["zones"]}
    failures = 0
    fixtures = sorted(FIXTURES.glob("*.rs"))
    if not fixtures:
        print(f"error: no fixtures found in {FIXTURES}", file=sys.stderr)
        return 1
    for fx in fixtures:
        src = fx.read_text(encoding="utf-8")
        m = FIXTURE_PRAGMA.search(src)
        if not m:
            print(f"FAIL {fx.name}: missing `lint-fixture:` pragma", file=sys.stderr)
            failures += 1
            continue
        zone_name, expect_raw = m.group(1), m.group(2)
        if zone_name not in zones:
            print(f"FAIL {fx.name}: unknown zone {zone_name!r}", file=sys.stderr)
            failures += 1
            continue
        expected = set()
        for part in filter(None, expect_raw.split(",")):
            rule, _, line = part.partition("@")
            expected.add((rule, int(line)))
        got = {
            (v.rule, v.line)
            for v in lint_file(fx.name, src, zones[zone_name]["rules"], manifest)
        }
        if got != expected:
            failures += 1
            print(f"FAIL {fx.name} (zone={zone_name})", file=sys.stderr)
            for rule, line in sorted(expected - got):
                print(f"  expected but did not fire: {rule}@{line}", file=sys.stderr)
            for rule, line in sorted(got - expected):
                print(f"  fired unexpectedly:        {rule}@{line}", file=sys.stderr)
    total = len(fixtures)
    if failures:
        print(f"self-test: {failures}/{total} fixtures FAILED", file=sys.stderr)
        return 1
    print(f"self-test ok: {total} fixtures")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path, default=REPO, help="repo root")
    ap.add_argument("--manifest", type=Path, default=MANIFEST)
    ap.add_argument("--self-test", action="store_true", help="run the fixture corpus")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args()

    if args.list_rules:
        for rule, doc in RULES.items():
            print(f"{rule:22s} {doc}")
        return 0

    manifest = load_manifest(args.manifest)
    rule_ids = {r for z in manifest["zones"] for r in z["rules"]}
    unknown = rule_ids - set(RULES)
    if unknown:
        print(f"error: manifest names unknown rules: {sorted(unknown)}", file=sys.stderr)
        return 2

    if args.self_test:
        return run_self_test(manifest)

    violations = lint_tree(args.root, manifest)
    for v in violations:
        print(v)
    if violations:
        by_rule: dict[str, int] = {}
        for v in violations:
            by_rule[v.rule] = by_rule.get(v.rule, 0) + 1
        summary = ", ".join(f"{r}×{n}" for r, n in sorted(by_rule.items()))
        print(f"pallas-lint: {len(violations)} violation(s): {summary}", file=sys.stderr)
        return 1
    print("pallas-lint: ok (0 violations)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
