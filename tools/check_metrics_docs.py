#!/usr/bin/env python3
"""Metrics/docs drift check (stdlib only, mirrored by the in-crate test
`every_rendered_metric_is_documented`).

Forward direction (hard failure): every `positron_*` metric-family name
that appears in the coordinator sources must be documented in
docs/OBSERVABILITY.md. Histogram families rendered via
`HistSnapshot::render_into` get `_bucket`/`_sum`/`_count` suffixes
appended at render time, so for each base name found next to a
`render_into` call the three suffixed names are required too.

Reverse direction (warning only): names documented but never found in
the sources are reported — stale docs are annoying but not a build
break, since prose may legitimately mention families from older
releases while migration notes exist.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs" / "OBSERVABILITY.md"
SOURCES = [
    REPO / "rust" / "src" / "coordinator" / "metrics.rs",
    REPO / "rust" / "src" / "coordinator" / "trace.rs",
    REPO / "rust" / "src" / "coordinator" / "http.rs",
    REPO / "rust" / "src" / "cli.rs",
]

NAME_RE = re.compile(r"positron_[a-z0-9_]+")
HIST_SUFFIXES = ("_bucket", "_sum", "_count")


def rendered_names() -> set[str]:
    """Every positron_* family the Rust sources can emit."""
    names: set[str] = set()
    for src in SOURCES:
        text = src.read_text(encoding="utf-8")
        for line in text.splitlines():
            # Skip pure comment lines: prose may mention historic names.
            if line.lstrip().startswith(("//", "///", "//!")):
                continue
            for name in NAME_RE.findall(line):
                names.add(name)
            # A histogram render emits the three suffixed families.
            if "render_into" in line:
                for name in NAME_RE.findall(line):
                    for suffix in HIST_SUFFIXES:
                        names.add(name + suffix)
    return names


def documented_names() -> set[str]:
    return set(NAME_RE.findall(DOCS.read_text(encoding="utf-8")))


def main() -> int:
    if not DOCS.is_file():
        print(f"error: {DOCS} is missing", file=sys.stderr)
        return 1
    rendered = rendered_names()
    documented = documented_names()

    missing = sorted(rendered - documented)
    if missing:
        print(
            "error: exported metric families missing from docs/OBSERVABILITY.md:",
            file=sys.stderr,
        )
        for name in missing:
            print(f"  {name}", file=sys.stderr)
        return 1

    # Reverse check: strip histogram suffixes before deciding a
    # documented name is stale, since the base family name only exists
    # in the sources without the suffix.
    stale = []
    for name in sorted(documented - rendered):
        base = name
        for suffix in HIST_SUFFIXES:
            if name.endswith(suffix):
                base = name[: -len(suffix)]
                break
        if base not in rendered and name not in rendered:
            stale.append(name)
    for name in stale:
        print(f"warning: documented but not found in sources: {name}")

    print(f"ok: {len(rendered)} exported metric families all documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
