//! Bench target: end-to-end native serving (`positron serve-bench`) —
//! logits-parity gate + HTTP round-trip + closed-loop throughput over
//! the in-tree blocked-GEMM backend. No artifacts or libxla needed.
//!
//! Run: `cargo bench --bench serve_native`

use positron::cli::{run_serve_bench, ServeBenchOpts};
use positron::coordinator::WeightFormat;

fn main() {
    let opts = ServeBenchOpts {
        requests: 4096,
        clients: 4,
        format: WeightFormat::Bp32,
        small: false,
        json: Some("BENCH_serve_native.json".to_string()),
    };
    match run_serve_bench(&opts) {
        Ok(lines) => {
            for line in lines {
                println!("{line}");
            }
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
