//! Bench: regenerate paper **Figs 6a/6b and 7** — the accuracy-vs-scale
//! tent plots — as CSV series + the quantitative claims.
//!
//! Run: `cargo bench --bench fig6_fig7_accuracy`

use positron::accuracy::{self, decimals_at};
use positron::formats::posit::{BP16_E3, BP32, P16, P32};
use positron::formats::{ieee::F32, takum::T32, Codec};

fn main() {
    // Fig 6a/6b: 16-bit curves.
    println!("Fig 6 — 16-bit accuracy (decimals) vs scale:");
    println!("{:>6} {:>10} {:>12}", "2^e", "posit16", "bposit16e3");
    for e in (-56..=56).step_by(8) {
        println!("{:>6} {:>10.2} {:>12.2}", e, decimals_at(&P16, e), decimals_at(&BP16_E3, e));
    }
    let floor = accuracy::curve(&BP16_E3, BP16_E3.min_scale(), BP16_E3.max_scale())
        .iter()
        .map(|p| p.decimals)
        .fold(f64::MAX, f64::min);
    println!("⟨16,6,3⟩ floor: {floor:.2} decimals (paper: ≥2); fovea cost vs ⟨16,2⟩: {:.2} decimals (paper: 0.3)",
        decimals_at(&P16, 0) - decimals_at(&BP16_E3, 0));

    // Fig 7: 32-bit curves.
    println!("\nFig 7 — 32-bit accuracy (decimals) vs scale:");
    println!("{:>6} {:>9} {:>9} {:>9} {:>9}", "2^e", "float32", "posit32", "takum32", "bposit32");
    for e in (-256..=256).step_by(16) {
        println!(
            "{:>6} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
            e,
            decimals_at(&F32, e),
            decimals_at(&P32, e),
            decimals_at(&T32, e),
            decimals_at(&BP32, e)
        );
    }

    let (lo, hi) = accuracy::golden_zone(&P32, &F32);
    let (blo, bhi) = accuracy::golden_zone(&BP32, &F32);
    println!("\nGolden Zones vs float32: posit32 2^{lo}..2^{hi} (paper ±20), b-posit32 2^{blo}..2^{bhi} (paper ±64)");
    println!(
        "bit patterns in b-posit32 zone: {:.1}% (paper 75%)",
        100.0 * accuracy::pattern_census(&BP32, blo, bhi + 1)
    );
    let (flo, fhi, _) = accuracy::fovea(&BP32);
    println!(
        "b-posit32 fovea: 2^{flo}..2^{fhi} (paper ±32) with {} frac bits (float32: 23)",
        BP32.frac_bits_at(0)
    );
}
