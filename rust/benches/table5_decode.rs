//! Bench: regenerate paper **Table 5** (decoder PPA at 16/32/64 bits) and
//! the **Fig 14** comparison series, with the paper's reported numbers
//! printed alongside for shape comparison.
//!
//! Run: `cargo bench --bench table5_decode`

use positron::cli::ppa_rows;
use positron::hw::report::format_table;

// (config, paper peak power mW, paper area µm², paper delay ns)
const PAPER: &[(&str, f64, f64, f64)] = &[
    ("float16 dec", 0.05, 315.0, 0.44),
    ("b-posit<16,6,5> dec", 0.11, 335.0, 0.39),
    ("posit<16,2> dec", 0.32, 705.0, 0.71),
    ("float32 dec", 0.13, 373.0, 0.75),
    ("b-posit<32,6,5> dec", 0.20, 553.0, 0.52),
    ("posit<32,2> dec", 0.94, 1890.0, 1.28),
    ("float64 dec", 0.38, 1034.0, 1.16),
    ("b-posit<64,6,5> dec", 0.37, 994.0, 0.65),
    ("posit<64,2> dec", 2.14, 4047.0, 1.50),
];

fn main() {
    let rows = ppa_rows(false, 60);
    let title = "Table 5 — decoder PPA (measured on the gate-level cost model)";
    println!("{}", format_table(title, &rows));

    println!("paper-reported values (freepdk45 post-layout) and measured/paper ratios:");
    println!(
        "{:<26} {:>9} {:>9} {:>9}   {:>7} {:>7} {:>7}",
        "design", "pwr(mW)", "area", "delay", "r_pwr", "r_area", "r_dly"
    );
    for (row, (name, pp, pa, pd)) in rows.iter().zip(PAPER) {
        println!(
            "{:<26} {:>9.2} {:>9.0} {:>9.2}   {:>7.2} {:>7.2} {:>7.2}",
            name,
            pp,
            pa,
            pd,
            row.peak_power_mw / pp,
            row.area_um2 / pa,
            row.delay_ns / pd
        );
    }

    // Fig 14 headline ratios (paper: −79% power, −71% area, −60% delay at 32).
    let (b32, p32) = (&rows[4], &rows[5]);
    println!("\nFig 14 ratios at 32 bits — b-posit vs posit decode:");
    println!(
        "  power  −{:.0}% (paper −79%)\n  area   −{:.0}% (paper −71%)\n  delay  −{:.0}% (paper −60%)",
        100.0 * (1.0 - b32.peak_power_mw / p32.peak_power_mw),
        100.0 * (1.0 - b32.area_um2 / p32.area_um2),
        100.0 * (1.0 - b32.delay_ns / p32.delay_ns)
    );
    let (f32r, b64, f64r) = (&rows[3], &rows[7], &rows[6]);
    println!(
        "  b-posit32 delay / float32 delay = {:.2} (paper 0.69)",
        b32.delay_ns / f32r.delay_ns
    );
    println!(
        "  b-posit64 delay / float64 delay = {:.2} (paper <0.56)",
        b64.delay_ns / f64r.delay_ns
    );
}
