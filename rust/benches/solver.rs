//! Bench: conjugate-gradient convergence per accumulation tier — f32/f64
//! fast reductions, BP-word quantized operators, and the quire-exact
//! tiers (one rounding per reduction) — on the 2D Poisson stencil and
//! random diagonally-dominant SPD operators, plus the Jacobi-
//! preconditioned f64 solve. Emits `BENCH_solver.json` and enforces the
//! SpMV bit-identity and quire-vs-fast iteration gates.
//!
//! Run: `cargo bench --bench solver`

fn main() {
    match positron::cli::run_solver_bench(&positron::cli::SolverBenchOpts::default()) {
        Ok(lines) => {
            for l in lines {
                println!("{l}");
            }
        }
        Err(e) => {
            eprintln!("solver-bench failed: {e}");
            std::process::exit(1);
        }
    }
}
