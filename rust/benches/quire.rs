//! Bench: quire (exact accumulator) MAC throughput — 800-bit paper sizing
//! vs lossless sizing vs naive round-each-step posit arithmetic, plus the
//! accuracy payoff on an ill-conditioned dot product.
//!
//! Run: `cargo bench --bench quire`

use positron::formats::posit::BP32;
use positron::formats::{op_add, op_mul, Decoded, Quire};
use positron::harness::Bencher;
use positron::testutil::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(99);
    let n = 1024;
    let xs: Vec<Decoded> = (0..n).map(|_| Decoded::from_f64((rng.f64() - 0.5) * 100.0)).collect();
    let ys: Vec<Decoded> = (0..n).map(|_| Decoded::from_f64((rng.f64() - 0.5) * 100.0)).collect();

    b.bench("quire/paper800/dot1024", || {
        let mut q = Quire::paper_800(&BP32);
        for (x, y) in xs.iter().zip(&ys) {
            q.add_product(x, y);
        }
        q.to_posit(&BP32)
    });
    b.bench("quire/exact/dot1024", || {
        let mut q = Quire::exact_for(&BP32);
        for (x, y) in xs.iter().zip(&ys) {
            q.add_product(x, y);
        }
        q.to_posit(&BP32)
    });
    let xb: Vec<u64> = xs.iter().map(|d| BP32.encode(d)).collect();
    let yb: Vec<u64> = ys.iter().map(|d| BP32.encode(d)).collect();
    b.bench("naive/round-each-step/dot1024", || {
        let mut acc = 0u64;
        for (x, y) in xb.iter().zip(&yb) {
            acc = op_add(&BP32, acc, op_mul(&BP32, *x, *y));
        }
        acc
    });

    println!("{}", b.table("quire MAC throughput (1024-element dot products)"));
    for r in b.results() {
        println!("{:<44} {:>10.1} MMAC/s", r.name, 1024.0 / r.mean_ns * 1e3);
    }

    // Accuracy payoff: ill-conditioned dot product.
    let big = 1e15;
    let ill: Vec<(f64, f64)> = vec![(big, 1.0), (3.5, 1.0), (-big, 1.0), (0.25, 1.0)];
    let mut q = Quire::exact_for(&BP32);
    let mut naive = 0u64;
    for (x, y) in &ill {
        let (dx, dy) = (Decoded::from_f64(*x), Decoded::from_f64(*y));
        q.add_product(&dx, &dy);
        naive = op_add(&BP32, naive, op_mul(&BP32, BP32.encode(&dx), BP32.encode(&dy)));
    }
    println!(
        "\nill-conditioned Σxᵢyᵢ (exact 3.75): quire = {}, naive = {}",
        BP32.to_f64(q.to_posit(&BP32)),
        BP32.to_f64(naive)
    );
}
