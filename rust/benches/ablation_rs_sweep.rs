//! Bench: ablation over the regime bound rS (the paper fixes rS = 6).
//! Sweeps rS ∈ {4,5,6,7,8} at n = 32, eS = 5, reporting the PPA of the
//! decoder/encoder pair and the numerics (dynamic range, worst-case
//! accuracy, fovea width) — the trade-off DESIGN.md calls out.
//!
//! Run: `cargo bench --bench ablation_rs_sweep`

use positron::accuracy;
use positron::formats::posit::PositSpec;
use positron::formats::Codec;
use positron::hw::designs::{bposit_dec, bposit_enc, power_vectors, DesignUnderTest};
use positron::hw::report::measure;

fn main() {
    println!(
        "{:<6} {:>10} {:>10} {:>10} {:>10} {:>12} {:>10} {:>10}",
        "rS", "dec_area", "dec_dly", "enc_area", "enc_dly", "range 2^±", "min_dec", "fovea±"
    );
    for rs in [4u32, 5, 6, 7, 8] {
        let spec = PositSpec::bounded(32, rs, 5);
        let dec = bposit_dec::build(&spec);
        let enc = bposit_enc::build(&spec);
        let dr = measure("d", &dec, &power_vectors(&DesignUnderTest::PositDec(&spec), 40));
        let er = measure("e", &enc, &power_vectors(&DesignUnderTest::PositEnc(&spec), 40));
        let curve = accuracy::curve(&spec, spec.min_scale(), spec.max_scale());
        let min_dec = curve.iter().map(|p| p.decimals).fold(f64::MAX, f64::min);
        let (flo, fhi, _) = accuracy::fovea(&spec);
        println!(
            "{:<6} {:>10.1} {:>10.3} {:>10.1} {:>10.3} {:>12} {:>10.2} {:>8}..{}",
            rs,
            dr.area_um2,
            dr.delay_ns,
            er.area_um2,
            er.delay_ns,
            spec.max_exp() + 1,
            min_dec,
            flo,
            fhi
        );
    }
    println!("\nrS=6 (paper's choice): 5 regime sizes, 2^±192 range, ≥20 frac bits — the");
    println!("sweep shows the hardware cost is nearly flat in rS while range grows 2^32");
    println!("per step and worst-case accuracy falls ~0.3 decimals per step.");
}
