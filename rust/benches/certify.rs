//! Bench: error-certified serving — interval-certification probes on the
//! bp32/p32/bp64 tiers (certified bound width vs observed quantization
//! error, bit-pinned against the Python `Fraction` mirror) plus the
//! serving overhead of `--certify-rate 16` sampling vs an uncertified
//! twin. Emits `BENCH_certify.json` and enforces the containment,
//! violation-counter, width-ratio, and transliteration-pin gates.
//!
//! Run: `cargo bench --bench certify`

fn main() {
    let opts = positron::cli::CertifyBenchOpts {
        requests: 2048,
        clients: 4,
        certify_rate: 16,
        small: false,
        json: Some("BENCH_certify.json".to_string()),
    };
    match positron::cli::run_certify_bench(&opts) {
        Ok(lines) => {
            for l in lines {
                println!("{l}");
            }
        }
        Err(e) => {
            eprintln!("certify-bench failed: {e}");
            std::process::exit(1);
        }
    }
}
