//! Bench: regenerate paper **Table 6** (encoder PPA at 16/32/64 bits) and
//! the **Fig 15** comparison series, with the paper's numbers alongside.
//!
//! Run: `cargo bench --bench table6_encode`

use positron::cli::ppa_rows;
use positron::hw::report::format_table;

const PAPER: &[(&str, f64, f64, f64)] = &[
    ("float16 enc", 0.06, 297.0, 0.29),
    ("b-posit<16,6,5> enc", 0.13, 418.0, 0.39),
    ("posit<16,2> enc", 0.26, 610.0, 0.71),
    ("float32 enc", 0.16, 777.0, 0.40),
    ("b-posit<32,6,5> enc", 0.23, 711.0, 0.43),
    ("posit<32,2> enc", 0.72, 1330.0, 0.77),
    ("float64 enc", 0.47, 1878.0, 0.53),
    ("b-posit<64,6,5> enc", 0.45, 1278.0, 0.46),
    ("posit<64,2> enc", 1.90, 3093.0, 1.17),
];

fn main() {
    let rows = ppa_rows(true, 60);
    let title = "Table 6 — encoder PPA (measured on the gate-level cost model)";
    println!("{}", format_table(title, &rows));

    println!("paper-reported values and measured/paper ratios:");
    println!(
        "{:<26} {:>9} {:>9} {:>9}   {:>7} {:>7} {:>7}",
        "design", "pwr(mW)", "area", "delay", "r_pwr", "r_area", "r_dly"
    );
    for (row, (name, pp, pa, pd)) in rows.iter().zip(PAPER) {
        println!(
            "{:<26} {:>9.2} {:>9.0} {:>9.2}   {:>7.2} {:>7.2} {:>7.2}",
            name, pp, pa, pd,
            row.peak_power_mw / pp,
            row.area_um2 / pa,
            row.delay_ns / pd
        );
    }

    let (b32, p32) = (&rows[4], &rows[5]);
    println!("\nFig 15 ratios at 32 bits — b-posit vs posit encode:");
    println!(
        "  power  −{:.0}% (paper −68%)\n  area   −{:.0}% (paper −46%)\n  delay  −{:.0}% (paper −44%)",
        100.0 * (1.0 - b32.peak_power_mw / p32.peak_power_mw),
        100.0 * (1.0 - b32.area_um2 / p32.area_um2),
        100.0 * (1.0 - b32.delay_ns / p32.delay_ns)
    );
    let (f64r, b64) = (&rows[6], &rows[7]);
    println!(
        "  b-posit64 area / float64 area = {:.2} (paper 0.68: \"almost 32% smaller\")",
        b64.area_um2 / f64r.area_um2
    );
}
