//! Bench: regenerate paper **Fig 16** — worst-case energy per two-operand
//! operation, energy = (dec_delay + enc_delay) × (2·dec_power + enc_power).
//!
//! Run: `cargo bench --bench fig16_energy`

use positron::cli::ppa_rows;

fn main() {
    let dec = ppa_rows(false, 60);
    let enc = ppa_rows(true, 60);
    let energy =
        |i: usize| {
            (dec[i].delay_ns + enc[i].delay_ns)
                * (2.0 * dec[i].peak_power_mw + enc[i].peak_power_mw)
        };

    println!("Fig 16 — worst-case decode+encode energy per op (pJ):");
    println!("{:<8} {:>10} {:>10} {:>10}", "width", "float", "b-posit", "posit");
    for (i, n) in [16u32, 32, 64].iter().enumerate() {
        println!(
            "{:<8} {:>10.2} {:>10.2} {:>10.2}",
            n,
            energy(i * 3),
            energy(i * 3 + 1),
            energy(i * 3 + 2)
        );
    }
    let r32 = energy(4) / energy(3);
    let r64 = energy(7) / energy(6);
    println!("\nb-posit/float energy ratio: 32-bit {r32:.2} (paper ≈1.0 — tied), 64-bit {r64:.2} (paper ≈0.60 — 40% less)");
    println!(
        "b-posit/posit  energy ratio: 32-bit {:.2}, 64-bit {:.2}",
        energy(4) / energy(5),
        energy(7) / energy(8)
    );
}
