//! Bench: end-to-end serving throughput/latency of the three-layer stack —
//! the quantized model under a closed-loop multi-client load, plus the
//! bare model-execute and quantizer costs for attribution.
//!
//! Run: `make artifacts && cargo bench --bench e2e_inference`

use std::sync::Arc;
use std::time::{Duration, Instant};

use positron::coordinator::{quantizer, InferenceServer, ServerConfig};
use positron::harness::Bencher;
use positron::runtime::{
    artifacts_available, default_artifact_dir, lit_f32_2d, ModelWeights, Runtime,
};

fn main() -> positron::error::Result<()> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::cpu(&dir)?;
    let w = ModelWeights::load(&rt)?;

    // 1. Bare model execution cost (batch of 64).
    let mut b = Bencher::new();
    let model = rt.load("model_bposit.hlo.txt")?;
    let mut args = vec![lit_f32_2d(&w.golden_x, w.batch, w.d)?];
    args.extend(w.bposit_arg_literals()?);
    b.bench("model_bposit/execute/batch64", || model.run_f32(&args).unwrap());
    let model_f = rt.load("model_f32.hlo.txt")?;
    let mut args_f = vec![lit_f32_2d(&w.golden_x, w.batch, w.d)?];
    args_f.extend(w.f32_arg_literals()?);
    b.bench("model_f32/execute/batch64", || model_f.run_f32(&args_f).unwrap());

    // 2. Quantizer cost per request (64 features).
    let feats = w.golden_x[..w.d].to_vec();
    b.bench("quantizer/roundtrip/64feat", || quantizer::roundtrip(&feats));
    println!("{}", b.table("component costs"));
    drop(rt);

    // 3. Closed-loop serving: sweep client counts.
    println!("closed-loop serving (b-posit model):");
    println!(
        "{:>8} {:>12} {:>10} {:>10} {:>11}",
        "clients", "req/s", "p50 µs", "p99 µs", "mean batch"
    );
    for clients in [1usize, 4, 16] {
        let server = Arc::new(InferenceServer::start(
            dir.clone(),
            ServerConfig { max_wait: Duration::from_micros(500), ..Default::default() },
        )?);
        let per_client = 400;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..clients {
            let srv = server.clone();
            let w2 = w.clone();
            handles.push(std::thread::spawn(move || {
                let mut done = 0;
                for i in 0..per_client {
                    let g = (c + i * 7) % w2.golden_y.len();
                    let f = w2.golden_x[g * w2.d..(g + 1) * w2.d].to_vec();
                    if srv.infer(f).is_ok() {
                        done += 1;
                    }
                }
                done
            }));
        }
        let done: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let wall = t0.elapsed().as_secs_f64();
        let m = server.metrics().snapshot();
        println!(
            "{:>8} {:>12.0} {:>10} {:>10} {:>11.1}",
            clients,
            done as f64 / wall,
            m.p50_us,
            m.p99_us,
            m.mean_batch
        );
    }
    Ok(())
}
