//! Bench: serial vs sharded (PALLAS_THREADS) blocked GEMM — the f32 fast
//! path, the decode-fused quantized-weight path, and the 800-bit
//! quire-exact path — with GFLOP-equivalents and a serial/sharded
//! bit-identity check. Emits `BENCH_vector_gemm.json`.
//!
//! Run: `cargo bench --bench vector_gemm`

fn main() {
    match positron::cli::run_gemm_bench(&[64, 128, 256, 512], 128, Some("BENCH_vector_gemm.json")) {
        Ok(lines) => {
            for l in lines {
                println!("{l}");
            }
        }
        Err(e) => {
            eprintln!("gemm-bench failed: {e}");
            std::process::exit(1);
        }
    }
}
