//! Bench: software codec hot path — decode/encode/add/mul throughput per
//! format (the L3 quantizer's cost driver; see EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench sw_codec`

use positron::formats::posit::{BP32, P32};
use positron::formats::{ieee::F32, op_add, op_mul, takum::T32, Codec, Decoded};
use positron::harness::Bencher;
use positron::testutil::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(7);
    let words32: Vec<u64> = (0..4096).map(|_| rng.next_u64() & 0xffff_ffff).collect();
    let vals: Vec<f64> = (0..4096).map(|_| (rng.f64() - 0.5) * 2000.0).collect();
    let valsf: Vec<f32> = vals.iter().map(|&x| x as f32).collect();

    // Decode throughput (per 4096-element block).
    for (name, c) in [("bp32", &BP32 as &dyn Codec), ("p32", &P32), ("f32", &F32), ("t32", &T32)] {
        b.bench(&format!("decode/{name}/4096"), || {
            let mut acc = 0i32;
            for &w in &words32 {
                acc = acc.wrapping_add(c.decode(w).exp);
            }
            acc
        });
    }

    // Encode throughput.
    for (name, c) in [("bp32", &BP32 as &dyn Codec), ("p32", &P32), ("f32", &F32), ("t32", &T32)] {
        b.bench(&format!("encode/{name}/4096"), || {
            let mut acc = 0u64;
            for &x in &vals {
                acc = acc.wrapping_add(c.encode(&Decoded::from_f64(x)));
            }
            acc
        });
    }

    // Arithmetic (decode → exact op → encode), the full ALU path.
    let pw: Vec<u64> = vals.iter().map(|&x| BP32.from_f64(x)).collect();
    b.bench("add/bp32/4096", || {
        let mut acc = 0u64;
        for pair in pw.chunks(2) {
            acc = acc.wrapping_add(op_add(&BP32, pair[0], pair[1]));
        }
        acc
    });
    b.bench("mul/bp32/4096", || {
        let mut acc = 0u64;
        for pair in pw.chunks(2) {
            acc = acc.wrapping_add(op_mul(&BP32, pair[0], pair[1]));
        }
        acc
    });

    // The L3 quantizer hot path: general codec (§Perf "before") vs the
    // specialized ⟨32,6,5⟩ fast path actually used on the request path.
    b.bench("quantizer/general/roundtrip4096", || {
        let mut acc = 0.0f32;
        for &x in &valsf {
            acc += positron::coordinator::quantizer::dequantize_one_general(
                positron::coordinator::quantizer::quantize_one_general(x),
            );
        }
        acc
    });
    b.bench("quantizer/fast/roundtrip4096", || {
        positron::coordinator::quantizer::roundtrip(&valsf)
    });

    println!("{}", b.table("software codec throughput (4096-element blocks)"));
    // Per-element rates.
    for r in b.results() {
        println!("{:<44} {:>10.1} Melem/s", r.name, 4096.0 / r.mean_ns * 1e3 / 2.0_f64.powi(0));
    }
}
