//! Bench: the 64-bit lane codec (BP64/P64 over u64 streams) vs the
//! general codec, plus the f64 dot-kernel family — the 64-bit rung of
//! the serving throughput sweep. Emits `BENCH_vector_codec64.json`
//! (elems/s + per-stage speedups + sharded bit-identity flag).
//!
//! Run: `cargo bench --bench vector_codec64`

fn main() {
    // Sweep block sizes: cache-resident, L2-scale, and streaming.
    for len in [4096usize, 65536, 1 << 20] {
        // Only the canonical 64k block writes the JSON artifact.
        let json = if len == 65536 { Some("BENCH_vector_codec64.json") } else { None };
        match positron::cli::run_vector_bench64(len, json) {
            Ok(lines) => {
                for l in lines {
                    println!("{l}");
                }
            }
            Err(e) => {
                eprintln!("vector-bench64 failed at len {len}: {e}");
                std::process::exit(1);
            }
        }
        println!();
    }
}
