//! Bench: branch-free vector codec vs the scalar fast path / general
//! codec, plus the dot-kernel family — the serving hot path's throughput
//! sweep. Emits `BENCH_vector_codec.json` (elems/s + per-stage speedups).
//!
//! Run: `cargo bench --bench vector_codec`

fn main() {
    // Sweep block sizes: cache-resident, L2-scale, and streaming.
    for len in [4096usize, 65536, 1 << 20] {
        // Only the canonical 64k block writes the JSON artifact.
        let json = if len == 65536 { Some("BENCH_vector_codec.json") } else { None };
        match positron::cli::run_vector_bench(len, json) {
            Ok(lines) => {
                for l in lines {
                    println!("{l}");
                }
            }
            Err(e) => {
                eprintln!("vector-bench failed at len {len}: {e}");
                std::process::exit(1);
            }
        }
        println!();
    }
}
