//! Property-based tests over the formats layer (testutil's forall runner;
//! the vendored dependency set has no proptest crate).

use positron::formats::posit::PositSpec;
use positron::formats::{ieee::IeeeSpec, math, op_add, op_mul, takum::TakumSpec, Codec, Decoded};
use positron::testutil::{forall, Rng};

/// A random but valid posit-family spec.
fn random_spec(rng: &mut Rng) -> PositSpec {
    let n = 3 + rng.below(62) as u32; // 3..=64
    let max_rs = n - 1;
    let rs = 2 + rng.below((max_rs - 1).max(1) as u64) as u32;
    let es = rng.below(8) as u32;
    PositSpec::bounded(n, rs.min(max_rs), es)
}

#[test]
fn prop_roundtrip_decode_encode_any_spec() {
    forall("decode∘encode = id over random specs", 400, |rng| {
        let spec = random_spec(rng);
        for _ in 0..50 {
            let bits = rng.next_u64() & spec.mask();
            let d = spec.decode(bits);
            let back = spec.encode(&d);
            if back != bits {
                return Err(format!("{spec:?}: {bits:#x} → {back:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_monotonic_any_spec() {
    forall("pattern order = value order", 200, |rng| {
        let spec = random_spec(rng);
        for _ in 0..30 {
            let a = rng.next_u64() & spec.mask();
            let b = rng.next_u64() & spec.mask();
            if a == spec.nar() || b == spec.nar() {
                continue;
            }
            let (va, vb) = (spec.to_f64(a), spec.to_f64(b));
            let cmp_val = va.partial_cmp(&vb).unwrap();
            let cmp_bits = spec.cmp_bits(a, b);
            // Distinct patterns always decode to distinct values (injective),
            // except possibly at f64 rounding of 64-bit formats — compare via
            // ordering only when the f64s differ.
            if va != vb && cmp_val != cmp_bits {
                return Err(format!("{spec:?}: order mismatch {a:#x} vs {b:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_add_commutes_and_neg_involution() {
    forall("a+b = b+a and −(−x) = x", 300, |rng| {
        let spec = random_spec(rng);
        let a = rng.next_u64() & spec.mask();
        let b = rng.next_u64() & spec.mask();
        if op_add(&spec, a, b) != op_add(&spec, b, a) {
            return Err(format!("{spec:?}: add not commutative"));
        }
        // negate = 2's complement of the word.
        let na = a.wrapping_neg() & spec.mask();
        let nna = na.wrapping_neg() & spec.mask();
        if nna != a {
            return Err("neg not involutive".into());
        }
        // and the decoded value flips sign exactly (NaR/zero fixed points).
        let (da, dna) = (spec.decode(a), spec.decode(na));
        if da.is_normal() && (da.to_f64() + dna.to_f64()).abs() > 0.0 && da.exp < 500 {
            let sum = math::add(&da, &dna);
            if !sum.is_zero() {
                return Err(format!("{spec:?}: x + (−x) ≠ 0 for {a:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mul_identity_and_sign() {
    forall("x·1 = x; sign(a·b) = sign(a)⊕sign(b)", 300, |rng| {
        let spec = random_spec(rng);
        let one = spec.from_f64(1.0);
        let a = rng.next_u64() & spec.mask();
        if a != spec.nar() && op_mul(&spec, a, one) != a {
            return Err(format!("{spec:?}: {a:#x}·1 ≠ {a:#x}"));
        }
        Ok(())
    });
}

#[test]
fn prop_ieee_roundtrip_random_spec() {
    forall("ieee decode∘encode = id", 300, |rng| {
        let eb = 3 + rng.below(9) as u32;
        let n = (eb + 3 + rng.below(30) as u32).min(64);
        let spec = IeeeSpec::new(n, eb);
        for _ in 0..40 {
            let bits = rng.next_u64() & spec.mask();
            let d = spec.decode(bits);
            if d.is_nan() {
                continue;
            }
            if spec.encode(&d) != bits {
                return Err(format!("ieee<{n},{eb}>: {bits:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_takum_roundtrip_any_width() {
    forall("takum decode∘encode = id", 200, |rng| {
        let n = 12 + rng.below(53) as u32;
        let spec = TakumSpec::new(n);
        for _ in 0..40 {
            let bits = rng.next_u64() & spec.mask();
            let d = spec.decode(bits);
            if d.is_nan() {
                continue;
            }
            if spec.encode(&d) != bits {
                return Err(format!("takum{n}: {bits:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_encode_nearest_no_value_closer() {
    // Faithful rounding: |encode(x) − x| ≤ one pattern step in either
    // direction (checked against the two neighbouring patterns).
    //
    // Restricted to binades where at least one fraction bit survives: when
    // the n-bit cut falls inside the exponent field, the Posit™ Standard's
    // pattern-space RNE intentionally differs from value-space nearest
    // (geometric vs arithmetic midpoints), so "nearest value" is not the
    // contract there.
    forall("encode is nearest-or-adjacent", 200, |rng| {
        let spec = random_spec(rng);
        let x = rng.nasty_f64();
        if !x.is_finite() || x == 0.0 {
            return Ok(());
        }
        let scale = x.abs().log2().floor();
        if !(-1000.0..1000.0).contains(&scale) || spec.frac_bits_at(scale as i32) == 0 {
            return Ok(());
        }
        let bits = spec.encode(&Decoded::from_f64(x));
        if bits == spec.nar() || bits == 0 {
            return Ok(());
        }
        let err = (spec.to_f64(bits) - x).abs();
        for nb in [bits.wrapping_add(1) & spec.mask(), bits.wrapping_sub(1) & spec.mask()] {
            if nb == spec.nar() || nb == 0 {
                continue;
            }
            let nerr = (spec.to_f64(nb) - x).abs();
            // Allow exact ties (RNE picks the even pattern).
            if nerr < err * (1.0 - 1e-12) {
                return Err(format!(
                    "{spec:?}: {x:e} → {bits:#x} (err {err:e}) but neighbour {nb:#x} closer ({nerr:e})"
                ));
            }
        }
        Ok(())
    });
}

// ----------------------------------------------------------------------
// Format-generic lane-codec properties (ISSUE-3 satellite), parameterized
// over the named serving formats at every width. All five run through the
// 64-bit generic lane path (`vector::codec64`), whose n ≤ 32 behavior is
// separately pinned to the 32-bit lanes — so one property covers the
// whole family.
// ----------------------------------------------------------------------

use positron::vector::codec64;

const NAMED_SPECS: [PositSpec; 5] = [
    positron::formats::posit::BP16,
    positron::formats::posit::BP32,
    positron::formats::posit::P32,
    positron::formats::posit::BP64,
    positron::formats::posit::P64,
];

#[test]
fn prop_named_roundtrip_error_within_half_ulp() {
    // |decode(encode(x)) − x| ≤ ½ ulp of the *decoded* spec value, where
    // ulp(w) = 2^(T − frac_bits_at(T)). Restricted to the interior of the
    // format's range (no saturation) — and when the format out-resolves
    // f64 (frac_bits > 52) the f64 input is exactly representable, so the
    // error is 0 by construction.
    forall("named-spec half-ulp roundtrip", 300, |rng| {
        for spec in NAMED_SPECS {
            for _ in 0..40 {
                let x = rng.nasty_f64();
                if !x.is_finite() || x == 0.0 || x.abs() < f64::MIN_POSITIVE {
                    continue;
                }
                let t = x.abs().log2().floor() as i32;
                // Interior only: one full regime step away from the ends.
                let step = 1 << spec.es;
                if t <= spec.min_exp() + step || t >= spec.max_exp() - step {
                    continue;
                }
                let w = codec64::encode_word(&spec, x);
                let d = spec.decode(w);
                let fb = spec.frac_bits_at(d.exp) as i32;
                if fb == 0 {
                    continue; // exponent-field cut: pattern-space ≠ value-space
                }
                let y = codec64::decode_word(&spec, w);
                let half_ulp = f64::powi(2.0, d.exp - fb - 1);
                let err = (y - x).abs();
                if err > half_ulp * (1.0 + 1e-12) {
                    return Err(format!(
                        "{spec:?}: {x:e} → {w:#x} → {y:e}, err {err:e} > ½ulp {half_ulp:e}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_named_sign_symmetry() {
    // encode(−x) is the two's complement of encode(x); decode of the
    // two's complement is −decode (posits have one unsigned zero and one
    // NaR, both fixed points of negation).
    forall("named-spec sign symmetry", 300, |rng| {
        for spec in NAMED_SPECS {
            let x = rng.nasty_f64();
            if !x.is_nan() {
                let pos = codec64::encode_word(&spec, x);
                let neg = codec64::encode_word(&spec, -x);
                if neg != pos.wrapping_neg() & spec.mask() && pos != spec.nar() {
                    return Err(format!("{spec:?}: encode(−{x:e}) ≠ ⁻encode({x:e})"));
                }
            }
            let w = rng.next_u64() & spec.mask();
            if w != 0 && w != spec.nar() {
                let a = codec64::decode_word(&spec, w);
                let b = codec64::decode_word(&spec, w.wrapping_neg() & spec.mask());
                if a.is_nan() != b.is_nan() {
                    return Err(format!("{spec:?}: NaN asymmetry at {w:#x}"));
                }
                if !a.is_nan() && b.to_bits() != (-a).to_bits() {
                    return Err(format!("{spec:?}: decode(⁻{w:#x}) = {b:e} ≠ −{a:e}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_named_nar_uniqueness() {
    // Exactly one pattern decodes to NaR; encode produces it only for
    // NaN/Inf inputs.
    forall("named-spec NaR uniqueness", 300, |rng| {
        for spec in NAMED_SPECS {
            let w = rng.next_u64() & spec.mask();
            let is_nan = codec64::decode_word(&spec, w).is_nan();
            if is_nan != (w == spec.nar()) {
                return Err(format!("{spec:?}: NaR/NaN mismatch at {w:#x}"));
            }
            let x = rng.nasty_f64();
            let enc = codec64::encode_word(&spec, x);
            if x.is_finite() && enc == spec.nar() {
                return Err(format!("{spec:?}: finite {x:e} encoded to NaR"));
            }
            if !x.is_finite() && enc != spec.nar() {
                return Err(format!("{spec:?}: non-finite {x:e} missed NaR"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_named_order_preserved_under_twos_complement_compare() {
    forall("named-spec ordering", 300, |rng| {
        for spec in NAMED_SPECS {
            let a = rng.next_u64() & spec.mask();
            let b = rng.next_u64() & spec.mask();
            if a == spec.nar() || b == spec.nar() {
                continue;
            }
            let (va, vb) = (codec64::decode_word(&spec, a), codec64::decode_word(&spec, b));
            // Compare only when the f64 images differ (64-bit formats can
            // collapse neighbours onto one f64).
            if va != vb && va.partial_cmp(&vb).unwrap() != spec.cmp_bits(a, b) {
                return Err(format!("{spec:?}: order mismatch {a:#x} vs {b:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_generic_engine_and_dispatch_match_per_width_codecs() {
    // The ISSUE-5 invariant: the width-generic lane engine and the routed
    // dispatch handle are bit-identical to the per-width codec paths on
    // every named serving format — generic ≡ named at both widths,
    // through the *new* API.
    use positron::vector::{codec, dispatch_spec, LaneCodec};
    forall("generic engine ≡ named codecs", 300, |rng| {
        for spec in NAMED_SPECS {
            let x = rng.nasty_f64();
            let w = rng.next_u64() & spec.mask();
            // Routed handle ≡ the 64-bit lane path (its superset tier).
            let dc = dispatch_spec(&spec);
            if dc.encode_one(x) != codec64::encode_word(&spec, x) {
                return Err(format!("{spec:?}: dispatch encode differs at {x:e}"));
            }
            let (a, b) = (dc.decode_one(w), codec64::decode_word(&spec, w));
            if !(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())) {
                return Err(format!("{spec:?}: dispatch decode differs at {w:#x}"));
            }
            // Generic engine at the 64-bit width ≡ the named module.
            let c64 = LaneCodec::<f64>::new(spec).map_err(|e| e.to_string())?;
            if c64.encode_word(x) != codec64::encode_word(&spec, x) {
                return Err(format!("{spec:?}: engine encode differs at {x:e}"));
            }
            // Narrow specs: the 32-bit engine ≡ the named 32-bit module
            // (f32 exchange contract).
            if spec.n <= 32 {
                let c32 = LaneCodec::<f32>::new(spec).map_err(|e| e.to_string())?;
                let xf = x as f32;
                if c32.encode_word(xf) != codec::encode_word(&spec, xf) {
                    return Err(format!("{spec:?}: 32-bit engine encode differs at {xf:e}"));
                }
                let w32 = w as u32;
                let (a, b) = (c32.decode_word(w32), codec::decode_word(&spec, w32));
                if !(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())) {
                    return Err(format!("{spec:?}: 32-bit engine decode differs at {w32:#x}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_math_add_associates_with_exact_operands() {
    // With small-integer operands everything is exact, so association holds.
    forall("exact-int association", 200, |rng| {
        let a = Decoded::from_f64((rng.below(1000) as f64) - 500.0);
        let b = Decoded::from_f64((rng.below(1000) as f64) - 500.0);
        let c = Decoded::from_f64((rng.below(1000) as f64) - 500.0);
        let l = math::add(&math::add(&a, &b), &c).to_f64();
        let r = math::add(&a, &math::add(&b, &c)).to_f64();
        if l != r {
            return Err(format!("({} + {}) + {} mismatch", a.to_f64(), b.to_f64(), c.to_f64()));
        }
        Ok(())
    });
}
