//! `PALLAS_THREADS` environment-variable resolution. This test binary
//! owns the variable: integration-test binaries run as separate
//! processes, and this is the only test in the binary, so the process
//! env mutation cannot race another test.

use positron::vector::parallel;

#[test]
fn pallas_threads_env_resolution() {
    // Unset → auto default: at least 1, at most the cap.
    std::env::remove_var("PALLAS_THREADS");
    let auto = parallel::num_threads();
    assert!((1..=parallel::MAX_THREADS).contains(&auto), "auto = {auto}");

    // Explicit positive value is honored verbatim (clamped to the cap).
    std::env::set_var("PALLAS_THREADS", "7");
    assert_eq!(parallel::num_threads(), 7);
    std::env::set_var("PALLAS_THREADS", "1");
    assert_eq!(parallel::num_threads(), 1);
    std::env::set_var("PALLAS_THREADS", "999999");
    assert_eq!(parallel::num_threads(), parallel::MAX_THREADS);

    // Invalid and zero values fall back to the auto default.
    for bad in ["0", "-3", "lots", ""] {
        std::env::set_var("PALLAS_THREADS", bad);
        assert_eq!(parallel::num_threads(), auto, "fallback for {bad:?}");
    }

    // The sharded entry points run correctly under an env-set count —
    // the end-to-end path the env var exists for.
    std::env::set_var("PALLAS_THREADS", "3");
    let xs: Vec<f32> = (0..40_000).map(|i| (i as f32 - 20_000.0) * 0.125).collect();
    let mut rt = xs.clone();
    positron::vector::parallel::bp32_roundtrip_in_place(&mut rt);
    assert_eq!(rt, xs, "fovea values survive the sharded roundtrip exactly");
    std::env::remove_var("PALLAS_THREADS");
}
