//! Coordinator integration — **ungated**: the native backend needs no
//! libxla and no build-time artifacts (a deterministic synthetic model
//! stands in for `weights.json`), so the full serving stack — batching
//! worker, backpressure, deadlines, failure answers, real HTTP listener
//! — runs under `cargo test` with default features.
//!
//! The PJRT-specific tests (compiled-model goldens) live in the
//! feature-gated module at the bottom.

use std::sync::Arc;
use std::time::Duration;

use positron::coordinator::backend::{
    reference_forward, stage_inputs, synth_weights, InferenceBackend, WeightFormat,
};
use positron::coordinator::{http, quantizer, InferError, InferenceServer, ServerConfig};
use positron::error::{anyhow, Result};
use positron::runtime::ModelWeights;

fn model() -> ModelWeights {
    synth_weights(12, 16, 5, 24, 0x90125)
}

fn start_native(w: &ModelWeights, cfg: ServerConfig) -> InferenceServer {
    InferenceServer::start_native(w.clone(), cfg).expect("native server start")
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn native_serving_matches_scalar_reference_bitwise() {
    let w = model();
    let server = start_native(&w, ServerConfig::default());
    assert_eq!(server.dims, (w.d, w.c));
    let mut correct = 0;
    for g in 0..w.batch {
        let feats = w.golden_x[g * w.d..(g + 1) * w.d].to_vec();
        let want = reference_forward(&w, WeightFormat::Bp32, &quantizer::roundtrip(&feats));
        let resp = server.infer(feats).unwrap();
        assert_eq!(bits(&resp.logits), bits(&want), "row {g}");
        let argmax =
            resp.logits.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        if argmax == w.golden_y[g] as usize {
            correct += 1;
        }
    }
    // The synthetic goldens are generated from the same reference pass.
    assert_eq!(correct, w.batch);
}

#[test]
fn native_f32_and_bp64_tiers_match_their_references() {
    let w = model();
    for format in [WeightFormat::F32, WeightFormat::Bp64] {
        let server = start_native(&w, ServerConfig::for_format(format));
        for g in 0..4 {
            let feats = w.golden_x[g * w.d..(g + 1) * w.d].to_vec();
            let want = reference_forward(&w, format, &stage_inputs(format, &feats));
            let resp = server.infer(feats).unwrap();
            assert_eq!(bits(&resp.logits), bits(&want), "{} row {g}", format.name());
        }
    }
}

#[test]
fn quantize_inputs_toggle_changes_nothing_for_fovea_inputs() {
    // Golden features sit on the 1/64 grid: the bp32 roundtrip is exact,
    // so both configurations must return identical logits.
    let w = model();
    let a = start_native(&w, ServerConfig { quantize_inputs: true, ..Default::default() });
    let b = start_native(&w, ServerConfig { quantize_inputs: false, ..Default::default() });
    let feats = w.golden_x[..w.d].to_vec();
    let ra = a.infer(feats.clone()).unwrap();
    let rb = b.infer(feats).unwrap();
    assert_eq!(bits(&ra.logits), bits(&rb.logits));
}

#[test]
fn rejects_wrong_feature_count() {
    let w = model();
    let server = start_native(&w, ServerConfig::default());
    match server.try_infer(vec![1.0; 3]) {
        Err(InferError::BadRequest(m)) => assert!(m.contains("features"), "{m}"),
        other => panic!("expected BadRequest, got {other:?}"),
    }
}

#[test]
fn batching_coalesces_concurrent_clients() {
    let w = model();
    let server = Arc::new(start_native(
        &w,
        ServerConfig { max_wait: Duration::from_millis(20), ..Default::default() },
    ));
    let mut handles = Vec::new();
    for t in 0..16 {
        let srv = server.clone();
        let feats = w.golden_x[(t % 4) * w.d..((t % 4) + 1) * w.d].to_vec();
        handles.push(std::thread::spawn(move || srv.infer(feats).unwrap()));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics().snapshot();
    assert_eq!(m.requests, 16);
    // With a 20 ms window, 16 concurrent requests should share batches.
    assert!(m.mean_batch > 1.5, "batching ineffective: mean {}", m.mean_batch);
    assert!(m.batches < 16);
}

/// Test backend: correct dims, but every batch takes `delay` — makes
/// queue states deterministic enough to probe backpressure and deadlines.
struct SlowBackend {
    d: usize,
    c: usize,
    delay: Duration,
    out: Vec<f32>,
}

impl InferenceBackend for SlowBackend {
    fn name(&self) -> &'static str {
        "test-slow"
    }
    fn dims(&self) -> (usize, usize) {
        (self.d, self.c)
    }
    fn max_batch(&self) -> usize {
        usize::MAX
    }
    fn run(&mut self, _x: &[f32], rows: usize) -> Result<&[f32]> {
        std::thread::sleep(self.delay);
        self.out.clear();
        self.out.resize(rows * self.c, 0.25);
        Ok(&self.out)
    }
}

/// Test backend whose every batch fails.
struct FailingBackend;

impl InferenceBackend for FailingBackend {
    fn name(&self) -> &'static str {
        "test-failing"
    }
    fn dims(&self) -> (usize, usize) {
        (2, 2)
    }
    fn max_batch(&self) -> usize {
        usize::MAX
    }
    fn run(&mut self, _x: &[f32], _rows: usize) -> Result<&[f32]> {
        Err(anyhow!("injected backend failure"))
    }
}

#[test]
fn backpressure_queue_full_rejects_and_counts() {
    let cfg = ServerConfig {
        max_batch: 1,
        max_wait: Duration::ZERO,
        queue_depth: 1,
        ..Default::default()
    };
    let server = InferenceServer::start_with_factory(
        || -> Result<Box<dyn InferenceBackend>> {
            Ok(Box::new(SlowBackend {
                d: 2,
                c: 2,
                delay: Duration::from_millis(50),
                out: Vec::new(),
            }))
        },
        cfg,
    )
    .unwrap();
    // Worker busy on the first request, queue depth 1: submitting fast
    // enough must hit Busy. Waiters are held so answers stay pending.
    let mut waiters = Vec::new();
    let mut busy = 0;
    for _ in 0..50 {
        match server.infer_async(vec![0.5, 0.5]) {
            Ok(rx) => waiters.push(rx),
            Err(e) => {
                assert!(e.to_string().contains("busy"), "{e}");
                busy += 1;
                break;
            }
        }
    }
    assert!(busy > 0, "queue never filled");
    let m = server.metrics().snapshot();
    assert_eq!(m.rejected as usize, busy);
    // Admitted requests all complete.
    for rx in waiters {
        let resp = rx.recv().unwrap().expect("admitted request must be answered");
        assert_eq!(resp.logits.len(), 2);
    }
}

#[test]
fn deadline_expiry_answers_instead_of_occupying_a_slot() {
    let cfg = ServerConfig {
        max_batch: 1,
        max_wait: Duration::ZERO,
        queue_depth: 8,
        deadline: Some(Duration::from_millis(5)),
        ..Default::default()
    };
    let server = InferenceServer::start_with_factory(
        || -> Result<Box<dyn InferenceBackend>> {
            Ok(Box::new(SlowBackend {
                d: 2,
                c: 2,
                delay: Duration::from_millis(60),
                out: Vec::new(),
            }))
        },
        cfg,
    )
    .unwrap();
    // First request occupies the worker for 60 ms; the second sits in
    // the queue past its 5 ms deadline and must be answered with a
    // deadline error, not executed.
    let first = server.infer_async(vec![0.0, 0.0]).unwrap();
    std::thread::sleep(Duration::from_millis(10)); // worker has picked up #1
    match server.try_infer(vec![1.0, 1.0]) {
        Err(InferError::DeadlineExceeded) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(first.recv().unwrap().is_ok(), "in-flight request unaffected");
    let m = server.metrics().snapshot();
    assert!(m.deadline_expired >= 1, "deadline metric did not move: {m:?}");
}

#[test]
fn batch_failure_answers_every_request_explicitly() {
    let server = InferenceServer::start_with_factory(
        || -> Result<Box<dyn InferenceBackend>> { Ok(Box::new(FailingBackend)) },
        ServerConfig::default(),
    )
    .unwrap();
    match server.try_infer(vec![0.0, 0.0]) {
        Err(InferError::Backend(m)) => {
            assert!(m.contains("injected backend failure"), "{m}")
        }
        other => panic!("expected Backend error, got {other:?}"),
    }
    let m = server.metrics().snapshot();
    assert_eq!(m.batch_failures, 1, "failure counter must move");
    assert_eq!(m.batches, 1);
}

#[test]
fn http_infer_and_metrics_roundtrip_on_ephemeral_port() {
    let w = model();
    let server = Arc::new(start_native(&w, ServerConfig::default()));
    let listener = http::serve("127.0.0.1:0", server.clone()).expect("bind ephemeral port");
    let addr = listener.local_addr();

    // POST /infer: logits must survive the JSON round-trip bit-exactly.
    for g in 0..4 {
        let x = &w.golden_x[g * w.d..(g + 1) * w.d];
        let body = format!(
            "{{\"features\":[{}]}}",
            x.iter().map(|v| format!("{v:?}")).collect::<Vec<_>>().join(",")
        );
        let (status, resp) = http::http_request(&addr, "POST", "/infer", &body).unwrap();
        assert_eq!(status, 200, "{resp}");
        let j = positron::json::Json::parse(&resp).expect("response is JSON");
        let logits = j.get("logits").and_then(|l| l.as_f32_vec()).expect("logits array");
        let want = reference_forward(&w, WeightFormat::Bp32, &quantizer::roundtrip(x));
        assert_eq!(bits(&logits), bits(&want), "HTTP row {g} not bit-exact");
        assert!(j.get("latency_us").and_then(|v| v.as_f64()).is_some());
    }

    // GET /metrics: Prometheus-style body with live counters.
    let (status, metrics_text) = http::http_request(&addr, "GET", "/metrics", "").unwrap();
    assert_eq!(status, 200);
    let batches = http::metric_value(&metrics_text, "positron_batches_total").unwrap();
    assert!(batches >= 1.0, "positron_batches_total must be non-zero:\n{metrics_text}");
    let requests = http::metric_value(&metrics_text, "positron_requests_total").unwrap();
    assert!(requests >= 4.0, "{metrics_text}");
    assert!(metrics_text.contains("positron_batch_failures_total 0"), "{metrics_text}");
    assert!(metrics_text.contains("positron_deadline_expired_total 0"), "{metrics_text}");

    // Query strings route to the same endpoint (Prometheus scrapers
    // append them).
    let (status, _) = http::http_request(&addr, "GET", "/metrics?format=prometheus", "").unwrap();
    assert_eq!(status, 200);

    // GET /healthz, bad JSON, wrong feature count, unknown route.
    let (status, body) = http::http_request(&addr, "GET", "/healthz", "").unwrap();
    assert_eq!((status, body.as_str()), (200, "ok\n"));
    let (status, _) = http::http_request(&addr, "POST", "/infer", "not json").unwrap();
    assert_eq!(status, 400);
    let (status, _) = http::http_request(&addr, "POST", "/infer", "{\"nope\":1}").unwrap();
    assert_eq!(status, 400);
    let (status, body) =
        http::http_request(&addr, "POST", "/infer", "{\"features\":[1.0]}").unwrap();
    assert_eq!(status, 400, "{body}");
    let (status, _) = http::http_request(&addr, "GET", "/nope", "").unwrap();
    assert_eq!(status, 404);

    drop(listener); // clean shutdown joins the accept thread
}

#[test]
fn http_maps_deadline_to_504() {
    let cfg = ServerConfig {
        max_batch: 1,
        max_wait: Duration::ZERO,
        queue_depth: 8,
        deadline: Some(Duration::from_millis(5)),
        ..Default::default()
    };
    let server = Arc::new(
        InferenceServer::start_with_factory(
            || -> Result<Box<dyn InferenceBackend>> {
                Ok(Box::new(SlowBackend {
                    d: 2,
                    c: 2,
                    delay: Duration::from_millis(60),
                    out: Vec::new(),
                }))
            },
            cfg,
        )
        .unwrap(),
    );
    let listener = http::serve("127.0.0.1:0", server.clone()).unwrap();
    let addr = listener.local_addr();
    let _first = server.infer_async(vec![0.0, 0.0]).unwrap();
    std::thread::sleep(Duration::from_millis(10));
    let (status, body) =
        http::http_request(&addr, "POST", "/infer", "{\"features\":[1.0,2.0]}").unwrap();
    assert_eq!(status, 504, "{body}");
    assert!(body.contains("deadline"), "{body}");
}

#[test]
fn tracez_http_roundtrip_correlates_infer_spans() {
    let w = model();
    let server = Arc::new(start_native(&w, ServerConfig::default()));
    let listener = http::serve("127.0.0.1:0", server.clone()).expect("bind ephemeral port");
    let addr = listener.local_addr();

    // POST /infer echoes a nonzero trace id.
    let x = &w.golden_x[..w.d];
    let body = format!(
        "{{\"features\":[{}]}}",
        x.iter().map(|v| format!("{v:?}")).collect::<Vec<_>>().join(",")
    );
    let (status, resp) = http::http_request(&addr, "POST", "/infer", &body).unwrap();
    assert_eq!(status, 200, "{resp}");
    let j = positron::json::Json::parse(&resp).unwrap();
    let trace_id = j.get("trace_id").and_then(|t| t.as_f64()).expect("trace_id echoed") as u64;
    assert!(trace_id >= 1, "trace ids start at 1");

    // The request span is pushed after the response bytes are written —
    // give the connection thread a moment to complete it.
    let mut request_span = None;
    for _ in 0..100 {
        let (status, tz) = http::http_request(&addr, "GET", "/debug/tracez", "").unwrap();
        assert_eq!(status, 200);
        let tz = positron::json::Json::parse(&tz).expect("tracez is JSON");
        let spans = tz.get("spans").and_then(|s| s.as_arr()).expect("spans array").to_vec();
        request_span = spans
            .iter()
            .find(|s| {
                s.get("trace_id").and_then(|t| t.as_f64()) == Some(trace_id as f64)
                    && s.get("kind").and_then(|k| k.as_str()) == Some("request")
            })
            .cloned();
        if request_span.is_some() {
            // Its batch span must be retained too, listing it as a member.
            let batch_id =
                request_span.as_ref().unwrap().get("batch_id").and_then(|b| b.as_f64()).unwrap();
            let batch = spans
                .iter()
                .find(|s| {
                    s.get("kind").and_then(|k| k.as_str()) == Some("batch")
                        && s.get("trace_id").and_then(|t| t.as_f64()) == Some(batch_id)
                })
                .expect("batch span retained");
            let members = batch.get("members").and_then(|m| m.as_arr()).expect("members");
            assert!(
                members.iter().any(|m| m.as_f64() == Some(trace_id as f64)),
                "batch span must list the request as a member"
            );
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let span = request_span.expect("request span must appear in /debug/tracez");
    // Every stage key is present and the span carries its wall total.
    let stages = span.get("stages").expect("stages object");
    for key in [
        "accept_ns", "parse_ns", "queue_wait_ns", "staging_ns", "input_codec_ns",
        "execute_ns", "readout_ns", "serialize_ns", "write_ns",
    ] {
        assert!(stages.get(key).and_then(|v| v.as_f64()).is_some(), "missing stage {key}");
    }
    assert!(span.get("total_ns").and_then(|t| t.as_f64()).unwrap() > 0.0);

    // ?min_us= far above any span filters everything out; ?limit= caps.
    let (status, none) =
        http::http_request(&addr, "GET", "/debug/tracez?min_us=10000000", "").unwrap();
    assert_eq!(status, 200);
    assert!(none.contains("\"count\":0"), "{none}");
    let (status, one) = http::http_request(&addr, "GET", "/debug/tracez?limit=1", "").unwrap();
    assert_eq!(status, 200);
    assert!(one.contains("\"count\":1"), "{one}");

    // Unknown debug paths 404 like any other route.
    let (status, _) = http::http_request(&addr, "GET", "/debug/nope", "").unwrap();
    assert_eq!(status, 404);
}

#[test]
fn span_stage_sum_tracks_recorded_latency() {
    // The span contract: the server-side stage sum (queue wait through
    // readout) accounts for the recorded latency within 5% (plus a small
    // absolute floor for scheduling/clock granularity on loaded CI).
    let w = model();
    let server = start_native(&w, ServerConfig::default());
    for g in 0..8 {
        let feats = w.golden_x[g * w.d..(g + 1) * w.d].to_vec();
        let resp = server.try_infer(feats).unwrap();
        let latency_ns = resp.latency.as_nanos() as u64;
        let sum = resp.stages.server_sum();
        let tol = (latency_ns / 20).max(250_000);
        assert!(
            sum.abs_diff(latency_ns) <= tol,
            "row {g}: stage sum {sum} ns vs latency {latency_ns} ns (tol {tol})"
        );
    }
}

#[test]
fn tracing_toggle_leaves_logits_bit_identical() {
    // Observability must never perturb the numeric path: logits with
    // span retention on and off are bit-identical to each other and to
    // the scalar reference.
    let w = model();
    let on = start_native(&w, ServerConfig { tracing: true, ..Default::default() });
    let off = start_native(&w, ServerConfig { tracing: false, ..Default::default() });
    for g in 0..w.batch {
        let feats = w.golden_x[g * w.d..(g + 1) * w.d].to_vec();
        let want = reference_forward(&w, WeightFormat::Bp32, &quantizer::roundtrip(&feats));
        let ra = on.infer(feats.clone()).unwrap();
        let rb = off.infer(feats).unwrap();
        assert_eq!(bits(&ra.logits), bits(&want), "traced row {g}");
        assert_eq!(bits(&rb.logits), bits(&want), "untraced row {g}");
        assert!(ra.trace_id >= 1 && rb.trace_id >= 1, "ids flow regardless of retention");
    }
    assert!(on.tracer().pushed() > 0, "traced server must retain spans");
    assert_eq!(off.tracer().pushed(), 0, "untraced server must retain none");
}

#[test]
fn histograms_and_http_counters_exposed_over_metrics() {
    let w = model();
    let server = Arc::new(start_native(&w, ServerConfig::default()));
    let listener = http::serve("127.0.0.1:0", server.clone()).expect("bind ephemeral port");
    let addr = listener.local_addr();
    for g in 0..3 {
        let x = &w.golden_x[g * w.d..(g + 1) * w.d];
        let body = format!(
            "{{\"features\":[{}]}}",
            x.iter().map(|v| format!("{v:?}")).collect::<Vec<_>>().join(",")
        );
        let (status, _) = http::http_request(&addr, "POST", "/infer", &body).unwrap();
        assert_eq!(status, 200);
    }
    let (status, text) = http::http_request(&addr, "GET", "/metrics", "").unwrap();
    assert_eq!(status, 200);
    // Histograms render in full _bucket/_sum/_count form with live counts.
    for name in [
        "positron_request_latency_us_bucket{le=\"+Inf\"}",
        "positron_request_latency_us_sum",
        "positron_queue_wait_us_count",
        "positron_codec_batch_ns_bucket",
        "positron_execute_batch_ns_count",
        "positron_staging_ns_total",
        "positron_readout_ns_total",
        "positron_codec_worker_ns_total",
    ] {
        assert!(text.contains(name), "missing `{name}` in:\n{text}");
    }
    let lat_count = http::metric_value(&text, "positron_request_latency_us_count").unwrap();
    assert!(lat_count >= 3.0, "{text}");
    // Connection/response counters: the three POSTs happened before this
    // scrape (the scrape's own response is counted after rendering).
    let conns = http::metric_value(&text, "positron_http_connections_total").unwrap();
    assert!(conns >= 4.0, "3 POSTs + this scrape: {text}");
    assert!(
        text.lines().any(|l| {
            l.starts_with("positron_http_responses_total{class=\"2xx\"}")
                && l.split(' ').nth(1).and_then(|v| v.parse::<f64>().ok()).is_some_and(|v| v >= 3.0)
        }),
        "{text}"
    );
}

#[test]
fn weight_cache_shared_across_servers() {
    let w = model();
    let _a = start_native(&w, ServerConfig::default());
    let (h0, _) = quantizer::weight_cache_stats();
    let _b = start_native(&w, ServerConfig::default());
    let (h1, _) = quantizer::weight_cache_stats();
    assert!(h1 >= h0 + 2, "second server must reuse cached weight encodings ({h0} → {h1})");
}

#[test]
fn weight_cache_counters_exported_over_http_metrics() {
    // The PR-4 hit/miss counters must surface in the Prometheus render:
    // starting a second server over the same weights is ≥ 2 cache hits
    // (one per layer), and /metrics must report at least that. The
    // counters are process-wide and monotone, so concurrent tests can
    // only push them higher — the lower bounds stay race-free.
    let w = synth_weights(9, 11, 4, 3, 0xcac4e);
    let first = start_native(&w, ServerConfig::default());
    let (h0, m0) = quantizer::weight_cache_stats();
    assert!(m0 >= 2, "first load must miss (encode) both layers");
    let second = Arc::new(start_native(&w, ServerConfig::default()));
    drop(first);

    let listener = http::serve("127.0.0.1:0", second.clone()).expect("bind ephemeral port");
    let (status, text) = http::http_request(&listener.local_addr(), "GET", "/metrics", "").unwrap();
    assert_eq!(status, 200);
    let hits = http::metric_value(&text, "positron_weight_cache_hits_total")
        .expect("hits metric rendered");
    let misses = http::metric_value(&text, "positron_weight_cache_misses_total")
        .expect("misses metric rendered");
    assert!(
        hits >= (h0 + 2) as f64,
        "second server sharing cached weights must hit both layers: {hits} < {} \n{text}",
        h0 + 2
    );
    assert!(misses >= m0 as f64, "misses are monotone: {misses} < {m0}\n{text}");
}

#[test]
fn native_server_loads_weights_json_from_disk() {
    // End-to-end through the ModelWeights::load_from_dir path: write a
    // synthetic weights.json, start the server from the directory.
    let w = synth_weights(3, 4, 2, 2, 0x77);
    let dir = std::env::temp_dir().join(format!("positron-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let fmt_f32 = |v: &[f32]| -> String {
        let items: Vec<String> = v.iter().map(|x| format!("{x:?}")).collect();
        format!("[{}]", items.join(","))
    };
    let fmt_i32 = |v: &[i32]| -> String {
        let items: Vec<String> = v.iter().map(|x| x.to_string()).collect();
        format!("[{}]", items.join(","))
    };
    let json = format!(
        "{{\"d\":{},\"h\":{},\"c\":{},\"batch\":{},\"w1\":{},\"b1\":{},\"w2\":{},\"b2\":{},\
         \"w1_bits\":{},\"w2_bits\":{},\"golden_x\":{},\"golden_y\":{},\
         \"golden_logits_f32\":{},\"golden_logits_bposit\":{}}}",
        w.d,
        w.h,
        w.c,
        w.batch,
        fmt_f32(&w.w1),
        fmt_f32(&w.b1),
        fmt_f32(&w.w2),
        fmt_f32(&w.b2),
        fmt_i32(&w.w1_bits),
        fmt_i32(&w.w2_bits),
        fmt_f32(&w.golden_x),
        fmt_i32(&w.golden_y),
        fmt_f32(&w.golden_logits_f32),
        fmt_f32(&w.golden_logits_bposit),
    );
    std::fs::write(dir.join("weights.json"), json).unwrap();
    let server = InferenceServer::start(dir.clone(), ServerConfig::default()).unwrap();
    assert_eq!(server.dims, (3, 2));
    let resp = server.infer(w.golden_x[..3].to_vec()).unwrap();
    let want = reference_forward(&w, WeightFormat::Bp32, &quantizer::roundtrip(&w.golden_x[..3]));
    assert_eq!(bits(&resp.logits), bits(&want));
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Event-driven listener integration (keep-alive, pipelining, admission
/// control, multi-model routing). The readiness loop is unix-only
/// (epoll/`poll(2)`); the non-unix fallback keeps the one-request-per-
/// connection contract, so these tests are gated on unix.
#[cfg(unix)]
mod event_loop {
    use super::*;
    use positron::coordinator::backend::InferenceBackend;
    use positron::coordinator::{HttpClient, ModelRegistry};

    fn infer_body(x: &[f32]) -> String {
        format!(
            "{{\"features\":[{}]}}",
            x.iter().map(|v| format!("{v:?}")).collect::<Vec<_>>().join(",")
        )
    }

    fn logits_of(body: &str) -> Vec<f32> {
        positron::json::Json::parse(body)
            .expect("response is JSON")
            .get("logits")
            .and_then(|l| l.as_f32_vec())
            .expect("logits array")
    }

    #[test]
    fn keep_alive_sequential_requests_are_bit_exact() {
        let w = model();
        let server = Arc::new(start_native(&w, ServerConfig::default()));
        let mut listener = http::serve("127.0.0.1:0", server).unwrap();
        let addr = listener.local_addr();

        // One connection, 12 sequential requests: every response rides
        // the same socket and stays bit-exact against the reference.
        let mut client = HttpClient::connect(&addr).unwrap();
        for round in 0..3 {
            for g in 0..4 {
                let x = &w.golden_x[g * w.d..(g + 1) * w.d];
                let resp = client.request("POST", "/infer", &infer_body(x)).unwrap();
                assert_eq!(resp.status, 200, "round {round} row {g}: {}", resp.body);
                assert_eq!(
                    resp.header("connection").map(str::to_ascii_lowercase),
                    Some("keep-alive".into()),
                    "round {round} row {g}"
                );
                let want = reference_forward(&w, WeightFormat::Bp32, &quantizer::roundtrip(x));
                assert_eq!(bits(&logits_of(&resp.body)), bits(&want), "round {round} row {g}");
            }
        }

        // Closing the connection feeds the keep-alive reuse histogram:
        // the sum must account for the 12 requests that shared it.
        drop(client);
        let mut sum = 0.0;
        for _ in 0..200 {
            let (_, text) = http::http_request(&addr, "GET", "/metrics", "").unwrap();
            sum = http::metric_value(&text, "positron_keepalive_requests_sum").unwrap_or(0.0);
            if sum >= 12.0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(sum >= 12.0, "keep-alive histogram must see the 12-request connection: {sum}");
        listener.shutdown();
    }

    #[test]
    fn pipelined_requests_are_answered_in_order() {
        let w = model();
        let server = Arc::new(start_native(&w, ServerConfig::default()));
        let mut listener = http::serve("127.0.0.1:0", server).unwrap();
        let mut client = HttpClient::connect(&listener.local_addr()).unwrap();

        // Six requests written back-to-back without reading a byte; the
        // responses must come back in request order (distinct golden
        // rows make reordering detectable).
        let n = 6;
        for g in 0..n {
            let x = &w.golden_x[(g % 4) * w.d..((g % 4) + 1) * w.d];
            client.send("POST", "/infer", &infer_body(x)).unwrap();
        }
        for g in 0..n {
            let resp = client.recv().unwrap();
            assert_eq!(resp.status, 200, "response {g}: {}", resp.body);
            let x = &w.golden_x[(g % 4) * w.d..((g % 4) + 1) * w.d];
            let want = reference_forward(&w, WeightFormat::Bp32, &quantizer::roundtrip(x));
            assert_eq!(bits(&logits_of(&resp.body)), bits(&want), "response {g} out of order");
        }
        listener.shutdown();
    }

    #[test]
    fn admission_control_sheds_with_fast_503() {
        // One admission slot over a 1 s backend: a second connection's
        // request must be shed before body parse — fast 503 with
        // Retry-After and a typed body — while the admitted request
        // still completes.
        let mut reg = ModelRegistry::new(false);
        let cfg = ServerConfig::builder()
            .max_batch(1)
            .max_wait(Duration::ZERO)
            .queue_depth(8)
            .max_inflight(1)
            .build()
            .unwrap();
        reg.register_with_factory(
            "slow",
            || -> Result<Box<dyn InferenceBackend>> {
                Ok(Box::new(SlowBackend {
                    d: 2,
                    c: 2,
                    delay: Duration::from_millis(1000),
                    out: Vec::new(),
                }))
            },
            cfg,
        )
        .unwrap();
        let reg = Arc::new(reg);
        let metrics = reg.metrics();
        let mut listener = http::serve_registry("127.0.0.1:0", reg).unwrap();
        let addr = listener.local_addr();

        let mut busy = HttpClient::connect(&addr).unwrap();
        busy.send("POST", "/v1/infer/slow", "{\"features\":[0.5,0.5]}").unwrap();
        std::thread::sleep(Duration::from_millis(100)); // request now in flight

        let mut shed = HttpClient::connect(&addr).unwrap();
        let t0 = std::time::Instant::now();
        let resp = shed.request("POST", "/v1/infer/slow", "{\"features\":[0.5,0.5]}").unwrap();
        assert_eq!(resp.status, 503, "{}", resp.body);
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "shed must answer without waiting for the backend ({:?})",
            t0.elapsed()
        );
        assert_eq!(resp.header("retry-after"), Some("1"), "{}", resp.body);
        let j = positron::json::Json::parse(&resp.body).unwrap();
        assert_eq!(j.get("code").and_then(|c| c.as_str()), Some("overloaded"), "{}", resp.body);
        assert_eq!(j.get("trace_id").and_then(|t| t.as_f64()), Some(0.0), "never reached queue");
        assert!(metrics.snapshot().http_shed >= 1, "shed counter must move");

        let ok = busy.recv().unwrap();
        assert_eq!(ok.status, 200, "admitted request unaffected: {}", ok.body);
        listener.shutdown();
    }

    #[test]
    fn queue_full_maps_to_429_with_typed_body() {
        // Admission budget wide open but queue depth 1: a pipelined
        // burst hits the server-side Busy path, which renders as 429
        // Too Many Requests (admission shed stays 503).
        let mut reg = ModelRegistry::new(false);
        let cfg = ServerConfig::builder()
            .max_batch(1)
            .max_wait(Duration::ZERO)
            .queue_depth(1)
            .max_inflight(64)
            .build()
            .unwrap();
        reg.register_with_factory(
            "slow",
            || -> Result<Box<dyn InferenceBackend>> {
                Ok(Box::new(SlowBackend {
                    d: 2,
                    c: 2,
                    delay: Duration::from_millis(150),
                    out: Vec::new(),
                }))
            },
            cfg,
        )
        .unwrap();
        let mut listener = http::serve_registry("127.0.0.1:0", Arc::new(reg)).unwrap();
        let mut client = HttpClient::connect(&listener.local_addr()).unwrap();
        for _ in 0..8 {
            client.send("POST", "/v1/infer/slow", "{\"features\":[0.5,0.5]}").unwrap();
        }
        let (mut ok, mut rejected) = (0, 0);
        for _ in 0..8 {
            let resp = client.recv().unwrap();
            match resp.status {
                200 => ok += 1,
                429 => {
                    rejected += 1;
                    assert_eq!(resp.header("retry-after"), Some("1"), "{}", resp.body);
                    let j = positron::json::Json::parse(&resp.body).unwrap();
                    assert_eq!(
                        j.get("code").and_then(|c| c.as_str()),
                        Some("too_many_requests"),
                        "{}",
                        resp.body
                    );
                }
                other => panic!("unexpected status {other}: {}", resp.body),
            }
        }
        assert!(ok >= 1, "at least the first request must be admitted");
        assert!(rejected >= 1, "queue depth 1 must reject under a pipelined burst");
        listener.shutdown();
    }

    #[test]
    fn registry_routes_tiers_and_shares_the_weight_cache() {
        let w = model();
        // A standalone bp32 server has already encoded these weights:
        // the registry's bp32 tier must hit the process-wide cache.
        let _warm = start_native(&w, ServerConfig::default());
        let (h0, _) = quantizer::weight_cache_stats();
        let mut reg = ModelRegistry::new(false);
        for format in [WeightFormat::Bp32, WeightFormat::Bp64] {
            let cfg = ServerConfig::builder().format(format).build().unwrap();
            reg.register_native(format.name(), w.clone(), cfg).unwrap();
        }
        let (h1, _) = quantizer::weight_cache_stats();
        assert!(h1 >= h0 + 2, "registry tier must reuse cached encodings ({h0} → {h1})");

        let mut listener = http::serve_registry("127.0.0.1:0", Arc::new(reg)).unwrap();
        let addr = listener.local_addr();

        // GET /v1/models lists both tiers, default first.
        let (status, body) = http::http_request(&addr, "GET", "/v1/models", "").unwrap();
        assert_eq!(status, 200, "{body}");
        let j = positron::json::Json::parse(&body).unwrap();
        assert_eq!(j.get("default").and_then(|d| d.as_str()), Some("bp32"), "{body}");
        let names: Vec<String> = j
            .get("models")
            .and_then(|m| m.as_arr())
            .expect("models array")
            .iter()
            .filter_map(|m| m.get("name").and_then(|n| n.as_str()).map(str::to_string))
            .collect();
        assert_eq!(names, ["bp32", "bp64"], "{body}");

        // Each tier answers bit-exactly against its own reference.
        let x = &w.golden_x[..w.d];
        for format in [WeightFormat::Bp32, WeightFormat::Bp64] {
            let path = format!("/v1/infer/{}", format.name());
            let (status, resp) = http::http_request(&addr, "POST", &path, &infer_body(x)).unwrap();
            assert_eq!(status, 200, "{resp}");
            let want = reference_forward(&w, format, &stage_inputs(format, x));
            assert_eq!(bits(&logits_of(&resp)), bits(&want), "{} tier", format.name());
        }

        // Unknown model name: typed 404.
        let (status, body) =
            http::http_request(&addr, "POST", "/v1/infer/fp8", &infer_body(x)).unwrap();
        assert_eq!(status, 404, "{body}");
        assert!(body.contains("\"code\":\"not_found\""), "{body}");

        // Legacy alias: POST /infer answers from the default tier with
        // logits identical to /v1/infer/bp32.
        let (status, legacy) = http::http_request(&addr, "POST", "/infer", &infer_body(x)).unwrap();
        assert_eq!(status, 200, "{legacy}");
        let want = reference_forward(&w, WeightFormat::Bp32, &quantizer::roundtrip(x));
        assert_eq!(bits(&logits_of(&legacy)), bits(&want), "legacy alias must hit default tier");
        listener.shutdown();
    }

    #[test]
    fn shutdown_completes_with_open_idle_keepalive_connections() {
        // The PR 7 bugfix: shutdown wakes the event loop through the
        // poller, so open idle keep-alive connections cannot stall it
        // (the old listener needed a TCP self-connect to unblock).
        let w = model();
        let server = Arc::new(start_native(&w, ServerConfig::default()));
        let mut listener = http::serve("127.0.0.1:0", server).unwrap();
        let addr = listener.local_addr();
        let mut clients = Vec::new();
        for i in 0..3 {
            let mut c = HttpClient::connect(&addr).unwrap();
            let resp = c.request("POST", "/infer", &infer_body(&w.golden_x[..w.d])).unwrap();
            assert_eq!(resp.status, 200, "conn {i}: {}", resp.body);
            clients.push(c); // held open and idle across shutdown
        }
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            listener.shutdown();
            let _ = tx.send(());
        });
        rx.recv_timeout(Duration::from_secs(5))
            .expect("shutdown must not hang on idle keep-alive connections");
        drop(clients);
    }

    #[test]
    fn event_loop_sustains_hundreds_of_connections_past_the_thread_cap() {
        // Far beyond the old 64-thread cap: 200 connections held open at
        // once, all tracked by the idle gauge, any of them servable.
        let w = model();
        let server = Arc::new(start_native(&w, ServerConfig::default()));
        let mut listener = http::serve("127.0.0.1:0", server).unwrap();
        let addr = listener.local_addr();
        let mut clients: Vec<HttpClient> = (0..200)
            .map(|i| HttpClient::connect(&addr).unwrap_or_else(|e| panic!("conn {i}: {e}")))
            .collect();
        let x = &w.golden_x[..w.d];
        let want = reference_forward(&w, WeightFormat::Bp32, &quantizer::roundtrip(x));
        for i in (0..clients.len()).step_by(20) {
            let resp = clients[i].request("POST", "/infer", &infer_body(x)).unwrap();
            assert_eq!(resp.status, 200, "conn {i}: {}", resp.body);
            assert_eq!(bits(&logits_of(&resp.body)), bits(&want), "conn {i}");
        }
        let mut idle = 0.0;
        for _ in 0..200 {
            let (_, text) = http::http_request(&addr, "GET", "/metrics", "").unwrap();
            idle = http::metric_value(&text, "positron_http_conn_state{state=\"idle\"}")
                .unwrap_or(0.0);
            if idle >= 200.0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(idle >= 200.0, "expected ≥ 200 idle connections tracked, saw {idle}");
        drop(clients);
        listener.shutdown();
    }

    #[test]
    fn hostile_json_bodies_get_typed_400_and_the_connection_survives() {
        // The PR 8 bugfixes: a deeply-nested body used to overflow the
        // recursive parser's stack and abort the whole process; a body
        // ending mid-\u-escape used to panic on an out-of-bounds slice.
        // Both must now come back as typed 400s on a connection that
        // stays usable.
        let w = model();
        let server = Arc::new(start_native(&w, ServerConfig::default()));
        let mut listener = http::serve("127.0.0.1:0", server).unwrap();
        let mut client = HttpClient::connect(&listener.local_addr()).unwrap();

        // ~300 KiB of '[' — well past the depth cap, well under the
        // body-size cap, so it reaches the parser.
        let deep = "[".repeat(300_000);
        let resp = client.request("POST", "/infer", &deep).unwrap();
        assert_eq!(resp.status, 400, "{}", resp.body);
        assert!(resp.body.contains("\"code\":\"bad_request\""), "{}", resp.body);

        // Body ending inside a \u escape (the old panic site), plus a
        // lone-surrogate body that must parse but fail feature checks.
        for body in ["{\"features\":\"\\u12", "{\"features\":[1.0,\"\\uD834\"]}"] {
            let resp = client.request("POST", "/infer", body).unwrap();
            assert_eq!(resp.status, 400, "{body:?}: {}", resp.body);
            assert!(resp.body.contains("\"code\":\"bad_request\""), "{body:?}: {}", resp.body);
        }

        // Same connection, same process: a valid request still answers
        // bit-exactly.
        let x = &w.golden_x[..w.d];
        let resp = client.request("POST", "/infer", &infer_body(x)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let want = reference_forward(&w, WeightFormat::Bp32, &quantizer::roundtrip(x));
        assert_eq!(bits(&logits_of(&resp.body)), bits(&want), "connection must stay usable");
        listener.shutdown();
    }
}

/// PJRT-specific integration: the compiled-model goldens. Needs the
/// `runtime` feature, libxla, and `make artifacts`.
#[cfg(feature = "runtime")]
mod pjrt {
    use super::*;
    use positron::coordinator::BackendKind;
    use positron::runtime::{artifacts_available, default_artifact_dir, Runtime};

    fn weights() -> Option<ModelWeights> {
        let dir = default_artifact_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return None;
        }
        let rt = Runtime::cpu(&dir).unwrap();
        Some(ModelWeights::load(&rt).unwrap())
    }

    fn start_pjrt(cfg: ServerConfig) -> InferenceServer {
        let cfg = ServerConfig { backend: BackendKind::Pjrt, ..cfg };
        InferenceServer::start(default_artifact_dir(), cfg).expect("server start")
    }

    #[test]
    fn serves_golden_batch_correctly() {
        let Some(w) = weights() else { return };
        let server = start_pjrt(ServerConfig::default());
        let mut correct = 0;
        for g in 0..w.golden_y.len() {
            let feats = w.golden_x[g * w.d..(g + 1) * w.d].to_vec();
            let resp = server.infer(feats).unwrap();
            assert_eq!(resp.logits.len(), w.c);
            let argmax = resp
                .logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax == w.golden_y[g] as usize {
                correct += 1;
            }
        }
        // Trained model classifies its own golden batch perfectly.
        assert_eq!(correct, w.golden_y.len());
    }

    #[test]
    fn f32_model_variant_servable() {
        let Some(w) = weights() else { return };
        let server = start_pjrt(ServerConfig::for_format(WeightFormat::F32));
        let feats = w.golden_x[..w.d].to_vec();
        let resp = server.infer(feats).unwrap();
        // Must match the recorded f32 golden logits for row 0.
        for (got, want) in resp.logits.iter().zip(&w.golden_logits_f32[..w.c]) {
            assert!((got - want).abs() <= 1e-4 * want.abs().max(1.0), "{got} vs {want}");
        }
    }
}
