//! Coordinator integration: the batching server against the real compiled
//! model — correctness, batching behavior, concurrency, backpressure.
//! Skips when artifacts haven't been built.
//!
//! Feature-gated: needs the PJRT/XLA backend (`--features runtime`).
#![cfg(feature = "runtime")]

use std::sync::Arc;
use std::time::Duration;

use positron::coordinator::{InferenceServer, ServerConfig};
use positron::runtime::{artifacts_available, default_artifact_dir, ModelWeights, Runtime};

fn weights() -> Option<ModelWeights> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::cpu(&dir).unwrap();
    Some(ModelWeights::load(&rt).unwrap())
}

fn start(cfg: ServerConfig) -> InferenceServer {
    InferenceServer::start(default_artifact_dir(), cfg).expect("server start")
}

#[test]
fn serves_golden_batch_correctly() {
    let Some(w) = weights() else { return };
    let server = start(ServerConfig::default());
    let mut correct = 0;
    for g in 0..w.golden_y.len() {
        let feats = w.golden_x[g * w.d..(g + 1) * w.d].to_vec();
        let resp = server.infer(feats).unwrap();
        assert_eq!(resp.logits.len(), w.c);
        let argmax =
            resp.logits.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        if argmax == w.golden_y[g] as usize {
            correct += 1;
        }
    }
    // Trained model classifies its own golden batch perfectly.
    assert_eq!(correct, w.golden_y.len());
}

#[test]
fn rejects_wrong_feature_count() {
    let Some(_) = weights() else { return };
    let server = start(ServerConfig::default());
    assert!(server.infer(vec![1.0; 3]).is_err());
}

#[test]
fn batching_coalesces_concurrent_clients() {
    let Some(w) = weights() else { return };
    let server = Arc::new(start(ServerConfig {
        max_wait: Duration::from_millis(20),
        ..Default::default()
    }));
    let mut handles = Vec::new();
    for t in 0..16 {
        let srv = server.clone();
        let feats = w.golden_x[(t % 4) * w.d..((t % 4) + 1) * w.d].to_vec();
        handles.push(std::thread::spawn(move || srv.infer(feats).unwrap()));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics().snapshot();
    assert_eq!(m.requests, 16);
    // With a 20 ms window, 16 concurrent requests should share batches.
    assert!(m.mean_batch > 1.5, "batching ineffective: mean {}", m.mean_batch);
    assert!(m.batches < 16);
}

#[test]
fn async_submission_and_metrics() {
    let Some(w) = weights() else { return };
    let server = start(ServerConfig::default());
    let mut waiters = Vec::new();
    for g in 0..8 {
        let feats = w.golden_x[g * w.d..(g + 1) * w.d].to_vec();
        waiters.push(server.infer_async(feats).unwrap());
    }
    for wtr in waiters {
        let resp = wtr.recv().unwrap();
        assert_eq!(resp.logits.len(), w.c);
        assert!(resp.latency < Duration::from_secs(5));
    }
    let m = server.metrics().snapshot();
    assert_eq!(m.requests, 8);
    assert!(m.p99_us > 0);
}

#[test]
fn quantize_inputs_toggle_changes_nothing_for_fovea_inputs() {
    // Golden features are small reals: bp32 roundtrip is exact, so both
    // configurations must return identical logits.
    let Some(w) = weights() else { return };
    let a = start(ServerConfig { quantize_inputs: true, ..Default::default() });
    let b = start(ServerConfig { quantize_inputs: false, ..Default::default() });
    let feats = w.golden_x[..w.d].to_vec();
    let ra = a.infer(feats.clone()).unwrap();
    let rb = b.infer(feats).unwrap();
    assert_eq!(ra.logits, rb.logits);
}

#[test]
fn f32_model_variant_servable() {
    let Some(w) = weights() else { return };
    let server =
        start(ServerConfig { model_file: "model_f32.hlo.txt".into(), ..Default::default() });
    let feats = w.golden_x[..w.d].to_vec();
    let resp = server.infer(feats).unwrap();
    // Must match the recorded f32 golden logits for row 0.
    for (got, want) in resp.logits.iter().zip(&w.golden_logits_f32[..w.c]) {
        assert!((got - want).abs() <= 1e-4 * want.abs().max(1.0), "{got} vs {want}");
    }
}
