//! Integration tests over the hardware layer: the paper's Table 5/6 and
//! Fig 14/15/16 *shape* claims, checked on freshly built netlists, plus
//! full-width functional verification sweeps.

use positron::formats::ieee::{F16, F32, F64};
use positron::formats::posit::{PositSpec, BP16, BP32, BP64, P16, P32, P64};
use positron::hw::designs::{
    bposit_dec, bposit_enc, float_dec, float_enc, posit_dec, posit_enc, power_vectors, verify,
    DesignUnderTest,
};
use positron::hw::report::{measure, CostReport};
use positron::hw::sta;

fn dec_rows() -> Vec<CostReport> {
    let mut rows = Vec::new();
    for n in [16u32, 32, 64] {
        let f = match n {
            16 => F16,
            32 => F32,
            _ => F64,
        };
        let b = PositSpec::bounded(n, 6, 5);
        let p = PositSpec::standard(n, 2);
        let vf = power_vectors(&DesignUnderTest::FloatDec(&f), 12);
        rows.push(measure(&format!("f{n}"), &float_dec::build(&f), &vf));
        let vb = power_vectors(&DesignUnderTest::PositDec(&b), 12);
        rows.push(measure(&format!("b{n}"), &bposit_dec::build(&b), &vb));
        let vp = power_vectors(&DesignUnderTest::PositDec(&p), 12);
        rows.push(measure(&format!("p{n}"), &posit_dec::build(&p), &vp));
    }
    rows
}

#[test]
fn table5_shape_claims() {
    let r = dec_rows();
    let (f, b, p) = (&r[3], &r[4], &r[5]); // 32-bit row triplet
    // b-posit32 decode beats posit32 decode on every axis (paper: −79%
    // power, −71% area, −60% delay; we demand the direction + ≥30%).
    assert!(
        b.peak_power_mw < 0.7 * p.peak_power_mw,
        "power {} vs {}",
        b.peak_power_mw,
        p.peak_power_mw
    );
    assert!(b.area_um2 < 0.7 * p.area_um2);
    assert!(b.delay_ns < 0.6 * p.delay_ns);
    // Paper: "the decoding of the b-posit is 39% faster than the IEEE float
    // decode" at 32 bits — i.e. b-posit delay ≈ 69% of float's.
    assert!(b.delay_ns < 0.85 * f.delay_ns, "bposit {} vs float {}", b.delay_ns, f.delay_ns);
    // 64-bit: b-posit at least 1.7× faster than float (paper: >2×).
    let (f64r, b64) = (&r[6], &r[7]);
    assert!(b64.delay_ns < f64r.delay_ns / 1.7);
    // Near-constant b-posit delay across widths; float and posit grow.
    let (b16, p16, f16) = (&r[1], &r[2], &r[0]);
    assert!(b64.delay_ns < b16.delay_ns * 1.5, "b-posit delay must stay flat");
    assert!(r[8].delay_ns > p16.delay_ns * 1.8, "posit delay must grow");
    assert!(f64r.delay_ns > f16.delay_ns * 1.2, "float delay must grow");
}

#[test]
fn table6_shape_claims() {
    let mut rows = Vec::new();
    for n in [16u32, 32, 64] {
        let f = match n {
            16 => F16,
            32 => F32,
            _ => F64,
        };
        let b = PositSpec::bounded(n, 6, 5);
        let p = PositSpec::standard(n, 2);
        let vf = power_vectors(&DesignUnderTest::FloatEnc(&f), 12);
        rows.push(measure("f", &float_enc::build(&f), &vf));
        let vb = power_vectors(&DesignUnderTest::PositEnc(&b), 12);
        rows.push(measure("b", &bposit_enc::build(&b), &vb));
        let vp = power_vectors(&DesignUnderTest::PositEnc(&p), 12);
        rows.push(measure("p", &posit_enc::build(&p), &vp));
    }
    let (b32, p32) = (&rows[4], &rows[5]);
    // Paper at 32: −68% power, −46% area, −44% delay vs posit encoder.
    assert!(b32.area_um2 < 0.7 * p32.area_um2);
    assert!(b32.delay_ns < 0.65 * p32.delay_ns);
    // 64-bit: b-posit encoder ~32% smaller than float encoder (paper).
    let (f64r, b64) = (&rows[6], &rows[7]);
    assert!(b64.area_um2 < 0.8 * f64r.area_um2, "b {} vs f {}", b64.area_um2, f64r.area_um2);
    // Near-constant delay.
    assert!(b64.delay_ns < rows[1].delay_ns * 1.5);
}

#[test]
fn fig16_energy_claims() {
    // energy = (dec_delay + enc_delay)·(2·dec_power + enc_power).
    let dec = dec_rows();
    let enc: Vec<CostReport> = {
        let mut rows = Vec::new();
        for n in [16u32, 32, 64] {
            let f = match n {
                16 => F16,
                32 => F32,
                _ => F64,
            };
            let b = PositSpec::bounded(n, 6, 5);
            let p = PositSpec::standard(n, 2);
            let vf = power_vectors(&DesignUnderTest::FloatEnc(&f), 12);
            rows.push(measure("f", &float_enc::build(&f), &vf));
            let vb = power_vectors(&DesignUnderTest::PositEnc(&b), 12);
            rows.push(measure("b", &bposit_enc::build(&b), &vb));
            let vp = power_vectors(&DesignUnderTest::PositEnc(&p), 12);
            rows.push(measure("p", &posit_enc::build(&p), &vp));
        }
        rows
    };
    let energy = |i: usize| {
        (dec[i].delay_ns + enc[i].delay_ns) * (2.0 * dec[i].peak_power_mw + enc[i].peak_power_mw)
    };
    // 64-bit: b-posit (idx 7) uses markedly less energy than float (6) and
    // posit (8) — the paper's headline "40% less than IEEE floats".
    assert!(energy(7) < 0.8 * energy(6), "b {} vs f {}", energy(7), energy(6));
    assert!(energy(7) < 0.5 * energy(8));
    // 32-bit: b-posit within ±35% of float ("tied").
    let ratio = energy(4) / energy(3);
    assert!((0.5..=1.35).contains(&ratio), "32-bit energy ratio {ratio}");
}

#[test]
fn decoder_verification_wide_sample_32() {
    let b = bposit_dec::build(&BP32);
    let p = posit_dec::build(&P32);
    for w in verify::sample_words(32, 4000) {
        verify::check_posit_decoder(&BP32, &b, w).unwrap();
        verify::check_posit_decoder(&P32, &p, w).unwrap();
        verify::check_decode_semantics(&BP32, w).unwrap();
        verify::check_decode_semantics(&P32, w).unwrap();
    }
}

#[test]
fn encoder_verification_wide_sample_64() {
    let b = bposit_enc::build(&BP64);
    let p = posit_enc::build(&P64);
    for w in verify::sample_words(64, 2500) {
        verify::check_posit_loopback(&BP64, &b, w).unwrap();
        verify::check_posit_loopback(&P64, &p, w).unwrap();
    }
}

#[test]
fn float_designs_verified_all_widths() {
    for spec in [F16, F32, F64] {
        let d = float_dec::build(&spec);
        let e = float_enc::build(&spec);
        for w in verify::sample_words(spec.n, 1500) {
            verify::check_float_decoder(&spec, &d, w).unwrap();
            verify::check_float_loopback(&spec, &e, w).unwrap();
        }
    }
}

#[test]
fn ablation_rs_bound_still_verifies() {
    // The generators are parameterized in rS; every variant must stay
    // functionally correct (the DESIGN.md ablation depends on this).
    for rs in [4u32, 5, 6, 7, 8] {
        let spec = PositSpec::bounded(32, rs, 5);
        let dec = bposit_dec::build(&spec);
        let enc = bposit_enc::build(&spec);
        for w in verify::sample_words(32, 400) {
            verify::check_posit_decoder(&spec, &dec, w).unwrap();
            verify::check_posit_loopback(&spec, &enc, w).unwrap();
        }
    }
}

#[test]
fn bposit_depth_constant_16_to_64() {
    let d16 = sta::logic_depth(&bposit_dec::build(&BP16));
    let d64 = sta::logic_depth(&bposit_dec::build(&BP64));
    assert!(d64 <= d16 + 4, "one-hot mux depth must not scale with n: {d16} → {d64}");
    let e16 = sta::logic_depth(&bposit_enc::build(&BP16));
    let e64 = sta::logic_depth(&bposit_enc::build(&BP64));
    assert!(e64 <= e16 + 4, "{e16} → {e64}");
}

#[test]
fn posit16_exotic_es_variants_verify() {
    // es = 0/1/3 variants of the standard decoder stay correct.
    for es in [0u32, 1, 3] {
        let spec = PositSpec::standard(16, es);
        let dec = posit_dec::build(&spec);
        let enc = posit_enc::build(&spec);
        for w in (0..=u16::MAX as u64).step_by(11) {
            verify::check_posit_decoder(&spec, &dec, w).unwrap();
            verify::check_posit_loopback(&spec, &enc, w).unwrap();
            verify::check_decode_semantics(&spec, w).unwrap();
        }
    }
}
