//! Vector-codec parity: the branch-free lane codec (rust/src/vector) vs
//! the scalar codecs it mirrors.
//!
//! Coverage (the ISSUE-1 test satellite):
//! - exhaustive 2^16-pattern parity for posit⟨16,2⟩ and b-posit⟨16,6,5⟩
//!   (decode of every pattern; encode of every pattern's value and of
//!   random f32s, exercising the saturation paths);
//! - stratified-random 2^20-sample parity for BP32 and P32 (every stratum
//!   of the top 20 pattern/value bits visited once);
//! - bit-identity of the BP32 lane codec against the scalar fast path;
//! - quire-exact dot/gemv vs an f64-Kahan reference.
//!
//! The f32-facing contract shared by all codecs here: encode flushes f32
//! subnormal inputs to 0 and maps NaN/Inf to NaR; decode flushes
//! sub-f32-normal magnitudes to ±0 and saturates beyond f32 to ±∞.

use positron::coordinator::quantizer;
use positron::formats::posit::{PositSpec, BP16, BP32, P16, P32};
use positron::formats::Decoded;
use positron::testutil::Rng;
use positron::vector::{codec, kernels, parallel, LaneCodec};

/// f64 → f32 under the vector-codec contract (cast, then FTZ keeping sign).
fn to_f32_contract(v: f64) -> f32 {
    let f = v as f32;
    if f != 0.0 && f.abs() < f32::MIN_POSITIVE {
        if f < 0.0 {
            -0.0
        } else {
            0.0
        }
    } else {
        f
    }
}

/// Scalar-reference encode under the contract (general pattern-space codec).
fn scalar_encode(spec: &PositSpec, x: f32) -> u32 {
    if !x.is_finite() {
        return spec.nar() as u32;
    }
    if x == 0.0 || x.abs() < f32::MIN_POSITIVE {
        return 0;
    }
    spec.encode(&Decoded::from_f64(x as f64)) as u32
}

/// Scalar-reference decode under the contract.
fn scalar_decode(spec: &PositSpec, w: u32) -> f32 {
    to_f32_contract(spec.decode(w as u64).to_f64())
}

fn assert_bits_eq(got: f32, want: f32, ctx: &str) {
    if want.is_nan() {
        assert!(got.is_nan(), "{ctx}: got {got}, want NaN");
    } else {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "{ctx}: got {got} ({:#010x}), want {want} ({:#010x})",
            got.to_bits(),
            want.to_bits()
        );
    }
}

fn exhaustive_16bit(spec: PositSpec) {
    // Decode: every 16-bit pattern.
    for w in 0..=u16::MAX as u32 {
        let got = codec::decode_word(&spec, w);
        let want = scalar_decode(&spec, w);
        assert_bits_eq(got, want, &format!("{spec:?} decode {w:#06x}"));
    }
    // Encode: every pattern's value that is representable under the f32
    // contract (b-posit16 spans 2^±192, so extremes overflow f32 — skip).
    let mut checked = 0u32;
    for w in 0..=u16::MAX as u32 {
        let v = spec.decode(w as u64).to_f64();
        if v.is_nan() || v == 0.0 {
            continue;
        }
        let x = to_f32_contract(v);
        if !x.is_finite() || x == 0.0 {
            continue; // outside the f32-facing contract
        }
        let got = codec::encode_word(&spec, x);
        let want = scalar_encode(&spec, x);
        assert_eq!(got, want, "{spec:?} encode {x} (from {w:#06x}): {got:#06x} vs {want:#06x}");
        checked += 1;
    }
    assert!(checked > 60_000, "{spec:?}: only {checked} encode cases checked");
    // Encode: random f32s spanning every scale — exercises saturation.
    let mut rng = Rng::new(0x16b_u64 + spec.rs as u64);
    for _ in 0..100_000 {
        let x = f32::from_bits(rng.next_u32());
        let got = codec::encode_word(&spec, x);
        let want = scalar_encode(&spec, x);
        assert_eq!(
            got,
            want,
            "{spec:?} encode {x} ({:#010x}): {got:#06x} vs {want:#06x}",
            x.to_bits()
        );
    }
}

#[test]
fn p16_exhaustive_parity() {
    exhaustive_16bit(P16);
}

#[test]
fn bp16_exhaustive_parity() {
    exhaustive_16bit(BP16); // the paper's ⟨16,6,5⟩
}

/// Stratified-random sweep: one sample per stratum of the top 20 bits, so
/// all 2^20 strata of the 32-bit pattern/value space are visited exactly
/// once with random low bits.
fn stratified_32bit(spec: PositSpec) {
    let mut rng = Rng::new(0x20_000 + spec.rs as u64);
    for stratum in 0..(1u32 << 20) {
        let low = rng.next_u32() & 0xfff;
        // Decode parity on the stratified pattern.
        let w = (stratum << 12) | low;
        let got = codec::decode_word(&spec, w);
        let want = scalar_decode(&spec, w);
        assert_bits_eq(got, want, &format!("{spec:?} decode {w:#010x}"));
        // Encode parity on the same bits reinterpreted as an f32 value —
        // stratifying sign, exponent, and the top mantissa bits.
        let x = f32::from_bits(w);
        let got = codec::encode_word(&spec, x);
        let want = scalar_encode(&spec, x);
        assert_eq!(got, want, "{spec:?} encode {x} ({w:#010x}): {got:#010x} vs {want:#010x}");
    }
}

#[test]
fn bp32_stratified_parity_2_20() {
    stratified_32bit(BP32);
}

#[test]
fn p32_stratified_parity_2_20() {
    stratified_32bit(P32);
}

#[test]
fn bp32_lane_bit_identical_to_scalar_fast_path() {
    // The acceptance bar: vector BP32 encode/decode is bit-identical to the
    // scalar fast path on all test vectors (corners + PRNG sweep), and the
    // slice drivers agree with the lane functions.
    let corners: [u32; 10] = [
        0,
        1,
        u32::MAX,
        0x8000_0000,
        0x8000_0001,
        0x7fff_ffff,
        0x4000_0000,
        0xC000_0000,
        0x0080_0000,
        0x7f80_0000,
    ];
    for w in corners {
        assert_bits_eq(
            codec::bp32_decode_lane(w),
            quantizer::fast_bp32_decode(w),
            &format!("decode corner {w:#010x}"),
        );
        let x = f32::from_bits(w);
        let want = quantizer::fast_bp32_encode(x);
        assert_eq!(codec::bp32_encode_lane(x), want, "encode corner {w:#010x}");
    }
    let mut rng = Rng::new(42);
    let mut words = Vec::with_capacity(1 << 16);
    let mut vals = Vec::with_capacity(1 << 16);
    for _ in 0..(1 << 16) {
        let w = rng.next_u32();
        words.push(w);
        vals.push(f32::from_bits(w));
        assert_bits_eq(
            codec::bp32_decode_lane(w),
            quantizer::fast_bp32_decode(w),
            &format!("decode {w:#010x}"),
        );
        let x = f32::from_bits(w);
        assert_eq!(codec::bp32_encode_lane(x), quantizer::fast_bp32_encode(x), "encode {w:#010x}");
    }
    // Slice drivers lane-for-lane.
    let mut enc = vec![0u32; vals.len()];
    codec::bp32_encode_into(&vals, &mut enc);
    let mut dec = vec![0f32; words.len()];
    codec::bp32_decode_into(&words, &mut dec);
    for i in 0..vals.len() {
        assert_eq!(enc[i], codec::bp32_encode_lane(vals[i]), "slice encode lane {i}");
        let lane = codec::bp32_decode_lane(words[i]);
        assert_bits_eq(dec[i], lane, &format!("slice decode lane {i}"));
    }
}

// ----------------------------------------------------------------------
// Width-generic lane API (the ISSUE-5 test satellite, 32-bit half): the
// generic engine must be the named BP32/P32 fast paths bitwise, and the
// unified par_* entry points must be thread-count invariant.
// ----------------------------------------------------------------------

#[test]
fn generic_engine_bit_identical_to_named_paths() {
    let mut rng = Rng::new(0x1a32);
    let bp = LaneCodec::<f32>::bp();
    let p = LaneCodec::<f32>::pstd();
    assert_eq!(bp.spec(), BP32);
    assert_eq!(p.spec(), P32);
    for _ in 0..100_000 {
        let w = rng.next_u32();
        let x = f32::from_bits(w);
        assert_eq!(bp.encode_word(x), codec::bp32_encode_lane(x), "bp32 encode {w:#010x}");
        assert_eq!(p.encode_word(x), codec::p32_encode_lane(x), "p32 encode {w:#010x}");
        assert_bits_eq(bp.decode_word(w), codec::bp32_decode_lane(w), "bp32 decode");
        assert_bits_eq(p.decode_word(w), codec::p32_decode_lane(w), "p32 decode");
    }
    // Slice drivers lane-for-lane, engine vs named, plus roundtrip.
    let xs: Vec<f32> = (0..1003)
        .map(|_| {
            let v = f32::from_bits(rng.next_u32());
            if v.is_finite() { v } else { 0.5 }
        })
        .collect();
    let via_engine = bp.encode(&xs);
    let mut named = vec![0u32; xs.len()];
    codec::bp32_encode_into(&xs, &mut named);
    assert_eq!(via_engine, named);
    let back_engine = bp.decode(&named);
    let mut back_named = vec![0f32; xs.len()];
    codec::bp32_decode_into(&named, &mut back_named);
    let mut rt = xs.clone();
    bp.roundtrip_in_place(&mut rt);
    for i in 0..xs.len() {
        assert_bits_eq(back_engine[i], back_named[i], &format!("slice decode lane {i}"));
        assert_bits_eq(rt[i], back_named[i], &format!("roundtrip lane {i}"));
    }
    // Spec-checked construction: the engine equals the checked generic
    // entry points of the named module for an arbitrary supported spec.
    let bp16 = LaneCodec::<f32>::new(BP16).unwrap();
    for _ in 0..20_000 {
        let x = f32::from_bits(rng.next_u32());
        assert_eq!(bp16.encode_word(x), codec::encode_word(&BP16, x), "bp16 encode {x:e}");
    }
}

#[test]
fn unified_par_entry_points_thread_identity() {
    let mut rng = Rng::new(0x7a32);
    let xs: Vec<f32> = (0..10_007)
        .map(|_| {
            let v = f32::from_bits(rng.next_u32());
            if v.is_finite() { v } else { -2.5 }
        })
        .collect();
    let bp = LaneCodec::<f32>::bp();
    let serial_w = bp.encode(&xs);
    let mut serial_f = vec![0f32; xs.len()];
    bp.decode_into(&serial_w, &mut serial_f);
    for t in [1usize, 2, 7] {
        let mut w = vec![0u32; xs.len()];
        parallel::par_encode_into_with(t, &BP32, &xs, &mut w);
        assert_eq!(w, serial_w, "generic-spec encode t={t}");
        parallel::par_bp_encode_into_with(t, &xs, &mut w);
        assert_eq!(w, serial_w, "serving-spec encode t={t}");
        let mut f = vec![0f32; xs.len()];
        parallel::par_decode_into_with(t, &BP32, &serial_w, &mut f);
        for i in 0..f.len() {
            assert_bits_eq(f[i], serial_f[i], &format!("decode t={t} lane {i}"));
        }
        let mut rt = xs.clone();
        parallel::par_roundtrip_in_place_with(t, &BP32, &mut rt);
        for i in 0..rt.len() {
            assert_bits_eq(rt[i], serial_f[i], &format!("roundtrip t={t} lane {i}"));
        }
    }
}

// ----------------------------------------------------------------------
// Quire kernels vs f64-Kahan reference
// ----------------------------------------------------------------------

/// Kahan-compensated f64 summation of the products aᵢ·bᵢ (each product is
/// exact in f64 for f32 inputs).
fn kahan_dot(a: &[f32], b: &[f32]) -> f64 {
    let mut sum = 0.0f64;
    let mut c = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let term = x as f64 * y as f64 - c;
        let t = sum + term;
        c = (t - sum) - term;
        sum = t;
    }
    sum
}

#[test]
fn quire_dot_matches_kahan_on_mixed_scales() {
    let mut rng = Rng::new(0xd07);
    let mut q = kernels::QuireDot::new();
    for trial in 0..20 {
        let n = 64 + (trial * 97) % 1000;
        let a: Vec<f32> = (0..n)
            .map(|_| {
                let m = (rng.f64() - 0.5) * f64::powi(2.0, rng.below(41) as i32 - 20);
                m as f32
            })
            .collect();
        let b: Vec<f32> = (0..n)
            .map(|_| {
                let m = (rng.f64() - 0.5) * f64::powi(2.0, rng.below(41) as i32 - 20);
                m as f32
            })
            .collect();
        let exact = q.dot_f32(&a, &b);
        let kahan = kahan_dot(&a, &b);
        // The quire is exact; Kahan's worst-case error is ~2ε·Σ|aᵢbᵢ|, so
        // scale the tolerance by the magnitude sum (not the cancelled
        // result) with generous headroom.
        let sum_abs: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
        let tol = 1e-9 * sum_abs.max(1.0);
        assert!(
            (exact - kahan).abs() <= tol,
            "trial {trial}: quire {exact} vs kahan {kahan} (n={n}, tol {tol:e})"
        );
    }
}

#[test]
fn quire_dot_exact_where_kahan_breaks() {
    // Σ over pairs (2^40, 1, -2^40): plain and even compensated f32 paths
    // lose the ±1 terms; the quire returns the exact integer.
    let mut a = Vec::new();
    let mut b = Vec::new();
    for i in 0..100 {
        let big = f32::powi(2.0, 40 + (i % 3));
        a.push(big);
        b.push(1.0f32);
        a.push(1.0);
        b.push(1.0);
        a.push(big);
        b.push(-1.0);
    }
    let mut q = kernels::QuireDot::new();
    let exact = q.dot_f32(&a, &b);
    assert_eq!(exact, 100.0, "quire must recover the cancelled units");
    // The f64-Kahan reference also gets this one right — agreement check.
    assert_eq!(kahan_dot(&a, &b), 100.0);
    // The rounded f32 fast path demonstrably cannot (2^40 + 1 rounds away).
    let fast = kernels::dot_f32(&a, &b);
    assert_ne!(fast, 100.0);
}

#[test]
fn gemv_quire_matches_kahan_rows() {
    let mut rng = Rng::new(0x6e3);
    let (rows, cols) = (17, 129);
    let a: Vec<f32> = (0..rows * cols).map(|_| (rng.f64() - 0.5) as f32 * 8.0).collect();
    let x: Vec<f32> = (0..cols).map(|_| (rng.f64() - 0.5) as f32 * 8.0).collect();
    let mut q = kernels::QuireDot::new();
    let mut y = vec![0f32; rows];
    q.gemv_f32(&a, &x, &mut y);
    for r in 0..rows {
        let want = kahan_dot(&a[r * cols..(r + 1) * cols], &x) as f32;
        // Quire row is exactly rounded; Kahan may differ by a final ulp
        // when its f64 error straddles an f32 rounding boundary.
        assert!(
            (y[r] - want).abs() <= f32::EPSILON * want.abs().max(1.0),
            "row {r}: quire {} vs kahan {want}",
            y[r]
        );
    }
}

#[test]
fn quire_dot_bp32_words_matches_f32_dot_on_exact_data() {
    // Integer-valued data: both the bp32 fused dot and the f64 reference
    // are exact, so the rounded bp32 result equals the true dot.
    let mut rng = Rng::new(0xabc);
    let a: Vec<f32> = (0..512).map(|_| (rng.below(2001) as f32) - 1000.0).collect();
    let b: Vec<f32> = (0..512).map(|_| (rng.below(65) as f32) - 32.0).collect();
    let a_bits: Vec<u32> = a.iter().map(|&v| codec::bp32_encode_lane(v)).collect();
    let b_bits: Vec<u32> = b.iter().map(|&v| codec::bp32_encode_lane(v)).collect();
    let mut q = kernels::QuireDot::new();
    let fused = codec::bp32_decode_lane(q.dot_bp32(&a_bits, &b_bits));
    let want = kahan_dot(&a, &b);
    assert_eq!(fused as f64, want, "bp32 fused dot vs exact integer dot");
}
