//! No-panic corpus for the JSON parser: every input — hostile or merely
//! malformed — must come back `Ok` or `Err`, never panic. The parser
//! feeds on untrusted HTTP bodies in the single-threaded event loop, so
//! a panic here is a remote crash (and a stack overflow is a process
//! abort). Companion to the in-module unit tests in `src/json.rs`.

use positron::json::{Json, MAX_DEPTH};
use positron::testutil::Rng;

/// The contract under test: parsing returns, and a successful parse of a
/// string-bearing document yields valid UTF-8 by construction (`String`).
fn total(src: &str) -> bool {
    Json::parse(src).is_ok()
}

#[test]
fn deep_nesting_at_and_over_the_cap() {
    for depth in [1, MAX_DEPTH - 1, MAX_DEPTH, MAX_DEPTH + 1, 4 * MAX_DEPTH] {
        for (open, close) in [("[", "]"), ("{\"k\":", "}")] {
            let src = format!("{}1{}", open.repeat(depth), close.repeat(depth));
            let ok = total(&src);
            // Depth counts every value level, so `depth` wrappers plus the
            // scalar parse iff depth + 1 <= MAX_DEPTH.
            assert_eq!(ok, depth + 1 <= MAX_DEPTH, "depth {depth} {open:?}");
        }
    }
    // Unclosed megabyte-scale nesting — the original DoS shape (a 4 MiB
    // body of '[' overflowed the recursion stack and aborted the
    // process). Must now fail fast at the cap.
    for n in [1 << 16, 1 << 20, 4 << 20] {
        assert!(!total(&"[".repeat(n)), "{n} open brackets");
        assert!(!total(&"{\"a\":".repeat(n / 5)), "{n} open objects");
    }
}

#[test]
fn truncated_escapes_and_strings() {
    let cases = [
        "\"", "\"\\", "\"\\u", "\"\\u1", "\"\\u12", "\"\\u123", "\"\\u1234", "\"\\uD834",
        "\"\\uD834\\", "\"\\uD834\\u", "\"\\uD834\\uDD", "\"abc", "\"\\q\"", "\"\\u+12a\"",
        "\"\\u 123\"", "\"\\ud8ZZ\"",
    ];
    for src in cases {
        assert!(!total(src), "{src:?} must be an error");
    }
    // Valid escapes still work, including the surrogate pair for U+1D11E.
    assert_eq!(Json::parse("\"\\uD834\\uDD1E\"").unwrap().as_str(), Some("\u{1D11E}"));
    assert_eq!(Json::parse("\"\\n\\t\\\\\\\"\\u0041\"").unwrap().as_str(), Some("\n\t\\\"A"));
}

#[test]
fn former_panic_sites_answer_typed_errors() {
    // Regression: hex4() used `.expect("hexdigit checked above")` after a
    // range check that did not cover a quad ending exactly at the buffer
    // edge, and number() ran `from_utf8(..).unwrap()` on its span. Both
    // are now typed parse errors; pin the diagnostic shape so a future
    // refactor cannot quietly reintroduce a panic-capable path.
    for src in ["\"\\u", "\"\\u1", "\"\\u12", "\"\\u123"] {
        let err = Json::parse(src).unwrap_err();
        assert!(err.contains("escape at byte"), "{src:?} → {err:?}");
    }
    for src in ["\"\\ug000\"", "\"\\u00g0\"", "\"\\u-123\"", "\"\\u12 4\""] {
        let err = Json::parse(src).unwrap_err();
        assert!(err.contains("escape at byte"), "{src:?} → {err:?}");
    }
    for src in ["-", "+", "1e", "1e+", "--1", "1.2.3", ".", "e5"] {
        let err = Json::parse(src).unwrap_err();
        assert!(err.contains("bad number"), "{src:?} → {err:?}");
    }
    // The happy paths those sites guard still decode.
    assert_eq!(Json::parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    assert_eq!(Json::parse("-2.5e3").unwrap().as_f64(), Some(-2500.0));
}

#[test]
fn lone_surrogates_replace_not_panic() {
    for (src, want) in [
        ("\"\\uD800\"", "\u{fffd}"),
        ("\"\\uDBFF\"", "\u{fffd}"),
        ("\"\\uDC00\"", "\u{fffd}"),
        ("\"\\uDFFF\"", "\u{fffd}"),
        ("\"\\uD834x\"", "\u{fffd}x"),
        ("\"\\uD834\\uD834\\uDD1E\"", "\u{fffd}\u{1D11E}"),
        ("\"\\uDD1E\\uD834\"", "\u{fffd}\u{fffd}"),
    ] {
        assert_eq!(Json::parse(src).unwrap().as_str(), Some(want), "{src:?}");
    }
}

#[test]
fn truncated_literals_and_numbers() {
    for src in [
        "tru", "fals", "n", "t", "f", "nul", "truee", "-", "+", ".", "1e", "1e+", "--1", "1.2.3",
        "0x10", "[1,", "[1", "{\"a\"", "{\"a\":", "{\"a\":1", "[,]", "{,}",
    ] {
        assert!(!total(src), "{src:?} must be an error");
    }
}

#[test]
fn random_byte_mutations_never_panic() {
    // Take valid documents, flip bytes at random, and parse the lossy
    // UTF-8 view. Any outcome is fine; returning is the contract.
    let seeds: Vec<String> = vec![
        "{\"features\":[1.0,-2.5e3,0.125],\"id\":\"run-7\",\"ok\":true}".into(),
        "[[1,2],[3,4],{\"deep\":[null,false,\"\\u0041\\uD834\\uDD1E\"]}]".into(),
        format!("[{}]", (0..64).map(|i| format!("{i}.5")).collect::<Vec<_>>().join(",")),
    ];
    let mut rng = Rng::new(0x6a50);
    let mut parsed = 0u32;
    for doc in &seeds {
        for _ in 0..2_000 {
            let mut bytes = doc.clone().into_bytes();
            let flips = 1 + rng.below(4) as usize;
            for _ in 0..flips {
                let at = rng.below(bytes.len() as u64) as usize;
                bytes[at] = (rng.next_u64() & 0xff) as u8;
            }
            let text = String::from_utf8_lossy(&bytes);
            if Json::parse(&text).is_ok() {
                parsed += 1;
            }
        }
    }
    // Sanity: the corpus is not vacuous — some mutants still parse.
    assert!(parsed > 0, "mutation corpus never produced a valid document");
}
