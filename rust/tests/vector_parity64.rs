//! 64-bit lane-codec and f64-kernel parity (the ISSUE-3 test satellite):
//! - BP64/P64 decode∘encode idempotence (and exactness where the format
//!   out-resolves f64);
//! - encode monotonicity over sorted f64 grids (posit order = two's-
//!   complement integer order);
//! - bit-exact agreement between the `codec64` generic path and the
//!   named BP64/P64 fast paths, lane and slice;
//! - quire-exact f64 dot/gemv/GEMM vs a Kahan-f64 estimate, an i128
//!   exact-integer reference, and an independent naive-quire reference
//!   built straight on `formats::Quire`, on random mixed-scale and
//!   cancellation-adversarial inputs;
//! - thread bit-identity t ∈ {1, 2, 7} for the sharded codec and every
//!   par_* f64 kernel.
//!
//! The deeper cross-language evidence (exhaustive 16-bit, stratified
//! 2^20 BP64/P64 vs the Python big-int oracle) lives in
//! python/tests/test_scalar_oracle64.py; these tests pin the Rust port
//! to the same behavior in-tree.

use positron::coordinator::quantizer;
use positron::formats::posit::{PositSpec, BP64, P64};
use positron::formats::{Decoded, Quire};
use positron::testutil::{mixed_scale_f64, Rng};
use positron::vector::{codec64, gemm, kernels, parallel, EncodedTensor, LaneCodec};

fn assert_bits_eq64(got: f64, want: f64, ctx: &str) {
    if want.is_nan() {
        assert!(got.is_nan(), "{ctx}: got {got}, want NaN");
    } else {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "{ctx}: got {got:e} ({:#018x}), want {want:e} ({:#018x})",
            got.to_bits(),
            want.to_bits()
        );
    }
}

// ----------------------------------------------------------------------
// Codec properties
// ----------------------------------------------------------------------

#[test]
fn decode_encode_idempotent_bp64_p64() {
    // decode∘encode projects f64 onto the format's value set; applying it
    // twice must be a fixed point bitwise. (Plain word-level roundtrip
    // does NOT hold for n = 64: P64's fovea out-resolves f64, so decode
    // loses bits by design — idempotence is the right invariant.)
    let mut rng = Rng::new(0x1de64);
    for spec in [BP64, P64] {
        for _ in 0..200_000 {
            let x = f64::from_bits(rng.next_u64());
            if x.is_nan() {
                continue;
            }
            let w = codec64::encode_word(&spec, x);
            let y = codec64::decode_word(&spec, w);
            let w2 = codec64::encode_word(&spec, y);
            let y2 = codec64::decode_word(&spec, w2);
            assert_bits_eq64(y2, y, &format!("{spec:?} idempotence at {x:e}"));
        }
        // Words whose value is f64-exact roundtrip at the word level too:
        // mask the fraction down to ≤ 52 significant bits.
        for _ in 0..100_000 {
            let w = rng.next_u64() & !0xff; // clear low bits: frac ≤ 52 sig bits
            let y = codec64::decode_word(&spec, w);
            if y.is_nan() || y == 0.0 {
                continue;
            }
            assert_eq!(
                codec64::encode_word(&spec, y),
                w,
                "{spec:?}: f64-exact word {w:#x} must roundtrip"
            );
        }
    }
}

#[test]
fn bp64_exact_on_in_range_f64() {
    // ⟨64,6,5⟩ keeps ≥ 52 fraction bits at every scale: the whole
    // in-range f64 grid is representable, so encode is lossless.
    let mut rng = Rng::new(0xb64);
    let mut checked = 0;
    for _ in 0..300_000 {
        let x = f64::from_bits(rng.next_u64());
        if !x.is_finite() || x == 0.0 {
            continue;
        }
        if !(f64::powi(2.0, -192)..f64::powi(2.0, 191)).contains(&x.abs()) {
            continue;
        }
        let y = codec64::bp64_decode_lane(codec64::bp64_encode_lane(x));
        assert_eq!(y.to_bits(), x.to_bits(), "{x:e}");
        checked += 1;
    }
    // ~19% of random f64 bit patterns fall in the 2^±192 range.
    assert!(checked > 40_000, "only {checked} in-range samples");
}

#[test]
fn encode_monotone_over_sorted_f64_grids() {
    // Posit patterns read as signed integers are ordered by value, so
    // encode must be monotone over any sorted f64 grid (FTZ'd subnormals
    // collapse onto 0, saturated tails onto ±maxpos — still monotone).
    let mut rng = Rng::new(0x5047);
    for spec in [BP64, P64] {
        let mut xs: Vec<f64> = (0..60_000)
            .map(|_| f64::from_bits(rng.next_u64()))
            .filter(|x| !x.is_nan())
            .collect();
        xs.extend([0.0, -0.0, f64::MAX, f64::MIN, f64::MIN_POSITIVE, -f64::MIN_POSITIVE]);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = i64::MIN;
        for &x in &xs {
            if x.is_infinite() {
                continue; // Inf maps to NaR, outside the order
            }
            let w = codec64::encode_word(&spec, x) as i64; // n = 64: sext = id
            assert!(
                w >= prev,
                "{spec:?}: encode not monotone at {x:e} ({w:#x} after {prev:#x})"
            );
            prev = w;
        }
    }
}

#[test]
fn generic_path_bit_identical_to_named_fast_paths() {
    let mut rng = Rng::new(0x64fa57);
    let mut xs = Vec::with_capacity(1 << 14);
    let mut ws = Vec::with_capacity(1 << 14);
    for _ in 0..(1 << 14) {
        let w = rng.next_u64();
        ws.push(w);
        xs.push(f64::from_bits(w));
    }
    for (&w, &x) in ws.iter().zip(&xs) {
        assert_eq!(codec64::encode_word(&BP64, x), codec64::bp64_encode_lane(x));
        assert_eq!(codec64::encode_word(&P64, x), codec64::p64_encode_lane(x));
        assert_bits_eq64(
            codec64::decode_word(&BP64, w),
            codec64::bp64_decode_lane(w),
            "bp64 decode",
        );
        assert_bits_eq64(codec64::decode_word(&P64, w), codec64::p64_decode_lane(w), "p64 decode");
    }
    // Slice drivers lane-for-lane (generic vs named).
    let clean: Vec<f64> = xs.iter().map(|&v| if v.is_nan() { 1.0 } else { v }).collect();
    let mut a = vec![0u64; clean.len()];
    let mut b = vec![0u64; clean.len()];
    codec64::bp64_encode_into(&clean, &mut a);
    codec64::encode_slice_into(&BP64, &clean, &mut b);
    assert_eq!(a, b);
    let mut fa = vec![0f64; ws.len()];
    let mut fb = vec![0f64; ws.len()];
    codec64::bp64_decode_into(&ws, &mut fa);
    codec64::decode_slice_into(&BP64, &ws, &mut fb);
    for i in 0..ws.len() {
        assert_bits_eq64(fb[i], fa[i], &format!("slice lane {i}"));
    }
}

#[test]
fn quantizer_bp64_matches_lane_and_general() {
    let mut rng = Rng::new(0xba64);
    for _ in 0..50_000 {
        let x = f64::from_bits(rng.next_u64());
        let lane = quantizer::quantize64_one(x);
        assert_eq!(lane, quantizer::quantize64_one_general(x), "encode {x:e}");
        let w = rng.next_u64() as i64;
        let a = quantizer::dequantize64_one(w);
        let b = quantizer::dequantize64_one_general(w);
        assert_bits_eq64(a, b, &format!("decode {w:#x}"));
    }
}

// ----------------------------------------------------------------------
// Quire-f64 kernels vs independent references
// ----------------------------------------------------------------------

/// Kahan-compensated f64 dot (approximate: f64 products round, unlike the
/// quire) — a sanity envelope, not a bit oracle.
fn kahan_dot64(a: &[f64], b: &[f64]) -> f64 {
    let mut sum = 0.0f64;
    let mut c = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let term = x * y - c;
        let t = sum + term;
        c = (t - sum) - term;
        sum = t;
    }
    sum
}

/// Independent naive-quire dot built straight on `formats::Quire` — the
/// bit-level oracle for the f64 kernel family.
fn naive_quire_dot64(a: &[f64], b: &[f64]) -> f64 {
    let mut q = Quire::exact_f64();
    for (&x, &y) in a.iter().zip(b) {
        q.add_product(&Decoded::from_f64(x), &Decoded::from_f64(y));
    }
    q.to_decoded().to_f64()
}

/// Cancellation-adversarial vectors: (big, tiny, −big) triples so plain
/// f64 accumulation loses every tiny term.
fn adversarial64(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut a = Vec::with_capacity(3 * n);
    let mut b = Vec::with_capacity(3 * n);
    for i in 0..n {
        let big = f64::powi(2.0, 500 + (i % 7) as i32);
        let tiny = f64::powi(2.0, -400 - (i % 11) as i32) * (1.0 + rng.f64());
        a.push(big);
        b.push(big);
        a.push(tiny);
        b.push(1.0);
        a.push(big);
        b.push(-big);
    }
    (a, b)
}

#[test]
fn quire_dot_f64_matches_naive_quire_and_i128_exact() {
    // Exact-integer data: Σ aᵢ·bᵢ fits in i128, giving a third,
    // arithmetic-free reference.
    let mut rng = Rng::new(0x1289);
    let mut q = kernels::QuireDotF64::new();
    for trial in 0..50 {
        let n = 16 + (trial * 37) % 500;
        let a: Vec<f64> = (0..n).map(|_| (rng.below(1 << 26) as i64 - (1 << 25)) as f64).collect();
        let b: Vec<f64> = (0..n).map(|_| (rng.below(1 << 26) as i64 - (1 << 25)) as f64).collect();
        let exact_i128: i128 =
            a.iter().zip(&b).map(|(&x, &y)| (x as i128) * (y as i128)).sum();
        let got = q.dot_f64(&a, &b);
        assert_eq!(got, exact_i128 as f64, "trial {trial} vs i128");
        assert_eq!(got.to_bits(), naive_quire_dot64(&a, &b).to_bits(), "trial {trial} vs naive");
    }
}

#[test]
fn quire_dot_f64_random_and_adversarial_vs_references() {
    let mut rng = Rng::new(0xd064);
    let mut q = kernels::QuireDotF64::new();
    // Random mixed-scale: bit-identical to the naive quire, within Kahan's
    // error envelope of the compensated estimate.
    for trial in 0..20 {
        let n = 64 + (trial * 97) % 800;
        let a = mixed_scale_f64(&mut rng, n, 81);
        let b = mixed_scale_f64(&mut rng, n, 81);
        let exact = q.dot_f64(&a, &b);
        assert_eq!(exact.to_bits(), naive_quire_dot64(&a, &b).to_bits(), "trial {trial}");
        let kahan = kahan_dot64(&a, &b);
        let sum_abs: f64 = a.iter().zip(&b).map(|(&x, &y)| (x * y).abs()).sum();
        let tol = 1e-9 * sum_abs.max(1.0);
        assert!(
            (exact - kahan).abs() <= tol,
            "trial {trial}: quire {exact:e} vs kahan {kahan:e} (tol {tol:e})"
        );
    }
    // Adversarial: the fast f64 path provably loses the tiny terms; the
    // quire and the naive reference agree bitwise and keep them.
    let (a, b) = adversarial64(40, 0xadf);
    let exact = q.dot_f64(&a, &b);
    assert_eq!(exact.to_bits(), naive_quire_dot64(&a, &b).to_bits());
    let tiny_sum: f64 = a
        .iter()
        .zip(&b)
        .filter(|(&x, _)| x.abs() < 1.0)
        .map(|(&x, &y)| x * y)
        .sum();
    assert!(exact != 0.0 && (exact - tiny_sum).abs() <= 1e-12 * tiny_sum.abs());
    // The fast path absorbs the 2^-400-scale terms into 2^1000-scale
    // accumulators, so it cannot reproduce the exact result.
    assert_ne!(kernels::dot_f64(&a, &b), exact, "fast path must lose the tiny terms");
}

#[test]
fn quire_gemv_gemm_f64_match_naive_reference_for_all_thread_counts() {
    let mut rng = Rng::new(0x6e64);
    let (m, k, n) = (11, 57, 9);
    for adversarial in [false, true] {
        let (a, b) = if adversarial {
            let (mut av, mut bv) = (Vec::new(), Vec::new());
            let (ra, rb) = adversarial64(m * k / 3 + 1, 0x6e3);
            av.extend_from_slice(&ra[..m * k]);
            bv.extend_from_slice(&rb[..k * n.min(ra.len() / k)]);
            bv.resize(k * n, 1.0);
            (av, bv)
        } else {
            (mixed_scale_f64(&mut rng, m * k, 61), mixed_scale_f64(&mut rng, k * n, 61))
        };
        // Naive per-element quire reference (no vector:: code).
        let mut c_ref = vec![0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let col: Vec<f64> = (0..k).map(|p| b[p * n + j]).collect();
                c_ref[i * n + j] = naive_quire_dot64(&a[i * k..(i + 1) * k], &col);
            }
        }
        let mut c = vec![0f64; m * n];
        gemm::gemm_quire_f64(&a, &b, &mut c, m, k, n);
        assert_eq!(
            c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            c_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "serial quire GEMM (adversarial={adversarial})"
        );
        let x = &b[..k];
        let mut y_ref = vec![0f64; m];
        for i in 0..m {
            y_ref[i] = naive_quire_dot64(&a[i * k..(i + 1) * k], x);
        }
        let mut q = kernels::QuireDotF64::new();
        let mut y = vec![0f64; m];
        q.gemv_f64(&a, x, &mut y);
        assert_eq!(
            y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "serial quire gemv (adversarial={adversarial})"
        );
        for t in [1usize, 2, 7] {
            let mut ct = vec![0f64; m * n];
            gemm::par_gemm_quire_f64_with(t, &a, &b, &mut ct, m, k, n);
            assert_eq!(
                ct.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                c_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "gemm t={t} (adversarial={adversarial})"
            );
            let mut yt = vec![0f64; m];
            kernels::par_gemv_quire_f64_with(t, &a, x, &mut yt);
            assert_eq!(
                yt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "gemv t={t} (adversarial={adversarial})"
            );
        }
    }
}

#[test]
fn thread_bit_identity_codec64_and_f64_kernels() {
    let mut rng = Rng::new(0xc64ec);
    let xs: Vec<f64> = (0..10_007)
        .map(|_| {
            let v = f64::from_bits(rng.next_u64());
            if v.is_finite() {
                v
            } else {
                -3.25
            }
        })
        .collect();
    let mut w_serial = vec![0u64; xs.len()];
    codec64::bp64_encode_into(&xs, &mut w_serial);
    let mut f_serial = vec![0f64; xs.len()];
    codec64::bp64_decode_into(&w_serial, &mut f_serial);
    let (m, k) = (29usize, 65usize);
    let a = &xs[..m * k];
    let x = &xs[m * k..m * k + k];
    let w_bits = &w_serial[..m * k];
    let mut y_fast = vec![0f64; m];
    kernels::gemv_f64(a, x, &mut y_fast);
    let mut q = kernels::QuireDotF64::new();
    let mut y_w = vec![0f64; m];
    q.gemv_bp64_weights(w_bits, x, &mut y_w);
    for t in [1usize, 2, 7] {
        let mut w = vec![0u64; xs.len()];
        parallel::bp64_encode_into_with(t, &xs, &mut w);
        assert_eq!(w, w_serial, "encode t={t}");
        let mut f = vec![0f64; xs.len()];
        parallel::bp64_decode_into_with(t, &w_serial, &mut f);
        for i in 0..f.len() {
            assert_bits_eq64(f[i], f_serial[i], &format!("decode t={t} lane {i}"));
        }
        let mut rt = xs.clone();
        parallel::bp64_roundtrip_in_place_with(t, &mut rt);
        for i in 0..rt.len() {
            assert_bits_eq64(rt[i], f_serial[i], &format!("roundtrip t={t} lane {i}"));
        }
        let mut y = vec![0f64; m];
        kernels::par_gemv_f64_with(t, a, x, &mut y);
        assert_eq!(
            y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y_fast.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "gemv f64 t={t}"
        );
        kernels::par_gemv_bp64_weights_with(t, w_bits, x, &mut y);
        assert_eq!(
            y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y_w.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "gemv bp64 t={t}"
        );
    }
}

// ----------------------------------------------------------------------
// Width-generic lane API (the ISSUE-5 test satellite, 64-bit half): the
// generic engine must be the named BP64/P64 fast paths bitwise, the
// unified par_* entry points must be thread-count invariant, and the
// typed EncodedTensor boundary must carry the serving layout losslessly.
// ----------------------------------------------------------------------

#[test]
fn generic_engine_bit_identical_to_named_paths_64() {
    let mut rng = Rng::new(0x1a64);
    let bp = LaneCodec::<f64>::bp();
    let p = LaneCodec::<f64>::pstd();
    assert_eq!(bp.spec(), BP64);
    assert_eq!(p.spec(), P64);
    for _ in 0..100_000 {
        let w = rng.next_u64();
        let x = f64::from_bits(w);
        assert_eq!(bp.encode_word(x), codec64::bp64_encode_lane(x), "bp64 encode {w:#018x}");
        assert_eq!(p.encode_word(x), codec64::p64_encode_lane(x), "p64 encode {w:#018x}");
        assert_bits_eq64(bp.decode_word(w), codec64::bp64_decode_lane(w), "bp64 decode");
        assert_bits_eq64(p.decode_word(w), codec64::p64_decode_lane(w), "p64 decode");
    }
    // Slice drivers lane-for-lane, engine vs named, plus roundtrip.
    let xs: Vec<f64> = (0..1003)
        .map(|_| {
            let v = f64::from_bits(rng.next_u64());
            if v.is_finite() { v } else { 0.5 }
        })
        .collect();
    let via_engine = bp.encode(&xs);
    let mut named = vec![0u64; xs.len()];
    codec64::bp64_encode_into(&xs, &mut named);
    assert_eq!(via_engine, named);
    let back_engine = bp.decode(&named);
    let mut back_named = vec![0f64; xs.len()];
    codec64::bp64_decode_into(&named, &mut back_named);
    let mut rt = xs.clone();
    bp.roundtrip_in_place(&mut rt);
    for i in 0..xs.len() {
        assert_bits_eq64(back_engine[i], back_named[i], &format!("slice decode lane {i}"));
        assert_bits_eq64(rt[i], back_named[i], &format!("roundtrip lane {i}"));
    }
    // Arbitrary supported spec: engine ≡ the named module's checked
    // generic entry points.
    let w48 = PositSpec::bounded(48, 6, 5);
    let c48 = LaneCodec::<f64>::new(w48).unwrap();
    for _ in 0..20_000 {
        let x = f64::from_bits(rng.next_u64());
        assert_eq!(c48.encode_word(x), codec64::encode_word(&w48, x), "⟨48,6,5⟩ encode {x:e}");
    }
}

#[test]
fn unified_par_entry_points_thread_identity_64() {
    let mut rng = Rng::new(0x7a64b);
    let xs: Vec<f64> = (0..10_007)
        .map(|_| {
            let v = f64::from_bits(rng.next_u64());
            if v.is_finite() { v } else { -2.5 }
        })
        .collect();
    let bp = LaneCodec::<f64>::bp();
    let serial_w = bp.encode(&xs);
    let mut serial_f = vec![0f64; xs.len()];
    bp.decode_into(&serial_w, &mut serial_f);
    for t in [1usize, 2, 7] {
        let mut w = vec![0u64; xs.len()];
        parallel::par_encode_into_with(t, &BP64, &xs, &mut w);
        assert_eq!(w, serial_w, "generic-spec encode t={t}");
        parallel::par_bp_encode_into_with(t, &xs, &mut w);
        assert_eq!(w, serial_w, "serving-spec encode t={t}");
        let mut f = vec![0f64; xs.len()];
        parallel::par_decode_into_with(t, &BP64, &serial_w, &mut f);
        for i in 0..f.len() {
            assert_bits_eq64(f[i], serial_f[i], &format!("decode t={t} lane {i}"));
        }
        let mut rt = xs.clone();
        parallel::par_roundtrip_in_place_with(t, &BP64, &mut rt);
        for i in 0..rt.len() {
            assert_bits_eq64(rt[i], serial_f[i], &format!("roundtrip t={t} lane {i}"));
        }
    }
}

#[test]
fn encoded_tensor_serving_layout_is_lossless_64() {
    // In-range f64 weights are exactly representable in ⟨64,6,5⟩, so the
    // typed tensor boundary must reproduce them bit-for-bit, and the
    // typed GEMM entry point must equal the raw-slice fast path.
    let mut rng = Rng::new(0xe764);
    let (m, k, n) = (9usize, 21usize, 6usize);
    let w = mixed_scale_f64(&mut rng, m * k, 61);
    let t = EncodedTensor::<f64>::encode_bp(m, k, &w).unwrap();
    let mut back = vec![0f64; m * k];
    t.decode_into(&mut back);
    for i in 0..w.len() {
        assert_bits_eq64(back[i], w[i], &format!("weight {i}"));
    }
    let b = mixed_scale_f64(&mut rng, k * n, 61);
    let mut c_typed = vec![0f64; m * n];
    gemm::par_gemm_encoded_fast(&t, &b, &mut c_typed, n);
    let mut c_raw = vec![0f64; m * n];
    gemm::par_gemm_bp64_weights_fast(t.words(), &b, &mut c_raw, m, k, n);
    for i in 0..c_typed.len() {
        assert_bits_eq64(c_typed[i], c_raw[i], &format!("logit {i}"));
    }
}

// A generic-width smoke: the codec64 generic path serves odd widths the
// 32-bit lanes reject (routing coverage beyond the named formats).
#[test]
fn odd_width_specs_roundtrip_through_codec64() {
    let mut rng = Rng::new(0x0dd);
    for spec in [
        PositSpec::bounded(48, 6, 5),
        PositSpec::bounded(40, 8, 3),
        PositSpec::standard(64, 4),
        PositSpec::bounded(33, 6, 5),
    ] {
        assert!(codec64::spec_supported(&spec));
        for _ in 0..20_000 {
            let x = f64::from_bits(rng.next_u64());
            if x.is_nan() {
                continue;
            }
            let w = codec64::encode_word(&spec, x);
            let y = codec64::decode_word(&spec, w);
            let w2 = codec64::encode_word(&spec, y);
            let y2 = codec64::decode_word(&spec, w2);
            assert_bits_eq64(y2, y, &format!("{spec:?} idempotence at {x:e}"));
        }
    }
}
