//! Cross-module integration tests over the formats layer: codec ↔
//! arithmetic ↔ quire ↔ conversion workflows.

use positron::formats::posit::{BP16, BP32, BP64, P16, P32};
use positron::formats::{
    convert, ieee, math, op_add, op_fma, op_mul, takum, Codec, Decoded, Quire,
};

#[test]
fn p16_addition_table_sampled_against_f64() {
    // posit16 values and sums are exactly representable in f64; encoding
    // the f64 sum must equal the posit-exact sum.
    for a in (0..=u16::MAX as u64).step_by(197) {
        if a == P16.nar() {
            continue;
        }
        for b in (0..=u16::MAX as u64).step_by(251) {
            if b == P16.nar() {
                continue;
            }
            let expect = P16.from_f64(P16.to_f64(a) + P16.to_f64(b));
            assert_eq!(op_add(&P16, a, b), expect, "{a:#x} + {b:#x}");
        }
    }
}

#[test]
fn p16_multiplication_sampled_against_f64() {
    for a in (0..=u16::MAX as u64).step_by(211) {
        if a == P16.nar() {
            continue;
        }
        for b in (0..=u16::MAX as u64).step_by(263) {
            if b == P16.nar() {
                continue;
            }
            let expect = P16.from_f64(P16.to_f64(a) * P16.to_f64(b));
            assert_eq!(op_mul(&P16, a, b), expect, "{a:#x} × {b:#x}");
        }
    }
}

#[test]
fn quire_dot_product_matches_exact_rational() {
    // A dot product engineered so naive bp32 loses bits but the quire is
    // exact (compare against f64 Kahan-style exact small case).
    let xs = [3.0f64, 1e-8, -3.0, 7.5, 2.0_f64.powi(40)];
    let ys = [2.0f64, 1e8, 2.0, 4.0, 2.0_f64.powi(-40)];
    // exact: 6 + 1 - 6 + 30 + 1 = 32
    let mut q = Quire::exact_for(&BP32);
    for (x, y) in xs.iter().zip(&ys) {
        q.add_product(&Decoded::from_f64(*x), &Decoded::from_f64(*y));
    }
    assert_eq!(q.to_decoded().to_f64(), 32.0);
    assert_eq!(BP32.to_f64(q.to_posit(&BP32)), 32.0);
}

#[test]
fn quire_800_vs_exact_agree_for_in_range_products() {
    let vals = [1.5, -2.25, 1024.0, 3.0e-5, -7.0];
    let mut q800 = Quire::paper_800(&BP32);
    let mut qex = Quire::exact_for(&BP32);
    for w in vals.windows(2) {
        let (a, b) = (Decoded::from_f64(w[0]), Decoded::from_f64(w[1]));
        q800.add_product(&a, &b);
        qex.add_product(&a, &b);
    }
    assert_eq!(q800.to_posit(&BP32), qex.to_posit(&BP32));
}

#[test]
fn fma_respects_posit_single_rounding() {
    // fma(a,b,c) in posit space == encode(exact(a·b+c)).
    for (a, b, c) in [(1.5, 1.25, -1.875), (3.0, 7.0, 1e-5), (0.1, 0.2, 0.3)] {
        let (pa, pb, pc) = (BP32.from_f64(a), BP32.from_f64(b), BP32.from_f64(c));
        let got = op_fma(&BP32, pa, pb, pc);
        let exact = math::fma(
            &BP32.decode(pa),
            &BP32.decode(pb),
            &BP32.decode(pc),
        );
        assert_eq!(got, BP32.encode(&exact));
    }
}

#[test]
fn conversion_chain_preserves_fovea_values() {
    // f32 → bp32 → p32 → bp64 → f32 is lossless for fovea values.
    let mut x = 0x12345u64;
    for _ in 0..5000 {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let v = ((x % 65536) as f32 - 32768.0) / 64.0;
        if v == 0.0 {
            continue;
        }
        let f = ieee::F32;
        let a = convert::convert(&f, &BP32, v.to_bits() as u64);
        let b = convert::convert(&BP32, &P32, a);
        let c = convert::convert(&P32, &BP64, b);
        let back = convert::convert(&BP64, &f, c);
        assert_eq!(back as u32, v.to_bits(), "chain broke {v}");
    }
}

#[test]
fn nar_poisons_every_op() {
    let nar = BP32.nar();
    let two = BP32.from_f64(2.0);
    assert_eq!(op_add(&BP32, nar, two), nar);
    assert_eq!(op_mul(&BP32, two, nar), nar);
    assert_eq!(op_fma(&BP32, nar, two, two), nar);
    let mut q = Quire::exact_for(&BP32);
    q.add(&BP32.decode(nar));
    q.add_product(&BP32.decode(two), &BP32.decode(two));
    assert_eq!(q.to_posit(&BP32), nar);
}

#[test]
fn bp16_vs_bp64_consistency() {
    // The same value encoded in bp16 and bp64 and brought back must agree
    // to bp16 precision (spec-family consistency across widths).
    for v in [1.0f64, -3.75, 255.0, 1.0 / 3.0, 9.8765e-3] {
        let short = BP16.to_f64(BP16.from_f64(v));
        let long = BP64.to_f64(BP64.from_f64(short));
        assert_eq!(long, short, "widening must be exact for {v}");
    }
}

#[test]
fn takum_and_bposit_agree_at_unity() {
    // Both formats represent small integers exactly.
    for i in 1..=256i32 {
        let v = i as f64;
        assert_eq!(takum::T32.to_f64(takum::T32.from_f64(v)), v);
        assert_eq!(BP32.to_f64(BP32.from_f64(v)), v);
    }
}

#[test]
fn sqrt_mul_roundtrip_bp32() {
    // √(x²) == |x| when x² stays in the fovea (exactness regression).
    for v in [1.5f64, 2.0, 3.25, 10.0, 0.125] {
        let p = BP32.from_f64(v);
        let sq = op_mul(&BP32, p, p);
        let back = positron::formats::op_sqrt(&BP32, sq);
        assert_eq!(BP32.to_f64(back), v);
    }
}

#[test]
fn paper_quire_sizing_800_for_all_widths() {
    for spec in [BP16, BP32, BP64] {
        assert_eq!(spec.quire_bits(), 800, "⟨{},6,5⟩ quire", spec.n);
    }
    assert_eq!(P32.quire_bits(), 512); // standard posit32: 16·n per standard
}
