//! Solver-layer integration tests: the cross-language golden
//! trajectories, the sparse/dense bitwise contract on the bench
//! operators, thread-count bit-identity, and the preconditioning wins.
//!
//! The golden bit patterns below are the output of the pure-stdlib
//! Python mirror (`python3 python/tests/test_solver_mirror.py
//! --emit-goldens`): grid-8 2D Poisson, b = ones, tol 1e-6, plain CG.
//! The mirror emulates the f32 tier with per-op RNE rounding and the
//! quire tiers with exact dyadic-rational accumulation, so agreement
//! here is agreement with an independent implementation of the paper's
//! exact-reduction semantics, not a self-fulfilling snapshot.

use positron::solver::{operators, solve, CgOptions, Precond, SolveReport, Tier};
use positron::testutil::Rng;
use positron::vector::kernels;
use positron::vector::lane::LaneElem;
use positron::vector::sparse::{self, Csr};

const GOLDEN_GRID: usize = 8;

fn golden_opts() -> CgOptions {
    CgOptions { tol: 1e-6, max_iters: 400, precond: Precond::None }
}

fn golden_solve(tier: Tier) -> SolveReport {
    let a = operators::poisson2d(GOLDEN_GRID);
    let b = operators::ones(GOLDEN_GRID * GOLDEN_GRID);
    solve(&a, &b, tier, &golden_opts())
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Exact ‖r‖₂ per iteration from the mirror's quire64 tier.
const GOLDEN_QUIRE64_RESIDUALS: &[u64] = &[
    0x4020000000000000,
    0x4023988e1409212e,
    0x401bd3e5c6f0e027,
    0x4013f860b75553e0,
    0x40055d49f1c6bc1a,
    0x3fefa526a1d6bb59,
    0x3fd076184c1a5d52,
    0x3fb473856c94bdc5,
    0x3f8af4692b732a53,
    0x3f5cc30f7ca48a89,
    0x3c91d92001ae4bfd,
];

/// Exact ‖r‖₂ per iteration from the mirror's f32 tier (per-op RNE f32
/// rounding in the recurrence, exact norm instrumentation).
const GOLDEN_F32_RESIDUALS: &[u64] = &[
    0x4020000000000000,
    0x4023988e1409212e,
    0x401bd3e5b4639c5a,
    0x4013f860b100d3c5,
    0x40055d4a049f3014,
    0x3fefa52668fa0712,
    0x3fd076184d2c7065,
    0x3fb47385886d723a,
    0x3f8af468c6a60dfc,
    0x3f5cc30f73289243,
    0x3e6d4928f0028765,
];

/// The quire64 final iterate (64 values, row-major on the 8×8 grid; the
/// 8-fold symmetry of the continuous solution survives exactly).
const GOLDEN_QUIRE64_X: &[u64] = &[
    0x3ff36b1dd56174c8,
    0x3ffed63baac2e98f,
    0x4002af9770cc929c,
    0x40042fbbcf213e39,
    0x40042fbbcf213e39,
    0x4002af9770cc929c,
    0x3ffed63baac2e98f,
    0x3ff36b1dd56174c8,
    0x3ffed63baac2e98f,
    0x40094750fa08861c,
    0x400f23841eaf9773,
    0x4010efcdfe4b9409,
    0x4010efcdfe4b9409,
    0x400f23841eaf9773,
    0x40094750fa08861c,
    0x3ffed63baac2e98f,
    0x4002af9770cc929c,
    0x400f23841eaf9773,
    0x40135bc609a90e7e,
    0x401525ca03fa5144,
    0x401525ca03fa5144,
    0x40135bc609a90e7e,
    0x400f23841eaf9773,
    0x4002af9770cc929c,
    0x40042fbbcf213e39,
    0x4010efcdfe4b9409,
    0x401525ca03fa5144,
    0x401725ca03fa5143,
    0x401725ca03fa5143,
    0x401525ca03fa5144,
    0x4010efcdfe4b9409,
    0x40042fbbcf213e39,
    0x40042fbbcf213e39,
    0x4010efcdfe4b9409,
    0x401525ca03fa5144,
    0x401725ca03fa5143,
    0x401725ca03fa5143,
    0x401525ca03fa5144,
    0x4010efcdfe4b9409,
    0x40042fbbcf213e39,
    0x4002af9770cc929c,
    0x400f23841eaf9773,
    0x40135bc609a90e7e,
    0x401525ca03fa5144,
    0x401525ca03fa5144,
    0x40135bc609a90e7e,
    0x400f23841eaf9773,
    0x4002af9770cc929c,
    0x3ffed63baac2e98f,
    0x40094750fa08861c,
    0x400f23841eaf9773,
    0x4010efcdfe4b9409,
    0x4010efcdfe4b9409,
    0x400f23841eaf9773,
    0x40094750fa08861c,
    0x3ffed63baac2e98f,
    0x3ff36b1dd56174c8,
    0x3ffed63baac2e98f,
    0x4002af9770cc929c,
    0x40042fbbcf213e39,
    0x40042fbbcf213e39,
    0x4002af9770cc929c,
    0x3ffed63baac2e98f,
    0x3ff36b1dd56174c8,
];

#[test]
fn quire64_trajectory_matches_the_python_mirror_bitwise() {
    let rep = golden_solve(Tier::Quire64);
    assert!(rep.converged && !rep.breakdown);
    assert_eq!(rep.iterations, GOLDEN_QUIRE64_RESIDUALS.len() - 1);
    assert_eq!(bits(&rep.residuals), GOLDEN_QUIRE64_RESIDUALS);
    assert_eq!(bits(&rep.x), GOLDEN_QUIRE64_X);
}

#[test]
fn f32_trajectory_matches_the_python_mirror_bitwise() {
    let rep = golden_solve(Tier::F32);
    assert!(rep.converged && !rep.breakdown);
    assert_eq!(rep.iterations, GOLDEN_F32_RESIDUALS.len() - 1);
    assert_eq!(bits(&rep.residuals), GOLDEN_F32_RESIDUALS);
}

#[test]
fn quire32_and_f64_share_the_exact_first_two_entries() {
    // Entry 0 (‖b‖₂) and entry 1 are exactly representable computations
    // on this operator, so every tier must agree on them bitwise.
    for tier in Tier::ALL {
        let rep = golden_solve(tier);
        assert_eq!(rep.residuals[0].to_bits(), GOLDEN_QUIRE64_RESIDUALS[0], "{}", tier.name());
        assert_eq!(rep.residuals[1].to_bits(), GOLDEN_QUIRE64_RESIDUALS[1], "{}", tier.name());
    }
}

#[test]
fn quire_tier_never_needs_more_iterations_than_fast_on_poisson() {
    // The CI gate's invariant, asserted in-tree on two sizes: exact
    // reductions cannot lose to rounded ones on the model problem.
    for grid in [8, 16] {
        let a = operators::poisson2d(grid);
        let b = operators::ones(grid * grid);
        let q32 = solve(&a, &b, Tier::Quire32, &golden_opts());
        let f32t = solve(&a, &b, Tier::F32, &golden_opts());
        assert!(q32.converged && f32t.converged, "grid {grid}");
        assert!(q32.iterations <= f32t.iterations, "grid {grid}");
        let q64 = solve(&a, &b, Tier::Quire64, &golden_opts());
        let f64t = solve(&a, &b, Tier::F64, &golden_opts());
        assert!(q64.iterations <= f64t.iterations, "grid {grid}");
    }
}

/// Sparse SpMV vs the dense gemv family on the densified bench
/// operators, per kernel flavor — the chunk-aware contract that makes
/// the solver's arithmetic identical to the serving kernels'.
fn spmv_vs_dense<E: LaneElem>(a64: &Csr<f64>, x_src: &[f64]) {
    let m = a64.convert::<E>();
    let (rows, cols) = (m.rows(), m.cols());
    let dense = m.to_dense();
    let x: Vec<E> = x_src.iter().map(|&v| E::from_f64(v)).collect();

    let mut ys = vec![E::ZERO; rows];
    let mut yd = vec![E::ZERO; rows];
    sparse::spmv(&m, &x, &mut ys);
    kernels::gemv(&dense, &x, &mut yd);
    for r in 0..rows {
        assert_eq!(ys[r].to_bits_u64(), yd[r].to_bits_u64(), "fast row {r}");
    }

    let mut q = E::quire();
    sparse::spmv_quire(&mut q, &m, &x, &mut ys);
    kernels::par_gemv_quire_with(1, &dense, &x, &mut yd);
    for r in 0..rows {
        assert_eq!(ys[r].to_bits_u64(), yd[r].to_bits_u64(), "quire row {r}");
    }

    let mw = m.encode_bp();
    let words: Vec<E::Word> = dense.iter().map(|&v| E::bp_encode_lane(v)).collect();
    sparse::spmv_bp_weights_fast(&mw, &x, &mut ys);
    kernels::par_gemv_bp_weights_with(1, &words, &x, &mut yd);
    assert_eq!(words.len(), rows * cols);
    for r in 0..rows {
        assert_eq!(ys[r].to_bits_u64(), yd[r].to_bits_u64(), "bp row {r}");
    }
}

#[test]
fn spmv_is_bitwise_dense_gemv_on_the_bench_operators() {
    let mut rng = Rng::new(0x5eed);
    for a in [operators::poisson2d(6), operators::rand_dd(40, 3, 4, 5)] {
        let x: Vec<f64> = (0..a.cols()).map(|_| (rng.f64() - 0.5) * 4.0).collect();
        spmv_vs_dense::<f32>(&a, &x);
        spmv_vs_dense::<f64>(&a, &x);
    }
}

#[test]
fn par_spmv_is_bit_identical_for_any_thread_count() {
    let a = operators::rand_dd(65, 4, 3, 9).convert::<f64>();
    let mut rng = Rng::new(0xabc);
    let x: Vec<f64> = (0..65).map(|_| (rng.f64() - 0.5) * 4.0).collect();
    let mut want = vec![0.0f64; 65];
    sparse::spmv(&a, &x, &mut want);
    let aw = a.encode_bp();
    let mut want_bp = vec![0.0f64; 65];
    sparse::spmv_bp_weights_fast(&aw, &x, &mut want_bp);
    for t in [1usize, 2, 7] {
        let mut y = vec![0.0f64; 65];
        sparse::par_spmv_with(t, &a, &x, &mut y);
        assert_eq!(bits(&y), bits(&want), "fast t={t}");
        sparse::par_spmv_quire_with(t, &a, &x, &mut y);
        let mut serial = vec![0.0f64; 65];
        let mut q = <f64 as LaneElem>::quire();
        sparse::spmv_quire(&mut q, &a, &x, &mut serial);
        assert_eq!(bits(&y), bits(&serial), "quire t={t}");
        sparse::par_spmv_bp_weights_fast_with(t, &aw, &x, &mut y);
        assert_eq!(bits(&y), bits(&want_bp), "bp t={t}");
    }
}

#[test]
fn jacobi_never_loses_on_poisson_and_wins_on_a_skewed_operator() {
    // Poisson's constant diagonal makes Jacobi an exact no-op (the
    // in-module test pins that bitwise); here the contract is the weaker
    // bench-gate form — preconditioning must never cost iterations.
    let a = operators::poisson2d(12);
    let b = operators::ones(144);
    let plain = solve(&a, &b, Tier::F64, &golden_opts());
    let opts = CgOptions { precond: Precond::Jacobi, ..golden_opts() };
    let pre = solve(&a, &b, Tier::F64, &opts);
    assert!(pre.iterations <= plain.iterations);

    // A diagonally-skewed operator (power-of-2 congruence scaling over
    // ~2^16) is what Jacobi exists for: a strict, large win.
    let a = operators::rand_dd(96, 3, 8, 11);
    let b = operators::ones(96);
    let opts_plain = CgOptions { max_iters: 200, ..golden_opts() };
    let opts_pre = CgOptions { max_iters: 200, precond: Precond::Jacobi, ..golden_opts() };
    let plain = solve(&a, &b, Tier::F64, &opts_plain);
    let pre = solve(&a, &b, Tier::F64, &opts_pre);
    assert!(pre.converged, "Jacobi must converge on the skewed operator");
    assert!(
        pre.iterations < plain.iterations,
        "jacobi {} vs plain {} (converged: {})",
        pre.iterations,
        plain.iterations,
        plain.converged
    );
}
