//! Runtime integration: load every AOT artifact, execute it on the PJRT
//! CPU client, and cross-check against both the Python-recorded goldens
//! and the Rust codec — the proof that all three layers agree.
//! Skips (with a notice) when artifacts haven't been built.
//!
//! Feature-gated: needs the PJRT/XLA backend (`--features runtime`).
#![cfg(feature = "runtime")]

use positron::formats::posit::BP32;
use positron::runtime::{
    artifacts_available, default_artifact_dir, lit_f32, lit_f32_2d, lit_i32, ModelWeights, Runtime,
};

fn runtime() -> Option<(Runtime, ModelWeights)> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::cpu(&dir).expect("pjrt cpu client");
    let w = ModelWeights::load(&rt).expect("weights.json");
    Some((rt, w))
}

#[test]
fn codec_decode_hlo_matches_rust_codec() {
    let Some((rt, _)) = runtime() else { return };
    let model = rt.load("codec_decode.hlo.txt").expect("load decode hlo");
    // 8192 words: corners + PRNG.
    let mut words: Vec<i32> = vec![0, 1, -1, i32::MAX, i32::MIN + 1, 0x40000000];
    let mut x = 0xdeadbeefcafef00du64;
    while words.len() < 8192 {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        words.push(x as i32);
    }
    let out = model.run_f32(&[lit_i32(&words)]).expect("execute");
    let mut checked = 0;
    for (w, got) in words.iter().zip(&out) {
        let d = BP32.decode(*w as u32 as u64);
        let want = d.to_f64();
        if want.is_nan() {
            assert!(got.is_nan(), "NaR must decode to NaN");
            continue;
        }
        // Kernel contract: f32 flush-to-zero below 2^-126, ±inf beyond f32.
        let want32 = if want != 0.0 && want.abs() < f64::powi(2.0, -126) {
            0.0f32
        } else {
            want as f32
        };
        assert_eq!(*got, want32, "decode({w:#x}) HLO {got} vs rust {want32}");
        checked += 1;
    }
    assert!(checked > 8000);
}

#[test]
fn codec_encode_hlo_matches_rust_codec() {
    let Some((rt, _)) = runtime() else { return };
    let model = rt.load("codec_encode.hlo.txt").expect("load encode hlo");
    let mut vals: Vec<f32> = vec![0.0, 1.0, -1.0, 1.5, 3.14159265, -2.71828, 1e30, -1e-30];
    let mut x = 0x0123456789abcdefu64;
    while vals.len() < 8192 {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let v = f32::from_bits(x as u32);
        vals.push(if v.is_finite() { v } else { 1.0 });
    }
    let out = model.run_i32(&[lit_f32(&vals)]).expect("execute");
    for (v, got) in vals.iter().zip(&out) {
        // Flushed subnormal inputs encode to 0 by kernel contract.
        let want = if *v != 0.0 && v.abs() < f32::powi(2.0, -126) {
            0i32
        } else {
            BP32.from_f64(*v as f64) as u32 as i32
        };
        assert_eq!(*got, want, "encode({v}) HLO {got:#x} vs rust {want:#x}");
    }
}

#[test]
fn model_bposit_hlo_matches_python_golden() {
    let Some((rt, w)) = runtime() else { return };
    let model = rt.load("model_bposit.hlo.txt").expect("load model");
    let mut args = vec![lit_f32_2d(&w.golden_x, w.batch, w.d).unwrap()];
    args.extend(w.bposit_arg_literals().unwrap());
    let logits = model.run_f32(&args).expect("execute");
    assert_eq!(logits.len(), w.golden_logits_bposit.len());
    for (i, (got, want)) in logits.iter().zip(&w.golden_logits_bposit).enumerate() {
        assert!(
            (got - want).abs() <= 1e-4 * want.abs().max(1.0),
            "logit {i}: rust-served {got} vs python golden {want}"
        );
    }
}

#[test]
fn model_f32_hlo_matches_python_golden() {
    let Some((rt, w)) = runtime() else { return };
    let model = rt.load("model_f32.hlo.txt").expect("load model");
    let mut args = vec![lit_f32_2d(&w.golden_x, w.batch, w.d).unwrap()];
    args.extend(w.f32_arg_literals().unwrap());
    let logits = model.run_f32(&args).expect("execute");
    for (got, want) in logits.iter().zip(&w.golden_logits_f32) {
        assert!((got - want).abs() <= 1e-4 * want.abs().max(1.0));
    }
}

#[test]
fn quantized_and_f32_models_agree_on_task() {
    // The b-posit-quantized model's *decisions* match f32's on the golden
    // batch (bp32 weights carry ≥ f32 precision in the fovea).
    let Some((rt, w)) = runtime() else { return };
    let mf = rt.load("model_f32.hlo.txt").unwrap();
    let mb = rt.load("model_bposit.hlo.txt").unwrap();
    let x = lit_f32_2d(&w.golden_x, w.batch, w.d).unwrap();
    let mut af = vec![x];
    af.extend(w.f32_arg_literals().unwrap());
    let x2 = lit_f32_2d(&w.golden_x, w.batch, w.d).unwrap();
    let mut ab = vec![x2];
    ab.extend(w.bposit_arg_literals().unwrap());
    let lf = mf.run_f32(&af).unwrap();
    let lb = mb.run_f32(&ab).unwrap();
    let argmax = |row: &[f32]| -> usize {
        row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
    };
    let mut agree = 0;
    for i in 0..w.batch {
        if argmax(&lf[i * w.c..(i + 1) * w.c]) == argmax(&lb[i * w.c..(i + 1) * w.c]) {
            agree += 1;
        }
    }
    assert_eq!(agree, w.batch, "quantized decisions must match f32");
}

#[test]
fn weights_quantization_matches_rust_quantizer() {
    // The Python-encoded weight words equal what the Rust quantizer
    // produces from the f32 weights — codec agreement at tensor scale.
    let Some((_rt, w)) = runtime() else { return };
    let ours = positron::coordinator::quantizer::quantize(&w.w1);
    assert_eq!(ours.len(), w.w1_bits.len());
    for (i, (a, b)) in ours.iter().zip(&w.w1_bits).enumerate() {
        assert_eq!(a, b, "w1[{i}] quantization mismatch");
    }
}
