//! Blocked-GEMM parity and sharding bit-identity (the ISSUE-2 test
//! satellite):
//! - the blocked f32 fast path vs a naive ascending-`p` triple loop:
//!   **bitwise** equality (blocking must buy locality, not reassociation);
//! - the blocked quire path vs an independent naive triple-loop quire
//!   reference built directly on `formats::Quire`, on random mixed-scale
//!   and adversarial cancellation-heavy matrices;
//! - `PALLAS_THREADS ∈ {1, 2, 7}`-style bit-identity for the sharded
//!   codec, `par_gemv_*`, and every `par_gemm_*` path (via the explicit
//!   `_with` thread-count entry points, which is what the env var feeds).

use positron::formats::posit::BP32;
use positron::formats::{Decoded, Quire};
use positron::testutil::Rng;
use positron::vector::{codec, gemm, kernels, parallel};

/// Independent reference: naive triple-loop GEMM with one 800-bit quire
/// accumulation per output element, built straight on the formats layer
/// (no vector:: code involved).
fn naive_quire_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut q = Quire::paper_800(&BP32);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            q.clear();
            for p in 0..k {
                q.add_product(
                    &Decoded::from_f64(a[i * k + p] as f64),
                    &Decoded::from_f64(b[p * n + j] as f64),
                );
            }
            c[i * n + j] = q.to_decoded().to_f64() as f32;
        }
    }
    c
}

/// Independent reference for the quantized-weight path.
fn naive_quire_gemm_bp32(a_bits: &[u32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut q = Quire::paper_800(&BP32);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            q.clear();
            for p in 0..k {
                q.add_product(
                    &BP32.decode(a_bits[i * k + p] as u64),
                    &Decoded::from_f64(b[p * n + j] as f64),
                );
            }
            c[i * n + j] = q.to_decoded().to_f64() as f32;
        }
    }
    c
}

fn naive_f32_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

use positron::testutil::mixed_scale_f32 as mixed;

/// Cancellation-heavy matrices: consecutive (big, tiny, −big) triples per
/// row/column so the f32 path loses the tiny terms and the quire must not.
fn adversarial(m: usize, k: usize, n: usize) -> (Vec<f32>, Vec<f32>) {
    let big = 16777216.0f32; // 2^24, exact in f32; big² = 2^48
    let mut a = vec![0f32; m * k];
    let mut b = vec![0f32; k * n];
    for i in 0..m {
        for p in 0..k {
            a[i * k + p] = match p % 3 {
                0 => big,
                1 => 1.0 + (i % 7) as f32,
                _ => -big,
            };
        }
    }
    for p in 0..k {
        for j in 0..n {
            b[p * n + j] = match p % 3 {
                0 => big,
                1 => 1.0 / 256.0 * (1 + (j % 5)) as f32,
                _ => big,
            };
        }
    }
    (a, b)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn blocked_f32_matches_naive_bitwise_across_blocking_boundaries() {
    let mut rng = Rng::new(0x61e8);
    // Shapes straddling MR/NR/KC/NC boundaries, including non-multiples.
    let shapes = [(1, 1, 1), (4, 8, 8), (5, 300, 9), (7, 513, 17), (33, 129, 131), (2, 1024, 3)];
    for (m, k, n) in shapes {
        let a = mixed(&mut rng, m * k, 31);
        let b = mixed(&mut rng, k * n, 31);
        let mut c = vec![0f32; m * n];
        gemm::gemm_f32(&a, &b, &mut c, m, k, n);
        assert_eq!(bits(&c), bits(&naive_f32_gemm(&a, &b, m, k, n)), "{m}x{k}x{n}");
    }
}

#[test]
fn blocked_quire_matches_naive_quire_reference_random() {
    let mut rng = Rng::new(0x9a11);
    for (m, k, n) in [(3, 5, 7), (8, 33, 12), (13, 257, 9)] {
        let a = mixed(&mut rng, m * k, 41);
        let b = mixed(&mut rng, k * n, 41);
        let mut c = vec![0f32; m * n];
        gemm::gemm_quire_f32(&a, &b, &mut c, m, k, n);
        assert_eq!(bits(&c), bits(&naive_quire_gemm(&a, &b, m, k, n)), "{m}x{k}x{n}");
    }
}

#[test]
fn blocked_quire_survives_adversarial_cancellation() {
    let (m, k, n) = (6, 24, 10);
    let (a, b) = adversarial(m, k, n);
    let mut c = vec![0f32; m * n];
    gemm::gemm_quire_f32(&a, &b, &mut c, m, k, n);
    let reference = naive_quire_gemm(&a, &b, m, k, n);
    assert_eq!(bits(&c), bits(&reference));
    // And the cancellation actually bites: the f32 path must disagree
    // (the tiny recovered terms are below f32 accumulation resolution).
    let fast = naive_f32_gemm(&a, &b, m, k, n);
    assert_ne!(bits(&fast), bits(&reference), "adversarial data too tame");
    // Exactness sanity on one element: k/3 triples of (2^48 + tiny - 2^48)
    // leave exactly the sum of the tiny cross terms.
    assert!(c.iter().all(|v| v.is_finite()));
}

#[test]
fn quantized_weight_gemm_matches_naive_reference() {
    let mut rng = Rng::new(0x0eed);
    let (m, k, n) = (5, 19, 6);
    let w = mixed(&mut rng, m * k, 21);
    let w_bits: Vec<u32> = w.iter().map(|&x| codec::bp32_encode_lane(x)).collect();
    let b = mixed(&mut rng, k * n, 21);
    let mut c = vec![0f32; m * n];
    gemm::gemm_bp32_weights(&w_bits, &b, &mut c, m, k, n);
    assert_eq!(bits(&c), bits(&naive_quire_gemm_bp32(&w_bits, &b, m, k, n)));
}

#[test]
fn thread_count_bit_identity_gemm_and_gemv() {
    let mut rng = Rng::new(0x1dea);
    let (m, k, n) = (29, 65, 23);
    let a = mixed(&mut rng, m * k, 31);
    let b = mixed(&mut rng, k * n, 31);
    let a_bits: Vec<u32> = a.iter().map(|&x| codec::bp32_encode_lane(x)).collect();
    let x = mixed(&mut rng, k, 31);

    let mut c_f32 = vec![0f32; m * n];
    gemm::gemm_f32(&a, &b, &mut c_f32, m, k, n);
    let mut c_quire = vec![0f32; m * n];
    gemm::gemm_quire_f32(&a, &b, &mut c_quire, m, k, n);
    let mut c_w = vec![0f32; m * n];
    gemm::gemm_bp32_weights(&a_bits, &b, &mut c_w, m, k, n);
    let mut c_wf = vec![0f32; m * n];
    gemm::gemm_bp32_weights_fast(&a_bits, &b, &mut c_wf, m, k, n);

    let mut y_f32 = vec![0f32; m];
    kernels::gemv_f32(&a[..m * k], &x, &mut y_f32);
    let mut q = kernels::QuireDot::new();
    let mut y_quire = vec![0f32; m];
    q.gemv_f32(&a[..m * k], &x, &mut y_quire);
    let mut y_w = vec![0f32; m];
    q.gemv_bp32_weights(&a_bits[..m * k], &x, &mut y_w);

    for t in [1usize, 2, 7] {
        let mut c = vec![0f32; m * n];
        gemm::par_gemm_f32_with(t, &a, &b, &mut c, m, k, n);
        assert_eq!(bits(&c), bits(&c_f32), "gemm f32 t={t}");
        gemm::par_gemm_quire_f32_with(t, &a, &b, &mut c, m, k, n);
        assert_eq!(bits(&c), bits(&c_quire), "gemm quire t={t}");
        gemm::par_gemm_bp32_weights_with(t, &a_bits, &b, &mut c, m, k, n);
        assert_eq!(bits(&c), bits(&c_w), "gemm bp32 t={t}");
        gemm::par_gemm_bp32_weights_fast_with(t, &a_bits, &b, &mut c, m, k, n);
        assert_eq!(bits(&c), bits(&c_wf), "gemm bp32 fast t={t}");

        let mut y = vec![0f32; m];
        kernels::par_gemv_f32_with(t, &a[..m * k], &x, &mut y);
        assert_eq!(bits(&y), bits(&y_f32), "gemv f32 t={t}");
        kernels::par_gemv_quire_f32_with(t, &a[..m * k], &x, &mut y);
        assert_eq!(bits(&y), bits(&y_quire), "gemv quire t={t}");
        kernels::par_gemv_bp32_weights_with(t, &a_bits[..m * k], &x, &mut y);
        assert_eq!(bits(&y), bits(&y_w), "gemv bp32 t={t}");
    }
}

#[test]
fn thread_count_bit_identity_sharded_codec() {
    let mut rng = Rng::new(0xc0dec);
    let xs: Vec<f32> = (0..10_007)
        .map(|_| {
            let v = f32::from_bits(rng.next_u32());
            if v.is_finite() {
                v
            } else {
                -3.25
            }
        })
        .collect();
    let mut w_serial = vec![0u32; xs.len()];
    codec::bp32_encode_into(&xs, &mut w_serial);
    let mut f_serial = vec![0f32; xs.len()];
    codec::bp32_decode_into(&w_serial, &mut f_serial);
    for t in [1usize, 2, 7] {
        let mut w = vec![0u32; xs.len()];
        parallel::bp32_encode_into_with(t, &xs, &mut w);
        assert_eq!(w, w_serial, "encode t={t}");
        let mut f = vec![0f32; xs.len()];
        parallel::bp32_decode_into_with(t, &w, &mut f);
        assert_eq!(bits(&f), bits(&f_serial), "decode t={t}");
        let mut rt = xs.clone();
        parallel::bp32_roundtrip_in_place_with(t, &mut rt);
        assert_eq!(bits(&rt), bits(&f_serial), "roundtrip t={t}");
    }
}

#[test]
fn quantizer_batch_apis_unchanged_by_sharding() {
    // The coordinator contract: routing the batch APIs through the sharded
    // codec must not change a single bit vs the scalar fast path.
    use positron::coordinator::quantizer;
    let mut rng = Rng::new(0xba7c4);
    let xs: Vec<f32> = (0..50_000)
        .map(|_| {
            let v = f32::from_bits(rng.next_u32());
            if v.is_finite() {
                v
            } else {
                0.5
            }
        })
        .collect();
    let batch = quantizer::quantize(&xs);
    for (i, &x) in xs.iter().enumerate() {
        assert_eq!(batch[i], quantizer::quantize_one(x), "quantize lane {i}");
    }
    let back = quantizer::dequantize(&batch);
    for (i, &w) in batch.iter().enumerate() {
        let want = quantizer::dequantize_one(w).to_bits();
        assert_eq!(back[i].to_bits(), want, "dequantize lane {i}");
    }
    let rt = quantizer::roundtrip(&xs);
    let mut rt_ip = xs.clone();
    quantizer::roundtrip_in_place(&mut rt_ip);
    assert_eq!(bits(&rt), bits(&rt_ip));
    for i in 0..xs.len() {
        assert_eq!(
            rt[i].to_bits(),
            quantizer::dequantize_one(quantizer::quantize_one(xs[i])).to_bits(),
            "roundtrip lane {i}"
        );
    }
}
