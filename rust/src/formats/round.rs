//! Pattern-space rounding primitives.
//!
//! Posit-family encoders serialize `regime ‖ exponent ‖ fraction` into a
//! conceptually infinite bit stream, cut it at `n-1` bits, and apply
//! round-to-nearest-even on the *pattern* (the Posit™ Standard's rounding
//! rule; SoftPosit does the same). [`BitStream`] is that serializer: an
//! MSB-aligned 128-bit window plus a sticky flag for anything pushed past
//! the window. All reproduced formats cut at ≤ 63 bits, so guard and round
//! positions always fall inside the window.

/// MSB-aligned bit accumulator with overflow sticky.
#[derive(Clone, Copy, Debug)]
pub struct BitStream {
    /// Bits accumulated so far, left-aligned: first pushed bit is bit 127.
    acc: u128,
    /// Number of bits pushed (may exceed 128).
    len: u32,
    /// OR of all bits pushed beyond the 128-bit window.
    overflow_sticky: bool,
}

impl BitStream {
    pub fn new() -> Self {
        BitStream { acc: 0, len: 0, overflow_sticky: false }
    }

    /// Push the low `width` bits of `bits`, MSB-first, after previously
    /// pushed bits.
    pub fn push(&mut self, bits: u64, width: u32) {
        debug_assert!(width <= 64);
        if width == 0 {
            return;
        }
        let bits = if width == 64 { bits } else { bits & ((1u64 << width) - 1) };
        let remaining = 128i64 - self.len as i64;
        if remaining <= 0 {
            self.overflow_sticky |= bits != 0;
        } else if (width as i64) <= remaining {
            self.acc |= (bits as u128) << (remaining - width as i64);
        } else {
            let keep = remaining as u32; // bits that fit
            let dropped = width - keep;
            self.acc |= (bits as u128) >> dropped;
            self.overflow_sticky |= bits & ((1u64 << dropped) - 1) != 0;
        }
        self.len += width;
    }

    /// Push a run of `count` copies of `bit`.
    pub fn push_run(&mut self, bit: u64, count: u32) {
        debug_assert!(bit <= 1);
        let mut left = count;
        while left > 0 {
            let chunk = left.min(63);
            let v = if bit == 1 { (1u64 << chunk) - 1 } else { 0 };
            self.push(v, chunk);
            left -= chunk;
        }
    }

    /// OR an out-of-band sticky contribution (e.g. `Decoded::sticky`).
    pub fn or_sticky(&mut self, s: bool) {
        self.overflow_sticky |= s;
    }

    /// Cut the stream at `cut` bits with round-to-nearest-even.
    ///
    /// Returns the rounded `cut`-bit pattern as u64 (`cut` ≤ 63). A carry
    /// out of the top produces `2^cut`, which callers must saturate.
    pub fn round_rne(&self, cut: u32) -> u64 {
        debug_assert!(cut <= 63 && cut < 128);
        let body = (self.acc >> (128 - cut)) as u64;
        let guard = (self.acc >> (127 - cut)) & 1 == 1;
        let below_mask = (1u128 << (127 - cut)) - 1;
        let sticky = (self.acc & below_mask) != 0 || self.overflow_sticky;
        if guard && (sticky || body & 1 == 1) {
            body + 1
        } else {
            body
        }
    }

    /// Number of bits pushed so far.
    pub fn len(&self) -> u32 {
        self.len
    }

    /// True if truncating at `cut` bits discards any set bit (inexact).
    pub fn inexact_at(&self, cut: u32) -> bool {
        let below_mask = (1u128 << (128 - cut)) - 1;
        (self.acc & below_mask) != 0 || self.overflow_sticky
    }
}

impl Default for BitStream {
    fn default() -> Self {
        Self::new()
    }
}

/// Round-to-nearest-even on a plain 64-bit significand: keep the top `keep`
/// bits of `sig` (counted from bit 63 downwards), with `extra_sticky` OR-ed
/// below. Returns (rounded, carry_out) where carry_out means the rounded
/// value reached `2^keep`.
pub fn rne64(sig: u64, keep: u32, extra_sticky: bool) -> (u64, bool) {
    debug_assert!(keep >= 1 && keep < 64);
    let drop = 64 - keep;
    let kept = sig >> drop;
    let guard = (sig >> (drop - 1)) & 1 == 1;
    let below = if drop >= 2 { sig & ((1u64 << (drop - 1)) - 1) != 0 } else { false };
    let sticky = below || extra_sticky;
    let rounded = kept + if guard && (sticky || kept & 1 == 1) { 1 } else { 0 };
    if rounded >> keep != 0 {
        (rounded >> 1, true)
    } else {
        (rounded, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_cut_basic() {
        let mut s = BitStream::new();
        s.push(0b101, 3);
        s.push(0b11, 2);
        // stream = 10111...
        assert_eq!(s.round_rne(5), 0b10111);
        assert_eq!(s.round_rne(4), 0b1100); // 1011|1 guard=1 sticky=0 lsb=1 → up
        assert_eq!(s.round_rne(3), 0b110); // 101|11 guard=1 sticky=1 → up
    }

    #[test]
    fn rne_ties_to_even() {
        let mut s = BitStream::new();
        s.push(0b0101, 4); // cut at 3: 010|1, guard=1 sticky=0, lsb=0 → stays 010
        assert_eq!(s.round_rne(3), 0b010);
        let mut s2 = BitStream::new();
        s2.push(0b0111, 4); // 011|1 tie, lsb=1 → up to 100
        assert_eq!(s2.round_rne(3), 0b100);
    }

    #[test]
    fn overflow_past_window_sets_sticky() {
        let mut s = BitStream::new();
        s.push_run(0, 126);
        s.push(0b11, 2); // exactly fills 128
        s.push(1, 1); // overflows
        assert!(s.inexact_at(120));
        // body at cut 10 is zero; guard 0; sticky true but no round-up
        assert_eq!(s.round_rne(10), 0);
    }

    #[test]
    fn push_run_long() {
        let mut s = BitStream::new();
        s.push_run(1, 70);
        s.push_run(0, 70);
        assert_eq!(s.len(), 140);
        // 8 ones kept, guard 1, sticky 1 → rounds up and carries out (0x100);
        // the caller is responsible for saturating a carry-out.
        assert_eq!(s.round_rne(8), 0x100);
    }

    #[test]
    fn carry_out_reported() {
        let mut s = BitStream::new();
        s.push(0b1111, 4);
        s.push(1, 1);
        s.push(1, 1); // 111111
        assert_eq!(s.round_rne(4), 0b10000); // carry out: caller saturates
    }

    #[test]
    fn or_sticky_influences_rounding() {
        let mut s = BitStream::new();
        s.push(0b1001, 4);
        // cut 3: 100|1 guard, no sticky, lsb 0 → tie stays at 100
        assert_eq!(s.round_rne(3), 0b100);
        s.or_sticky(true);
        // now sticky → round up
        assert_eq!(s.round_rne(3), 0b101);
    }

    #[test]
    fn rne64_basics() {
        let sig = (1u64 << 63) | (1u64 << 10);
        let (r, c) = rne64(sig, 53, false);
        // guard bit set (bit 10), sticky 0, kept lsb (bit 11) = 0 → tie-to-even stays
        assert_eq!(r, sig >> 11);
        assert!(!c);
        // with sticky set, rounds up
        let (r, _) = rne64(sig, 53, true);
        assert_eq!(r, (sig >> 11) + 1);
        // All-ones carries out.
        let (r, c) = rne64(u64::MAX, 8, false);
        assert_eq!(r, 0x80);
        assert!(c);
    }
}
