//! Quire: the posit standard's exact (Kulisch-style) fixed-point
//! accumulator. The paper highlights that the ⟨n,6,5⟩ b-posit family shares
//! a single **800-bit** quire for every precision n > 12, because the
//! dynamic range is pinned at 2^±192 regardless of n.
//!
//! Two sizings are provided:
//! - [`Quire::paper_800`]: the paper's architectural sizing — 31 carry-guard
//!   bits + 2·(2·192)+1 value positions = 800 bits, LSB at 2^−384. Product
//!   bits below 2^−384 (possible for b-posits, whose minpos carries fraction
//!   bits) are tracked in a sticky flag, keeping results faithfully rounded.
//! - [`Quire::exact_for`]: widened so that every product of two format
//!   values is representable exactly (lossless dot products).
//!
//! The accumulator is a little-endian two's-complement multi-limb integer
//! scaled by 2^lsb_exp.

use super::decoded::{Class, Decoded};
use super::posit::PositSpec;

/// Fixed-point exact accumulator.
#[derive(Clone, Debug)]
pub struct Quire {
    /// Little-endian limbs, two's complement.
    limbs: Vec<u64>,
    /// Binary weight of bit 0 of limb 0.
    lsb_exp: i32,
    /// Any nonzero value bits discarded below the LSB.
    sticky: bool,
    /// Sticky NaR: set by NaR inputs or overflow past the carry guard.
    nar: bool,
}

impl Quire {
    /// Quire with `width` bits and least-significant-bit weight 2^lsb_exp.
    pub fn new(width: u32, lsb_exp: i32) -> Quire {
        assert!(width >= 128 && width % 64 == 0);
        Quire { limbs: vec![0u64; (width / 64) as usize], lsb_exp, sticky: false, nar: false }
    }

    /// The paper's 800-bit quire for a ⟨n,rS,eS⟩ spec: 31 carry bits +
    /// 2·(2·|Tmin|)+1 positions (= 800 for eS=5, rS=6).
    pub fn paper_800(spec: &PositSpec) -> Quire {
        let t = spec.min_exp().unsigned_abs();
        let width = (31 + 4 * t + 1 + 63) / 64 * 64; // round up to limb size
        Quire::new(width, -(2 * t as i32))
    }

    /// Lossless sizing: LSB down to minpos², MSB up to maxpos² + 31 carries.
    pub fn exact_for(spec: &PositSpec) -> Quire {
        let min_lsb = 2 * (spec.min_exp() - 63); // product LSB can't be lower
        let top = 2 * (spec.max_exp() + 1) + 32;
        let width = ((top - min_lsb) as u32 + 63) / 64 * 64;
        Quire::new(width, min_lsb)
    }

    /// Lossless sizing for the full f64 range: every product of two f64
    /// values (subnormals included) accumulates exactly. Product bits
    /// reach down to 2·(−1074) − 126 = −2274 (two min-subnormal
    /// significands at [`Decoded`]'s 63-bit alignment) and up past
    /// 2·1023 + 32 carry-guard bits — 4416 bits of storage. This is the
    /// f64 analogue of [`Quire::paper_800`] for the 64-bit vector
    /// kernels: software-sized rather than architectural, since f64's
    /// 2^±1022 range has no posit-style pinning.
    pub fn exact_f64() -> Quire {
        Quire::new(4416, -2274)
    }

    pub fn width(&self) -> u32 {
        self.limbs.len() as u32 * 64
    }

    pub fn is_nar(&self) -> bool {
        self.nar
    }

    pub fn clear(&mut self) {
        self.limbs.iter_mut().for_each(|l| *l = 0);
        self.sticky = false;
        self.nar = false;
    }

    pub fn is_zero(&self) -> bool {
        !self.nar && !self.sticky && self.limbs.iter().all(|&l| l == 0)
    }

    fn is_negative(&self) -> bool {
        *self.limbs.last().unwrap() >> 63 == 1
    }

    /// Add `mag · 2^weight` (mag ≤ 128 bits) with the given sign into the
    /// accumulator.
    fn add_mag(&mut self, mag: u128, weight: i32, negative: bool) {
        if mag == 0 {
            return;
        }
        let mut mag = mag;
        let mut weight = weight;
        let rel = weight - self.lsb_exp;
        if rel < 0 {
            let drop = (-rel) as u32;
            if drop >= 128 {
                self.sticky = true;
                return;
            }
            if mag & ((1u128 << drop) - 1) != 0 {
                self.sticky = true;
            }
            mag >>= drop;
            weight += drop as i32;
            if mag == 0 {
                return;
            }
        }
        let bit_off = (weight - self.lsb_exp) as u32;
        let limb_off = (bit_off / 64) as usize;
        let shift = bit_off % 64;
        // Spread the (≤128-bit) magnitude over up to 3 limbs.
        let lo = (mag as u64).wrapping_shl(shift);
        let mid = if shift == 0 {
            (mag >> 64) as u64
        } else {
            ((mag >> (64 - shift)) & u64::MAX as u128) as u64
        };
        let hi = if shift == 0 { 0u64 } else { (mag >> (128 - shift)) as u64 };
        let add = [lo, mid, hi];
        if negative {
            // Two's-complement subtract: add !x + borrow chain ≡ subtract.
            let mut borrow = 0u64;
            for (i, &a) in add.iter().enumerate() {
                let idx = limb_off + i;
                if idx >= self.limbs.len() {
                    if a != 0 || borrow != 0 {
                        self.nar = true; // magnitude exceeded quire range
                    }
                    continue;
                }
                let (v1, b1) = self.limbs[idx].overflowing_sub(a);
                let (v2, b2) = v1.overflowing_sub(borrow);
                self.limbs[idx] = v2;
                borrow = (b1 || b2) as u64;
            }
            if borrow == 1 {
                for idx in (limb_off + add.len()).min(self.limbs.len())..self.limbs.len() {
                    let (v, b) = self.limbs[idx].overflowing_sub(1);
                    self.limbs[idx] = v;
                    if !b {
                        borrow = 0;
                        break;
                    }
                }
                // A borrow off the top is fine: that's two's-complement wrap
                // into negative territory (the sign bit is the carry guard).
            }
        } else {
            let mut carry = 0u64;
            for (i, &a) in add.iter().enumerate() {
                let idx = limb_off + i;
                if idx >= self.limbs.len() {
                    if a != 0 || carry != 0 {
                        self.nar = true;
                    }
                    continue;
                }
                let (v1, c1) = self.limbs[idx].overflowing_add(a);
                let (v2, c2) = v1.overflowing_add(carry);
                self.limbs[idx] = v2;
                carry = (c1 || c2) as u64;
            }
            if carry == 1 {
                for idx in (limb_off + add.len()).min(self.limbs.len())..self.limbs.len() {
                    let (v, c) = self.limbs[idx].overflowing_add(1);
                    self.limbs[idx] = v;
                    if !c {
                        carry = 0;
                        break;
                    }
                }
            }
        }
    }

    /// Accumulate a single decoded value (sign included).
    pub fn add(&mut self, d: &Decoded) {
        match d.class {
            Class::Zero => {}
            Class::Nan | Class::Inf => self.nar = true,
            Class::Normal => {
                self.sticky |= d.sticky;
                self.add_mag(d.sig as u128, d.exp - 63, d.sign);
            }
        }
    }

    /// Accumulate the exact product a·b (fused multiply-accumulate).
    pub fn add_product(&mut self, a: &Decoded, b: &Decoded) {
        if a.is_nan() || b.is_nan() || a.is_inf() || b.is_inf() {
            self.nar = true;
            return;
        }
        if a.is_zero() || b.is_zero() {
            return;
        }
        self.sticky |= a.sticky || b.sticky;
        let prod = a.sig as u128 * b.sig as u128; // exact, ≤ 128 bits
        self.add_mag(prod, a.exp + b.exp - 126, a.sign ^ b.sign);
    }

    /// Subtract the exact product a·b.
    pub fn sub_product(&mut self, a: &Decoded, b: &Decoded) {
        let neg = Decoded { sign: !a.sign, ..*a };
        self.add_product(&neg, b);
    }

    /// Read the accumulator out as a decoded value (faithful: a sticky bit
    /// collected from sub-LSB truncation is propagated for final rounding).
    pub fn to_decoded(&self) -> Decoded {
        if self.nar {
            return Decoded::NAN;
        }
        let negative = self.is_negative();
        let mut mag = self.limbs.clone();
        if negative {
            // two's complement negate
            let mut carry = 1u64;
            for l in mag.iter_mut() {
                let (v, c) = (!*l).overflowing_add(carry);
                *l = v;
                carry = c as u64;
            }
        }
        // Find most significant set bit.
        let mut top = None;
        for (i, &l) in mag.iter().enumerate().rev() {
            if l != 0 {
                top = Some(i * 64 + 63 - l.leading_zeros() as usize);
                break;
            }
        }
        let Some(msb) = top else {
            return if self.sticky {
                // Value was entirely below the quire LSB: round to minimal
                // representation — report as sticky-tiny normal.
                Decoded {
                    class: Class::Normal,
                    sign: negative,
                    exp: self.lsb_exp - 1,
                    sig: 1u64 << 63,
                    sticky: true,
                }
            } else {
                Decoded::ZERO
            };
        };
        // Extract 64 bits from msb downwards.
        let mut sig = 0u64;
        let mut sticky = self.sticky;
        let lo_bit = msb as i64 - 63;
        for k in 0..64u32 {
            let pos = lo_bit + k as i64;
            if pos >= 0 {
                let bit = (mag[(pos / 64) as usize] >> (pos % 64)) & 1;
                sig |= bit << k;
            }
        }
        // Bits below lo_bit → sticky.
        if lo_bit > 0 {
            for pos in 0..lo_bit {
                if (mag[(pos / 64) as usize] >> (pos % 64)) & 1 == 1 {
                    sticky = true;
                    break;
                }
            }
        }
        Decoded {
            class: Class::Normal,
            sign: negative,
            exp: self.lsb_exp + msb as i32,
            sig,
            sticky,
        }
    }

    /// Round out to a posit pattern in the given spec.
    pub fn to_posit(&self, spec: &PositSpec) -> u64 {
        spec.encode(&self.to_decoded())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{BP32, P16, P32};

    fn dec(x: f64) -> Decoded {
        Decoded::from_f64(x)
    }

    #[test]
    fn paper_sizing_is_800_bits() {
        assert_eq!(Quire::paper_800(&BP32).width(), 832); // 800 rounded to limbs
        // architectural positions: 31 carry + 769 value = 800 ≤ 832 storage
        let q = Quire::paper_800(&BP32);
        assert_eq!(q.lsb_exp, -384);
    }

    #[test]
    fn simple_sum() {
        let mut q = Quire::exact_for(&BP32);
        q.add(&dec(1.5));
        q.add(&dec(2.25));
        q.add(&dec(-0.75));
        assert_eq!(q.to_decoded().to_f64(), 3.0);
    }

    #[test]
    fn product_accumulation_exact() {
        let mut q = Quire::exact_for(&BP32);
        q.add_product(&dec(3.0), &dec(4.0));
        q.add_product(&dec(0.5), &dec(0.25));
        assert_eq!(q.to_decoded().to_f64(), 12.125);
    }

    #[test]
    fn perfect_cancellation() {
        let mut q = Quire::exact_for(&BP32);
        let a = dec(1.234567891234e10);
        let b = dec(9.87654321e-8);
        q.add_product(&a, &b);
        q.sub_product(&a, &b);
        assert!(q.is_zero());
        assert_eq!(q.to_decoded().to_f64(), 0.0);
    }

    #[test]
    fn big_small_big_recovers_small() {
        // The classic quire win: (2^100 + 1) - 2^100 = 1 exactly.
        let mut q = Quire::exact_for(&BP32);
        q.add(&dec(f64::powi(2.0, 100)));
        q.add(&dec(1.0));
        q.add(&dec(-f64::powi(2.0, 100)));
        assert_eq!(q.to_decoded().to_f64(), 1.0);
    }

    #[test]
    fn nar_propagates() {
        let mut q = Quire::exact_for(&BP32);
        q.add(&Decoded::NAN);
        q.add(&dec(5.0));
        assert!(q.is_nar());
        assert_eq!(q.to_posit(&BP32), BP32.nar());
    }

    #[test]
    fn negative_sum() {
        let mut q = Quire::exact_for(&P32);
        q.add(&dec(-10.5));
        q.add(&dec(4.25));
        assert_eq!(q.to_decoded().to_f64(), -6.25);
    }

    #[test]
    fn fused_dot_product_beats_naive_p16() {
        // Σ aᵢ·bᵢ where intermediate rounding in p16 loses bits but the
        // quire keeps everything.
        let a = [256.0, 1.0 / 256.0, -256.0];
        let b = [256.0, 1.0, 256.0];
        // exact: 65536 + 1/256 - 65536 = 1/256
        let mut q = Quire::exact_for(&P16);
        let mut naive = P16.from_f64(0.0);
        for i in 0..3 {
            let (da, db) = (dec(a[i]), dec(b[i]));
            q.add_product(&da, &db);
            // naive: round the product and the sum at each step
            let prod = P16.from_f64(a[i] * b[i]);
            let sum = P16.to_f64(naive) + P16.to_f64(prod);
            naive = P16.from_f64(sum);
        }
        let fused = q.to_posit(&P16);
        assert_eq!(P16.to_f64(fused), 1.0 / 256.0);
        // naive path loses the small term entirely (65536 + 1/256 → 65536)
        assert_ne!(P16.to_f64(naive), 1.0 / 256.0);
    }

    #[test]
    fn paper_800_faithful_with_sub_lsb_products() {
        // b-posit minpos² has bits below 2^-384; the 800-bit quire tracks
        // them as sticky and still reports a faithful nonzero result.
        let minpos = BP32.decode(1);
        let mut q = Quire::paper_800(&BP32);
        q.add_product(&minpos, &minpos);
        let d = q.to_decoded();
        assert!(!d.is_zero());
        let expect = BP32.to_f64(1);
        // value ≈ minpos² = 2^-384·(1+2^-20)²; exp of result ≈ -384
        assert_eq!(d.exp, -384);
        let _ = expect;
    }

    #[test]
    fn exact_f64_covers_the_full_double_range() {
        let mut q = Quire::exact_f64();
        // Largest-magnitude products: no overflow, exact readout.
        let big = dec(f64::MAX);
        q.add_product(&big, &big);
        assert!(!q.is_nar());
        q.sub_product(&big, &big);
        assert!(q.is_zero());
        // Smallest-magnitude products: min-subnormal² accumulates exactly
        // (no sticky), and cancels exactly.
        let tiny = dec(f64::from_bits(1)); // 2^-1074
        q.add_product(&tiny, &tiny);
        let d = q.to_decoded();
        assert!(!d.is_zero() && !d.sticky);
        assert_eq!(d.exp, -2148);
        q.sub_product(&tiny, &tiny);
        assert!(q.is_zero());
        // Mixed extreme scales in one accumulation: the classic quire win
        // at f64 scale.
        q.clear();
        q.add_product(&dec(f64::powi(2.0, 1000)), &dec(f64::powi(2.0, 20)));
        q.add_product(&tiny, &tiny);
        q.add_product(&dec(-f64::powi(2.0, 1000)), &dec(f64::powi(2.0, 20)));
        let d = q.to_decoded();
        assert_eq!(d.exp, -2148, "tiny term recovered after 2^1020 cancellation");
    }

    #[test]
    fn sticky_only_value_reports_tiny() {
        let mut q = Quire::new(128, 0);
        q.add(&dec(0.25)); // entirely below LSB weight 2^0
        let d = q.to_decoded();
        assert!(d.sticky);
        assert!(!d.is_zero());
    }

    #[test]
    fn overflow_past_guard_is_nar() {
        let mut q = Quire::new(128, 0);
        // 2^200 exceeds the 128-bit window
        q.add(&Decoded::normal(false, 200, 1u64 << 63));
        assert!(q.is_nar());
    }

    #[test]
    fn many_accumulations_carry_guard() {
        // 2^20 × maxterm accumulations must not overflow exact quire.
        let mut q = Quire::exact_for(&P16);
        let x = dec(1000.0);
        for _ in 0..1_000_000 {
            q.add(&x);
        }
        assert_eq!(q.to_decoded().to_f64(), 1e9);
    }
}
