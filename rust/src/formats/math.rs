//! Format-independent arithmetic on the unpacked [`Decoded`] representation.
//!
//! This is the "Arithmetic" middle stage of the decode → compute → encode
//! pipeline common to floats, posits, and b-posits (paper §2). Operations
//! are computed exactly into a 64-bit significand plus a sticky flag, which
//! is sufficient for correct final rounding by any of the codecs here (all
//! keep ≤ 61 fraction bits, so the guard/round positions always land inside
//! the 64-bit significand and everything below collapses into sticky).
//!
//! Exception semantics are the *caller's* format rules: these functions use
//! IEEE-style classes (Inf/NaN distinct); posit encoders collapse both to
//! NaR. Division by zero yields Inf (→ NaR in posit-land), 0/0 and Inf−Inf
//! yield NaN, sqrt of a negative yields NaN.

use super::decoded::{Class, Decoded};

/// Exact-significand addition (a + b).
pub fn add(a: &Decoded, b: &Decoded) -> Decoded {
    match (a.class, b.class) {
        (Class::Nan, _) | (_, Class::Nan) => Decoded::NAN,
        (Class::Inf, Class::Inf) => {
            if a.sign == b.sign { *a } else { Decoded::NAN }
        }
        (Class::Inf, _) => *a,
        (_, Class::Inf) => *b,
        (Class::Zero, Class::Zero) => Decoded::zero(a.sign && b.sign),
        (Class::Zero, _) => *b,
        (_, Class::Zero) => *a,
        (Class::Normal, Class::Normal) => add_normal(a, b),
    }
}

/// a − b.
pub fn sub(a: &Decoded, b: &Decoded) -> Decoded {
    let nb = match b.class {
        Class::Zero => Decoded::zero(!b.sign),
        _ => Decoded { sign: !b.sign, ..*b },
    };
    add(a, &nb)
}

fn add_normal(a: &Decoded, b: &Decoded) -> Decoded {
    // Order so |x| ≥ |y|.
    let (x, y) = if a.exp > b.exp || (a.exp == b.exp && a.sig >= b.sig) { (a, b) } else { (b, a) };
    let diff = (x.exp - y.exp) as u32;
    // Work in 128-bit with the big operand at bits [126:63].
    let xs = (x.sig as u128) << 63;
    let (ys, mut sticky) = if diff == 0 {
        ((y.sig as u128) << 63, false)
    } else if diff < 64 {
        let kept = (y.sig as u128) << (63 - diff.min(63));
        (kept, false) // diff < 64 keeps everything (63+64-diff ≥ 64 bits of room)
    } else if diff < 127 {
        let sh = diff - 63; // shift right below the 63-bit guard zone
        let kept = (y.sig as u128) >> sh;
        let lost = y.sig & ((1u64 << sh.min(63)) - 1) != 0;
        (kept, lost)
    } else {
        (0u128, true)
    };
    sticky |= x.sticky || y.sticky;
    let same_sign = x.sign == y.sign;
    let mut acc: u128;
    if same_sign {
        acc = xs + ys;
    } else {
        // |x| ≥ |y| so no underflow. If bits of y were dropped (shift loss
        // or y's own sticky), the true |y| is slightly larger than `ys`, so
        // the true difference lies just BELOW xs−ys: bias down one unit and
        // let sticky mark the half-open gap (faithful). When x itself is
        // sticky too the direction is ambiguous — a one-ulp faithfulness
        // slip we accept for chained inexact operands (codec outputs are
        // always exact, so this never affects single operations).
        acc = xs - ys;
        if sticky && !x.sticky {
            if acc == 0 {
                // Kept bits cancelled exactly and only dust remains on y's
                // side: the true result is a tiny value with y's sign.
                return Decoded {
                    class: Class::Normal,
                    sign: y.sign,
                    exp: x.exp - 127,
                    sig: 1u64 << 63,
                    sticky: true,
                };
            }
            acc -= 1;
        }
        if acc == 0 {
            return if sticky {
                // Cancellation down to the sticky dust: faithful tiny value.
                Decoded {
                    class: Class::Normal,
                    sign: x.sign,
                    exp: x.exp - 126,
                    sig: 1u64 << 63,
                    sticky: true,
                }
            } else {
                Decoded::ZERO
            };
        }
    }
    // Normalize: MSB of acc to position 126 (value weight 2^exp).
    let msb = 127 - acc.leading_zeros() as i32;
    let exp = x.exp + (msb - 126);
    let sig;
    if msb >= 63 {
        let drop = (msb - 63) as u32;
        sig = (acc >> drop) as u64;
        if drop > 0 && acc & ((1u128 << drop) - 1) != 0 {
            sticky = true;
        }
    } else {
        sig = (acc as u64) << (63 - msb);
    }
    Decoded { class: Class::Normal, sign: x.sign, exp, sig, sticky }
}

/// a × b.
pub fn mul(a: &Decoded, b: &Decoded) -> Decoded {
    let sign = a.sign ^ b.sign;
    match (a.class, b.class) {
        (Class::Nan, _) | (_, Class::Nan) => Decoded::NAN,
        (Class::Inf, Class::Zero) | (Class::Zero, Class::Inf) => Decoded::NAN,
        (Class::Inf, _) | (_, Class::Inf) => Decoded::inf(sign),
        (Class::Zero, _) | (_, Class::Zero) => Decoded::zero(sign),
        (Class::Normal, Class::Normal) => {
            let prod = a.sig as u128 * b.sig as u128; // ∈ [2^126, 2^128)
            let msb = 127 - prod.leading_zeros() as i32; // 126 or 127
            let drop = (msb - 63) as u32;
            let sig = (prod >> drop) as u64;
            let sticky = prod & ((1u128 << drop) - 1) != 0 || a.sticky || b.sticky;
            Decoded { class: Class::Normal, sign, exp: a.exp + b.exp + (msb - 126), sig, sticky }
        }
    }
}

/// a ÷ b.
pub fn div(a: &Decoded, b: &Decoded) -> Decoded {
    let sign = a.sign ^ b.sign;
    match (a.class, b.class) {
        (Class::Nan, _) | (_, Class::Nan) => Decoded::NAN,
        (Class::Inf, Class::Inf) => Decoded::NAN,
        (Class::Inf, _) => Decoded::inf(sign),
        (_, Class::Inf) => Decoded::zero(sign),
        (Class::Zero, Class::Zero) => Decoded::NAN,
        (Class::Zero, _) => Decoded::zero(sign),
        (_, Class::Zero) => Decoded::inf(sign), // x/0 → Inf (posit: NaR)
        (Class::Normal, Class::Normal) => {
            // q = (a.sig << 63) / b.sig ∈ (2^62, 2^64)
            let num = (a.sig as u128) << 63;
            let den = b.sig as u128;
            let q = num / den;
            let r = num % den;
            let msb = 127 - q.leading_zeros() as i32; // 62 or 63
            let (sig, extra_sticky) = if msb == 63 {
                (q as u64, false)
            } else {
                // Shift up one and refine with one more quotient bit.
                let num2 = r << 1;
                let bit = (num2 >= den) as u64;
                let r2 = num2 - if bit == 1 { den } else { 0 };
                (((q as u64) << 1) | bit, r2 != 0)
            };
            let sticky = (msb == 63 && r != 0) || extra_sticky || a.sticky || b.sticky;
            Decoded { class: Class::Normal, sign, exp: a.exp - b.exp + (msb - 63), sig, sticky }
        }
    }
}

/// √a.
pub fn sqrt(a: &Decoded) -> Decoded {
    match a.class {
        Class::Nan => Decoded::NAN,
        Class::Zero => *a,
        Class::Inf => {
            if a.sign { Decoded::NAN } else { *a }
        }
        Class::Normal => {
            if a.sign {
                return Decoded::NAN;
            }
            // value = sig·2^E with E = exp−63. Rewrite as X·4^k with
            // X ∈ [2^126, 2^128) so that s = isqrt(X) ∈ [2^63, 2^64) is a
            // normalized significand and sqrt(value) = s·2^k.
            let e = a.exp - 63;
            let (x, k) = if e % 2 == 0 {
                ((a.sig as u128) << 64, (e - 64) / 2) // E even: X ∈ [2^127, 2^128)
            } else {
                ((a.sig as u128) << 63, (e - 63) / 2) // E odd: X ∈ [2^126, 2^127)
            };
            let s = isqrt128(x);
            let rem = x - s * s;
            Decoded {
                class: Class::Normal,
                sign: false,
                exp: 63 + k,
                sig: s as u64,
                sticky: rem != 0 || a.sticky,
            }
        }
    }
}

/// Integer square root of a u128 (Newton's method with careful init).
fn isqrt128(x: u128) -> u128 {
    if x == 0 {
        return 0;
    }
    // Initial over-estimate (≥ √x, ≤ 2^64−1 so squaring never overflows).
    let bits = 128 - x.leading_zeros();
    let mut g: u128 = (1u128 << (bits / 2 + 1)).min((1u128 << 64) - 1);
    loop {
        let next = (g + x / g) >> 1;
        if next >= g {
            break;
        }
        g = next;
    }
    // g = floor(sqrt(x)) or close; correct downwards/upwards.
    while g * g > x {
        g -= 1;
    }
    while (g + 1).checked_mul(g + 1).map(|sq| sq <= x).unwrap_or(false) {
        g += 1;
    }
    g
}

/// Fused multiply-add: a·b + c computed with a single rounding (the 128-bit
/// product is added exactly before normalization).
pub fn fma(a: &Decoded, b: &Decoded, c: &Decoded) -> Decoded {
    let p = mul(a, b);
    if !p.is_normal() || !c.is_normal() {
        return add(&p, c);
    }
    if p.sticky {
        // mul dropped bits only when the product didn't fit 64 bits; redo
        // exactly: represent the product on 128 bits split into hi/lo
        // Decoded parts and add both.
        let prod = a.sig as u128 * b.sig as u128;
        let msb = 127 - prod.leading_zeros() as i32;
        let e = a.exp + b.exp + (msb - 126);
        let hi_sig = (prod >> (msb - 63)) as u64;
        let lo_bits = prod & ((1u128 << (msb - 63)) - 1);
        let hi = Decoded { class: Class::Normal, sign: p.sign, exp: e, sig: hi_sig, sticky: false };
        let step1 = add(&hi, c);
        if lo_bits == 0 {
            return step1;
        }
        // lo value = lo_bits · 2^(e−msb): bit i of the product has weight
        // 2^(e−msb+i), so lo's MSB (at position lo_msb) has weight e−msb+lo_msb.
        let lo_msb = 127 - lo_bits.leading_zeros() as i32;
        let lo_exp2 = (e - msb) + lo_msb;
        let lo_sig = if lo_msb >= 63 {
            (lo_bits >> (lo_msb - 63)) as u64
        } else {
            (lo_bits as u64) << (63 - lo_msb)
        };
        let lo_sticky = lo_msb > 63 && lo_bits & ((1u128 << (lo_msb - 63)) - 1) != 0;
        let lo = Decoded {
            class: Class::Normal,
            sign: p.sign,
            exp: lo_exp2,
            sig: lo_sig,
            sticky: lo_sticky,
        };
        add(&step1, &lo)
    } else {
        add(&p, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(x: f64) -> Decoded {
        Decoded::from_f64(x)
    }

    #[test]
    fn add_exact_cases() {
        assert_eq!(add(&d(1.5), &d(2.25)).to_f64(), 3.75);
        assert_eq!(add(&d(-1.5), &d(1.5)).to_f64(), 0.0);
        assert_eq!(add(&d(1e300), &d(-1e300)).to_f64(), 0.0);
        assert_eq!(add(&d(0.0), &d(-7.0)).to_f64(), -7.0);
    }

    #[test]
    fn add_matches_f64_randomized() {
        // f64 ops with ≤ 52-bit inputs that stay exact in 64-bit sig space.
        let mut x = 0x853c49e6748fea9bu64;
        for _ in 0..100_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let a = ((x >> 20) as i32 as f64) * 0.001953125; // scaled ints: exact
            let b = ((x & 0xffff_ffff) as i32 as f64) * 32.0;
            let r = add(&d(a), &d(b));
            assert_eq!(r.to_f64(), a + b, "add mismatch {a} + {b}");
            assert!(!r.sticky);
        }
    }

    #[test]
    fn sub_cancellation() {
        let a = d(1.0000000000000002); // 1 + 2^-52
        let b = d(1.0);
        let r = sub(&a, &b);
        assert_eq!(r.to_f64(), f64::powi(2.0, -52));
        assert!(!r.sticky);
    }

    #[test]
    fn mul_matches_f64() {
        let mut x = 0xda3e39cb94b95bdbu64;
        for _ in 0..100_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            // 26-bit operands: product exact in f64
            let a = ((x >> 38) as f64) + 1.0;
            let b = (((x >> 12) & 0x3ff_ffff) as f64) + 1.0;
            let r = mul(&d(a), &d(b));
            assert_eq!(r.to_f64(), a * b, "mul mismatch {a} * {b}");
        }
    }

    #[test]
    fn mul_signs_and_specials() {
        assert_eq!(mul(&d(-2.0), &d(3.0)).to_f64(), -6.0);
        assert!(mul(&d(f64::INFINITY), &d(0.0)).is_nan());
        assert_eq!(mul(&d(f64::INFINITY), &d(-2.0)).to_f64(), f64::NEG_INFINITY);
        assert!(mul(&d(f64::NAN), &d(1.0)).is_nan());
    }

    #[test]
    fn div_exact_and_inexact() {
        assert_eq!(div(&d(1.0), &d(4.0)).to_f64(), 0.25);
        assert_eq!(div(&d(-12.0), &d(3.0)).to_f64(), -4.0);
        let third = div(&d(1.0), &d(3.0));
        assert!(third.sticky);
        assert!((third.to_f64() - 1.0 / 3.0).abs() < 1e-16);
        assert!(div(&d(1.0), &d(0.0)).is_inf());
        assert!(div(&d(0.0), &d(0.0)).is_nan());
        assert!(div(&d(f64::INFINITY), &d(f64::INFINITY)).is_nan());
    }

    #[test]
    fn div_matches_f64_when_exact() {
        let mut x = 0xf1ea5eed12345678u64;
        for _ in 0..50_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let a = ((x >> 32) & 0xffff) as f64 + 1.0;
            let b = f64::powi(2.0, ((x & 7) as i32) - 3); // power of two: exact division
            let r = div(&d(a), &d(b));
            assert_eq!(r.to_f64(), a / b);
            assert!(!r.sticky);
        }
    }

    #[test]
    fn sqrt_exact_squares() {
        for k in 1..2000u64 {
            let x = (k * k) as f64;
            let r = sqrt(&d(x));
            assert_eq!(r.to_f64(), k as f64, "sqrt({x})");
            assert!(!r.sticky, "sqrt of perfect square must be exact");
        }
    }

    #[test]
    fn sqrt_matches_f64() {
        let mut x = 0xabcdef9876543210u64;
        for _ in 0..20_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let a = f64::from_bits((x & 0x7fef_ffff_ffff_ffff).max(1));
            if !a.is_finite() || a == 0.0 {
                continue;
            }
            let r = sqrt(&d(a)).to_f64();
            let expect = a.sqrt();
            // faithful: within 1 ulp (our to_f64 rounds the 64-bit sig)
            let ulp = (expect.to_bits() as i64 - r.to_bits() as i64).abs();
            assert!(ulp <= 1, "sqrt({a}): got {r}, want {expect}");
        }
    }

    #[test]
    fn sqrt_specials() {
        assert!(sqrt(&d(-1.0)).is_nan());
        assert!(sqrt(&d(f64::NAN)).is_nan());
        assert_eq!(sqrt(&d(0.0)).to_f64(), 0.0);
        assert_eq!(sqrt(&d(f64::INFINITY)).to_f64(), f64::INFINITY);
        assert!(sqrt(&d(f64::NEG_INFINITY)).is_nan());
    }

    #[test]
    fn fma_single_rounding() {
        // fma(x, y, -x·y_rounded) exposes the double-rounding difference.
        let a = d(1.0 + f64::powi(2.0, -30));
        let b = d(1.0 + f64::powi(2.0, -31));
        let exact_f64 = f64::mul_add(1.0 + f64::powi(2.0, -30), 1.0 + f64::powi(2.0, -31), -1.0);
        let r = fma(&a, &b, &d(-1.0));
        assert_eq!(r.to_f64(), exact_f64);
    }

    #[test]
    fn fma_specials() {
        assert!(fma(&d(f64::INFINITY), &d(0.0), &d(1.0)).is_nan());
        assert_eq!(fma(&d(2.0), &d(3.0), &d(4.0)).to_f64(), 10.0);
    }

    #[test]
    fn isqrt_boundaries() {
        assert_eq!(isqrt128(0), 0);
        assert_eq!(isqrt128(1), 1);
        assert_eq!(isqrt128(3), 1);
        assert_eq!(isqrt128(4), 2);
        assert_eq!(isqrt128(u128::MAX), (1u128 << 64) - 1);
        let big = (1u128 << 100) - 1;
        let s = isqrt128(big);
        assert!(s * s <= big && (s + 1) * (s + 1) > big);
    }

    #[test]
    fn add_sticky_faithfulness() {
        // big + tiny: tiny collapses to sticky; result strictly between
        // big and big+ulp.
        let big = d(f64::powi(2.0, 80));
        let tiny = d(1.0);
        let r = add(&big, &tiny);
        assert!(r.sticky);
        assert_eq!(r.exp, 80);
        assert_eq!(r.sig, 1u64 << 63);
        // And subtracting the dust: big - tiny < big.
        let r2 = sub(&big, &tiny);
        assert!(r2.sticky);
        // sig should be all-ones-ish: 2^80 - 1 ≈ 1.111…·2^79
        assert_eq!(r2.exp, 79);
        assert_eq!(r2.sig, u64::MAX);
    }
}
