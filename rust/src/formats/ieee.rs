//! Parameterized IEEE-754 binary float codec ⟨n, eb⟩ with full subnormal,
//! NaN, and infinity support — the software analogue of Berkeley HardFloat's
//! decode (recode) and encode stages that the paper benchmarks against
//! (Figs 8/9).
//!
//! Decode normalizes subnormals (the leading-zero count + left shift that
//! costs hardware its LZC), producing the same [`Decoded`] unpacked form the
//! posit codecs use; encode denormalizes (right shift), applies RNE, and
//! handles overflow→Inf / underflow→0.

use super::decoded::{Class, Decoded};

/// Static description of an IEEE-754-style binary format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IeeeSpec {
    /// Total width in bits, ≤ 64.
    pub n: u32,
    /// Exponent field width in bits.
    pub eb: u32,
}

/// IEEE binary16 (half).
pub const F16: IeeeSpec = IeeeSpec { n: 16, eb: 5 };
/// Google bfloat16 (the paper's §1.4 bounded-range comparator).
pub const BF16: IeeeSpec = IeeeSpec { n: 16, eb: 8 };
/// IEEE binary32 (single).
pub const F32: IeeeSpec = IeeeSpec { n: 32, eb: 8 };
/// IEEE binary64 (double).
pub const F64: IeeeSpec = IeeeSpec { n: 64, eb: 11 };

impl IeeeSpec {
    pub fn new(n: u32, eb: u32) -> IeeeSpec {
        assert!(n <= 64 && eb >= 2 && eb <= 16 && eb + 2 <= n);
        IeeeSpec { n, eb }
    }

    /// Fraction field width.
    #[inline]
    pub fn fb(&self) -> u32 {
        self.n - 1 - self.eb
    }

    /// Exponent bias.
    #[inline]
    pub fn bias(&self) -> i32 {
        (1 << (self.eb - 1)) - 1
    }

    /// Maximum unbiased exponent of a normal value.
    pub fn max_exp(&self) -> i32 {
        (1 << (self.eb - 1)) - 1 // all-ones minus one, unbiased
    }

    /// Minimum unbiased exponent of a normal value.
    pub fn min_exp(&self) -> i32 {
        1 - self.bias()
    }

    /// Minimum unbiased exponent reachable by subnormals.
    pub fn min_exp_subnormal(&self) -> i32 {
        self.min_exp() - self.fb() as i32
    }

    #[inline]
    pub fn mask(&self) -> u64 {
        if self.n == 64 { u64::MAX } else { (1u64 << self.n) - 1 }
    }

    /// Canonical quiet NaN pattern.
    pub fn qnan(&self) -> u64 {
        let exp_all = ((1u64 << self.eb) - 1) << self.fb();
        exp_all | (1u64 << (self.fb() - 1))
    }

    /// Infinity pattern with sign.
    pub fn inf_bits(&self, sign: bool) -> u64 {
        let v = ((1u64 << self.eb) - 1) << self.fb();
        if sign { v | (1u64 << (self.n - 1)) } else { v }
    }

    /// Number of explicit significand bits at unbiased exponent `e` (for the
    /// accuracy analysis: tapering on the subnormal side, Fig 7 green curve).
    pub fn frac_bits_at(&self, e: i32) -> u32 {
        if e >= self.min_exp() {
            self.fb()
        } else {
            // Subnormal: each step below min_exp loses one significand bit.
            let lost = (self.min_exp() - e) as u32;
            self.fb().saturating_sub(lost)
        }
    }

    // ------------------------------------------------------------------
    // Decode (HardFloat recode stage)
    // ------------------------------------------------------------------

    /// Unpack an IEEE pattern; subnormals are normalized (CLZ + left shift).
    pub fn decode(&self, bits: u64) -> Decoded {
        let bits = bits & self.mask();
        let sign = (bits >> (self.n - 1)) & 1 == 1;
        let fb = self.fb();
        let biased = ((bits >> fb) & ((1u64 << self.eb) - 1)) as i32;
        let frac = bits & ((1u64 << fb) - 1);
        let exp_all = (1i32 << self.eb) - 1;
        if biased == exp_all {
            return if frac == 0 { Decoded::inf(sign) } else { Decoded::NAN };
        }
        if biased == 0 {
            if frac == 0 {
                return Decoded::zero(sign);
            }
            // Subnormal: normalize (the hardware's CLZ + left shift).
            // frac's leading 1 sits at bit fb-1-lz; move it to bit 63.
            let lz = frac.leading_zeros() - (64 - fb);
            let exp = self.min_exp() - 1 - lz as i32;
            let sig = frac << (64 - fb + lz);
            return Decoded::normal(sign, exp, sig);
        }
        let exp = biased - self.bias();
        let sig = (1u64 << 63) | (frac << (63 - fb));
        Decoded::normal(sign, exp, sig)
    }

    // ------------------------------------------------------------------
    // Encode (HardFloat back-conversion, Fig 9)
    // ------------------------------------------------------------------

    /// Pack an internal value into an IEEE pattern with RNE, subnormal
    /// denormalization, overflow→±Inf and total-underflow→±0.
    pub fn encode(&self, d: &Decoded) -> u64 {
        let sign_bit = if d.sign { 1u64 << (self.n - 1) } else { 0 };
        match d.class {
            Class::Zero => sign_bit,
            Class::Nan => self.qnan(),
            Class::Inf => self.inf_bits(d.sign),
            Class::Normal => {
                let fb = self.fb();
                let deficit = if d.exp >= self.min_exp() {
                    0u32
                } else {
                    (self.min_exp() - d.exp) as u32
                };
                if deficit > fb + 1 {
                    // Strictly below half of the smallest subnormal → ±0.
                    return sign_bit;
                }
                if deficit == fb + 1 {
                    // Value in [half·minsub, minsub): tie at exactly half
                    // rounds to even (zero); anything above rounds to 1 ulp.
                    let tie = d.sig == 1u64 << 63 && !d.sticky;
                    return if tie { sign_bit } else { sign_bit | 1 };
                }
                if deficit > 0 {
                    // Subnormal: denormalize (right shift by `deficit`) and
                    // round. A carry to 2^keep is either a larger subnormal
                    // or exactly the smallest normal (1 << fb) — in both
                    // cases the raw field value is the correct pattern body.
                    let keep = fb + 1 - deficit;
                    let (r, carry) = super::round::rne64(d.sig, keep, d.sticky);
                    let field = if carry { 1u64 << keep } else { r };
                    return sign_bit | field;
                }
                // Normal range.
                let (rounded, carry) = super::round::rne64(d.sig, fb + 1, d.sticky);
                let exp = d.exp + if carry { 1 } else { 0 };
                if exp > self.max_exp() {
                    return self.inf_bits(d.sign);
                }
                let biased = (exp + self.bias()) as u64;
                sign_bit | (biased << fb) | (rounded & ((1u64 << fb) - 1))
            }
        }
    }

    /// Encode an f64 (exact unpack, then IEEE rounding at this width).
    pub fn from_f64(&self, x: f64) -> u64 {
        self.encode(&Decoded::from_f64(x))
    }

    /// Decode to f64 (exact whenever fb ≤ 52, i.e. every format here but f64
    /// itself, which is the identity).
    pub fn to_f64(&self, bits: u64) -> f64 {
        self.decode(bits).to_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_vs_native() {
        // Our ⟨32,8⟩ codec must agree bit-exactly with the hardware f32 path.
        let samples: Vec<f32> = vec![
            0.0, -0.0, 1.0, -1.0, 3.14159265, 1e-38, 1e-39, 1e-45, -1e-44,
            f32::MIN_POSITIVE, f32::MAX, f32::INFINITY, f32::NEG_INFINITY,
            1.5e38, 2.3e-41, 6.6e-34,
        ];
        for x in samples {
            let via = F32.from_f64(x as f64);
            assert_eq!(via, x.to_bits() as u64, "encode mismatch for {x}");
            let back = F32.to_f64(x.to_bits() as u64);
            assert_eq!(back as f32, x, "decode mismatch for {x}");
        }
    }

    #[test]
    fn f32_exhaustive_exponent_boundary_sweep() {
        // All patterns around the subnormal/normal boundary and a PRNG sweep:
        // decode→encode must be the identity for every non-NaN pattern.
        for base in [0u32, 0x0000_0000, 0x007f_fff0, 0x0080_0000, 0x7f7f_fff0] {
            for off in 0..32u32 {
                let bits = base.wrapping_add(off);
                if f32::from_bits(bits).is_nan() {
                    continue;
                }
                let d = F32.decode(bits as u64);
                assert_eq!(F32.encode(&d), bits as u64, "identity failed {bits:#010x}");
            }
        }
        let mut x = 0x243f6a8885a308d3u64;
        for _ in 0..300_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let bits = (x as u32) as u64;
            if f32::from_bits(bits as u32).is_nan() {
                continue;
            }
            assert_eq!(F32.encode(&F32.decode(bits)), bits);
        }
    }

    #[test]
    fn f16_exhaustive_identity() {
        for bits in 0..=u16::MAX as u64 {
            let d = F16.decode(bits);
            if d.is_nan() {
                continue; // NaN payloads canonicalize
            }
            assert_eq!(F16.encode(&d), bits, "f16 identity failed {bits:#06x}");
        }
    }

    #[test]
    fn bf16_exhaustive_identity() {
        for bits in 0..=u16::MAX as u64 {
            let d = BF16.decode(bits);
            if d.is_nan() {
                continue;
            }
            assert_eq!(BF16.encode(&d), bits, "bf16 identity failed {bits:#06x}");
        }
    }

    #[test]
    fn f64_identity_sampled() {
        let mut x = 0x9e3779b97f4a7c15u64;
        for _ in 0..200_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            if f64::from_bits(x).is_nan() {
                continue;
            }
            assert_eq!(F64.encode(&F64.decode(x)), x, "f64 identity failed {x:#x}");
        }
    }

    #[test]
    fn f16_pi() {
        // float16(π) = 0x4248 = 3.140625 (Fig. 1's float16 π).
        let bits = F16.from_f64(std::f64::consts::PI);
        assert_eq!(bits, 0x4248);
        assert_eq!(F16.to_f64(0x4248), 3.140625);
    }

    #[test]
    fn fig1_posit_beats_float_on_pi() {
        // Paper Fig 1: the 16-bit posit π is >100× more accurate than the
        // 16-bit float π... measured as relative error ratio.
        use super::super::posit::P16;
        let pi = std::f64::consts::PI;
        let ferr = (F16.to_f64(F16.from_f64(pi)) - pi).abs();
        let perr = (P16.to_f64(P16.from_f64(pi)) - pi).abs();
        assert!(perr < ferr, "posit should beat float on π");
        // float16 has 10 frac bits at exp 1, posit16 has 11 here, but float16
        // rounds π down coarsely: ratio is large though format-dependent.
        assert!(ferr / perr > 10.0, "ratio {}", ferr / perr);
    }

    #[test]
    fn subnormal_f32_encode_decode() {
        // min subnormal, mid subnormal, max subnormal
        for bits in [1u32, 0x0000_0001, 0x0040_0000, 0x007f_ffff] {
            let x = f32::from_bits(bits);
            let d = F32.decode(bits as u64);
            assert!(d.is_normal());
            assert_eq!(d.to_f64() as f32, x);
            assert_eq!(F32.encode(&d), bits as u64);
        }
    }

    #[test]
    fn subnormal_rounding_from_wider() {
        // A value halfway between 0 and the min f32 subnormal ties to even 0.
        let half_min_sub = f64::powi(2.0, -150);
        assert_eq!(F32.from_f64(half_min_sub), 0);
        // Slightly above rounds to the min subnormal.
        assert_eq!(F32.from_f64(half_min_sub * 1.0001), 1);
        // 1.5× min subnormal ties to even → 2 ulps... (2 is even)
        assert_eq!(F32.from_f64(f64::powi(2.0, -149) * 1.5), 2);
    }

    #[test]
    fn overflow_to_inf() {
        assert_eq!(F16.from_f64(1e10), F16.inf_bits(false));
        assert_eq!(F16.from_f64(-1e10), F16.inf_bits(true));
        assert_eq!(F32.from_f64(1e40), F32.inf_bits(false));
        // f32 boundary: values ≥ 2^128·(1−2^-25) round to Inf
        assert_eq!(F32.from_f64(3.4028236e38), F32.inf_bits(false));
        assert_eq!(F32.from_f64(3.4028234e38) as u32, f32::MAX.to_bits());
    }

    #[test]
    fn frac_bits_taper_on_subnormal_side() {
        assert_eq!(F32.frac_bits_at(0), 23);
        assert_eq!(F32.frac_bits_at(-126), 23);
        assert_eq!(F32.frac_bits_at(-127), 22);
        assert_eq!(F32.frac_bits_at(-149), 0);
        assert_eq!(F32.frac_bits_at(-200), 0);
    }

    #[test]
    fn spec_parameters() {
        assert_eq!(F32.bias(), 127);
        assert_eq!(F32.max_exp(), 127);
        assert_eq!(F32.min_exp(), -126);
        assert_eq!(F32.min_exp_subnormal(), -149);
        assert_eq!(F16.bias(), 15);
        assert_eq!(BF16.fb(), 7);
        assert_eq!(F64.bias(), 1023);
    }
}
