//! Numeric formats: IEEE floats, standard posits, b-posits, takums, and the
//! quire — the complete format zoo the paper compares.
//!
//! Everything decodes into a shared unpacked form ([`decoded::Decoded`]),
//! computes via [`math`], and encodes back — the same three-stage pipeline
//! (decode → arithmetic → encode) whose hardware cost the paper measures.

pub mod decoded;
pub mod round;
pub mod posit;
pub mod ieee;
pub mod takum;
pub mod quire;
pub mod math;
pub mod convert;

pub use decoded::{Class, Decoded};
pub use ieee::IeeeSpec;
pub use posit::PositSpec;
pub use quire::Quire;
pub use takum::TakumSpec;

/// Uniform interface over every codec in the zoo (used by the accuracy
/// analysis, the cross-format converter, and the CLI).
pub trait Codec {
    /// Total width in bits.
    fn n(&self) -> u32;
    /// Human-readable format name (e.g. `posit<32,2>`, `b-posit<32,6,5>`).
    fn name(&self) -> String;
    /// Unpack a bit pattern.
    fn decode(&self, bits: u64) -> Decoded;
    /// Pack a value (with the format's own rounding + saturation rules).
    fn encode(&self, d: &Decoded) -> u64;
    /// Explicit significand (fraction) bits available at binary scale `e`.
    fn frac_bits_at(&self, e: i32) -> u32;
    /// Largest binary scale of a finite value.
    fn max_scale(&self) -> i32;
    /// Smallest binary scale of a nonzero value.
    fn min_scale(&self) -> i32;

    /// Round an f64 through this format (encode then decode).
    fn roundtrip_f64(&self, x: f64) -> f64 {
        self.decode(self.encode(&Decoded::from_f64(x))).to_f64()
    }
}

impl Codec for PositSpec {
    fn n(&self) -> u32 {
        self.n
    }
    fn name(&self) -> String {
        if self.is_bounded() {
            format!("b-posit<{},{},{}>", self.n, self.rs, self.es)
        } else {
            format!("posit<{},{}>", self.n, self.es)
        }
    }
    fn decode(&self, bits: u64) -> Decoded {
        PositSpec::decode(self, bits)
    }
    fn encode(&self, d: &Decoded) -> u64 {
        PositSpec::encode(self, d)
    }
    fn frac_bits_at(&self, e: i32) -> u32 {
        PositSpec::frac_bits_at(self, e)
    }
    fn max_scale(&self) -> i32 {
        self.max_exp()
    }
    fn min_scale(&self) -> i32 {
        self.min_exp()
    }
}

impl Codec for IeeeSpec {
    fn n(&self) -> u32 {
        self.n
    }
    fn name(&self) -> String {
        match (self.n, self.eb) {
            (16, 5) => "float16".into(),
            (16, 8) => "bfloat16".into(),
            (32, 8) => "float32".into(),
            (64, 11) => "float64".into(),
            _ => format!("ieee<{},{}>", self.n, self.eb),
        }
    }
    fn decode(&self, bits: u64) -> Decoded {
        IeeeSpec::decode(self, bits)
    }
    fn encode(&self, d: &Decoded) -> u64 {
        IeeeSpec::encode(self, d)
    }
    fn frac_bits_at(&self, e: i32) -> u32 {
        IeeeSpec::frac_bits_at(self, e)
    }
    fn max_scale(&self) -> i32 {
        self.max_exp()
    }
    fn min_scale(&self) -> i32 {
        self.min_exp_subnormal()
    }
}

impl Codec for TakumSpec {
    fn n(&self) -> u32 {
        self.n
    }
    fn name(&self) -> String {
        format!("takum{}", self.n)
    }
    fn decode(&self, bits: u64) -> Decoded {
        TakumSpec::decode(self, bits)
    }
    fn encode(&self, d: &Decoded) -> u64 {
        TakumSpec::encode(self, d)
    }
    fn frac_bits_at(&self, e: i32) -> u32 {
        TakumSpec::frac_bits_at(self, e)
    }
    fn max_scale(&self) -> i32 {
        self.max_exp()
    }
    fn min_scale(&self) -> i32 {
        self.min_exp()
    }
}

/// Computed format arithmetic: decode both operands, run the shared exact
/// arithmetic, re-encode under the format's rounding rules. These are the
/// software mirrors of a hardware ALU wrapped in decode/encode stages.
pub fn op_add<C: Codec + ?Sized>(c: &C, a: u64, b: u64) -> u64 {
    c.encode(&math::add(&c.decode(a), &c.decode(b)))
}

pub fn op_sub<C: Codec + ?Sized>(c: &C, a: u64, b: u64) -> u64 {
    c.encode(&math::sub(&c.decode(a), &c.decode(b)))
}

pub fn op_mul<C: Codec + ?Sized>(c: &C, a: u64, b: u64) -> u64 {
    c.encode(&math::mul(&c.decode(a), &c.decode(b)))
}

pub fn op_div<C: Codec + ?Sized>(c: &C, a: u64, b: u64) -> u64 {
    c.encode(&math::div(&c.decode(a), &c.decode(b)))
}

pub fn op_sqrt<C: Codec + ?Sized>(c: &C, a: u64) -> u64 {
    c.encode(&math::sqrt(&c.decode(a)))
}

pub fn op_fma<C: Codec + ?Sized>(c: &C, a: u64, b: u64, acc: u64) -> u64 {
    c.encode(&math::fma(&c.decode(a), &c.decode(b), &c.decode(acc)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use posit::{BP32, P16, P32};

    #[test]
    fn names() {
        assert_eq!(P32.name(), "posit<32,2>");
        assert_eq!(BP32.name(), "b-posit<32,6,5>");
        assert_eq!(ieee::F32.name(), "float32");
        assert_eq!(takum::T32.name(), "takum32");
    }

    #[test]
    fn op_add_p16_exhaustive_row() {
        // One full row of the addition table vs f64 reference (p16 values
        // are exact in f64, and p16 results have ≤ 12 significant bits so
        // the f64 sum rounds identically).
        let a_bits = P16.from_f64(1.0);
        for b_bits in 0..=u16::MAX as u64 {
            if b_bits == P16.nar() {
                continue;
            }
            let expect = P16.from_f64(1.0 + P16.to_f64(b_bits));
            let got = op_add(&P16, a_bits, b_bits);
            assert_eq!(got, expect, "1.0 + {b_bits:#06x}");
        }
    }

    #[test]
    fn op_mul_by_nar_is_nar() {
        assert_eq!(op_mul(&P32, P32.nar(), P32.from_f64(2.0)), P32.nar());
        assert_eq!(op_div(&P32, P32.from_f64(1.0), 0), P32.nar()); // 1/0 → NaR
        assert_eq!(op_sqrt(&P32, P32.from_f64(-4.0)), P32.nar());
    }

    #[test]
    fn op_basic_bp32() {
        let two = BP32.from_f64(2.0);
        let three = BP32.from_f64(3.0);
        assert_eq!(BP32.to_f64(op_add(&BP32, two, three)), 5.0);
        assert_eq!(BP32.to_f64(op_mul(&BP32, two, three)), 6.0);
        assert_eq!(BP32.to_f64(op_sub(&BP32, two, three)), -1.0);
        assert_eq!(BP32.to_f64(op_sqrt(&BP32, BP32.from_f64(9.0))), 3.0);
        assert_eq!(BP32.to_f64(op_fma(&BP32, two, three, two)), 8.0);
    }

    #[test]
    fn roundtrip_f64_helper() {
        assert_eq!(P16.roundtrip_f64(1.0), 1.0);
        assert!((P16.roundtrip_f64(std::f64::consts::PI) - 3.1416015625).abs() < 1e-12);
    }
}
