//! Unified internal ("unpacked") representation shared by every format.
//!
//! All codecs (IEEE float, standard posit, b-posit, takum) decode into a
//! [`Decoded`] value and encode from one. This mirrors the hardware story of
//! the paper: decode → float-like internal form → arithmetic → encode.
//!
//! Representation: `value = (-1)^sign * (sig / 2^63) * 2^exp`, with the
//! significand normalized so that bit 63 (the hidden bit) is set:
//! `sig ∈ [2^63, 2^64)`. `sticky` records that the true value lies strictly
//! between `sig` and `sig + 1` ulp at this width; it participates in the
//! final round-to-nearest-even performed by the encoders.
//!
//! Every format reproduced here keeps at most 61 fraction bits, so a 64-bit
//! significand plus a sticky flag is *exact* for rounding purposes.

/// Classification of a decoded value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    /// Exact zero (posits have a single unsigned zero; IEEE zero keeps sign).
    Zero,
    /// Ordinary finite nonzero value.
    Normal,
    /// IEEE infinity (posit encoders map this to NaR).
    Inf,
    /// IEEE NaN / posit NaR ("Not a Real").
    Nan,
}

/// Unpacked value: sign-magnitude, normalized 64-bit significand.
#[derive(Clone, Copy, Debug)]
pub struct Decoded {
    pub class: Class,
    pub sign: bool,
    /// Unbiased exponent of the leading (hidden) bit.
    pub exp: i32,
    /// Normalized significand, hidden bit at position 63. Zero unless `Normal`.
    pub sig: u64,
    /// True if nonzero value bits were discarded below bit 0 of `sig`.
    pub sticky: bool,
}

impl Decoded {
    pub const ZERO: Decoded =
        Decoded { class: Class::Zero, sign: false, exp: 0, sig: 0, sticky: false };
    pub const NAN: Decoded =
        Decoded { class: Class::Nan, sign: false, exp: 0, sig: 0, sticky: false };

    /// Infinity with the given sign.
    pub fn inf(sign: bool) -> Decoded {
        Decoded { class: Class::Inf, sign, exp: 0, sig: 0, sticky: false }
    }

    /// Signed zero (sign only meaningful for IEEE).
    pub fn zero(sign: bool) -> Decoded {
        Decoded { class: Class::Zero, sign, exp: 0, sig: 0, sticky: false }
    }

    /// Construct a normal value; `sig` must already be normalized.
    pub fn normal(sign: bool, exp: i32, sig: u64) -> Decoded {
        debug_assert!(sig >> 63 == 1, "significand not normalized: {sig:#x}");
        Decoded { class: Class::Normal, sign, exp, sig, sticky: false }
    }

    pub fn is_zero(&self) -> bool { self.class == Class::Zero }
    pub fn is_nan(&self) -> bool { self.class == Class::Nan }
    pub fn is_inf(&self) -> bool { self.class == Class::Inf }
    pub fn is_normal(&self) -> bool { self.class == Class::Normal }

    /// Exact conversion from `f64` (f64 has 52 fraction bits < 63, so no
    /// information is lost; subnormal doubles are normalized).
    pub fn from_f64(x: f64) -> Decoded {
        let bits = x.to_bits();
        let sign = bits >> 63 == 1;
        let biased = ((bits >> 52) & 0x7ff) as i32;
        let frac = bits & ((1u64 << 52) - 1);
        if biased == 0x7ff {
            return if frac == 0 { Decoded::inf(sign) } else { Decoded::NAN };
        }
        if biased == 0 {
            if frac == 0 {
                return Decoded::zero(sign);
            }
            // Subnormal: normalize. frac's leading 1 sits at bit 63−lz;
            // move it to bit 63 and place the exponent accordingly: the
            // value is frac·2^−1074, so exp = −1074 + (63 − lz).
            let lz = frac.leading_zeros();
            let exp = -1074 + (63 - lz) as i32;
            let sig = frac << lz;
            return Decoded::normal(sign, exp, sig);
        }
        let exp = biased - 1023;
        let sig = (1u64 << 63) | (frac << 11);
        Decoded::normal(sign, exp, sig)
    }

    /// Round-to-nearest-even conversion to `f64` (faithful; used for display
    /// and tests — formats with ≤ 52 fraction bits convert exactly).
    pub fn to_f64(&self) -> f64 {
        match self.class {
            Class::Zero => {
                if self.sign { -0.0 } else { 0.0 }
            }
            Class::Nan => f64::NAN,
            Class::Inf => {
                if self.sign { f64::NEG_INFINITY } else { f64::INFINITY }
            }
            Class::Normal => {
                if self.exp > 1023 {
                    return if self.sign { f64::NEG_INFINITY } else { f64::INFINITY };
                }
                if self.exp < -1022 - 53 {
                    return if self.sign { -0.0 } else { 0.0 };
                }
                // Keep 53 significand bits (plus subnormal shift if needed).
                let extra_shift = if self.exp < -1022 { (-1022 - self.exp) as u32 } else { 0 };
                let keep = 53u32.saturating_sub(extra_shift);
                if keep == 0 {
                    // exp == -1075 exactly (anything lower returned ±0
                    // above): the value lies in [2^-1075, 2^-1074). RNE
                    // against the min subnormal: the exact midpoint
                    // 2^-1075 (sig = 2^63, no sticky) ties to even 0;
                    // everything above rounds to ±2^-1074.
                    let up = self.sig > (1u64 << 63) || self.sticky;
                    let mag = if up { f64::from_bits(1) } else { 0.0 };
                    return if self.sign { -mag } else { mag };
                }
                let drop = 64 - keep;
                let kept = self.sig >> drop;
                let guard = (self.sig >> (drop - 1)) & 1;
                let below = self.sig & ((1u64 << (drop - 1)) - 1);
                let sticky = below != 0 || self.sticky;
                let rounded = kept + if guard == 1 && (sticky || kept & 1 == 1) { 1 } else { 0 };
                // rounded has `keep` significant bits (maybe keep+1 on carry).
                let mut mag = rounded as f64;
                // Scale by 2^(exp - (keep-1)).
                let scale = self.exp - (keep as i32 - 1);
                mag = libm_scalbn(mag, scale);
                if self.sign { -mag } else { mag }
            }
        }
    }

    /// Magnitude comparison helper for Normal values: compare (exp, sig, sticky).
    pub fn mag_cmp(&self, other: &Decoded) -> std::cmp::Ordering {
        use std::cmp::Ordering;
        debug_assert!(self.is_normal() && other.is_normal());
        match self.exp.cmp(&other.exp) {
            Ordering::Equal => match self.sig.cmp(&other.sig) {
                Ordering::Equal => self.sticky.cmp(&other.sticky),
                o => o,
            },
            o => o,
        }
    }
}

/// Minimal `scalbn` (no libm dependency): exact scaling by powers of two
/// with correct handling of overflow/underflow through division.
fn libm_scalbn(x: f64, n: i32) -> f64 {
    let mut x = x;
    let mut n = n;
    while n > 1000 {
        x *= f64::from_bits(0x7fe0000000000000); // 2^1023
        n -= 1023;
        if x.is_infinite() {
            return x;
        }
    }
    while n < -1000 {
        x *= f64::from_bits(0x0010000000000000); // 2^-1022
        n += 1022;
        if x == 0.0 {
            return x;
        }
    }
    if n >= 0 {
        if n > 1023 {
            return x * f64::INFINITY;
        }
        x * f64::from_bits(((1023 + n) as u64) << 52)
    } else {
        // n ∈ [-1000, -1): split to stay in normal range.
        if n >= -1022 {
            x * f64::from_bits(((1023 + n) as u64) << 52)
        } else {
            x * f64::from_bits(1u64 << 52) * f64::from_bits(((1023 + n + 1074) as u64) << 52)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_roundtrip_exact() {
        let cases = [
            0.0, -0.0, 1.0, -1.0, std::f64::consts::PI, 1e-300, -1e300, 1.5e-310,
            f64::MIN_POSITIVE, 6.6e-34,
        ];
        for &x in &cases {
            let d = Decoded::from_f64(x);
            let back = d.to_f64();
            assert_eq!(back.to_bits(), x.to_bits(), "roundtrip failed for {x}");
        }
    }

    #[test]
    fn f64_specials() {
        assert!(Decoded::from_f64(f64::NAN).is_nan());
        assert!(Decoded::from_f64(f64::INFINITY).is_inf());
        assert!(Decoded::from_f64(f64::NEG_INFINITY).sign);
        assert!(Decoded::from_f64(0.0).is_zero());
        assert_eq!(Decoded::from_f64(f64::INFINITY).to_f64(), f64::INFINITY);
        assert!(Decoded::from_f64(f64::NAN).to_f64().is_nan());
    }

    #[test]
    fn subnormal_f64_normalizes() {
        let x = f64::from_bits(1); // smallest subnormal, 2^-1074
        let d = Decoded::from_f64(x);
        assert!(d.is_normal());
        assert_eq!(d.exp, -1074);
        assert_eq!(d.sig, 1u64 << 63);
        assert_eq!(d.to_f64(), x);
    }

    #[test]
    fn to_f64_rne_at_the_min_subnormal_boundary() {
        // Values in (2^-1075, 2^-1074) round UP to the min subnormal;
        // exactly 2^-1075 is the tie and goes to even (0). This boundary
        // is live for quire readouts (e.g. 2^-500 · 1.5·2^-575).
        let above = Decoded::normal(false, -1075, (1u64 << 63) | (1u64 << 62));
        assert_eq!(above.to_f64().to_bits(), f64::from_bits(1).to_bits());
        let neg = Decoded::normal(true, -1075, (1u64 << 63) | 1);
        assert_eq!(neg.to_f64().to_bits(), (-f64::from_bits(1)).to_bits());
        let tie = Decoded::normal(false, -1075, 1u64 << 63);
        assert_eq!(tie.to_f64().to_bits(), 0.0f64.to_bits());
        let sticky_tie =
            Decoded { sticky: true, ..Decoded::normal(false, -1075, 1u64 << 63) };
        assert_eq!(sticky_tie.to_f64().to_bits(), f64::from_bits(1).to_bits());
        // Below the boundary still flushes to ±0.
        let below = Decoded::normal(false, -1076, u64::MAX);
        assert_eq!(below.to_f64(), 0.0);
        // And the kernel-level symptom: exact dot 2^-500 · 1.5·2^-575.
        let mut q = crate::formats::Quire::exact_f64();
        q.add_product(
            &Decoded::from_f64(f64::powi(2.0, -500)),
            &Decoded::from_f64(1.5 * f64::powi(2.0, -575)),
        );
        assert_eq!(q.to_decoded().to_f64(), f64::from_bits(1));
    }

    #[test]
    fn normal_constructor_sets_fields() {
        let d = Decoded::normal(true, 5, (1u64 << 63) | (1u64 << 40));
        assert!(d.sign);
        assert_eq!(d.exp, 5);
        assert!(d.is_normal());
        assert!(!d.sticky);
    }

    #[test]
    fn mag_cmp_orders_by_exp_then_sig() {
        use std::cmp::Ordering::*;
        let a = Decoded::normal(false, 1, 1u64 << 63);
        let b = Decoded::normal(false, 2, 1u64 << 63);
        let c = Decoded::normal(false, 2, (1u64 << 63) | 1);
        assert_eq!(a.mag_cmp(&b), Less);
        assert_eq!(b.mag_cmp(&c), Less);
        assert_eq!(c.mag_cmp(&c), Equal);
    }

    #[test]
    fn scalbn_extremes() {
        assert_eq!(libm_scalbn(1.0, -1074), f64::from_bits(1));
        assert_eq!(libm_scalbn(1.0, 1023), f64::from_bits(0x7fe0000000000000));
        assert_eq!(libm_scalbn(1.5, 2), 6.0);
    }
}
