//! Posit-family codec: standard posits ⟨n,eS⟩ and bounded posits (b-posits)
//! ⟨n,rS,eS⟩ share one implementation.
//!
//! As the paper observes (§1.4), *"a standard n-bit posit has a maximum
//! regime size rS equal to n−1"* — so a standard posit is exactly a b-posit
//! with `rs = n-1`, and one parameterized codec covers both. The b-posit of
//! the paper is `⟨n, 6, 5⟩`.
//!
//! Semantics implemented here (see DESIGN.md §Format semantics):
//! - `000…0` is zero; `100…0` is NaR. Negative values are the 2's complement
//!   of their magnitude pattern, so posit comparison is signed-integer
//!   comparison and NaR sorts below every real posit.
//! - The regime is a run of identical bits terminated by the first opposite
//!   bit **or by reaching `rs` bits** (the b-posit rule). A run of k zeros
//!   encodes r = −k; a run of k ones encodes r = k−1.
//! - Effective exponent `T = r·2^eS + e`; value = (−1)^s · 2^T · (1+f).
//! - Rounding is round-to-nearest-even in pattern space (Posit™ Standard
//!   rule), with saturation: a nonzero real never rounds to zero or NaR.

use super::decoded::{Class, Decoded};
use super::round::BitStream;

/// Static description of a posit-family format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PositSpec {
    /// Total width in bits, 2 ≤ n ≤ 64.
    pub n: u32,
    /// Maximum regime field size, 2 ≤ rs ≤ n−1. `rs = n-1` ⇒ standard posit.
    pub rs: u32,
    /// Exponent field size in bits, 0 ≤ es ≤ 30.
    pub es: u32,
}

/// Standard 8-bit posit ⟨8,2⟩ per the Posit™ Standard (2022).
pub const P8: PositSpec = PositSpec { n: 8, rs: 7, es: 2 };
/// Standard 16-bit posit ⟨16,2⟩.
pub const P16: PositSpec = PositSpec { n: 16, rs: 15, es: 2 };
/// Standard 32-bit posit ⟨32,2⟩.
pub const P32: PositSpec = PositSpec { n: 32, rs: 31, es: 2 };
/// Standard 64-bit posit ⟨64,2⟩.
pub const P64: PositSpec = PositSpec { n: 64, rs: 63, es: 2 };
/// Paper's 16-bit b-posit ⟨16,6,5⟩ (Tables 5/6 configuration).
pub const BP16: PositSpec = PositSpec { n: 16, rs: 6, es: 5 };
/// Paper's 32-bit b-posit ⟨32,6,5⟩ — dynamic range 2^−192 … 2^192.
pub const BP32: PositSpec = PositSpec { n: 32, rs: 6, es: 5 };
/// Paper's 64-bit b-posit ⟨64,6,5⟩.
pub const BP64: PositSpec = PositSpec { n: 64, rs: 6, es: 5 };
/// Fig. 6b configuration: ⟨16,6,3⟩ (eS=3 compensates the halved range).
pub const BP16_E3: PositSpec = PositSpec { n: 16, rs: 6, es: 3 };

impl PositSpec {
    /// Standard posit ⟨n,es⟩ (unbounded regime, i.e. rs = n−1).
    pub fn standard(n: u32, es: u32) -> PositSpec {
        assert!((2..=64).contains(&n));
        PositSpec { n, rs: n - 1, es }
    }

    /// Bounded posit ⟨n,rs,es⟩.
    pub fn bounded(n: u32, rs: u32, es: u32) -> PositSpec {
        assert!((2..=64).contains(&n), "n out of range");
        assert!(rs >= 2 && rs <= n - 1, "rs out of range");
        PositSpec { n, rs, es }
    }

    /// True if this is a bounded (b-posit) configuration.
    pub fn is_bounded(&self) -> bool {
        self.rs < self.n - 1
    }

    /// Bit mask covering the n-bit word.
    #[inline]
    pub fn mask(&self) -> u64 {
        if self.n == 64 { u64::MAX } else { (1u64 << self.n) - 1 }
    }

    /// Width of the body (everything after the sign bit).
    #[inline]
    pub fn m(&self) -> u32 {
        self.n - 1
    }

    /// The NaR ("Not a Real") pattern: 100…0.
    #[inline]
    pub fn nar(&self) -> u64 {
        1u64 << (self.n - 1)
    }

    /// Magnitude body of the largest finite posit (0111…1).
    #[inline]
    pub fn maxpos_body(&self) -> u64 {
        (1u64 << self.m()) - 1
    }

    /// Largest representable regime value r.
    pub fn r_max(&self) -> i32 {
        self.rs as i32 - 1
    }

    /// Smallest representable regime value r. For a standard posit the body
    /// of all zeros is the zero pattern, so the longest usable zero-run is
    /// m−1; for a true b-posit the capped run of rs zeros still leaves
    /// payload bits, so −rs is reachable.
    pub fn r_min(&self) -> i32 {
        if self.is_bounded() { -(self.rs as i32) } else { -(self.m() as i32 - 1) }
    }

    /// Largest effective exponent T (scale of maxpos).
    pub fn max_exp(&self) -> i32 {
        // maxpos: maximal regime; exponent bits all ones if any survive.
        let reg_len = self.regime_len(self.r_max());
        let rem = self.m().saturating_sub(reg_len);
        let e = if rem >= self.es {
            (1i32 << self.es) - 1
        } else {
            // partial/ghost exponent bits: surviving bits are ones, ghosts zero
            (((1u64 << rem) - 1) << (self.es - rem)) as i32
        };
        self.r_max() * (1 << self.es) + e
    }

    /// Smallest effective exponent T (scale of minpos).
    pub fn min_exp(&self) -> i32 {
        self.r_min() * (1 << self.es)
    }

    /// Number of distinct regime-field sizes (the paper's "five possible
    /// combinations" for rs=6: sizes 2..=6).
    pub fn regime_size_count(&self) -> u32 {
        self.rs - 1
    }

    /// Quire width in bits per the paper's sizing rule: carry guard (31) +
    /// 2·(2·|Tmin|) + 1, rounded up to a multiple of 64 is the storage size;
    /// the architectural size for ⟨n,6,5⟩ is 800.
    pub fn quire_bits(&self) -> u32 {
        let t = self.min_exp().unsigned_abs();
        31 + 4 * t + 1
    }

    /// Length of the regime *field* (including terminator when present) for
    /// regime value r.
    pub fn regime_len(&self, r: i32) -> u32 {
        let run = if r >= 0 { r as u32 + 1 } else { (-r) as u32 };
        if run >= self.rs { self.rs } else { run + 1 }
    }

    /// Number of explicit fraction bits carried by a value with effective
    /// exponent T (used by the accuracy analysis for Figs 6/7).
    pub fn frac_bits_at(&self, t: i32) -> u32 {
        let r = t >> self.es;
        let reg_len = self.regime_len(r);
        self.m().saturating_sub(reg_len).saturating_sub(self.es)
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    /// Unpack an n-bit pattern into the internal representation.
    pub fn decode(&self, bits: u64) -> Decoded {
        let bits = bits & self.mask();
        if bits == 0 {
            return Decoded::ZERO;
        }
        if bits == self.nar() {
            return Decoded::NAN;
        }
        let sign = (bits >> (self.n - 1)) & 1 == 1;
        let word = if sign { bits.wrapping_neg() & self.mask() } else { bits };
        let m = self.m();
        let body = word & self.maxpos_body();
        // Leading-run length of the body's MSB value.
        let b0 = (body >> (m - 1)) & 1;
        let probe = if b0 == 1 { !body & self.maxpos_body() } else { body };
        // `probe` has a 0-run where the regime run is; count its leading zeros
        // within the m-bit field.
        let run_raw = if probe == 0 { m } else { (probe << (64 - m)).leading_zeros() };
        let run = run_raw.min(self.rs);
        let reg_len = if run == self.rs { self.rs } else { run + 1 };
        let r: i32 = if b0 == 1 { run as i32 - 1 } else { -(run as i32) };
        let rem_w = m - reg_len.min(m);
        let rem = if rem_w == 0 { 0 } else { body & ((1u64 << rem_w) - 1) };
        let (e, frac, fw) = if rem_w >= self.es {
            let fw = rem_w - self.es;
            (
                (rem >> fw) as i32,
                if fw == 0 { 0 } else { rem & ((1u64 << fw) - 1) },
                fw,
            )
        } else {
            // Some or all exponent bits are ghosts (zero).
            ((rem << (self.es - rem_w)) as i32, 0, 0)
        };
        let t = r * (1 << self.es) + e;
        let sig = (1u64 << 63) | if fw == 0 { 0 } else { frac << (63 - fw) };
        Decoded::normal(sign, t, sig)
    }

    // ------------------------------------------------------------------
    // Encode
    // ------------------------------------------------------------------

    /// Pack an internal value into an n-bit pattern with round-to-nearest-
    /// even (pattern space) and posit saturation semantics.
    pub fn encode(&self, d: &Decoded) -> u64 {
        match d.class {
            Class::Zero => 0,
            Class::Nan | Class::Inf => self.nar(),
            Class::Normal => {
                let body = self.encode_body(d);
                if d.sign {
                    body.wrapping_neg() & self.mask()
                } else {
                    body
                }
            }
        }
    }

    /// Encode the magnitude into a positive body pattern in [1, 2^m − 1].
    fn encode_body(&self, d: &Decoded) -> u64 {
        let m = self.m();
        let t = d.exp;
        let r = t >> self.es; // floor division by 2^es
        let e = (t - (r << self.es)) as u64; // in [0, 2^es)
        if r > self.r_max() {
            return self.maxpos_body();
        }
        if r < self.r_min() {
            return 1; // minpos
        }
        let mut s = BitStream::new();
        // Regime field.
        if r >= 0 {
            let run = r as u32 + 1;
            if run >= self.rs {
                s.push_run(1, self.rs);
            } else {
                s.push_run(1, run);
                s.push(0, 1);
            }
        } else {
            let run = (-r) as u32;
            if run >= self.rs {
                s.push_run(0, self.rs);
            } else {
                s.push_run(0, run);
                s.push(1, 1);
            }
        }
        // Exponent field.
        s.push(e, self.es);
        // Fraction: significand without the hidden bit.
        s.push(d.sig << 1 >> 1, 63);
        s.or_sticky(d.sticky);
        let body = s.round_rne(m);
        if body >> m != 0 || body == self.maxpos_body() + 1 {
            return self.maxpos_body(); // carry out: saturate, never NaR
        }
        if body == 0 {
            return 1; // never round a nonzero real to zero
        }
        body
    }

    // ------------------------------------------------------------------
    // Convenience
    // ------------------------------------------------------------------

    /// Encode an f64 value (exact unpack, then posit rounding).
    pub fn from_f64(&self, x: f64) -> u64 {
        self.encode(&Decoded::from_f64(x))
    }

    /// Decode to f64 (exact for n ≤ 53+overhead; faithful otherwise).
    pub fn to_f64(&self, bits: u64) -> f64 {
        self.decode(bits).to_f64()
    }

    /// Signed-integer comparison of two patterns (the posit comparison rule:
    /// reinterpret as 2's-complement integers; NaR is the minimum).
    pub fn cmp_bits(&self, a: u64, b: u64) -> std::cmp::Ordering {
        self.sext(a).cmp(&self.sext(b))
    }

    /// Sign-extend an n-bit pattern to i64.
    pub fn sext(&self, bits: u64) -> i64 {
        let sh = 64 - self.n;
        ((bits << sh) as i64) >> sh
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_and_nar() {
        for spec in [P16, P32, BP16, BP32, BP64] {
            assert!(spec.decode(0).is_zero());
            assert!(spec.decode(spec.nar()).is_nan());
            assert_eq!(spec.encode(&Decoded::ZERO), 0);
            assert_eq!(spec.encode(&Decoded::NAN), spec.nar());
            assert_eq!(spec.encode(&Decoded::inf(true)), spec.nar());
        }
    }

    #[test]
    fn p16_pi_matches_known_pattern() {
        // posit16(π): s=0, regime=10 (r=0), e=01, frac=10010010001 (1169)
        // → 0x4C91 = 2·(1+1169/2048) = 3.1416015625 (Fig. 1's posit16 π).
        let bits = P16.from_f64(std::f64::consts::PI);
        assert_eq!(bits, 0x4C91, "got {bits:#06x}");
        assert_eq!(P16.to_f64(0x4C91), 3.1416015625);
    }

    #[test]
    fn p32_known_values() {
        // posit32(1.0) = 0x40000000
        assert_eq!(P32.from_f64(1.0), 0x4000_0000);
        assert_eq!(P32.to_f64(0x4000_0000), 1.0);
        // posit32(-1.0) = 2's complement
        assert_eq!(P32.from_f64(-1.0), 0xC000_0000);
        assert_eq!(P32.to_f64(0xC000_0000), -1.0);
        // posit32(0.5): r=-1 → regime 01… wait sign 0, regime "01" is r=0.
        // 0.5 = 2^-1: T=-1 → r=-1,e=3: regime 0 1 (run 1 zero + term), e=11, frac 0
        assert_eq!(P32.to_f64(P32.from_f64(0.5)), 0.5);
        // maxpos for posit32 = 2^120
        let maxpos = P32.decode(P32.maxpos_body());
        assert_eq!(maxpos.exp, 120);
        assert_eq!(P32.max_exp(), 120);
        assert_eq!(P32.min_exp(), -120);
    }

    #[test]
    fn bp32_paper_dynamic_range() {
        // Paper §Abstract: ⟨32,6,5⟩ spans 2^-192 … 2^192 (maxpos scale 191 + frac).
        assert_eq!(BP32.min_exp(), -192);
        assert_eq!(BP32.max_exp(), 191);
        assert_eq!(BP32.r_min(), -6);
        assert_eq!(BP32.r_max(), 5);
        // Five possible regime sizes (paper §1.4 / §3.1).
        assert_eq!(BP32.regime_size_count(), 5);
        // Quire: paper says 800 bits.
        assert_eq!(BP32.quire_bits(), 800);
        assert_eq!(BP64.quire_bits(), 800); // "for any precision n > 12"
        assert_eq!(BP16.quire_bits(), 800);
    }

    #[test]
    fn bp32_cosmological_constant() {
        // Paper §1.4: Λ = 1.4657e-52 representable to 8 decimal places.
        let lam = 1.4657e-52;
        let bits = BP32.from_f64(lam);
        let back = BP32.to_f64(bits);
        let rel = ((back - lam) / lam).abs();
        // At T=-173 the b-posit32 carries 20 fraction bits → worst-case
        // relative error 2^-21 ≈ 4.8e-7 (the paper's "eight decimal places"
        // display, Λ ≈ 1.4657003e-52, is ~7 significant digits).
        assert!(rel < 4.8e-7, "relative error {rel:e} too large");
        assert_eq!(BP32.decode(bits).exp, -173);
    }

    #[test]
    fn bp32_frac_bits_range() {
        // ⟨32,6,5⟩: fraction bits range 20 (long regime) … 24 (fovea).
        assert_eq!(BP32.frac_bits_at(0), 24);
        assert_eq!(BP32.frac_bits_at(-32), 24); // r=-1, size-2 regime
        assert_eq!(BP32.frac_bits_at(31), 24);
        assert_eq!(BP32.frac_bits_at(32), 23); // r=1, size-3 regime
        assert_eq!(BP32.frac_bits_at(191), 20); // maximal regime
        assert_eq!(BP32.frac_bits_at(-192), 20);
    }

    #[test]
    fn regime_lengths_match_paper_table3() {
        // Table 3: r(4-bit 2's comp) → size: 0/-1→2, 1/-2→3, 2/-3→4, 3/-4→5,
        // 4,5/-5,-6→6.
        let s = BP32;
        assert_eq!(s.regime_len(0), 2);
        assert_eq!(s.regime_len(-1), 2);
        assert_eq!(s.regime_len(1), 3);
        assert_eq!(s.regime_len(-2), 3);
        assert_eq!(s.regime_len(2), 4);
        assert_eq!(s.regime_len(-3), 4);
        assert_eq!(s.regime_len(3), 5);
        assert_eq!(s.regime_len(-4), 5);
        assert_eq!(s.regime_len(4), 6);
        assert_eq!(s.regime_len(5), 6);
        assert_eq!(s.regime_len(-5), 6);
        assert_eq!(s.regime_len(-6), 6);
    }

    #[test]
    fn roundtrip_all_p16() {
        // Every 16-bit standard posit pattern decodes and re-encodes to itself.
        for bits in 0..=u16::MAX as u64 {
            let d = P16.decode(bits);
            let back = P16.encode(&d);
            assert_eq!(back, bits, "p16 roundtrip failed for {bits:#06x}");
        }
    }

    #[test]
    fn roundtrip_all_bp16() {
        for bits in 0..=u16::MAX as u64 {
            let d = BP16.decode(bits);
            let back = BP16.encode(&d);
            assert_eq!(back, bits, "bp16 roundtrip failed for {bits:#06x}");
        }
    }

    #[test]
    fn roundtrip_all_bp16_e3() {
        for bits in 0..=u16::MAX as u64 {
            let d = BP16_E3.decode(bits);
            let back = BP16_E3.encode(&d);
            assert_eq!(back, bits, "bp16e3 roundtrip failed for {bits:#06x}");
        }
    }

    #[test]
    fn roundtrip_all_p8() {
        for bits in 0..=u8::MAX as u64 {
            let d = P8.decode(bits);
            assert_eq!(P8.encode(&d), bits);
        }
    }

    #[test]
    fn monotonic_p16_and_bp16() {
        // Posit patterns, read as signed ints, are ordered by value.
        for spec in [P16, BP16, BP16_E3] {
            let mut prev = f64::NEG_INFINITY;
            // skip NaR (0x8000): start just above it.
            for i in 1..=u16::MAX as u64 {
                let bits = (0x8000 + i) & 0xffff;
                let v = spec.to_f64(bits);
                assert!(v > prev, "non-monotonic at {bits:#06x}: {v} ≤ {prev}");
                prev = v;
            }
        }
    }

    #[test]
    fn saturation_not_nar() {
        // Huge values saturate at maxpos; tiny nonzero values at minpos.
        for spec in [P16, P32, BP16, BP32] {
            assert_eq!(spec.from_f64(1e300), spec.maxpos_body());
            assert_eq!(spec.from_f64(-1e300), spec.nar() + 1); // -maxpos
            assert_eq!(spec.from_f64(1e-300), 1);
            assert_eq!(spec.from_f64(-1e-300), spec.mask()); // -minpos = 111…1
        }
    }

    #[test]
    fn bp32_minpos_value() {
        // b-posit minpos: body=1 → regime 000000, e=0, frac=…001 (20 frac bits)
        let d = BP32.decode(1);
        assert_eq!(d.exp, -192);
        assert_eq!(d.sig, (1u64 << 63) | (1u64 << 43)); // 1 + 2^-20
    }

    #[test]
    fn standard_minpos_maxpos_values() {
        // posit16 minpos = 2^-56, maxpos = 2^56
        let minpos = P16.decode(1);
        assert_eq!(minpos.exp, -56);
        assert_eq!(minpos.sig, 1u64 << 63);
        let maxpos = P16.decode(P16.maxpos_body());
        assert_eq!(maxpos.exp, 56);
    }

    #[test]
    fn rounding_ties_to_even_pattern() {
        // For ⟨16,2⟩, 1 + 2^-12 is exactly between patterns of 1 and 1+2^-11
        // (fovea has 12 frac bits... at T=0: n-1-2-2=11 frac bits). So
        // 1 + 2^-12 is a tie; even pattern wins (frac lsb 0 → stays at 1.0).
        let bits = P16.from_f64(1.0 + f64::powi(2.0, -12));
        assert_eq!(P16.to_f64(bits), 1.0);
        // Just above the tie rounds up.
        let bits = P16.from_f64(1.0 + f64::powi(2.0, -12) + 1e-9);
        assert!(P16.to_f64(bits) > 1.0);
    }

    #[test]
    fn cmp_bits_ordering() {
        let a = P32.from_f64(-2.5);
        let b = P32.from_f64(1.0);
        assert_eq!(P32.cmp_bits(a, b), std::cmp::Ordering::Less);
        assert_eq!(P32.cmp_bits(P32.nar(), a), std::cmp::Ordering::Less);
    }

    #[test]
    fn p64_roundtrip_sampled() {
        // Sampled 64-bit roundtrip (exhaustive is infeasible).
        let mut x = 0x9e3779b97f4a7c15u64;
        for _ in 0..200_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            for spec in [P64, BP64] {
                let d = spec.decode(x);
                assert_eq!(spec.encode(&d), x, "roundtrip failed {x:#x} in {spec:?}");
            }
        }
    }

    #[test]
    fn ghost_exponent_bits_decode_as_zero() {
        // ⟨16,2⟩ pattern with regime occupying all but one bit: body = 14
        // ones + final 0 terminator is r=13 with ghost exponent.
        // body 0b111111111111110 (15 bits): run=14, terminated? bit15..: run
        // of 14 ones then a 0 → r=13, regLen=15, rem=0 → e ghost = 0.
        let body = 0b111_1111_1111_1110u64;
        let d = P16.decode(body);
        assert_eq!(d.exp, 13 * 4);
        assert_eq!(d.sig, 1u64 << 63);
    }
}
