//! Linear takum codec (Hunhold 2024, paper ref [14]) — the third
//! bounded-range format compared in Fig 7.
//!
//! A takum packs: sign S (1 bit), direction D (1 bit), regime R (3 bits),
//! characteristic C (r bits, r derived from D/R), mantissa M (n−5−r bits).
//!
//! - D=1: r = R,     c = 2^r − 1 + C   (c ∈ [0, 254])
//! - D=0: r = 7 − R, c = −2^(r+1) + 1 + C  (c ∈ [−255, −1])
//!
//! Value = (−1)^s · 2^c · (1+f); negatives are 2's complements of the whole
//! word (takums, like posits, map 2's-complement integers onto the reals),
//! `0…0` is zero and `10…0` is NaR. The characteristic costs 4–11 bits of
//! overhead total, giving the "reverse bell curve" accuracy distribution the
//! paper contrasts with the b-posit's bell shape.

use super::decoded::{Class, Decoded};
use super::round::BitStream;

/// Static description of a takum format (width only; the rest is fixed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TakumSpec {
    /// Total width in bits, 12 ≤ n ≤ 64.
    pub n: u32,
}

/// 16-bit takum.
pub const T16: TakumSpec = TakumSpec { n: 16 };
/// 32-bit takum (Fig 7's gray curve).
pub const T32: TakumSpec = TakumSpec { n: 32 };
/// 64-bit takum.
pub const T64: TakumSpec = TakumSpec { n: 64 };

impl TakumSpec {
    pub fn new(n: u32) -> TakumSpec {
        assert!((12..=64).contains(&n), "takum needs 12 ≤ n ≤ 64");
        TakumSpec { n }
    }

    #[inline]
    pub fn mask(&self) -> u64 {
        if self.n == 64 { u64::MAX } else { (1u64 << self.n) - 1 }
    }

    #[inline]
    pub fn nar(&self) -> u64 {
        1u64 << (self.n - 1)
    }

    #[inline]
    pub fn maxpos_body(&self) -> u64 {
        (1u64 << (self.n - 1)) - 1
    }

    pub fn max_exp(&self) -> i32 {
        254
    }

    pub fn min_exp(&self) -> i32 {
        -255
    }

    /// Characteristic width r for a given characteristic value c.
    fn r_of_c(c: i32) -> u32 {
        if c >= 0 {
            31 - (c as u32 + 1).leading_zeros() // floor(log2(c+1))
        } else {
            31 - ((-c) as u32).leading_zeros() // floor(log2(−c))
        }
    }

    /// Explicit mantissa bits at characteristic c (accuracy analysis).
    pub fn frac_bits_at(&self, c: i32) -> u32 {
        if c < self.min_exp() || c > self.max_exp() {
            return 0;
        }
        (self.n - 5).saturating_sub(Self::r_of_c(c))
    }

    /// Unpack an n-bit takum pattern.
    pub fn decode(&self, bits: u64) -> Decoded {
        let bits = bits & self.mask();
        if bits == 0 {
            return Decoded::ZERO;
        }
        if bits == self.nar() {
            return Decoded::NAN;
        }
        let sign = (bits >> (self.n - 1)) & 1 == 1;
        let word = if sign { bits.wrapping_neg() & self.mask() } else { bits };
        let m = self.n - 1; // body width
        let body = word & self.maxpos_body();
        let d = (body >> (m - 1)) & 1;
        let r_field = ((body >> (m - 4)) & 0b111) as u32;
        let r = if d == 1 { r_field } else { 7 - r_field };
        // Characteristic: next r bits below the regime.
        let after_r = m - 4; // bits remaining after S(implicit)/D/R
        let c_field = if r == 0 {
            0u64
        } else {
            (body >> (after_r - r)) & ((1u64 << r) - 1)
        };
        let c: i32 = if d == 1 {
            (1i32 << r) - 1 + c_field as i32
        } else {
            -(1i32 << (r + 1)) + 1 + c_field as i32
        };
        let fw = after_r - r; // mantissa width (≥ 0 since n ≥ 12 ⇒ after_r ≥ 7 ≥ r)
        let frac = if fw == 0 { 0 } else { body & ((1u64 << fw) - 1) };
        let sig = (1u64 << 63) | if fw == 0 { 0 } else { frac << (63 - fw) };
        Decoded::normal(sign, c, sig)
    }

    /// Pack an internal value with RNE in pattern space + saturation.
    pub fn encode(&self, dec: &Decoded) -> u64 {
        match dec.class {
            Class::Zero => 0,
            Class::Nan | Class::Inf => self.nar(),
            Class::Normal => {
                let body = self.encode_body(dec);
                if dec.sign {
                    body.wrapping_neg() & self.mask()
                } else {
                    body
                }
            }
        }
    }

    fn encode_body(&self, dec: &Decoded) -> u64 {
        let m = self.n - 1;
        let c = dec.exp;
        if c > self.max_exp() {
            return self.maxpos_body();
        }
        if c < self.min_exp() {
            return 1;
        }
        let r = Self::r_of_c(c);
        let (d, r_field, c_field) = if c >= 0 {
            (1u64, r as u64, (c - ((1 << r) - 1)) as u64)
        } else {
            (0u64, (7 - r) as u64, (c + (1 << (r + 1)) - 1) as u64)
        };
        let mut s = BitStream::new();
        s.push(d, 1);
        s.push(r_field, 3);
        s.push(c_field, r);
        s.push(dec.sig << 1 >> 1, 63);
        s.or_sticky(dec.sticky);
        let body = s.round_rne(m);
        if body >> m != 0 {
            return self.maxpos_body();
        }
        if body == 0 {
            return 1;
        }
        body
    }

    pub fn from_f64(&self, x: f64) -> u64 {
        self.encode(&Decoded::from_f64(x))
    }

    pub fn to_f64(&self, bits: u64) -> f64 {
        self.decode(bits).to_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_nar_one() {
        for spec in [T16, T32, T64] {
            assert!(spec.decode(0).is_zero());
            assert!(spec.decode(spec.nar()).is_nan());
            let one = spec.from_f64(1.0);
            assert_eq!(spec.to_f64(one), 1.0);
            // 1.0: c=0 → D=1,R=0,C empty → body = 100…0 of the body field
            assert_eq!(one, 1u64 << (spec.n - 2));
        }
    }

    #[test]
    fn dynamic_range_pm_254() {
        // Paper §1.4: takum scaling spans 2^-254… wait, c ∈ [-255, 254];
        // maxpos scale 254, minpos scale -255.
        let maxpos = T32.decode(T32.maxpos_body());
        assert_eq!(maxpos.exp, 254);
        let minpos = T32.decode(1);
        assert_eq!(minpos.exp, -255);
    }

    #[test]
    fn roundtrip_all_t16() {
        for bits in 0..=u16::MAX as u64 {
            let d = T16.decode(bits);
            assert_eq!(T16.encode(&d), bits, "t16 roundtrip failed {bits:#06x}");
        }
    }

    #[test]
    fn roundtrip_sampled_t32_t64() {
        let mut x = 0x2545f4914f6cdd1du64;
        for _ in 0..200_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            for spec in [T32, T64] {
                let bits = x & spec.mask();
                if bits == spec.nar() {
                    continue;
                }
                let d = spec.decode(bits);
                assert_eq!(spec.encode(&d), bits, "roundtrip failed {bits:#x} n={}", spec.n);
            }
        }
    }

    #[test]
    fn monotonic_t16() {
        let mut prev = f64::NEG_INFINITY;
        for i in 1..=u16::MAX as u64 {
            let bits = (T16.nar() + i) & T16.mask();
            let v = T16.to_f64(bits);
            assert!(v > prev, "non-monotonic at {bits:#06x}");
            prev = v;
        }
    }

    #[test]
    fn characteristic_widths() {
        // c=0 → r=0 (no C bits): n-5 mantissa bits — the sharp peak.
        assert_eq!(T32.frac_bits_at(0), 27);
        assert_eq!(T32.frac_bits_at(1), 26); // r=1
        assert_eq!(T32.frac_bits_at(-1), 27); // r=0
        assert_eq!(T32.frac_bits_at(254), 20); // r=7
        assert_eq!(T32.frac_bits_at(-255), 20);
        assert_eq!(T32.frac_bits_at(300), 0); // out of range
    }

    #[test]
    fn saturation() {
        assert_eq!(T32.from_f64(1e300), T32.maxpos_body());
        assert_eq!(T32.from_f64(1e-300), 1);
        assert_eq!(T32.from_f64(-1e300), T32.nar() + 1);
    }

    #[test]
    fn pi_accuracy_t32() {
        let pi = std::f64::consts::PI;
        let back = T32.to_f64(T32.from_f64(pi));
        // c=1 → r=1 → 26 mantissa bits → rel err < 2^-26
        assert!(((back - pi) / pi).abs() < f64::powi(2.0, -26));
    }
}
