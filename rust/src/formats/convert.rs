//! Cross-format conversion through the shared unpacked representation.
//!
//! Conversion is decode-then-encode: exact unpack in the source format, then
//! the destination format's own rounding/saturation. This is how the
//! coordinator quantizes f32 tensors to b-posit words and back.

use super::{Codec, Decoded};

/// Convert a bit pattern from `src` to `dst` (value-preserving up to the
/// destination's rounding).
pub fn convert<S: Codec + ?Sized, D: Codec + ?Sized>(src: &S, dst: &D, bits: u64) -> u64 {
    dst.encode(&src.decode(bits))
}

/// Quantize a slice of f32s into destination-format words.
pub fn quantize_f32<D: Codec + ?Sized>(dst: &D, xs: &[f32], out: &mut [u64]) {
    debug_assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = dst.encode(&Decoded::from_f64(x as f64));
    }
}

/// Dequantize destination-format words back to f32 (round-to-nearest via
/// the f64 path; exact for every ≤32-bit format at f32's precision or a
/// faithful double rounding otherwise).
pub fn dequantize_f32<S: Codec + ?Sized>(src: &S, bits: &[u64], out: &mut [f32]) {
    debug_assert_eq!(bits.len(), out.len());
    for (o, &b) in out.iter_mut().zip(bits) {
        *o = src.decode(b).to_f64() as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::ieee::{F16, F32};
    use crate::formats::posit::{BP16, BP32, P16, P32};
    use crate::formats::takum::T32;

    #[test]
    fn f32_to_bp32_in_fovea_is_lossless() {
        // b-posit32's fovea (2^-32 … 2^32) carries 24 fraction bits ≥ f32's
        // 23: every normal f32 in that range converts exactly.
        let mut x = 0x0123456789abcdefu64;
        for _ in 0..50_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let f = f32::from_bits((x as u32 & 0x3fff_ffff) | 0x2000_0000); // exp ∈ fovea-ish
            let v = f as f64;
            let out_of_range = v.abs() < f64::powi(2.0, -32) || v.abs() >= f64::powi(2.0, 32);
            if !v.is_finite() || v == 0.0 || out_of_range {
                continue;
            }
            let bp = convert(&F32, &BP32, f.to_bits() as u64);
            let back = convert(&BP32, &F32, bp);
            assert_eq!(back as u32, f.to_bits(), "lossless fovea roundtrip failed for {f}");
        }
    }

    #[test]
    fn p32_to_f64_like_range() {
        // posit32 → takum32 → posit32 identity holds in the takum-accurate zone.
        for v in [1.0f64, -2.5, 1e4, 3.25e-5, 123456.0] {
            let p = P32.from_f64(v);
            let t = convert(&P32, &T32, p);
            let back = convert(&T32, &P32, t);
            assert_eq!(back, p, "roundtrip through takum32 failed for {v}");
        }
    }

    #[test]
    fn quantize_dequantize_roundtrip() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.125).collect();
        let mut q = vec![0u64; xs.len()];
        quantize_f32(&BP32, &xs, &mut q);
        let mut back = vec![0f32; xs.len()];
        dequantize_f32(&BP32, &q, &mut back);
        // All inputs are small multiples of 2^-3: exact in bp32's fovea.
        assert_eq!(xs, back);
    }

    #[test]
    fn f16_to_p16_error_bounded() {
        // Converting f16 → p16 near 1.0 gains accuracy; far away it may
        // lose some, but never more than the p16 ulp.
        for bits in 0..=u16::MAX as u64 {
            let d = F16.decode(bits);
            if !d.is_normal() {
                continue;
            }
            let v = d.to_f64();
            let p = convert(&F16, &P16, bits);
            let back = P16.to_f64(p);
            if v.abs() > P16.to_f64(P16.maxpos_body()) {
                continue; // saturated
            }
            let fb = crate::formats::Codec::frac_bits_at(&P16, v.abs().log2().floor() as i32);
            let tol = f64::powi(2.0, -(fb as i32)) * v.abs().max(1e-300);
            assert!((back - v).abs() <= tol, "f16→p16 error too large for {v}: {back}");
        }
    }

    #[test]
    fn specials_convert() {
        assert_eq!(convert(&F32, &BP32, F32.qnan()), BP32.nar());
        assert_eq!(convert(&F32, &BP32, F32.inf_bits(false)), BP32.nar());
        assert_eq!(convert(&BP32, &F32, BP32.nar()), F32.qnan());
        assert_eq!(convert(&F32, &BP16, 0), 0);
        // b-posit saturation: 1e300 exceeds ⟨16,6,5⟩'s 2^192 range → maxpos
        use crate::formats::ieee::F64;
        let sat = convert(&F64, &BP16, (1e300f64).to_bits());
        assert_eq!(sat, BP16.maxpos_body());
    }
}
