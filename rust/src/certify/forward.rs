//! Interval twin of the native serving forward pass.
//!
//! [`interval_forward`] mirrors the dense two-layer chain the native
//! backend serves (`GEMM → bias + ReLU → GEMM`, see
//! `coordinator::backend`) over [`Interval`] arithmetic, accumulating
//! each output element in ascending input-index order — the same
//! single-accumulator chain order as both `reference_forward` and the
//! blocked GEMM microkernel (whose f32 fast path is bit-identical to
//! the naive triple loop; CI's serve-bench parity gate holds the two
//! together). Evaluation containment of the interval ops therefore
//! brackets the *served* logits, and exact containment brackets the
//! real-arithmetic result; both are proven in the Python mirror and
//! pinned by the committed golden vectors.
//!
//! Weights enter as point intervals of their *dequantized* values (the
//! certificate is with respect to the weights the model actually
//! serves), activations as quantization hulls `[raw, staged]`.

use super::interval::Interval;
use crate::vector::lane::LaneElem;

/// Dequantized model snapshot in the transposed layout the interval
/// twin consumes: `w1t[i*d + p]` is layer-1 weight (input `p` → hidden
/// `i`), `w2t[q*h + i]` is layer-2 weight (hidden `i` → logit `q`).
/// Built once per backend from its encoded tensors (decode is cheap and
/// happens off the hot path, only when certification is enabled).
#[derive(Clone, Debug)]
pub struct IntervalModel<E: LaneElem> {
    d: usize,
    h: usize,
    c: usize,
    w1t: Vec<E>,
    b1: Vec<E>,
    w2t: Vec<E>,
    b2: Vec<E>,
}

impl<E: LaneElem> IntervalModel<E> {
    /// Validates the shapes (`w1t: h×d`, `b1: h`, `w2t: c×h`, `b2: c`);
    /// `None` on any mismatch so the forward pass can index safely.
    pub fn new(
        d: usize,
        h: usize,
        c: usize,
        w1t: Vec<E>,
        b1: Vec<E>,
        w2t: Vec<E>,
        b2: Vec<E>,
    ) -> Option<Self> {
        let shapes_ok = d > 0
            && h > 0
            && c > 0
            && w1t.len() == d.checked_mul(h)?
            && b1.len() == h
            && w2t.len() == h.checked_mul(c)?
            && b2.len() == c;
        if !shapes_ok {
            return None;
        }
        Some(IntervalModel { d, h, c, w1t, b1, w2t, b2 })
    }

    /// Input width (features per request).
    pub fn d(&self) -> usize {
        self.d
    }

    /// Output width (logits per request).
    pub fn c(&self) -> usize {
        self.c
    }
}

/// Runs the interval twin for one request. `xints` carries one interval
/// per input feature (quantization hulls); returns one certified
/// `[lo, hi]` per logit. A length mismatch yields all-poisoned bounds —
/// fail closed, never panic.
pub fn interval_forward<E: LaneElem>(
    model: &IntervalModel<E>,
    xints: &[Interval<E>],
) -> Vec<Interval<E>> {
    let (d, h, c) = (model.d, model.h, model.c);
    if xints.len() != d {
        return vec![Interval::poison(); c];
    }
    let mut hid: Vec<Interval<E>> = Vec::with_capacity(h);
    for i in 0..h {
        let mut acc = Interval::zero();
        for (p, &x) in xints.iter().enumerate() {
            acc = acc.add(Interval::point(model.w1t[i * d + p]).mul(x));
        }
        hid.push(acc.add(Interval::point(model.b1[i])).relu());
    }
    let mut out: Vec<Interval<E>> = Vec::with_capacity(c);
    for q in 0..c {
        let mut acc = Interval::zero();
        for (i, &hv) in hid.iter().enumerate() {
            acc = acc.add(Interval::point(model.w2t[q * h + i]).mul(hv));
        }
        out.push(acc.add(Interval::point(model.b2[q])));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    /// f32 reference chain in the same ascending order (the
    /// reference_forward shape, transposed weights).
    fn ref_chain32(m: &IntervalModel<f32>, x: &[f32]) -> Vec<f32> {
        let (d, h, c) = (m.d, m.h, m.c);
        let mut hid = vec![0.0f32; h];
        for i in 0..h {
            let mut acc = 0.0f32;
            for p in 0..d {
                acc += m.w1t[i * d + p] * x[p];
            }
            let v = acc + m.b1[i];
            hid[i] = if v > 0.0 { v } else { 0.0 };
        }
        let mut out = vec![0.0f32; c];
        for q in 0..c {
            let mut acc = 0.0f32;
            for i in 0..h {
                acc += m.w2t[q * h + i] * hid[i];
            }
            out[q] = acc + m.b2[q];
        }
        out
    }

    fn synth(rng: &mut Rng, d: usize, h: usize, c: usize) -> IntervalModel<f32> {
        let v = |rng: &mut Rng| (rng.f64() - 0.5) as f32 * 0.5;
        let w1t: Vec<f32> = (0..d * h).map(|_| v(rng)).collect();
        let b1: Vec<f32> = (0..h).map(|_| v(rng)).collect();
        let w2t: Vec<f32> = (0..h * c).map(|_| v(rng)).collect();
        let b2: Vec<f32> = (0..c).map(|_| v(rng)).collect();
        IntervalModel::new(d, h, c, w1t, b1, w2t, b2).expect("shapes valid")
    }

    #[test]
    fn new_rejects_shape_mismatches() {
        assert!(IntervalModel::new(2, 2, 1, vec![0.0f32; 3], vec![0.0; 2], vec![0.0; 2], vec![0.0])
            .is_none());
        assert!(IntervalModel::new(0, 2, 1, vec![], vec![0.0f32; 2], vec![0.0; 2], vec![0.0])
            .is_none());
    }

    #[test]
    fn forward_brackets_f32_chain_over_input_hulls() {
        let mut rng = Rng::new(0xF0A4);
        let m = synth(&mut rng, 16, 12, 6);
        for _ in 0..50 {
            // A point input plus a nearby perturbed point; the hull
            // interval must bracket the chain at both.
            let x: Vec<f32> = (0..16).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
            let xq: Vec<f32> =
                x.iter().map(|&v| v + v * (rng.f64() as f32 - 0.5) * 1e-6).collect();
            let xints: Vec<Interval<f32>> =
                x.iter().zip(&xq).map(|(&a, &b)| Interval::hull(a, b)).collect();
            let bounds = interval_forward(&m, &xints);
            let at_x = ref_chain32(&m, &x);
            let at_xq = ref_chain32(&m, &xq);
            for j in 0..6 {
                assert!(bounds[j].contains(at_x[j]), "logit {j} raw");
                assert!(bounds[j].contains(at_xq[j]), "logit {j} staged");
                let w = bounds[j].width_f64();
                assert!(w.is_finite() && w > 0.0);
            }
        }
    }

    #[test]
    fn length_mismatch_fails_closed() {
        let mut rng = Rng::new(1);
        let m = synth(&mut rng, 4, 3, 2);
        let bounds = interval_forward(&m, &[Interval::point(1.0f32); 3]);
        assert_eq!(bounds.len(), 2);
        assert!(bounds.iter().all(|b| b.is_poisoned()));
    }
}
