//! Error-certified serving: an interval-arithmetic twin of the native
//! forward pass.
//!
//! The quantized serving tiers (bp32/bp64/p32) trade precision for the
//! paper's hardware win; this module turns the resulting accuracy claim
//! into a *measured, certified* property. [`interval`] carries a
//! directed-rounding `Interval<E>` type (the `lo/hi` idiom of
//! efloat.nim: every op rounds its lower endpoint one representable
//! float down and its upper endpoint one up, so the interval always
//! contains the exact real result and every round-to-nearest evaluation
//! over its operands). [`forward`] runs the interval twin of the
//! serving GEMM → bias + ReLU → GEMM chain: decoded weights enter as
//! point intervals of their dequantized values, activations as their
//! quantization hulls `[raw, quantized]`, and each output logit leaves
//! with a certified `[lo, hi]` bound on the exact real-arithmetic
//! result.
//!
//! The algorithms here are careful transliterations of the pure-stdlib
//! Python mirror (`python/tests/test_certify_mirror.py`), which proves
//! containment against exact `Fraction` arithmetic; the committed
//! golden vectors (`rust/tests/data/certify_golden.json`) pin the two
//! implementations together bit-for-bit. The serving integration — the
//! deterministic 1-in-N sampling hook, metrics, and the `/infer` echo —
//! lives in `coordinator::{backend,server}`; the width-vs-error
//! tightness gates run in `positron certify-bench` (see
//! docs/CERTIFY.md).
//!
//! This directory is a pallas-lint *kernel* zone: no float `min`/`max`,
//! no `mul_add`, no wallclock, no randomness, no panics.

pub mod forward;
pub mod interval;

pub use forward::{interval_forward, IntervalModel};
pub use interval::Interval;
