//! Directed-rounding interval arithmetic over the lane element types.
//!
//! An [`Interval<E>`] is a closed range `[lo, hi]` of `E` (f32 or f64)
//! maintaining two invariants through every op:
//!
//! 1. **Exact containment** — the interval contains the exact
//!    real-arithmetic result of the op applied to any reals drawn from
//!    the operand intervals.
//! 2. **Evaluation containment** — it also contains every
//!    round-to-nearest-even evaluation of the op at width `E` over such
//!    operands (the serving kernels evaluate in ascending-index order
//!    at width `E`, so the interval twin of a kernel chain brackets the
//!    served value bit-for-bit).
//!
//! Both follow from monotonicity of RNE plus one outward
//! [`next_float`]/[`prev_float`] step per endpoint per op: for any
//! real z, `prev(fl(z)) ≤ z ≤ next(fl(z))`. The Python mirror
//! (`python/tests/test_certify_mirror.py`) proves both invariants
//! against exact `Fraction` arithmetic; this file is its
//! transliteration, pinned bit-for-bit by the committed golden chains.
//!
//! NaN semantics: any NaN (operand or a produced `inf − inf` /
//! `0 × inf`) poisons the interval to `[NaN, NaN]`, which propagates
//! and fails closed — a poisoned interval contains nothing and reports
//! infinite width.
//!
//! [`next_float`]: crate::vector::lane::LaneElem::next_float
//! [`prev_float`]: crate::vector::lane::LaneElem::prev_float

use crate::vector::lane::LaneElem;

/// A closed directed-rounding interval (see the module docs for the
/// invariants). Construct via [`Interval::point`] / [`Interval::hull`];
/// the poisoned interval is `[NaN, NaN]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval<E: LaneElem> {
    /// Lower endpoint (≤ every contained value).
    pub lo: E,
    /// Upper endpoint (≥ every contained value).
    pub hi: E,
}

impl<E: LaneElem> Interval<E> {
    /// The additive-identity point interval `[0, 0]`.
    #[inline(always)]
    pub fn zero() -> Self {
        Interval { lo: E::ZERO, hi: E::ZERO }
    }

    /// The poisoned interval `[NaN, NaN]`.
    #[inline(always)]
    pub fn poison() -> Self {
        let nan = E::from_f64(f64::NAN);
        Interval { lo: nan, hi: nan }
    }

    /// Degenerate interval at `v` (poisoned if `v` is NaN).
    #[inline(always)]
    pub fn point(v: E) -> Self {
        if v.is_nan() {
            return Self::poison();
        }
        Interval { lo: v, hi: v }
    }

    /// Smallest interval containing both `x` and `y` (the quantization
    /// hull `[raw, quantized]` of a staged activation).
    #[inline(always)]
    pub fn hull(x: E, y: E) -> Self {
        if x.is_nan() || y.is_nan() {
            return Self::poison();
        }
        if x < y {
            Interval { lo: x, hi: y }
        } else {
            Interval { lo: y, hi: x }
        }
    }

    /// True when either endpoint is NaN.
    #[inline(always)]
    pub fn is_poisoned(self) -> bool {
        self.lo.is_nan() || self.hi.is_nan()
    }

    /// Interval sum: endpoint-wise add, rounded outward.
    #[inline(always)]
    pub fn add(self, b: Self) -> Self {
        if self.is_poisoned() || b.is_poisoned() {
            return Self::poison();
        }
        let lo = self.lo + b.lo;
        let hi = self.hi + b.hi;
        if lo.is_nan() || hi.is_nan() {
            // inf + -inf across mixed-sign endpoints
            return Self::poison();
        }
        Interval { lo: lo.prev_float(), hi: hi.next_float() }
    }

    /// Interval difference: `[lo − b.hi, hi − b.lo]`, rounded outward.
    #[inline(always)]
    pub fn sub(self, b: Self) -> Self {
        if self.is_poisoned() || b.is_poisoned() {
            return Self::poison();
        }
        let lo = self.lo - b.hi;
        let hi = self.hi - b.lo;
        if lo.is_nan() || hi.is_nan() {
            return Self::poison();
        }
        Interval { lo: lo.prev_float(), hi: hi.next_float() }
    }

    /// Interval product: extrema of the four corner products, rounded
    /// outward. The corner scan keeps the FIRST extremum on ties with
    /// explicit `<`/`>` compares (the kernel zone bans float
    /// `min`/`max`), mirroring the Python mirror's loop exactly.
    #[inline(always)]
    pub fn mul(self, b: Self) -> Self {
        if self.is_poisoned() || b.is_poisoned() {
            return Self::poison();
        }
        let c = [self.lo * b.lo, self.lo * b.hi, self.hi * b.lo, self.hi * b.hi];
        if c[0].is_nan() || c[1].is_nan() || c[2].is_nan() || c[3].is_nan() {
            // 0 × inf at some corner
            return Self::poison();
        }
        let mut lo = c[0];
        let mut hi = c[0];
        for &v in &c[1..] {
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        Interval { lo: lo.prev_float(), hi: hi.next_float() }
    }

    /// Fused-shape multiply-add `self × b + c` as the mul-then-add
    /// composition of the two audited ops (the kernel zone bans the fp
    /// `mul_add`, and the serving kernels round the product and the sum
    /// separately — composing keeps evaluation containment).
    #[inline(always)]
    pub fn mad(self, b: Self, c: Self) -> Self {
        self.mul(b).add(c)
    }

    /// ReLU: clamps both endpoints at zero from below (exact — no
    /// rounding, no outward step needed).
    #[inline(always)]
    pub fn relu(self) -> Self {
        if self.is_poisoned() {
            return Self::poison();
        }
        let lo = if self.lo > E::ZERO { self.lo } else { E::ZERO };
        let hi = if self.hi > E::ZERO { self.hi } else { E::ZERO };
        Interval { lo, hi }
    }

    /// Certified width: an f64 upper bound on `hi − lo` (one extra
    /// `next_float` absorbs the f64 subtraction's own rounding when the
    /// endpoints are f64). Poisoned or unbounded intervals report +∞ —
    /// fail closed.
    #[inline(always)]
    pub fn width_f64(self) -> f64 {
        if self.is_poisoned() {
            return f64::INFINITY;
        }
        let w = self.hi.to_f64() - self.lo.to_f64();
        if w.is_nan() || w.is_infinite() {
            return f64::INFINITY;
        }
        w.next_float()
    }

    /// True when `v` lies inside the interval (poisoned intervals and
    /// NaN probes contain nothing).
    #[inline(always)]
    pub fn contains(self, v: E) -> bool {
        if self.is_poisoned() || v.is_nan() {
            return false;
        }
        self.lo <= v && v <= self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    fn iv(lo: f32, hi: f32) -> Interval<f32> {
        Interval { lo, hi }
    }

    #[test]
    fn point_and_hull_orient_endpoints() {
        let p = Interval::point(2.5f32);
        assert_eq!((p.lo, p.hi), (2.5, 2.5));
        let h = Interval::hull(3.0f32, -1.0);
        assert_eq!((h.lo, h.hi), (-1.0, 3.0));
        assert!(Interval::point(f32::NAN).is_poisoned());
        assert!(Interval::hull(1.0f32, f32::NAN).is_poisoned());
    }

    #[test]
    fn ops_contain_sampled_rne_results_f32() {
        // Random operand intervals; every sampled endpoint-combination
        // evaluation must land inside the op's result interval.
        let mut rng = Rng::new(0xCE27);
        for _ in 0..2000 {
            let mk = |rng: &mut Rng| {
                let a = (rng.f64() - 0.5) as f32 * 8.0;
                let b = a + rng.f64() as f32 * 0.25;
                Interval::hull(a, b)
            };
            let x = mk(&mut rng);
            let y = mk(&mut rng);
            let sum = x.add(y);
            let dif = x.sub(y);
            let prd = x.mul(y);
            for &xa in &[x.lo, x.hi] {
                for &ya in &[y.lo, y.hi] {
                    assert!(sum.contains(xa + ya), "{xa} + {ya} vs {sum:?}");
                    assert!(dif.contains(xa - ya), "{xa} - {ya} vs {dif:?}");
                    assert!(prd.contains(xa * ya), "{xa} * {ya} vs {prd:?}");
                }
            }
            let r = x.relu();
            let clamped = if x.hi > 0.0 { x.hi } else { 0.0 };
            assert!(r.contains(clamped));
            assert!(r.lo >= 0.0);
        }
    }

    #[test]
    fn mad_matches_mul_then_add_composition() {
        let a = iv(1.25, 1.5);
        let b = iv(-2.0, 0.5);
        let c = iv(0.125, 0.25);
        assert_eq!(a.mad(b, c), a.mul(b).add(c));
    }

    #[test]
    fn nan_poisoning_propagates_and_fails_closed() {
        let p: Interval<f32> = Interval::poison();
        let x = iv(1.0, 2.0);
        assert!(p.add(x).is_poisoned());
        assert!(x.mul(p).is_poisoned());
        assert!(p.relu().is_poisoned());
        assert!(!p.contains(1.5));
        assert_eq!(p.width_f64(), f64::INFINITY);
        // inf − inf inside an op poisons too.
        let inf = iv(f32::INFINITY, f32::INFINITY);
        let ninf = iv(f32::NEG_INFINITY, f32::NEG_INFINITY);
        assert!(inf.add(ninf).is_poisoned());
        // 0 × inf poisons.
        assert!(iv(0.0, 0.0).mul(inf).is_poisoned());
        // Unbounded (but not poisoned) intervals report infinite width.
        assert_eq!(iv(0.0, f32::INFINITY).width_f64(), f64::INFINITY);
    }

    #[test]
    fn width_upper_bounds_endpoint_gap_both_widths() {
        let x = iv(1.0, 1.0 + 2.0 * f32::EPSILON);
        let w = x.width_f64();
        assert!(w >= (x.hi as f64 - x.lo as f64) && w.is_finite());
        let y: Interval<f64> = Interval { lo: -1.0, hi: -1.0 + 1e-12 };
        assert!(y.width_f64() >= 1e-12 - 1e-27);
        assert_eq!(Interval::point(4.0f64).width_f64(), f64::from_bits(1));
    }
}
