//! `positron` — leader binary: CLI over the codec zoo, the gate-level PPA
//! tables, the accuracy analysis, and the batching inference demo.

use positron::cli::{self, Command};
use positron::coordinator::{InferenceServer, ServerConfig};
use positron::runtime::{artifacts_available, ModelWeights, Runtime};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = match cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{}", cli::HELP);
            std::process::exit(2);
        }
    };
    if let Err(e) = run(cmd) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(cmd: Command) -> positron::error::Result<()> {
    match cmd {
        Command::Help => println!("{}", cli::HELP),
        Command::Info => {
            println!("positron — b-posit ⟨n,6,5⟩ reproduction");
            println!("formats: p8 p16 p32 p64 bp16 bp32 bp64 bp16e3 f16 bf16 f32 f64 t16 t32 t64");
            println!(
                "runtime: {}",
                if positron::runtime::runtime_enabled() {
                    "enabled (PJRT/XLA)"
                } else {
                    "disabled (build with --features runtime)"
                }
            );
            let dir = positron::runtime::default_artifact_dir();
            println!(
                "artifacts: {} ({})",
                dir.display(),
                if artifacts_available(&dir) {
                    "present"
                } else {
                    "missing — run `make artifacts`"
                }
            );
        }
        Command::Codec { fmt, values } => {
            for line in cli::run_codec(&fmt, &values).map_err(positron::error::Error::msg)? {
                println!("{line}");
            }
        }
        Command::Accuracy { csv_dir } => {
            let lines = cli::run_accuracy(csv_dir.as_deref());
            for line in lines.map_err(positron::error::Error::msg)? {
                println!("{line}");
            }
        }
        Command::Tables => {
            for table in cli::run_tables() {
                println!("{table}");
            }
        }
        Command::VectorBench { len, bits, json } => {
            let lines = if bits == 64 {
                cli::run_vector_bench64(len, json.as_deref())
            } else {
                cli::run_vector_bench(len, json.as_deref())
            };
            for line in lines.map_err(positron::error::Error::msg)? {
                println!("{line}");
            }
        }
        Command::GemmBench { sizes, quire_max, json } => {
            for line in cli::run_gemm_bench(&sizes, quire_max, json.as_deref())
                .map_err(positron::error::Error::msg)?
            {
                println!("{line}");
            }
        }
        Command::Serve { requests, artifact_dir } => {
            let rt = Runtime::cpu(&artifact_dir)?;
            println!("platform: {}", rt.platform());
            let weights = ModelWeights::load(&rt)?;
            drop(rt); // the server worker owns its own PJRT client
            let server =
                InferenceServer::start(artifact_dir.clone().into(), ServerConfig::default())?;
            let d = weights.d;
            let n_gold = weights.golden_y.len();
            let t0 = std::time::Instant::now();
            let mut correct = 0usize;
            for i in 0..requests {
                let g = i % n_gold;
                let feats = weights.golden_x[g * d..(g + 1) * d].to_vec();
                let resp = server.infer(feats)?;
                let argmax = resp
                    .logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if argmax == weights.golden_y[g] as usize {
                    correct += 1;
                }
            }
            let wall = t0.elapsed();
            let m = server.metrics().snapshot();
            println!(
                "served {requests} requests in {:.2}s ({:.0} req/s), accuracy {:.1}%",
                wall.as_secs_f64(),
                requests as f64 / wall.as_secs_f64(),
                100.0 * correct as f64 / requests as f64
            );
            println!(
                "latency p50 {} µs  p99 {} µs  max {} µs; {} batches, mean batch {:.1}, {} rejected",
                m.p50_us, m.p99_us, m.max_us, m.batches, m.mean_batch, m.rejected
            );
            println!(
                "codec {:.1} µs/batch, execute {:.1} µs/batch (codec share {:.2}%)",
                m.codec_ns_per_batch() / 1e3,
                m.execute_ns_per_batch() / 1e3,
                100.0 * m.codec_ns as f64 / (m.codec_ns + m.execute_ns).max(1) as f64
            );
            println!("--- /metrics ---\n{}", m.render());
        }
    }
    Ok(())
}
