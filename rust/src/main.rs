//! `positron` — leader binary: CLI over the codec zoo, the gate-level PPA
//! tables, the accuracy analysis, and the inference server (native
//! blocked-GEMM backend by default, PJRT opt-in, real HTTP listener).

use std::sync::Arc;
use std::time::Duration;

use positron::cli::{self, Command, ServeOpts};
use positron::coordinator::{backend, http, InferenceServer, ModelRegistry, ServerConfig};
use positron::runtime::{artifacts_available, ModelWeights};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = match cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{}", cli::HELP);
            std::process::exit(2);
        }
    };
    if let Err(e) = run(cmd) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(cmd: Command) -> positron::error::Result<()> {
    match cmd {
        Command::Help => println!("{}", cli::HELP),
        Command::Info => {
            println!("positron — b-posit ⟨n,6,5⟩ reproduction");
            println!("formats: p8 p16 p32 p64 bp16 bp32 bp64 bp16e3 f16 bf16 f32 f64 t16 t32 t64");
            println!(
                "runtime: {}",
                if positron::runtime::runtime_enabled() {
                    "enabled (PJRT/XLA)"
                } else {
                    "disabled (build with --features runtime)"
                }
            );
            let dir = positron::runtime::default_artifact_dir();
            println!(
                "artifacts: {} ({})",
                dir.display(),
                if artifacts_available(&dir) {
                    "present"
                } else {
                    "missing — run `make artifacts`"
                }
            );
        }
        Command::Codec { fmt, values } => {
            for line in cli::run_codec(&fmt, &values).map_err(positron::error::Error::msg)? {
                println!("{line}");
            }
        }
        Command::Accuracy { csv_dir } => {
            let lines = cli::run_accuracy(csv_dir.as_deref());
            for line in lines.map_err(positron::error::Error::msg)? {
                println!("{line}");
            }
        }
        Command::Tables => {
            for table in cli::run_tables() {
                println!("{table}");
            }
        }
        Command::VectorBench { len, bits, json } => {
            let lines = if bits == 64 {
                cli::run_vector_bench64(len, json.as_deref())
            } else {
                cli::run_vector_bench(len, json.as_deref())
            };
            for line in lines.map_err(positron::error::Error::msg)? {
                println!("{line}");
            }
        }
        Command::GemmBench { sizes, quire_max, json } => {
            for line in cli::run_gemm_bench(&sizes, quire_max, json.as_deref())
                .map_err(positron::error::Error::msg)?
            {
                println!("{line}");
            }
        }
        Command::SolverBench(o) => {
            for line in cli::run_solver_bench(&o).map_err(positron::error::Error::msg)? {
                println!("{line}");
            }
        }
        Command::Serve(o) => serve(o)?,
        Command::ServeBench(o) => {
            for line in cli::run_serve_bench(&o).map_err(positron::error::Error::msg)? {
                println!("{line}");
            }
        }
        Command::CertifyBench(o) => {
            for line in cli::run_certify_bench(&o).map_err(positron::error::Error::msg)? {
                println!("{line}");
            }
        }
    }
    Ok(())
}

fn serve(o: ServeOpts) -> positron::error::Result<()> {
    let tier_cfg = |format: backend::WeightFormat| {
        let mut b = ServerConfig::builder()
            .backend(o.backend)
            .format(format)
            .tracing(o.tracing)
            .certify_rate(o.certify_rate);
        if let Some(ms) = o.deadline_ms {
            b = b.deadline(Duration::from_millis(ms));
        }
        if let Some(n) = o.max_inflight {
            b = b.max_inflight(n);
        }
        b.build()
    };
    let weights = if o.synthetic {
        backend::synth_weights(64, 128, 16, 64, 0x5eed)
    } else {
        ModelWeights::load_from_dir(&o.artifact_dir)?
    };

    // Multi-model: one event-driven listener fronts every tier in
    // --models over the same weights (the content-hash weight cache
    // dedups the per-format encodes across restarts).
    if !o.models.is_empty() {
        let addr = o.http.as_deref().unwrap_or("127.0.0.1:8080");
        let mut reg = ModelRegistry::new(o.tracing);
        for fmt in &o.models {
            reg.register_native(fmt.name(), weights.clone(), tier_cfg(*fmt)?)?;
        }
        let reg = Arc::new(reg);
        let names: Vec<String> =
            reg.entries().iter().map(|e| e.name().to_string()).collect();
        let listener = http::serve_registry(addr, reg)?;
        println!(
            "serving tiers [{}] on http://{} — POST /v1/infer/<model>, GET /v1/models, \
             POST /infer (default {}), GET /metrics, /healthz, /debug/tracez \
             (Ctrl-C to stop)",
            names.join(", "),
            listener.local_addr(),
            names[0]
        );
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }

    let cfg = tier_cfg(o.format)?;
    let server = if o.synthetic {
        InferenceServer::start_native(weights.clone(), cfg)?
    } else {
        InferenceServer::start(o.artifact_dir.clone().into(), cfg)?
    };
    let server = Arc::new(server);
    println!(
        "serving {} ({} backend, {} weights, d={} c={})",
        if o.synthetic { "synthetic model" } else { o.artifact_dir.as_str() },
        o.backend.name(),
        o.format.name(),
        server.dims.0,
        server.dims.1
    );
    if let Some(addr) = &o.http {
        let listener = http::serve(addr, server.clone())?;
        println!(
            "listening on http://{} — POST /v1/infer/{}, GET /v1/models, POST /infer \
             {{\"features\":[…]}}, GET /metrics, /healthz, /debug/tracez (Ctrl-C to stop)",
            listener.local_addr(),
            o.format.name()
        );
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    // Self-driving demo loop over the golden batch.
    let d = weights.d;
    let n_gold = weights.golden_y.len().max(1);
    let t0 = std::time::Instant::now();
    let mut correct = 0usize;
    for i in 0..o.requests {
        let g = i % n_gold;
        let feats = weights.golden_x[g * d..(g + 1) * d].to_vec();
        let resp = server.infer(feats)?;
        let argmax = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == weights.golden_y[g] as usize {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    let m = server.metrics().snapshot();
    println!(
        "served {} requests in {:.2}s ({:.0} req/s), accuracy {:.1}%",
        o.requests,
        wall.as_secs_f64(),
        o.requests as f64 / wall.as_secs_f64(),
        100.0 * correct as f64 / o.requests.max(1) as f64
    );
    println!(
        "latency p50 {} µs  p99 {} µs  max {} µs; {} batches, mean batch {:.1}, {} rejected",
        m.p50_us, m.p99_us, m.max_us, m.batches, m.mean_batch, m.rejected
    );
    println!(
        "codec {:.1} µs/batch, execute {:.1} µs/batch (codec share {:.2}%)",
        m.codec_ns_per_batch() / 1e3,
        m.execute_ns_per_batch() / 1e3,
        100.0 * m.codec_ns as f64 / (m.codec_ns + m.execute_ns).max(1) as f64
    );
    println!("--- /metrics ---\n{}", m.render());
    Ok(())
}
