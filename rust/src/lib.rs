//! # positron
//!
//! Reproduction of *"Closing the Gap Between Float and Posit Hardware
//! Efficiency"* (Jonnalagadda, Thotli, Gustafson): the **b-posit** bounded-
//! regime posit format, its decode/encode hardware, and a three-layer
//! Rust + JAX + Pallas stack that serves b-posit-quantized models.
//!
//! Layer map (see DESIGN.md):
//! - [`formats`] — the numeric-format zoo: IEEE floats, standard posits,
//!   b-posits, takums, the 800-bit quire, and exact shared arithmetic.
//! - [`vector`] — the serving hot path's data plane, organized around a
//!   **width-generic lane API** (`vector::lane`): the `LaneElem` trait
//!   (f32 ↔ u32/u64 words, f64 ↔ u64/u128 intermediates) carries the
//!   branch-free batched codec — one macro-expanded datapath for both
//!   widths, the software mirror of the paper's claim that the bounded
//!   regime makes decode/encode structurally identical across widths —
//!   plus the generic `LaneCodec<E>` engine, the spec-carrying
//!   `EncodedTensor<E>` weight buffer, one generic dot/axpy/gemv and
//!   register/L1-blocked GEMM family (fast + quire-exact +
//!   quantized-weight paths), and a zero-dependency scoped fork-join
//!   pool (`PALLAS_THREADS`) whose generic `par_*` family shards codecs
//!   and row-blocked kernels across cores with bit-identical results.
//!   The named BP32/P32/BP64/P64 fast paths are monomorphized spec
//!   constants over the same engine (see docs/API.md for the migration
//!   table).
//!   The sparse side ([`vector::sparse`]) carries a CSR type and SpMV in
//!   the same three kernel flavors, bit-identical to the dense gemv on
//!   densified matrices.
//! - [`solver`] — tiered iterative solvers (CG + Jacobi-preconditioned
//!   CG over the sparse layer) with per-iteration exact residual
//!   trajectories: the f32/bp32/quire32/f64/bp64/quire64 accumulation
//!   tiers made comparable on one operator (see docs/SOLVERS.md and
//!   `positron solver-bench`).
//! - [`certify`] — interval-arithmetic error certification: directed-
//!   rounding `Interval<E>` ops (outward `next_float`/`prev_float`
//!   steps, NaN-poisoning) and an interval twin of the serving forward
//!   pass producing per-logit certified error bounds, sampled 1-in-N in
//!   production (see docs/CERTIFY.md and `positron certify-bench`).
//! - [`hw`] — gate-level substrate (cell library, netlists, logic sim, STA,
//!   power) and the six decoder/encoder circuits of Figs 8–13.
//! - [`accuracy`] — decimal-accuracy curves, Golden Zone and fovea analysis
//!   (Figs 6/7).
//! - [`runtime`] — PJRT loader/executor for the AOT-compiled JAX artifacts
//!   (behind the `runtime` cargo feature; a stub with a clear "disabled"
//!   error path otherwise, so offline builds need no libxla) plus the
//!   artifact-file loaders (`ModelWeights::load_from_dir` reads
//!   `weights.json` with no runtime at all).
//! - [`coordinator`] — the L3 serving stack: pluggable execution backends
//!   behind the `InferenceBackend` trait (the default **native** backend
//!   runs dense layers on the blocked quantized-weight GEMM, weights
//!   encoded once via a content-hash cache; PJRT is the feature-gated
//!   alternative), the batching worker (backpressure, per-request
//!   deadlines, explicit batch-failure answers), a zero-dependency
//!   event-driven HTTP/1.1 listener (epoll/`poll(2)` readiness loop,
//!   keep-alive + pipelining, admission control, multi-model routing:
//!   `POST /v1/infer/<model>`, `GET /v1/models`, `GET /metrics`,
//!   `GET /debug/tracez` — see docs/HTTP_API.md),
//!   quantization through the vector codec with buffer reuse, and a
//!   zero-dependency observability layer: per-request trace spans with
//!   staged nanosecond timings, power-of-2 log-bucketed latency/queue/
//!   codec/execute histograms alongside the bounded-reservoir quantiles,
//!   and HTTP connection/response counters (see docs/OBSERVABILITY.md).
//! - [`harness`] — self-contained benchmark harness (criterion-style) with
//!   JSON emission for `BENCH_*.json` artifacts.
//! - [`json`] — minimal total JSON parser (untrusted HTTP bodies +
//!   build-time artifacts; recursion capped at `json::MAX_DEPTH`, never
//!   panics — see `tests/json_corpus.rs`).
//! - [`error`] — in-tree anyhow-style error type (offline dependency set).
//! - [`testutil`] — PRNG + property-testing utilities used across tests.
//!
//! Static invariants — panic-freedom on the serving path ([`json`],
//! [`coordinator`]), bit-determinism in the kernel zones ([`vector`],
//! [`solver`], [`formats`]), unsafe/atomic hygiene everywhere — are
//! enforced by `tools/pallas_lint.py` (a pure-python lexical pass, wired
//! into CI ahead of clippy); rules, zones, and the suppression syntax
//! are catalogued in docs/LINTS.md.

pub mod error;
pub mod formats;
pub mod solver;
pub mod vector;
pub mod certify;
pub mod hw;
pub mod accuracy;
pub mod runtime;
pub mod coordinator;
pub mod harness;
pub mod testutil;
pub mod cli;
pub mod json;
