//! Minimal `anyhow`-style error handling (the offline dependency set has no
//! anyhow crate): a string-backed [`Error`], a [`Result`] alias, the
//! [`anyhow!`] macro, and a [`Context`] extension trait. The API surface
//! mirrors the subset of anyhow the runtime/coordinator layers use, so the
//! code reads identically to the anyhow-based original.

use std::fmt;

/// String-backed error; cheap to construct, formats as its message.
pub struct Error(String);

impl Error {
    /// Build an error from anything stringable (mirror of `anyhow::Error::msg`).
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// `?` conversion from any std error. `Error` itself deliberately does not
// implement `std::error::Error`, which keeps this blanket impl coherent
// (the same trick anyhow uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// Result alias defaulting the error type (mirror of `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string (mirror of `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

pub use crate::anyhow;

/// Attach context to an error (mirror of `anyhow::Context`).
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_debug_show_message() {
        let e = anyhow!("bad thing {}", 42);
        assert_eq!(e.to_string(), "bad thing 42");
        assert_eq!(format!("{e:?}"), "bad thing 42");
        assert_eq!(format!("{e:#}"), "bad thing 42"); // alternate flag tolerated
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r2: std::result::Result<(), String> = Err("inner".into());
        let e2 = r2.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e2}"), "outer 1: inner");
    }
}
