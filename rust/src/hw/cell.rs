//! Standard-cell library for the gate-level cost model.
//!
//! Values are calibrated to a NanGate/FreePDK 45 nm-class open cell library
//! (typical corner): areas in µm², intrinsic delays in ns, per-transition
//! switching energies in fJ, and a linear fanout delay slope. The paper's
//! PPA numbers come from post-layout synthesis on freepdk45; this model
//! reproduces the *structural* cost differences between the decoder/encoder
//! architectures (gate count, logic depth, data-dependent switching), which
//! is what drives the paper's comparisons (see DESIGN.md §Hardware cost
//! model calibration).

/// Gate/cell types available to netlists.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CellKind {
    /// Constant 0 driver (zero cost; folded at analysis time).
    Const0,
    /// Constant 1 driver (zero cost).
    Const1,
    /// Buffer (used by the fanout-buffering pass).
    Buf,
    Inv,
    Nand2,
    Nor2,
    And2,
    Or2,
    Xor2,
    Xnor2,
    /// 2:1 multiplexer: out = s ? b : a.
    Mux2,
    /// AND-OR-INVERT 2-1: out = !((a & b) | c).
    Aoi21,
    /// OR-AND-INVERT 2-1: out = !((a | b) & c).
    Oai21,
}

/// Physical parameters of one cell.
#[derive(Clone, Copy, Debug)]
pub struct CellParams {
    /// Cell area in µm².
    pub area: f64,
    /// Intrinsic pin-to-pin delay in ns (worst arc, typical corner).
    pub delay: f64,
    /// Additional delay per fanout load in ns.
    pub load_slope: f64,
    /// Switching energy per output transition in fJ (internal + average
    /// output load).
    pub energy: f64,
}

impl CellKind {
    /// Library parameters (NanGate45-class, typical corner).
    pub fn params(self) -> CellParams {
        use CellKind::*;
        match self {
            Const0 | Const1 => CellParams { area: 0.0, delay: 0.0, load_slope: 0.0, energy: 0.0 },
            Buf => CellParams { area: 0.798, delay: 0.022, load_slope: 0.0030, energy: 0.9 },
            Inv => CellParams { area: 0.532, delay: 0.010, load_slope: 0.0036, energy: 0.45 },
            Nand2 => CellParams { area: 0.798, delay: 0.014, load_slope: 0.0042, energy: 0.60 },
            Nor2 => CellParams { area: 0.798, delay: 0.018, load_slope: 0.0048, energy: 0.62 },
            And2 => CellParams { area: 1.064, delay: 0.024, load_slope: 0.0040, energy: 0.85 },
            Or2 => CellParams { area: 1.064, delay: 0.026, load_slope: 0.0042, energy: 0.88 },
            Xor2 => CellParams { area: 1.596, delay: 0.032, load_slope: 0.0050, energy: 1.55 },
            Xnor2 => CellParams { area: 1.596, delay: 0.032, load_slope: 0.0050, energy: 1.55 },
            Mux2 => CellParams { area: 1.862, delay: 0.030, load_slope: 0.0044, energy: 1.25 },
            Aoi21 => CellParams { area: 1.064, delay: 0.020, load_slope: 0.0046, energy: 0.72 },
            Oai21 => CellParams { area: 1.064, delay: 0.020, load_slope: 0.0046, energy: 0.72 },
        }
    }

    /// Number of input pins.
    pub fn arity(self) -> usize {
        use CellKind::*;
        match self {
            Const0 | Const1 => 0,
            Buf | Inv => 1,
            Nand2 | Nor2 | And2 | Or2 | Xor2 | Xnor2 => 2,
            Mux2 | Aoi21 | Oai21 => 3,
        }
    }

    /// Combinational function. `ins` must hold `arity()` values; for Mux2
    /// the order is (s, a, b) → s ? b : a; for AOI/OAI it is (a, b, c).
    pub fn eval(self, ins: &[bool]) -> bool {
        use CellKind::*;
        match self {
            Const0 => false,
            Const1 => true,
            Buf => ins[0],
            Inv => !ins[0],
            Nand2 => !(ins[0] & ins[1]),
            Nor2 => !(ins[0] | ins[1]),
            And2 => ins[0] & ins[1],
            Or2 => ins[0] | ins[1],
            Xor2 => ins[0] ^ ins[1],
            Xnor2 => !(ins[0] ^ ins[1]),
            Mux2 => {
                if ins[0] { ins[2] } else { ins[1] }
            }
            Aoi21 => !((ins[0] & ins[1]) | ins[2]),
            Oai21 => !((ins[0] | ins[1]) & ins[2]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truth_tables() {
        use CellKind::*;
        let f = false;
        let t = true;
        assert!(!Const0.eval(&[]));
        assert!(Const1.eval(&[]));
        assert!(Inv.eval(&[f]));
        assert!(!Inv.eval(&[t]));
        assert!(Nand2.eval(&[t, f]));
        assert!(!Nand2.eval(&[t, t]));
        assert!(Nor2.eval(&[f, f]));
        assert!(!Nor2.eval(&[t, f]));
        assert_eq!(Xor2.eval(&[t, t]), false);
        assert_eq!(Xnor2.eval(&[t, t]), true);
        // Mux2: (s, a, b) → s ? b : a
        assert_eq!(Mux2.eval(&[f, t, f]), true);
        assert_eq!(Mux2.eval(&[t, t, f]), false);
        assert_eq!(Aoi21.eval(&[t, t, f]), false);
        assert_eq!(Aoi21.eval(&[f, t, f]), true);
        assert_eq!(Oai21.eval(&[f, f, t]), true);
        assert_eq!(Oai21.eval(&[t, f, t]), false);
    }

    #[test]
    fn params_sane() {
        use CellKind::*;
        for k in [Buf, Inv, Nand2, Nor2, And2, Or2, Xor2, Xnor2, Mux2, Aoi21, Oai21] {
            let p = k.params();
            assert!(p.area > 0.0 && p.delay > 0.0 && p.energy > 0.0);
            assert_eq!(k.arity() > 0, true);
        }
        // XOR must cost more than NAND (drives the posit-vs-float story).
        assert!(Xor2.params().area > Nand2.params().area);
        assert!(Xor2.params().energy > Nand2.params().energy);
    }
}
