//! Static timing analysis: worst-case arrival times over the levelized
//! netlist with a linear load model (intrinsic delay + slope × fanout).

use super::netlist::Netlist;

/// Timing report for one netlist.
#[derive(Clone, Debug)]
pub struct TimingReport {
    /// Critical-path delay in ns.
    pub critical_ns: f64,
    /// Arrival time per net (ns).
    pub arrival: Vec<f64>,
    /// Gate indices along the critical path, input-side first.
    pub critical_path: Vec<usize>,
}

/// Compute worst-case arrival times. Primary inputs arrive at t=0.
pub fn analyze(nl: &Netlist) -> TimingReport {
    let n = nl.n_nets() as usize;
    let fanouts = nl.fanouts();
    let mut arrival = vec![0.0f64; n];
    let mut from_gate: Vec<Option<usize>> = vec![None; n];
    for (gi, g) in nl.gates.iter().enumerate() {
        let a = g.kind.arity();
        let mut worst = 0.0f64;
        for i in 0..a {
            worst = worst.max(arrival[g.ins[i] as usize]);
        }
        let p = g.kind.params();
        let d = p.delay + p.load_slope * fanouts[g.out as usize] as f64;
        arrival[g.out as usize] = worst + d;
        from_gate[g.out as usize] = Some(gi);
    }
    // Critical endpoint: the worst arrival among declared outputs (fall back
    // to any net if no outputs are declared).
    let mut end_net: Option<u32> = None;
    let mut worst = -1.0;
    for (_, bus) in &nl.output_buses {
        for &net in bus {
            if arrival[net as usize] > worst {
                worst = arrival[net as usize];
                end_net = Some(net);
            }
        }
    }
    if end_net.is_none() {
        for net in 0..n {
            if arrival[net] > worst {
                worst = arrival[net];
                end_net = Some(net as u32);
            }
        }
    }
    // Trace back the critical path.
    let mut path = Vec::new();
    let mut cur = end_net;
    while let Some(net) = cur {
        let Some(gi) = from_gate[net as usize] else { break };
        path.push(gi);
        let g = &nl.gates[gi];
        let a = g.kind.arity();
        let mut best: Option<u32> = None;
        let mut best_t = -1.0;
        for i in 0..a {
            let t = arrival[g.ins[i] as usize];
            if t > best_t {
                best_t = t;
                best = Some(g.ins[i]);
            }
        }
        cur = if best_t > 0.0 { best } else { None };
    }
    path.reverse();
    TimingReport { critical_ns: worst.max(0.0), arrival, critical_path: path }
}

/// Logic depth (gate count) along the critical path.
pub fn logic_depth(nl: &Netlist) -> usize {
    analyze(nl).critical_path.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::netlist::Netlist;

    #[test]
    fn chain_delay_adds_up() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 1)[0];
        let mut x = a;
        for _ in 0..10 {
            x = nl.not(x);
        }
        nl.output_bus("y", &[x]);
        let rep = analyze(&nl);
        // 10 inverters; each ~0.010 + slope·1 ≈ 0.0136 ns
        assert!(rep.critical_ns > 0.10 && rep.critical_ns < 0.20, "got {}", rep.critical_ns);
        assert_eq!(rep.critical_path.len(), 10);
    }

    #[test]
    fn parallel_beats_serial() {
        // OR-reduction: a balanced tree must be faster than a linear chain.
        let build = |balanced: bool| {
            let mut nl = Netlist::new();
            let a = nl.input_bus("a", 32);
            let out = if balanced {
                let mut level = a.clone();
                while level.len() > 1 {
                    let mut next = Vec::new();
                    for pair in level.chunks(2) {
                        next.push(if pair.len() == 2 { nl.or2(pair[0], pair[1]) } else { pair[0] });
                    }
                    level = next;
                }
                level[0]
            } else {
                let mut acc = a[0];
                for &x in &a[1..] {
                    acc = nl.or2(acc, x);
                }
                acc
            };
            nl.output_bus("y", &[out]);
            analyze(&nl).critical_ns
        };
        let tree = build(true);
        let chain = build(false);
        assert!(tree < chain / 3.0, "tree {tree} vs chain {chain}");
    }

    #[test]
    fn fanout_increases_delay() {
        let mk = |fan: usize| {
            let mut nl = Netlist::new();
            let a = nl.input_bus("a", 1)[0];
            let x = nl.not(a);
            let sinks: Vec<_> = (0..fan).map(|_| nl.not(x)).collect();
            nl.output_bus("y", &sinks);
            analyze(&nl).critical_ns
        };
        assert!(mk(16) > mk(1));
    }
}
