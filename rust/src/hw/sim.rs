//! Netlist simulation: zero-delay functional evaluation (for verification)
//! and a discrete-time timing simulation that counts every transition,
//! including glitches (for the power model — deep sequential logic like the
//! standard-posit decoder's LZC→shifter chain glitches far more than the
//! b-posit's parallel mux tree, and the paper's "peak power" is exactly
//! this data-dependent switching at its worst).

use super::cell::CellKind;
use super::netlist::{Netlist, NetId};

/// Assign bus values by name and evaluate; returns (name, value) for every
/// output bus. Bus values are little-endian u64s.
pub fn eval(nl: &Netlist, inputs: &[(&str, u64)]) -> Vec<(String, u64)> {
    let vals = eval_nets(nl, inputs);
    nl.output_buses
        .iter()
        .map(|(name, bus)| (name.clone(), bus_value(bus, &vals)))
        .collect()
}

/// Evaluate and return the full net-value vector.
pub fn eval_nets(nl: &Netlist, inputs: &[(&str, u64)]) -> Vec<bool> {
    let mut vals = vec![false; nl.n_nets() as usize];
    for (name, v) in inputs {
        let bus = nl.input(name);
        assert!(bus.len() <= 64, "bus {name} too wide");
        for (i, &net) in bus.iter().enumerate() {
            vals[net as usize] = (v >> i) & 1 == 1;
        }
    }
    let mut ins_buf = [false; 3];
    for g in &nl.gates {
        let a = g.kind.arity();
        for i in 0..a {
            ins_buf[i] = vals[g.ins[i] as usize];
        }
        vals[g.out as usize] = g.kind.eval(&ins_buf[..a]);
    }
    vals
}

/// Read a bus value out of a net-value vector.
pub fn bus_value(bus: &[NetId], vals: &[bool]) -> u64 {
    let mut v = 0u64;
    for (i, &net) in bus.iter().enumerate() {
        if vals[net as usize] {
            v |= 1u64 << i;
        }
    }
    v
}

/// Result of a timing simulation of one input transition.
#[derive(Clone, Debug)]
pub struct TransitionReport {
    /// Total number of output transitions observed (including glitches).
    pub transitions: u64,
    /// Total switched energy in fJ (Σ transitions × cell energy).
    pub energy_fj: f64,
}

/// Timing simulation: apply `from` inputs until stable, then switch to
/// `to` inputs and count every gate-output transition (glitches included)
/// until the network settles. Gate delays are quantized to 1 ps ticks.
pub fn simulate_transition(
    nl: &Netlist,
    from: &[(&str, u64)],
    to: &[(&str, u64)],
) -> TransitionReport {
    let n = nl.n_nets() as usize;
    let stable = eval_nets(nl, from);
    let mut vals = stable;

    // Per-gate integer delay in picoseconds.
    let fanouts = nl.fanouts();
    let delay_ps: Vec<u64> = nl
        .gates
        .iter()
        .map(|g| {
            let p = g.kind.params();
            let d = p.delay + p.load_slope * fanouts[g.out as usize] as f64;
            (d * 1000.0).round().max(1.0) as u64
        })
        .collect();

    // driver gate index per net
    let mut driver: Vec<Option<usize>> = vec![None; n];
    for (gi, g) in nl.gates.iter().enumerate() {
        driver[g.out as usize] = Some(gi);
    }
    // sinks per net
    let mut sinks: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (gi, g) in nl.gates.iter().enumerate() {
        for i in 0..g.kind.arity() {
            sinks[g.ins[i] as usize].push(gi);
        }
    }

    // Event wheel keyed by time: (time, net, value).
    use std::collections::BinaryHeap;
    use std::cmp::Reverse;
    let mut heap: BinaryHeap<Reverse<(u64, u32, bool)>> = BinaryHeap::new();

    // Apply the new primary-input values at t=0.
    for (name, v) in to {
        let bus = nl.input(name);
        for (i, &net) in bus.iter().enumerate() {
            let nv = (v >> i) & 1 == 1;
            if vals[net as usize] != nv {
                heap.push(Reverse((0, net, nv)));
            }
        }
    }

    let mut transitions = 0u64;
    let mut energy = 0.0f64;
    let mut ins_buf = [false; 3];
    let mut guard = 0u64;
    while let Some(Reverse((t, net, nv))) = heap.pop() {
        guard += 1;
        assert!(guard < 100_000_000, "timing sim did not settle (oscillation?)");
        if vals[net as usize] == nv {
            continue;
        }
        vals[net as usize] = nv;
        if driver[net as usize].is_some() {
            // A gate output switched: count it.
            let gi = driver[net as usize].unwrap();
            transitions += 1;
            energy += nl.gates[gi].kind.params().energy;
        }
        for &gi in &sinks[net as usize] {
            let g = &nl.gates[gi];
            let a = g.kind.arity();
            for i in 0..a {
                ins_buf[i] = vals[g.ins[i] as usize];
            }
            let out = g.kind.eval(&ins_buf[..a]);
            // Schedule the new value after the gate delay. Posting even
            // when equal to the *current* value is required for glitch
            // cancellation modeling; we use a simple inertial filter: only
            // post when different from the currently scheduled steady state.
            heap.push(Reverse((t + delay_ps[gi], g.out, out)));
        }
    }
    TransitionReport { transitions, energy_fj: energy }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::netlist::Netlist;

    fn adder1() -> Netlist {
        // full adder: sum = a^b^cin, cout = ab + cin(a^b)
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 1)[0];
        let b = nl.input_bus("b", 1)[0];
        let c = nl.input_bus("cin", 1)[0];
        let axb = nl.xor2(a, b);
        let sum = nl.xor2(axb, c);
        let ab = nl.and2(a, b);
        let cx = nl.and2(axb, c);
        let cout = nl.or2(ab, cx);
        nl.output_bus("sum", &[sum]);
        nl.output_bus("cout", &[cout]);
        nl
    }

    #[test]
    fn full_adder_truth_table() {
        let nl = adder1();
        for a in 0..2u64 {
            for b in 0..2u64 {
                for c in 0..2u64 {
                    let outs = eval(&nl, &[("a", a), ("b", b), ("cin", c)]);
                    let sum = outs.iter().find(|(n, _)| n == "sum").unwrap().1;
                    let cout = outs.iter().find(|(n, _)| n == "cout").unwrap().1;
                    assert_eq!(sum, (a + b + c) & 1);
                    assert_eq!(cout, (a + b + c) >> 1);
                }
            }
        }
    }

    #[test]
    fn transition_counting() {
        let nl = adder1();
        // 0,0,0 → 1,1,1 switches everything.
        let rep = simulate_transition(
            &nl,
            &[("a", 0), ("b", 0), ("cin", 0)],
            &[("a", 1), ("b", 1), ("cin", 1)],
        );
        assert!(rep.transitions >= 3, "expected several transitions, got {}", rep.transitions);
        assert!(rep.energy_fj > 0.0);
        // No input change → no transitions.
        let rep0 = simulate_transition(
            &nl,
            &[("a", 1), ("b", 0), ("cin", 0)],
            &[("a", 1), ("b", 0), ("cin", 0)],
        );
        assert_eq!(rep0.transitions, 0);
    }

    #[test]
    fn glitch_visible_in_chain() {
        // x -> INV -> INV -> AND(x, ..): classic hazard; timing sim should
        // see the glitch transitions that zero-delay eval hides.
        let mut nl = Netlist::new();
        let x = nl.input_bus("x", 1)[0];
        let n1 = nl.not(x);
        let n2 = nl.not(n1);
        let n3 = nl.not(n2);
        let y = nl.and2(x, n3); // settles to 0 always, but glitches on 0→1
        nl.output_bus("y", &[y]);
        let outs = eval(&nl, &[("x", 1)]);
        assert_eq!(outs[0].1, 0);
        let rep = simulate_transition(&nl, &[("x", 0)], &[("x", 1)]);
        // y pulses high briefly: the AND output transitions at least twice.
        assert!(rep.transitions >= 4, "glitch not captured: {}", rep.transitions);
    }
}
