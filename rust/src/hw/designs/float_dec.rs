//! IEEE floating-point decoder (paper Fig 8; Berkeley HardFloat's recode
//! stage). Unlike most float "decoders" in the literature, this one pays
//! the full IEEE bill the paper insists on: exception detection AND
//! subnormal normalization (LZC + left shifter — the same components that
//! dominate the standard posit decoder, here only fb bits wide).
//!
//! Outputs (recoded form):
//! - `sign` (1)
//! - `exp` (eb+1, two's complement): the true unbiased exponent of the
//!   (normalized) value; don't-care for zero/inf/NaN.
//! - `sig` (fb+1): significand with explicit hidden bit, normalized so the
//!   MSB is 1 for every nonzero finite value (subnormals are shifted up).
//! - flags `is_nan`, `is_inf`, `is_zero`, `is_sub`.

use crate::formats::IeeeSpec;
use crate::hw::components::{
    and_reduce, barrel_shift_left, const_bus, lzc_msb_first, mux2_bus, nor_reduce, ripple_add,
    ripple_sub,
};
use crate::hw::netlist::{Bus, NetId, Netlist};

/// Build the float decoder netlist for `spec`.
pub fn build(spec: &IeeeSpec) -> Netlist {
    let n = spec.n as usize;
    let eb = spec.eb as usize;
    let fb = spec.fb() as usize;
    let bias = spec.bias() as i64;

    let mut nl = Netlist::new();
    let f = nl.input_bus("f", n as u32);
    let sign = f[n - 1];
    let exp_field: Bus = f[fb..fb + eb].to_vec();
    let frac: Bus = f[..fb].to_vec();

    // Exception detection.
    let exp_zero = nor_reduce(&mut nl, &exp_field);
    let exp_ones = and_reduce(&mut nl, &exp_field);
    let frac_zero = nor_reduce(&mut nl, &frac);
    let frac_nz = nl.not(frac_zero);
    let is_nan = nl.and2(exp_ones, frac_nz);
    let is_inf = nl.and2(exp_ones, frac_zero);
    let is_zero = nl.and2(exp_zero, frac_zero);
    let is_sub = nl.and2(exp_zero, frac_nz);

    // Subnormal normalization: LZC over the fraction, then a left shifter.
    let frac_msb_first: Vec<NetId> = frac.iter().rev().copied().collect();
    let (lz, _) = lzc_msb_first(&mut nl, &frac_msb_first);
    let zero = nl.zero();
    let one = nl.one();
    // fb+1-wide significand path: [frac, 0] shifted left by lz then one
    // more statically (hidden-bit slot).
    let mut frac_ext: Bus = frac.clone();
    frac_ext.push(zero);
    let s1 = barrel_shift_left(&mut nl, &frac_ext, &lz);
    let mut sig_sub: Bus = Vec::with_capacity(fb + 1);
    sig_sub.push(zero);
    sig_sub.extend(&s1[..fb]);
    // Normal significand: hidden 1 on top of the fraction.
    let mut sig_norm: Bus = frac.clone();
    sig_norm.push(one);
    let sig = mux2_bus(&mut nl, is_sub, &sig_norm, &sig_sub);

    // Recoded exponent (eb+1 bits, signed).
    // Normal: exp_field − bias.
    let exp_ext: Bus = {
        let mut e = exp_field.clone();
        e.push(zero);
        e
    };
    let bias_bus = const_bus(&mut nl, bias as u64, eb + 1);
    let (exp_norm, _) = ripple_sub(&mut nl, &exp_ext, &bias_bus);
    // Subnormal: −bias − lz = ¬lz + (1 − bias).
    let mut lz_ext: Bus = lz.clone();
    while lz_ext.len() < eb + 1 {
        lz_ext.push(zero);
    }
    lz_ext.truncate(eb + 1);
    let nlz: Bus = lz_ext.iter().map(|&b| nl.not(b)).collect();
    let c = const_bus(&mut nl, ((1 - bias) as u64) & ((1u64 << (eb + 1)) - 1), eb + 1);
    let (exp_sub, _) = ripple_add(&mut nl, &nlz, &c, zero);
    let exp = mux2_bus(&mut nl, is_sub, &exp_norm, &exp_sub);

    nl.output_bus("sign", &[sign]);
    nl.output_bus("exp", &exp);
    nl.output_bus("sig", &sig);
    nl.output_bus("is_nan", &[is_nan]);
    nl.output_bus("is_inf", &[is_inf]);
    nl.output_bus("is_zero", &[is_zero]);
    nl.output_bus("is_sub", &[is_sub]);
    nl.buffer_high_fanout(12);
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::ieee::{F16, F32, F64};
    use crate::hw::sta;

    #[test]
    fn depth_grows_with_precision() {
        // The subnormal LZC+shifter grows with fb: delay rises from 16→64.
        let d16 = sta::analyze(&build(&F16)).critical_ns;
        let d64 = sta::analyze(&build(&F64)).critical_ns;
        assert!(d64 > d16, "float decode delay should grow: {d16} vs {d64}");
    }

    #[test]
    fn f32_reasonable_size() {
        let nl = build(&F32);
        assert!(nl.gate_count() > 100, "float32 decoder suspiciously small");
        assert!(nl.gate_count() < 2000);
    }
}
