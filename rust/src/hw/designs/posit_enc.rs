//! Standard posit encoder (paper Fig 11, after ref [6]).
//!
//! Magnitude-domain packing followed by a full-width conditional two's
//! complement — the mirror image of the reference decoder:
//!
//! 1. Run length `a` = |r|+(r≥0) via an XOR row and an incrementer (the
//!    "binary adder" of [6]).
//! 2. A binary decoder + log-depth suffix-OR tree builds the thermometer
//!    mask of the top `a` bits; a full-width right barrel shifter places
//!    [terminator ‖ exponent ‖ fraction] below the run; a mux row merges
//!    run and tail.
//! 3. Conditional two's complement of the assembled n-bit word (XOR row +
//!    ripple incrementer) applies the sign.
//!
//! Inputs are magnitude-domain fields: sign, regime r (wr bits, two's
//! complement), exponent (eS bits), fraction (fovea width, magnitude form).

use crate::formats::PositSpec;
use crate::hw::components::{
    barrel_shift_right, binary_decoder, cond_twos_complement, incrementer, suffix_or_tree,
    xor_broadcast,
};
use crate::hw::netlist::{Bus, NetId, Netlist};

use super::{frac_port_width, regime_port_width};

/// Build the standard posit encoder netlist for `spec` (rs = n−1).
pub fn build(spec: &PositSpec) -> Netlist {
    assert!(!spec.is_bounded());
    let n = spec.n as usize;
    let es = spec.es as usize;
    let fw = frac_port_width(spec) as usize;
    let wr = regime_port_width(spec) as usize;

    let mut nl = Netlist::new();
    let sign = nl.input_bus("sign", 1)[0];
    let r_in = nl.input_bus("regime", wr as u32); // magnitude regime value
    let e_in = nl.input_bus("exp", es as u32); // magnitude exponent
    let frac = nl.input_bus("frac", fw as u32); // magnitude fraction

    // 1. Run length a = r ≥ 0 ? r+1 : −r  = (r XOR msb) + 1.
    let msb = r_in[wr - 1];
    let one = nl.one();
    let rx = xor_broadcast(&mut nl, msb, &r_in);
    let (a, _) = incrementer(&mut nl, &rx, one);
    let pol = nl.not(msb); // run of 1s for non-negative regimes

    // 2a. Thermometer mask of the top `a` body bits (decoder + suffix-OR
    //     tree, log depth).
    let oh = binary_decoder(&mut nl, &a, n);
    let ge = suffix_or_tree(&mut nl, &oh); // ge[v] = (a ≥ v)
    let thermo: Vec<NetId> = (0..n - 1).map(|i| ge[n - 1 - i]).collect();

    // 2b. Tail template [¬pol ‖ exp ‖ frac ‖ 0…] left-aligned in n−1 bits,
    //     shifted right by a.
    let npol = nl.not(pol);
    let mut tail_msb_first: Vec<NetId> = Vec::with_capacity(n - 1);
    tail_msb_first.push(npol);
    tail_msb_first.extend(e_in.iter().rev());
    for i in 0..fw {
        tail_msb_first.push(frac[fw - 1 - i]);
    }
    let zero = nl.zero();
    while tail_msb_first.len() < n - 1 {
        tail_msb_first.push(zero);
    }
    let tail: Bus = tail_msb_first.into_iter().rev().collect(); // to LE
    let shifted = barrel_shift_right(&mut nl, &tail, &a);

    // 2c. Merge: run bits where thermo, shifted tail elsewhere.
    let body: Bus = (0..n - 1).map(|i| nl.mux2(thermo[i], shifted[i], pol)).collect();

    // 3. Apply the sign: conditional two's complement of the full word.
    let mut full: Bus = body;
    full.push(zero); // sign slot; 2^n − body sets it for negatives
    let word = cond_twos_complement(&mut nl, sign, &full);

    nl.output_bus("p", &word);
    nl.buffer_high_fanout(12);
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{P16, P32, P64};
    use crate::hw::sta;

    #[test]
    fn depth_grows_with_n() {
        let d16 = sta::logic_depth(&build(&P16));
        let d64 = sta::logic_depth(&build(&P64));
        assert!(d64 > d16, "posit encoder depth must grow: {d16} vs {d64}");
    }

    #[test]
    fn costlier_than_bposit_encoder_at_32() {
        use crate::formats::posit::BP32;
        let p = build(&P32);
        let b = super::super::bposit_enc::build(&BP32);
        assert!(p.area() > b.area());
        assert!(sta::analyze(&p).critical_ns > sta::analyze(&b).critical_ns);
    }
}
