//! Functional verification of the decoder/encoder netlists against golden
//! software models — the substitute for the paper's RTL verification flow.
//!
//! Three layers of checking:
//! 1. **Field equivalence**: netlist outputs == golden field extraction,
//!    for every pattern (exhaustive at 16 bits, sampled + corners at 32/64).
//! 2. **Semantic soundness**: the golden fields reconstruct exactly the
//!    value of [`PositSpec::decode`] via the paper's identity
//!    `T = r_out·2^eS + e_out + exp_cin`, `|sig| = 1 + f_mag` — proving the
//!    field contract itself is right, not just consistently wrong.
//! 3. **Loopback**: decoder fields fed into the encoder reproduce the
//!    original word bit-exactly.

use crate::formats::{IeeeSpec, PositSpec};
use crate::hw::netlist::Netlist;
use crate::hw::sim;

use super::{frac_port_width, regime_port_width};

// ----------------------------------------------------------------------
// Posit-family golden models
// ----------------------------------------------------------------------

/// Golden decoder output fields (see designs/mod.rs for the contract).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PositDecFields {
    pub sign: bool,
    /// r_out as an unsigned wr-bit pattern (two's complement inside).
    pub regime: u64,
    pub exp: u64,
    pub exp_cin: bool,
    pub frac: u64,
    pub chck: bool,
}

/// Golden model dispatch: the b-posit decoder uses the signed-form (XOR
/// shortcut) contract; the standard posit reference decoder ([6]) uses the
/// magnitude contract (full 2's complement up front).
pub fn golden_posit_dec(spec: &PositSpec, word: u64) -> PositDecFields {
    if spec.is_bounded() {
        golden_posit_dec_signed(spec, word)
    } else {
        golden_posit_dec_mag(spec, word)
    }
}

/// Magnitude-contract golden model (standard posit decoder): fields of the
/// two's-complemented magnitude; exp_cin is always 0.
pub fn golden_posit_dec_mag(spec: &PositSpec, word: u64) -> PositDecFields {
    let n = spec.n;
    let word = word & spec.mask();
    let sign = word >> (n - 1) & 1 == 1;
    let chck = word & spec.maxpos_body() == 0;
    let mag = if sign { word.wrapping_neg() & spec.mask() } else { word };
    // Decode the magnitude with the signed-contract extractor (sign 0).
    let f = golden_posit_dec_signed(spec, mag & !(1u64 << (n - 1)));
    PositDecFields { sign, regime: f.regime, exp: f.exp, exp_cin: false, frac: f.frac, chck }
}

/// Signed-form-contract golden model (the paper's b-posit decoder).
pub fn golden_posit_dec_signed(spec: &PositSpec, word: u64) -> PositDecFields {
    let n = spec.n;
    let rs = spec.rs;
    let es = spec.es;
    let fw = frac_port_width(spec);
    let wr = regime_port_width(spec);
    let word = word & spec.mask();
    let sign = word >> (n - 1) & 1 == 1;
    let m = word >> (n - 2) & 1;
    let body = word & spec.maxpos_body();
    let chck = body == 0;
    // Raw-polarity run length, capped at rs (includes the regime MSB).
    let mut run = 1u32;
    let mut i = n as i32 - 3;
    while i >= 0 && run < rs {
        if (word >> i) & 1 == m {
            run += 1;
        } else {
            break;
        }
        i -= 1;
    }
    let reg_len = if run == rs { rs } else { run + 1 };
    let r_raw: i64 = if m == 1 { run as i64 - 1 } else { -(run as i64) };
    let rem_w = (n - 1).saturating_sub(reg_len);
    let rem = if rem_w == 0 { 0 } else { body & ((1u64 << rem_w) - 1) };
    // Left-align into es+fw bits.
    let payload = rem << (es + fw - rem_w);
    let e_raw = payload >> fw;
    let frac = payload & ((1u64 << fw) - 1);
    let sflip = if sign { u64::MAX } else { 0 };
    let wr_mask = (1u64 << wr) - 1;
    let regime = ((r_raw as u64) ^ sflip) & wr_mask;
    let exp = (e_raw ^ sflip) & ((1u64 << es) - 1);
    let exp_cin = sign && frac == 0;
    PositDecFields { sign, regime, exp, exp_cin, frac, chck }
}

/// Golden encoder inputs + expected word. Returns `None` for zero/NaR
/// (which the encoder doesn't handle — chck gates them upstream).
pub fn golden_posit_enc_case(spec: &PositSpec, word: u64) -> Option<(PositEncInputs, u64)> {
    let word = word & spec.mask();
    if word == 0 || word == spec.nar() {
        return None;
    }
    let d = spec.decode(word);
    let t = d.exp;
    let r_m = t >> spec.es;
    let e_m = (t - (r_m << spec.es)) as u64;
    let dec = golden_posit_dec(spec, word);
    let wr = regime_port_width(spec);
    Some((
        PositEncInputs {
            sign: dec.sign,
            regime: (r_m as u64) & ((1u64 << wr) - 1),
            exp: e_m,
            frac: dec.frac,
        },
        word,
    ))
}

/// Magnitude-domain encoder inputs.
#[derive(Clone, Copy, Debug)]
pub struct PositEncInputs {
    pub sign: bool,
    pub regime: u64,
    pub exp: u64,
    pub frac: u64,
}

/// Check the decoder netlist against the golden model for one word.
pub fn check_posit_decoder(spec: &PositSpec, nl: &Netlist, word: u64) -> Result<(), String> {
    let g = golden_posit_dec(spec, word);
    let outs = sim::eval(nl, &[("p", word)]);
    let get = |name: &str| outs.iter().find(|(n, _)| n == name).unwrap().1;
    if get("chck") != g.chck as u64 {
        return Err(format!("chck mismatch for {word:#x}"));
    }
    if g.chck {
        return Ok(()); // remaining fields are don't-care for zero/NaR
    }
    for (name, want) in [
        ("sign", g.sign as u64),
        ("regime", g.regime),
        ("exp", g.exp),
        ("exp_cin", g.exp_cin as u64),
        ("frac", g.frac),
    ] {
        let got = get(name);
        if got != want {
            return Err(format!(
                "{}: {name} mismatch for {word:#x}: got {got:#x}, want {want:#x}",
                crate::formats::Codec::name(spec)
            ));
        }
    }
    Ok(())
}

/// Check the golden decoder fields reconstruct the codec's decoded value
/// (layer-2 semantic soundness).
pub fn check_decode_semantics(spec: &PositSpec, word: u64) -> Result<(), String> {
    let word = word & spec.mask();
    if word == 0 || word == spec.nar() {
        return Ok(());
    }
    let g = golden_posit_dec(spec, word);
    let wr = regime_port_width(spec);
    let fw = frac_port_width(spec);
    // Sign-extend the regime field.
    let sh = 64 - wr;
    let r_out = ((g.regime << sh) as i64) >> sh;
    let t = r_out * (1i64 << spec.es) + g.exp as i64 + g.exp_cin as i64;
    // Signed-form contract (b-posit): the fraction needs the conditional
    // complement; magnitude contract (standard posit): it is already the
    // magnitude fraction.
    let f_m = if spec.is_bounded() && g.sign {
        if g.frac == 0 { 0 } else { (1u64 << fw) - g.frac }
    } else {
        g.frac
    };
    let d = spec.decode(word);
    if d.sign != g.sign {
        return Err(format!("semantic sign mismatch {word:#x}"));
    }
    if d.exp as i64 != t {
        return Err(format!("semantic T mismatch {word:#x}: fields give {t}, codec {}", d.exp));
    }
    let want_sig = (1u64 << 63) | (f_m << (63 - fw));
    if d.sig != want_sig {
        return Err(format!("semantic sig mismatch {word:#x}: {:#x} vs {want_sig:#x}", d.sig));
    }
    Ok(())
}

/// Loopback: run the encoder netlist on golden magnitude fields and demand
/// the original word.
pub fn check_posit_loopback(spec: &PositSpec, enc: &Netlist, word: u64) -> Result<(), String> {
    let Some((inp, want)) = golden_posit_enc_case(spec, word) else {
        return Ok(());
    };
    let outs = sim::eval(
        enc,
        &[
            ("sign", inp.sign as u64),
            ("regime", inp.regime),
            ("exp", inp.exp),
            ("frac", inp.frac),
        ],
    );
    let got = outs.iter().find(|(n, _)| n == "p").unwrap().1;
    if got != want {
        return Err(format!(
            "{} encoder loopback failed for {word:#x}: got {got:#x}",
            crate::formats::Codec::name(spec)
        ));
    }
    Ok(())
}

// ----------------------------------------------------------------------
// Float golden models
// ----------------------------------------------------------------------

/// Golden float decoder (recoded) fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FloatDecFields {
    pub sign: bool,
    pub exp: u64,
    pub sig: u64,
    pub is_nan: bool,
    pub is_inf: bool,
    pub is_zero: bool,
    pub is_sub: bool,
}

/// Software golden model of the float decoder (matches the netlist's
/// deterministic don't-care choices for special values).
pub fn golden_float_dec(spec: &IeeeSpec, word: u64) -> FloatDecFields {
    let fb = spec.fb();
    let eb = spec.eb;
    let bias = spec.bias() as i64;
    let word = word & spec.mask();
    let sign = word >> (spec.n - 1) & 1 == 1;
    let biased = (word >> fb) & ((1u64 << eb) - 1);
    let frac = word & ((1u64 << fb) - 1);
    let exp_all = (1u64 << eb) - 1;
    let is_nan = biased == exp_all && frac != 0;
    let is_inf = biased == exp_all && frac == 0;
    let is_zero = biased == 0 && frac == 0;
    let is_sub = biased == 0 && frac != 0;
    let emask = (1u64 << (eb + 1)) - 1;
    let (exp, sig) = if is_sub {
        let lz = frac.leading_zeros() - (64 - fb);
        let exp = ((-bias - lz as i64) as u64) & emask;
        let sig = (frac << (lz + 1)) & ((1u64 << (fb + 1)) - 1);
        (exp, sig)
    } else {
        // Normal path also covers the deterministic don't-cares for
        // zero/inf/nan (the netlist's mux defaults).
        let exp = ((biased as i64 - bias) as u64) & emask;
        let sig = (1u64 << fb) | frac;
        (exp, sig)
    };
    FloatDecFields { sign, exp, sig, is_nan, is_inf, is_zero, is_sub }
}

/// Check the float decoder netlist for one word.
pub fn check_float_decoder(spec: &IeeeSpec, nl: &Netlist, word: u64) -> Result<(), String> {
    let g = golden_float_dec(spec, word);
    let outs = sim::eval(nl, &[("f", word)]);
    let get = |name: &str| outs.iter().find(|(n, _)| n == name).unwrap().1;
    for (name, want) in [
        ("sign", g.sign as u64),
        ("exp", g.exp),
        ("sig", g.sig),
        ("is_nan", g.is_nan as u64),
        ("is_inf", g.is_inf as u64),
        ("is_zero", g.is_zero as u64),
        ("is_sub", g.is_sub as u64),
    ] {
        let got = get(name);
        if got != want {
            return Err(format!(
                "float{}: {name} mismatch for {word:#x}: got {got:#x} want {want:#x}",
                spec.n
            ));
        }
    }
    // Semantic: recoded fields must match the software codec for finite
    // nonzero values.
    if !(g.is_nan || g.is_inf || g.is_zero) {
        let d = spec.decode(word);
        let sh = 64 - (spec.eb + 1);
        let e_signed = ((g.exp << sh) as i64) >> sh;
        if d.exp as i64 != e_signed {
            return Err(format!("float{} semantic exp mismatch {word:#x}", spec.n));
        }
        if d.sig >> (63 - fbits(spec)) != g.sig {
            return Err(format!("float{} semantic sig mismatch {word:#x}", spec.n));
        }
    }
    Ok(())
}

fn fbits(spec: &IeeeSpec) -> u32 {
    spec.fb()
}

/// Loopback: decoder golden fields through the encoder netlist must
/// reproduce the word (NaNs canonicalize to the quiet NaN).
pub fn check_float_loopback(spec: &IeeeSpec, enc: &Netlist, word: u64) -> Result<(), String> {
    let word = word & spec.mask();
    let g = golden_float_dec(spec, word);
    let outs = sim::eval(
        enc,
        &[
            ("sign", g.sign as u64),
            ("exp", g.exp),
            ("sig", g.sig),
            ("is_nan", g.is_nan as u64),
            ("is_inf", g.is_inf as u64),
            ("is_zero", g.is_zero as u64),
        ],
    );
    let got = outs.iter().find(|(n, _)| n == "f").unwrap().1;
    let want = if g.is_nan { spec.qnan() } else { word };
    if got != want {
        return Err(format!(
            "float{} encoder loopback failed for {word:#x}: got {got:#x} want {want:#x}",
            spec.n
        ));
    }
    Ok(())
}

// ----------------------------------------------------------------------
// Pattern generators shared by tests and benches
// ----------------------------------------------------------------------

/// Corner patterns plus a deterministic PRNG sample of `count` words.
pub fn sample_words(n: u32, count: usize) -> Vec<u64> {
    let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
    let mut v: Vec<u64> = vec![
        0,
        1,
        2,
        3,
        mask,
        mask - 1,
        1u64 << (n - 1),          // NaR / -0
        (1u64 << (n - 1)) + 1,    // most negative magnitudes
        (1u64 << (n - 1)) - 1,    // maxpos
        1u64 << (n - 2),          // 1.0-ish
        (1u64 << (n - 2)) + 1,
        (1u64 << (n - 2)) - 1,
        0x5555_5555_5555_5555 & mask,
        0xaaaa_aaaa_aaaa_aaaa & mask,
    ];
    let mut x = 0x853c49e6748fea9bu64 ^ (n as u64) << 32;
    for _ in 0..count {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        v.push(x & mask);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::ieee::{F16, F32, F64};
    use crate::formats::posit::{BP16, BP32, BP64, P16, P32, P64};
    use crate::hw::designs::{bposit_dec, bposit_enc, float_dec, float_enc, posit_dec, posit_enc};

    #[test]
    fn golden_semantics_exhaustive_16() {
        for spec in [P16, BP16] {
            for w in 0..=u16::MAX as u64 {
                check_decode_semantics(&spec, w).unwrap();
            }
        }
    }

    #[test]
    fn golden_semantics_sampled_32_64() {
        for spec in [P32, BP32, P64, BP64] {
            for w in sample_words(spec.n, 20_000) {
                check_decode_semantics(&spec, w).unwrap();
            }
        }
    }

    #[test]
    fn bposit16_decoder_exhaustive() {
        let nl = bposit_dec::build(&BP16);
        for w in 0..=u16::MAX as u64 {
            check_posit_decoder(&BP16, &nl, w).unwrap();
        }
    }

    #[test]
    fn posit16_decoder_exhaustive() {
        let nl = posit_dec::build(&P16);
        for w in 0..=u16::MAX as u64 {
            check_posit_decoder(&P16, &nl, w).unwrap();
        }
    }

    #[test]
    fn decoder_32_64_sampled() {
        for (spec, bounded) in [(P32, false), (P64, false), (BP32, true), (BP64, true)] {
            let nl = if bounded { bposit_dec::build(&spec) } else { posit_dec::build(&spec) };
            for w in sample_words(spec.n, 3000) {
                check_posit_decoder(&spec, &nl, w).unwrap();
            }
        }
    }

    #[test]
    fn bposit16_encoder_loopback_exhaustive() {
        let enc = bposit_enc::build(&BP16);
        for w in 0..=u16::MAX as u64 {
            check_posit_loopback(&BP16, &enc, w).unwrap();
        }
    }

    #[test]
    fn posit16_encoder_loopback_exhaustive() {
        let enc = posit_enc::build(&P16);
        for w in 0..=u16::MAX as u64 {
            check_posit_loopback(&P16, &enc, w).unwrap();
        }
    }

    #[test]
    fn encoder_32_64_sampled() {
        for (spec, bounded) in [(P32, false), (P64, false), (BP32, true), (BP64, true)] {
            let enc = if bounded { bposit_enc::build(&spec) } else { posit_enc::build(&spec) };
            for w in sample_words(spec.n, 3000) {
                check_posit_loopback(&spec, &enc, w).unwrap();
            }
        }
    }

    #[test]
    fn float16_decoder_exhaustive() {
        let nl = float_dec::build(&F16);
        for w in 0..=u16::MAX as u64 {
            check_float_decoder(&F16, &nl, w).unwrap();
        }
    }

    #[test]
    fn float16_encoder_loopback_exhaustive() {
        let enc = float_enc::build(&F16);
        for w in 0..=u16::MAX as u64 {
            check_float_loopback(&F16, &enc, w).unwrap();
        }
    }

    #[test]
    fn float_32_64_sampled() {
        for spec in [F32, F64] {
            let dec = float_dec::build(&spec);
            let enc = float_enc::build(&spec);
            for w in sample_words(spec.n, 3000) {
                check_float_decoder(&spec, &dec, w).unwrap();
                check_float_loopback(&spec, &enc, w).unwrap();
            }
        }
    }
}
