//! IEEE floating-point encoder (paper Fig 9; HardFloat's back-conversion,
//! all steps except final rounding): bias restoration, subnormal
//! denormalization (comparator + right shifter), and special-case field
//! forcing (NaN/Inf → exp all-ones, zero/subnormal → exp all-zeros).

use crate::formats::IeeeSpec;
use crate::hw::components::{
    barrel_shift_right, const_bus, mux2_bus, ripple_add, ripple_sub, twos_complement,
};
use crate::hw::netlist::{Bus, NetId, Netlist};

/// Build the float encoder netlist for `spec`. Inputs mirror the decoder's
/// outputs: sign (1), exp (eb+1 signed), sig (fb+1 with hidden bit), and
/// the is_nan / is_inf / is_zero flags.
pub fn build(spec: &IeeeSpec) -> Netlist {
    let n = spec.n as usize;
    let eb = spec.eb as usize;
    let fb = spec.fb() as usize;
    let bias = spec.bias() as i64;
    let min_exp = spec.min_exp() as i64;

    let mut nl = Netlist::new();
    let sign = nl.input_bus("sign", 1)[0];
    let exp = nl.input_bus("exp", (eb + 1) as u32);
    let sig = nl.input_bus("sig", (fb + 1) as u32);
    let is_nan = nl.input_bus("is_nan", 1)[0];
    let is_inf = nl.input_bus("is_inf", 1)[0];
    let is_zero = nl.input_bus("is_zero", 1)[0];

    let zero = nl.zero();

    // Subnormal detection + shift distance: d2 = exp − min_exp; negative ⇒
    // subnormal; dist = −d2.
    let min_bus = const_bus(&mut nl, (min_exp as u64) & ((1u64 << (eb + 1)) - 1), eb + 1);
    let (d2, _) = ripple_sub(&mut nl, &exp, &min_bus);
    let is_sub = d2[eb]; // sign bit of the two's-complement difference
    let (dist_full, _) = twos_complement(&mut nl, &d2);
    // Shift distances beyond fb+1 can't occur for in-range inputs; use the
    // low ⌈log2(fb+2)⌉ bits.
    let amt_bits = (usize::BITS - (fb + 1).leading_zeros()) as usize;
    let dist: Bus = dist_full[..amt_bits.min(dist_full.len())].to_vec();

    // Fraction paths.
    let shifted = barrel_shift_right(&mut nl, &sig, &dist);
    let frac_sub: Bus = shifted[..fb].to_vec();
    let frac_norm: Bus = sig[..fb].to_vec();
    let f1 = mux2_bus(&mut nl, is_sub, &frac_norm, &frac_sub);
    // Special forcing: inf/zero → 0; nan → quiet payload (MSB of frac).
    let zeros_f = const_bus(&mut nl, 0, fb);
    let qnan_f = const_bus(&mut nl, 1u64 << (fb - 1), fb);
    let inf_or_zero = nl.or2(is_inf, is_zero);
    let f2 = mux2_bus(&mut nl, inf_or_zero, &f1, &zeros_f);
    let frac_out = mux2_bus(&mut nl, is_nan, &f2, &qnan_f);

    // Exponent paths: normal → exp + bias (low eb bits).
    let bias_bus = const_bus(&mut nl, bias as u64, eb + 1);
    let (biased, _) = ripple_add(&mut nl, &exp, &bias_bus, zero);
    let exp_norm: Bus = biased[..eb].to_vec();
    let zeros_e = const_bus(&mut nl, 0, eb);
    let ones_e = const_bus(&mut nl, (1u64 << eb) - 1, eb);
    let e1 = mux2_bus(&mut nl, is_sub, &exp_norm, &zeros_e);
    let nan_or_inf = nl.or2(is_nan, is_inf);
    let e2 = mux2_bus(&mut nl, nan_or_inf, &e1, &ones_e);
    let exp_out = mux2_bus(&mut nl, is_zero, &e2, &zeros_e);

    // Assemble the word; NaN output is canonically positive (qNaN).
    let n_nan = nl.not(is_nan);
    let sign_out = nl.and2(sign, n_nan);
    let mut word: Vec<NetId> = Vec::with_capacity(n);
    word.extend(&frac_out);
    word.extend(&exp_out);
    word.push(sign_out);
    nl.output_bus("f", &word);
    nl.buffer_high_fanout(12);
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::ieee::{F16, F32, F64};
    use crate::hw::sta;

    #[test]
    fn delay_grows_with_precision() {
        let d16 = sta::analyze(&build(&F16)).critical_ns;
        let d64 = sta::analyze(&build(&F64)).critical_ns;
        assert!(d64 > d16);
    }

    #[test]
    fn smaller_than_float_decoder_is_not_required_but_nonempty() {
        let nl = build(&F32);
        assert!(nl.gate_count() > 80);
    }
}
