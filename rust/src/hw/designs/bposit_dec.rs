//! Proposed b-posit decoder (paper Fig 12 / §3.1).
//!
//! Structure — everything hangs off a one-hot regime-size detection and
//! runs **in parallel**, with no data-dependent shifts:
//!
//! 1. XOR the rs−1 bits after the regime MSB with the regime MSB
//!    (detects "first opposite bit or cap reached").
//! 2. Map to a one-hot string of rs entries with a prefix-AND chain
//!    (Table 2).
//! 3. A single (rs−1)-input one-hot mux taps rs−1 different substrings of
//!    the word → exponent ‖ fraction, left-aligned.
//! 4. In parallel, a priority encoder (pure OR trees on the one-hot) gives
//!    the regime value; one XOR layer folds in the raw-word polarity and
//!    the sign (the paper's "effectively a 1's complement").
//! 5. `exp_cin` (sign ∧ frac=0) is emitted for the arithmetic stage —
//!    off the critical path.
//!
//! Critical path: XOR → NOT/AND chain (≤ rs−1) → mux AND-OR — independent
//! of n, which is the paper's headline scalability property.

use crate::formats::PositSpec;
use crate::hw::components::{mux_onehot, nor_reduce, onehot_to_binary, or_reduce, xor_broadcast};
use crate::hw::netlist::{Bus, NetId, Netlist};

use super::{frac_port_width, regime_port_width};

/// Build the b-posit decoder netlist for `spec` (requires a bounded spec;
/// `rs` may be anything in [3, n−2] for the ablation sweep).
pub fn build(spec: &PositSpec) -> Netlist {
    assert!(spec.is_bounded(), "use posit_dec::build for unbounded regimes");
    let n = spec.n as usize;
    let rs = spec.rs as usize;
    let es = spec.es as usize;
    let fw = frac_port_width(spec) as usize;
    let wr = regime_port_width(spec) as usize;

    let mut nl = Netlist::new();
    let p = nl.input_bus("p", n as u32); // little-endian: p[n-1] = sign

    let sign = p[n - 1];
    let m = p[n - 2]; // regime MSB

    // chck: zero/NaR detector — NOR over everything below the sign.
    let chck = nor_reduce(&mut nl, &p[..n - 1]);

    // 1. XOR the rs−1 bits below the regime MSB with the regime MSB.
    let probe: Vec<NetId> = (0..rs - 1).map(|i| p[n - 3 - i]).collect();
    let x = xor_broadcast(&mut nl, m, &probe);

    // 2. One-hot regime-size detection (Table 2): oh[k] means "first
    //    opposite bit at offset k" (regime field size k+2) for k < rs−1;
    //    oh[rs−1] means "no opposite bit within the cap" (size rs, full run).
    //    Balanced AND trees (not a sequential prefix chain) keep the
    //    detection depth at ⌈log2 rs⌉ — §Perf iteration 2 (was a chain).
    let nx: Vec<NetId> = x.iter().map(|&b| nl.not(b)).collect();
    let mut oh: Bus = Vec::with_capacity(rs);
    for k in 0..rs - 1 {
        let mut terms: Vec<NetId> = nx[..k].to_vec();
        terms.push(x[k]);
        oh.push(crate::hw::components::and_reduce(&mut nl, &terms));
    }
    oh.push(crate::hw::components::and_reduce(&mut nl, &nx));

    // 3. The one-hot payload mux: size k+2 regime leaves payload
    //    p[n-4-k .. 0], left-aligned into es+fw bits with zero padding.
    //    The last two one-hot entries (sizes rs (terminated) and rs (full
    //    run)) share a tap, so the mux has rs−1 inputs (5 for rs=6).
    let zero = nl.zero();
    let width = es + fw; // = n−3
    let mut taps: Vec<Bus> = Vec::with_capacity(rs - 1);
    for k in 0..rs - 1 {
        let reg_len = k + 2;
        // payload bits: p[n-2-reg_len .. 0], width n-1-reg_len, left-aligned
        let pw = n - 1 - reg_len;
        let mut tap: Bus = Vec::with_capacity(width);
        // low (width - pw) bits are zero padding
        for _ in 0..width - pw {
            tap.push(zero);
        }
        tap.extend(&p[..pw]);
        taps.push(tap);
    }
    let mut sels: Bus = oh[..rs - 2].to_vec();
    let shared = or_reduce(&mut nl, &[oh[rs - 2], oh[rs - 1]]);
    sels.push(shared);
    let tap_refs: Vec<&[NetId]> = taps.iter().map(|t| t.as_slice()).collect();
    let payload = mux_onehot(&mut nl, &sels, &tap_refs);

    // Split payload: top es bits are the raw exponent, rest the fraction.
    let frac: Bus = payload[..fw].to_vec();
    let e_raw: Bus = payload[fw..].to_vec();

    // 4. Regime value: priority-encode the one-hot, then one XOR layer for
    //    polarity (¬m) and sign: r_out = idx ⊕ (¬m ⊕ s), sign bit ¬m ⊕ s.
    let idx = onehot_to_binary(&mut nl, &oh); // ceil(log2(rs)) bits
    let nm = nl.not(m);
    let pol = nl.xor2(nm, sign);
    let mut regime: Bus = idx.iter().map(|&b| nl.xor2(b, pol)).collect();
    while regime.len() < wr {
        regime.push(pol); // sign-extend with the polarity bit
    }

    // Exponent: e_out = e_raw ⊕ sign (the XOR-only 1's complement).
    let exp = xor_broadcast(&mut nl, sign, &e_raw);

    // 5. exp_cin = sign ∧ (frac = 0) — deferred 2's-complement carry.
    let f_zero = nor_reduce(&mut nl, &frac);
    let exp_cin = nl.and2(sign, f_zero);

    nl.output_bus("sign", &[sign]);
    nl.output_bus("regime", &regime);
    nl.output_bus("exp", &exp);
    nl.output_bus("exp_cin", &[exp_cin]);
    nl.output_bus("frac", &frac);
    nl.output_bus("chck", &[chck]);
    nl.buffer_high_fanout(12);
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{BP16, BP32};
    use crate::hw::sta;

    #[test]
    fn builds_and_has_shallow_depth() {
        let nl16 = build(&BP16);
        let nl32 = build(&BP32);
        let d16 = sta::logic_depth(&nl16);
        let d32 = sta::logic_depth(&nl32);
        // Depth must be essentially flat across precision (paper's claim).
        assert!(d32 <= d16 + 3, "depth grew: {d16} → {d32}");
        // And shallow in absolute terms (no LZC→shifter chain). The deepest
        // output is exp_cin (frac NOR-tree + AND), which the paper defers to
        // the arithmetic stage; including it the depth stays well under the
        // posit decoder's LZC→shifter chain.
        assert!(d32 < 20, "b-posit decoder too deep: {d32}");
    }

    #[test]
    fn area_scales_with_n_but_delay_does_not() {
        let specs = [
            PositSpec::bounded(16, 6, 5),
            PositSpec::bounded(32, 6, 5),
            PositSpec::bounded(64, 6, 5),
        ];
        let mut prev_area = 0.0;
        let mut delays = Vec::new();
        for s in &specs {
            let nl = build(s);
            assert!(nl.area() > prev_area, "area must grow with n");
            prev_area = nl.area();
            delays.push(sta::analyze(&nl).critical_ns);
        }
        // Near-constant delay: 64-bit within 40% of 16-bit.
        assert!(delays[2] < delays[0] * 1.4, "delay not flat: {delays:?}");
    }
}
