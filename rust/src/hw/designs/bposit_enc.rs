//! Proposed b-posit encoder (paper Fig 13 / §3.2).
//!
//! Packing is again select-based rather than shift-based:
//!
//! 1. `exp_cin = sign ∧ (frac = 0)`; exponent → raw form via XOR with sign
//!    plus an eS-bit increment on `exp_cin` (the deferred 2's complement).
//! 2. An exponent-overflow never ripples into a full-width adder: the
//!    regime value is bumped by a speculative 4-bit incrementer selected by
//!    a mux ("the change in the final regime string is accounted for using
//!    another multiplexer"). The overflow condition itself
//!    (sign ∧ frac=0 ∧ exp=0) is computed directly from the inputs, in
//!    parallel with everything else.
//! 3. The 3 LSBs of the (raw-domain) regime value XOR its MSB give the
//!    regime-size index (Table 3); a 3×6 binary decoder yields the
//!    intermediate regime string (Table 4); one XOR layer applies the run
//!    polarity.
//! 4. A final (rs−1)-input one-hot mux picks among the five packing
//!    layouts: [regime_k ‖ exponent ‖ fraction-truncated-to-fit].
//!
//! Critical path: XOR → decoder → XOR → mux — constant in n; only the mux
//! input width grows with precision.

use crate::formats::PositSpec;
use crate::hw::components::{
    binary_decoder, incrementer, mux2_bus, mux_onehot, nor_reduce, or_reduce, xor_broadcast,
};
use crate::hw::netlist::{Bus, NetId, Netlist};

use super::{frac_port_width, regime_port_width};

/// Build the b-posit encoder netlist for `spec`.
pub fn build(spec: &PositSpec) -> Netlist {
    assert!(spec.is_bounded());
    let n = spec.n as usize;
    let rs = spec.rs as usize;
    let es = spec.es as usize;
    let fw = frac_port_width(spec) as usize;
    let wr = regime_port_width(spec) as usize;

    let mut nl = Netlist::new();
    let sign = nl.input_bus("sign", 1)[0];
    let r_in = nl.input_bus("regime", wr as u32); // magnitude-domain, post-carry
    let e_in = nl.input_bus("exp", es as u32); // magnitude-domain
    let frac = nl.input_bus("frac", fw as u32); // signed form, left-aligned

    // 1. Deferred 2's complement of the exponent.
    let f_zero = nor_reduce(&mut nl, &frac);
    let cin = nl.and2(sign, f_zero);
    let e_x = xor_broadcast(&mut nl, sign, &e_in);
    let (e_raw, _carry) = incrementer(&mut nl, &e_x, cin);

    // 2. Exponent overflow (ovf ⇔ sign ∧ frac=0 ∧ exp=0) bumps the regime.
    let e_zero = nor_reduce(&mut nl, &e_in);
    let ovf = nl.and2(cin, e_zero);
    let r_x = xor_broadcast(&mut nl, sign, &r_in); // raw-domain regime value
    let one = nl.one();
    let (r_plus, _) = incrementer(&mut nl, &r_x, one); // speculative, parallel
    let r_eff = mux2_bus(&mut nl, ovf, &r_x, &r_plus);

    // 3. Regime-size index (Table 3) and regime string (Table 4).
    let msb = r_eff[wr - 1];
    let low: Vec<NetId> = r_eff[..wr - 1].to_vec();
    let idx = xor_broadcast(&mut nl, msb, &low); // "1's complement" index
    let onehot = binary_decoder(&mut nl, &idx, rs);
    // Intermediate string (MSB-first, rs+1 bits): [0, onehot[0..rs-1]];
    // polarity XOR: px = ¬msb (run of 1s for r_eff ≥ 0).
    let px = nl.not(msb);
    let zero = nl.zero();
    let mut string: Vec<NetId> = Vec::with_capacity(rs + 1);
    string.push(nl.xor2(zero, px)); // = px, kept as XOR for structural fidelity
    for k in 0..rs {
        string.push(nl.xor2(onehot[k], px));
    }

    // 4. Packing candidates for regime sizes 2..=rs (MSB-first assembly).
    //    Candidate k: string[0..k] ++ e_raw ++ frac[top n-1-k-es bits].
    let mut taps: Vec<Bus> = Vec::with_capacity(rs - 1);
    for size in 2..=rs {
        let keep_frac = n - 1 - size - es;
        let mut tap_msb_first: Vec<NetId> = Vec::with_capacity(n - 1);
        tap_msb_first.extend(&string[..size]);
        tap_msb_first.extend(e_raw.iter().rev()); // e_raw is LE; emit MSB-first
        // frac is LE with MSB at fw-1; take the top keep_frac bits.
        for i in 0..keep_frac {
            tap_msb_first.push(frac[fw - 1 - i]);
        }
        // Convert MSB-first to the little-endian bus convention.
        let tap: Bus = tap_msb_first.into_iter().rev().collect();
        taps.push(tap);
    }
    let mut sels: Bus = onehot[..rs - 2].to_vec();
    let shared = or_reduce(&mut nl, &[onehot[rs - 2], onehot[rs - 1]]);
    sels.push(shared);
    let tap_refs: Vec<&[NetId]> = taps.iter().map(|t| t.as_slice()).collect();
    let body = mux_onehot(&mut nl, &sels, &tap_refs);

    let mut word: Bus = body;
    word.push(sign);
    nl.output_bus("p", &word);
    nl.buffer_high_fanout(12);
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{BP16, BP32, BP64};
    use crate::hw::sta;

    #[test]
    fn near_constant_delay_across_n() {
        let d: Vec<f64> = [BP16, BP32, BP64]
            .iter()
            .map(|s| sta::analyze(&build(s)).critical_ns)
            .collect();
        assert!(d[2] < d[0] * 1.4, "encoder delay not flat: {d:?}");
    }

    #[test]
    fn area_grows_with_n() {
        let a16 = build(&BP16).area();
        let a64 = build(&BP64).area();
        assert!(a64 > a16 * 2.0, "area should scale with n: {a16} vs {a64}");
    }
}
