//! The six decoder/encoder circuits the paper evaluates (Figs 8–13), as
//! parameterized structural netlist generators, plus the functional-
//! equivalence verification harness.
//!
//! # Interface conventions (shared by posit and b-posit designs)
//!
//! **Decoder** (`p[n]` in):
//! - `sign` (1): the word's sign bit.
//! - `regime` (w_r, two's complement): the sign-corrected regime value
//!   `r_out = r_raw ⊕ sign` where `r_raw` is extracted from the *raw signed
//!   word* (no up-front two's complement — the paper's XOR shortcut).
//! - `exp` (eS): `e_out = e_raw ⊕ sign` (1's-complement correction).
//! - `exp_cin` (1): `sign ∧ (fraction = 0)` — the deferred +1 that turns
//!   the 1's complement into a 2's complement; consumed by the arithmetic
//!   stage, off the decode critical path (paper §3.1).
//! - `frac` (fw_max): fraction bits **in signed form**, left-aligned
//!   (zero-padded at the LSB end for longer regimes).
//! - `chck` (1): NOR of all bits below the sign — flags zero/NaR.
//!
//! The decoded value satisfies: `T_mag = r_out·2^eS + e_out + exp_cin` and
//! `|value| = 2^T_mag · (1 + f_mag)` with `f_mag` the (conditionally
//! complemented) fraction — see `verify::check_decoder`.
//!
//! **Encoder** (magnitude-domain fields in, raw word out):
//! - inputs `sign` (1), `regime` (w_r, two's complement, post-carry
//!   magnitude value), `exp` (eS, magnitude), `frac` (fw_max, signed form —
//!   the form the ALU carries per the paper);
//! - output `p` (n): the packed word, produced *without* a full-width
//!   two's complement: per-field XOR with sign + an eS-bit increment when
//!   `sign ∧ frac=0`, with exponent-overflow absorbed by a regime
//!   mux (b-posit) / adder (posit).
//!
//! The float designs follow HardFloat's recoded-format convention instead
//! (see `float_dec`/`float_enc`).

pub mod bposit_dec;
pub mod bposit_enc;
pub mod posit_dec;
pub mod posit_enc;
pub mod float_dec;
pub mod float_enc;
pub mod verify;

use crate::formats::{IeeeSpec, PositSpec};

/// Which design a vector set is being generated for.
pub enum DesignUnderTest<'a> {
    PositDec(&'a PositSpec),
    PositEnc(&'a PositSpec),
    FloatDec(&'a IeeeSpec),
    FloatEnc(&'a IeeeSpec),
}

/// Input-transition vector pairs for power analysis: adversarial
/// worst-case pairs (maximal-regime flips, subnormal↔max for floats — the
/// paper's "worst case, data-dependent" convention) plus PRNG background
/// pairs.
pub fn power_vectors(
    dut: &DesignUnderTest,
    random_pairs: usize,
) -> Vec<(Vec<(&'static str, u64)>, Vec<(&'static str, u64)>)> {
    let n = match dut {
        DesignUnderTest::PositDec(s) | DesignUnderTest::PositEnc(s) => s.n,
        DesignUnderTest::FloatDec(s) | DesignUnderTest::FloatEnc(s) => s.n,
    };
    let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
    let maxpos = (1u64 << (n - 1)) - 1;
    // Adversarial word pairs: full-regime polarity flips and extreme swings.
    let mut word_pairs: Vec<(u64, u64)> = vec![
        (maxpos, (1u64 << (n - 1)) + 1), // maxpos ↔ −maxpos
        (maxpos, 1),                     // maxpos ↔ minpos
        (1, mask),                       // minpos ↔ −minpos
        (0x5555_5555_5555_5555 & mask, 0xaaaa_aaaa_aaaa_aaaa & mask),
        (1u64 << (n - 2), maxpos),
    ];
    let mut x = 0x1234_5678_9abc_def0u64 ^ ((n as u64) << 17);
    for _ in 0..random_pairs {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let a = x & mask;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        word_pairs.push((a, x & mask));
    }
    let assign = |w: u64| -> Vec<(&'static str, u64)> {
        match dut {
            DesignUnderTest::PositDec(_) => vec![("p", w)],
            DesignUnderTest::FloatDec(_) => vec![("f", w)],
            DesignUnderTest::PositEnc(s) => {
                let (inp, _) = verify::golden_posit_enc_case(s, w)
                    .unwrap_or_else(|| verify::golden_posit_enc_case(s, 1 << (s.n - 2)).unwrap());
                vec![
                    ("sign", inp.sign as u64),
                    ("regime", inp.regime),
                    ("exp", inp.exp),
                    ("frac", inp.frac),
                ]
            }
            DesignUnderTest::FloatEnc(s) => {
                let g = verify::golden_float_dec(s, w);
                vec![
                    ("sign", g.sign as u64),
                    ("exp", g.exp),
                    ("sig", g.sig),
                    ("is_nan", g.is_nan as u64),
                    ("is_inf", g.is_inf as u64),
                    ("is_zero", g.is_zero as u64),
                ]
            }
        }
    };
    word_pairs.into_iter().map(|(a, b)| (assign(a), assign(b))).collect()
}

/// Width of the decoder/encoder regime-value port for a posit-family spec.
pub fn regime_port_width(spec: &PositSpec) -> u32 {
    // Two's-complement range [−rs, rs−1] → ⌈log2(rs)⌉+1 bits.
    let rs = spec.rs;
    (32 - (rs - 1).leading_zeros()) + 1
}

/// Maximum fraction width (fovea): the widest payload, at regime size 2.
pub fn frac_port_width(spec: &PositSpec) -> u32 {
    spec.n - 3 - spec.es
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{BP16, BP32, BP64, P16, P32, P64};

    #[test]
    fn port_widths() {
        assert_eq!(regime_port_width(&BP32), 4); // r ∈ [-6,5]
        assert_eq!(regime_port_width(&BP16), 4);
        assert_eq!(regime_port_width(&BP64), 4);
        assert_eq!(regime_port_width(&P16), 5); // r ∈ [-15,14]
        assert_eq!(regime_port_width(&P32), 6);
        assert_eq!(regime_port_width(&P64), 7);
        assert_eq!(frac_port_width(&BP32), 24); // fovea fraction
        assert_eq!(frac_port_width(&P32), 27);
        assert_eq!(frac_port_width(&BP16), 8);
    }
}
