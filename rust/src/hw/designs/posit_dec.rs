//! Standard posit decoder (paper Fig 10, after ref [6]).
//!
//! The reference design decodes the **magnitude**: a conditional two's
//! complement of the whole body runs first (XOR row + (n−1)-bit ripple
//! incrementer), then the regime may span nearly the whole word, so decode
//! is **sequential**:
//!
//! 1. Conditional two's complement (sign-gated) of the n−1-bit body.
//! 2. Leading-run detection: XOR with the regime MSB + leading-zero count
//!    (divide & conquer, log depth — the "optimal circuits" of §1.3).
//! 3. Left barrel shifter (log stages, each a full-width mux row) aligns
//!    the exponent and fraction — it cannot start until the LZC finishes.
//!
//! The chain 2's-comp → LZC → shifter is exactly the serialization the
//! b-posit decoder removes (it defers the complement to one XOR layer and
//! replaces LZC+shift with a constant-depth one-hot mux).
//!
//! Output contract (magnitude domain — contrast designs/mod.rs):
//! `regime`/`exp`/`frac` are the magnitude fields; `exp_cin` is constant 0.

use crate::formats::PositSpec;
use crate::hw::components::{
    barrel_shift_left, cond_twos_complement, lzc_msb_first, nor_reduce, xor_broadcast,
};
use crate::hw::netlist::{Bus, NetId, Netlist};

use super::{frac_port_width, regime_port_width};

/// Build the standard posit decoder netlist for `spec` (rs = n−1).
pub fn build(spec: &PositSpec) -> Netlist {
    assert!(!spec.is_bounded(), "use bposit_dec::build for bounded regimes");
    let n = spec.n as usize;
    let es = spec.es as usize;
    let fw = frac_port_width(spec) as usize;
    let wr = regime_port_width(spec) as usize;

    let mut nl = Netlist::new();
    let p = nl.input_bus("p", n as u32);
    let sign = p[n - 1];

    let chck = nor_reduce(&mut nl, &p[..n - 1]);

    // 1. Conditional two's complement of the body (the up-front cost the
    //    b-posit design defers; ripple carry over n−1 bits).
    let body_m = cond_twos_complement(&mut nl, sign, &p[..n - 1]);
    let m = body_m[n - 2]; // magnitude regime MSB

    // 2. Polarity-normalize and count the leading run.
    let tail: Vec<NetId> = (0..n - 2).map(|i| body_m[n - 3 - i]).collect(); // MSB-first
    let x = xor_broadcast(&mut nl, m, &tail);
    let (k, _allz) = lzc_msb_first(&mut nl, &x);

    // 3. Shift the magnitude body left by k (then drop two more bits
    //    statically: regime MSB + terminator) to align exp‖frac.
    let shifted = barrel_shift_left(&mut nl, &body_m, &k);
    let mut e_raw: Bus = Vec::with_capacity(es);
    for i in 0..es {
        e_raw.push(shifted[n - 4 - i]);
    }
    e_raw.reverse();
    let mut frac: Bus = Vec::with_capacity(fw);
    for i in 0..fw {
        frac.push(shifted[n - 4 - es - i]);
    }
    frac.reverse();

    // Regime value (magnitude): r = m ? k : ~k — one XOR layer with ¬m.
    let pol = nl.not(m);
    let mut regime: Bus = k.iter().map(|&b| nl.xor2(b, pol)).collect();
    while regime.len() < wr {
        regime.push(pol);
    }
    regime.truncate(wr);

    let zero = nl.zero();
    nl.output_bus("sign", &[sign]);
    nl.output_bus("regime", &regime);
    nl.output_bus("exp", &e_raw);
    nl.output_bus("exp_cin", &[zero]); // magnitude contract: no deferred carry
    nl.output_bus("frac", &frac);
    nl.output_bus("chck", &[chck]);
    nl.buffer_high_fanout(12);
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{P16, P32, P64};
    use crate::hw::sta;

    #[test]
    fn depth_grows_with_n() {
        let d16 = sta::logic_depth(&build(&P16));
        let d64 = sta::logic_depth(&build(&P64));
        assert!(d64 > d16, "posit decoder depth should grow: {d16} vs {d64}");
    }

    #[test]
    fn costlier_than_bposit_at_same_width() {
        use crate::formats::posit::BP32;
        let posit = build(&P32);
        let bposit = super::super::bposit_dec::build(&BP32);
        assert!(posit.area() > bposit.area(), "posit {} ≤ bposit {}", posit.area(), bposit.area());
        let dp = sta::analyze(&posit).critical_ns;
        let db = sta::analyze(&bposit).critical_ns;
        assert!(dp > db, "posit delay {dp} should exceed b-posit {db}");
    }

    #[test]
    fn slower_than_float_decode_at_32() {
        // Paper Table 5: posit32 decode is ~1.7× slower than float32 decode.
        use crate::formats::ieee::F32;
        let dp = sta::analyze(&build(&P32)).critical_ns;
        let df = sta::analyze(&super::super::float_dec::build(&F32)).critical_ns;
        assert!(dp > df, "posit32 {dp} should be slower than float32 {df}");
    }
}
