//! PPA (power / performance / area) cost reporting for a netlist — the
//! measurement side of Tables 5/6 and Figs 14–16.

use super::netlist::Netlist;
use super::power::{self, PowerReport};
use super::sta;

/// Combined cost report for one design.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub name: String,
    /// Peak (worst-case-vector) power in mW.
    pub peak_power_mw: f64,
    /// Average power over the vector set in mW.
    pub avg_power_mw: f64,
    /// Cell area in µm².
    pub area_um2: f64,
    /// Critical-path delay in ns.
    pub delay_ns: f64,
    /// Logic cells.
    pub gates: usize,
    /// Gates on the critical path.
    pub depth: usize,
}

/// Measure a netlist against a set of input transition pairs.
///
/// `pairs` should include the adversarial worst-case vectors for the design
/// (max-length regimes, subnormal floats) plus random background pairs — the
/// same "various input vectors" convention as the paper's §4.
pub fn measure(
    name: &str,
    nl: &Netlist,
    pairs: &[(Vec<(&str, u64)>, Vec<(&str, u64)>)],
) -> CostReport {
    let timing = sta::analyze(nl);
    let p: PowerReport = power::analyze(nl, pairs);
    CostReport {
        name: name.to_string(),
        peak_power_mw: p.peak_mw,
        avg_power_mw: p.avg_mw,
        area_um2: nl.area(),
        delay_ns: timing.critical_ns,
        gates: nl.gate_count(),
        depth: timing.critical_path.len(),
    }
}

/// Render a slice of reports as an aligned text table (the shape of the
/// paper's Tables 5 and 6).
pub fn format_table(title: &str, rows: &[CostReport]) -> String {
    let mut s = String::new();
    s.push_str(&format!("{title}\n"));
    s.push_str(&format!(
        "{:<28} {:>12} {:>12} {:>10} {:>8} {:>7}\n",
        "Design", "PeakPwr(mW)", "Area(um^2)", "Delay(ns)", "Gates", "Depth"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<28} {:>12.3} {:>12.1} {:>10.3} {:>8} {:>7}\n",
            r.name, r.peak_power_mw, r.area_um2, r.delay_ns, r.gates, r.depth
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::netlist::Netlist;

    #[test]
    fn measure_reports_consistent_fields() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 8);
        let b = nl.input_bus("b", 8);
        let z = nl.zero();
        let (sum, _) = crate::hw::components::ripple_add(&mut nl, &a, &b, z);
        nl.output_bus("sum", &sum);
        let pairs = vec![
            (vec![("a", 0u64), ("b", 0u64)], vec![("a", 255u64), ("b", 255u64)]),
            (vec![("a", 0), ("b", 0)], vec![("a", 1), ("b", 0)]),
        ];
        let rep = measure("rca8", &nl, &pairs);
        assert!(rep.area_um2 > 0.0 && rep.delay_ns > 0.0 && rep.peak_power_mw > 0.0);
        assert!(rep.peak_power_mw >= rep.avg_power_mw);
        assert_eq!(rep.gates, nl.gate_count());
        let table = format_table("test", &[rep]);
        assert!(table.contains("rca8"));
    }
}
