//! Structural netlist builder.
//!
//! A [`Netlist`] is a DAG of cells over single-bit nets, built bottom-up so
//! that gate insertion order is already a topological order (every gate's
//! inputs exist before the gate). Buses are plain `Vec<NetId>` with LSB at
//! index 0.

use super::cell::CellKind;

/// Index of a single-bit net.
pub type NetId = u32;

/// A bus is a little-endian vector of nets (bit i at index i).
pub type Bus = Vec<NetId>;

/// One instantiated cell.
#[derive(Clone, Copy, Debug)]
pub struct Gate {
    pub kind: CellKind,
    /// Input nets; only the first `kind.arity()` entries are valid.
    pub ins: [NetId; 3],
    pub out: NetId,
}

/// A combinational netlist with named input/output buses.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub gates: Vec<Gate>,
    n_nets: u32,
    /// Primary inputs (flattened, in declaration order).
    pub inputs: Vec<NetId>,
    pub input_buses: Vec<(String, Bus)>,
    pub output_buses: Vec<(String, Bus)>,
    const0: Option<NetId>,
    const1: Option<NetId>,
}

impl Netlist {
    pub fn new() -> Netlist {
        Netlist::default()
    }

    fn fresh(&mut self) -> NetId {
        let id = self.n_nets;
        self.n_nets += 1;
        id
    }

    pub fn n_nets(&self) -> u32 {
        self.n_nets
    }

    /// Declare a primary input bus of `width` bits (LSB first).
    pub fn input_bus(&mut self, name: &str, width: u32) -> Bus {
        let bus: Bus = (0..width).map(|_| self.fresh()).collect();
        self.inputs.extend(&bus);
        self.input_buses.push((name.to_string(), bus.clone()));
        bus
    }

    /// Declare a named output bus.
    pub fn output_bus(&mut self, name: &str, bus: &[NetId]) {
        self.output_buses.push((name.to_string(), bus.to_vec()));
    }

    /// Find a named output bus.
    pub fn output(&self, name: &str) -> &Bus {
        let bus = self.output_buses.iter().find(|(n, _)| n == name);
        &bus.unwrap_or_else(|| panic!("no output bus {name}")).1
    }

    /// Find a named input bus.
    pub fn input(&self, name: &str) -> &Bus {
        let bus = self.input_buses.iter().find(|(n, _)| n == name);
        &bus.unwrap_or_else(|| panic!("no input bus {name}")).1
    }

    /// Constant-0 net (shared).
    pub fn zero(&mut self) -> NetId {
        if let Some(z) = self.const0 {
            return z;
        }
        let z = self.fresh();
        self.gates.push(Gate { kind: CellKind::Const0, ins: [0; 3], out: z });
        self.const0 = Some(z);
        z
    }

    /// Constant-1 net (shared).
    pub fn one(&mut self) -> NetId {
        if let Some(o) = self.const1 {
            return o;
        }
        let o = self.fresh();
        self.gates.push(Gate { kind: CellKind::Const1, ins: [0; 3], out: o });
        self.const1 = Some(o);
        o
    }

    fn push(&mut self, kind: CellKind, ins: [NetId; 3]) -> NetId {
        let out = self.fresh();
        self.gates.push(Gate { kind, ins, out });
        out
    }

    pub fn buf(&mut self, a: NetId) -> NetId {
        self.push(CellKind::Buf, [a, 0, 0])
    }

    pub fn not(&mut self, a: NetId) -> NetId {
        self.push(CellKind::Inv, [a, 0, 0])
    }

    pub fn and2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(CellKind::And2, [a, b, 0])
    }

    pub fn or2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(CellKind::Or2, [a, b, 0])
    }

    pub fn nand2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(CellKind::Nand2, [a, b, 0])
    }

    pub fn nor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(CellKind::Nor2, [a, b, 0])
    }

    pub fn xor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(CellKind::Xor2, [a, b, 0])
    }

    pub fn xnor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(CellKind::Xnor2, [a, b, 0])
    }

    /// out = s ? b : a.
    pub fn mux2(&mut self, s: NetId, a: NetId, b: NetId) -> NetId {
        self.push(CellKind::Mux2, [s, a, b])
    }

    /// out = !((a & b) | c).
    pub fn aoi21(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        self.push(CellKind::Aoi21, [a, b, c])
    }

    /// out = !((a | b) & c).
    pub fn oai21(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        self.push(CellKind::Oai21, [a, b, c])
    }

    // ------------------------------------------------------------------
    // Analysis helpers
    // ------------------------------------------------------------------

    /// Total cell area in µm².
    pub fn area(&self) -> f64 {
        self.gates.iter().map(|g| g.kind.params().area).sum()
    }

    /// Number of logic cells (constants excluded).
    pub fn gate_count(&self) -> usize {
        self.gates
            .iter()
            .filter(|g| !matches!(g.kind, CellKind::Const0 | CellKind::Const1))
            .count()
    }

    /// Fanout count per net (used by STA's load-dependent delay).
    pub fn fanouts(&self) -> Vec<u32> {
        let mut fo = vec![0u32; self.n_nets as usize];
        for g in &self.gates {
            for i in 0..g.kind.arity() {
                fo[g.ins[i] as usize] += 1;
            }
        }
        // Primary outputs also load their drivers.
        for (_, bus) in &self.output_buses {
            for &n in bus {
                fo[n as usize] += 1;
            }
        }
        fo
    }

    /// Insert buffer trees on nets whose fanout exceeds `max_fanout`
    /// (a simple post-pass mirroring what synthesis does; keeps the STA's
    /// linear load model honest on high-fanout select/broadcast nets).
    pub fn buffer_high_fanout(&mut self, max_fanout: u32) {
        loop {
            let fo = self.fanouts();
            // Find worst offender that is not already a buffer chain root.
            let mut worst: Option<(NetId, u32)> = None;
            for (net, &f) in fo.iter().enumerate() {
                if f > max_fanout {
                    match worst {
                        Some((_, wf)) if wf >= f => {}
                        _ => worst = Some((net as NetId, f)),
                    }
                }
            }
            let Some((net, f)) = worst else { break };
            // Split the sinks of `net` between it and `ceil(f/max)−1` new
            // buffers.
            let n_bufs = (f + max_fanout - 1) / max_fanout - 1;
            if n_bufs == 0 {
                break;
            }
            let bufs: Vec<NetId> = (0..n_bufs).map(|_| self.buf(net)).collect();
            // Reassign sinks round-robin (skip the buffers we just added,
            // which are the last `n_bufs` gates).
            let skip_from = self.gates.len() - n_bufs as usize;
            let mut assigned = 0u32;
            let total = f;
            let per = (total + n_bufs) / (n_bufs + 1);
            for (gi, g) in self.gates.iter_mut().enumerate() {
                if gi >= skip_from {
                    continue;
                }
                for i in 0..g.kind.arity() {
                    if g.ins[i] == net {
                        let slot = assigned / per;
                        if slot > 0 && (slot as usize) <= bufs.len() {
                            g.ins[i] = bufs[slot as usize - 1];
                        }
                        assigned += 1;
                    }
                }
            }
            // Buffers were appended after their driver exists → topological
            // order is preserved, EXCEPT sinks that appear before the buffer
            // in gate order now read a later net. Re-topologize.
            self.topo_sort();
        }
    }

    /// Re-establish topological gate order (Kahn) after structural edits.
    pub fn topo_sort(&mut self) {
        let n = self.n_nets as usize;
        let mut driver: Vec<Option<usize>> = vec![None; n];
        for (gi, g) in self.gates.iter().enumerate() {
            driver[g.out as usize] = Some(gi);
        }
        let mut visited = vec![false; self.gates.len()];
        let mut order: Vec<usize> = Vec::with_capacity(self.gates.len());
        // Iterative DFS from every gate.
        let mut stack: Vec<(usize, usize)> = Vec::new();
        for root in 0..self.gates.len() {
            if visited[root] {
                continue;
            }
            stack.push((root, 0));
            visited[root] = true;
            while let Some((gi, pin)) = stack.pop() {
                let g = self.gates[gi];
                if pin < g.kind.arity() {
                    stack.push((gi, pin + 1));
                    if let Some(dep) = driver[g.ins[pin] as usize] {
                        if !visited[dep] {
                            visited[dep] = true;
                            stack.push((dep, 0));
                        }
                    }
                } else {
                    order.push(gi);
                }
            }
        }
        let mut new_gates = Vec::with_capacity(self.gates.len());
        for gi in order {
            new_gates.push(self.gates[gi]);
        }
        self.gates = new_gates;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_count() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 4);
        let b = nl.input_bus("b", 4);
        let outs: Vec<NetId> = a.iter().zip(&b).map(|(&x, &y)| nl.xor2(x, y)).collect();
        nl.output_bus("y", &outs);
        assert_eq!(nl.gate_count(), 4);
        assert!(nl.area() > 6.0);
        assert_eq!(nl.inputs.len(), 8);
        assert_eq!(nl.output("y").len(), 4);
    }

    #[test]
    fn constants_shared() {
        let mut nl = Netlist::new();
        let z1 = nl.zero();
        let z2 = nl.zero();
        let o1 = nl.one();
        assert_eq!(z1, z2);
        assert_ne!(z1, o1);
        assert_eq!(nl.gate_count(), 0); // constants don't count
    }

    #[test]
    fn fanout_counting() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 1)[0];
        let x = nl.not(a);
        let _ = nl.and2(x, a);
        let _ = nl.or2(x, a);
        nl.output_bus("o", &[x]);
        let fo = nl.fanouts();
        assert_eq!(fo[x as usize], 3); // two sinks + primary output
        assert_eq!(fo[a as usize], 3);
    }

    #[test]
    fn buffering_reduces_max_fanout() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 1)[0];
        let sinks: Vec<NetId> = (0..40).map(|_| nl.not(a)).collect();
        nl.output_bus("o", &sinks);
        nl.buffer_high_fanout(8);
        let fo = nl.fanouts();
        let max = fo.iter().max().copied().unwrap();
        assert!(max <= 9, "max fanout {max} after buffering");
        // Function preserved: all outputs still invert `a`.
        let sim = crate::hw::sim::eval(&nl, &[("a", 1)]);
        for (name, bits) in sim {
            if name == "o" {
                assert_eq!(bits, 0, "inverters must output 0 for input 1");
            }
        }
    }
}
