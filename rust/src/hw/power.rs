//! Power estimation from switching activity.
//!
//! The paper reports **worst-case (peak) power** from post-layout analysis
//! with data-dependent vectors ("the power usage and delays are
//! data-dependent for posits and b-posits, with longer regimes creating
//! longer delays"). We mirror that: drive the netlist with a set of input
//! transition pairs (adversarial + random), run the glitch-aware timing
//! simulation, and report
//!
//!   peak power = max over pairs of (switched energy) / (critical delay)
//!
//! plus the average for context. Leakage is approximated as a per-area
//! constant (NanGate45-class ~0.02 µW/µm² is negligible at these sizes and
//! folded into the figure).

use super::netlist::Netlist;
use super::sim::simulate_transition;
use super::sta;

/// Power analysis result.
#[derive(Clone, Copy, Debug)]
pub struct PowerReport {
    /// Peak (worst-vector) power in mW.
    pub peak_mw: f64,
    /// Average power over the vector set in mW.
    pub avg_mw: f64,
    /// Worst-pair switched energy in fJ.
    pub worst_energy_fj: f64,
    /// Transitions observed on the worst pair.
    pub worst_transitions: u64,
}

/// Leakage power density (mW per µm²), NanGate45-class.
const LEAKAGE_MW_PER_UM2: f64 = 2.0e-5;

/// Estimate power over a set of named input vector pairs.
///
/// Each element of `pairs` is (from, to) where both are full input
/// assignments (name, value).
pub fn analyze(nl: &Netlist, pairs: &[(Vec<(&str, u64)>, Vec<(&str, u64)>)]) -> PowerReport {
    let timing = sta::analyze(nl);
    // Energy-to-power conversion window: the critical-path delay (the
    // fastest clock this block could run at) — the same convention that
    // makes "faster and smaller" cost a bit more peak power (paper §4).
    let period_ns = timing.critical_ns.max(1e-3);
    let leakage = nl.area() * LEAKAGE_MW_PER_UM2;
    let mut worst = 0.0f64;
    let mut worst_tr = 0u64;
    let mut total = 0.0f64;
    for (from, to) in pairs {
        let rep = simulate_transition(nl, from, to);
        total += rep.energy_fj;
        if rep.energy_fj > worst {
            worst = rep.energy_fj;
            worst_tr = rep.transitions;
        }
    }
    let avg_energy = if pairs.is_empty() { 0.0 } else { total / pairs.len() as f64 };
    // fJ / ns = µW; /1000 → mW.
    PowerReport {
        peak_mw: worst / period_ns / 1000.0 + leakage,
        avg_mw: avg_energy / period_ns / 1000.0 + leakage,
        worst_energy_fj: worst,
        worst_transitions: worst_tr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::netlist::Netlist;

    #[test]
    fn more_switching_more_power() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 16);
        let b = nl.input_bus("b", 16);
        let outs: Vec<_> = a.iter().zip(&b).map(|(&x, &y)| nl.xor2(x, y)).collect();
        nl.output_bus("y", &outs);
        let quiet = analyze(&nl, &[(vec![("a", 0), ("b", 0)], vec![("a", 1), ("b", 0)])]);
        let busy = analyze(&nl, &[(vec![("a", 0), ("b", 0)], vec![("a", 0xffff), ("b", 0xffff)])]);
        assert!(busy.peak_mw > quiet.peak_mw);
        assert!(busy.worst_transitions > quiet.worst_transitions);
    }

    #[test]
    fn peak_at_least_avg() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 8);
        let mut acc = a[0];
        for &x in &a[1..] {
            acc = nl.xor2(acc, x);
        }
        nl.output_bus("y", &[acc]);
        let pairs: Vec<_> = (0..8u64)
            .map(|i| (vec![("a", i * 3 % 256)], vec![("a", i * 97 % 256)]))
            .collect();
        let rep = analyze(&nl, &pairs);
        assert!(rep.peak_mw >= rep.avg_mw);
        assert!(rep.peak_mw > 0.0);
    }
}
