//! Reusable circuit components: reduction trees, multiplexers, barrel
//! shifters, leading-zero counters, adders, decoders, priority encoders.
//!
//! These are the building blocks the paper's block diagrams are drawn from:
//! the standard posit decoder needs the *sequential* LZC → barrel-shifter
//! chain; the b-posit decoder needs only the one-hot logic + wide one-hot
//! mux; the float decoder needs LZC + shifter for subnormals. Costs and
//! depths therefore emerge from structure, not hand-tuned constants.

use super::netlist::{Bus, NetId, Netlist};

/// Balanced OR-reduction tree.
pub fn or_reduce(nl: &mut Netlist, bits: &[NetId]) -> NetId {
    reduce(nl, bits, |nl, a, b| nl.or2(a, b))
}

/// Balanced AND-reduction tree.
pub fn and_reduce(nl: &mut Netlist, bits: &[NetId]) -> NetId {
    reduce(nl, bits, |nl, a, b| nl.and2(a, b))
}

/// Balanced XOR-reduction tree.
pub fn xor_reduce(nl: &mut Netlist, bits: &[NetId]) -> NetId {
    reduce(nl, bits, |nl, a, b| nl.xor2(a, b))
}

/// NOR-reduction (OR tree + inverter): the posit "chck" zero/NaR detector.
pub fn nor_reduce(nl: &mut Netlist, bits: &[NetId]) -> NetId {
    let o = or_reduce(nl, bits);
    nl.not(o)
}

fn reduce(
    nl: &mut Netlist,
    bits: &[NetId],
    mut f: impl FnMut(&mut Netlist, NetId, NetId) -> NetId,
) -> NetId {
    assert!(!bits.is_empty());
    let mut level = bits.to_vec();
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        for pair in level.chunks(2) {
            next.push(if pair.len() == 2 { f(nl, pair[0], pair[1]) } else { pair[0] });
        }
        level = next;
    }
    level[0]
}

/// Bitwise XOR of a bus with a single broadcast bit.
pub fn xor_broadcast(nl: &mut Netlist, bit: NetId, bus: &[NetId]) -> Bus {
    bus.iter().map(|&b| nl.xor2(bit, b)).collect()
}

/// Per-bit 2:1 mux over two equal-width buses: out = s ? b : a.
pub fn mux2_bus(nl: &mut Netlist, s: NetId, a: &[NetId], b: &[NetId]) -> Bus {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| nl.mux2(s, x, y)).collect()
}

/// One-hot mux (AND-OR): out = Σ_k sel[k]·in[k]. This is the paper's core
/// b-posit structure: a k-input mux whose select is a one-hot string; depth
/// is O(log k) regardless of input width.
pub fn mux_onehot(nl: &mut Netlist, sels: &[NetId], inputs: &[&[NetId]]) -> Bus {
    assert_eq!(sels.len(), inputs.len());
    let width = inputs[0].len();
    assert!(inputs.iter().all(|i| i.len() == width));
    let mut out = Vec::with_capacity(width);
    for bit in 0..width {
        let terms: Vec<NetId> =
            sels.iter().zip(inputs).map(|(&s, inp)| nl.and2(s, inp[bit])).collect();
        out.push(or_reduce(nl, &terms));
    }
    out
}

/// Binary-select mux over 2^k inputs via a mux2 tree (used where selects
/// are binary-encoded, e.g. shifter stages).
pub fn mux_binary(nl: &mut Netlist, sels: &[NetId], inputs: &[&[NetId]]) -> Bus {
    assert_eq!(inputs.len(), 1 << sels.len());
    let width = inputs[0].len();
    let mut layer: Vec<Bus> = inputs.iter().map(|i| i.to_vec()).collect();
    for &s in sels {
        let mut next = Vec::with_capacity(layer.len() / 2);
        for pair in layer.chunks(2) {
            next.push(mux2_bus(nl, s, &pair[0], &pair[1]));
        }
        layer = next;
    }
    assert_eq!(layer.len(), 1);
    assert_eq!(layer[0].len(), width);
    layer.pop().unwrap()
}

/// Logarithmic left barrel shifter (shift toward MSB, zero fill).
/// `amount` is little-endian; stage k shifts by 2^k.
pub fn barrel_shift_left(nl: &mut Netlist, bits: &[NetId], amount: &[NetId]) -> Bus {
    let zero = nl.zero();
    let mut cur: Bus = bits.to_vec();
    for (k, &a) in amount.iter().enumerate() {
        let sh = 1usize << k;
        let mut shifted = Vec::with_capacity(cur.len());
        for i in 0..cur.len() {
            let from = if i >= sh { cur[i - sh] } else { zero };
            shifted.push(nl.mux2(a, cur[i], from));
        }
        cur = shifted;
    }
    cur
}

/// Logarithmic right barrel shifter (shift toward LSB, zero fill).
pub fn barrel_shift_right(nl: &mut Netlist, bits: &[NetId], amount: &[NetId]) -> Bus {
    let zero = nl.zero();
    let mut cur: Bus = bits.to_vec();
    for (k, &a) in amount.iter().enumerate() {
        let sh = 1usize << k;
        let mut shifted = Vec::with_capacity(cur.len());
        for i in 0..cur.len() {
            let from = if i + sh < cur.len() { cur[i + sh] } else { zero };
            shifted.push(nl.mux2(a, cur[i], from));
        }
        cur = shifted;
    }
    cur
}

/// Leading-zero counter over `bits` given **MSB-first** (divide & conquer,
/// the "optimal circuits" the paper's §1.3 mentions: logarithmic depth).
/// Returns (count, all_zero): `count` is ⌈log2(len+1)⌉ bits little-endian;
/// when every bit is 0, count reads `len`.
pub fn lzc_msb_first(nl: &mut Netlist, bits: &[NetId]) -> (Bus, NetId) {
    // Pad at the low end (after the LSB) with constant ones so the padded
    // width is a power of two without affecting the count for real inputs.
    let len = bits.len();
    let p = len.next_power_of_two();
    let one = nl.one();
    let mut padded = bits.to_vec();
    padded.extend(std::iter::repeat(one).take(p - len));
    let (valid, count) = lzc_rec(nl, &padded);
    let all_zero = nl.not(valid);
    // With the 1-padding, any non-zero input yields the exact count in
    // log2(p) bits, and an all-zero input yields the count of the padded
    // run. When len < p that padded count IS `len` (correct). When len == p
    // the true count `len` needs one more bit: gate the low bits with
    // `valid` and emit `all_zero` as the MSB so the output reads exactly
    // `len`.
    let out = if p == len {
        let mut o: Bus = count.iter().map(|&c| nl.and2(c, valid)).collect();
        o.push(all_zero);
        o
    } else {
        count
    };
    (out, all_zero)
}

/// Recursive LZC core on power-of-two MSB-first slices.
/// Returns (any_one, count little-endian with log2(len) bits).
fn lzc_rec(nl: &mut Netlist, bits: &[NetId]) -> (NetId, Bus) {
    if bits.len() == 1 {
        return (bits[0], Vec::new());
    }
    let half = bits.len() / 2;
    let (v_hi, c_hi) = lzc_rec(nl, &bits[..half]);
    let (v_lo, c_lo) = lzc_rec(nl, &bits[half..]);
    let valid = nl.or2(v_hi, v_lo);
    // If the high half has a one: count = 0 ++ c_hi, else: count = 1 ++ c_lo.
    let mut count = Vec::with_capacity(c_hi.len() + 1);
    for i in 0..c_hi.len() {
        count.push(nl.mux2(v_hi, c_lo[i], c_hi[i]));
    }
    count.push(nl.not(v_hi));
    (valid, count)
}

/// Ripple-carry adder. Returns (sum, carry_out).
pub fn ripple_add(nl: &mut Netlist, a: &[NetId], b: &[NetId], cin: NetId) -> (Bus, NetId) {
    assert_eq!(a.len(), b.len());
    let mut c = cin;
    let mut sum = Vec::with_capacity(a.len());
    for i in 0..a.len() {
        let axb = nl.xor2(a[i], b[i]);
        sum.push(nl.xor2(axb, c));
        let t1 = nl.and2(a[i], b[i]);
        let t2 = nl.and2(axb, c);
        c = nl.or2(t1, t2);
    }
    (sum, c)
}

/// Subtractor a − b via a + !b + 1. Returns (diff, carry_out) where
/// carry_out = 1 means no borrow (a ≥ b for unsigned operands).
pub fn ripple_sub(nl: &mut Netlist, a: &[NetId], b: &[NetId]) -> (Bus, NetId) {
    let nb: Bus = b.iter().map(|&x| nl.not(x)).collect();
    let one = nl.one();
    ripple_add(nl, a, &nb, one)
}

/// Incrementer: a + cin (half-adder chain). Returns (sum, carry_out).
pub fn incrementer(nl: &mut Netlist, a: &[NetId], cin: NetId) -> (Bus, NetId) {
    let mut c = cin;
    let mut sum = Vec::with_capacity(a.len());
    for &bit in a {
        sum.push(nl.xor2(bit, c));
        c = nl.and2(bit, c);
    }
    (sum, c)
}

/// Two's complement: !a + 1. Returns (negated, carry_out).
pub fn twos_complement(nl: &mut Netlist, a: &[NetId]) -> (Bus, NetId) {
    let na: Bus = a.iter().map(|&x| nl.not(x)).collect();
    let one = nl.one();
    incrementer(nl, &na, one)
}

/// Conditional two's complement: negate when `neg` is 1 (XOR + masked
/// increment) — the full-cost path the paper's XOR-only shortcut avoids.
pub fn cond_twos_complement(nl: &mut Netlist, neg: NetId, a: &[NetId]) -> Bus {
    let x = xor_broadcast(nl, neg, a);
    let (sum, _) = incrementer(nl, &x, neg);
    sum
}

/// Binary decoder: k select bits → up to `n_out` one-hot outputs
/// (n_out ≤ 2^k; extra codes are unused).
pub fn binary_decoder(nl: &mut Netlist, sel: &[NetId], n_out: usize) -> Bus {
    assert!(n_out <= 1 << sel.len());
    let nsel: Bus = sel.iter().map(|&s| nl.not(s)).collect();
    let mut out = Vec::with_capacity(n_out);
    for code in 0..n_out {
        let lits: Vec<NetId> =
            sel.iter()
                .enumerate()
                .map(|(i, &s)| if code >> i & 1 == 1 { s } else { nsel[i] })
                .collect();
        out.push(and_reduce(nl, &lits));
    }
    out
}

/// Priority encoder specialised for a one-hot input: binary index of the
/// set bit (pure OR trees; undefined when no bit or multiple bits are set).
pub fn onehot_to_binary(nl: &mut Netlist, onehot: &[NetId]) -> Bus {
    let width = (usize::BITS - (onehot.len() - 1).leading_zeros()).max(1) as usize;
    let mut out = Vec::with_capacity(width);
    for j in 0..width {
        let terms: Vec<NetId> = onehot
            .iter()
            .enumerate()
            .filter(|(k, _)| k >> j & 1 == 1)
            .map(|(_, &n)| n)
            .collect();
        out.push(if terms.is_empty() { nl.zero() } else { or_reduce(nl, &terms) });
    }
    out
}

/// All suffix ORs of a bus in log depth (Sklansky parallel prefix):
/// out[i] = bits[i] | bits[i+1] | … | bits[n-1].
pub fn suffix_or_tree(nl: &mut Netlist, bits: &[NetId]) -> Bus {
    let n = bits.len();
    let mut cur: Bus = bits.to_vec();
    let mut step = 1;
    while step < n {
        let mut next = cur.clone();
        for i in 0..n {
            if i + step < n {
                next[i] = nl.or2(cur[i], cur[i + step]);
            }
        }
        cur = next;
        step <<= 1;
    }
    cur
}

/// Equality with a constant: AND of per-bit literals.
pub fn eq_const(nl: &mut Netlist, bus: &[NetId], value: u64) -> NetId {
    let lits: Vec<NetId> = bus
        .iter()
        .enumerate()
        .map(|(i, &b)| if value >> i & 1 == 1 { b } else { nl.not(b) })
        .collect();
    and_reduce(nl, &lits)
}

/// Constant bus of the low `width` bits of `value`.
pub fn const_bus(nl: &mut Netlist, value: u64, width: usize) -> Bus {
    (0..width)
        .map(|i| if value >> i & 1 == 1 { nl.one() } else { nl.zero() })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::netlist::Netlist;
    use crate::hw::sim::eval;

    fn run1(nl: &Netlist, inputs: &[(&str, u64)], out: &str) -> u64 {
        eval(nl, inputs).into_iter().find(|(n, _)| n == out).unwrap().1
    }

    #[test]
    fn reductions() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 8);
        let o = or_reduce(&mut nl, &a);
        let an = and_reduce(&mut nl, &a);
        let x = xor_reduce(&mut nl, &a);
        let nr = nor_reduce(&mut nl, &a);
        nl.output_bus("or", &[o]);
        nl.output_bus("and", &[an]);
        nl.output_bus("xor", &[x]);
        nl.output_bus("nor", &[nr]);
        for v in [0u64, 1, 0x80, 0xff, 0x5a, 0x7f] {
            assert_eq!(run1(&nl, &[("a", v)], "or"), (v != 0) as u64);
            assert_eq!(run1(&nl, &[("a", v)], "and"), (v == 0xff) as u64);
            assert_eq!(run1(&nl, &[("a", v)], "xor"), (v.count_ones() & 1) as u64);
            assert_eq!(run1(&nl, &[("a", v)], "nor"), (v == 0) as u64);
        }
    }

    #[test]
    fn onehot_mux_selects() {
        let mut nl = Netlist::new();
        let s = nl.input_bus("s", 3);
        let a = nl.input_bus("a", 4);
        let b = nl.input_bus("b", 4);
        let c = nl.input_bus("c", 4);
        let o = mux_onehot(&mut nl, &s, &[&a, &b, &c]);
        nl.output_bus("o", &o);
        let base = [("a", 3u64), ("b", 9u64), ("c", 14u64)];
        for (i, want) in [(1u64, 3u64), (2, 9), (4, 14)] {
            let mut ins = base.to_vec();
            ins.push(("s", i));
            assert_eq!(run1(&nl, &ins, "o"), want);
        }
    }

    #[test]
    fn barrel_shifters() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 16);
        let sh = nl.input_bus("sh", 4);
        let l = barrel_shift_left(&mut nl, &a, &sh);
        let r = barrel_shift_right(&mut nl, &a, &sh);
        nl.output_bus("l", &l);
        nl.output_bus("r", &r);
        for (v, s) in [(0x1234u64, 0u64), (0x1234, 4), (0xffff, 15), (0x0001, 7)] {
            assert_eq!(run1(&nl, &[("a", v), ("sh", s)], "l"), (v << s) & 0xffff);
            assert_eq!(run1(&nl, &[("a", v), ("sh", s)], "r"), v >> s);
        }
    }

    #[test]
    fn lzc_exhaustive_8bit() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 8); // little-endian input bus
        let msb_first: Vec<_> = a.iter().rev().copied().collect();
        let (count, all_zero) = lzc_msb_first(&mut nl, &msb_first);
        nl.output_bus("count", &count);
        nl.output_bus("z", &[all_zero]);
        for v in 0..256u64 {
            let expect = if v == 0 { 8 } else { (v as u8).leading_zeros() as u64 };
            assert_eq!(run1(&nl, &[("a", v)], "count"), expect, "lzc({v:#04x})");
            assert_eq!(run1(&nl, &[("a", v)], "z"), (v == 0) as u64);
        }
    }

    #[test]
    fn lzc_non_power_of_two() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 11);
        let msb_first: Vec<_> = a.iter().rev().copied().collect();
        let (count, _) = lzc_msb_first(&mut nl, &msb_first);
        nl.output_bus("count", &count);
        for v in [0u64, 1, 0x400, 0x3ff, 0x200, 5] {
            let expect = if v == 0 { 11 } else { 10 - (63 - v.leading_zeros() as u64) };
            assert_eq!(run1(&nl, &[("a", v)], "count"), expect, "lzc11({v:#x})");
        }
    }

    #[test]
    fn adders() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 8);
        let b = nl.input_bus("b", 8);
        let z = nl.zero();
        let (sum, cout) = ripple_add(&mut nl, &a, &b, z);
        let (diff, nb) = ripple_sub(&mut nl, &a, &b);
        nl.output_bus("sum", &sum);
        nl.output_bus("cout", &[cout]);
        nl.output_bus("diff", &diff);
        nl.output_bus("noborrow", &[nb]);
        for (x, y) in [(0u64, 0u64), (1, 1), (255, 1), (200, 100), (17, 42)] {
            assert_eq!(run1(&nl, &[("a", x), ("b", y)], "sum"), (x + y) & 0xff);
            assert_eq!(run1(&nl, &[("a", x), ("b", y)], "cout"), (x + y) >> 8);
            assert_eq!(run1(&nl, &[("a", x), ("b", y)], "diff"), x.wrapping_sub(y) & 0xff);
            assert_eq!(run1(&nl, &[("a", x), ("b", y)], "noborrow"), (x >= y) as u64);
        }
    }

    #[test]
    fn twos_complement_and_incrementer() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 6);
        let neg = nl.input_bus("neg", 1)[0];
        let (tc, _) = twos_complement(&mut nl, &a);
        let cond = cond_twos_complement(&mut nl, neg, &a);
        nl.output_bus("tc", &tc);
        nl.output_bus("cond", &cond);
        for v in 0..64u64 {
            assert_eq!(run1(&nl, &[("a", v), ("neg", 0)], "tc"), v.wrapping_neg() & 63);
            assert_eq!(run1(&nl, &[("a", v), ("neg", 0)], "cond"), v);
            assert_eq!(run1(&nl, &[("a", v), ("neg", 1)], "cond"), v.wrapping_neg() & 63);
        }
    }

    #[test]
    fn decoder_and_priority_encoder_roundtrip() {
        let mut nl = Netlist::new();
        let s = nl.input_bus("s", 3);
        let oh = binary_decoder(&mut nl, &s, 6);
        let back = onehot_to_binary(&mut nl, &oh);
        nl.output_bus("oh", &oh);
        nl.output_bus("back", &back);
        for v in 0..6u64 {
            assert_eq!(run1(&nl, &[("s", v)], "oh"), 1 << v);
            assert_eq!(run1(&nl, &[("s", v)], "back"), v);
        }
    }

    #[test]
    fn eq_const_works() {
        let mut nl = Netlist::new();
        let a = nl.input_bus("a", 8);
        let e = eq_const(&mut nl, &a, 0x5a);
        nl.output_bus("e", &[e]);
        assert_eq!(run1(&nl, &[("a", 0x5a)], "e"), 1);
        assert_eq!(run1(&nl, &[("a", 0x5b)], "e"), 0);
    }

    #[test]
    fn mux_binary_selects() {
        let mut nl = Netlist::new();
        let s = nl.input_bus("s", 2);
        let buses: Vec<Bus> = (0..4).map(|i| nl.input_bus(&format!("i{i}"), 4)).collect();
        let refs: Vec<&[NetId]> = buses.iter().map(|b| b.as_slice()).collect();
        let o = mux_binary(&mut nl, &s, &refs);
        nl.output_bus("o", &o);
        for k in 0..4u64 {
            let ins = vec![("i0", 1u64), ("i1", 5), ("i2", 9), ("i3", 13), ("s", k)];
            assert_eq!(run1(&nl, &ins, "o"), 1 + 4 * k);
        }
    }
}
