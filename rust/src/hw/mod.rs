//! Gate-level hardware substrate: standard-cell library, structural
//! netlists, logic/timing simulation, static timing analysis, switching
//! power estimation, and the six decoder/encoder circuit designs the paper
//! evaluates (Figs 8–13).

pub mod cell;
pub mod netlist;
pub mod sim;
pub mod sta;
pub mod power;
pub mod components;
pub mod report;
pub mod designs;
