//! Accuracy analysis: decimal-accuracy curves, Golden Zone, fovea, and
//! bit-pattern census — everything needed to regenerate the paper's
//! Figs 6a/6b (16-bit posit vs b-posit) and Fig 7 (float32 / posit32 /
//! takum32 / b-posit32).
//!
//! Decimal accuracy at a binary scale e follows the posit literature's
//! convention: a format carrying `fb` explicit fraction bits in that binade
//! resolves relative steps of 2^−(fb+1) (half-ulp rounding), i.e.
//! `decimals(e) = (fb(e)+1)·log10(2)`.

use crate::formats::Codec;

/// One point of an accuracy plot: binade scale and decimals of accuracy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccuracyPoint {
    /// Binary scale (floor(log2 |x|)).
    pub scale: i32,
    /// Decimals of accuracy; 0 when the binade is unrepresentable.
    pub decimals: f64,
}

/// Decimals of accuracy of `fmt` for values in the binade 2^scale.
pub fn decimals_at<C: Codec + ?Sized>(fmt: &C, scale: i32) -> f64 {
    if scale < fmt.min_scale() || scale > fmt.max_scale() {
        return 0.0;
    }
    let fb = fmt.frac_bits_at(scale);
    (fb as f64 + 1.0) * std::f64::consts::LOG10_2
}

/// Full accuracy curve over [lo, hi] binades (the tent plots of Figs 6/7).
pub fn curve<C: Codec + ?Sized>(fmt: &C, lo: i32, hi: i32) -> Vec<AccuracyPoint> {
    (lo..=hi).map(|scale| AccuracyPoint { scale, decimals: decimals_at(fmt, scale) }).collect()
}

/// The fovea: the (closed) binade range achieving maximum accuracy.
pub fn fovea<C: Codec + ?Sized>(fmt: &C) -> (i32, i32, f64) {
    let pts = curve(fmt, fmt.min_scale(), fmt.max_scale());
    let max = pts.iter().map(|p| p.decimals).fold(0.0, f64::max);
    let lo = pts.iter().find(|p| p.decimals == max).unwrap().scale;
    let hi = pts.iter().rev().find(|p| p.decimals == max).unwrap().scale;
    (lo, hi, max)
}

/// The Golden Zone (de Dinechin): binades where `fmt` is at least as
/// accurate as `baseline`. Returns the contiguous range around scale 0.
pub fn golden_zone<A: Codec + ?Sized, B: Codec + ?Sized>(fmt: &A, baseline: &B) -> (i32, i32) {
    let mut lo = 0;
    while lo - 1 >= fmt.min_scale().max(-2000)
        && decimals_at(fmt, lo - 1) >= decimals_at(baseline, lo - 1)
    {
        lo -= 1;
    }
    let mut hi = 0;
    while hi + 1 <= fmt.max_scale().min(2000)
        && decimals_at(fmt, hi + 1) >= decimals_at(baseline, hi + 1)
    {
        hi += 1;
    }
    (lo, hi)
}

/// Fraction of all finite nonzero bit patterns whose value lies in
/// [2^lo, 2^hi) by magnitude (the paper's "75 % of the bit patterns fall
/// within that region" census). Computed analytically from per-binade
/// pattern counts — exact, no enumeration.
pub fn pattern_census<C: Codec + ?Sized>(fmt: &C, lo: i32, hi: i32) -> f64 {
    let mut in_zone = 0u128;
    let mut total = 0u128;
    for scale in fmt.min_scale()..=fmt.max_scale() {
        let count = 1u128 << fmt.frac_bits_at(scale);
        total += count;
        if scale >= lo && scale < hi {
            in_zone += count;
        }
    }
    in_zone as f64 / total as f64
}

/// Empirical accuracy check: measure −log10 of the worst relative
/// round-trip error over `samples` log-uniform values in the binade, via
/// the real codec. Used by tests to pin the analytic curve to reality.
pub fn empirical_decimals<C: Codec + ?Sized>(fmt: &C, scale: i32, samples: u32) -> f64 {
    let mut worst: f64 = 0.0;
    for i in 0..samples {
        let frac = (i as f64 + 0.5) / samples as f64; // mid-points: worst case
        let x = (1.0 + frac) * f64::powi(2.0, scale);
        let back = fmt.roundtrip_f64(x);
        let rel = ((back - x) / x).abs();
        worst = worst.max(rel);
    }
    if worst == 0.0 {
        f64::INFINITY
    } else {
        -worst.log10()
    }
}

/// Render a set of curves as CSV (scale, then one decimals column per fmt).
pub fn curves_csv(fmts: &[(&str, &dyn Codec)], lo: i32, hi: i32) -> String {
    let mut s = String::from("scale");
    for (name, _) in fmts {
        s.push(',');
        s.push_str(name);
    }
    s.push('\n');
    for scale in lo..=hi {
        s.push_str(&scale.to_string());
        for (_, f) in fmts {
            s.push_str(&format!(",{:.4}", decimals_at(*f, scale)));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::ieee::F32;
    use crate::formats::posit::{BP16_E3, BP32, P16, P32};
    use crate::formats::takum::T32;

    #[test]
    fn bp32_fovea_matches_paper() {
        // Paper §1.4: b-posit32 fovea covers 2^-32 … 2^32 with 24 fraction
        // bits ("twice the accuracy of IEEE floats in that region").
        let (lo, hi, max) = fovea(&BP32);
        assert_eq!(lo, -32);
        assert_eq!(hi, 31);
        assert!((max - 25.0 * std::f64::consts::LOG10_2).abs() < 1e-12);
        // float32 fovea decimals: 24·log10(2) ≈ 7.22 — exactly one bit less.
        assert!(decimals_at(&BP32, 0) > decimals_at(&F32, 0));
    }

    #[test]
    fn p32_fovea_matches_paper() {
        // "For standard posits, [the fovea] ranges from 1/16 to 16":
        // scales −4..3 with es=2 (regime size 2).
        let (lo, hi, _) = fovea(&P32);
        assert_eq!(lo, -4);
        assert_eq!(hi, 3);
        // "four additional bits of significand compared to IEEE floats"
        assert_eq!(P32.frac_bits_at(0), 27);
        assert_eq!(crate::formats::Codec::frac_bits_at(&F32, 0), 23);
    }

    #[test]
    fn golden_zone_p32_and_bp32_vs_f32() {
        // Paper: standard posit32 Golden Zone ≈ 2^-20…2^20; b-posit32
        // extends it to 2^-64…2^64.
        let (lo, hi) = golden_zone(&P32, &F32);
        assert!((-26..=-16).contains(&lo), "p32 zone lo = {lo}");
        assert!((15..=25).contains(&hi), "p32 zone hi = {hi}");
        let (blo, bhi) = golden_zone(&BP32, &F32);
        assert_eq!(blo, -64, "bp32 zone lo");
        assert_eq!(bhi, 63, "bp32 zone hi");
    }

    #[test]
    fn census_75_percent_in_golden_zone() {
        // Paper: "75% of the bit patterns fall within that region" (2^±64).
        let frac = pattern_census(&BP32, -64, 64);
        assert!((frac - 0.75).abs() < 0.01, "census = {frac}");
    }

    #[test]
    fn fig6_bposit16_floor_two_decimals() {
        // Fig 6b: ⟨16,6,3⟩ accuracy "never drops below two decimals".
        let pts = curve(&BP16_E3, BP16_E3.min_scale(), BP16_E3.max_scale());
        let min = pts.iter().map(|p| p.decimals).fold(f64::MAX, f64::min);
        assert!(min >= 2.0, "min decimals = {min}");
        // …and costs ~0.3 decimals at the fovea vs the standard posit.
        let drop = decimals_at(&P16, 0) - decimals_at(&BP16_E3, 0);
        assert!((0.2..=0.4).contains(&drop), "fovea cost = {drop}");
    }

    #[test]
    fn fig6_standard_posit16_tapers_to_zero() {
        // Fig 6a: ⟨16,2⟩ accuracy reaches ~0 decimals at the extremes
        // (no fraction bits near maxpos/minpos — only the rounding half-bit).
        assert_eq!(P16.frac_bits_at(P16.max_scale()), 0);
        assert_eq!(P16.frac_bits_at(P16.min_scale()), 0);
        assert!(decimals_at(&P16, P16.max_scale()) < 0.5);
    }

    #[test]
    fn fig7_curve_shapes() {
        // Fig 7's qualitative content, checked pointwise:
        // near 1.0: posit32 > bposit32 > float32.
        assert!(decimals_at(&P32, 0) > decimals_at(&BP32, 0));
        assert!(decimals_at(&BP32, 0) > decimals_at(&F32, 0));
        // at 2^130: float32/posit32 dead, b-posit32 & takum32 alive.
        assert_eq!(decimals_at(&F32, 130), 0.0);
        assert_eq!(decimals_at(&P32, 130), 0.0);
        assert!(decimals_at(&BP32, 100) > 5.0);
        assert!(decimals_at(&T32, 100) > 5.0);
        // at extreme 2^240: only takum survives.
        assert_eq!(decimals_at(&BP32, 240), 0.0);
        assert!(decimals_at(&T32, 240) > 5.0);
        // takum has the "sharp point": strictly more accurate at 0 than ±8.
        assert!(decimals_at(&T32, 0) > decimals_at(&T32, 8));
        assert!(decimals_at(&T32, 0) >= decimals_at(&P32, 0) - 0.5);
    }

    #[test]
    fn empirical_matches_analytic() {
        // The analytic curve must agree with measured round-trip error to
        // within the half-ulp convention (±0.35 decimals).
        let cases: [(&dyn Codec, i32); 3] = [(&BP32, 0), (&BP32, -100), (&P32, 10)];
        for (fmt, scale) in cases {
            let analytic = decimals_at(fmt, scale);
            let measured = empirical_decimals(fmt, scale, 4000);
            assert!(
                (measured - analytic).abs() < 0.35,
                "scale {scale}: analytic {analytic} vs measured {measured}"
            );
        }
    }

    #[test]
    fn csv_renders() {
        let s = curves_csv(&[("f32", &F32), ("bp32", &BP32)], -4, 4);
        assert!(s.lines().count() == 10);
        assert!(s.starts_with("scale,f32,bp32"));
    }
}
