//! Hand-rolled CLI (the vendored dependency set has no clap).
//!
//! Subcommands:
//! - `info`                    — build/config summary
//! - `codec <fmt> <value…>`    — encode/decode values in any format
//! - `accuracy [--csv DIR]`    — Golden Zone / fovea / census + Fig 6/7 CSVs
//! - `tables`                  — gate-level PPA tables (Tables 5/6, Fig 16)
//! - `vector-bench`            — scalar vs vector codec + kernel throughput,
//!                               emitted as BENCH_vector_codec.json
//! - `gemm-bench`              — serial vs sharded blocked GEMM (quire +
//!                               f32 paths), emitted as BENCH_vector_gemm.json
//! - `solver-bench`            — per-tier CG convergence on sparse SPD
//!                               operators (SpMV bit-identity + quire-vs-fast
//!                               gates), emitted as BENCH_solver.json
//! - `serve`                   — run the inference server (native backend by
//!                               default; `--http ADDR` exposes /metrics and
//!                               /infer over a real listener)
//! - `serve-bench`             — e2e native-serving benchmark with a logits
//!                               parity gate, emitted as BENCH_serve_native.json
//! - `certify-bench`           — interval-certification probes (bound width vs
//!                               observed quantization error, bit-pinned against
//!                               the Python mirror) + `--certify-rate` serving
//!                               overhead, emitted as BENCH_certify.json
//!
//! Bench subcommands validate the output JSON path *before* running (a
//! long bench that dies on the final write is wasted work) and report
//! unwritable paths as clean errors — the binary exits non-zero, never
//! panics.

use crate::accuracy;
use crate::coordinator::backend::{BackendKind, WeightFormat};
use crate::formats::{ieee, posit, takum, Codec, Decoded};
use crate::hw::designs::{bposit_dec, bposit_enc, float_dec, float_enc, posit_dec, posit_enc};
use crate::hw::report;
use crate::vector::lane::LaneElem;

/// `serve` options (native serving is the default everywhere).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub requests: usize,
    pub artifact_dir: String,
    pub backend: BackendKind,
    pub format: WeightFormat,
    /// Bind a real HTTP listener here (e.g. `127.0.0.1:8080`) and serve
    /// until killed instead of running the self-driving demo loop.
    pub http: Option<String>,
    pub deadline_ms: Option<u64>,
    /// Serve a deterministic synthetic model (no artifacts needed).
    pub synthetic: bool,
    /// Record request/batch spans for `GET /debug/tracez` (on by
    /// default; `--no-tracing` turns span retention off — histograms
    /// and counters stay on either way).
    pub tracing: bool,
    /// Extra tiers to register alongside `format` (`--models
    /// f32,bp64` or `--models all`): one listener serves them all at
    /// `/v1/infer/<name>` over the same weights, sharing the
    /// content-hash weight cache. Native backend only.
    pub models: Vec<WeightFormat>,
    /// Per-tier admission budget override (`--max-inflight N`).
    pub max_inflight: Option<usize>,
    /// Certify every Nth request per tier through the interval twin
    /// (`--certify-rate N`; 0 = off).
    pub certify_rate: usize,
}

/// `serve-bench` options.
#[derive(Clone, Debug)]
pub struct ServeBenchOpts {
    pub requests: usize,
    pub clients: usize,
    pub format: WeightFormat,
    /// Small model + few requests: the CI smoke configuration.
    pub small: bool,
    pub json: Option<String>,
}

/// `certify-bench` options: interval-certification probes (bound width
/// vs observed quantization error, transliteration-pinned) plus the
/// serving overhead of `--certify-rate N` sampling.
#[derive(Clone, Debug)]
pub struct CertifyBenchOpts {
    /// Requests for the serving-overhead section.
    pub requests: usize,
    pub clients: usize,
    /// Sampling rate under test (certify every Nth request).
    pub certify_rate: usize,
    /// Small model + few requests: the CI smoke configuration. The
    /// probes always run at full (tiny) size — only the overhead
    /// section shrinks.
    pub small: bool,
    pub json: Option<String>,
}

/// `solver-bench` options: per-tier CG convergence trajectories on the
/// synthetic SPD operators (see `crate::solver`).
#[derive(Clone, Debug)]
pub struct SolverBenchOpts {
    /// Poisson grid edges (n = grid² unknowns).
    pub grids: Vec<usize>,
    /// Random diagonally-dominant operator sizes.
    pub dd_sizes: Vec<usize>,
    /// Relative residual tolerance.
    pub tol: f64,
    /// Iteration cap per solve.
    pub max_iters: usize,
    /// Quire tiers are skipped above this many unknowns (they are exact
    /// but slow; the fast tiers still cover the size).
    pub quire_max: usize,
    pub json: Option<String>,
}

impl Default for SolverBenchOpts {
    fn default() -> SolverBenchOpts {
        SolverBenchOpts {
            grids: vec![32, 128, 1024],
            dd_sizes: vec![1024, 16384, 262144],
            tol: 1e-6,
            max_iters: 500,
            quire_max: 16384,
            json: Some("BENCH_solver.json".to_string()),
        }
    }
}

/// Parsed command line.
#[derive(Debug)]
pub enum Command {
    Info,
    Codec { fmt: String, values: Vec<String> },
    Accuracy { csv_dir: Option<String> },
    Tables,
    VectorBench { len: usize, bits: u32, json: Option<String> },
    GemmBench { sizes: Vec<usize>, quire_max: usize, json: Option<String> },
    SolverBench(SolverBenchOpts),
    Serve(ServeOpts),
    ServeBench(ServeBenchOpts),
    CertifyBench(CertifyBenchOpts),
    Help,
}

/// Parse argv (excluding program name).
pub fn parse(args: &[String]) -> Result<Command, String> {
    let mut it = args.iter();
    let Some(cmd) = it.next() else { return Ok(Command::Help) };
    match cmd.as_str() {
        "info" => Ok(Command::Info),
        "help" | "--help" | "-h" => Ok(Command::Help),
        "codec" => {
            let fmt = it.next().ok_or("codec: missing format (e.g. bp32)")?.clone();
            let values: Vec<String> = it.cloned().collect();
            if values.is_empty() {
                return Err("codec: provide at least one value or 0x-pattern".into());
            }
            Ok(Command::Codec { fmt, values })
        }
        "accuracy" => {
            let mut csv_dir = None;
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--csv" => csv_dir = Some(it.next().ok_or("--csv needs a dir")?.clone()),
                    other => return Err(format!("accuracy: unknown flag {other}")),
                }
            }
            Ok(Command::Accuracy { csv_dir })
        }
        "tables" => Ok(Command::Tables),
        "vector-bench" => {
            let mut len = 65536usize;
            let mut bits = 32u32;
            let mut json: Option<Option<String>> = None; // None = default for the width
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--len" => {
                        len = it.next().ok_or("--len needs N")?.parse().map_err(|e| e.to_string())?
                    }
                    "--bits" => {
                        bits = it
                            .next()
                            .ok_or("--bits needs 32 or 64")?
                            .parse()
                            .map_err(|e: std::num::ParseIntError| e.to_string())?;
                        if bits != 32 && bits != 64 {
                            return Err("vector-bench: --bits must be 32 or 64".into());
                        }
                    }
                    "--json" => {
                        json = Some(Some(it.next().ok_or("--json needs a path")?.clone()))
                    }
                    "--no-json" => json = Some(None),
                    other => return Err(format!("vector-bench: unknown flag {other}")),
                }
            }
            if len == 0 {
                return Err("vector-bench: --len must be positive".into());
            }
            let json = json.unwrap_or_else(|| {
                Some(
                    if bits == 64 { "BENCH_vector_codec64.json" } else { "BENCH_vector_codec.json" }
                        .to_string(),
                )
            });
            Ok(Command::VectorBench { len, bits, json })
        }
        "gemm-bench" => {
            let mut sizes = vec![64usize, 128, 256, 512];
            let mut quire_max = 128usize;
            let mut json = Some("BENCH_vector_gemm.json".to_string());
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--sizes" => {
                        let list = it.next().ok_or("--sizes needs a comma list (e.g. 64,128)")?;
                        sizes = list
                            .split(',')
                            .map(|s| {
                                s.trim().parse::<usize>().map_err(|e| format!("--sizes {s}: {e}"))
                            })
                            .collect::<Result<Vec<usize>, String>>()?;
                    }
                    "--quire-max" => {
                        let arg = it.next().ok_or("--quire-max needs N")?;
                        quire_max = arg.parse().map_err(|e| e.to_string())?
                    }
                    "--json" => json = Some(it.next().ok_or("--json needs a path")?.clone()),
                    "--no-json" => json = None,
                    other => return Err(format!("gemm-bench: unknown flag {other}")),
                }
            }
            if sizes.is_empty() || sizes.contains(&0) {
                return Err("gemm-bench: --sizes must be a non-empty list of positive sizes".into());
            }
            Ok(Command::GemmBench { sizes, quire_max, json })
        }
        "solver-bench" => {
            let mut o = SolverBenchOpts::default();
            let csv = |flag: &str, list: &str| -> Result<Vec<usize>, String> {
                list.split(',')
                    .map(|s| s.trim().parse::<usize>().map_err(|e| format!("{flag} {s}: {e}")))
                    .collect()
            };
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--small" => {
                        o.grids = vec![8, 16, 32];
                        o.dd_sizes = vec![64, 256, 1024];
                        o.max_iters = 400;
                    }
                    "--grids" => {
                        o.grids = csv("--grids", it.next().ok_or("--grids needs a comma list")?)?
                    }
                    "--dd-sizes" => {
                        let list = it.next().ok_or("--dd-sizes needs a comma list")?;
                        o.dd_sizes = csv("--dd-sizes", list)?
                    }
                    "--tol" => {
                        let arg = it.next().ok_or("--tol needs a value")?;
                        o.tol = arg.parse().map_err(|e| format!("--tol {arg}: {e}"))?
                    }
                    "--max-iters" => {
                        let arg = it.next().ok_or("--max-iters needs N")?;
                        o.max_iters = arg.parse().map_err(|e| format!("--max-iters {arg}: {e}"))?
                    }
                    "--quire-max" => {
                        let arg = it.next().ok_or("--quire-max needs N")?;
                        o.quire_max = arg.parse().map_err(|e| format!("--quire-max {arg}: {e}"))?
                    }
                    "--json" => o.json = Some(it.next().ok_or("--json needs a path")?.clone()),
                    "--no-json" => o.json = None,
                    other => return Err(format!("solver-bench: unknown flag {other}")),
                }
            }
            if o.grids.iter().any(|&g| g < 2) {
                return Err("solver-bench: --grids entries must be at least 2".into());
            }
            if o.dd_sizes.contains(&0) {
                return Err("solver-bench: --dd-sizes entries must be positive".into());
            }
            if o.grids.is_empty() && o.dd_sizes.is_empty() {
                return Err("solver-bench: no operators (empty --grids and --dd-sizes)".into());
            }
            if !(o.tol > 0.0 && o.tol.is_finite()) {
                return Err("solver-bench: --tol must be a positive finite value".into());
            }
            Ok(Command::SolverBench(o))
        }
        "serve" => {
            let mut o = ServeOpts {
                requests: 512,
                artifact_dir: crate::runtime::default_artifact_dir().display().to_string(),
                backend: BackendKind::Native,
                format: WeightFormat::Bp32,
                http: None,
                deadline_ms: None,
                synthetic: false,
                tracing: true,
                models: Vec::new(),
                max_inflight: None,
                certify_rate: 0,
            };
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--requests" => {
                        let arg = it.next().ok_or("--requests needs N")?;
                        o.requests = arg.parse().map_err(|e| e.to_string())?
                    }
                    "--artifacts" => {
                        o.artifact_dir = it.next().ok_or("--artifacts needs a dir")?.clone()
                    }
                    "--backend" => {
                        o.backend = BackendKind::parse(it.next().ok_or("--backend needs a name")?)?
                    }
                    "--format" => {
                        o.format = WeightFormat::parse(it.next().ok_or("--format needs a name")?)?
                    }
                    "--http" => o.http = Some(it.next().ok_or("--http needs ADDR:PORT")?.clone()),
                    "--deadline-ms" => {
                        let arg = it.next().ok_or("--deadline-ms needs N")?;
                        o.deadline_ms = Some(arg.parse().map_err(|e| e.to_string())?)
                    }
                    "--synthetic" => o.synthetic = true,
                    "--no-tracing" => o.tracing = false,
                    "--models" => {
                        let list = it.next().ok_or("--models needs a comma list or `all`")?;
                        o.models = if list == "all" {
                            WeightFormat::ALL.to_vec()
                        } else {
                            list.split(',')
                                .map(|s| WeightFormat::parse(s.trim()))
                                .collect::<Result<Vec<_>, String>>()?
                        };
                    }
                    "--max-inflight" => {
                        let arg = it.next().ok_or("--max-inflight needs N")?;
                        o.max_inflight = Some(arg.parse().map_err(|e| e.to_string())?)
                    }
                    "--certify-rate" => {
                        let arg = it.next().ok_or("--certify-rate needs N (0 = off)")?;
                        o.certify_rate = arg.parse().map_err(|e| e.to_string())?
                    }
                    other => return Err(format!("serve: unknown flag {other}")),
                }
            }
            if o.synthetic && o.backend == BackendKind::Pjrt {
                return Err("serve: --synthetic implies the native backend".into());
            }
            if !o.models.is_empty() {
                if o.backend == BackendKind::Pjrt {
                    return Err("serve: --models is native-backend only".into());
                }
                if o.http.is_none() {
                    return Err("serve: --models needs --http (multi-model routing is an \
                                HTTP feature)"
                        .into());
                }
            }
            Ok(Command::Serve(o))
        }
        "serve-bench" => {
            let mut o = ServeBenchOpts {
                requests: 2048,
                clients: 4,
                format: WeightFormat::Bp32,
                small: false,
                json: Some("BENCH_serve_native.json".to_string()),
            };
            let mut requests_explicit = false;
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--requests" => {
                        let arg = it.next().ok_or("--requests needs N")?;
                        o.requests = arg.parse().map_err(|e| e.to_string())?;
                        requests_explicit = true;
                    }
                    "--clients" => {
                        let arg = it.next().ok_or("--clients needs N")?;
                        o.clients = arg.parse().map_err(|e| e.to_string())?
                    }
                    "--format" => {
                        o.format = WeightFormat::parse(it.next().ok_or("--format needs a name")?)?
                    }
                    "--small" => o.small = true,
                    "--json" => {
                        o.json = Some(it.next().ok_or("--json needs a path")?.clone())
                    }
                    "--no-json" => o.json = None,
                    other => return Err(format!("serve-bench: unknown flag {other}")),
                }
            }
            // Applied after the loop so the result is flag-order
            // independent: --small lowers the default request count but
            // never overrides an explicit --requests.
            if o.small && !requests_explicit {
                o.requests = o.requests.min(256);
            }
            if o.requests == 0 || o.clients == 0 {
                return Err("serve-bench: --requests and --clients must be positive".into());
            }
            Ok(Command::ServeBench(o))
        }
        "certify-bench" => {
            let mut o = CertifyBenchOpts {
                requests: 2048,
                clients: 4,
                certify_rate: 16,
                small: false,
                json: Some("BENCH_certify.json".to_string()),
            };
            let mut requests_explicit = false;
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--requests" => {
                        let arg = it.next().ok_or("--requests needs N")?;
                        o.requests = arg.parse().map_err(|e| e.to_string())?;
                        requests_explicit = true;
                    }
                    "--clients" => {
                        let arg = it.next().ok_or("--clients needs N")?;
                        o.clients = arg.parse().map_err(|e| e.to_string())?
                    }
                    "--certify-rate" => {
                        let arg = it.next().ok_or("--certify-rate needs N")?;
                        o.certify_rate = arg.parse().map_err(|e| e.to_string())?
                    }
                    "--small" => o.small = true,
                    "--json" => o.json = Some(it.next().ok_or("--json needs a path")?.clone()),
                    "--no-json" => o.json = None,
                    other => return Err(format!("certify-bench: unknown flag {other}")),
                }
            }
            if o.small && !requests_explicit {
                o.requests = o.requests.min(256);
            }
            if o.requests == 0 || o.clients == 0 {
                return Err("certify-bench: --requests and --clients must be positive".into());
            }
            if o.certify_rate == 0 {
                return Err("certify-bench: --certify-rate must be positive (it measures \
                            the cost of sampling)"
                    .into());
            }
            Ok(Command::CertifyBench(o))
        }
        other => Err(format!("unknown command {other}; try help")),
    }
}

/// Look up a format by short name.
pub fn lookup_format(name: &str) -> Option<Box<dyn Codec>> {
    Some(match name {
        "p8" => Box::new(posit::P8),
        "p16" => Box::new(posit::P16),
        "p32" => Box::new(posit::P32),
        "p64" => Box::new(posit::P64),
        "bp16" => Box::new(posit::BP16),
        "bp32" => Box::new(posit::BP32),
        "bp64" => Box::new(posit::BP64),
        "bp16e3" => Box::new(posit::BP16_E3),
        "f16" => Box::new(ieee::F16),
        "bf16" => Box::new(ieee::BF16),
        "f32" => Box::new(ieee::F32),
        "f64" => Box::new(ieee::F64),
        "t16" => Box::new(takum::T16),
        "t32" => Box::new(takum::T32),
        "t64" => Box::new(takum::T64),
        _ => return None,
    })
}

pub const HELP: &str = "positron — b-posit reproduction (Closing the Gap Between Float and Posit Hardware Efficiency)

USAGE: positron <command> [args]

COMMANDS:
  info                       build + format-zoo summary
  codec <fmt> <v…>           encode/decode values (fmt: p16 p32 bp32 f32 t32 …;
                             values: decimals or 0x bit patterns)
  accuracy [--csv DIR]       Golden Zone / fovea / census; optional Fig-6/7 CSVs
  tables                     gate-level decode/encode PPA (paper Tables 5/6 + Fig 16)
  vector-bench [--len N] [--bits 32|64] [--json PATH | --no-json]
                             scalar vs vector codec + dot-kernel throughput;
                             writes BENCH_vector_codec.json by default, or
                             BENCH_vector_codec64.json in --bits 64 mode
                             (BP64/P64 lanes, f64 kernels, sharded codec
                             bit-identity verified)
  gemm-bench [--sizes N,N,…] [--quire-max N] [--json PATH | --no-json]
                             serial vs sharded (PALLAS_THREADS) blocked GEMM,
                             f32 + quire-exact paths, GFLOP-equivalents;
                             writes BENCH_vector_gemm.json by default
  solver-bench [--small] [--grids N,N,…] [--dd-sizes N,N,…] [--tol F]
        [--max-iters N] [--quire-max N] [--json PATH | --no-json]
                             tiered CG convergence bench: per-tier
                             (f32/bp32/quire32/f64/bp64/quire64)
                             iterations-to-tolerance, exact residual
                             trajectories and wall time on 2D Poisson
                             (n = grid²; default grids span 1k–1M
                             unknowns) and random diagonally-dominant SPD
                             operators, plus Jacobi-preconditioned f64;
                             hard-gates SpMV serial/sharded/dense
                             bit-identity and quire-vs-fast iteration
                             counts; writes BENCH_solver.json by default
  serve [--requests N] [--artifacts DIR] [--backend native|pjrt]
        [--format bp32|f32|bp64] [--http ADDR:PORT] [--deadline-ms N] [--synthetic]
        [--no-tracing] [--models f32,bp64|all] [--max-inflight N] [--certify-rate N]
                             inference server on the in-tree native backend
                             (default; needs only weights.json) or PJRT;
                             --http serves POST /v1/infer/<model>,
                             GET /v1/models, legacy POST /infer,
                             GET /metrics, GET /healthz and
                             GET /debug/tracez (?min_us= / ?limit=) on an
                             event-driven keep-alive listener
                             (docs/HTTP_API.md); --models registers extra
                             tiers over the same weights; --max-inflight
                             sets the per-tier admission budget;
                             --synthetic serves a deterministic model with
                             no artifacts; --no-tracing turns span
                             retention off (histograms stay on);
                             --certify-rate N runs every Nth request
                             through the interval twin (per-request
                             certified logit error bounds; docs/CERTIFY.md)
  serve-bench [--requests N] [--clients N] [--format bp32|f32|bp64] [--small]
        [--json PATH | --no-json]
                             e2e native serving bench: in-process + HTTP
                             logits parity vs the scalar reference (hard
                             gate), closed-loop throughput, tracing
                             overhead (spans on vs off, logits
                             bit-compared), keep-alive parity on one
                             connection, event-loop vs thread-per-conn
                             baseline, and a connections × batch ×
                             deadline scaling sweep; writes
                             BENCH_serve_native.json by default
  certify-bench [--requests N] [--clients N] [--certify-rate N] [--small]
        [--json PATH | --no-json]
                             error-certification bench: per-tier interval
                             probes (bp32/p32/bp64) on coherent-rounding
                             models — certified bound width within 10x of
                             the observed quantization error (bp64:
                             absolute width gate), every served logit
                             inside its bound, and the computed widths
                             bit-compared against constants pinned by the
                             Python Fraction mirror; plus serving
                             throughput at --certify-rate N (default 16)
                             vs uncertified with the violation counter
                             (must stay 0); writes BENCH_certify.json by
                             default
  help                       this message
";

/// Execute `codec`: returns printable lines.
pub fn run_codec(fmt: &str, values: &[String]) -> Result<Vec<String>, String> {
    let c = lookup_format(fmt).ok_or_else(|| format!("unknown format {fmt}"))?;
    let mut out = Vec::new();
    for v in values {
        if let Some(hex) = v.strip_prefix("0x") {
            let bits = u64::from_str_radix(hex, 16).map_err(|e| format!("{v}: {e}"))?;
            let d = c.decode(bits);
            out.push(format!("{} decode {v} = {} (exp {}, frac_bits {})",
                c.name(), d.to_f64(), d.exp, c.frac_bits_at(d.exp)));
        } else {
            let x: f64 = v.parse().map_err(|e| format!("{v}: {e}"))?;
            let bits = c.encode(&Decoded::from_f64(x));
            let back = c.decode(bits).to_f64();
            let relerr = if x != 0.0 { ((back - x) / x).abs() } else { 0.0 };
            out.push(format!(
                "{} encode {v} = {:#0w$x} → {} (rel err {:.3e})",
                c.name(),
                bits,
                back,
                relerr,
                w = (c.n() as usize / 4) + 2
            ));
        }
    }
    Ok(out)
}

/// Execute `accuracy`: summary lines (+ CSVs when requested).
pub fn run_accuracy(csv_dir: Option<&str>) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    let f32s = ieee::F32;
    for (name, spec) in [("posit<32,2>", posit::P32), ("b-posit<32,6,5>", posit::BP32)] {
        let (lo, hi) = accuracy::golden_zone(&spec, &f32s);
        let (flo, fhi, fdec) = accuracy::fovea(&spec);
        let census = accuracy::pattern_census(&spec, lo, hi + 1);
        out.push(format!(
            "{name}: golden zone 2^{lo}..2^{hi} ({:.1}% of patterns), fovea 2^{flo}..2^{fhi} ({fdec:.2} decimals)",
            census * 100.0
        ));
    }
    if let Some(dir) = csv_dir {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        let fig7 = accuracy::curves_csv(
            &[
                ("float32", &ieee::F32),
                ("posit32", &posit::P32),
                ("takum32", &takum::T32),
                ("bposit32", &posit::BP32),
            ],
            -260,
            260,
        );
        std::fs::write(format!("{dir}/fig7_accuracy32.csv"), fig7).map_err(|e| e.to_string())?;
        let fig6 = accuracy::curves_csv(
            &[("posit16", &posit::P16), ("bposit16_e3", &posit::BP16_E3)],
            -64,
            64,
        );
        std::fs::write(format!("{dir}/fig6_accuracy16.csv"), fig6).map_err(|e| e.to_string())?;
        out.push(format!("wrote {dir}/fig6_accuracy16.csv and fig7_accuracy32.csv"));
    }
    Ok(out)
}

/// Measured PPA rows for decode or encode across 16/32/64 — the data
/// behind paper Tables 5/6 and Figs 14/15 (shared with the bench targets).
pub fn ppa_rows(encode: bool, random_pairs: usize) -> Vec<report::CostReport> {
    use crate::hw::designs::{power_vectors, DesignUnderTest};
    let stage = if encode { "enc" } else { "dec" };
    let mut rows = Vec::new();
    for n in [16u32, 32, 64] {
        let fspec = match n {
            16 => ieee::F16,
            32 => ieee::F32,
            _ => ieee::F64,
        };
        let bspec = posit::PositSpec::bounded(n, 6, 5);
        let pspec = posit::PositSpec::standard(n, 2);
        let entries: Vec<(String, crate::hw::netlist::Netlist, DesignUnderTest)> = if encode {
            vec![
                (
                    format!("float{n} {stage}"),
                    float_enc::build(&fspec),
                    DesignUnderTest::FloatEnc(&fspec),
                ),
                (
                    format!("b-posit<{n},6,5> {stage}"),
                    bposit_enc::build(&bspec),
                    DesignUnderTest::PositEnc(&bspec),
                ),
                (
                    format!("posit<{n},2> {stage}"),
                    posit_enc::build(&pspec),
                    DesignUnderTest::PositEnc(&pspec),
                ),
            ]
        } else {
            vec![
                (
                    format!("float{n} {stage}"),
                    float_dec::build(&fspec),
                    DesignUnderTest::FloatDec(&fspec),
                ),
                (
                    format!("b-posit<{n},6,5> {stage}"),
                    bposit_dec::build(&bspec),
                    DesignUnderTest::PositDec(&bspec),
                ),
                (
                    format!("posit<{n},2> {stage}"),
                    posit_dec::build(&pspec),
                    DesignUnderTest::PositDec(&pspec),
                ),
            ]
        };
        for (name, nl, dut) in entries {
            let pairs = power_vectors(&dut, random_pairs);
            rows.push(report::measure(&name, &nl, &pairs));
        }
    }
    rows
}

/// Execute `tables`: the three decode + three encode designs at 16/32/64.
pub fn run_tables() -> Vec<String> {
    vec![
        report::format_table("Decode (paper Table 5)", &ppa_rows(false, 40)),
        report::format_table("Encode (paper Table 6)", &ppa_rows(true, 40)),
    ]
}

/// Fail fast on an unwritable bench-JSON destination: probe the path
/// before any benchmarking so a bad `--json` argument surfaces as an
/// immediate clean error (non-zero exit) instead of a panic or a failure
/// after minutes of measurement. The probe opens without truncating, so
/// an existing artifact survives intact if the run later fails; only the
/// final `fs::write` replaces it.
fn ensure_json_writable(path: &str) -> Result<(), String> {
    std::fs::OpenOptions::new()
        .write(true)
        .create(true)
        .open(path)
        .map(|_| ())
        .map_err(|e| format!("cannot write bench JSON to {path}: {e}"))
}

/// An independent scalar fast-path reference for the serving format at
/// one width (only the 32-bit tier has one: the `quantizer::fast_bp32_*`
/// pair). When present, the bench also emits `{bp}_{encode,decode}_vs_fast`
/// speedup keys — the lane engine measured against the independent
/// scalar implementation — which CI gates at ≥ 1.0 like every other key,
/// so unifying the scalar baseline on the (much slower) general codec
/// did not weaken the regression gate.
struct FastScalarRef<E: LaneElem> {
    /// Checksum-returning sweep of the independent scalar encoder.
    encode: fn(&[E]) -> u64,
    /// Checksum-returning sweep of the independent scalar decoder.
    decode: fn(&[E::Word]) -> f64,
}

/// The single generic code path behind `vector-bench` at **both** widths
/// (the old hand-duplicated 32/64 functions collapsed; docs/API.md):
/// general codec vs branch-free lane engine for the serving b-posit and
/// standard-posit specs, the bits floor, and the dot-kernel family, over
/// `len`-element mixed-scale blocks. Also verifies that the sharded
/// codec is bit-identical to serial for t ∈ {1, 2, 7} at this width
/// (recorded as `bit_identical` in the JSON, gated in CI for both
/// widths). Emits one JSON schema — `BENCH_vector_codec.json` /
/// `BENCH_vector_codec64.json` differ only in the `bench` id and the
/// per-width stage key prefixes.
fn run_vector_bench_generic<E: LaneElem>(
    len: usize,
    json_path: Option<&str>,
    fast: Option<FastScalarRef<E>>,
) -> Result<Vec<String>, String> {
    use crate::harness::Bencher;
    use crate::testutil::Rng;
    use crate::vector::{kernels, parallel, LaneCodec};

    if let Some(path) = json_path {
        ensure_json_writable(path)?;
    }
    let bits = E::BITS;
    let bench_id = if bits == 64 { "vector_codec64" } else { "vector_codec" };
    let mut rng = Rng::new(0x5eed ^ ((bits as u64) << 32));
    // Mixed-scale finite values spanning every regime length (and, at 64
    // bits, both saturation zones of the 2^±192 formats) — worst case for
    // the branchy general codec, steady state for the lane path (always
    // the same straight-line code).
    let (span, off) = if bits == 64 { (441u64, 220i32) } else { (61u64, 30i32) };
    let xs: Vec<E> = (0..len)
        .map(|_| {
            let mag = (rng.f64() + 0.5) * f64::powi(2.0, rng.below(span) as i32 - off);
            E::from_f64(if rng.below(2) == 0 { mag } else { -mag })
        })
        .collect();
    let bp = LaneCodec::<E>::bp();
    let pstd = LaneCodec::<E>::pstd();
    let words = bp.encode(&xs);
    let p_words = pstd.encode(&xs);
    let ys: Vec<E> = (0..len).map(|_| E::from_f64((rng.f64() - 0.5) * 4.0)).collect();
    let mut out_w = words.clone();
    let mut out_f = xs.clone();

    // Sharded-vs-serial bit-identity through the unified par_* entry
    // points: the acceptance contract, checked before any timing (and
    // gated on in CI via the JSON flag — at both widths).
    let mut bit_identical = true;
    for t in [1usize, 2, 7] {
        let mut w = words.clone();
        parallel::par_bp_encode_into_with(t, &xs, &mut w);
        bit_identical &= w == words;
        let mut f = xs.clone();
        parallel::par_bp_decode_into_with(t, &words, &mut f);
        bp.decode_into(&words, &mut out_f);
        bit_identical &=
            f.iter().zip(&out_f).all(|(a, b)| a.to_bits_u64() == b.to_bits_u64());
    }

    let mut b = Bencher::new();
    let (bp_name, p_name) = (E::BP_NAME, E::PSTD_NAME);

    // --- serving b-posit: general codec (scalar) vs lane engine ---
    b.bench(&format!("{bp_name}_encode/scalar/{len}"), || {
        let mut acc = 0u64;
        for &x in &xs {
            acc = acc.wrapping_add(E::BP.from_f64(x.to_f64()));
        }
        acc
    });
    b.bench(&format!("{bp_name}_encode/vector/{len}"), || {
        bp.encode_into(&xs, &mut out_w);
        out_w[0]
    });
    b.bench(&format!("{bp_name}_decode/scalar/{len}"), || {
        let mut acc = 0f64;
        for &w in &words {
            acc += E::BP.to_f64(E::word_to_u64(w));
        }
        acc
    });
    b.bench(&format!("{bp_name}_decode/vector/{len}"), || {
        bp.decode_into(&words, &mut out_f);
        out_f[0]
    });
    b.bench(&format!("{bp_name}_roundtrip/scalar/{len}"), || {
        let mut acc = 0f64;
        for &x in &xs {
            acc += E::BP.to_f64(E::BP.from_f64(x.to_f64()));
        }
        acc
    });
    b.bench(&format!("{bp_name}_roundtrip/vector/{len}"), || {
        out_f.copy_from_slice(&xs);
        bp.roundtrip_in_place(&mut out_f);
        out_f[0]
    });

    // --- standard posit: general codec vs lane engine ---
    b.bench(&format!("{p_name}_encode/scalar/{len}"), || {
        let mut acc = 0u64;
        for &x in &xs {
            acc = acc.wrapping_add(E::PSTD.from_f64(x.to_f64()));
        }
        acc
    });
    b.bench(&format!("{p_name}_encode/vector/{len}"), || {
        pstd.encode_into(&xs, &mut out_w);
        out_w[0]
    });
    b.bench(&format!("{p_name}_decode/scalar/{len}"), || {
        let mut acc = 0f64;
        for &w in &p_words {
            acc += E::PSTD.to_f64(E::word_to_u64(w));
        }
        acc
    });
    b.bench(&format!("{p_name}_decode/vector/{len}"), || {
        pstd.decode_into(&p_words, &mut out_f);
        out_f[0]
    });

    // --- independent scalar fast path (32-bit tier only) ---
    if let Some(fs) = &fast {
        b.bench(&format!("{bp_name}_encode/fastscalar/{len}"), || (fs.encode)(&xs));
        b.bench(&format!("{bp_name}_decode/fastscalar/{len}"), || (fs.decode)(&words));
    }

    // --- float⇄bits: the memcpy-speed floor for the sweep ---
    b.bench(&format!("f{bits}_bits/vector/{len}"), || {
        for (o, &x) in out_w.iter_mut().zip(&xs) {
            *o = E::word_from_u64(x.to_bits_u64());
        }
        out_w[0]
    });

    // --- dot kernels (the serving workload) ---
    b.bench(&format!("dot/f{bits}_fast/{len}"), || kernels::dot(&xs, &ys));
    b.bench(&format!("dot/{bp_name}_weights_fast/{len}"), || {
        kernels::dot_bp_weights_fast::<E>(&words, &ys)
    });
    let mut q = E::quire();
    b.bench(&format!("dot/quire_exact/{len}"), || kernels::quire_dot(&mut q, &xs, &ys));

    let mut out =
        vec![b.table(&format!("{bits}-bit vector codec throughput ({len}-element blocks)"))];
    for r in b.results() {
        out.push(format!("{:<44} {:>10.1} Melem/s", r.name, len as f64 / r.mean_ns * 1e3));
    }

    // Speedups: general-codec (scalar) mean / lane (vector) mean per stage.
    let mean = |prefix: &str| -> f64 {
        b.results()
            .iter()
            .find(|r| r.name.starts_with(prefix))
            .map(|r| r.mean_ns)
            .unwrap_or(f64::NAN)
    };
    let stages = [
        format!("{bp_name}_encode"),
        format!("{bp_name}_decode"),
        format!("{bp_name}_roundtrip"),
        format!("{p_name}_encode"),
        format!("{p_name}_decode"),
    ];
    let mut speedup_json = Vec::new();
    for s in &stages {
        let sp = mean(&format!("{s}/scalar")) / mean(&format!("{s}/vector"));
        out.push(format!("speedup {s:<16} {sp:>6.2}x (vector vs scalar)"));
        speedup_json.push(format!("\"{s}\":{sp:.3}"));
    }
    if fast.is_some() {
        // Gate the lane engine against the *independent* fast scalar too
        // (the pre-redesign 32-bit baseline), not just the general codec.
        for stage in ["encode", "decode"] {
            let sp = mean(&format!("{bp_name}_{stage}/fastscalar"))
                / mean(&format!("{bp_name}_{stage}/vector"));
            out.push(format!(
                "speedup {bp_name}_{stage}_vs_fast {sp:>6.2}x (vector vs fast scalar)"
            ));
            speedup_json.push(format!("\"{bp_name}_{stage}_vs_fast\":{sp:.3}"));
        }
    }
    out.push(format!(
        "sharded codec bit-identical to serial: {}",
        if bit_identical { "yes" } else { "NO — BUG" }
    ));
    if !bit_identical {
        return Err(format!(
            "sharded {bits}-bit codec differs from serial — bit-identity broken"
        ));
    }

    if let Some(path) = json_path {
        let json = format!(
            "{{\"bench\":\"{bench_id}\",\"len\":{len},\"bit_identical\":{bit_identical},\
             \"speedup\":{{{}}},\"results\":{}}}",
            speedup_json.join(","),
            b.results_json()
        );
        std::fs::write(path, &json).map_err(|e| format!("write {path}: {e}"))?;
        out.push(format!("wrote {path}"));
    }
    Ok(out)
}

/// Execute `vector-bench` (32-bit mode): the generic code path at
/// `E = f32`, plus the independent `fast_bp32_*` scalar reference (the
/// tier that has one); optionally writes `BENCH_vector_codec.json`.
pub fn run_vector_bench(len: usize, json_path: Option<&str>) -> Result<Vec<String>, String> {
    run_vector_bench_generic::<f32>(
        len,
        json_path,
        Some(FastScalarRef {
            encode: |xs| {
                let mut acc = 0u32;
                for &x in xs {
                    acc = acc.wrapping_add(crate::coordinator::quantizer::fast_bp32_encode(x));
                }
                acc as u64
            },
            decode: |ws| {
                let mut acc = 0f32;
                for &w in ws {
                    acc += crate::coordinator::quantizer::fast_bp32_decode(w);
                }
                acc as f64
            },
        }),
    )
}

/// Execute `vector-bench --bits 64`: the generic code path at `E = f64`
/// (no independent scalar fast path exists at this width — the general
/// codec was always its scalar baseline); optionally writes
/// `BENCH_vector_codec64.json`.
pub fn run_vector_bench64(len: usize, json_path: Option<&str>) -> Result<Vec<String>, String> {
    run_vector_bench_generic::<f64>(len, json_path, None)
}

/// Execute `gemm-bench`: serial vs sharded blocked GEMM across `sizes`
/// (square m=k=n), on the f32 fast path, the decode-fused quantized-weight
/// fast path, and (up to `quire_max`) the 800-bit quire-exact paths.
/// Reports GFLOP-equivalents (2·n³ flops per GEMM), verifies that every
/// sharded result is bit-identical to its serial counterpart, and
/// optionally writes `BENCH_vector_gemm.json` (schema in
/// rust/benches/README.md). Shared by the CLI and the `vector_gemm`
/// bench target.
pub fn run_gemm_bench(
    sizes: &[usize],
    quire_max: usize,
    json_path: Option<&str>,
) -> Result<Vec<String>, String> {
    use crate::harness::Bencher;
    use crate::testutil::Rng;
    use crate::vector::{codec, gemm, parallel};

    if let Some(path) = json_path {
        ensure_json_writable(path)?;
    }
    let threads = parallel::num_threads();
    let mut b = Bencher::new();
    let mut out = Vec::new();
    let mut bit_identical = true;
    let mut speedup_json = Vec::new();
    let mut gflops_json = Vec::new();
    let mut rng = Rng::new(0x6e44);

    for &s in sizes {
        let (m, k, n) = (s, s, s);
        // Mixed-scale finite values (|x| ∈ [2^-16, 2^16]): exercises every
        // regime length without overflowing f32 partial sums.
        let a = crate::testutil::mixed_scale_f32(&mut rng, m * k, 33);
        let bm = crate::testutil::mixed_scale_f32(&mut rng, k * n, 33);
        let a_bits: Vec<u32> = {
            let mut w = vec![0u32; a.len()];
            codec::bp32_encode_into(&a, &mut w);
            w
        };
        let mut c = vec![0f32; m * n];
        let flops = 2.0 * (m as f64) * (k as f64) * (n as f64);

        // Serial-vs-sharded bit-identity, checked once per path before
        // timing (the acceptance contract, not just a bench).
        let mut c_ref = vec![0f32; m * n];
        gemm::gemm_f32(&a, &bm, &mut c_ref, m, k, n);
        gemm::par_gemm_f32_with(threads, &a, &bm, &mut c, m, k, n);
        bit_identical &= c_ref.iter().zip(&c).all(|(x, y)| x.to_bits() == y.to_bits());
        gemm::gemm_bp32_weights_fast(&a_bits, &bm, &mut c_ref, m, k, n);
        gemm::par_gemm_bp32_weights_fast_with(threads, &a_bits, &bm, &mut c, m, k, n);
        bit_identical &= c_ref.iter().zip(&c).all(|(x, y)| x.to_bits() == y.to_bits());

        let mut pairs: Vec<(String, f64, f64)> = Vec::new(); // (path, serial, par)
        let t0 = b.bench(&format!("gemm_f32/serial/{s}"), || {
            gemm::gemm_f32(&a, &bm, &mut c, m, k, n);
            c[0]
        });
        let serial_ns = t0.mean_ns;
        let t1 = b.bench(&format!("gemm_f32/par{threads}/{s}"), || {
            gemm::par_gemm_f32_with(threads, &a, &bm, &mut c, m, k, n);
            c[0]
        });
        pairs.push(("f32".into(), serial_ns, t1.mean_ns));

        let t2 = b.bench(&format!("gemm_bp32_fast/serial/{s}"), || {
            gemm::gemm_bp32_weights_fast(&a_bits, &bm, &mut c, m, k, n);
            c[0]
        });
        let serial_w_ns = t2.mean_ns;
        let t3 = b.bench(&format!("gemm_bp32_fast/par{threads}/{s}"), || {
            gemm::par_gemm_bp32_weights_fast_with(threads, &a_bits, &bm, &mut c, m, k, n);
            c[0]
        });
        pairs.push(("bp32_fast".into(), serial_w_ns, t3.mean_ns));

        if s <= quire_max {
            gemm::gemm_quire_f32(&a, &bm, &mut c_ref, m, k, n);
            gemm::par_gemm_quire_f32_with(threads, &a, &bm, &mut c, m, k, n);
            bit_identical &= c_ref.iter().zip(&c).all(|(x, y)| x.to_bits() == y.to_bits());
            let q0 = b.bench(&format!("gemm_quire/serial/{s}"), || {
                gemm::gemm_quire_f32(&a, &bm, &mut c, m, k, n);
                c[0]
            });
            let serial_q_ns = q0.mean_ns;
            let q1 = b.bench(&format!("gemm_quire/par{threads}/{s}"), || {
                gemm::par_gemm_quire_f32_with(threads, &a, &bm, &mut c, m, k, n);
                c[0]
            });
            pairs.push(("quire".into(), serial_q_ns, q1.mean_ns));
        }

        for (path, ser, par) in pairs {
            let sp = ser / par;
            out.push(format!(
                "{s:>5}³ {path:<10} serial {:>8.2} GF-eq  sharded×{threads} {:>8.2} GF-eq  speedup {sp:>5.2}x",
                flops / ser,
                flops / par
            ));
            speedup_json.push(format!("\"{path}_{s}\":{sp:.3}"));
            gflops_json.push(format!("\"{path}_serial_{s}\":{:.3}", flops / ser));
            gflops_json.push(format!("\"{path}_par_{s}\":{:.3}", flops / par));
        }
    }

    out.insert(0, b.table(&format!("blocked GEMM throughput ({threads} threads available)")));
    out.push(format!(
        "sharded results bit-identical to serial: {}",
        if bit_identical { "yes" } else { "NO — BUG" }
    ));
    if !bit_identical {
        let msg = "sharded GEMM result differs from serial — bit-identity contract broken";
        return Err(msg.into());
    }

    if let Some(path) = json_path {
        let sizes_list: Vec<String> = sizes.iter().map(|s| s.to_string()).collect();
        let json = format!(
            "{{\"bench\":\"vector_gemm\",\"threads\":{threads},\"sizes\":[{}],\"bit_identical\":{bit_identical},\"speedup\":{{{}}},\"gflops\":{{{}}},\"results\":{}}}",
            sizes_list.join(","),
            speedup_json.join(","),
            gflops_json.join(","),
            b.results_json()
        );
        std::fs::write(path, &json).map_err(|e| format!("write {path}: {e}"))?;
        out.push(format!("wrote {path}"));
    }
    Ok(out)
}

/// Serial vs sharded (t ∈ {1, 2, threads}) vs dense bit-identity for
/// every SpMV flavor on one operator — the solver's arithmetic contract,
/// checked as a hard gate before any solve is timed. The dense
/// comparison is quadratic in memory, so it runs only when `dense` is
/// set (small operators).
fn spmv_bit_checks<E: LaneElem>(
    a: &crate::vector::sparse::Csr<E>,
    threads: usize,
    dense: bool,
) -> bool {
    use crate::testutil::Rng;
    use crate::vector::{kernels, sparse};

    let (rows, cols) = (a.rows(), a.cols());
    let mut rng = Rng::new(0x50_17e5 ^ rows as u64);
    let x: Vec<E> = (0..cols).map(|_| E::from_f64((rng.f64() - 0.5) * 4.0)).collect();
    let aw = a.encode_bp();
    let eq = |u: &[E], v: &[E]| u.iter().zip(v).all(|(a, b)| a.to_bits_u64() == b.to_bits_u64());

    let mut serial = vec![E::ZERO; rows];
    sparse::spmv(a, &x, &mut serial);
    let mut serial_q = vec![E::ZERO; rows];
    let mut q = E::quire();
    sparse::spmv_quire(&mut q, a, &x, &mut serial_q);
    let mut serial_bp = vec![E::ZERO; rows];
    sparse::spmv_bp_weights_fast(&aw, &x, &mut serial_bp);

    let mut ok = true;
    let mut y = vec![E::ZERO; rows];
    for t in [1, 2, threads] {
        sparse::par_spmv_with(t, a, &x, &mut y);
        ok &= eq(&y, &serial);
        sparse::par_spmv_quire_with(t, a, &x, &mut y);
        ok &= eq(&y, &serial_q);
        sparse::par_spmv_bp_weights_fast_with(t, &aw, &x, &mut y);
        ok &= eq(&y, &serial_bp);
    }
    if dense {
        let d = a.to_dense();
        kernels::gemv(&d, &x, &mut y);
        ok &= eq(&y, &serial);
        kernels::par_gemv_quire_with(1, &d, &x, &mut y);
        ok &= eq(&y, &serial_q);
        let words: Vec<E::Word> = d.iter().map(|&v| E::bp_encode_lane(v)).collect();
        kernels::par_gemv_bp_weights_with(1, &words, &x, &mut y);
        ok &= eq(&y, &serial_bp);
    }
    ok
}

/// A finite f64 as a JSON number (non-finite values render as null; the
/// solver only emits finite residuals, this is belt and braces).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:?}")
    } else {
        "null".to_string()
    }
}

/// Execute `solver-bench`: per-tier CG trajectories on the 2D Poisson
/// stencil and random diagonally-dominant SPD operators, plus the
/// Jacobi-preconditioned f64 solve, with two hard gates — SpMV
/// serial/sharded/dense bit-identity, and the quire tiers never needing
/// more iterations than their fast counterparts on Poisson. Writes
/// `BENCH_solver.json` (schema in rust/benches/README.md) before gating,
/// so a failed run still leaves the evidence on disk. Shared by the CLI
/// and the `solver` bench target.
pub fn run_solver_bench(o: &SolverBenchOpts) -> Result<Vec<String>, String> {
    use crate::solver::{operators, solve, CgOptions, Precond, Tier};
    use crate::vector::parallel;

    if let Some(path) = &o.json {
        ensure_json_writable(path)?;
    }
    let threads = parallel::num_threads();
    let mut out = Vec::new();
    let mut bit_identical = true;
    let mut gate_errors: Vec<String> = Vec::new();
    let mut ops_json: Vec<String> = Vec::new();

    let mut operators_list: Vec<(&str, Option<usize>, crate::vector::sparse::Csr<f64>)> =
        Vec::new();
    for &g in &o.grids {
        operators_list.push(("poisson2d", Some(g), operators::poisson2d(g)));
    }
    for &n in &o.dd_sizes {
        operators_list.push(("rand_dd", None, operators::rand_dd(n, 3, 4, 1000 + n as u64)));
    }

    for (kind, grid, a) in &operators_list {
        let n = a.rows();
        let b = operators::ones(n);
        let label = match grid {
            Some(g) => format!("{kind} grid={g} n={n} nnz={}", a.nnz()),
            None => format!("{kind} n={n} nnz={}", a.nnz()),
        };

        // Bit-identity first: dense equivalence only while the densified
        // operator stays small.
        let ok64 = spmv_bit_checks(a, threads, n <= 2048);
        let ok32 = spmv_bit_checks(&a.convert::<f32>(), threads, n <= 2048);
        bit_identical &= ok64 && ok32;

        out.push(format!("{label}:"));
        let mut solves_json: Vec<String> = Vec::new();
        let mut iters: Vec<(Tier, usize, bool)> = Vec::new();
        {
            let mut run = |tier: Tier, precond: Precond| {
                let opts = CgOptions { tol: o.tol, max_iters: o.max_iters, precond };
                let rep = solve(a, &b, tier, &opts);
                out.push(format!(
                    "  {:>7}/{:<6} {:>4} iters{} final {:.3e} true {:.3e} {:>9.2} ms",
                    tier.name(),
                    precond.name(),
                    rep.iterations,
                    if rep.converged {
                        " (conv)"
                    } else if rep.breakdown {
                        " (BRKDN)"
                    } else {
                        " (cap)  "
                    },
                    rep.final_residual,
                    rep.true_residual,
                    rep.wall_ns as f64 / 1e6,
                ));
                let residuals: Vec<String> = rep.residuals.iter().map(|&r| json_f64(r)).collect();
                solves_json.push(format!(
                    "{{\"tier\":\"{}\",\"precond\":\"{}\",\"iterations\":{},\"converged\":{},\
                     \"breakdown\":{},\"final_residual\":{},\"true_residual\":{},\"wall_ns\":{},\
                     \"residuals\":[{}]}}",
                    tier.name(),
                    precond.name(),
                    rep.iterations,
                    rep.converged,
                    rep.breakdown,
                    json_f64(rep.final_residual),
                    json_f64(rep.true_residual),
                    rep.wall_ns,
                    residuals.join(",")
                ));
                if precond == Precond::None {
                    iters.push((tier, rep.iterations, rep.converged));
                }
            };
            for tier in Tier::ALL {
                if tier.is_quire() && n > o.quire_max {
                    continue;
                }
                run(tier, Precond::None);
            }
            run(Tier::F64, Precond::Jacobi);
        }

        // Gate (Poisson only): exact reductions must never lose to
        // rounded ones — mirror-validated on the CI sizes.
        if *kind == "poisson2d" {
            let find = |t: Tier| iters.iter().find(|e| e.0 == t).map(|e| (e.1, e.2));
            for (quire, fast) in [(Tier::Quire32, Tier::F32), (Tier::Quire64, Tier::F64)] {
                if let (Some((qi, qc)), Some((fi, _))) = (find(quire), find(fast)) {
                    if !qc || qi > fi {
                        gate_errors.push(format!(
                            "{label}: {} took {qi} iters (converged: {qc}) vs {} {fi}",
                            quire.name(),
                            fast.name()
                        ));
                    }
                }
            }
        }

        let grid_json = match grid {
            Some(g) => format!("\"grid\":{g},"),
            None => String::new(),
        };
        ops_json.push(format!(
            "{{\"operator\":\"{kind}\",{grid_json}\"n\":{n},\"nnz\":{},\"solves\":[{}]}}",
            a.nnz(),
            solves_json.join(",")
        ));
    }

    out.push(format!(
        "spmv serial/sharded/dense bit-identical: {}",
        if bit_identical { "yes" } else { "NO — BUG" }
    ));

    if let Some(path) = &o.json {
        let json = format!(
            "{{\"bench\":\"solver\",\"tol\":{},\"max_iters\":{},\"threads\":{threads},\
             \"spmv_bit_identical\":{bit_identical},\"operators\":[{}]}}",
            json_f64(o.tol),
            o.max_iters,
            ops_json.join(",")
        );
        std::fs::write(path, &json).map_err(|e| format!("write {path}: {e}"))?;
        out.push(format!("wrote {path}"));
    }

    if !bit_identical {
        return Err("sparse SpMV differs from its serial/dense twin — bit-identity broken".into());
    }
    if !gate_errors.is_empty() {
        return Err(format!("quire-vs-fast iteration gate failed: {}", gate_errors.join("; ")));
    }
    Ok(out)
}

/// Drive `requests` closed-loop inferences from `clients` threads over
/// the golden rows of `w`, returning `(completed, req_per_s)`. Shared by
/// the throughput and tracing-overhead sections of `serve-bench`.
fn closed_loop(
    server: &std::sync::Arc<crate::coordinator::InferenceServer>,
    w: &crate::runtime::ModelWeights,
    clients: usize,
    requests: usize,
) -> (usize, f64) {
    let d = w.d;
    let per_client = requests.div_ceil(clients.max(1));
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for cid in 0..clients.max(1) {
            let srv = server.clone();
            handles.push(s.spawn(move || {
                let mut ok = 0usize;
                for i in 0..per_client {
                    let g = (cid * 31 + i) % w.batch;
                    let feats = w.golden_x[g * d..(g + 1) * d].to_vec();
                    if srv.infer(feats).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        for hnd in handles {
            done += hnd.join().unwrap();
        }
    });
    (done, done as f64 / t0.elapsed().as_secs_f64().max(1e-9))
}

/// Drive `requests` closed-loop HTTP inferences from `conns` concurrent
/// connections. With `keep_alive`, every client opens one connection
/// up front (all held simultaneously — this is what demonstrates the
/// event loop past the old 64-thread cap) and reuses it; otherwise
/// each request is a fresh `Connection: close` round trip, matching the
/// thread-per-connection baseline's contract. Returns
/// `(ok, shed, req_per_s)` where `shed` counts 429/503 answers.
fn http_closed_loop(
    addr: &std::net::SocketAddr,
    bodies: &[String],
    conns: usize,
    requests: usize,
    keep_alive: bool,
) -> (usize, usize, f64) {
    use crate::coordinator::http;
    let conns = conns.max(1);
    let per_conn = requests.div_ceil(conns);
    let barrier = std::sync::Barrier::new(conns + 1);
    let (mut ok, mut shed) = (0usize, 0usize);
    let mut t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for cid in 0..conns {
            let barrier = &barrier;
            handles.push(s.spawn(move || {
                let mut client = None;
                if keep_alive {
                    // Retry: a big fan-in can transiently overflow the
                    // accept backlog.
                    for _ in 0..50 {
                        match http::HttpClient::connect(addr) {
                            Ok(c) => {
                                client = Some(c);
                                break;
                            }
                            Err(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
                        }
                    }
                }
                barrier.wait();
                let (mut ok, mut shed) = (0usize, 0usize);
                for i in 0..per_conn {
                    let body = &bodies[(cid * 31 + i) % bodies.len()];
                    let status = match client.as_mut() {
                        Some(c) => c.request("POST", "/infer", body).map(|r| r.status),
                        None => http::http_request(addr, "POST", "/infer", body).map(|r| r.0),
                    };
                    match status {
                        Ok(200) => ok += 1,
                        Ok(429) | Ok(503) => shed += 1,
                        _ => {}
                    }
                }
                (ok, shed)
            }));
        }
        barrier.wait();
        t0 = std::time::Instant::now();
        for hnd in handles {
            let (o2, s2) = hnd.join().unwrap();
            ok += o2;
            shed += s2;
        }
    });
    (ok, shed, ok as f64 / t0.elapsed().as_secs_f64().max(1e-9))
}

/// Execute `serve-bench`: the end-to-end native serving benchmark.
///
/// Starts the server on the native backend over a deterministic
/// synthetic model (no artifacts required — the same path CI uses), then:
/// 1. **Parity gate** — every golden row is inferred in-process and the
///    logits must be *bit-identical* to the scalar reference forward
///    pass ([`crate::coordinator::backend::reference_forward`]).
/// 2. **HTTP round-trip** — a real listener on an ephemeral port serves
///    `POST /infer` (logits must survive the JSON round-trip bit-exactly
///    and the response must echo a trace id), `GET /metrics` (must
///    report a non-zero batch count), `GET /debug/tracez` (must return
///    retained spans), and an unknown debug path (must 404).
/// 3. **Closed-loop throughput** — `clients` threads × `requests` total,
///    reported as req/s with latency quantiles and the codec/execute
///    split.
/// 4. **Tracing overhead** — two fresh servers over a standard-shaped
///    model (d=64, h=128, c=16 regardless of `--small`, so the numbers
///    are comparable across runs), span retention on vs off, rounds
///    interleaved and best-of kept; logits from both must be
///    bit-identical to the scalar reference (`tracing_parity`).
/// 5. **Front-end scaling** — keep-alive parity (many requests reusing
///    one connection, each bit-compared to the reference:
///    `keepalive_parity`), the event loop raced against the
///    thread-per-connection baseline at the small sweep point
///    (`req_per_s_event` / `req_per_s_threaded` — the CI gate requires
///    the event loop to win), and a closed-loop scaling sweep over
///    connections × batch × deadline (every connection held open
///    simultaneously — the 256-connection points run past the old
///    64-thread cap) recording req/s and shed rate per point (`sweep`).
///
/// The parity/HTTP/keep-alive gates failing is a hard error (non-zero
/// exit); all flags and measurements are recorded in
/// `BENCH_serve_native.json` for the CI bench gate.
pub fn run_serve_bench(o: &ServeBenchOpts) -> Result<Vec<String>, String> {
    use crate::coordinator::{backend, http, InferenceServer, ServerConfig};
    use std::sync::Arc;
    use std::time::Duration;

    if let Some(path) = &o.json {
        ensure_json_writable(path)?;
    }
    let (d, h, c, batch) = if o.small { (16, 24, 8, 32) } else { (64, 128, 16, 64) };
    let w = backend::synth_weights(d, h, c, batch, 0x5e7e);
    let cfg = ServerConfig::builder()
        .format(o.format)
        .max_wait(Duration::from_micros(500))
        .build()
        .map_err(|e| format!("{e:#}"))?;
    let server =
        Arc::new(InferenceServer::start_native(w.clone(), cfg).map_err(|e| format!("{e:#}"))?);
    let mut out = Vec::new();

    // 1. In-process logits parity vs the scalar reference.
    let mut parity = true;
    for g in 0..batch {
        let x = w.golden_x[g * d..(g + 1) * d].to_vec();
        let want = backend::reference_forward(&w, o.format, &backend::stage_inputs(o.format, &x));
        let got = server.infer(x).map_err(|e| format!("{e:#}"))?;
        parity &= got.logits.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
    }
    out.push(format!(
        "logits parity vs scalar reference ({} rows, {}): {}",
        batch,
        o.format.name(),
        if parity { "bit-identical" } else { "MISMATCH — BUG" }
    ));

    // 2. HTTP round-trip on an ephemeral port.
    let listener =
        http::serve("127.0.0.1:0", server.clone()).map_err(|e| format!("{e:#}"))?;
    let addr = listener.local_addr();
    let mut http_ok = true;
    for g in 0..batch.min(8) {
        let x = &w.golden_x[g * d..(g + 1) * d];
        let body = format!(
            "{{\"features\":[{}]}}",
            x.iter().map(|v| format!("{v:?}")).collect::<Vec<_>>().join(",")
        );
        let (status, resp) = http::http_request(&addr, "POST", "/infer", &body)?;
        if status != 200 {
            http_ok = false;
            continue;
        }
        let j = crate::json::Json::parse(&resp).ok();
        let logits = j
            .as_ref()
            .and_then(|j| j.get("logits").and_then(|l| l.as_f32_vec()))
            .unwrap_or_default();
        let want = backend::reference_forward(&w, o.format, &backend::stage_inputs(o.format, x));
        http_ok &= logits.len() == want.len()
            && logits.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
        // The response must echo a nonzero trace id for tracez correlation.
        http_ok &= j
            .as_ref()
            .and_then(|j| j.get("trace_id").and_then(|t| t.as_f64()))
            .is_some_and(|t| t >= 1.0);
    }
    let (mstatus, mbody) = http::http_request(&addr, "GET", "/metrics", "")?;
    http_ok &= mstatus == 200
        && http::metric_value(&mbody, "positron_batches_total").is_some_and(|v| v >= 1.0)
        && mbody.contains("positron_request_latency_us_bucket");
    let (tstatus, tbody) = http::http_request(&addr, "GET", "/debug/tracez", "")?;
    http_ok &= tstatus == 200 && tbody.contains("\"trace_id\"");
    let (nstatus, _) = http::http_request(&addr, "GET", "/debug/nope", "")?;
    http_ok &= nstatus == 404;
    out.push(format!(
        "HTTP round-trip on {addr} (/infer bit-exact + trace_id, /metrics live, \
         /debug/tracez live, unknown debug 404): {}",
        if http_ok { "ok" } else { "FAILED" }
    ));
    drop(listener);

    // 3. Closed-loop throughput.
    let (done, req_per_s) = closed_loop(&server, &w, o.clients, o.requests);
    let snap = server.metrics().snapshot();
    out.push(format!(
        "closed loop: {done} requests, {} clients, {req_per_s:.0} req/s \
         (p50 {} µs, p99 {} µs, max {} µs, mean batch {:.1})",
        o.clients, snap.p50_us, snap.p99_us, snap.max_us, snap.mean_batch
    ));
    out.push(format!(
        "codec {:.1} µs/batch, execute {:.1} µs/batch over {} batches \
         (queue wait p50 {} µs, p99 {} µs)",
        snap.codec_ns_per_batch() / 1e3,
        snap.execute_ns_per_batch() / 1e3,
        snap.batches,
        snap.hist_queue_us.quantile(0.5),
        snap.hist_queue_us.quantile(0.99),
    ));

    // 4. Tracing overhead: span retention on vs off. The model shape is
    //    fixed (standard, not --small) so the percentage is comparable
    //    across runs; --small only trims the request count to keep the
    //    test smoke fast.
    let (od, oh, oc, obatch) = (64usize, 128usize, 16usize, 64usize);
    let oreq = if o.small { 128 } else { 512 };
    let ow = backend::synth_weights(od, oh, oc, obatch, 0x0b5e);
    let mk = |tracing: bool| -> Result<Arc<InferenceServer>, String> {
        let cfg = ServerConfig::builder()
            .format(o.format)
            .max_wait(Duration::from_micros(500))
            .tracing(tracing)
            .build()
            .map_err(|e| format!("{e:#}"))?;
        Ok(Arc::new(InferenceServer::start_native(ow.clone(), cfg).map_err(|e| format!("{e:#}"))?))
    };
    let traced = mk(true)?;
    let untraced = mk(false)?;
    // Observability must never perturb the result: logits from both
    // servers must be bit-identical to the scalar reference.
    let mut tracing_parity = true;
    for g in 0..obatch {
        let x = ow.golden_x[g * od..(g + 1) * od].to_vec();
        let want =
            backend::reference_forward(&ow, o.format, &backend::stage_inputs(o.format, &x));
        let a = traced.infer(x.clone()).map_err(|e| format!("{e:#}"))?;
        let b = untraced.infer(x).map_err(|e| format!("{e:#}"))?;
        tracing_parity &= a.logits.iter().zip(&want).all(|(p, q)| p.to_bits() == q.to_bits())
            && b.logits.iter().zip(&want).all(|(p, q)| p.to_bits() == q.to_bits());
    }
    // The traced server must actually retain spans; the untraced one none.
    tracing_parity &= traced.tracer().pushed() > 0 && untraced.tracer().pushed() == 0;
    // Interleave (on, off) rounds and keep the best of each so scheduler
    // noise doesn't masquerade as tracing cost.
    let (mut best_on, mut best_off) = (0.0f64, 0.0f64);
    for _ in 0..2 {
        let (_, r_on) = closed_loop(&traced, &ow, o.clients, oreq);
        let (_, r_off) = closed_loop(&untraced, &ow, o.clients, oreq);
        best_on = best_on.max(r_on);
        best_off = best_off.max(r_off);
    }
    // Raw difference — may be negative when the traced run wins on noise.
    let tracing_overhead_pct = (best_off - best_on) / best_off.max(1e-9) * 100.0;
    out.push(format!(
        "tracing overhead: {best_on:.0} req/s traced vs {best_off:.0} req/s untraced \
         ({tracing_overhead_pct:+.2}%); logits {}",
        if tracing_parity { "bit-identical with tracing on/off" } else { "DIFFER — BUG" }
    ));

    // 5. HTTP front end: keep-alive parity on one reused connection,
    //    the event loop vs the thread-per-connection baseline, and a
    //    closed-loop scaling sweep (connections × batch × deadline).
    let bodies: Vec<String> = (0..batch)
        .map(|g| {
            let x = &w.golden_x[g * d..(g + 1) * d];
            format!(
                "{{\"features\":[{}]}}",
                x.iter().map(|v| format!("{v:?}")).collect::<Vec<_>>().join(",")
            )
        })
        .collect();
    let ev_listener =
        http::serve("127.0.0.1:0", server.clone()).map_err(|e| format!("{e:#}"))?;
    let ev_addr = ev_listener.local_addr();
    let mut keepalive_parity = true;
    let mut ka_client = http::HttpClient::connect(&ev_addr)?;
    let ka_rounds = 3usize;
    for _ in 0..ka_rounds {
        for (g, body) in bodies.iter().enumerate().take(batch.min(8)) {
            let x = &w.golden_x[g * d..(g + 1) * d];
            let want =
                backend::reference_forward(&w, o.format, &backend::stage_inputs(o.format, x));
            let resp = ka_client.request("POST", "/infer", body)?;
            let logits = crate::json::Json::parse(&resp.body)
                .ok()
                .and_then(|j| j.get("logits").and_then(|l| l.as_f32_vec()))
                .unwrap_or_default();
            keepalive_parity &= resp.status == 200
                && logits.len() == want.len()
                && logits.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
        }
    }
    drop(ka_client);
    out.push(format!(
        "keep-alive parity ({} requests on one connection): {}",
        ka_rounds * batch.min(8),
        if keepalive_parity { "bit-identical" } else { "MISMATCH — BUG" }
    ));

    let base_conns = 16usize;
    let base_reqs = o.requests.min(if o.small { 256 } else { 1024 });
    let (ev_ok, _, req_per_s_event) =
        http_closed_loop(&ev_addr, &bodies, base_conns, base_reqs, true);
    drop(ev_listener);
    let th_listener =
        http::serve_threaded("127.0.0.1:0", server.clone()).map_err(|e| format!("{e:#}"))?;
    let (th_ok, _, req_per_s_threaded) =
        http_closed_loop(&th_listener.local_addr(), &bodies, base_conns, base_reqs, false);
    drop(th_listener);
    out.push(format!(
        "front-end baseline ({base_conns} conns × {base_reqs} reqs): event loop \
         {req_per_s_event:.0} req/s ({ev_ok} ok) vs thread-per-conn \
         {req_per_s_threaded:.0} req/s ({th_ok} ok)"
    ));

    let sweep_conns: &[usize] = if o.small { &[16, 256] } else { &[64, 256] };
    let sweep_batch: &[usize] = if o.small { &[8, 32] } else { &[16, 64] };
    let sweep_deadline: &[Option<u64>] = &[None, Some(25)];
    let mut sweep_json = Vec::new();
    out.push("scaling sweep (closed-loop keep-alive HTTP):".to_string());
    for &sc in sweep_conns {
        for &sb in sweep_batch {
            for &sd in sweep_deadline {
                let mut builder = ServerConfig::builder()
                    .format(o.format)
                    .max_wait(Duration::from_micros(500))
                    .max_batch(sb)
                    .max_inflight(sc.max(sb));
                if let Some(ms) = sd {
                    builder = builder.deadline(Duration::from_millis(ms));
                }
                let scfg = builder.build().map_err(|e| format!("{e:#}"))?;
                let srv = Arc::new(
                    InferenceServer::start_native(w.clone(), scfg)
                        .map_err(|e| format!("{e:#}"))?,
                );
                let lst =
                    http::serve("127.0.0.1:0", srv.clone()).map_err(|e| format!("{e:#}"))?;
                let (sok, ssh, rps) =
                    http_closed_loop(&lst.local_addr(), &bodies, sc, o.requests, true);
                drop(lst);
                let shed_rate = ssh as f64 / (sok + ssh).max(1) as f64;
                out.push(format!(
                    "  conns {sc:>4}  batch {sb:>3}  deadline {:>4}  {rps:>8.0} req/s  \
                     ok {sok}  shed {ssh} ({:.1}%)",
                    sd.map_or("none".to_string(), |m| format!("{m}ms")),
                    100.0 * shed_rate
                ));
                sweep_json.push(format!(
                    "{{\"connections\":{sc},\"batch\":{sb},\"deadline_ms\":{},\"ok\":{sok},\
                     \"shed\":{ssh},\"req_per_s\":{rps:.1},\"shed_rate\":{shed_rate:.4}}}",
                    sd.map_or("null".to_string(), |m| m.to_string())
                ));
            }
        }
    }

    if let Some(path) = &o.json {
        let batches = snap.batches.max(1) as f64;
        let sweep = sweep_json.join(",");
        let json = format!(
            "{{\"bench\":\"serve_native\",\"format\":\"{}\",\"small\":{},\"d\":{d},\"h\":{h},\
             \"c\":{c},\"requests\":{},\"clients\":{},\"parity\":{parity},\
             \"http_roundtrip\":{http_ok},\"req_per_s\":{req_per_s:.1},\
             \"p50_us\":{},\"p99_us\":{},\"max_us\":{},\
             \"queue_wait_p50_us\":{},\"queue_wait_p99_us\":{},\"mean_batch\":{:.3},\
             \"batches\":{},\"rejected\":{},\"codec_ns_per_batch\":{:.0},\
             \"execute_ns_per_batch\":{:.0},\"staging_ns_per_batch\":{:.0},\
             \"readout_ns_per_batch\":{:.0},\"codec_worker_ns_total\":{},\
             \"req_per_s_traced\":{best_on:.1},\"req_per_s_untraced\":{best_off:.1},\
             \"tracing_overhead_pct\":{tracing_overhead_pct:.2},\
             \"tracing_parity\":{tracing_parity},\"keepalive_parity\":{keepalive_parity},\
             \"req_per_s_event\":{req_per_s_event:.1},\
             \"req_per_s_threaded\":{req_per_s_threaded:.1},\
             \"sweep\":[{sweep}],\"threads\":{}}}",
            o.format.name(),
            o.small,
            done,
            o.clients,
            snap.p50_us,
            snap.p99_us,
            snap.max_us,
            snap.hist_queue_us.quantile(0.5),
            snap.hist_queue_us.quantile(0.99),
            snap.mean_batch,
            snap.batches,
            snap.rejected,
            snap.codec_ns_per_batch(),
            snap.execute_ns_per_batch(),
            snap.staging_ns as f64 / batches,
            snap.readout_ns as f64 / batches,
            snap.codec_worker_ns,
            snap.codec_threads,
        );
        std::fs::write(path, &json).map_err(|e| format!("write {path}: {e}"))?;
        out.push(format!("wrote {path}"));
    }
    if !parity {
        return Err("native backend logits differ from scalar reference — parity broken".into());
    }
    if !http_ok {
        return Err("HTTP round-trip failed (status, parity, /metrics, or /debug/tracez)".into());
    }
    if !keepalive_parity {
        return Err("keep-alive responses differ from the scalar reference".into());
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// certify-bench: the error-certification benchmark.
// ---------------------------------------------------------------------------

/// Exact f64 bits of the (max_width, max_obs_err) each probe must
/// produce, pinned by the pure-Python `Fraction` mirror
/// (python/tests/test_certify_mirror.py `BENCH_EXPECT`). The Rust probes
/// below are transliterations of the mirror's, so bit-equality IS the
/// correctness test — any drift in the RNG stream, draw order, rounding
/// chain, or interval ops shows up as a hard bench failure.
const CERTIFY_EXPECT_BP32: (u64, u64) = (0x4537000000000001, 0x451019777F000000);
const CERTIFY_EXPECT_P32: (u64, u64) = (0x462734AC00000001, 0x462473A1E1CAB670);
const CERTIFY_EXPECT_BP64: u64 = 0x3D30C00000000001;

/// Exact power of two as f64 (valid for the normal exponent range; the
/// probes use 2^100, 2^79, 2^-18). Spelled via bits so the constant is
/// exact by construction, matching the mirror's `2.0**e`.
fn pow2(e: i32) -> f64 {
    f64::from_bits(((1023 + e) as u64) << 52)
}

/// Mirror of the probe's `ref_forward32`: the f32 ascending-p chain
/// (mul-round, add-round per term; explicit-compare ReLU) over
/// transposed weights — the same op order `run_lane_tier` is CI-gated
/// bit-identical to.
fn probe_forward32(
    w1t: &[f32],
    b1: &[f32],
    w2t: &[f32],
    b2: &[f32],
    x: &[f32],
    d: usize,
    h: usize,
    c: usize,
) -> Vec<f32> {
    let mut hid = vec![0f32; h];
    for i in 0..h {
        let mut acc = 0f32;
        for p in 0..d {
            acc += w1t[i * d + p] * x[p];
        }
        let v = acc + b1[i];
        hid[i] = if v > 0.0 { v } else { 0.0 };
    }
    let mut out = vec![0f32; c];
    for q in 0..c {
        let mut acc = 0f32;
        for i in 0..h {
            acc += w2t[q * h + i] * hid[i];
        }
        out[q] = acc + b2[q];
    }
    out
}

/// Mirror of the probe's `ref_forward64`: the f64 chain over the same
/// (f32-valued) weights — the full-precision reference whose distance
/// from the served f32/quantized logits is the "observed error".
fn probe_forward64(
    w1t: &[f32],
    b1: &[f32],
    w2t: &[f32],
    b2: &[f32],
    x: &[f64],
    d: usize,
    h: usize,
    c: usize,
) -> Vec<f64> {
    let mut hid = vec![0f64; h];
    for i in 0..h {
        let mut acc = 0f64;
        for p in 0..d {
            acc += w1t[i * d + p] as f64 * x[p];
        }
        let v = acc + b1[i];
        hid[i] = if v > 0.0 { v } else { 0.0 };
    }
    let mut out = vec![0f64; c];
    for q in 0..c {
        let mut acc = 0f64;
        for i in 0..h {
            acc += w2t[q * h + i] as f64 * hid[i];
        }
        out[q] = acc + b2[q];
    }
    out
}

/// One 32-bit-tier probe: a positive-weight model at f32 exponent t=100
/// (inside BP32's rounding band), inputs built as an 18-bit-fraction
/// grid point plus a sub-half-ulp offset so every quantization rounds
/// DOWN. Coherent rounding + positive weights = no error cancellation,
/// so the observed quantization error tracks the certified width and the
/// <10x tightness gate has real margin. Returns
/// `(max_width, max_obs_err, contained)`.
fn certify_probe32(quant: impl Fn(f32) -> f32) -> Result<(f64, f64, bool), String> {
    use crate::certify::{interval_forward, Interval, IntervalModel};
    use crate::testutil::Rng;

    let (d, h, c) = (4usize, 4usize, 3usize);
    let t = 100i32;
    let mut rng = Rng::new(5);
    // Draw order is the mirror's: w1t, b1, w2t, b2, then per-request
    // inputs (two draws each: grid point, offset).
    let scale = pow2(t);
    let w1t: Vec<f32> = (0..d * h).map(|_| (0.3 + 0.7 * rng.f64()) as f32).collect();
    let b1: Vec<f32> = (0..h).map(|_| (rng.f64() * 0.05 * scale) as f32).collect();
    let w2t: Vec<f32> = (0..h * c).map(|_| (0.3 + 0.7 * rng.f64()) as f32).collect();
    let b2: Vec<f32> = (0..c).map(|_| (rng.f64() * 0.05 * scale) as f32).collect();
    let model =
        IntervalModel::<f32>::new(d, h, c, w1t.clone(), b1.clone(), w2t.clone(), b2.clone())
            .ok_or("certify-bench: probe model shapes rejected")?;

    let (mut max_w, mut max_e, mut contained) = (0f64, 0f64, true);
    for _ in 0..64 {
        let x_raw: Vec<f32> = (0..d)
            .map(|_| {
                let g = ((1.0 + rng.below(1 << 18) as f64 * pow2(-18)) * pow2(t)) as f32;
                let off = ((0.40 + 0.05 * rng.f64()) * pow2(t - 21)) as f32;
                g + off
            })
            .collect();
        let x_q: Vec<f32> = x_raw.iter().map(|&v| quant(v)).collect();
        let xints: Vec<Interval<f32>> =
            x_raw.iter().zip(&x_q).map(|(&r, &q)| Interval::hull(r, q)).collect();
        let bounds = interval_forward(&model, &xints);
        let served = probe_forward32(&w1t, &b1, &w2t, &b2, &x_q, d, h, c);
        let x64: Vec<f64> = x_raw.iter().map(|&v| v as f64).collect();
        let refd = probe_forward64(&w1t, &b1, &w2t, &b2, &x64, d, h, c);
        for j in 0..c {
            let b = &bounds[j];
            let (lo, hi) = (b.lo as f64, b.hi as f64);
            let s = served[j] as f64;
            let r = refd[j];
            if b.is_poisoned() || !(lo <= s && s <= hi && lo <= r && r <= hi) {
                contained = false;
            }
            let w = b.width_f64();
            let e = (s - r).abs();
            if w > max_w {
                max_w = w;
            }
            if e > max_e {
                max_e = e;
            }
        }
    }
    Ok((max_w, max_e, contained))
}

/// The BP64 probe: quantization of normal f64 is exact, so the input
/// hull collapses to a point and the certified width is pure
/// directed-rounding accumulation — gated absolutely (< 1e-9), not
/// relative to observed error. Returns `(max_width, contained)`.
fn certify_probe64() -> Result<(f64, bool), String> {
    use crate::certify::{interval_forward, Interval, IntervalModel};
    use crate::testutil::Rng;
    use crate::vector::lane::LaneElem;

    let (d, h, c) = (16usize, 12usize, 6usize);
    let mut rng = Rng::new(5);
    let w1t: Vec<f32> = (0..d * h).map(|_| (rng.f64() - 0.5) as f32).collect();
    let b1: Vec<f32> = (0..h).map(|_| ((rng.f64() - 0.5) * 0.2) as f32).collect();
    let w2t: Vec<f32> = (0..h * c).map(|_| (rng.f64() - 0.5) as f32).collect();
    let b2: Vec<f32> = (0..c).map(|_| ((rng.f64() - 0.5) * 0.2) as f32).collect();
    let widen = |v: &[f32]| v.iter().map(|&x| x as f64).collect::<Vec<f64>>();
    let model = IntervalModel::<f64>::new(d, h, c, widen(&w1t), widen(&b1), widen(&w2t), widen(&b2))
        .ok_or("certify-bench: bp64 probe model shapes rejected")?;

    let (mut max_w, mut contained) = (0f64, true);
    for _ in 0..32 {
        let x: Vec<f64> = (0..d).map(|_| (rng.f64() - 0.5) * 8.0).collect();
        for &v in &x {
            // The tier's soundness premise: BP64 encodes normal f64
            // exactly. A non-roundtripping input would break it.
            let q = <f64 as LaneElem>::bp_decode_lane(<f64 as LaneElem>::bp_encode_lane(v));
            if q != v {
                contained = false;
            }
        }
        let xints: Vec<Interval<f64>> = x.iter().map(|&v| Interval::point(v)).collect();
        let bounds = interval_forward(&model, &xints);
        let served = probe_forward64(&w1t, &b1, &w2t, &b2, &x, d, h, c);
        for j in 0..c {
            let b = &bounds[j];
            if b.is_poisoned() || !(b.lo <= served[j] && served[j] <= b.hi) {
                contained = false;
            }
            let w = b.width_f64();
            if w > max_w {
                max_w = w;
            }
        }
    }
    Ok((max_w, contained))
}

/// Execute `certify-bench`: the error-certified-serving benchmark.
///
/// 1. **Probes** — deterministic interval-certification runs on three
///    tiers (bp32 and p32 quantization hulls at f32 width; bp64 point
///    inputs at f64 width). Hard gates: every served logit inside its
///    bound; bp32/p32 `max_width / max_obs_err < 10` (the bound is a
///    working error estimate, not just sound); bp64 `max_width < 1e-9`;
///    and the computed widths/errors **bit-equal** the constants the
///    Python `Fraction` mirror pinned — the transliteration check.
/// 2. **Serving overhead** — closed-loop throughput of a bp32 server
///    with `--certify-rate N` vs an uncertified twin (interleaved
///    rounds, best-of, like serve-bench's tracing section), plus the
///    sampled-response contract: exactly every Nth sequential request
///    echoes a finite `certified_error_bound`, and
///    `positron_certify_violations_total` stays 0 (hard gate).
///
/// Writes `BENCH_certify.json` before gating, so a failed run still
/// leaves the evidence on disk. Shared by the CLI and the `certify`
/// bench target; CI runs `certify-bench --small` and additionally gates
/// `certify_overhead_pct < 5`.
pub fn run_certify_bench(o: &CertifyBenchOpts) -> Result<Vec<String>, String> {
    use crate::coordinator::{backend, InferenceServer, ServerConfig};
    use crate::vector::lane::LaneElem;
    use std::sync::Arc;
    use std::time::Duration;

    if let Some(path) = &o.json {
        ensure_json_writable(path)?;
    }
    let mut out = Vec::new();

    // 1. Probes (always full size — they are tiny and bit-pinned).
    let bp32 = certify_probe32(|v| {
        <f32 as LaneElem>::bp_decode_lane(<f32 as LaneElem>::bp_encode_lane(v))
    })?;
    let p32 = certify_probe32(|v| {
        <f32 as LaneElem>::pstd_decode_lane(<f32 as LaneElem>::pstd_encode_lane(v))
    })?;
    let bp64 = certify_probe64()?;
    let ratio32 = bp32.0 / bp32.1;
    let ratio_p32 = p32.0 / p32.1;
    out.push(format!(
        "probe bp32: max width {:.4e} vs max observed err {:.4e} (ratio {:.4}), contained: {}",
        bp32.0,
        bp32.1,
        ratio32,
        if bp32.2 { "yes" } else { "NO — BUG" }
    ));
    out.push(format!(
        "probe p32:  max width {:.4e} vs max observed err {:.4e} (ratio {:.4}), contained: {}",
        p32.0,
        p32.1,
        ratio_p32,
        if p32.2 { "yes" } else { "NO — BUG" }
    ));
    out.push(format!(
        "probe bp64: max width {:.4e} (absolute gate < 1e-9), contained: {}",
        bp64.0,
        if bp64.1 { "yes" } else { "NO — BUG" }
    ));
    let pinned = bp32.0.to_bits() == CERTIFY_EXPECT_BP32.0
        && bp32.1.to_bits() == CERTIFY_EXPECT_BP32.1
        && p32.0.to_bits() == CERTIFY_EXPECT_P32.0
        && p32.1.to_bits() == CERTIFY_EXPECT_P32.1
        && bp64.0.to_bits() == CERTIFY_EXPECT_BP64;
    out.push(format!(
        "probe widths bit-equal the Python-mirror pins: {}",
        if pinned { "yes" } else { "NO — transliteration drift" }
    ));

    // 2. Serving overhead + the sampled-response/violation contract.
    let (d, h, c, batch) = if o.small { (16, 24, 8, 32) } else { (64, 128, 16, 64) };
    let w = backend::synth_weights(d, h, c, batch, 0xCE47);
    let mk = |rate: usize| -> Result<Arc<InferenceServer>, String> {
        let cfg = ServerConfig::builder()
            .format(backend::WeightFormat::Bp32)
            .max_wait(Duration::from_micros(500))
            .certify_rate(rate)
            .build()
            .map_err(|e| format!("{e:#}"))?;
        Ok(Arc::new(InferenceServer::start_native(w.clone(), cfg).map_err(|e| format!("{e:#}"))?))
    };
    let certified = mk(o.certify_rate)?;
    let plain = mk(0)?;

    // Echo contract on sequential requests: exactly every Nth response
    // carries a finite certified bound; the uncertified server never does.
    let mut echo_ok = true;
    let mut echoed = 0usize;
    for i in 0..2 * o.certify_rate {
        let g = i % batch;
        let feats = w.golden_x[g * d..(g + 1) * d].to_vec();
        let resp = certified.infer(feats.clone()).map_err(|e| format!("{e:#}"))?;
        match resp.certified_error_bound {
            Some(width) => {
                echoed += 1;
                echo_ok &= width.is_finite() && width > 0.0;
                echo_ok &= (i + 1) % o.certify_rate == 0;
            }
            None => echo_ok &= (i + 1) % o.certify_rate != 0,
        }
        echo_ok &= plain.infer(feats).map_err(|e| format!("{e:#}"))?.certified_error_bound.is_none();
    }
    echo_ok &= echoed == 2;
    out.push(format!(
        "sampled responses echo finite certified_error_bound (every {}th of {} sequential): {}",
        o.certify_rate,
        2 * o.certify_rate,
        if echo_ok { "yes" } else { "NO — BUG" }
    ));

    // Interleaved best-of rounds so scheduler noise doesn't masquerade
    // as certification cost.
    let (mut best_cert, mut best_plain) = (0.0f64, 0.0f64);
    for _ in 0..2 {
        let (_, r_cert) = closed_loop(&certified, &w, o.clients, o.requests);
        let (_, r_plain) = closed_loop(&plain, &w, o.clients, o.requests);
        best_cert = best_cert.max(r_cert);
        best_plain = best_plain.max(r_plain);
    }
    let overhead_pct = (best_plain - best_cert) / best_plain.max(1e-9) * 100.0;
    let snap = certified.metrics().snapshot();
    let plain_snap = plain.metrics().snapshot();
    let violations = snap.certify_violations + plain_snap.certify_violations;
    out.push(format!(
        "certify overhead at rate {}: {best_cert:.0} req/s certified vs {best_plain:.0} req/s \
         uncertified ({overhead_pct:+.2}%); {} requests certified, {violations} violations",
        o.certify_rate, snap.certified_requests
    ));
    let plain_clean = plain_snap.certified_requests == 0;

    let containment = bp32.2 && p32.2 && bp64.1;
    if let Some(path) = &o.json {
        let json = format!(
            "{{\"bench\":\"certify\",\"small\":{},\"certify_rate\":{},\"requests\":{},\
             \"clients\":{},\"probes\":{{\
             \"bp32\":{{\"max_width\":{},\"max_width_bits\":\"{:016x}\",\"max_obs_err\":{},\
             \"max_obs_err_bits\":\"{:016x}\",\"ratio\":{:.4},\"contained\":{}}},\
             \"p32\":{{\"max_width\":{},\"max_width_bits\":\"{:016x}\",\"max_obs_err\":{},\
             \"max_obs_err_bits\":\"{:016x}\",\"ratio\":{:.4},\"contained\":{}}},\
             \"bp64\":{{\"max_width\":{},\"max_width_bits\":\"{:016x}\",\"contained\":{}}}}},\
             \"pinned\":{pinned},\"containment\":{containment},\"echo_ok\":{echo_ok},\
             \"certified_requests\":{},\"violations\":{violations},\
             \"req_per_s_certified\":{best_cert:.1},\"req_per_s_uncertified\":{best_plain:.1},\
             \"certify_overhead_pct\":{overhead_pct:.2}}}",
            o.small,
            o.certify_rate,
            o.requests,
            o.clients,
            json_f64(bp32.0),
            bp32.0.to_bits(),
            json_f64(bp32.1),
            bp32.1.to_bits(),
            ratio32,
            bp32.2,
            json_f64(p32.0),
            p32.0.to_bits(),
            json_f64(p32.1),
            p32.1.to_bits(),
            ratio_p32,
            p32.2,
            json_f64(bp64.0),
            bp64.0.to_bits(),
            bp64.1,
            snap.certified_requests,
        );
        std::fs::write(path, &json).map_err(|e| format!("write {path}: {e}"))?;
        out.push(format!("wrote {path}"));
    }

    // Hard gates, after the JSON so a failure leaves evidence.
    if !containment {
        return Err("certify-bench: a served logit escaped its certified bound".into());
    }
    if violations != 0 {
        return Err(format!(
            "certify-bench: positron_certify_violations_total = {violations} (must be 0)"
        ));
    }
    if !pinned {
        return Err(format!(
            "certify-bench: probe widths drifted from the Python-mirror pins \
             (bp32 {:016x}/{:016x}, p32 {:016x}/{:016x}, bp64 {:016x})",
            bp32.0.to_bits(),
            bp32.1.to_bits(),
            p32.0.to_bits(),
            p32.1.to_bits(),
            bp64.0.to_bits()
        ));
    }
    if !(ratio32 < 10.0 && ratio_p32 < 10.0) {
        return Err(format!(
            "certify-bench: width/error ratio gate failed (bp32 {ratio32:.3}, p32 {ratio_p32:.3}, \
             must be < 10)"
        ));
    }
    if !(bp64.0 > 0.0 && bp64.0 < 1e-9) {
        return Err(format!("certify-bench: bp64 width {:.3e} outside (0, 1e-9)", bp64.0));
    }
    if !echo_ok || !plain_clean {
        return Err("certify-bench: certified_error_bound echo contract broken".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_gemm_bench_flags() {
        let args: Vec<String> =
            ["gemm-bench", "--sizes", "8,16", "--quire-max", "8", "--no-json"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        match parse(&args).unwrap() {
            Command::GemmBench { sizes, quire_max, json } => {
                assert_eq!(sizes, vec![8, 16]);
                assert_eq!(quire_max, 8);
                assert!(json.is_none());
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        assert!(parse(&["gemm-bench".into(), "--sizes".into(), "0".into()]).is_err());
        assert!(parse(&["gemm-bench".into(), "--sizes".into(), "x".into()]).is_err());
        assert!(parse(&["gemm-bench".into(), "--bogus".into()]).is_err());
    }

    #[test]
    fn parse_solver_bench_flags() {
        let parse_sb = |args: &[&str]| {
            let v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
            parse(&v)
        };
        match parse_sb(&["solver-bench", "--small", "--no-json"]).unwrap() {
            Command::SolverBench(o) => {
                assert_eq!(o.grids, vec![8, 16, 32]);
                assert_eq!(o.dd_sizes, vec![64, 256, 1024]);
                assert_eq!(o.max_iters, 400);
                assert!(o.json.is_none());
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        let args = ["solver-bench", "--grids", "8, 16", "--dd-sizes", "32", "--tol", "1e-4"];
        match parse_sb(&args).unwrap() {
            Command::SolverBench(o) => {
                assert_eq!(o.grids, vec![8, 16]);
                assert_eq!(o.dd_sizes, vec![32]);
                assert_eq!(o.tol, 1e-4);
                assert_eq!(o.json.as_deref(), Some("BENCH_solver.json"));
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        assert!(parse_sb(&["solver-bench", "--grids", "1"]).is_err());
        assert!(parse_sb(&["solver-bench", "--dd-sizes", "0"]).is_err());
        assert!(parse_sb(&["solver-bench", "--grids", "", "--dd-sizes", ""]).is_err());
        assert!(parse_sb(&["solver-bench", "--tol", "-1"]).is_err());
        assert!(parse_sb(&["solver-bench", "--tol", "nan"]).is_err());
        assert!(parse_sb(&["solver-bench", "--bogus"]).is_err());
    }

    #[test]
    fn solver_bench_smoke_passes_its_own_gates() {
        // Tiny end-to-end run of the bench harness itself: both gates
        // (SpMV bit-identity, quire <= fast on Poisson) must hold, with
        // no JSON side effects from a unit test.
        let o = SolverBenchOpts {
            grids: vec![6],
            dd_sizes: vec![24],
            tol: 1e-6,
            max_iters: 200,
            quire_max: 64,
            json: None,
        };
        let out = run_solver_bench(&o).unwrap();
        assert!(out.iter().any(|l| l.contains("bit-identical: yes")), "{out:?}");
    }

    #[test]
    fn bench_json_path_fails_fast_when_unwritable() {
        // The bugfix contract: an unwritable --json destination is a clean
        // error before any benchmarking happens (this test would take
        // minutes if the benches ran first), never a panic.
        let bad = "/nonexistent-dir-for-positron-test/out.json";
        let err = run_gemm_bench(&[4], 0, Some(bad)).unwrap_err();
        assert!(err.contains(bad), "{err}");
        let err = run_vector_bench(16, Some(bad)).unwrap_err();
        assert!(err.contains(bad), "{err}");
        let err = run_vector_bench64(16, Some(bad)).unwrap_err();
        assert!(err.contains(bad), "{err}");
        let o = SolverBenchOpts {
            grids: vec![2],
            dd_sizes: Vec::new(),
            quire_max: 4,
            json: Some(bad.to_string()),
            ..SolverBenchOpts::default()
        };
        let err = run_solver_bench(&o).unwrap_err();
        assert!(err.contains(bad), "{err}");
        let o = CertifyBenchOpts {
            requests: 8,
            clients: 1,
            certify_rate: 4,
            small: true,
            json: Some(bad.to_string()),
        };
        let err = run_certify_bench(&o).unwrap_err();
        assert!(err.contains(bad), "{err}");
    }

    #[test]
    fn parse_vector_bench_bits_flag() {
        let parse_vb = |args: &[&str]| {
            let v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
            parse(&v).unwrap()
        };
        match parse_vb(&["vector-bench", "--bits", "64", "--len", "128"]) {
            Command::VectorBench { len, bits, json } => {
                assert_eq!((len, bits), (128, 64));
                assert_eq!(json.as_deref(), Some("BENCH_vector_codec64.json"));
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        match parse_vb(&["vector-bench"]) {
            Command::VectorBench { bits, json, .. } => {
                assert_eq!(bits, 32);
                assert_eq!(json.as_deref(), Some("BENCH_vector_codec.json"));
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        // Explicit --json wins regardless of width; --no-json disables.
        match parse_vb(&["vector-bench", "--bits", "64", "--json", "x.json"]) {
            Command::VectorBench { json, .. } => assert_eq!(json.as_deref(), Some("x.json")),
            other => panic!("unexpected parse: {other:?}"),
        }
        match parse_vb(&["vector-bench", "--bits", "64", "--no-json"]) {
            Command::VectorBench { json, .. } => assert!(json.is_none()),
            other => panic!("unexpected parse: {other:?}"),
        }
        assert!(parse(&["vector-bench".into(), "--bits".into(), "48".into()]).is_err());
    }

    #[test]
    fn parse_serve_and_serve_bench_flags() {
        let args: Vec<String> = [
            "serve",
            "--backend",
            "native",
            "--format",
            "bp64",
            "--http",
            "127.0.0.1:0",
            "--deadline-ms",
            "250",
            "--synthetic",
            "--no-tracing",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        match parse(&args).unwrap() {
            Command::Serve(o) => {
                assert_eq!(o.backend, BackendKind::Native);
                assert_eq!(o.format, WeightFormat::Bp64);
                assert_eq!(o.http.as_deref(), Some("127.0.0.1:0"));
                assert_eq!(o.deadline_ms, Some(250));
                assert!(o.synthetic);
                assert!(!o.tracing);
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        // Defaults: native backend, bp32 weights, no listener, tracing on.
        match parse(&["serve".to_string()]).unwrap() {
            Command::Serve(o) => {
                assert_eq!(o.backend, BackendKind::Native);
                assert_eq!(o.format, WeightFormat::Bp32);
                assert!(o.http.is_none() && o.deadline_ms.is_none() && !o.synthetic);
                assert!(o.tracing);
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        assert!(parse(&["serve".into(), "--backend".into(), "gpu".into()]).is_err());
        assert!(parse(&["serve".into(), "--format".into(), "fp8".into()]).is_err());
        // Multi-model routing flags.
        let args: Vec<String> =
            ["serve", "--http", "127.0.0.1:0", "--models", "f32,bp64", "--max-inflight", "128"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        match parse(&args).unwrap() {
            Command::Serve(o) => {
                assert_eq!(o.models, vec![WeightFormat::F32, WeightFormat::Bp64]);
                assert_eq!(o.max_inflight, Some(128));
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        let args: Vec<String> = ["serve", "--http", "127.0.0.1:0", "--models", "all"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        match parse(&args).unwrap() {
            Command::Serve(o) => assert_eq!(o.models, WeightFormat::ALL.to_vec()),
            other => panic!("unexpected parse: {other:?}"),
        }
        // --models without a listener, or on PJRT, is rejected.
        assert!(parse(&["serve".into(), "--models".into(), "f32".into()]).is_err());
        let args: Vec<String> = [
            "serve",
            "--http",
            "127.0.0.1:0",
            "--backend",
            "pjrt",
            "--models",
            "f32",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert!(parse(&args).is_err());
        assert!(parse(&["serve".into(), "--models".into(), "fp8".into()]).is_err());
        assert!(
            parse(&["serve".into(), "--synthetic".into(), "--backend".into(), "pjrt".into()])
                .is_err()
        );
        let args: Vec<String> = ["serve-bench", "--small", "--format", "f32", "--no-json"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        match parse(&args).unwrap() {
            Command::ServeBench(o) => {
                assert!(o.small);
                assert_eq!(o.format, WeightFormat::F32);
                assert!(o.json.is_none());
                assert!(o.requests <= 256);
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        match parse(&["serve-bench".to_string()]).unwrap() {
            Command::ServeBench(o) => {
                assert_eq!(o.json.as_deref(), Some("BENCH_serve_native.json"));
                assert_eq!(o.format, WeightFormat::Bp32);
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        // --small and --requests compose flag-order-independently.
        for args in [["serve-bench", "--small", "--requests", "1000"],
            ["serve-bench", "--requests", "1000", "--small"]]
        {
            let v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
            match parse(&v).unwrap() {
                Command::ServeBench(o) => {
                    assert!(o.small);
                    assert_eq!(o.requests, 1000, "{args:?}");
                }
                other => panic!("unexpected parse: {other:?}"),
            }
        }
        assert!(parse(&["serve-bench".into(), "--requests".into(), "0".into()]).is_err());
    }

    #[test]
    fn parse_certify_bench_flags() {
        let parse_cb = |args: &[&str]| {
            let v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
            parse(&v)
        };
        match parse_cb(&["certify-bench"]).unwrap() {
            Command::CertifyBench(o) => {
                assert_eq!(o.certify_rate, 16);
                assert_eq!(o.json.as_deref(), Some("BENCH_certify.json"));
                assert!(!o.small);
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        match parse_cb(&["certify-bench", "--small", "--certify-rate", "8", "--no-json"]).unwrap()
        {
            Command::CertifyBench(o) => {
                assert!(o.small);
                assert_eq!(o.certify_rate, 8);
                assert!(o.json.is_none());
                assert!(o.requests <= 256);
            }
            other => panic!("unexpected parse: {other:?}"),
        }
        // --small composes with an explicit --requests flag-order-free.
        match parse_cb(&["certify-bench", "--requests", "999", "--small"]).unwrap() {
            Command::CertifyBench(o) => assert_eq!(o.requests, 999),
            other => panic!("unexpected parse: {other:?}"),
        }
        assert!(parse_cb(&["certify-bench", "--certify-rate", "0"]).is_err());
        assert!(parse_cb(&["certify-bench", "--requests", "0"]).is_err());
        assert!(parse_cb(&["certify-bench", "--bogus"]).is_err());
        // serve grew the matching knob.
        match parse_cb(&["serve", "--certify-rate", "32"]).unwrap() {
            Command::Serve(o) => assert_eq!(o.certify_rate, 32),
            other => panic!("unexpected parse: {other:?}"),
        }
        match parse_cb(&["serve"]).unwrap() {
            Command::Serve(o) => assert_eq!(o.certify_rate, 0),
            other => panic!("unexpected parse: {other:?}"),
        }
    }

    /// The transliteration contract: the Rust probes must reproduce the
    /// Python Fraction-mirror's pinned (max_width, max_obs_err) bits
    /// exactly (python/tests/test_certify_mirror.py BENCH_EXPECT).
    #[test]
    fn certify_probes_match_python_mirror_pins() {
        use crate::vector::lane::LaneElem;
        let (w, e, contained) = certify_probe32(|v| {
            <f32 as LaneElem>::bp_decode_lane(<f32 as LaneElem>::bp_encode_lane(v))
        })
        .unwrap();
        assert!(contained, "bp32 probe containment");
        assert_eq!(w.to_bits(), CERTIFY_EXPECT_BP32.0, "bp32 width {:016x}", w.to_bits());
        assert_eq!(e.to_bits(), CERTIFY_EXPECT_BP32.1, "bp32 err {:016x}", e.to_bits());
        assert!(w / e < 10.0, "bp32 ratio {}", w / e);

        let (w, e, contained) = certify_probe32(|v| {
            <f32 as LaneElem>::pstd_decode_lane(<f32 as LaneElem>::pstd_encode_lane(v))
        })
        .unwrap();
        assert!(contained, "p32 probe containment");
        assert_eq!(w.to_bits(), CERTIFY_EXPECT_P32.0, "p32 width {:016x}", w.to_bits());
        assert_eq!(e.to_bits(), CERTIFY_EXPECT_P32.1, "p32 err {:016x}", e.to_bits());
        assert!(w / e < 10.0, "p32 ratio {}", w / e);

        let (w, contained) = certify_probe64().unwrap();
        assert!(contained, "bp64 probe containment");
        assert_eq!(w.to_bits(), CERTIFY_EXPECT_BP64, "bp64 width {:016x}", w.to_bits());
        assert!(w > 0.0 && w < 1e-9, "bp64 width {w:.3e}");
    }

    #[test]
    fn certify_bench_smoke_small() {
        // The CI smoke in-process: probes + a small certified/uncertified
        // server pair. Success means containment held, the widths matched
        // the mirror pins, every sampled response echoed a finite bound,
        // and the violation counter stayed 0 — all hard gates inside.
        let o = CertifyBenchOpts {
            requests: 32,
            clients: 2,
            certify_rate: 4,
            small: true,
            json: None,
        };
        let lines = run_certify_bench(&o).expect("small certify-bench runs");
        assert!(lines.iter().any(|l| l.contains("bit-equal the Python-mirror pins: yes")), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("0 violations")), "{lines:?}");
    }

    #[test]
    fn serve_bench_smoke_small() {
        // The CI smoke in-process: small synthetic model, no JSON. The
        // parity and HTTP gates are hard errors, so success here means
        // the native serving stack answered real HTTP requests with
        // logits bit-identical to the scalar reference.
        let o = ServeBenchOpts {
            requests: 32,
            clients: 2,
            format: WeightFormat::Bp32,
            small: true,
            json: None,
        };
        let lines = run_serve_bench(&o).expect("small serve-bench runs");
        assert!(lines.iter().any(|l| l.contains("bit-identical")), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("ok")), "{lines:?}");
    }

    #[test]
    fn serve_bench_json_path_fails_fast_when_unwritable() {
        let bad = "/nonexistent-dir-for-positron-test/serve.json";
        let o = ServeBenchOpts {
            requests: 8,
            clients: 1,
            format: WeightFormat::Bp32,
            small: true,
            json: Some(bad.to_string()),
        };
        let err = run_serve_bench(&o).unwrap_err();
        assert!(err.contains(bad), "{err}");
    }

    #[test]
    fn vector_bench64_smoke_tiny() {
        // Tiny block, no JSON: exercises the full 64-bit bench path
        // including the sharded bit-identity verification.
        let lines = run_vector_bench64(64, None).expect("tiny vector-bench64 runs");
        assert!(
            lines.iter().any(|l| l.contains("bit-identical to serial: yes")),
            "{lines:?}"
        );
    }

    #[test]
    fn gemm_bench_smoke_tiny() {
        // One tiny size, no JSON: exercises the full bench path (including
        // the bit-identity verification) in a few seconds of bench budget.
        let lines = run_gemm_bench(&[4], 4, None).expect("tiny gemm-bench runs");
        assert!(lines.iter().any(|l| l.contains("bit-identical to serial: yes")), "{lines:?}");
    }
}
