//! Runtime layer: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them. Python never runs on this
//! path — the artifacts are self-contained.
//!
//! The actual executor is PJRT/XLA-backed and lives in [`pjrt`], compiled
//! only with the `runtime` cargo feature (it needs the `xla` crate and a
//! libxla install; see rust/Cargo.toml). Default builds get a stub whose
//! constructors return a clear "runtime disabled" error, so every other
//! layer — formats, vector codec, coordinator codec path, CLI, benches —
//! builds and tests fully offline.
//!
//! [`Literal`] is the backend-agnostic host tensor exchanged with the
//! executor; it owns its buffer so the serving loop can reuse allocations
//! across batches ([`Literal::copy_from_f32`]).

use std::path::{Path, PathBuf};

use crate::error::{anyhow, Context, Result};
use crate::json::Json;

#[cfg(feature = "runtime")]
mod pjrt;

/// Error message for every entry point that needs the PJRT backend.
pub const RUNTIME_DISABLED: &str = "PJRT runtime disabled at build time: rebuild with `cargo build \
     --release --features runtime` (requires the `xla` crate and libxla; see rust/Cargo.toml)";

/// True when this build carries the PJRT/XLA backend.
pub fn runtime_enabled() -> bool {
    cfg!(feature = "runtime")
}

/// Backend-agnostic host tensor: typed buffer + dims. The buffer is plain
/// host memory; the PJRT backend converts on execute.
#[derive(Clone, Debug)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Literal {
    /// Total element count.
    pub fn len(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Overwrite an existing f32 literal in place (no reallocation) —
    /// the serving loop's per-batch input refresh.
    pub fn copy_from_f32(&mut self, src: &[f32]) -> Result<()> {
        match self {
            Literal::F32 { data, .. } if data.len() == src.len() => {
                data.copy_from_slice(src);
                Ok(())
            }
            Literal::F32 { data, .. } => {
                Err(anyhow!("literal length mismatch: have {}, got {}", data.len(), src.len()))
            }
            Literal::I32 { .. } => Err(anyhow!("copy_from_f32 on an i32 literal")),
        }
    }
}

/// Build a rank-1 f32 literal.
pub fn lit_f32(v: &[f32]) -> Literal {
    Literal::F32 { data: v.to_vec(), dims: vec![v.len()] }
}

/// Build a rank-2 f32 literal.
pub fn lit_f32_2d(v: &[f32], rows: usize, cols: usize) -> Result<Literal> {
    if v.len() != rows * cols {
        return Err(anyhow!("lit_f32_2d: {} elements for {rows}x{cols}", v.len()));
    }
    Ok(Literal::F32 { data: v.to_vec(), dims: vec![rows, cols] })
}

/// Build a rank-1 i32 literal.
pub fn lit_i32(v: &[i32]) -> Literal {
    Literal::I32 { data: v.to_vec(), dims: vec![v.len()] }
}

/// Build a rank-2 i32 literal.
pub fn lit_i32_2d(v: &[i32], rows: usize, cols: usize) -> Result<Literal> {
    if v.len() != rows * cols {
        return Err(anyhow!("lit_i32_2d: {} elements for {rows}x{cols}", v.len()));
    }
    Ok(Literal::I32 { data: v.to_vec(), dims: vec![rows, cols] })
}

/// A PJRT client plus the artifact directory.
pub struct Runtime {
    #[cfg(feature = "runtime")]
    backend: pjrt::Backend,
    dir: PathBuf,
}

/// One compiled executable (a single HLO module).
pub struct LoadedModel {
    #[cfg(feature = "runtime")]
    exe: pjrt::Executable,
    pub name: String,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifact directory. Errors with
    /// [`RUNTIME_DISABLED`] when built without the `runtime` feature.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifact_dir.as_ref().to_path_buf();
        #[cfg(feature = "runtime")]
        {
            Ok(Runtime { backend: pjrt::Backend::cpu()?, dir })
        }
        #[cfg(not(feature = "runtime"))]
        {
            let _ = dir;
            Err(anyhow!("{RUNTIME_DISABLED}"))
        }
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "runtime")]
        {
            self.backend.platform()
        }
        #[cfg(not(feature = "runtime"))]
        {
            "disabled".to_string()
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load and compile an HLO-text artifact (e.g. `model_bposit.hlo.txt`).
    pub fn load(&self, file: &str) -> Result<LoadedModel> {
        #[cfg(feature = "runtime")]
        {
            let exe = self.backend.compile(&self.dir.join(file))?;
            Ok(LoadedModel { exe, name: file.to_string() })
        }
        #[cfg(not(feature = "runtime"))]
        {
            let _ = file;
            Err(anyhow!("{RUNTIME_DISABLED}"))
        }
    }

    /// Read + parse a JSON artifact.
    pub fn json(&self, file: &str) -> Result<Json> {
        let path = self.dir.join(file);
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        Json::parse(&text).map_err(|e| anyhow!("parse {file}: {e}"))
    }
}

impl LoadedModel {
    /// Execute and read the output back as a f32 vector.
    pub fn run_f32(&self, inputs: &[Literal]) -> Result<Vec<f32>> {
        #[cfg(feature = "runtime")]
        {
            self.exe.run_f32(inputs).with_context(|| format!("execute {}", self.name))
        }
        #[cfg(not(feature = "runtime"))]
        {
            let _ = inputs;
            Err(anyhow!("{RUNTIME_DISABLED}"))
        }
    }

    /// Execute and read the output back as an i32 vector.
    pub fn run_i32(&self, inputs: &[Literal]) -> Result<Vec<i32>> {
        #[cfg(feature = "runtime")]
        {
            self.exe.run_i32(inputs).with_context(|| format!("execute {}", self.name))
        }
        #[cfg(not(feature = "runtime"))]
        {
            let _ = inputs;
            Err(anyhow!("{RUNTIME_DISABLED}"))
        }
    }
}

/// The trained model weights + golden vectors exported by aot.py.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub d: usize,
    pub h: usize,
    pub c: usize,
    pub batch: usize,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub w1_bits: Vec<i32>,
    pub w2_bits: Vec<i32>,
    pub golden_x: Vec<f32>,
    pub golden_y: Vec<i32>,
    pub golden_logits_f32: Vec<f32>,
    pub golden_logits_bposit: Vec<f32>,
}

impl ModelWeights {
    pub fn load(rt: &Runtime) -> Result<ModelWeights> {
        Self::load_from_dir(rt.dir())
    }

    /// Load `weights.json` straight from an artifact directory — no PJRT
    /// client, no `runtime` feature. This is the native serving backend's
    /// entire artifact dependency.
    pub fn load_from_dir(dir: impl AsRef<Path>) -> Result<ModelWeights> {
        let path = dir.as_ref().join("weights.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let f = |k: &str| -> Result<Vec<f32>> {
            j.get(k).and_then(|v| v.as_f32_vec()).ok_or_else(|| anyhow!("weights.json missing {k}"))
        };
        let i = |k: &str| -> Result<Vec<i32>> {
            Ok(j.get(k)
                .and_then(|v| v.as_i64_vec())
                .ok_or_else(|| anyhow!("weights.json missing {k}"))?
                .into_iter()
                .map(|x| x as i32)
                .collect())
        };
        let dim = |k: &str| -> Result<usize> {
            j.get(k).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("missing {k}"))
        };
        Ok(ModelWeights {
            d: dim("d")?,
            h: dim("h")?,
            c: dim("c")?,
            batch: dim("batch")?,
            w1: f("w1")?,
            b1: f("b1")?,
            w2: f("w2")?,
            b2: f("b2")?,
            w1_bits: i("w1_bits")?,
            w2_bits: i("w2_bits")?,
            golden_x: f("golden_x")?,
            golden_y: i("golden_y")?,
            golden_logits_f32: f("golden_logits_f32")?,
            golden_logits_bposit: f("golden_logits_bposit")?,
        })
    }

    /// Literals for the quantized model in aot.py's argument order
    /// (w1_bits, b1, w2_bits, b2) — prepend the batch literal to call.
    pub fn bposit_arg_literals(&self) -> Result<Vec<Literal>> {
        Ok(vec![
            lit_i32_2d(&self.w1_bits, self.d, self.h)?,
            lit_f32(&self.b1),
            lit_i32_2d(&self.w2_bits, self.h, self.c)?,
            lit_f32(&self.b2),
        ])
    }

    /// Literals for the f32 model (w1, b1, w2, b2).
    pub fn f32_arg_literals(&self) -> Result<Vec<Literal>> {
        Ok(vec![
            lit_f32_2d(&self.w1, self.d, self.h)?,
            lit_f32(&self.b1),
            lit_f32_2d(&self.w2, self.h, self.c)?,
            lit_f32(&self.b2),
        ])
    }
}

/// Locate the artifact directory: $POSITRON_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("POSITRON_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if the AOT artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("model_bposit.hlo.txt").exists() && dir.join("weights.json").exists()
}

/// True if `weights.json` exists — all the native serving backend needs
/// (the compiled HLO artifacts are only required by the PJRT backend).
pub fn weights_available(dir: &Path) -> bool {
    dir.join("weights.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_copy() {
        let mut l = lit_f32_2d(&[1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        assert_eq!(l.len(), 4);
        l.copy_from_f32(&[5.0, 6.0, 7.0, 8.0]).unwrap();
        match &l {
            Literal::F32 { data, dims } => {
                assert_eq!(data, &vec![5.0, 6.0, 7.0, 8.0]);
                assert_eq!(dims, &vec![2, 2]);
            }
            _ => panic!("wrong variant"),
        }
        assert!(l.copy_from_f32(&[1.0]).is_err());
        assert!(lit_i32(&[1]).len() == 1);
        assert!(lit_f32_2d(&[1.0], 2, 2).is_err());
        assert!(lit_i32_2d(&[1], 2, 2).is_err());
    }

    #[test]
    fn stub_reports_disabled() {
        if runtime_enabled() {
            return; // real backend present; covered by integration tests
        }
        let err = Runtime::cpu("artifacts").unwrap_err();
        assert!(err.to_string().contains("runtime disabled"), "{err}");
    }
}
