//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python never runs on this path — the artifacts are self-contained.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::Json;

/// A PJRT client plus the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

/// One compiled executable (a single HLO module).
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir: artifact_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load and compile an HLO-text artifact (e.g. `model_bposit.hlo.txt`).
    pub fn load(&self, file: &str) -> Result<LoadedModel> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {file}: {e:?}"))?;
        Ok(LoadedModel { exe, name: file.to_string() })
    }

    /// Read + parse a JSON artifact.
    pub fn json(&self, file: &str) -> Result<Json> {
        let path = self.dir.join(file);
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        Json::parse(&text).map_err(|e| anyhow!("parse {file}: {e}"))
    }
}

impl LoadedModel {
    /// Execute with the given literals; unwraps the 1-tuple result
    /// (aot.py lowers with return_tuple=True).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// Execute and read the output back as a f32 vector.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let out = self.run(inputs)?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
    }

    /// Execute and read the output back as an i32 vector.
    pub fn run_i32(&self, inputs: &[xla::Literal]) -> Result<Vec<i32>> {
        let out = self.run(inputs)?;
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
    }
}

/// Build a rank-1 f32 literal.
pub fn lit_f32(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Build a rank-2 f32 literal.
pub fn lit_f32_2d(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(v.len(), rows * cols);
    xla::Literal::vec1(v)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build a rank-1 i32 literal.
pub fn lit_i32(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Build a rank-2 i32 literal.
pub fn lit_i32_2d(v: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(v.len(), rows * cols);
    xla::Literal::vec1(v)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// The trained model weights + golden vectors exported by aot.py.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub d: usize,
    pub h: usize,
    pub c: usize,
    pub batch: usize,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub w1_bits: Vec<i32>,
    pub w2_bits: Vec<i32>,
    pub golden_x: Vec<f32>,
    pub golden_y: Vec<i32>,
    pub golden_logits_f32: Vec<f32>,
    pub golden_logits_bposit: Vec<f32>,
}

impl ModelWeights {
    pub fn load(rt: &Runtime) -> Result<ModelWeights> {
        let j = rt.json("weights.json")?;
        let f = |k: &str| -> Result<Vec<f32>> {
            j.get(k).and_then(|v| v.as_f32_vec()).ok_or_else(|| anyhow!("weights.json missing {k}"))
        };
        let i = |k: &str| -> Result<Vec<i32>> {
            Ok(j.get(k)
                .and_then(|v| v.as_i64_vec())
                .ok_or_else(|| anyhow!("weights.json missing {k}"))?
                .into_iter()
                .map(|x| x as i32)
                .collect())
        };
        let dim = |k: &str| -> Result<usize> {
            j.get(k).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("missing {k}"))
        };
        Ok(ModelWeights {
            d: dim("d")?,
            h: dim("h")?,
            c: dim("c")?,
            batch: dim("batch")?,
            w1: f("w1")?,
            b1: f("b1")?,
            w2: f("w2")?,
            b2: f("b2")?,
            w1_bits: i("w1_bits")?,
            w2_bits: i("w2_bits")?,
            golden_x: f("golden_x")?,
            golden_y: i("golden_y")?,
            golden_logits_f32: f("golden_logits_f32")?,
            golden_logits_bposit: f("golden_logits_bposit")?,
        })
    }

    /// Literals for the quantized model in aot.py's argument order
    /// (w1_bits, b1, w2_bits, b2) — prepend the batch literal to call.
    pub fn bposit_arg_literals(&self) -> Result<Vec<xla::Literal>> {
        Ok(vec![
            lit_i32_2d(&self.w1_bits, self.d, self.h)?,
            lit_f32(&self.b1),
            lit_i32_2d(&self.w2_bits, self.h, self.c)?,
            lit_f32(&self.b2),
        ])
    }

    /// Literals for the f32 model (w1, b1, w2, b2).
    pub fn f32_arg_literals(&self) -> Result<Vec<xla::Literal>> {
        Ok(vec![
            lit_f32_2d(&self.w1, self.d, self.h)?,
            lit_f32(&self.b1),
            lit_f32_2d(&self.w2, self.h, self.c)?,
            lit_f32(&self.b2),
        ])
    }
}

/// Locate the artifact directory: $POSITRON_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("POSITRON_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if the AOT artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("model_bposit.hlo.txt").exists() && dir.join("weights.json").exists()
}
