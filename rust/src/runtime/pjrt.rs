//! XLA/PJRT-backed executor — compiled only with `--features runtime`.
//! Requires the `xla` crate (xla-rs) and a libxla install; see
//! rust/Cargo.toml for how to vendor it. Everything xla-typed stays inside
//! this module so the rest of the crate is backend-agnostic.

use std::path::Path;

use super::Literal;
use crate::error::{anyhow, Result};

/// CPU PJRT client.
pub struct Backend {
    client: xla::PjRtClient,
}

/// One compiled HLO module.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Backend {
    pub fn cpu() -> Result<Backend> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Backend { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Parse + compile an HLO-text artifact.
    pub fn compile(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Executable { exe })
    }
}

/// Convert a backend-agnostic literal to an xla literal.
fn to_xla(l: &Literal) -> Result<xla::Literal> {
    let (lit, dims) = match l {
        Literal::F32 { data, dims } => (xla::Literal::vec1(data.as_slice()), dims),
        Literal::I32 { data, dims } => (xla::Literal::vec1(data.as_slice()), dims),
    };
    if dims.len() <= 1 {
        return Ok(lit);
    }
    let shape: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&shape).map_err(|e| anyhow!("reshape: {e:?}"))
}

impl Executable {
    /// Execute with the given literals; unwraps the 1-tuple result
    /// (aot.py lowers with return_tuple=True).
    fn run(&self, inputs: &[Literal]) -> Result<xla::Literal> {
        let args: Vec<xla::Literal> = inputs.iter().map(to_xla).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    pub fn run_f32(&self, inputs: &[Literal]) -> Result<Vec<f32>> {
        let out = self.run(inputs)?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
    }

    pub fn run_i32(&self, inputs: &[Literal]) -> Result<Vec<i32>> {
        let out = self.run(inputs)?;
        out.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
    }
}
