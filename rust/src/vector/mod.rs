//! Vector layer: the serving hot path's data plane.
//!
//! Five parts:
//! - [`codec`] — branch-free, chunked (8-lane) batched encode/decode for
//!   b-posit⟨32,6,5⟩, posit⟨32,2⟩, any ⟨n≤32,rs,es⟩ spec, and f32⇄bits,
//!   with in-place variants for zero-allocation buffer reuse. This is the
//!   software mirror of the paper's bounded-regime ⇒ fixed-mux insight.
//! - [`codec64`] — the 64-bit rung of the same lane structure: any
//!   ⟨n≤64,rs,es⟩ spec over `&[f64]`/`&[u64]` streams with u128
//!   intermediates, plus `bp64_*`/`p64_*` named fast paths — the paper's
//!   "greater advantages at 64-bit" scalability claim, in software.
//! - [`kernels`] — batched `dot`, `axpy`, and `gemv` over f32 *and* f64
//!   with quire-exact accumulation ([`crate::formats::Quire`]: the
//!   800-bit posit quire, plus an f64-range exact sizing) and rounded
//!   fast paths, and `par_gemv_*` row-sharded variants.
//! - [`gemm`] — register/L1-blocked GEMM (fast, quire-exact, and
//!   quantized-weight paths at both widths on the same MR×NR
//!   microkernel), serial and row-sharded.
//! - [`parallel`] — zero-dependency scoped fork-join sharding over
//!   `std::thread` workers (`PALLAS_THREADS`, auto default), used by the
//!   batched codecs, gemv, and GEMM. Shards are contiguous row/element
//!   blocks, so every `par_*` result is bit-identical to serial for any
//!   thread count.
//!
//! The coordinator's quantizer routes every batch through the sharded
//! codecs; `positron vector-bench` (32- and 64-bit modes) / `gemm-bench`
//! and the `vector_codec` / `vector_codec64` / `vector_gemm` bench
//! targets measure throughput and emit `BENCH_vector_codec.json` /
//! `BENCH_vector_codec64.json` / `BENCH_vector_gemm.json`.

pub mod codec;
pub mod codec64;
pub mod gemm;
pub mod kernels;
pub mod parallel;

pub use codec::LANES;

use crate::formats::posit::PositSpec;

/// Which batched codec implementation serves a spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecRoute {
    /// 32-bit lane codec ([`codec`]): n ≤ 32 over u32/f32 streams.
    Lane32,
    /// 64-bit lane codec ([`codec64`]): 32 < n ≤ 64 over u64/f64 streams.
    Lane64,
    /// General pattern-space codec in `formats::posit` (es = 0, n = 2, …).
    General,
}

/// Route a spec to its batched codec tier: the narrowest lane codec that
/// supports it, else the general codec. Narrow specs (n ≤ 32) are also
/// valid for [`codec64`] — its generic path is a strict superset — but
/// the 32-bit lanes are the faster stream type for them.
pub fn route_spec(spec: &PositSpec) -> CodecRoute {
    if codec::spec_supported(spec) {
        CodecRoute::Lane32
    } else if codec64::spec_supported(spec) {
        CodecRoute::Lane64
    } else {
        CodecRoute::General
    }
}
