//! Vector layer: the serving hot path's data plane.
//!
//! Two halves:
//! - [`codec`] — branch-free, chunked (8-lane) batched encode/decode for
//!   b-posit⟨32,6,5⟩, posit⟨32,2⟩, any ⟨n≤32,rs,es⟩ spec, and f32⇄bits,
//!   with in-place variants for zero-allocation buffer reuse. This is the
//!   software mirror of the paper's bounded-regime ⇒ fixed-mux insight.
//! - [`kernels`] — batched `dot`, `axpy`, and `gemv` with 800-bit
//!   [`crate::formats::Quire`]-exact accumulation plus rounded f32 fast
//!   paths: the repo's first linear-algebra workload, and the layer later
//!   scaling work (explicit SIMD, sharding, GEMM) plugs into.
//!
//! The coordinator's quantizer routes every batch through [`codec`];
//! `positron vector-bench` and `cargo bench --bench vector_codec` measure
//! the scalar-vs-vector throughput and emit `BENCH_vector_codec.json`.

pub mod codec;
pub mod kernels;

pub use codec::LANES;
