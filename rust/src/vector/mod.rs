//! Vector layer: the serving hot path's data plane.
//!
//! Four parts:
//! - [`codec`] — branch-free, chunked (8-lane) batched encode/decode for
//!   b-posit⟨32,6,5⟩, posit⟨32,2⟩, any ⟨n≤32,rs,es⟩ spec, and f32⇄bits,
//!   with in-place variants for zero-allocation buffer reuse. This is the
//!   software mirror of the paper's bounded-regime ⇒ fixed-mux insight.
//! - [`kernels`] — batched `dot`, `axpy`, and `gemv` with 800-bit
//!   [`crate::formats::Quire`]-exact accumulation plus rounded f32 fast
//!   paths, and `par_gemv_*` row-sharded variants.
//! - [`gemm`] — register/L1-blocked GEMM (f32 fast path, quire-exact
//!   path, quantized-weight serving path), serial and row-sharded; the
//!   quantized-matmul workload at tensor scale.
//! - [`parallel`] — zero-dependency scoped fork-join sharding over
//!   `std::thread` workers (`PALLAS_THREADS`, auto default), used by the
//!   batched codec, gemv, and GEMM. Shards are contiguous row/element
//!   blocks, so every `par_*` result is bit-identical to serial for any
//!   thread count.
//!
//! The coordinator's quantizer routes every batch through the sharded
//! codec; `positron vector-bench` / `gemm-bench` and the `vector_codec` /
//! `vector_gemm` bench targets measure throughput and emit
//! `BENCH_vector_codec.json` / `BENCH_vector_gemm.json`.

pub mod codec;
pub mod gemm;
pub mod kernels;
pub mod parallel;

pub use codec::LANES;
