//! Vector layer: the serving hot path's data plane.
//!
//! The layer is organized around **one width-generic lane API** — the
//! software mirror of the paper's claim that the bounded regime makes
//! b-posit decode/encode structurally identical across widths:
//!
//! - [`lane`] — the width axis itself: the [`lane::LaneElem`] trait
//!   (f32 ↔ u32/u64, f64 ↔ u64/u128), the branch-free 8-lane
//!   encode/decode primitives expanded from **one macro body** at both
//!   widths, the generic engine [`lane::LaneCodec`], and the
//!   spec-carrying typed weight buffer [`lane::EncodedTensor`] that
//!   replaces raw `&[u32]`/`&[u64]` slices at API boundaries.
//! - [`codec`] / [`codec64`] — the named BP32/P32 and BP64/P64 fast
//!   paths and per-width slice drivers, as monomorphized spec constants
//!   over the lane engine (kept as the historical entry-point names; see
//!   `docs/API.md` for the migration table).
//! - [`kernels`] — one generic `dot`/`axpy`/`gemv` family over any
//!   [`lane::LaneElem`], with rounded fast paths, quire-exact paths
//!   ([`crate::formats::Quire`]), decode-fused quantized-weight paths,
//!   and row-sharded `par_*` entry points.
//! - [`gemm`] — one generic register/L1-blocked GEMM family (fast,
//!   quire-exact, and quantized-weight paths) on a shared MR×NR
//!   microkernel, serial and row-sharded, plus the
//!   [`lane::EncodedTensor`]-consuming serving entry point.
//! - [`sparse`] — CSR matrix type + SpMV in the same three kernel
//!   flavors as the dense gemv family (fast, quire-exact, decode-fused
//!   quantized-weight) with row-sharded `par_spmv_*` forms; the fast row
//!   kernel is chunk-aware so SpMV is bit-identical to dense
//!   [`kernels::gemv`] on the densification. Feeds [`crate::solver`].
//! - [`parallel`] — zero-dependency scoped fork-join sharding over
//!   `std::thread` workers (`PALLAS_THREADS`, auto default) with one
//!   generic sharded-codec family. Shards are contiguous row/element
//!   blocks, so every `par_*` result is bit-identical to serial for any
//!   thread count.
//!
//! The coordinator's quantizer routes every batch through the sharded
//! generic codec; `positron vector-bench` (one generic code path for
//! both `--bits` modes) / `gemm-bench` and the `vector_codec` /
//! `vector_codec64` / `vector_gemm` bench targets measure throughput and
//! emit `BENCH_vector_codec.json` / `BENCH_vector_codec64.json` /
//! `BENCH_vector_gemm.json`.

pub mod codec;
pub mod codec64;
pub mod gemm;
pub mod kernels;
pub mod lane;
pub mod parallel;
pub mod sparse;

pub use lane::{EncodedTensor, LaneCodec, LaneElem, LaneSigned, LANES};

use crate::formats::posit::PositSpec;
use crate::formats::Decoded;

/// Which batched codec implementation serves a spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecRoute {
    /// 32-bit lane codec ([`codec`]): n ≤ 32 over u32/f32 streams.
    Lane32,
    /// 64-bit lane codec ([`codec64`]): 32 < n ≤ 64 over u64/f64 streams.
    Lane64,
    /// General pattern-space codec in `formats::posit` (es = 0, n = 2, …).
    General,
}

/// Route a spec to its batched codec tier: the narrowest lane codec that
/// supports it, else the general codec. Narrow specs (n ≤ 32) are also
/// valid for [`codec64`] — its generic path is a strict superset — but
/// the 32-bit lanes are the faster stream type for them.
///
/// Callers that would `match` on the result to pick an implementation
/// should use [`dispatch_spec`] instead: it returns a handle that has
/// already done the dispatch.
pub fn route_spec(spec: &PositSpec) -> CodecRoute {
    if codec::spec_supported(spec) {
        CodecRoute::Lane32
    } else if codec64::spec_supported(spec) {
        CodecRoute::Lane64
    } else {
        CodecRoute::General
    }
}

/// A routed batch codec for an arbitrary spec: the typed replacement for
/// "`match route_spec(..)` and call a per-tier API". Exchange types are
/// the width superset (f64 values, u64 words, valid for every n ≤ 64),
/// so one handle serves lane-supported and general-codec specs alike;
/// the lane tiers run the branch-free engine, the general tier runs the
/// exact pattern-space codec under the same FTZ/NaR contract.
#[derive(Clone, Copy, Debug)]
pub struct DispatchCodec {
    spec: PositSpec,
    route: CodecRoute,
}

/// Build the routed codec handle for `spec` — see [`DispatchCodec`].
pub fn dispatch_spec(spec: &PositSpec) -> DispatchCodec {
    DispatchCodec { spec: *spec, route: route_spec(spec) }
}

impl DispatchCodec {
    /// Which tier this handle dispatches to (diagnostics; no need to
    /// match on it to use the codec).
    pub fn route(&self) -> CodecRoute {
        self.route
    }

    /// The spec this handle serves.
    pub fn spec(&self) -> PositSpec {
        self.spec
    }

    /// Encode one f64 (FTZ below 2^−1022, NaN/Inf → NaR).
    pub fn encode_one(&self, x: f64) -> u64 {
        match self.route {
            // Both lane tiers run the 64-bit lane engine: at f64 exchange
            // width it is a strict superset of the 32-bit lanes and
            // bit-identical to the general codec under the contract.
            CodecRoute::Lane32 | CodecRoute::Lane64 => {
                <f64 as LaneElem>::encode_lane(self.spec.n, self.spec.rs, self.spec.es, x)
            }
            CodecRoute::General => {
                if !x.is_finite() {
                    self.spec.nar()
                } else if x == 0.0 || x.abs() < f64::MIN_POSITIVE {
                    0
                } else {
                    self.spec.encode(&Decoded::from_f64(x))
                }
            }
        }
    }

    /// Decode one word to f64 (sub-normal-range magnitudes flush to ±0,
    /// NaR → NaN).
    pub fn decode_one(&self, w: u64) -> f64 {
        match self.route {
            CodecRoute::Lane32 | CodecRoute::Lane64 => {
                <f64 as LaneElem>::decode_lane(self.spec.n, self.spec.rs, self.spec.es, w)
            }
            CodecRoute::General => {
                let v = self.spec.decode(w & self.spec.mask()).to_f64();
                if v != 0.0 && v.abs() < f64::MIN_POSITIVE {
                    if v < 0.0 {
                        -0.0
                    } else {
                        0.0
                    }
                } else {
                    v
                }
            }
        }
    }

    /// Batched encode into a caller-owned buffer (`out.len() == xs.len()`).
    pub fn encode_into(&self, xs: &[f64], out: &mut [u64]) {
        assert_eq!(xs.len(), out.len(), "dispatch encode: length mismatch");
        match self.route {
            CodecRoute::Lane32 | CodecRoute::Lane64 => {
                lane::encode_slice::<f64>(self.spec.n, self.spec.rs, self.spec.es, xs, out);
            }
            CodecRoute::General => {
                for (o, &x) in out.iter_mut().zip(xs) {
                    *o = self.encode_one(x);
                }
            }
        }
    }

    /// Batched decode into a caller-owned buffer.
    pub fn decode_into(&self, ws: &[u64], out: &mut [f64]) {
        assert_eq!(ws.len(), out.len(), "dispatch decode: length mismatch");
        match self.route {
            CodecRoute::Lane32 | CodecRoute::Lane64 => {
                lane::decode_slice::<f64>(self.spec.n, self.spec.rs, self.spec.es, ws, out);
            }
            CodecRoute::General => {
                for (o, &w) in out.iter_mut().zip(ws) {
                    *o = self.decode_one(w);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{BP32, BP64, P64};
    use crate::testutil::Rng;

    #[test]
    fn dispatch_serves_lane_and_general_specs_without_matching() {
        let mut rng = Rng::new(0xd15);
        // A spec from each tier; the *caller* code below is identical for
        // all three — that is the point of the handle.
        let es0 = PositSpec { n: 16, rs: 15, es: 0 };
        for (spec, want_route) in [
            (BP32, CodecRoute::Lane32),
            (BP64, CodecRoute::Lane64),
            (P64, CodecRoute::Lane64),
            (es0, CodecRoute::General),
        ] {
            let dc = dispatch_spec(&spec);
            assert_eq!(dc.route(), want_route, "{spec:?}");
            assert_eq!(dc.spec(), spec);
            let xs: Vec<f64> = (0..100)
                .map(|_| {
                    let v = f64::from_bits(rng.next_u64());
                    if v.is_finite() { v } else { 1.5 }
                })
                .collect();
            let mut words = vec![0u64; xs.len()];
            dc.encode_into(&xs, &mut words);
            let mut back = vec![0f64; xs.len()];
            dc.decode_into(&words, &mut back);
            for (i, (&w, &y)) in words.iter().zip(&back).enumerate() {
                assert_eq!(w, dc.encode_one(xs[i]), "{spec:?} lane {i}");
                let one = dc.decode_one(w);
                assert!(
                    y.to_bits() == one.to_bits() || (y.is_nan() && one.is_nan()),
                    "{spec:?} lane {i}"
                );
                // decode∘encode is idempotent on every tier.
                let w2 = dc.encode_one(y);
                let y2 = dc.decode_one(w2);
                assert!(
                    y2.to_bits() == y.to_bits() || (y2.is_nan() && y.is_nan()),
                    "{spec:?} idempotence lane {i}"
                );
            }
            // Contract corners hold on every tier.
            assert_eq!(dc.encode_one(f64::NAN), spec.nar());
            assert_eq!(dc.encode_one(0.0), 0);
            assert_eq!(dc.encode_one(f64::from_bits(1)), 0, "FTZ on {spec:?}");
            assert!(dc.decode_one(spec.nar()).is_nan());
        }
    }

    #[test]
    fn dispatch_lane_tiers_match_codec64_bitwise() {
        let mut rng = Rng::new(0xd16);
        for spec in [BP32, BP64, P64, PositSpec::bounded(48, 6, 5)] {
            let dc = dispatch_spec(&spec);
            for _ in 0..5_000 {
                let w = rng.next_u64();
                let x = f64::from_bits(w);
                assert_eq!(dc.encode_one(x), codec64::encode_word(&spec, x), "{spec:?}");
                let (a, b) = (dc.decode_one(w), codec64::decode_word(&spec, w));
                assert!(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()), "{spec:?}");
            }
        }
    }
}
