//! Width-generic lane API: **one** codec/kernel/quantizer surface for the
//! 32- and 64-bit tiers.
//!
//! The paper's bounded-regime insight makes b-posit decode/encode
//! *structurally identical* across widths — the only things that change
//! from 32 to 64 bits are the word type (u32 → u64), the serialization
//! intermediate (u64 → u128), the float exchange type (f32 → f64), and a
//! handful of IEEE field constants. This module says exactly that, once:
//!
//! - [`LaneElem`] — the width axis as a trait, implemented for `f32` and
//!   `f64`. It carries the word/intermediate types, the IEEE constants,
//!   the serving-format spec constants ([`LaneElem::BP`] = ⟨N,6,5⟩,
//!   [`LaneElem::PSTD`] = ⟨N,2⟩), and the branch-free lane primitives.
//!   Both impls are expanded from **one** macro body (`lane_elem_impl!`),
//!   so the 32- and 64-bit datapaths cannot drift apart: they are the
//!   same token stream with different width parameters, and the expansion
//!   with the 32-bit parameters is exactly the algorithm previously
//!   hand-duplicated in `codec.rs`/`codec64.rs`. Outputs are gated
//!   bit-identical to the pre-refactor codecs by the golden-vector,
//!   parity, and proptest suites.
//! - [`LaneSigned`] — the inverse axis (`i32`/`i64`, the wire bit-pattern
//!   types), so decode-direction generics infer their width from the
//!   argument type alone.
//! - [`LaneCodec`] — the generic engine: a spec-checked batched
//!   encode/decode/roundtrip context over any lane-supported
//!   ⟨n ≤ N, rs, 1 ≤ es ≤ 8⟩ spec at either width. The named BP32 / P32 /
//!   BP64 / P64 fast paths in [`super::codec`] / [`super::codec64`] are
//!   monomorphized spec constants over this engine.
//! - [`EncodedTensor`] — a spec-carrying typed weight buffer that
//!   replaces raw `&[u32]`/`&[u64]` slices at API boundaries: a width
//!   mismatch is now a *type* error (`EncodedTensor<f32>` vs
//!   `EncodedTensor<f64>`), and a spec or shape mismatch is a checked
//!   constructor error instead of silently misinterpreted bits.
//!
//! Consumers: `vector::parallel` shards the generic engine,
//! `vector::kernels`/`vector::gemm` run one generic kernel family over
//! `E`, and `coordinator::quantizer`/`coordinator::backend` quantize and
//! serve through it. See `docs/API.md` for the old-symbol → generic-call
//! migration table.

use std::marker::PhantomData;
use std::sync::Arc;

use crate::error::{anyhow, Result};
use crate::formats::posit::{PositSpec, BP32, BP64, P32, P64};
use crate::formats::Quire;

/// Lane width of the chunked loops. 8 × u32 = one AVX2 register; the inner
/// loops carry no cross-lane dependency, so narrower ISAs still profit via
/// unrolled ILP (and the u64 lanes split into two registers cleanly).
pub const LANES: usize = 8;

mod sealed {
    /// The width axis is closed: exactly f32 and f64.
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
    impl Sealed for i32 {}
    impl Sealed for i64 {}
}

/// The width axis of the lane stack, implemented for `f32` (32-bit tier:
/// u32 words, u64 intermediates) and `f64` (64-bit tier: u64 words, u128
/// intermediates). Everything the codec, kernel, and quantizer layers
/// need to be written once lives here; see the module docs.
pub trait LaneElem:
    sealed::Sealed
    + Copy
    + Default
    + PartialOrd
    + Send
    + Sync
    + std::fmt::Debug
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    /// Encoded posit-family word (u32 / u64).
    type Word: Copy + Default + PartialEq + Eq + Ord + std::fmt::Debug + Send + Sync + 'static;
    /// Serialization intermediate holding regime ‖ exponent ‖ fraction
    /// before the pattern-space RNE cut (u64 / u128 — twice the word).
    type Wide: Copy + std::fmt::Debug + Send + Sync + 'static;
    /// Signed wire type for quantized bit patterns (i32 / i64); the
    /// inverse mapping is [`LaneSigned`].
    type Signed: Copy + Default + PartialEq + Eq + std::fmt::Debug + Send + Sync + 'static;

    /// Word width in bits (32 / 64) — also the maximum supported spec n.
    const BITS: u32;
    /// Additive identity of the float exchange type.
    const ZERO: Self;
    /// Smallest positive normal value (the FTZ threshold of the codec
    /// contract at this width).
    const MIN_POS: Self;
    /// The serving b-posit spec at this width: ⟨BITS, 6, 5⟩.
    const BP: PositSpec;
    /// The standard-posit comparison spec at this width: ⟨BITS, 2⟩.
    const PSTD: PositSpec;
    /// Short name of the serving format ("bp32" / "bp64") — bench stage
    /// and JSON keys.
    const BP_NAME: &'static str;
    /// Short name of the standard-posit format ("p32" / "p64").
    const PSTD_NAME: &'static str;

    /// True when the branch-free lane codec at this width supports the
    /// spec: n ≤ BITS, a real regime bound, and 1 ≤ es ≤ 8.
    fn spec_supported(spec: &PositSpec) -> bool {
        (3..=Self::BITS).contains(&spec.n)
            && spec.rs >= 2
            && spec.rs <= spec.n - 1
            && (1..=8).contains(&spec.es)
    }

    /// Encode one float into an n-bit posit/b-posit word. Branch-free:
    /// every `if` in the implementation is a pure value select. Contract:
    /// subnormal inputs flush to the zero pattern (FTZ), NaN/Inf → NaR.
    fn encode_lane(n: u32, rs: u32, es: u32, x: Self) -> Self::Word;

    /// Decode one n-bit posit/b-posit word to the float exchange type.
    /// Contract: magnitudes below the normal float range flush to ±0,
    /// above it saturate to ±∞, NaR → canonical quiet NaN.
    fn decode_lane(n: u32, rs: u32, es: u32, w: Self::Word) -> Self;

    /// Encode one float under the serving spec [`Self::BP`] (monomorphized
    /// constants — the named fast path).
    #[inline(always)]
    fn bp_encode_lane(x: Self) -> Self::Word {
        Self::encode_lane(Self::BITS, 6, 5, x)
    }

    /// Decode one word under the serving spec [`Self::BP`].
    #[inline(always)]
    fn bp_decode_lane(w: Self::Word) -> Self {
        Self::decode_lane(Self::BITS, 6, 5, w)
    }

    /// Encode one float under the standard-posit spec [`Self::PSTD`].
    #[inline(always)]
    fn pstd_encode_lane(x: Self) -> Self::Word {
        Self::encode_lane(Self::BITS, Self::BITS - 1, 2, x)
    }

    /// Decode one word under the standard-posit spec [`Self::PSTD`].
    #[inline(always)]
    fn pstd_decode_lane(w: Self::Word) -> Self {
        Self::decode_lane(Self::BITS, Self::BITS - 1, 2, w)
    }

    /// A quire sized for exact accumulation of products at this width:
    /// the paper's 800-bit shared quire for the f32 tier, the
    /// f64-range-exact sizing for the f64 tier.
    fn quire() -> Quire;

    /// Widen to f64 (exact at both widths).
    fn to_f64(self) -> f64;
    /// Narrow/adopt from f64 (rounds for f32 — the staging conversions).
    fn from_f64(v: f64) -> Self;
    /// Adopt from f32 (exact at both widths — the serving input type).
    fn from_f32(v: f32) -> Self;
    /// Narrow to f32 (rounds for f64 — the serving output type).
    fn to_f32(self) -> f32;
    /// Magnitude (needed by the contract tiers; inherent `abs` forwarded).
    fn abs(self) -> Self;
    /// True for finite values (inherent `is_finite` forwarded).
    fn is_finite(self) -> bool;
    /// True for NaN (inherent `is_nan` forwarded).
    fn is_nan(self) -> bool;
    /// Raw bit pattern widened to u64 (tests and hashing).
    fn to_bits_u64(self) -> u64;
    /// Next representable float toward +∞ (NaN and +∞ return self;
    /// ±0 → smallest positive subnormal). The outward-rounding step of
    /// the certify interval twin.
    fn next_float(self) -> Self;
    /// Previous representable float toward −∞ (NaN and −∞ return self;
    /// ±0 → smallest-magnitude negative subnormal).
    fn prev_float(self) -> Self;

    /// Word → u64 (zero-extending; feeds the general `PositSpec` codec).
    fn word_to_u64(w: Self::Word) -> u64;
    /// u64 → word (truncating; adopts general-codec results).
    fn word_from_u64(v: u64) -> Self::Word;
    /// Word → signed wire bit pattern (same bits).
    fn word_to_signed(w: Self::Word) -> Self::Signed;
    /// Signed wire bit pattern → word (same bits).
    fn signed_to_word(s: Self::Signed) -> Self::Word;
}

/// The signed wire-type axis (i32 / i64): quantized tensors travel as
/// signed bit patterns, and decode-direction generics key on this trait
/// so the element width is inferred from the *argument* type —
/// `dequantize(&[i32])` needs no turbofish.
pub trait LaneSigned: sealed::Sealed + Copy + Send + Sync + 'static {
    /// The float exchange type whose words these bit patterns carry.
    type Elem: LaneElem<Signed = Self>;

    /// Bit pattern → word (same bits).
    fn to_word(self) -> <Self::Elem as LaneElem>::Word;
    /// Word → bit pattern (same bits).
    fn from_word(w: <Self::Elem as LaneElem>::Word) -> Self;
}

impl LaneSigned for i32 {
    type Elem = f32;

    #[inline(always)]
    fn to_word(self) -> u32 {
        self as u32
    }

    #[inline(always)]
    fn from_word(w: u32) -> i32 {
        w as i32
    }
}

impl LaneSigned for i64 {
    type Elem = f64;

    #[inline(always)]
    fn to_word(self) -> u64 {
        self as u64
    }

    #[inline(always)]
    fn from_word(w: u64) -> i64 {
        w as i64
    }
}

/// One macro body = one datapath. Expanding it with the 32-bit parameters
/// yields exactly the algorithm previously hand-written in `codec.rs`;
/// the 64-bit expansion is `codec64.rs`. Width parameters:
/// float / word / wide / signed types, word and wide bit counts, the IEEE
/// fraction/exponent field widths, bias, normal-exponent range, and the
/// canonical NaN pattern.
macro_rules! lane_elem_impl {
    ($f:ty, $w:ty, $wide:ty, $s:ty, $word_bits:expr, $wide_bits:expr,
     $fbits:expr, $ebits:expr, $bias:expr, $emin:expr, $emax:expr,
     $nan_bits:expr, $bp:expr, $pstd:expr, $bp_name:expr, $pstd_name:expr,
     $quire:expr) => {
        // Width-parameterized macro body: several casts are identities at
        // one of the two expansions (e.g. `w as u64` when Word = u64).
        #[allow(clippy::unnecessary_cast)]
        impl LaneElem for $f {
            type Word = $w;
            type Wide = $wide;
            type Signed = $s;

            const BITS: u32 = $word_bits;
            const ZERO: Self = 0.0;
            const MIN_POS: Self = <$f>::MIN_POSITIVE;
            const BP: PositSpec = $bp;
            const PSTD: PositSpec = $pstd;
            const BP_NAME: &'static str = $bp_name;
            const PSTD_NAME: &'static str = $pstd_name;

            #[inline(always)]
            fn encode_lane(n: u32, rs: u32, es: u32, x: $f) -> $w {
                debug_assert!(
                    (3..=$word_bits).contains(&n)
                        && rs >= 2
                        && rs <= n - 1
                        && (1..=8).contains(&es)
                );
                let m = n - 1;
                let mask_n: $w = if n == $word_bits { <$w>::MAX } else { ((1 as $w) << n) - 1 };
                let nar: $w = (1 as $w) << m;
                let maxpos: $wide = ((1 as $wide) << m) - 1;
                let bounded = rs < m;
                let r_max: i32 = rs as i32 - 1;
                let r_min: i32 = if bounded { -(rs as i32) } else { -(n as i32 - 2) };

                let bits = x.to_bits();
                let sign = bits >> ($word_bits - 1);
                let biased = ((bits >> $fbits) & (((1 as $w) << $ebits) - 1)) as i32;
                let frac = (bits & (((1 as $w) << $fbits) - 1)) as $wide;
                let is_zero_or_sub = biased == 0; // zero and FTZ'd subnormals
                let is_special = biased == (1i32 << $ebits) - 1; // NaN/Inf → NaR
                let t = biased - $bias;
                let r = t >> es; // floor(t / 2^es)
                let e = (t & ((1i32 << es) - 1)) as $wide; // t mod 2^es, in [0, 2^es)
                let sat_hi = r > r_max;
                let sat_lo = r < r_min;
                let rc = r.clamp(r_min, r_max); // keep shifts in range; sat masks win below
                let run: u32 = if rc >= 0 { (rc + 1) as u32 } else { (-rc) as u32 };
                let capped = run >= rs; // regime hits the bound: no terminator bit
                let w_reg = if capped { rs } else { run + 1 };
                // Regime field value in w_reg bits: a run of ones/zeros plus
                // the terminator when not capped.
                let reg_ones = ((1 as $wide) << w_reg) - 1;
                let reg_val: $wide =
                    if rc >= 0 { reg_ones - ((!capped) as $wide) } else { (!capped) as $wide };
                // Serialize regime ‖ exponent ‖ fraction MSB-first into the
                // wide stream (w_reg + es + fbits ≤ wide_bits − 2 for every
                // supported spec: shifts never underflow).
                let sh_reg = $wide_bits - w_reg;
                let sh_exp = sh_reg - es;
                let sh_frac = sh_exp - $fbits;
                let s = (reg_val << sh_reg) | (e << sh_exp) | (frac << sh_frac);
                // Cut at m bits with round-to-nearest-even: rem+lsb>half ⟺ up.
                let cut = $wide_bits - m;
                let q = s >> cut;
                let rem = s & (((1 as $wide) << cut) - 1);
                let half = (1 as $wide) << (cut - 1);
                let up = (rem + (q & 1) > half) as $wide;
                // Carry-out saturates to maxpos (never NaR); a nonzero real
                // never rounds to the zero pattern (min clamp to minpos).
                let body = (q + up).min(maxpos).max(1);
                let body = if sat_hi { maxpos } else { body };
                let body = if sat_lo { 1 } else { body };
                let bodyw = body as $w;
                let word = (if sign == 1 { bodyw.wrapping_neg() } else { bodyw }) & mask_n;
                let word = if is_zero_or_sub { 0 } else { word };
                if is_special {
                    nar
                } else {
                    word
                }
            }

            #[inline(always)]
            fn decode_lane(n: u32, rs: u32, es: u32, word: $w) -> $f {
                debug_assert!(
                    (3..=$word_bits).contains(&n)
                        && rs >= 2
                        && rs <= n - 1
                        && (1..=8).contains(&es)
                );
                let m = n - 1;
                let mask_n: $w = if n == $word_bits { <$w>::MAX } else { ((1 as $w) << n) - 1 };
                let body_mask: $w = ((1 as $w) << m) - 1;
                let nar: $w = (1 as $w) << m;

                let word = word & mask_n;
                let is_zero = word == 0;
                let is_nar = word == nar;
                let sign = (word >> m) & 1;
                let mag = (if sign == 1 { word.wrapping_neg() } else { word }) & body_mask;
                let b0 = (mag >> (m - 1)) & 1;
                // Leading-run length within the m-bit body, capped at rs.
                let probe = (if b0 == 1 { !mag } else { mag }) & body_mask;
                let lz = (probe << ($word_bits - m)).leading_zeros(); // probe == 0 ⇒ lz ≥ m
                let run = lz.min(m).min(rs);
                let reg_len = run + (run != rs) as u32; // +terminator unless capped
                let r: i32 = if b0 == 1 { run as i32 - 1 } else { -(run as i32) };
                // Align the first post-regime bit to the top of the wide
                // stream (the two-step shift keeps the amount in range even
                // when reg_len = m). Ghost exponent bits and the empty
                // fraction fall out as zeros automatically.
                let pay = ((mag as $wide) << ($wide_bits - 1 - m + reg_len)) << 1;
                let e = (pay >> ($wide_bits - es)) as i32;
                let frac_top = pay << es; // fraction, MSB-aligned at the top bit
                let t = r * (1i32 << es) + e;
                // RNE the fraction down to the float's fbits; guard/sticky
                // live in the low (wide_bits − fbits) bits of frac_top.
                let q = (frac_top >> ($wide_bits - $fbits)) as $w;
                let rem = frac_top & (((1 as $wide) << ($wide_bits - $fbits)) - 1);
                let up = (rem + (q & 1) as $wide > ((1 as $wide) << ($wide_bits - $fbits - 1)))
                    as $w;
                let frac = q + up;
                let tt = t + (frac >> $fbits) as i32; // rounding carry bumps the scale
                let frac = frac & (((1 as $w) << $fbits) - 1);
                let underflow = tt < $emin; // FTZ contract (keeps the sign)
                let overflow = tt > $emax;
                let ttc = tt.clamp($emin, $emax);
                let fb = (sign << ($word_bits - 1)) | (((ttc + $bias) as $w) << $fbits) | frac;
                let fb = if underflow { sign << ($word_bits - 1) } else { fb };
                let fb = if overflow {
                    (sign << ($word_bits - 1)) | ((((1 as $w) << $ebits) - 1) << $fbits)
                } else {
                    fb
                };
                let fb = if is_zero { 0 } else { fb };
                let fb = if is_nar { $nan_bits } else { fb };
                <$f>::from_bits(fb)
            }

            #[inline(always)]
            fn quire() -> Quire {
                $quire
            }

            #[inline(always)]
            fn to_f64(self) -> f64 {
                self as f64
            }

            #[inline(always)]
            fn from_f64(v: f64) -> Self {
                v as $f
            }

            #[inline(always)]
            fn from_f32(v: f32) -> Self {
                v as $f
            }

            #[inline(always)]
            fn to_f32(self) -> f32 {
                self as f32
            }

            #[inline(always)]
            fn abs(self) -> Self {
                <$f>::abs(self)
            }

            #[inline(always)]
            fn is_finite(self) -> bool {
                <$f>::is_finite(self)
            }

            #[inline(always)]
            fn is_nan(self) -> bool {
                <$f>::is_nan(self)
            }

            #[inline(always)]
            fn to_bits_u64(self) -> u64 {
                self.to_bits() as u64
            }

            #[inline(always)]
            fn next_float(self) -> Self {
                if self.is_nan() || self == <$f>::INFINITY {
                    return self;
                }
                if self == 0.0 {
                    return <$f>::from_bits(1);
                }
                let b = self.to_bits();
                if b >> ($word_bits - 1) == 0 {
                    <$f>::from_bits(b + 1)
                } else {
                    <$f>::from_bits(b - 1)
                }
            }

            #[inline(always)]
            fn prev_float(self) -> Self {
                if self.is_nan() || self == <$f>::NEG_INFINITY {
                    return self;
                }
                if self == 0.0 {
                    return <$f>::from_bits(((1 as $w) << ($word_bits - 1)) | 1);
                }
                let b = self.to_bits();
                if b >> ($word_bits - 1) == 0 {
                    <$f>::from_bits(b - 1)
                } else {
                    <$f>::from_bits(b + 1)
                }
            }

            #[inline(always)]
            fn word_to_u64(w: $w) -> u64 {
                w as u64
            }

            #[inline(always)]
            fn word_from_u64(v: u64) -> $w {
                v as $w
            }

            #[inline(always)]
            fn word_to_signed(w: $w) -> $s {
                w as $s
            }

            #[inline(always)]
            fn signed_to_word(s: $s) -> $w {
                s as $w
            }
        }
    };
}

lane_elem_impl!(
    f32,
    u32,
    u64,
    i32,
    32,
    64,
    23,
    8,
    127,
    -126,
    127,
    0x7fc0_0000u32,
    BP32,
    P32,
    "bp32",
    "p32",
    Quire::paper_800(&BP32)
);

lane_elem_impl!(
    f64,
    u64,
    u128,
    i64,
    64,
    128,
    52,
    11,
    1023,
    -1022,
    1023,
    0x7ff8_0000_0000_0000u64,
    BP64,
    P64,
    "bp64",
    "p64",
    Quire::exact_f64()
);

// ----------------------------------------------------------------------
// Chunked slice drivers. The spec parameters are loop-invariant at every
// call site (the named wrappers pass literal constants), so each use
// monomorphizes to a dedicated straight-line inner loop exactly as the
// per-width drivers did.
// ----------------------------------------------------------------------

/// Batched encode under an arbitrary (already-validated) spec.
#[inline(always)]
pub fn encode_slice<E: LaneElem>(n: u32, rs: u32, es: u32, xs: &[E], out: &mut [E::Word]) {
    assert_eq!(xs.len(), out.len(), "encode: input/output length mismatch");
    let split = xs.len() - xs.len() % LANES;
    let (xh, xt) = xs.split_at(split);
    let (oh, ot) = out.split_at_mut(split);
    for (xc, oc) in xh.chunks_exact(LANES).zip(oh.chunks_exact_mut(LANES)) {
        for l in 0..LANES {
            oc[l] = E::encode_lane(n, rs, es, xc[l]);
        }
    }
    for (x, o) in xt.iter().zip(ot.iter_mut()) {
        *o = E::encode_lane(n, rs, es, *x);
    }
}

/// Batched decode under an arbitrary (already-validated) spec.
#[inline(always)]
pub fn decode_slice<E: LaneElem>(n: u32, rs: u32, es: u32, ws: &[E::Word], out: &mut [E]) {
    assert_eq!(ws.len(), out.len(), "decode: input/output length mismatch");
    let split = ws.len() - ws.len() % LANES;
    let (wh, wt) = ws.split_at(split);
    let (oh, ot) = out.split_at_mut(split);
    for (wc, oc) in wh.chunks_exact(LANES).zip(oh.chunks_exact_mut(LANES)) {
        for l in 0..LANES {
            oc[l] = E::decode_lane(n, rs, es, wc[l]);
        }
    }
    for (w, o) in wt.iter().zip(ot.iter_mut()) {
        *o = E::decode_lane(n, rs, es, *w);
    }
}

/// Fused quantize+dequantize in place under an arbitrary spec (no word
/// buffer, no allocation).
#[inline(always)]
pub fn roundtrip_slice_in_place<E: LaneElem>(n: u32, rs: u32, es: u32, xs: &mut [E]) {
    let split = xs.len() - xs.len() % LANES;
    let (head, tail) = xs.split_at_mut(split);
    for c in head.chunks_exact_mut(LANES) {
        for l in 0..LANES {
            c[l] = E::decode_lane(n, rs, es, E::encode_lane(n, rs, es, c[l]));
        }
    }
    for x in tail.iter_mut() {
        *x = E::decode_lane(n, rs, es, E::encode_lane(n, rs, es, *x));
    }
}

/// Batched encode under the serving spec `E::BP` (monomorphized constants).
#[inline(always)]
pub fn bp_encode_into<E: LaneElem>(xs: &[E], out: &mut [E::Word]) {
    encode_slice::<E>(E::BITS, 6, 5, xs, out);
}

/// Batched decode under the serving spec `E::BP`.
#[inline(always)]
pub fn bp_decode_into<E: LaneElem>(ws: &[E::Word], out: &mut [E]) {
    decode_slice::<E>(E::BITS, 6, 5, ws, out);
}

/// Fused serving-spec roundtrip in place.
#[inline(always)]
pub fn bp_roundtrip_in_place<E: LaneElem>(xs: &mut [E]) {
    roundtrip_slice_in_place::<E>(E::BITS, 6, 5, xs);
}

/// Batched encode under the standard-posit spec `E::PSTD`.
#[inline(always)]
pub fn pstd_encode_into<E: LaneElem>(xs: &[E], out: &mut [E::Word]) {
    encode_slice::<E>(E::BITS, E::BITS - 1, 2, xs, out);
}

/// Batched decode under the standard-posit spec `E::PSTD`.
#[inline(always)]
pub fn pstd_decode_into<E: LaneElem>(ws: &[E::Word], out: &mut [E]) {
    decode_slice::<E>(E::BITS, E::BITS - 1, 2, ws, out);
}

// ----------------------------------------------------------------------
// The generic engine
// ----------------------------------------------------------------------

/// Spec-checked batched codec over any lane-supported spec at width `E`.
/// Construction validates the spec once; every batch call after that is
/// assertion-free on the spec axis. The named per-format functions in
/// [`super::codec`]/[`super::codec64`] are this engine at fixed specs.
#[derive(Clone, Copy, Debug)]
pub struct LaneCodec<E: LaneElem> {
    spec: PositSpec,
    _elem: PhantomData<E>,
}

impl<E: LaneElem> LaneCodec<E> {
    /// Build an engine for `spec`; errors when the lane codec at this
    /// width cannot serve it (n > `E::BITS`, es = 0, degenerate rs —
    /// those route to the general pattern-space codec, see
    /// [`super::dispatch_spec`]).
    pub fn new(spec: PositSpec) -> Result<LaneCodec<E>> {
        if !E::spec_supported(&spec) {
            return Err(anyhow!(
                "{}-bit lane codec does not support {spec:?}",
                E::BITS
            ));
        }
        Ok(LaneCodec { spec, _elem: PhantomData })
    }

    /// The engine for the serving b-posit spec ⟨BITS,6,5⟩.
    pub fn bp() -> LaneCodec<E> {
        LaneCodec { spec: E::BP, _elem: PhantomData }
    }

    /// The engine for the standard posit ⟨BITS,2⟩.
    pub fn pstd() -> LaneCodec<E> {
        LaneCodec { spec: E::PSTD, _elem: PhantomData }
    }

    /// The spec this engine serves.
    pub fn spec(&self) -> PositSpec {
        self.spec
    }

    /// Encode one float.
    #[inline]
    pub fn encode_word(&self, x: E) -> E::Word {
        E::encode_lane(self.spec.n, self.spec.rs, self.spec.es, x)
    }

    /// Decode one word.
    #[inline]
    pub fn decode_word(&self, w: E::Word) -> E {
        E::decode_lane(self.spec.n, self.spec.rs, self.spec.es, w)
    }

    /// Batched encode into a caller-owned buffer (`out.len() == xs.len()`).
    pub fn encode_into(&self, xs: &[E], out: &mut [E::Word]) {
        encode_slice::<E>(self.spec.n, self.spec.rs, self.spec.es, xs, out);
    }

    /// Batched decode into a caller-owned buffer.
    pub fn decode_into(&self, ws: &[E::Word], out: &mut [E]) {
        decode_slice::<E>(self.spec.n, self.spec.rs, self.spec.es, ws, out);
    }

    /// Allocating batched encode.
    pub fn encode(&self, xs: &[E]) -> Vec<E::Word> {
        let mut out: Vec<E::Word> = vec![Default::default(); xs.len()];
        self.encode_into(xs, &mut out);
        out
    }

    /// Allocating batched decode.
    pub fn decode(&self, ws: &[E::Word]) -> Vec<E> {
        let mut out = vec![E::ZERO; ws.len()];
        self.decode_into(ws, &mut out);
        out
    }

    /// Fused quantize+dequantize of a buffer in place (no word buffer,
    /// no allocation).
    pub fn roundtrip_in_place(&self, xs: &mut [E]) {
        roundtrip_slice_in_place::<E>(self.spec.n, self.spec.rs, self.spec.es, xs);
    }

    /// Fused roundtrip into a separate output buffer.
    pub fn roundtrip_into(&self, xs: &[E], out: &mut [E]) {
        assert_eq!(xs.len(), out.len(), "roundtrip: input/output length mismatch");
        out.copy_from_slice(xs);
        self.roundtrip_in_place(out);
    }
}

// ----------------------------------------------------------------------
// Spec-carrying typed weight buffers
// ----------------------------------------------------------------------

/// An encoded row-major `rows × cols` tensor of posit-family words,
/// carrying its spec and shape. Replaces raw `&[u32]`/`&[u64]` slices at
/// API boundaries: the element width is part of the *type*
/// (`EncodedTensor<f32>` holds u32 words, `EncodedTensor<f64>` u64), and
/// the spec/shape are validated at construction, so a mismatch is a
/// checked error at the boundary instead of silently reinterpreted bits
/// deep inside a kernel. The word storage is `Arc`-shared so the
/// process-wide weight cache and multiple servers can hold one encoding.
#[derive(Clone)]
pub struct EncodedTensor<E: LaneElem> {
    spec: PositSpec,
    rows: usize,
    cols: usize,
    words: Arc<Vec<E::Word>>,
}

impl<E: LaneElem> EncodedTensor<E> {
    /// Adopt already-encoded words (e.g. from the weight cache). Errors
    /// when the spec is outside this width's lane support or the word
    /// count does not match `rows × cols`.
    pub fn from_words(
        spec: PositSpec,
        rows: usize,
        cols: usize,
        words: Arc<Vec<E::Word>>,
    ) -> Result<EncodedTensor<E>> {
        if !E::spec_supported(&spec) {
            return Err(anyhow!("{}-bit encoded tensor: unsupported {spec:?}", E::BITS));
        }
        if words.len() != rows * cols {
            return Err(anyhow!(
                "encoded tensor: {} words for a {rows}×{cols} shape",
                words.len()
            ));
        }
        Ok(EncodedTensor { spec, rows, cols, words })
    }

    /// Encode a float tensor under `spec`.
    pub fn encode(spec: PositSpec, rows: usize, cols: usize, xs: &[E]) -> Result<EncodedTensor<E>> {
        if xs.len() != rows * cols {
            return Err(anyhow!("encoded tensor: {} values for a {rows}×{cols} shape", xs.len()));
        }
        let codec = LaneCodec::<E>::new(spec)?;
        Ok(EncodedTensor { spec, rows, cols, words: Arc::new(codec.encode(xs)) })
    }

    /// Encode under the serving spec `E::BP`.
    pub fn encode_bp(rows: usize, cols: usize, xs: &[E]) -> Result<EncodedTensor<E>> {
        Self::encode(E::BP, rows, cols, xs)
    }

    /// The spec the words are encoded under.
    pub fn spec(&self) -> PositSpec {
        self.spec
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total word count (`rows × cols`).
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True when the tensor holds no words.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// True when encoded under the serving b-posit spec (`E::BP`) — the
    /// layout the decode-fused GEMM fast paths consume.
    pub fn is_serving_format(&self) -> bool {
        self.spec == E::BP
    }

    /// The raw word storage, row-major.
    pub fn words(&self) -> &[E::Word] {
        &self.words
    }

    /// The shared word storage (cheap clone for cache handoff).
    pub fn shared_words(&self) -> Arc<Vec<E::Word>> {
        self.words.clone()
    }

    /// A contiguous row slab `[r0, r0 + nrows)` of the word storage.
    pub fn row_slab(&self, r0: usize, nrows: usize) -> &[E::Word] {
        &self.words[r0 * self.cols..(r0 + nrows) * self.cols]
    }

    /// Decode the whole tensor into a caller buffer (`out.len() == len()`).
    /// The serving spec takes the monomorphized fast lane; other specs go
    /// through the generic lane driver.
    pub fn decode_into(&self, out: &mut [E]) {
        if self.is_serving_format() {
            bp_decode_into::<E>(&self.words, out);
        } else {
            decode_slice::<E>(self.spec.n, self.spec.rs, self.spec.es, &self.words, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::BP16;
    use crate::testutil::Rng;

    #[test]
    fn trait_constants_name_the_serving_formats() {
        assert_eq!(<f32 as LaneElem>::BP, BP32);
        assert_eq!(<f32 as LaneElem>::PSTD, P32);
        assert_eq!(<f64 as LaneElem>::BP, BP64);
        assert_eq!(<f64 as LaneElem>::PSTD, P64);
        assert_eq!(<f32 as LaneElem>::BITS, 32);
        assert_eq!(<f64 as LaneElem>::BITS, 64);
        assert_eq!(<f32 as LaneElem>::BP_NAME, "bp32");
        assert_eq!(<f64 as LaneElem>::PSTD_NAME, "p64");
        assert!(<f32 as LaneElem>::spec_supported(&BP16));
        assert!(!<f32 as LaneElem>::spec_supported(&BP64));
        assert!(<f64 as LaneElem>::spec_supported(&BP64));
    }

    #[test]
    fn next_prev_float_edges_both_widths() {
        // Mirror of test_next_prev_float_edges in the Python certify
        // mirror: zero crossings, subnormal steps, infinities, NaN.
        assert_eq!(0.0f32.next_float().to_bits(), 1);
        assert_eq!(0.0f32.prev_float().to_bits(), 0x8000_0001);
        assert_eq!((-0.0f32).next_float().to_bits(), 1);
        assert_eq!(f32::from_bits(1).prev_float(), 0.0);
        assert_eq!(f32::MAX.next_float(), f32::INFINITY);
        assert_eq!(f32::INFINITY.next_float(), f32::INFINITY);
        assert_eq!(f32::NEG_INFINITY.next_float(), f32::MIN);
        assert_eq!(f32::NEG_INFINITY.prev_float(), f32::NEG_INFINITY);
        assert!(f32::NAN.next_float().is_nan() && f32::NAN.prev_float().is_nan());
        assert!(1.0f32.next_float() > 1.0 && 1.0f32.prev_float() < 1.0);

        assert_eq!(0.0f64.next_float().to_bits(), 1);
        assert_eq!(0.0f64.prev_float().to_bits(), 0x8000_0000_0000_0001);
        assert_eq!(f64::MAX.next_float(), f64::INFINITY);
        assert_eq!(f64::NEG_INFINITY.prev_float(), f64::NEG_INFINITY);
        assert!(f64::NAN.prev_float().is_nan());
        let x = 1.5f64;
        assert_eq!(x.next_float().prev_float(), x);
        assert_eq!(x.prev_float().next_float(), x);
        assert_eq!((-x).next_float().to_bits(), (-x).to_bits() - 1);
    }

    #[test]
    fn signed_axis_roundtrips_bit_patterns() {
        assert_eq!(<i32 as LaneSigned>::to_word(-1), u32::MAX);
        assert_eq!(<i32 as LaneSigned>::from_word(0x8000_0000), i32::MIN);
        assert_eq!(<i64 as LaneSigned>::to_word(-1), u64::MAX);
        assert_eq!(<i64 as LaneSigned>::from_word(1u64 << 63), i64::MIN);
    }

    #[test]
    fn engine_matches_lane_primitives_both_widths() {
        let mut rng = Rng::new(0x1a9e);
        let c32 = LaneCodec::<f32>::bp();
        let c64 = LaneCodec::<f64>::bp();
        let p32 = LaneCodec::<f32>::pstd();
        let p64 = LaneCodec::<f64>::pstd();
        for _ in 0..20_000 {
            let w = rng.next_u64();
            let x32 = f32::from_bits(w as u32);
            let x64 = f64::from_bits(w);
            assert_eq!(c32.encode_word(x32), <f32 as LaneElem>::bp_encode_lane(x32));
            assert_eq!(c64.encode_word(x64), <f64 as LaneElem>::bp_encode_lane(x64));
            assert_eq!(p32.encode_word(x32), <f32 as LaneElem>::pstd_encode_lane(x32));
            assert_eq!(p64.encode_word(x64), <f64 as LaneElem>::pstd_encode_lane(x64));
            let (a, b) = (c32.decode_word(w as u32), <f32 as LaneElem>::bp_decode_lane(w as u32));
            assert!(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()));
            let (a, b) = (c64.decode_word(w), <f64 as LaneElem>::bp_decode_lane(w));
            assert!(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()));
        }
    }

    #[test]
    fn engine_rejects_unsupported_specs() {
        // es = 0 and over-wide specs stay on the general codec.
        let es0 = PositSpec { n: 16, rs: 15, es: 0 };
        assert!(LaneCodec::<f32>::new(es0).is_err());
        assert!(LaneCodec::<f64>::new(es0).is_err());
        assert!(LaneCodec::<f32>::new(BP64).is_err());
        assert!(LaneCodec::<f64>::new(BP64).is_ok());
    }

    #[test]
    fn engine_slice_paths_roundtrip() {
        let xs: Vec<f64> = (0..37).map(|i| (i as f64 - 18.0) * 1.73).collect();
        let c = LaneCodec::<f64>::new(PositSpec::bounded(48, 6, 5)).unwrap();
        let words = c.encode(&xs);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(words[i], c.encode_word(x), "lane {i}");
        }
        let back = c.decode(&words);
        let mut rt = xs.clone();
        c.roundtrip_in_place(&mut rt);
        let mut rt2 = vec![0f64; xs.len()];
        c.roundtrip_into(&xs, &mut rt2);
        for i in 0..xs.len() {
            assert_eq!(back[i].to_bits(), rt[i].to_bits(), "lane {i}");
            assert_eq!(rt[i].to_bits(), rt2[i].to_bits(), "lane {i}");
        }
    }

    #[test]
    fn encoded_tensor_checks_spec_and_shape() {
        let xs: Vec<f32> = (0..12).map(|i| i as f32 * 0.25 - 1.5).collect();
        let t = EncodedTensor::<f32>::encode_bp(3, 4, &xs).unwrap();
        assert_eq!((t.rows(), t.cols(), t.len()), (3, 4, 12));
        assert!(t.is_serving_format() && !t.is_empty());
        assert_eq!(t.spec(), BP32);
        let mut back = vec![0f32; 12];
        t.decode_into(&mut back);
        assert_eq!(back, xs, "fovea values survive the roundtrip exactly");
        assert_eq!(t.row_slab(1, 2).len(), 8);
        assert_eq!(t.row_slab(0, 3), t.words());
        // Shape mismatch is a checked error.
        assert!(EncodedTensor::<f32>::encode_bp(3, 5, &xs).is_err());
        assert!(EncodedTensor::<f32>::from_words(BP32, 2, 2, t.shared_words()).is_err());
        // Spec outside the width's lane support is a checked error.
        assert!(EncodedTensor::<f32>::encode(BP64, 3, 4, &xs).is_err());
        let es0 = PositSpec { n: 16, rs: 15, es: 0 };
        assert!(EncodedTensor::<f32>::encode(es0, 3, 4, &xs).is_err());
        // Non-serving lane specs decode through the generic driver.
        let t16 = EncodedTensor::<f32>::encode(BP16, 3, 4, &xs).unwrap();
        assert!(!t16.is_serving_format());
        let mut back16 = vec![0f32; 12];
        t16.decode_into(&mut back16);
        for (i, v) in back16.iter().enumerate() {
            assert_eq!(
                *v,
                <f32 as LaneElem>::decode_lane(16, 6, 5, t16.words()[i]),
                "lane {i}"
            );
        }
        // Arc sharing: a clone points at the same storage.
        let t2 = t.clone();
        assert!(Arc::ptr_eq(&t.shared_words(), &t2.shared_words()));
    }
}
