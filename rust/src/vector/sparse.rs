//! Sparse (CSR) matrix type and SpMV kernels in the same three flavors
//! as the dense [`super::kernels`] gemv family — **fast**, **quire-exact**,
//! and **decode-fused quantized-weight** — plus row-sharded `par_spmv_*`
//! forms that are bit-identical to serial for any thread count. The
//! sparse shards are **nnz-balanced** ([`nnz_shard_bounds`]): boundaries
//! land where the CSR prefix-nnz crosses `i·nnz/t`, not at equal row
//! counts, so skewed (power-law) nnz profiles still spread work evenly.
//!
//! The fast row kernel is *chunk-aware*: a stored entry at column `c`
//! lands in accumulator `c & 7` while `c < cols - cols % 8`, and the
//! remaining entries join the serial tail, with the dense kernel's exact
//! combine tree in between. Because the accumulators start at `+0.0` and
//! an IEEE add of `±0.0` to a value that is not `-0.0` cannot change its
//! bits (and `+0.0 + -0.0 = +0.0` under round-to-nearest-even, so the
//! accumulators can never *become* `-0.0`), skipping the products of the
//! absent (zero) dense entries is bitwise inert: **`spmv` on a CSR matrix
//! is bit-identical to the dense [`super::kernels::gemv`] on its
//! densification** for finite data. The same argument covers the
//! decode-fused flavor (the zero word decodes to exactly `+0.0`), and the
//! quire flavor is trivial (the quire skips zero products outright). The
//! claim is proven against the pure-stdlib Python mirror
//! (`python/tests/test_solver_mirror.py`) and re-checked bitwise by
//! `tests/solver.rs` and the `solver-bench` CI gate.
//!
//! Consumed by [`crate::solver`] (tiered conjugate-gradient) — the first
//! workload to drive the vector engine from outside the HTTP path.

use super::lane::LaneElem;
use super::parallel;
use crate::error::{anyhow, Result};
use crate::formats::{Decoded, Quire};

/// Compressed-sparse-row matrix over a lane element type. Column indices
/// are strictly ascending within each row (the constructors enforce it) —
/// the fast kernel's bitwise-equivalence contract depends on stored
/// entries being visited in dense column order.
#[derive(Clone, Debug)]
pub struct Csr<E: LaneElem> {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<usize>,
    vals: Vec<E>,
}

/// A [`Csr`] whose values are serving-spec (`⟨N,6,5⟩` b-posit) words —
/// the sparse analogue of the quantized-weight dense layout. Built by
/// [`Csr::encode_bp`]; consumed by the decode-fused SpMV flavor.
#[derive(Clone, Debug)]
pub struct CsrWords<E: LaneElem> {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<usize>,
    words: Vec<E::Word>,
}

impl<E: LaneElem> Csr<E> {
    /// Build from (row, col, value) triplets in any order. Rejects
    /// out-of-bounds indices and duplicate coordinates (summing
    /// duplicates would add a hidden rounding step).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        entries: &[(usize, usize, E)],
    ) -> Result<Csr<E>> {
        let mut sorted: Vec<(usize, usize, E)> = entries.to_vec();
        sorted.sort_by_key(|&(r, c, _)| (r, c));
        let mut row_ptr = vec![0usize; rows + 1];
        let mut col_idx = Vec::with_capacity(sorted.len());
        let mut vals = Vec::with_capacity(sorted.len());
        for (k, &(r, c, v)) in sorted.iter().enumerate() {
            if r >= rows || c >= cols {
                return Err(anyhow!("csr: entry ({r},{c}) outside {rows}x{cols}"));
            }
            if k > 0 && (r, c) == (sorted[k - 1].0, sorted[k - 1].1) {
                return Err(anyhow!("csr: duplicate entry at ({r},{c})"));
            }
            row_ptr[r + 1] += 1;
            col_idx.push(c);
            vals.push(v);
        }
        for r in 0..rows {
            row_ptr[r + 1] += row_ptr[r];
        }
        Ok(Csr { rows, cols, row_ptr, col_idx, vals })
    }

    /// Build from a row-major dense matrix, keeping entries that compare
    /// unequal to zero. (`-0.0` compares equal and is dropped; its
    /// products are bitwise inert, see the module docs.)
    pub fn from_dense(rows: usize, cols: usize, a: &[E]) -> Csr<E> {
        assert_eq!(a.len(), rows * cols, "csr from_dense: shape mismatch");
        let mut row_ptr = vec![0usize; rows + 1];
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let v = a[r * cols + c];
                if v != E::ZERO {
                    row_ptr[r + 1] += 1;
                    col_idx.push(c);
                    vals.push(v);
                }
            }
        }
        for r in 0..rows {
            row_ptr[r + 1] += row_ptr[r];
        }
        Csr { rows, cols, row_ptr, col_idx, vals }
    }

    /// Densify to a row-major `rows × cols` buffer (absent entries `+0.0`).
    pub fn to_dense(&self) -> Vec<E> {
        let mut out = vec![E::ZERO; self.rows * self.cols];
        for r in 0..self.rows {
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                out[r * self.cols + self.col_idx[k]] = self.vals[k];
            }
        }
        out
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored-entry count.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// One row's (column indices, values), ascending by column.
    pub fn row(&self, r: usize) -> (&[usize], &[E]) {
        let span = self.row_ptr[r]..self.row_ptr[r + 1];
        (&self.col_idx[span.clone()], &self.vals[span])
    }

    /// The main diagonal widened to f64 (absent entries read as 0).
    pub fn diag_f64(&self) -> Vec<f64> {
        let mut d = vec![0.0f64; self.rows.min(self.cols)];
        for (r, dr) in d.iter_mut().enumerate() {
            let (idx, vals) = self.row(r);
            if let Ok(k) = idx.binary_search(&r) {
                *dr = vals[k].to_f64();
            }
        }
        d
    }

    /// Convert the values to another lane width through f64 (exact when
    /// widening; one RNE rounding per value when narrowing).
    pub fn convert<T: LaneElem>(&self) -> Csr<T> {
        Csr {
            rows: self.rows,
            cols: self.cols,
            row_ptr: self.row_ptr.clone(),
            col_idx: self.col_idx.clone(),
            vals: self.vals.iter().map(|v| T::from_f64(v.to_f64())).collect(),
        }
    }

    /// Quantize the values to serving-spec words (one `⟨N,6,5⟩` RNE
    /// rounding per entry), keeping the sparsity pattern.
    pub fn encode_bp(&self) -> CsrWords<E> {
        CsrWords {
            rows: self.rows,
            cols: self.cols,
            row_ptr: self.row_ptr.clone(),
            col_idx: self.col_idx.clone(),
            words: self.vals.iter().map(|&v| E::bp_encode_lane(v)).collect(),
        }
    }
}

impl<E: LaneElem> CsrWords<E> {
    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored-entry count.
    pub fn nnz(&self) -> usize {
        self.words.len()
    }

    /// One row's (column indices, words), ascending by column.
    pub fn row(&self, r: usize) -> (&[usize], &[E::Word]) {
        let span = self.row_ptr[r]..self.row_ptr[r + 1];
        (&self.col_idx[span.clone()], &self.words[span])
    }

    /// Decode back to a float-valued [`Csr`] (the values the decode-fused
    /// kernel actually multiplies by).
    pub fn decode(&self) -> Csr<E> {
        Csr {
            rows: self.rows,
            cols: self.cols,
            row_ptr: self.row_ptr.clone(),
            col_idx: self.col_idx.clone(),
            vals: self.words.iter().map(|&w| E::bp_decode_lane(w)).collect(),
        }
    }

    /// The main diagonal as decoded f64 (absent entries read as 0).
    pub fn diag_f64(&self) -> Vec<f64> {
        self.decode().diag_f64()
    }
}

// ----------------------------------------------------------------------
// Serial kernels. Each `y[r]` is produced by one self-contained row
// kernel, so the row-sharded forms below are bit-identical by
// construction.
// ----------------------------------------------------------------------

/// Chunk-aware fast row dot — the sparse twin of the dense 8-accumulator
/// kernel (same lane assignment `c & 7`, same combine tree, same
/// ascending tail), see the module docs for the bitwise argument.
#[inline]
fn row_dot_fast<E: LaneElem>(idx: &[usize], vals: &[E], x: &[E], chunks: usize) -> E {
    let mut acc = [E::ZERO; 8];
    let mut k = 0;
    while k < idx.len() && idx[k] < chunks {
        acc[idx[k] & 7] += vals[k] * x[idx[k]];
        k += 1;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while k < idx.len() {
        s += vals[k] * x[idx[k]];
        k += 1;
    }
    s
}

/// Fast SpMV worker over a contiguous row block starting at `r0`.
fn spmv_rows<E: LaneElem>(m: &Csr<E>, x: &[E], r0: usize, y: &mut [E]) {
    let chunks = m.cols - m.cols % 8;
    for (dr, yr) in y.iter_mut().enumerate() {
        let (idx, vals) = m.row(r0 + dr);
        *yr = row_dot_fast(idx, vals, x, chunks);
    }
}

/// Decode-fused fast SpMV worker over a contiguous row block.
fn spmv_bp_rows<E: LaneElem>(m: &CsrWords<E>, x: &[E], r0: usize, y: &mut [E]) {
    let chunks = m.cols - m.cols % 8;
    for (dr, yr) in y.iter_mut().enumerate() {
        let (idx, words) = m.row(r0 + dr);
        let mut acc = [E::ZERO; 8];
        let mut k = 0;
        while k < idx.len() && idx[k] < chunks {
            acc[idx[k] & 7] += E::bp_decode_lane(words[k]) * x[idx[k]];
            k += 1;
        }
        let mut s =
            ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
        while k < idx.len() {
            s += E::bp_decode_lane(words[k]) * x[idx[k]];
            k += 1;
        }
        *yr = s;
    }
}

/// Quire-exact SpMV worker over a contiguous row block: one exact row
/// reduction per output, rounded once to `E`.
fn spmv_quire_rows<E: LaneElem>(q: &mut Quire, m: &Csr<E>, x: &[E], r0: usize, y: &mut [E]) {
    for (dr, yr) in y.iter_mut().enumerate() {
        let (idx, vals) = m.row(r0 + dr);
        q.clear();
        for (k, &c) in idx.iter().enumerate() {
            q.add_product(&Decoded::from_f64(vals[k].to_f64()), &Decoded::from_f64(x[c].to_f64()));
        }
        *yr = E::from_f64(q.to_decoded().to_f64());
    }
}

/// Rounded fast SpMV: `y ← A·x`, bit-identical to [`super::kernels::gemv`]
/// on the densified matrix.
pub fn spmv<E: LaneElem>(m: &Csr<E>, x: &[E], y: &mut [E]) {
    assert_eq!(x.len(), m.cols, "spmv: x length mismatch");
    assert_eq!(y.len(), m.rows, "spmv: y length mismatch");
    spmv_rows(m, x, 0, y);
}

/// Quire-exact SpMV: every row reduction accumulates exactly in the
/// caller's quire and rounds once at readout.
pub fn spmv_quire<E: LaneElem>(q: &mut Quire, m: &Csr<E>, x: &[E], y: &mut [E]) {
    assert_eq!(x.len(), m.cols, "spmv: x length mismatch");
    assert_eq!(y.len(), m.rows, "spmv: y length mismatch");
    spmv_quire_rows(q, m, x, 0, y);
}

/// Decode-fused fast SpMV over serving-spec quantized values.
pub fn spmv_bp_weights_fast<E: LaneElem>(m: &CsrWords<E>, x: &[E], y: &mut [E]) {
    assert_eq!(x.len(), m.cols, "spmv: x length mismatch");
    assert_eq!(y.len(), m.rows, "spmv: y length mismatch");
    spmv_bp_rows(m, x, 0, y);
}

// ----------------------------------------------------------------------
// Row-sharded forms (the unified par_* family): contiguous row blocks,
// one serial worker per shard, bit-identical to serial for any thread
// count. Unlike the dense kernels (uniform per-row cost → equal row
// counts), the sparse shards balance **stored entries**: boundaries come
// from a binary search over the monotone CSR `row_ptr`, so a power-law
// nnz profile (most entries in a few rows) no longer serializes behind
// an equal-rows split. The split never changes results — each output
// row is one self-contained serial kernel call either way.
// ----------------------------------------------------------------------

/// Row boundaries splitting `rows = row_ptr.len() - 1` rows into at most
/// `threads` contiguous shards of near-equal stored-entry count:
/// boundary `i` is the first row whose prefix nnz (`row_ptr[r]`) reaches
/// `i·nnz/threads`, found with [`slice::partition_point`] over the
/// monotone prefix array. Rows are never split, so one pathological row
/// bounds the achievable balance, but every shard's nnz is otherwise
/// within one row of the ideal `nnz/threads`. Always starts at 0, ends
/// at `rows`, and is non-decreasing — the
/// [`parallel::for_each_row_block_at`] contract.
pub fn nnz_shard_bounds(row_ptr: &[usize], threads: usize) -> Vec<usize> {
    let rows = row_ptr.len().saturating_sub(1);
    let nnz = row_ptr.last().copied().unwrap_or(0);
    let t = threads.clamp(1, rows.max(1));
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0usize);
    for i in 1..t {
        let target = ((i as u128 * nnz as u128) / t as u128) as usize;
        let b = row_ptr.partition_point(|&p| p < target).min(rows);
        let prev = bounds[bounds.len() - 1];
        bounds.push(b.max(prev));
    }
    bounds.push(rows);
    bounds
}

/// Sharded fast SpMV with an explicit thread count.
pub fn par_spmv_with<E: LaneElem>(threads: usize, m: &Csr<E>, x: &[E], y: &mut [E]) {
    assert_eq!(x.len(), m.cols, "spmv: x length mismatch");
    assert_eq!(y.len(), m.rows, "spmv: y length mismatch");
    parallel::for_each_row_block_at(&nnz_shard_bounds(&m.row_ptr, threads), 1, y, |r0, yb| {
        spmv_rows(m, x, r0, yb);
    });
}

/// Sharded fast SpMV (auto thread count from `PALLAS_THREADS`).
pub fn par_spmv<E: LaneElem>(m: &Csr<E>, x: &[E], y: &mut [E]) {
    par_spmv_with(parallel::auto_shards(m.rows, parallel::ROWS_MIN_SHARD), m, x, y);
}

/// Sharded quire-exact SpMV with an explicit thread count (each shard
/// owns a private quire).
pub fn par_spmv_quire_with<E: LaneElem>(threads: usize, m: &Csr<E>, x: &[E], y: &mut [E]) {
    assert_eq!(x.len(), m.cols, "spmv: x length mismatch");
    assert_eq!(y.len(), m.rows, "spmv: y length mismatch");
    parallel::for_each_row_block_at(&nnz_shard_bounds(&m.row_ptr, threads), 1, y, |r0, yb| {
        let mut q = E::quire();
        spmv_quire_rows(&mut q, m, x, r0, yb);
    });
}

/// Sharded quire-exact SpMV (auto thread count).
pub fn par_spmv_quire<E: LaneElem>(m: &Csr<E>, x: &[E], y: &mut [E]) {
    par_spmv_quire_with(parallel::auto_shards(m.rows, parallel::ROWS_MIN_SHARD), m, x, y);
}

/// Sharded decode-fused fast SpMV with an explicit thread count.
pub fn par_spmv_bp_weights_fast_with<E: LaneElem>(
    threads: usize,
    m: &CsrWords<E>,
    x: &[E],
    y: &mut [E],
) {
    assert_eq!(x.len(), m.cols, "spmv: x length mismatch");
    assert_eq!(y.len(), m.rows, "spmv: y length mismatch");
    parallel::for_each_row_block_at(&nnz_shard_bounds(&m.row_ptr, threads), 1, y, |r0, yb| {
        spmv_bp_rows(m, x, r0, yb);
    });
}

/// Sharded decode-fused fast SpMV (auto thread count).
pub fn par_spmv_bp_weights_fast<E: LaneElem>(m: &CsrWords<E>, x: &[E], y: &mut [E]) {
    let shards = parallel::auto_shards(m.rows, parallel::ROWS_MIN_SHARD);
    par_spmv_bp_weights_fast_with(shards, m, x, y);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{mixed_scale_f32, mixed_scale_f64, Rng};
    use crate::vector::kernels;

    /// Random sparse matrix (≈60% fill, mixed scales) as triplets + the
    /// dense twin.
    fn random_case<E: LaneElem>(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        gen: impl Fn(&mut Rng, usize) -> Vec<E>,
    ) -> (Csr<E>, Vec<E>) {
        let raw = gen(rng, rows * cols);
        let mut dense = vec![E::ZERO; rows * cols];
        let mut trips = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.below(5) < 3 {
                    let v = raw[r * cols + c];
                    dense[r * cols + c] = v;
                    trips.push((r, c, v));
                }
            }
        }
        (Csr::from_triplets(rows, cols, &trips).unwrap(), dense)
    }

    fn mk_f32(rng: &mut Rng, n: usize) -> Vec<f32> {
        mixed_scale_f32(rng, n, 12)
    }

    fn mk_f64(rng: &mut Rng, n: usize) -> Vec<f64> {
        mixed_scale_f64(rng, n, 12)
    }

    #[test]
    fn from_triplets_validates() {
        assert!(Csr::from_triplets(2, 2, &[(0, 0, 1.0f32), (2, 0, 1.0)]).is_err());
        assert!(Csr::from_triplets(2, 2, &[(0, 3, 1.0f32)]).is_err());
        assert!(Csr::from_triplets(2, 2, &[(1, 1, 1.0f32), (1, 1, 2.0)]).is_err());
        let m = Csr::from_triplets(2, 3, &[(1, 2, 5.0f32), (0, 1, 3.0)]).unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.to_dense(), vec![0.0, 3.0, 0.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(0x5a01);
        let (m, dense) = random_case(&mut rng, 7, 13, mk_f64);
        assert_eq!(m.to_dense(), dense);
        let back = Csr::<f64>::from_dense(7, 13, &dense);
        assert_eq!(back.to_dense(), dense);
        assert_eq!(m.diag_f64(), (0..7).map(|i| dense[i * 13 + i]).collect::<Vec<_>>());
    }

    #[test]
    fn spmv_matches_dense_gemv_bitwise_both_widths() {
        let mut rng = Rng::new(0x5a02);
        for _ in 0..20 {
            let rows = 1 + rng.below(24) as usize;
            let cols = 1 + rng.below(67) as usize;
            {
                let (m, dense) = random_case(&mut rng, rows, cols, mk_f32);
                let x = mk_f32(&mut rng, cols);
                let mut y = vec![0f32; rows];
                let mut want = vec![0f32; rows];
                spmv(&m, &x, &mut y);
                kernels::gemv(&dense, &x, &mut want);
                for r in 0..rows {
                    assert_eq!(y[r].to_bits(), want[r].to_bits(), "f32 row {r}");
                }
            }
            {
                let (m, dense) = random_case(&mut rng, rows, cols, mk_f64);
                let x = mk_f64(&mut rng, cols);
                let mut y = vec![0f64; rows];
                let mut want = vec![0f64; rows];
                spmv(&m, &x, &mut y);
                kernels::gemv(&dense, &x, &mut want);
                for r in 0..rows {
                    assert_eq!(y[r].to_bits(), want[r].to_bits(), "f64 row {r}");
                }
            }
        }
    }

    #[test]
    fn quire_and_bp_flavors_match_their_dense_twins() {
        let mut rng = Rng::new(0x5a03);
        for _ in 0..8 {
            let rows = 1 + rng.below(12) as usize;
            let cols = 1 + rng.below(40) as usize;
            let (m, dense) = random_case(&mut rng, rows, cols, mk_f32);
            let x = mk_f32(&mut rng, cols);

            let mut y = vec![0f32; rows];
            let mut q = <f32 as LaneElem>::quire();
            spmv_quire(&mut q, &m, &x, &mut y);
            let mut want = vec![0f32; rows];
            let mut qd = kernels::QuireDot::new();
            qd.gemv_f32(&dense, &x, &mut want);
            for r in 0..rows {
                assert_eq!(y[r].to_bits(), want[r].to_bits(), "quire row {r}");
            }

            // Decode-fused: quantize the dense twin with the same codec
            // so the products agree bit-for-bit.
            let mw = m.encode_bp();
            let dense_w: Vec<u32> =
                dense.iter().map(|&v| <f32 as LaneElem>::bp_encode_lane(v)).collect();
            let mut yw = vec![0f32; rows];
            spmv_bp_weights_fast(&mw, &x, &mut yw);
            for r in 0..rows {
                let want =
                    kernels::dot_bp_weights_fast::<f32>(&dense_w[r * cols..(r + 1) * cols], &x);
                assert_eq!(yw[r].to_bits(), want.to_bits(), "bp row {r}");
            }
            assert_eq!(mw.decode().to_dense().len(), rows * cols);
        }
    }

    #[test]
    fn nnz_shard_bounds_are_valid_and_balanced() {
        // Degenerate shapes.
        assert_eq!(nnz_shard_bounds(&[0], 4), vec![0, 0]);
        assert_eq!(nnz_shard_bounds(&[0, 0, 0], 2), vec![0, 0, 2]);
        assert_eq!(nnz_shard_bounds(&[0, 3, 5], 1), vec![0, 2]);
        // More threads than rows clamps to one row per shard at most.
        assert_eq!(nnz_shard_bounds(&[0, 1, 2], 16), vec![0, 1, 2]);

        // Power-law profile: row r holds ~n/(r+1) entries, so an
        // equal-rows split would put over half the work in shard 0.
        let rows = 64usize;
        let mut row_ptr = vec![0usize; rows + 1];
        for r in 0..rows {
            row_ptr[r + 1] = row_ptr[r] + (1024 / (r + 1)).max(1);
        }
        let nnz = row_ptr[rows];
        for t in [2usize, 3, 7, 16] {
            let b = nnz_shard_bounds(&row_ptr, t);
            assert_eq!(b.len(), t + 1, "t={t}");
            assert_eq!(b[0], 0, "t={t}");
            assert_eq!(b[t], rows, "t={t}");
            assert!(b.windows(2).all(|w| w[0] <= w[1]), "t={t}: not ascending");
            // Each shard's nnz is within one row of the ideal: the
            // boundary lands at the first row whose prefix crosses the
            // target, so a shard can overshoot by at most its boundary
            // row's nnz (max single-row nnz = 1024 here).
            let max_row = (0..rows).map(|r| row_ptr[r + 1] - row_ptr[r]).max().unwrap();
            for i in 0..t {
                let shard = row_ptr[b[i + 1]] - row_ptr[b[i]];
                assert!(
                    shard <= nnz / t + max_row + 1,
                    "t={t} shard {i}: {shard} nnz vs ideal {}",
                    nnz / t
                );
            }
        }
    }

    #[test]
    fn par_spmv_power_law_nnz_bit_identical_for_any_thread_count() {
        // Zipf-style operator: row r dense in its first ~cols/(r+1)
        // columns — the shape the nnz-balanced boundaries exist for.
        // Every flavor must stay bit-identical to serial at every t.
        let mut rng = Rng::new(0x5a05);
        let (rows, cols) = (48usize, 96usize);
        let raw = mk_f32(&mut rng, rows * cols);
        let mut trips = Vec::new();
        for r in 0..rows {
            let k = (cols / (r + 1)).max(1);
            for c in 0..k {
                trips.push((r, c, raw[r * cols + c]));
            }
        }
        let m = Csr::from_triplets(rows, cols, &trips).unwrap();
        let mw = m.encode_bp();
        let x = mk_f32(&mut rng, cols);
        let mut serial = vec![0f32; rows];
        spmv(&m, &x, &mut serial);
        let mut serial_q = vec![0f32; rows];
        let mut q = <f32 as LaneElem>::quire();
        spmv_quire(&mut q, &m, &x, &mut serial_q);
        let mut serial_w = vec![0f32; rows];
        spmv_bp_weights_fast(&mw, &x, &mut serial_w);
        for t in [1, 2, 7] {
            let mut y = vec![0f32; rows];
            par_spmv_with(t, &m, &x, &mut y);
            assert_eq!(
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "fast t={t}"
            );
            par_spmv_quire_with(t, &m, &x, &mut y);
            assert_eq!(
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial_q.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "quire t={t}"
            );
            par_spmv_bp_weights_fast_with(t, &mw, &x, &mut y);
            assert_eq!(
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial_w.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "bp t={t}"
            );
        }
    }

    #[test]
    fn par_spmv_bit_identical_for_any_thread_count() {
        let mut rng = Rng::new(0x5a04);
        let (m, _) = random_case(&mut rng, 33, 65, mk_f64);
        let mw = m.encode_bp();
        let x = mk_f64(&mut rng, 65);
        let mut serial = vec![0f64; 33];
        spmv(&m, &x, &mut serial);
        let mut serial_q = vec![0f64; 33];
        let mut q = <f64 as LaneElem>::quire();
        spmv_quire(&mut q, &m, &x, &mut serial_q);
        let mut serial_w = vec![0f64; 33];
        spmv_bp_weights_fast(&mw, &x, &mut serial_w);
        for t in [1, 2, 7] {
            let mut y = vec![0f64; 33];
            par_spmv_with(t, &m, &x, &mut y);
            assert_eq!(
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "fast t={t}"
            );
            par_spmv_quire_with(t, &m, &x, &mut y);
            assert_eq!(
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial_q.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "quire t={t}"
            );
            par_spmv_bp_weights_fast_with(t, &mw, &x, &mut y);
            assert_eq!(
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial_w.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "bp t={t}"
            );
        }
    }
}
