//! Zero-dependency scoped fork-join pool for the vector layer.
//!
//! The vendored dependency set has no rayon, so multi-core sharding is
//! built directly on [`std::thread::scope`]: each call forks `t − 1`
//! scoped workers, runs the last shard on the caller thread, and joins
//! before returning — no persistent pool state, no channels, no unsafe.
//! Work is always split into **contiguous** blocks (whole rows for
//! matrix kernels), so every output element is produced by exactly the
//! same instruction sequence as in the serial path and results are
//! **bit-identical for any thread count**.
//!
//! The batched-codec surface is **one generic family** over
//! [`LaneElem`]: `par_encode_into*` / `par_decode_into*` /
//! `par_roundtrip_in_place*` for any lane-supported spec, and the
//! `par_bp_*` serving-spec forms whose inner loops monomorphize with
//! literal ⟨N,6,5⟩ constants. The historical per-width names
//! (`bp32_encode_into_with`, `encode64_slice_into_with`, …) are thin
//! aliases over it — see `docs/API.md`.
//!
//! Thread count resolution (see [`num_threads`]): the `PALLAS_THREADS`
//! environment variable when set to a positive integer, otherwise
//! [`std::thread::available_parallelism`]. Small batches stay serial via
//! [`auto_shards`], which caps the shard count so each worker gets at
//! least a threshold's worth of elements — forking threads for a batch
//! that encodes in microseconds would be pure overhead.

use super::lane::{self, LaneElem};
use crate::formats::posit::PositSpec;

/// Hard cap on worker threads (sanity bound for absurd `PALLAS_THREADS`).
pub const MAX_THREADS: usize = 256;

/// Minimum elements per shard for the batched codec entry points: below
/// `threads × this`, the sharded wrappers degrade to the serial codec.
/// ~16k lane-codec elements is a few microseconds of work — comparable to
/// a thread spawn, so smaller shards cannot win.
pub const CODEC_MIN_SHARD: usize = 16 * 1024;

/// Minimum output rows per shard for GEMM/gemv row-block sharding. Rows
/// are whole dot products, so even one row is substantial work; 8 keeps
/// shard bookkeeping negligible.
pub const ROWS_MIN_SHARD: usize = 8;

/// Worker count: `PALLAS_THREADS` if set to a positive integer (clamped
/// to [`MAX_THREADS`]), else the machine's available parallelism, else 1.
/// Invalid or zero values fall back to the auto default. The env var is
/// re-read on every call (so tests and operators can change it live);
/// the auto default is probed once per process — `available_parallelism`
/// is a syscall and this sits on the per-batch serving path.
pub fn num_threads() -> usize {
    match std::env::var("PALLAS_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(t) if t >= 1 => t.min(MAX_THREADS),
            _ => auto_threads(),
        },
        Err(_) => auto_threads(),
    }
}

fn auto_threads() -> usize {
    static AUTO: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *AUTO.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_THREADS)
    })
}

/// Shard count for a `len`-element batch: [`num_threads`], but never so
/// many that a shard falls below `min_per_shard` elements (and never 0).
pub fn auto_shards(len: usize, min_per_shard: usize) -> usize {
    num_threads().min(len / min_per_shard.max(1)).max(1)
}

/// Fork-join over contiguous row blocks of `data` (`rows × width`,
/// row-major): splits the rows into at most `threads` near-equal
/// contiguous blocks and runs `f(first_row, block)` for each, the last on
/// the caller thread. `f` must produce each row independently of the
/// split, which every caller in this crate satisfies by construction
/// (one output row = one serial kernel invocation).
pub fn for_each_row_block<T, F>(threads: usize, rows: usize, width: usize, data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(data.len(), rows * width, "row sharding: shape mismatch");
    let t = threads.clamp(1, rows.max(1));
    if t <= 1 {
        f(0, data);
        return;
    }
    let base = rows / t;
    let rem = rows % t;
    std::thread::scope(|s| {
        let fr = &f;
        let mut rest = data;
        let mut row0 = 0usize;
        for i in 0..t {
            let nrows = base + usize::from(i < rem);
            let (block, tail) = std::mem::take(&mut rest).split_at_mut(nrows * width);
            rest = tail;
            let r0 = row0;
            row0 += nrows;
            if i == t - 1 {
                fr(r0, block);
            } else {
                s.spawn(move || fr(r0, block));
            }
        }
    });
}

/// Fork-join over **explicit** contiguous row blocks: `bounds` is an
/// ascending row-boundary list starting at 0 and ending at the row count
/// (shard `i` covers rows `bounds[i]..bounds[i + 1]`; empty shards spawn
/// nothing). The variable-boundary form of [`for_each_row_block`] for
/// callers whose per-row cost is non-uniform — the sparse kernels pass
/// boundaries balanced by stored-entry count instead of row count. The
/// contract is unchanged: `f` must produce each row independently of the
/// split, so results are bit-identical to serial for any boundary
/// choice.
pub fn for_each_row_block_at<T, F>(bounds: &[usize], width: usize, data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(bounds.len() >= 2 && bounds[0] == 0, "row bounds: must start at 0");
    assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "row bounds: must be ascending");
    let rows = bounds[bounds.len() - 1];
    assert_eq!(data.len(), rows * width, "row sharding: shape mismatch");
    let shards = bounds.len() - 1;
    if shards <= 1 {
        f(0, data);
        return;
    }
    std::thread::scope(|s| {
        let fr = &f;
        let mut rest = data;
        for i in 0..shards {
            let nrows = bounds[i + 1] - bounds[i];
            let (block, tail) = std::mem::take(&mut rest).split_at_mut(nrows * width);
            rest = tail;
            let r0 = bounds[i];
            if i == shards - 1 {
                fr(r0, block);
            } else if nrows > 0 {
                s.spawn(move || fr(r0, block));
            }
        }
    });
}

/// Fork-join over contiguous element blocks of `out`: `f(offset, block)`.
pub fn for_each_block<T, F>(threads: usize, out: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let len = out.len();
    for_each_row_block(threads, len, 1, out, f);
}

/// [`for_each_block`] plus summed per-thread worker nanoseconds: each
/// worker times its own shard into a plain `&mut u64` slot handed out
/// before the spawn (per-thread accumulation, merged after the join — no
/// atomics anywhere near the lane loops), and the caller gets the total
/// CPU time across shards. The block split is **identical** to
/// [`for_each_block`] for the same `threads`, so outputs stay
/// bit-identical to the untimed path.
pub fn for_each_block_timed<T, F>(threads: usize, out: &mut [T], f: F) -> u64
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let len = out.len();
    let t = threads.clamp(1, len.max(1));
    if t <= 1 {
        // lint:allow(no-wallclock): per-shard timing instrumentation only;
        // the measured nanoseconds never influence shard boundaries or
        // results (same for the two shard timers below)
        let t0 = std::time::Instant::now();
        f(0, out);
        return t0.elapsed().as_nanos() as u64;
    }
    let base = len / t;
    let rem = len % t;
    let mut shard_ns = vec![0u64; t];
    std::thread::scope(|s| {
        let fr = &f;
        let mut rest = out;
        let mut off = 0usize;
        let mut slots = shard_ns.iter_mut();
        for i in 0..t {
            let n = base + usize::from(i < rem);
            let (block, tail) = std::mem::take(&mut rest).split_at_mut(n);
            rest = tail;
            let o = off;
            off += n;
            let slot = slots.next().expect("one slot per shard");
            if i == t - 1 {
                let t0 = std::time::Instant::now(); // lint:allow(no-wallclock): instrumentation only
                fr(o, block);
                *slot = t0.elapsed().as_nanos() as u64;
            } else {
                s.spawn(move || {
                    let t0 = std::time::Instant::now(); // lint:allow(no-wallclock): instrumentation only
                    fr(o, block);
                    *slot = t0.elapsed().as_nanos() as u64;
                });
            }
        }
    });
    shard_ns.iter().sum()
}

// ----------------------------------------------------------------------
// Sharded batch codec — the generic family. Each entry point splits the
// batch into contiguous blocks and runs the serial lane codec on every
// block, so results are bit-identical to the serial path for any thread
// count (the codec is elementwise).
// ----------------------------------------------------------------------

/// Sharded batched encode under any lane-supported spec at width `E`,
/// with an explicit shard count.
pub fn par_encode_into_with<E: LaneElem>(
    threads: usize,
    spec: &PositSpec,
    xs: &[E],
    out: &mut [E::Word],
) {
    assert!(E::spec_supported(spec), "{}-bit lane codec does not support {spec:?}", E::BITS);
    assert_eq!(xs.len(), out.len(), "encode: input/output length mismatch");
    let (n, rs, es) = (spec.n, spec.rs, spec.es);
    for_each_block(threads, out, |off, block| {
        lane::encode_slice::<E>(n, rs, es, &xs[off..off + block.len()], block);
    });
}

/// Sharded batched encode under any lane-supported spec (auto shards).
pub fn par_encode_into<E: LaneElem>(spec: &PositSpec, xs: &[E], out: &mut [E::Word]) {
    par_encode_into_with::<E>(auto_shards(xs.len(), CODEC_MIN_SHARD), spec, xs, out);
}

/// Sharded batched decode under any lane-supported spec at width `E`,
/// with an explicit shard count.
pub fn par_decode_into_with<E: LaneElem>(
    threads: usize,
    spec: &PositSpec,
    ws: &[E::Word],
    out: &mut [E],
) {
    assert!(E::spec_supported(spec), "{}-bit lane codec does not support {spec:?}", E::BITS);
    assert_eq!(ws.len(), out.len(), "decode: input/output length mismatch");
    let (n, rs, es) = (spec.n, spec.rs, spec.es);
    for_each_block(threads, out, |off, block| {
        lane::decode_slice::<E>(n, rs, es, &ws[off..off + block.len()], block);
    });
}

/// Sharded batched decode under any lane-supported spec (auto shards).
pub fn par_decode_into<E: LaneElem>(spec: &PositSpec, ws: &[E::Word], out: &mut [E]) {
    par_decode_into_with::<E>(auto_shards(ws.len(), CODEC_MIN_SHARD), spec, ws, out);
}

/// Sharded fused quantize+dequantize in place under any lane-supported
/// spec, with an explicit shard count.
pub fn par_roundtrip_in_place_with<E: LaneElem>(threads: usize, spec: &PositSpec, xs: &mut [E]) {
    assert!(E::spec_supported(spec), "{}-bit lane codec does not support {spec:?}", E::BITS);
    let (n, rs, es) = (spec.n, spec.rs, spec.es);
    for_each_block(threads, xs, |_, block| {
        lane::roundtrip_slice_in_place::<E>(n, rs, es, block);
    });
}

/// Sharded fused roundtrip in place under any lane-supported spec (auto
/// shards).
pub fn par_roundtrip_in_place<E: LaneElem>(spec: &PositSpec, xs: &mut [E]) {
    par_roundtrip_in_place_with::<E>(auto_shards(xs.len(), CODEC_MIN_SHARD), spec, xs);
}

// ---- serving-spec (`E::BP`) forms: inner loops monomorphize with
// ---- literal ⟨N,6,5⟩ constants, exactly like the old named wrappers.

/// Sharded batched serving-spec encode with an explicit shard count.
pub fn par_bp_encode_into_with<E: LaneElem>(threads: usize, xs: &[E], out: &mut [E::Word]) {
    assert_eq!(xs.len(), out.len(), "encode: input/output length mismatch");
    for_each_block(threads, out, |off, block| {
        lane::bp_encode_into::<E>(&xs[off..off + block.len()], block);
    });
}

/// Sharded batched serving-spec encode (auto shards).
pub fn par_bp_encode_into<E: LaneElem>(xs: &[E], out: &mut [E::Word]) {
    par_bp_encode_into_with::<E>(auto_shards(xs.len(), CODEC_MIN_SHARD), xs, out);
}

/// Sharded batched serving-spec decode with an explicit shard count.
pub fn par_bp_decode_into_with<E: LaneElem>(threads: usize, ws: &[E::Word], out: &mut [E]) {
    assert_eq!(ws.len(), out.len(), "decode: input/output length mismatch");
    for_each_block(threads, out, |off, block| {
        lane::bp_decode_into::<E>(&ws[off..off + block.len()], block);
    });
}

/// Sharded batched serving-spec decode (auto shards).
pub fn par_bp_decode_into<E: LaneElem>(ws: &[E::Word], out: &mut [E]) {
    par_bp_decode_into_with::<E>(auto_shards(ws.len(), CODEC_MIN_SHARD), ws, out);
}

/// Sharded fused serving-spec roundtrip in place with an explicit shard
/// count — the server's staged-buffer batch path.
pub fn par_bp_roundtrip_in_place_with<E: LaneElem>(threads: usize, xs: &mut [E]) {
    for_each_block(threads, xs, |_, block| lane::bp_roundtrip_in_place::<E>(block));
}

/// Sharded fused serving-spec roundtrip in place (auto shards).
pub fn par_bp_roundtrip_in_place<E: LaneElem>(xs: &mut [E]) {
    par_bp_roundtrip_in_place_with::<E>(auto_shards(xs.len(), CODEC_MIN_SHARD), xs);
}

/// [`par_bp_roundtrip_in_place_with`] plus summed per-thread worker
/// nanoseconds (the serving profiler's codec CPU-cost hook). Same shard
/// split, bit-identical output for any thread count.
pub fn par_bp_roundtrip_in_place_timed_with<E: LaneElem>(threads: usize, xs: &mut [E]) -> u64 {
    for_each_block_timed(threads, xs, |_, block| lane::bp_roundtrip_in_place::<E>(block))
}

/// Auto-shard form of [`par_bp_roundtrip_in_place_timed_with`] — uses the
/// same [`auto_shards`] split as [`par_bp_roundtrip_in_place`].
pub fn par_bp_roundtrip_in_place_timed<E: LaneElem>(xs: &mut [E]) -> u64 {
    par_bp_roundtrip_in_place_timed_with::<E>(auto_shards(xs.len(), CODEC_MIN_SHARD), xs)
}

// ----------------------------------------------------------------------
// Historical per-width names — thin aliases over the generic family
// (kept so the 32/64 call sites and bench trajectories read unchanged;
// see docs/API.md).
// ----------------------------------------------------------------------

/// Sharded batched b-posit32 encode with an explicit shard count.
pub fn bp32_encode_into_with(threads: usize, xs: &[f32], out: &mut [u32]) {
    par_bp_encode_into_with(threads, xs, out);
}

/// Sharded batched b-posit32 encode (auto thread count).
pub fn bp32_encode_into(xs: &[f32], out: &mut [u32]) {
    par_bp_encode_into(xs, out);
}

/// Sharded batched b-posit32 decode with an explicit shard count.
pub fn bp32_decode_into_with(threads: usize, ws: &[u32], out: &mut [f32]) {
    par_bp_decode_into_with(threads, ws, out);
}

/// Sharded batched b-posit32 decode (auto thread count).
pub fn bp32_decode_into(ws: &[u32], out: &mut [f32]) {
    par_bp_decode_into(ws, out);
}

/// Sharded fused b-posit32 quantize+dequantize in place with an explicit
/// shard count.
pub fn bp32_roundtrip_in_place_with(threads: usize, xs: &mut [f32]) {
    par_bp_roundtrip_in_place_with(threads, xs);
}

/// Sharded fused b-posit32 roundtrip in place (auto thread count).
pub fn bp32_roundtrip_in_place(xs: &mut [f32]) {
    par_bp_roundtrip_in_place(xs);
}

/// Sharded batched encode under any 32-bit-lane-supported spec.
pub fn encode_slice_into_with(threads: usize, spec: &PositSpec, xs: &[f32], out: &mut [u32]) {
    par_encode_into_with(threads, spec, xs, out);
}

/// Sharded batched decode under any 32-bit-lane-supported spec.
pub fn decode_slice_into_with(threads: usize, spec: &PositSpec, ws: &[u32], out: &mut [f32]) {
    par_decode_into_with(threads, spec, ws, out);
}

/// Sharded batched b-posit64 encode with an explicit shard count.
pub fn bp64_encode_into_with(threads: usize, xs: &[f64], out: &mut [u64]) {
    par_bp_encode_into_with(threads, xs, out);
}

/// Sharded batched b-posit64 encode (auto thread count).
pub fn bp64_encode_into(xs: &[f64], out: &mut [u64]) {
    par_bp_encode_into(xs, out);
}

/// Sharded batched b-posit64 decode with an explicit shard count.
pub fn bp64_decode_into_with(threads: usize, ws: &[u64], out: &mut [f64]) {
    par_bp_decode_into_with(threads, ws, out);
}

/// Sharded batched b-posit64 decode (auto thread count).
pub fn bp64_decode_into(ws: &[u64], out: &mut [f64]) {
    par_bp_decode_into(ws, out);
}

/// Sharded fused b-posit64 quantize+dequantize in place with an explicit
/// shard count.
pub fn bp64_roundtrip_in_place_with(threads: usize, xs: &mut [f64]) {
    par_bp_roundtrip_in_place_with(threads, xs);
}

/// Sharded fused b-posit64 roundtrip in place (auto thread count).
pub fn bp64_roundtrip_in_place(xs: &mut [f64]) {
    par_bp_roundtrip_in_place(xs);
}

/// Sharded batched encode under any 64-bit-lane-supported spec.
pub fn encode64_slice_into_with(threads: usize, spec: &PositSpec, xs: &[f64], out: &mut [u64]) {
    par_encode_into_with(threads, spec, xs, out);
}

/// Sharded batched decode under any 64-bit-lane-supported spec.
pub fn decode64_slice_into_with(threads: usize, spec: &PositSpec, ws: &[u64], out: &mut [f64]) {
    par_decode_into_with(threads, spec, ws, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vector::{codec, codec64};

    #[test]
    fn row_blocks_cover_exactly_once() {
        // Every element written exactly once with the right row index, for
        // thread counts below, at, and above the row count.
        for t in [1usize, 2, 3, 7, 16] {
            let (rows, width) = (13usize, 5usize);
            let mut data = vec![0u32; rows * width];
            for_each_row_block(t, rows, width, &mut data, |r0, block| {
                let nrows = block.len() / width;
                for r in 0..nrows {
                    for c in 0..width {
                        block[r * width + c] += ((r0 + r) * width + c) as u32 + 1;
                    }
                }
            });
            let expect: Vec<u32> = (1..=(rows * width) as u32).collect();
            assert_eq!(data, expect, "t={t}");
        }
    }

    #[test]
    fn explicit_bounds_cover_exactly_once() {
        // Variable boundaries (including empty shards) write every
        // element exactly once with the right row index.
        let (rows, width) = (13usize, 5usize);
        for bounds in [
            vec![0, 13],
            vec![0, 1, 13],
            vec![0, 0, 4, 4, 9, 13],
            vec![0, 2, 2, 2, 13, 13],
        ] {
            let mut data = vec![0u32; rows * width];
            for_each_row_block_at(&bounds, width, &mut data, |r0, block| {
                let nrows = block.len() / width;
                for r in 0..nrows {
                    for c in 0..width {
                        block[r * width + c] += ((r0 + r) * width + c) as u32 + 1;
                    }
                }
            });
            let expect: Vec<u32> = (1..=(rows * width) as u32).collect();
            assert_eq!(data, expect, "bounds={bounds:?}");
        }
        // Zero-row degenerate forms: the single (or last) shard still
        // gets one call, with an empty block.
        let mut empty: Vec<u32> = Vec::new();
        for_each_row_block_at(&[0, 0], 3, &mut empty, |_, b| assert!(b.is_empty()));
        for_each_row_block_at(&[0, 0, 0], 3, &mut empty, |_, b| assert!(b.is_empty()));
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let mut empty: Vec<u32> = Vec::new();
        for_each_block(4, &mut empty, |_, _| {});
        let mut one = vec![7u32];
        for_each_block(4, &mut one, |off, b| {
            assert_eq!(off, 0);
            b[0] += 1;
        });
        assert_eq!(one, vec![8]);
    }

    #[test]
    fn sharded_codec_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x7a11a5);
        let xs: Vec<f32> = (0..4097)
            .map(|_| {
                let v = f32::from_bits(rng.next_u32());
                if v.is_finite() {
                    v
                } else {
                    2.5
                }
            })
            .collect();
        let mut serial_w = vec![0u32; xs.len()];
        codec::bp32_encode_into(&xs, &mut serial_w);
        let mut serial_f = vec![0f32; xs.len()];
        codec::bp32_decode_into(&serial_w, &mut serial_f);
        for t in [1usize, 2, 7] {
            let mut w = vec![0u32; xs.len()];
            bp32_encode_into_with(t, &xs, &mut w);
            assert_eq!(w, serial_w, "encode t={t}");
            let mut f = vec![0f32; xs.len()];
            bp32_decode_into_with(t, &w, &mut f);
            assert_eq!(
                f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial_f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "decode t={t}"
            );
            let mut rt = xs.clone();
            bp32_roundtrip_in_place_with(t, &mut rt);
            assert_eq!(
                rt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial_f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "roundtrip t={t}"
            );
        }
    }

    #[test]
    fn sharded_codec64_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x7a64);
        let xs: Vec<f64> = (0..4097)
            .map(|_| {
                let v = f64::from_bits(rng.next_u64());
                if v.is_finite() {
                    v
                } else {
                    2.5
                }
            })
            .collect();
        let mut serial_w = vec![0u64; xs.len()];
        codec64::bp64_encode_into(&xs, &mut serial_w);
        let mut serial_f = vec![0f64; xs.len()];
        codec64::bp64_decode_into(&serial_w, &mut serial_f);
        for t in [1usize, 2, 7] {
            let mut w = vec![0u64; xs.len()];
            bp64_encode_into_with(t, &xs, &mut w);
            assert_eq!(w, serial_w, "encode t={t}");
            let mut f = vec![0f64; xs.len()];
            bp64_decode_into_with(t, &w, &mut f);
            assert_eq!(
                f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial_f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "decode t={t}"
            );
            let mut rt = xs.clone();
            bp64_roundtrip_in_place_with(t, &mut rt);
            assert_eq!(
                rt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial_f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "roundtrip t={t}"
            );
            // Generic 64-bit spec entry points route through codec64.
            let mut wg = vec![0u64; xs.len()];
            encode64_slice_into_with(t, &crate::formats::posit::P64, &xs, &mut wg);
            let mut fg = vec![0f64; xs.len()];
            decode64_slice_into_with(t, &crate::formats::posit::P64, &wg, &mut fg);
            for (i, &w1) in wg.iter().enumerate() {
                assert_eq!(w1, codec64::p64_encode_lane(xs[i]), "p64 encode lane {i} t={t}");
                assert_eq!(
                    fg[i].to_bits(),
                    codec64::p64_decode_lane(w1).to_bits(),
                    "p64 decode lane {i} t={t}"
                );
            }
        }
    }

    #[test]
    fn generic_par_family_matches_named_aliases() {
        // One generic surface, two widths: the unified par_* entry points
        // must agree bit-for-bit with the historical per-width names.
        let mut rng = crate::testutil::Rng::new(0x9a11);
        let xs32: Vec<f32> = (0..1009)
            .map(|_| {
                let v = f32::from_bits(rng.next_u32());
                if v.is_finite() { v } else { -1.25 }
            })
            .collect();
        let xs64: Vec<f64> = xs32.iter().map(|&v| v as f64).collect();
        for t in [1usize, 3] {
            let mut a = vec![0u32; xs32.len()];
            let mut b = vec![0u32; xs32.len()];
            par_encode_into_with(t, &crate::formats::posit::BP32, &xs32, &mut a);
            bp32_encode_into_with(t, &xs32, &mut b);
            assert_eq!(a, b, "32-bit t={t}");
            let mut a64 = vec![0u64; xs64.len()];
            let mut b64 = vec![0u64; xs64.len()];
            par_encode_into_with(t, &crate::formats::posit::BP64, &xs64, &mut a64);
            bp64_encode_into_with(t, &xs64, &mut b64);
            assert_eq!(a64, b64, "64-bit t={t}");
            let mut r32 = xs32.clone();
            par_roundtrip_in_place_with(t, &crate::formats::posit::BP32, &mut r32);
            let mut n32 = xs32.clone();
            bp32_roundtrip_in_place_with(t, &mut n32);
            assert_eq!(
                r32.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                n32.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "roundtrip t={t}"
            );
        }
        // Auto-shard generic forms cover the same paths.
        let mut w = vec![0u32; xs32.len()];
        par_encode_into(&crate::formats::posit::BP32, &xs32, &mut w);
        let mut f = vec![0f32; xs32.len()];
        par_decode_into(&crate::formats::posit::BP32, &w, &mut f);
        let mut w2 = vec![0u32; xs32.len()];
        par_bp_encode_into(&xs32, &mut w2);
        assert_eq!(w, w2);
    }

    #[test]
    fn timed_block_split_is_bit_identical_and_reports_time() {
        // The timed fork-join must use the exact split of the untimed one
        // (so staged inputs stay bit-identical under profiling) and must
        // report nonzero summed worker time for real work.
        let mut rng = crate::testutil::Rng::new(0x71eed);
        let xs: Vec<f32> = (0..65_537)
            .map(|_| {
                let v = f32::from_bits(rng.next_u32());
                if v.is_finite() { v } else { 0.75 }
            })
            .collect();
        for t in [1usize, 2, 7] {
            let mut plain = xs.clone();
            bp32_roundtrip_in_place_with(t, &mut plain);
            let mut timed = xs.clone();
            let ns = par_bp_roundtrip_in_place_timed_with::<f32>(t, &mut timed);
            assert_eq!(
                timed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                plain.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "t={t}"
            );
            assert!(ns > 0, "t={t}: 64Ki roundtrip must take measurable time");
        }
        // Auto form matches the auto-shard untimed path too.
        let mut a = xs.clone();
        par_bp_roundtrip_in_place::<f32>(&mut a);
        let mut b = xs.clone();
        let _ = par_bp_roundtrip_in_place_timed::<f32>(&mut b);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // Degenerate inputs stay safe (timing an empty slice is fine).
        let mut empty: Vec<f32> = Vec::new();
        let _ = for_each_block_timed(4, &mut empty, |_, _| {});
    }

    #[test]
    fn auto_shards_keeps_small_batches_serial() {
        assert_eq!(auto_shards(0, CODEC_MIN_SHARD), 1);
        assert_eq!(auto_shards(CODEC_MIN_SHARD - 1, CODEC_MIN_SHARD), 1);
        assert!(auto_shards(usize::MAX, CODEC_MIN_SHARD) >= 1);
        assert!(num_threads() >= 1 && num_threads() <= MAX_THREADS);
    }
}
