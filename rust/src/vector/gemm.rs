//! Register/L1-blocked GEMM over the serving formats — the quantized
//! matmul workload at tensor scale, written **once** for both lane
//! widths.
//!
//! All matrices are dense row-major: `C (m×n) = A (m×k) · B (k×n)`.
//! Three kernel families, each generic over [`LaneElem`] with a serial
//! and a sharded (`par_*`) entry point:
//! - **fast path** ([`gemm`]): BLIS-style blocking — B packed into
//!   `KC×NC` blocks of `NR`-wide panels (L1/L2 resident), an `MR×NR`
//!   register-tile microkernel with one scalar accumulator chain per
//!   output element. Because each element's adds run in plain
//!   ascending-`p` order (the C tile is reloaded across `KC` blocks),
//!   the blocked result is **bit-identical to the naive triple loop**
//!   — blocking buys cache locality and ILP without reassociation.
//! - **quire-exact path** ([`gemm_quire`]): per-tile column packing
//!   (`NR` columns of B made contiguous per tile), then one
//!   [`LaneElem::quire`] accumulation per output element, rounded once
//!   at readout — the posit standard's fused dot product, at GEMM
//!   shape. Exactness makes the result independent of accumulation
//!   order.
//! - **quantized-weight path** ([`gemm_bp_weights`] /
//!   [`gemm_bp_weights_fast`]): A is serving-spec posit words (the
//!   stored model weights), B is float activations — the serving
//!   matmul. The fast variant lane-decodes A row-blocks into a scratch
//!   panel and reuses the float microkernel; the exact variant decodes
//!   into the quire accumulation. [`par_gemm_encoded_fast`] is the
//!   [`EncodedTensor`]-typed serving entry point (shape and spec are
//!   carried by the tensor, not re-asserted by every caller).
//!
//! The historical `*_f32`/`*_f64`/`*_bp32_*`/`*_bp64_*` names are thin
//! monomorphized aliases (see docs/API.md). Sharding splits C into
//! contiguous row blocks via [`super::parallel`]; every row is produced
//! by the same serial kernel regardless of the split, so `par_*`
//! results are bit-identical to serial for any thread count.

use super::lane::{self, EncodedTensor, LaneElem};
use super::parallel;
use crate::formats::Decoded;

/// Microkernel rows (register tile height).
pub const MR: usize = 4;
/// Microkernel columns (register tile width; one 8-lane vector).
pub const NR: usize = 8;
/// k-dimension block (B panel rows kept L1-resident).
pub const KC: usize = 256;
/// n-dimension block (packed B block kept L2-resident).
pub const NC: usize = 128;

fn check_shape(a_len: usize, b_len: usize, c_len: usize, m: usize, k: usize, n: usize) {
    assert_eq!(a_len, m * k, "gemm: A must be m×k");
    assert_eq!(b_len, k * n, "gemm: B must be k×n");
    assert_eq!(c_len, m * n, "gemm: C must be m×n");
}

/// Out-of-place matrix transpose: `dst` (cols×rows) ← `src` (rows×cols),
/// both row-major, tiled so both sides stream through cache. Generic over
/// the element so the serving layer can transpose f32 activations and
/// i32/u32/u64 weight words with the same code — the native backend's
/// weights-as-A GEMM formulation stages everything transposed once at
/// load (weights) or per batch (activations).
pub fn transpose<T: Copy>(src: &[T], dst: &mut [T], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols, "transpose: src must be rows×cols");
    assert_eq!(dst.len(), rows * cols, "transpose: dst must be cols×rows");
    const TB: usize = 32;
    for i0 in (0..rows).step_by(TB) {
        let i1 = rows.min(i0 + TB);
        for j0 in (0..cols).step_by(TB) {
            let j1 = cols.min(j0 + TB);
            for i in i0..i1 {
                for j in j0..j1 {
                    dst[j * rows + i] = src[i * cols + j];
                }
            }
        }
    }
}

/// Pack `B[pc..pc+kc, jc..jc+nc]` into `NR`-wide panels: panel `pi`
/// holds `kc` rows of `NR` contiguous values (zero-padded past `nc`).
fn pack_b<E: LaneElem>(
    b: &[E],
    bpack: &mut [E],
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
    ldb: usize,
) {
    let panels = nc.div_ceil(NR);
    bpack[..panels * kc * NR].fill(E::ZERO);
    for (pi, jr) in (0..nc).step_by(NR).enumerate() {
        let nr = NR.min(nc - jr);
        let dst_base = pi * kc * NR;
        for p in 0..kc {
            let src = (pc + p) * ldb + jc + jr;
            let dst = dst_base + p * NR;
            bpack[dst..dst + nr].copy_from_slice(&b[src..src + nr]);
        }
    }
}

/// `MR×NR` register-tile microkernel: loads the C tile, accumulates
/// `kc` products per element in ascending-`p` order (one scalar chain
/// per element — no reassociation), stores it back. The full-`NR`
/// inner loop over the zero-padded panel is branch-free and
/// autovectorizer-friendly; only the live `nr` columns are stored.
#[inline(always)]
fn micro<E: LaneElem>(
    a: &[E],
    lda: usize,
    a_off: usize,
    bpanel: &[E],
    c: &mut [E],
    ldc: usize,
    c_off: usize,
    mr: usize,
    nr: usize,
    kc: usize,
) {
    let mut acc = [[E::ZERO; NR]; MR];
    for i in 0..mr {
        for j in 0..nr {
            acc[i][j] = c[c_off + i * ldc + j];
        }
    }
    for p in 0..kc {
        let brow = &bpanel[p * NR..p * NR + NR];
        for (i, acc_i) in acc.iter_mut().enumerate().take(mr) {
            let av = a[a_off + i * lda + p];
            for j in 0..NR {
                acc_i[j] += av * brow[j];
            }
        }
    }
    for i in 0..mr {
        for j in 0..nr {
            c[c_off + i * ldc + j] = acc[i][j];
        }
    }
}

/// Blocked GEMM: `C ← A·B` (C is overwritten). Bit-identical to the
/// naive ascending-`p` triple loop (see module docs).
pub fn gemm<E: LaneElem>(a: &[E], b: &[E], c: &mut [E], m: usize, k: usize, n: usize) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    c.fill(E::ZERO);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut bpack = vec![E::ZERO; NC.div_ceil(NR) * KC * NR];
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b(b, &mut bpack, pc, jc, kc, nc, n);
            for ic in (0..m).step_by(MR) {
                let mr = MR.min(m - ic);
                for jr in (0..nc).step_by(NR) {
                    let nr = NR.min(nc - jr);
                    let panel = (jr / NR) * kc * NR;
                    micro(
                        a,
                        k,
                        ic * k + pc,
                        &bpack[panel..panel + kc * NR],
                        c,
                        n,
                        ic * n + jc + jr,
                        mr,
                        nr,
                        kc,
                    );
                }
            }
        }
    }
}

/// Sharded blocked GEMM with an explicit thread count.
pub fn par_gemm_with<E: LaneElem>(
    threads: usize,
    a: &[E],
    b: &[E],
    c: &mut [E],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        gemm(&a[r0 * k..(r0 + rows) * k], b, cb, rows, k, n);
    });
}

/// Sharded blocked GEMM (auto thread count from `PALLAS_THREADS`).
pub fn par_gemm<E: LaneElem>(a: &[E], b: &[E], c: &mut [E], m: usize, k: usize, n: usize) {
    par_gemm_with(parallel::auto_shards(m, parallel::ROWS_MIN_SHARD), a, b, c, m, k, n);
}

/// Quire-exact GEMM: every `C[i,j]` is an exact accumulation of its k
/// products in a width-appropriate quire, rounded once at readout.
pub fn gemm_quire<E: LaneElem>(a: &[E], b: &[E], c: &mut [E], m: usize, k: usize, n: usize) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    quire_rows(a, b, c, k, n);
}

/// Sharded quire-exact GEMM with an explicit thread count (each shard
/// owns its own quire and column-pack scratch).
pub fn par_gemm_quire_with<E: LaneElem>(
    threads: usize,
    a: &[E],
    b: &[E],
    c: &mut [E],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        quire_rows(&a[r0 * k..(r0 + rows) * k], b, cb, k, n);
    });
}

/// Sharded quire-exact GEMM (auto thread count).
pub fn par_gemm_quire<E: LaneElem>(a: &[E], b: &[E], c: &mut [E], m: usize, k: usize, n: usize) {
    par_gemm_quire_with(parallel::auto_shards(m, parallel::ROWS_MIN_SHARD), a, b, c, m, k, n);
}

/// Quire GEMM worker over a row slab: per `NR`-column tile, pack the B
/// columns contiguously, then run one exact accumulation per element.
fn quire_rows<E: LaneElem>(a_rows: &[E], b: &[E], c_rows: &mut [E], k: usize, n: usize) {
    if n == 0 || c_rows.is_empty() {
        return;
    }
    let rows = c_rows.len() / n;
    let mut q = E::quire();
    let mut colpack = vec![E::ZERO; k * NR];
    for jc in (0..n).step_by(NR) {
        let nr = NR.min(n - jc);
        for j in 0..nr {
            for p in 0..k {
                colpack[j * k + p] = b[p * n + jc + j];
            }
        }
        for i in 0..rows {
            let arow = &a_rows[i * k..(i + 1) * k];
            for j in 0..nr {
                let col = &colpack[j * k..(j + 1) * k];
                q.clear();
                for p in 0..k {
                    q.add_product(
                        &Decoded::from_f64(arow[p].to_f64()),
                        &Decoded::from_f64(col[p].to_f64()),
                    );
                }
                c_rows[i * n + jc + j] = E::from_f64(q.to_decoded().to_f64());
            }
        }
    }
}

/// Quire-exact quantized-weight GEMM: `A` is m×k serving-spec posit
/// words (the stored model weights), `B` is k×n float activations; each
/// output is an exact fused dot rounded once — the serving matmul's
/// reference semantics.
pub fn gemm_bp_weights<E: LaneElem>(
    a_bits: &[E::Word],
    b: &[E],
    c: &mut [E],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    quire_rows_bp(a_bits, b, c, k, n);
}

/// Sharded quire-exact quantized-weight GEMM with an explicit thread
/// count.
pub fn par_gemm_bp_weights_with<E: LaneElem>(
    threads: usize,
    a_bits: &[E::Word],
    b: &[E],
    c: &mut [E],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        quire_rows_bp(&a_bits[r0 * k..(r0 + rows) * k], b, cb, k, n);
    });
}

/// Sharded quire-exact quantized-weight GEMM (auto thread count).
pub fn par_gemm_bp_weights<E: LaneElem>(
    a_bits: &[E::Word],
    b: &[E],
    c: &mut [E],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights_with(
        parallel::auto_shards(m, parallel::ROWS_MIN_SHARD),
        a_bits,
        b,
        c,
        m,
        k,
        n,
    );
}

fn quire_rows_bp<E: LaneElem>(a_rows: &[E::Word], b: &[E], c_rows: &mut [E], k: usize, n: usize) {
    if n == 0 || c_rows.is_empty() {
        return;
    }
    let rows = c_rows.len() / n;
    let mut q = E::quire();
    let mut colpack = vec![E::ZERO; k * NR];
    // Decode the whole row slab once up front (the expensive general-
    // codec path), not once per NR-column tile — same scratch-size
    // tradeoff as the fast path's float panel, ceil(n/NR)× less decoding.
    let adec: Vec<Decoded> = a_rows.iter().map(|&w| E::BP.decode(E::word_to_u64(w))).collect();
    for jc in (0..n).step_by(NR) {
        let nr = NR.min(n - jc);
        for j in 0..nr {
            for p in 0..k {
                colpack[j * k + p] = b[p * n + jc + j];
            }
        }
        for i in 0..rows {
            let arow = &adec[i * k..(i + 1) * k];
            for j in 0..nr {
                let col = &colpack[j * k..(j + 1) * k];
                q.clear();
                for p in 0..k {
                    q.add_product(&arow[p], &Decoded::from_f64(col[p].to_f64()));
                }
                c_rows[i * n + jc + j] = E::from_f64(q.to_decoded().to_f64());
            }
        }
    }
}

/// Rounded fast path for quantized weights: lane-decode each A row block
/// into a float scratch panel, then run the blocked GEMM on it —
/// decode-then-GEMM with the decode amortized at panel granularity.
pub fn gemm_bp_weights_fast<E: LaneElem>(
    a_bits: &[E::Word],
    b: &[E],
    c: &mut [E],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    let mut a = vec![E::ZERO; a_bits.len()];
    lane::bp_decode_into::<E>(a_bits, &mut a);
    gemm(&a, b, c, m, k, n);
}

/// Sharded fast quantized-weight GEMM with an explicit thread count
/// (each shard decodes only its own row slab).
pub fn par_gemm_bp_weights_fast_with<E: LaneElem>(
    threads: usize,
    a_bits: &[E::Word],
    b: &[E],
    c: &mut [E],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        gemm_bp_weights_fast(&a_bits[r0 * k..(r0 + rows) * k], b, cb, rows, k, n);
    });
}

/// Sharded fast quantized-weight GEMM (auto thread count).
pub fn par_gemm_bp_weights_fast<E: LaneElem>(
    a_bits: &[E::Word],
    b: &[E],
    c: &mut [E],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights_fast_with(
        parallel::auto_shards(m, parallel::ROWS_MIN_SHARD),
        a_bits,
        b,
        c,
        m,
        k,
        n,
    );
}

/// The typed serving entry point: `C (m×n) ← W · B` where `W` is an
/// [`EncodedTensor`] carrying its own spec and `m×k` shape, so the
/// caller passes only the batch width `n` — shape mismatches are caught
/// here and spec/width mismatches cannot be expressed at all. Serving-
/// spec tensors run the decode-fused fast path; other lane specs decode
/// once and run the float GEMM.
pub fn par_gemm_encoded_fast<E: LaneElem>(w: &EncodedTensor<E>, b: &[E], c: &mut [E], n: usize) {
    let (m, k) = (w.rows(), w.cols());
    assert_eq!(b.len(), k * n, "gemm: B must be k×n");
    assert_eq!(c.len(), m * n, "gemm: C must be m×n");
    if w.is_serving_format() {
        par_gemm_bp_weights_fast(w.words(), b, c, m, k, n);
    } else {
        let mut a = vec![E::ZERO; w.len()];
        w.decode_into(&mut a);
        par_gemm(&a, b, c, m, k, n);
    }
}

// ----------------------------------------------------------------------
// Historical per-width names — monomorphized aliases (docs/API.md).
// ----------------------------------------------------------------------

/// Blocked f32 GEMM: `C ← A·B` (bit-identical to the naive triple loop).
pub fn gemm_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm(a, b, c, m, k, n);
}

/// Sharded blocked f32 GEMM with an explicit thread count.
pub fn par_gemm_f32_with(
    threads: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_with(threads, a, b, c, m, k, n);
}

/// Sharded blocked f32 GEMM (auto thread count from `PALLAS_THREADS`).
pub fn par_gemm_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    par_gemm(a, b, c, m, k, n);
}

/// Quire-exact f32 GEMM (800-bit accumulators, one rounding per output).
pub fn gemm_quire_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_quire(a, b, c, m, k, n);
}

/// Sharded quire-exact f32 GEMM with an explicit thread count.
pub fn par_gemm_quire_f32_with(
    threads: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_quire_with(threads, a, b, c, m, k, n);
}

/// Sharded quire-exact f32 GEMM (auto thread count).
pub fn par_gemm_quire_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    par_gemm_quire(a, b, c, m, k, n);
}

/// Quire-exact bp32-quantized-weight GEMM.
pub fn gemm_bp32_weights(a_bits: &[u32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_bp_weights(a_bits, b, c, m, k, n);
}

/// Sharded quire-exact bp32-quantized-weight GEMM, explicit thread count.
pub fn par_gemm_bp32_weights_with(
    threads: usize,
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights_with(threads, a_bits, b, c, m, k, n);
}

/// Sharded quire-exact bp32-quantized-weight GEMM (auto thread count).
pub fn par_gemm_bp32_weights(
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights(a_bits, b, c, m, k, n);
}

/// Decode-fused fast bp32-quantized-weight GEMM.
pub fn gemm_bp32_weights_fast(
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    gemm_bp_weights_fast(a_bits, b, c, m, k, n);
}

/// Sharded fast bp32-quantized-weight GEMM with an explicit thread count.
pub fn par_gemm_bp32_weights_fast_with(
    threads: usize,
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights_fast_with(threads, a_bits, b, c, m, k, n);
}

/// Sharded fast bp32-quantized-weight GEMM (auto thread count).
pub fn par_gemm_bp32_weights_fast(
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights_fast(a_bits, b, c, m, k, n);
}

/// Blocked f64 GEMM: `C ← A·B` (bit-identical to the naive triple loop).
pub fn gemm_f64(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    gemm(a, b, c, m, k, n);
}

/// Sharded blocked f64 GEMM with an explicit thread count.
pub fn par_gemm_f64_with(
    threads: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_with(threads, a, b, c, m, k, n);
}

/// Sharded blocked f64 GEMM (auto thread count from `PALLAS_THREADS`).
pub fn par_gemm_f64(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    par_gemm(a, b, c, m, k, n);
}

/// Quire-exact f64 GEMM ([`crate::formats::Quire::exact_f64`] sizing).
pub fn gemm_quire_f64(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    gemm_quire(a, b, c, m, k, n);
}

/// Sharded quire-exact f64 GEMM with an explicit thread count.
pub fn par_gemm_quire_f64_with(
    threads: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_quire_with(threads, a, b, c, m, k, n);
}

/// Sharded quire-exact f64 GEMM (auto thread count).
pub fn par_gemm_quire_f64(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    par_gemm_quire(a, b, c, m, k, n);
}

/// Quire-exact bp64-quantized-weight GEMM.
pub fn gemm_bp64_weights(a_bits: &[u64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    gemm_bp_weights(a_bits, b, c, m, k, n);
}

/// Sharded quire-exact bp64-quantized-weight GEMM, explicit thread count.
pub fn par_gemm_bp64_weights_with(
    threads: usize,
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights_with(threads, a_bits, b, c, m, k, n);
}

/// Sharded quire-exact bp64-quantized-weight GEMM (auto thread count).
pub fn par_gemm_bp64_weights(
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights(a_bits, b, c, m, k, n);
}

/// Decode-fused fast bp64-quantized-weight GEMM.
pub fn gemm_bp64_weights_fast(
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    gemm_bp_weights_fast(a_bits, b, c, m, k, n);
}

/// Sharded fast bp64-weight GEMM with an explicit thread count.
pub fn par_gemm_bp64_weights_fast_with(
    threads: usize,
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights_fast_with(threads, a_bits, b, c, m, k, n);
}

/// Sharded fast bp64-weight GEMM (auto thread count).
pub fn par_gemm_bp64_weights_fast(
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp_weights_fast(a_bits, b, c, m, k, n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vector::{codec, codec64};

    #[test]
    fn transpose_roundtrips_and_matches_indexing() {
        let mut rng = crate::testutil::Rng::new(0x7a39);
        for (rows, cols) in [(1, 1), (3, 7), (33, 65), (64, 40)] {
            let src: Vec<u32> = (0..rows * cols).map(|_| rng.next_u32()).collect();
            let mut t = vec![0u32; rows * cols];
            transpose(&src, &mut t, rows, cols);
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(t[j * rows + i], src[i * cols + j], "{rows}x{cols} ({i},{j})");
                }
            }
            let mut back = vec![0u32; rows * cols];
            transpose(&t, &mut back, cols, rows);
            assert_eq!(back, src, "{rows}x{cols} double transpose");
        }
    }

    /// Naive ascending-`p` triple loop: one scalar accumulator chain per
    /// element — the order the blocked kernel must reproduce exactly.
    fn naive_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn mixed(rng: &mut crate::testutil::Rng, len: usize) -> Vec<f32> {
        crate::testutil::mixed_scale_f32(rng, len, 31)
    }

    #[test]
    fn blocked_matches_naive_bitwise_on_edge_shapes() {
        let mut rng = crate::testutil::Rng::new(0x9e44);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 300, 9), (17, 129, 33), (33, 1, 2)]
        {
            let a = mixed(&mut rng, m * k);
            let b = mixed(&mut rng, k * n);
            let mut c = vec![0f32; m * n];
            gemm_f32(&a, &b, &mut c, m, k, n);
            let r = naive_f32(&a, &b, m, k, n);
            assert_eq!(
                c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                r.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn quire_gemm_recovers_cancellation_the_fast_path_loses() {
        // Row · column of [2^24, 1, -2^24]·[2^24, 1, 2^24]: exact result 1,
        // f32 accumulation loses it entirely.
        let a = [16777216.0f32, 1.0, -16777216.0];
        let b = [16777216.0f32, 1.0, 16777216.0]; // 3×1 column, row-major
        let mut c_fast = [0f32; 1];
        gemm_f32(&a, &b, &mut c_fast, 1, 3, 1);
        assert_eq!(c_fast[0], 0.0);
        let mut c_exact = [0f32; 1];
        gemm_quire_f32(&a, &b, &mut c_exact, 1, 3, 1);
        assert_eq!(c_exact[0], 1.0);
    }

    #[test]
    fn bp32_weight_paths_agree_with_gemv_kernels() {
        use crate::vector::kernels;
        let mut rng = crate::testutil::Rng::new(0xbeef);
        let (m, k) = (6, 17);
        let w: Vec<f32> = mixed(&mut rng, m * k);
        let w_bits: Vec<u32> = w.iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let x = mixed(&mut rng, k);
        // n = 1 GEMM ≡ gemv.
        let mut c = vec![0f32; m];
        gemm_bp32_weights(&w_bits, &x, &mut c, m, k, 1);
        let mut y = vec![0f32; m];
        let mut q = kernels::QuireDot::new();
        q.gemv_bp32_weights(&w_bits, &x, &mut y);
        assert_eq!(c, y);
        let mut cf = vec![0f32; m];
        gemm_bp32_weights_fast(&w_bits, &x, &mut cf, m, k, 1);
        for r in 0..m {
            let fast = kernels::dot_bp32_weights_fast(&w_bits[r * k..(r + 1) * k], &x);
            assert_eq!(cf[r], fast, "row {r}");
        }
    }

    #[test]
    fn encoded_tensor_entry_point_matches_raw_slice_paths() {
        use crate::formats::posit::BP32;
        use std::sync::Arc;
        let mut rng = crate::testutil::Rng::new(0xe7e7);
        let (m, k, n) = (7, 19, 5);
        let w: Vec<f32> = mixed(&mut rng, m * k);
        let w_bits: Vec<u32> = w.iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let b = mixed(&mut rng, k * n);
        let t = EncodedTensor::<f32>::from_words(BP32, m, k, Arc::new(w_bits.clone())).unwrap();
        let mut c_t = vec![0f32; m * n];
        par_gemm_encoded_fast(&t, &b, &mut c_t, n);
        let mut c_raw = vec![0f32; m * n];
        par_gemm_bp32_weights_fast(&w_bits, &b, &mut c_raw, m, k, n);
        assert_eq!(
            c_t.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            c_raw.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "typed entry point must be the raw fast path"
        );
        // 64-bit width through the same generic entry point.
        let w64: Vec<f64> = w.iter().map(|&v| v as f64).collect();
        let b64: Vec<f64> = b.iter().map(|&v| v as f64).collect();
        let t64 = EncodedTensor::<f64>::encode_bp(m, k, &w64).unwrap();
        let mut c64 = vec![0f64; m * n];
        par_gemm_encoded_fast(&t64, &b64, &mut c64, n);
        let mut c64_raw = vec![0f64; m * n];
        par_gemm_bp64_weights_fast(t64.words(), &b64, &mut c64_raw, m, k, n);
        assert_eq!(
            c64.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            c64_raw.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn par_paths_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x600d);
        let (m, k, n) = (13, 37, 11);
        let a = mixed(&mut rng, m * k);
        let b = mixed(&mut rng, k * n);
        let a_bits: Vec<u32> = a.iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let mut serial = vec![0f32; m * n];
        gemm_f32(&a, &b, &mut serial, m, k, n);
        let mut serial_q = vec![0f32; m * n];
        gemm_quire_f32(&a, &b, &mut serial_q, m, k, n);
        let mut serial_w = vec![0f32; m * n];
        gemm_bp32_weights(&a_bits, &b, &mut serial_w, m, k, n);
        for t in [1usize, 2, 7, 32] {
            let mut c = vec![0f32; m * n];
            par_gemm_f32_with(t, &a, &b, &mut c, m, k, n);
            assert_eq!(c, serial, "f32 t={t}");
            par_gemm_quire_f32_with(t, &a, &b, &mut c, m, k, n);
            assert_eq!(c, serial_q, "quire t={t}");
            par_gemm_bp32_weights_with(t, &a_bits, &b, &mut c, m, k, n);
            assert_eq!(c, serial_w, "bp32 t={t}");
        }
    }

    fn naive_f64(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn mixed64(rng: &mut crate::testutil::Rng, len: usize) -> Vec<f64> {
        crate::testutil::mixed_scale_f64(rng, len, 61)
    }

    #[test]
    fn blocked_f64_matches_naive_bitwise_on_edge_shapes() {
        let mut rng = crate::testutil::Rng::new(0x9e64);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 300, 9), (17, 129, 33), (33, 1, 2)]
        {
            let a = mixed64(&mut rng, m * k);
            let b = mixed64(&mut rng, k * n);
            let mut c = vec![0f64; m * n];
            gemm_f64(&a, &b, &mut c, m, k, n);
            let r = naive_f64(&a, &b, m, k, n);
            assert_eq!(
                c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                r.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn quire_f64_gemm_recovers_cancellation_the_fast_path_loses() {
        let big = f64::powi(2.0, 53);
        let a = [big, 1.0, -big];
        let b = [big, 1.0, big];
        let mut c_fast = [0f64; 1];
        gemm_f64(&a, &b, &mut c_fast, 1, 3, 1);
        assert_eq!(c_fast[0], 0.0);
        let mut c_exact = [0f64; 1];
        gemm_quire_f64(&a, &b, &mut c_exact, 1, 3, 1);
        assert_eq!(c_exact[0], 1.0);
    }

    #[test]
    fn bp64_weight_paths_agree_with_gemv_kernels() {
        use crate::vector::kernels;
        let mut rng = crate::testutil::Rng::new(0xbe64);
        let (m, k) = (6, 17);
        let w: Vec<f64> = mixed64(&mut rng, m * k);
        let w_bits: Vec<u64> = w.iter().map(|&x| codec64::bp64_encode_lane(x)).collect();
        let x = mixed64(&mut rng, k);
        // n = 1 GEMM ≡ gemv.
        let mut c = vec![0f64; m];
        gemm_bp64_weights(&w_bits, &x, &mut c, m, k, 1);
        let mut y = vec![0f64; m];
        let mut q = kernels::QuireDotF64::new();
        q.gemv_bp64_weights(&w_bits, &x, &mut y);
        assert_eq!(c, y);
        let mut cf = vec![0f64; m];
        gemm_bp64_weights_fast(&w_bits, &x, &mut cf, m, k, 1);
        for r in 0..m {
            let fast = kernels::dot_bp64_weights_fast(&w_bits[r * k..(r + 1) * k], &x);
            assert_eq!(cf[r], fast, "row {r}");
        }
    }

    #[test]
    fn par_f64_paths_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x6064);
        let (m, k, n) = (13, 37, 11);
        let a = mixed64(&mut rng, m * k);
        let b = mixed64(&mut rng, k * n);
        let a_bits: Vec<u64> = a.iter().map(|&x| codec64::bp64_encode_lane(x)).collect();
        let mut serial = vec![0f64; m * n];
        gemm_f64(&a, &b, &mut serial, m, k, n);
        let mut serial_q = vec![0f64; m * n];
        gemm_quire_f64(&a, &b, &mut serial_q, m, k, n);
        let mut serial_w = vec![0f64; m * n];
        gemm_bp64_weights(&a_bits, &b, &mut serial_w, m, k, n);
        let mut serial_wf = vec![0f64; m * n];
        gemm_bp64_weights_fast(&a_bits, &b, &mut serial_wf, m, k, n);
        for t in [1usize, 2, 7, 32] {
            let mut c = vec![0f64; m * n];
            par_gemm_f64_with(t, &a, &b, &mut c, m, k, n);
            assert_eq!(c, serial, "f64 t={t}");
            par_gemm_quire_f64_with(t, &a, &b, &mut c, m, k, n);
            assert_eq!(c, serial_q, "quire t={t}");
            par_gemm_bp64_weights_with(t, &a_bits, &b, &mut c, m, k, n);
            assert_eq!(c, serial_w, "bp64 t={t}");
            par_gemm_bp64_weights_fast_with(t, &a_bits, &b, &mut c, m, k, n);
            assert_eq!(c, serial_wf, "bp64 fast t={t}");
        }
    }

    #[test]
    fn zero_sized_dimensions_are_noops_f64() {
        let mut c: Vec<f64> = Vec::new();
        gemm_f64(&[], &[], &mut c, 0, 0, 0);
        gemm_quire_f64(&[], &[], &mut c, 0, 5, 0);
        par_gemm_f64_with(4, &[], &[], &mut c, 0, 0, 0);
        let mut c1 = vec![7f64; 2];
        gemm_f64(&[], &[], &mut c1, 2, 0, 1);
        assert_eq!(c1, vec![0.0, 0.0], "k=0 zeroes C");
    }

    #[test]
    fn zero_sized_dimensions_are_noops() {
        let mut c: Vec<f32> = Vec::new();
        gemm_f32(&[], &[], &mut c, 0, 0, 0);
        gemm_quire_f32(&[], &[], &mut c, 0, 5, 0);
        par_gemm_f32_with(4, &[], &[], &mut c, 0, 0, 0);
        let mut c1 = vec![7f32; 2];
        gemm_f32(&[], &[], &mut c1, 2, 0, 1);
        assert_eq!(c1, vec![0.0, 0.0], "k=0 zeroes C");
    }
}
