//! Register/L1-blocked GEMM over the serving formats — the quantized
//! matmul workload at tensor scale.
//!
//! All matrices are dense row-major: `C (m×n) = A (m×k) · B (k×n)`.
//! Three kernel families, each with a serial and a sharded (`par_*`)
//! entry point:
//! - **f32 fast path** ([`gemm_f32`]): BLIS-style blocking — B packed
//!   into `KC×NC` blocks of `NR`-wide panels (L1/L2 resident), an
//!   `MR×NR` register-tile microkernel with one scalar accumulator
//!   chain per output element. Because each element's adds run in plain
//!   ascending-`p` order (the C tile is reloaded across `KC` blocks),
//!   the blocked result is **bit-identical to the naive triple loop**
//!   — blocking buys cache locality and ILP without reassociation.
//! - **800-bit quire-exact path** ([`gemm_quire_f32`]): per-tile column
//!   packing (`NR` columns of B made contiguous per tile), then one
//!   [`Quire`] accumulation per output element, rounded once at
//!   readout — the posit standard's fused dot product, at GEMM shape.
//!   Exactness makes the result independent of accumulation order.
//! - **quantized-weight path** ([`gemm_bp32_weights`] /
//!   [`gemm_bp32_weights_fast`]): A is b-posit32 words (the stored
//!   model weights), B is f32 activations — the serving matmul. The
//!   fast variant lane-decodes A row-blocks into a scratch panel and
//!   reuses the f32 microkernel; the exact variant decodes into the
//!   quire accumulation.
//!
//! Sharding ([`par_gemm_f32`] etc.) splits C into contiguous row
//! blocks via [`super::parallel`]; every row is produced by the same
//! serial kernel regardless of the split, so `par_*` results are
//! bit-identical to serial for any thread count.

use super::codec;
use super::codec64;
use super::parallel;
use crate::formats::posit::{BP32, BP64};
use crate::formats::{Decoded, Quire};

/// Microkernel rows (register tile height).
pub const MR: usize = 4;
/// Microkernel columns (register tile width; one 8-lane vector).
pub const NR: usize = 8;
/// k-dimension block (B panel rows kept L1-resident).
pub const KC: usize = 256;
/// n-dimension block (packed B block kept L2-resident).
pub const NC: usize = 128;

fn check_shape(a_len: usize, b_len: usize, c_len: usize, m: usize, k: usize, n: usize) {
    assert_eq!(a_len, m * k, "gemm: A must be m×k");
    assert_eq!(b_len, k * n, "gemm: B must be k×n");
    assert_eq!(c_len, m * n, "gemm: C must be m×n");
}

/// Out-of-place matrix transpose: `dst` (cols×rows) ← `src` (rows×cols),
/// both row-major, tiled so both sides stream through cache. Generic over
/// the element so the serving layer can transpose f32 activations and
/// i32/u32/u64 weight words with the same code — the native backend's
/// weights-as-A GEMM formulation stages everything transposed once at
/// load (weights) or per batch (activations).
pub fn transpose<T: Copy>(src: &[T], dst: &mut [T], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols, "transpose: src must be rows×cols");
    assert_eq!(dst.len(), rows * cols, "transpose: dst must be cols×rows");
    const TB: usize = 32;
    for i0 in (0..rows).step_by(TB) {
        let i1 = rows.min(i0 + TB);
        for j0 in (0..cols).step_by(TB) {
            let j1 = cols.min(j0 + TB);
            for i in i0..i1 {
                for j in j0..j1 {
                    dst[j * rows + i] = src[i * cols + j];
                }
            }
        }
    }
}

/// Pack `B[pc..pc+kc, jc..jc+nc]` into `NR`-wide panels: panel `pi`
/// holds `kc` rows of `NR` contiguous values (zero-padded past `nc`).
fn pack_b(b: &[f32], bpack: &mut [f32], pc: usize, jc: usize, kc: usize, nc: usize, ldb: usize) {
    let panels = nc.div_ceil(NR);
    bpack[..panels * kc * NR].fill(0.0);
    for (pi, jr) in (0..nc).step_by(NR).enumerate() {
        let nr = NR.min(nc - jr);
        let dst_base = pi * kc * NR;
        for p in 0..kc {
            let src = (pc + p) * ldb + jc + jr;
            let dst = dst_base + p * NR;
            bpack[dst..dst + nr].copy_from_slice(&b[src..src + nr]);
        }
    }
}

/// `MR×NR` register-tile microkernel: loads the C tile, accumulates
/// `kc` products per element in ascending-`p` order (one scalar chain
/// per element — no reassociation), stores it back. The full-`NR`
/// inner loop over the zero-padded panel is branch-free and
/// autovectorizer-friendly; only the live `nr` columns are stored.
#[inline(always)]
fn micro_f32(
    a: &[f32],
    lda: usize,
    a_off: usize,
    bpanel: &[f32],
    c: &mut [f32],
    ldc: usize,
    c_off: usize,
    mr: usize,
    nr: usize,
    kc: usize,
) {
    let mut acc = [[0f32; NR]; MR];
    for i in 0..mr {
        for j in 0..nr {
            acc[i][j] = c[c_off + i * ldc + j];
        }
    }
    for p in 0..kc {
        let brow = &bpanel[p * NR..p * NR + NR];
        for (i, acc_i) in acc.iter_mut().enumerate().take(mr) {
            let av = a[a_off + i * lda + p];
            for j in 0..NR {
                acc_i[j] += av * brow[j];
            }
        }
    }
    for i in 0..mr {
        for j in 0..nr {
            c[c_off + i * ldc + j] = acc[i][j];
        }
    }
}

/// Blocked f32 GEMM: `C ← A·B` (C is overwritten). Bit-identical to the
/// naive ascending-`p` triple loop (see module docs).
pub fn gemm_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut bpack = vec![0f32; NC.div_ceil(NR) * KC * NR];
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b(b, &mut bpack, pc, jc, kc, nc, n);
            for ic in (0..m).step_by(MR) {
                let mr = MR.min(m - ic);
                for jr in (0..nc).step_by(NR) {
                    let nr = NR.min(nc - jr);
                    let panel = (jr / NR) * kc * NR;
                    micro_f32(
                        a,
                        k,
                        ic * k + pc,
                        &bpack[panel..panel + kc * NR],
                        c,
                        n,
                        ic * n + jc + jr,
                        mr,
                        nr,
                        kc,
                    );
                }
            }
        }
    }
}

/// Sharded blocked f32 GEMM with an explicit thread count.
pub fn par_gemm_f32_with(
    threads: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        gemm_f32(&a[r0 * k..(r0 + rows) * k], b, cb, rows, k, n);
    });
}

/// Sharded blocked f32 GEMM (auto thread count from `PALLAS_THREADS`).
pub fn par_gemm_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    par_gemm_f32_with(parallel::auto_shards(m, parallel::ROWS_MIN_SHARD), a, b, c, m, k, n);
}

/// Quire-exact GEMM: every `C[i,j]` is an exact 800-bit accumulation of
/// its k products, rounded once to f32 at readout.
pub fn gemm_quire_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    quire_rows_f32(a, b, c, k, n);
}

/// Sharded quire-exact GEMM with an explicit thread count (each shard
/// owns its own quire and column-pack scratch).
pub fn par_gemm_quire_f32_with(
    threads: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        quire_rows_f32(&a[r0 * k..(r0 + rows) * k], b, cb, k, n);
    });
}

/// Sharded quire-exact GEMM (auto thread count).
pub fn par_gemm_quire_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    par_gemm_quire_f32_with(parallel::auto_shards(m, parallel::ROWS_MIN_SHARD), a, b, c, m, k, n);
}

/// Quire GEMM worker over a row slab: per `NR`-column tile, pack the B
/// columns contiguously, then run one exact accumulation per element.
fn quire_rows_f32(a_rows: &[f32], b: &[f32], c_rows: &mut [f32], k: usize, n: usize) {
    if n == 0 || c_rows.is_empty() {
        return;
    }
    let rows = c_rows.len() / n;
    let mut q = Quire::paper_800(&BP32);
    let mut colpack = vec![0f32; k * NR];
    for jc in (0..n).step_by(NR) {
        let nr = NR.min(n - jc);
        for j in 0..nr {
            for p in 0..k {
                colpack[j * k + p] = b[p * n + jc + j];
            }
        }
        for i in 0..rows {
            let arow = &a_rows[i * k..(i + 1) * k];
            for j in 0..nr {
                let col = &colpack[j * k..(j + 1) * k];
                q.clear();
                for p in 0..k {
                    q.add_product(
                        &Decoded::from_f64(arow[p] as f64),
                        &Decoded::from_f64(col[p] as f64),
                    );
                }
                c_rows[i * n + jc + j] = q.to_decoded().to_f64() as f32;
            }
        }
    }
}

/// Quire-exact quantized-weight GEMM: `A` is m×k b-posit32 words (the
/// stored model weights), `B` is k×n f32 activations; each output is an
/// exact fused dot rounded once to f32 — the serving matmul's reference
/// semantics.
pub fn gemm_bp32_weights(a_bits: &[u32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    quire_rows_bp32(a_bits, b, c, k, n);
}

/// Sharded quire-exact quantized-weight GEMM with an explicit thread count.
pub fn par_gemm_bp32_weights_with(
    threads: usize,
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        quire_rows_bp32(&a_bits[r0 * k..(r0 + rows) * k], b, cb, k, n);
    });
}

/// Sharded quire-exact quantized-weight GEMM (auto thread count).
pub fn par_gemm_bp32_weights(
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp32_weights_with(
        parallel::auto_shards(m, parallel::ROWS_MIN_SHARD),
        a_bits,
        b,
        c,
        m,
        k,
        n,
    );
}

fn quire_rows_bp32(a_rows: &[u32], b: &[f32], c_rows: &mut [f32], k: usize, n: usize) {
    if n == 0 || c_rows.is_empty() {
        return;
    }
    let rows = c_rows.len() / n;
    let mut q = Quire::paper_800(&BP32);
    let mut colpack = vec![0f32; k * NR];
    // Decode the whole row slab once up front (the expensive general-
    // codec path), not once per NR-column tile — same scratch-size
    // tradeoff as the fast path's f64 panel, ceil(n/NR)× less decoding.
    let adec: Vec<Decoded> = a_rows.iter().map(|&w| BP32.decode(w as u64)).collect();
    for jc in (0..n).step_by(NR) {
        let nr = NR.min(n - jc);
        for j in 0..nr {
            for p in 0..k {
                colpack[j * k + p] = b[p * n + jc + j];
            }
        }
        for i in 0..rows {
            let arow = &adec[i * k..(i + 1) * k];
            for j in 0..nr {
                let col = &colpack[j * k..(j + 1) * k];
                q.clear();
                for p in 0..k {
                    q.add_product(&arow[p], &Decoded::from_f64(col[p] as f64));
                }
                c_rows[i * n + jc + j] = q.to_decoded().to_f64() as f32;
            }
        }
    }
}

/// Rounded fast path for quantized weights: lane-decode each A row block
/// into an f32 scratch panel, then run the blocked f32 GEMM on it —
/// decode-then-GEMM with the decode amortized at panel granularity.
pub fn gemm_bp32_weights_fast(
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    let mut a = vec![0f32; a_bits.len()];
    codec::bp32_decode_into(a_bits, &mut a);
    gemm_f32(&a, b, c, m, k, n);
}

/// Sharded fast quantized-weight GEMM with an explicit thread count
/// (each shard decodes only its own row slab).
pub fn par_gemm_bp32_weights_fast_with(
    threads: usize,
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        gemm_bp32_weights_fast(&a_bits[r0 * k..(r0 + rows) * k], b, cb, rows, k, n);
    });
}

/// Sharded fast quantized-weight GEMM (auto thread count).
pub fn par_gemm_bp32_weights_fast(
    a_bits: &[u32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp32_weights_fast_with(
        parallel::auto_shards(m, parallel::ROWS_MIN_SHARD),
        a_bits,
        b,
        c,
        m,
        k,
        n,
    );
}

// ----------------------------------------------------------------------
// f64 GEMM family (the 64-bit lane stack), on the same MR×NR microkernel
// geometry. Same bit-identity contract: the blocked f64 fast path equals
// the naive ascending-`p` triple loop bitwise, and every par_* entry
// point equals its serial counterpart for any thread count.
// ----------------------------------------------------------------------

/// Pack `B[pc..pc+kc, jc..jc+nc]` into `NR`-wide f64 panels.
fn pack_b64(b: &[f64], bpack: &mut [f64], pc: usize, jc: usize, kc: usize, nc: usize, ldb: usize) {
    let panels = nc.div_ceil(NR);
    bpack[..panels * kc * NR].fill(0.0);
    for (pi, jr) in (0..nc).step_by(NR).enumerate() {
        let nr = NR.min(nc - jr);
        let dst_base = pi * kc * NR;
        for p in 0..kc {
            let src = (pc + p) * ldb + jc + jr;
            let dst = dst_base + p * NR;
            bpack[dst..dst + nr].copy_from_slice(&b[src..src + nr]);
        }
    }
}

/// `MR×NR` f64 register-tile microkernel (one scalar accumulator chain
/// per element, ascending-`p` order — no reassociation).
#[inline(always)]
fn micro_f64(
    a: &[f64],
    lda: usize,
    a_off: usize,
    bpanel: &[f64],
    c: &mut [f64],
    ldc: usize,
    c_off: usize,
    mr: usize,
    nr: usize,
    kc: usize,
) {
    let mut acc = [[0f64; NR]; MR];
    for i in 0..mr {
        for j in 0..nr {
            acc[i][j] = c[c_off + i * ldc + j];
        }
    }
    for p in 0..kc {
        let brow = &bpanel[p * NR..p * NR + NR];
        for (i, acc_i) in acc.iter_mut().enumerate().take(mr) {
            let av = a[a_off + i * lda + p];
            for j in 0..NR {
                acc_i[j] += av * brow[j];
            }
        }
    }
    for i in 0..mr {
        for j in 0..nr {
            c[c_off + i * ldc + j] = acc[i][j];
        }
    }
}

/// Blocked f64 GEMM: `C ← A·B` (C is overwritten). Bit-identical to the
/// naive ascending-`p` triple loop.
pub fn gemm_f64(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut bpack = vec![0f64; NC.div_ceil(NR) * KC * NR];
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b64(b, &mut bpack, pc, jc, kc, nc, n);
            for ic in (0..m).step_by(MR) {
                let mr = MR.min(m - ic);
                for jr in (0..nc).step_by(NR) {
                    let nr = NR.min(nc - jr);
                    let panel = (jr / NR) * kc * NR;
                    micro_f64(
                        a,
                        k,
                        ic * k + pc,
                        &bpack[panel..panel + kc * NR],
                        c,
                        n,
                        ic * n + jc + jr,
                        mr,
                        nr,
                        kc,
                    );
                }
            }
        }
    }
}

/// Sharded blocked f64 GEMM with an explicit thread count.
pub fn par_gemm_f64_with(
    threads: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        gemm_f64(&a[r0 * k..(r0 + rows) * k], b, cb, rows, k, n);
    });
}

/// Sharded blocked f64 GEMM (auto thread count from `PALLAS_THREADS`).
pub fn par_gemm_f64(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    par_gemm_f64_with(parallel::auto_shards(m, parallel::ROWS_MIN_SHARD), a, b, c, m, k, n);
}

/// Quire-exact f64 GEMM: every `C[i,j]` is an exact accumulation of its
/// k products in an [`Quire::exact_f64`]-sized quire, rounded once at
/// readout — order-independent by construction.
pub fn gemm_quire_f64(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    quire_rows_f64(a, b, c, k, n);
}

/// Sharded quire-exact f64 GEMM with an explicit thread count.
pub fn par_gemm_quire_f64_with(
    threads: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        quire_rows_f64(&a[r0 * k..(r0 + rows) * k], b, cb, k, n);
    });
}

/// Sharded quire-exact f64 GEMM (auto thread count).
pub fn par_gemm_quire_f64(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    par_gemm_quire_f64_with(parallel::auto_shards(m, parallel::ROWS_MIN_SHARD), a, b, c, m, k, n);
}

fn quire_rows_f64(a_rows: &[f64], b: &[f64], c_rows: &mut [f64], k: usize, n: usize) {
    if n == 0 || c_rows.is_empty() {
        return;
    }
    let rows = c_rows.len() / n;
    let mut q = Quire::exact_f64();
    let mut colpack = vec![0f64; k * NR];
    for jc in (0..n).step_by(NR) {
        let nr = NR.min(n - jc);
        for j in 0..nr {
            for p in 0..k {
                colpack[j * k + p] = b[p * n + jc + j];
            }
        }
        for i in 0..rows {
            let arow = &a_rows[i * k..(i + 1) * k];
            for j in 0..nr {
                let col = &colpack[j * k..(j + 1) * k];
                q.clear();
                for p in 0..k {
                    q.add_product(&Decoded::from_f64(arow[p]), &Decoded::from_f64(col[p]));
                }
                c_rows[i * n + jc + j] = q.to_decoded().to_f64();
            }
        }
    }
}

/// Quire-exact bp64-quantized-weight GEMM: `A` is m×k b-posit64 words,
/// `B` is k×n f64 activations; each output is an exact fused dot rounded
/// once to f64.
pub fn gemm_bp64_weights(a_bits: &[u64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    quire_rows_bp64(a_bits, b, c, k, n);
}

/// Sharded quire-exact bp64-quantized-weight GEMM, explicit thread count.
pub fn par_gemm_bp64_weights_with(
    threads: usize,
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        quire_rows_bp64(&a_bits[r0 * k..(r0 + rows) * k], b, cb, k, n);
    });
}

/// Sharded quire-exact bp64-quantized-weight GEMM (auto thread count).
pub fn par_gemm_bp64_weights(
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp64_weights_with(
        parallel::auto_shards(m, parallel::ROWS_MIN_SHARD),
        a_bits,
        b,
        c,
        m,
        k,
        n,
    );
}

fn quire_rows_bp64(a_rows: &[u64], b: &[f64], c_rows: &mut [f64], k: usize, n: usize) {
    if n == 0 || c_rows.is_empty() {
        return;
    }
    let rows = c_rows.len() / n;
    let mut q = Quire::exact_f64();
    let mut colpack = vec![0f64; k * NR];
    // Decode the whole row slab once up front (the expensive general-
    // codec path), not once per NR-column tile — same scratch-size
    // tradeoff as the fast path's f64 panel, ceil(n/NR)× less decoding.
    let adec: Vec<Decoded> = a_rows.iter().map(|&w| BP64.decode(w)).collect();
    for jc in (0..n).step_by(NR) {
        let nr = NR.min(n - jc);
        for j in 0..nr {
            for p in 0..k {
                colpack[j * k + p] = b[p * n + jc + j];
            }
        }
        for i in 0..rows {
            let arow = &adec[i * k..(i + 1) * k];
            for j in 0..nr {
                let col = &colpack[j * k..(j + 1) * k];
                q.clear();
                for p in 0..k {
                    q.add_product(&arow[p], &Decoded::from_f64(col[p]));
                }
                c_rows[i * n + jc + j] = q.to_decoded().to_f64();
            }
        }
    }
}

/// Rounded fast path for bp64 weights: lane-decode A into an f64 scratch
/// panel, then run the blocked f64 GEMM on it.
pub fn gemm_bp64_weights_fast(
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    let mut a = vec![0f64; a_bits.len()];
    codec64::bp64_decode_into(a_bits, &mut a);
    gemm_f64(&a, b, c, m, k, n);
}

/// Sharded fast bp64-weight GEMM with an explicit thread count (each
/// shard decodes only its own row slab).
pub fn par_gemm_bp64_weights_fast_with(
    threads: usize,
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    check_shape(a_bits.len(), b.len(), c.len(), m, k, n);
    if n == 0 {
        return;
    }
    parallel::for_each_row_block(threads, m, n, c, |r0, cb| {
        let rows = cb.len() / n;
        gemm_bp64_weights_fast(&a_bits[r0 * k..(r0 + rows) * k], b, cb, rows, k, n);
    });
}

/// Sharded fast bp64-weight GEMM (auto thread count).
pub fn par_gemm_bp64_weights_fast(
    a_bits: &[u64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    par_gemm_bp64_weights_fast_with(
        parallel::auto_shards(m, parallel::ROWS_MIN_SHARD),
        a_bits,
        b,
        c,
        m,
        k,
        n,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrips_and_matches_indexing() {
        let mut rng = crate::testutil::Rng::new(0x7a39);
        for (rows, cols) in [(1, 1), (3, 7), (33, 65), (64, 40)] {
            let src: Vec<u32> = (0..rows * cols).map(|_| rng.next_u32()).collect();
            let mut t = vec![0u32; rows * cols];
            transpose(&src, &mut t, rows, cols);
            for i in 0..rows {
                for j in 0..cols {
                    assert_eq!(t[j * rows + i], src[i * cols + j], "{rows}x{cols} ({i},{j})");
                }
            }
            let mut back = vec![0u32; rows * cols];
            transpose(&t, &mut back, cols, rows);
            assert_eq!(back, src, "{rows}x{cols} double transpose");
        }
    }

    /// Naive ascending-`p` triple loop: one scalar accumulator chain per
    /// element — the order the blocked kernel must reproduce exactly.
    fn naive_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn mixed(rng: &mut crate::testutil::Rng, len: usize) -> Vec<f32> {
        crate::testutil::mixed_scale_f32(rng, len, 31)
    }

    #[test]
    fn blocked_matches_naive_bitwise_on_edge_shapes() {
        let mut rng = crate::testutil::Rng::new(0x9e44);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 300, 9), (17, 129, 33), (33, 1, 2)]
        {
            let a = mixed(&mut rng, m * k);
            let b = mixed(&mut rng, k * n);
            let mut c = vec![0f32; m * n];
            gemm_f32(&a, &b, &mut c, m, k, n);
            let r = naive_f32(&a, &b, m, k, n);
            assert_eq!(
                c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                r.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn quire_gemm_recovers_cancellation_the_fast_path_loses() {
        // Row · column of [2^24, 1, -2^24]·[2^24, 1, 2^24]: exact result 1,
        // f32 accumulation loses it entirely.
        let a = [16777216.0f32, 1.0, -16777216.0];
        let b = [16777216.0f32, 1.0, 16777216.0]; // 3×1 column, row-major
        let mut c_fast = [0f32; 1];
        gemm_f32(&a, &b, &mut c_fast, 1, 3, 1);
        assert_eq!(c_fast[0], 0.0);
        let mut c_exact = [0f32; 1];
        gemm_quire_f32(&a, &b, &mut c_exact, 1, 3, 1);
        assert_eq!(c_exact[0], 1.0);
    }

    #[test]
    fn bp32_weight_paths_agree_with_gemv_kernels() {
        use crate::vector::kernels;
        let mut rng = crate::testutil::Rng::new(0xbeef);
        let (m, k) = (6, 17);
        let w: Vec<f32> = mixed(&mut rng, m * k);
        let w_bits: Vec<u32> = w.iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let x = mixed(&mut rng, k);
        // n = 1 GEMM ≡ gemv.
        let mut c = vec![0f32; m];
        gemm_bp32_weights(&w_bits, &x, &mut c, m, k, 1);
        let mut y = vec![0f32; m];
        let mut q = kernels::QuireDot::new();
        q.gemv_bp32_weights(&w_bits, &x, &mut y);
        assert_eq!(c, y);
        let mut cf = vec![0f32; m];
        gemm_bp32_weights_fast(&w_bits, &x, &mut cf, m, k, 1);
        for r in 0..m {
            let fast = kernels::dot_bp32_weights_fast(&w_bits[r * k..(r + 1) * k], &x);
            assert_eq!(cf[r], fast, "row {r}");
        }
    }

    #[test]
    fn par_paths_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x600d);
        let (m, k, n) = (13, 37, 11);
        let a = mixed(&mut rng, m * k);
        let b = mixed(&mut rng, k * n);
        let a_bits: Vec<u32> = a.iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let mut serial = vec![0f32; m * n];
        gemm_f32(&a, &b, &mut serial, m, k, n);
        let mut serial_q = vec![0f32; m * n];
        gemm_quire_f32(&a, &b, &mut serial_q, m, k, n);
        let mut serial_w = vec![0f32; m * n];
        gemm_bp32_weights(&a_bits, &b, &mut serial_w, m, k, n);
        for t in [1usize, 2, 7, 32] {
            let mut c = vec![0f32; m * n];
            par_gemm_f32_with(t, &a, &b, &mut c, m, k, n);
            assert_eq!(c, serial, "f32 t={t}");
            par_gemm_quire_f32_with(t, &a, &b, &mut c, m, k, n);
            assert_eq!(c, serial_q, "quire t={t}");
            par_gemm_bp32_weights_with(t, &a_bits, &b, &mut c, m, k, n);
            assert_eq!(c, serial_w, "bp32 t={t}");
        }
    }

    fn naive_f64(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn mixed64(rng: &mut crate::testutil::Rng, len: usize) -> Vec<f64> {
        crate::testutil::mixed_scale_f64(rng, len, 61)
    }

    #[test]
    fn blocked_f64_matches_naive_bitwise_on_edge_shapes() {
        let mut rng = crate::testutil::Rng::new(0x9e64);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 300, 9), (17, 129, 33), (33, 1, 2)]
        {
            let a = mixed64(&mut rng, m * k);
            let b = mixed64(&mut rng, k * n);
            let mut c = vec![0f64; m * n];
            gemm_f64(&a, &b, &mut c, m, k, n);
            let r = naive_f64(&a, &b, m, k, n);
            assert_eq!(
                c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                r.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn quire_f64_gemm_recovers_cancellation_the_fast_path_loses() {
        let big = f64::powi(2.0, 53);
        let a = [big, 1.0, -big];
        let b = [big, 1.0, big];
        let mut c_fast = [0f64; 1];
        gemm_f64(&a, &b, &mut c_fast, 1, 3, 1);
        assert_eq!(c_fast[0], 0.0);
        let mut c_exact = [0f64; 1];
        gemm_quire_f64(&a, &b, &mut c_exact, 1, 3, 1);
        assert_eq!(c_exact[0], 1.0);
    }

    #[test]
    fn bp64_weight_paths_agree_with_gemv_kernels() {
        use crate::vector::kernels;
        let mut rng = crate::testutil::Rng::new(0xbe64);
        let (m, k) = (6, 17);
        let w: Vec<f64> = mixed64(&mut rng, m * k);
        let w_bits: Vec<u64> = w.iter().map(|&x| codec64::bp64_encode_lane(x)).collect();
        let x = mixed64(&mut rng, k);
        // n = 1 GEMM ≡ gemv.
        let mut c = vec![0f64; m];
        gemm_bp64_weights(&w_bits, &x, &mut c, m, k, 1);
        let mut y = vec![0f64; m];
        let mut q = kernels::QuireDotF64::new();
        q.gemv_bp64_weights(&w_bits, &x, &mut y);
        assert_eq!(c, y);
        let mut cf = vec![0f64; m];
        gemm_bp64_weights_fast(&w_bits, &x, &mut cf, m, k, 1);
        for r in 0..m {
            let fast = kernels::dot_bp64_weights_fast(&w_bits[r * k..(r + 1) * k], &x);
            assert_eq!(cf[r], fast, "row {r}");
        }
    }

    #[test]
    fn par_f64_paths_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x6064);
        let (m, k, n) = (13, 37, 11);
        let a = mixed64(&mut rng, m * k);
        let b = mixed64(&mut rng, k * n);
        let a_bits: Vec<u64> = a.iter().map(|&x| codec64::bp64_encode_lane(x)).collect();
        let mut serial = vec![0f64; m * n];
        gemm_f64(&a, &b, &mut serial, m, k, n);
        let mut serial_q = vec![0f64; m * n];
        gemm_quire_f64(&a, &b, &mut serial_q, m, k, n);
        let mut serial_w = vec![0f64; m * n];
        gemm_bp64_weights(&a_bits, &b, &mut serial_w, m, k, n);
        let mut serial_wf = vec![0f64; m * n];
        gemm_bp64_weights_fast(&a_bits, &b, &mut serial_wf, m, k, n);
        for t in [1usize, 2, 7, 32] {
            let mut c = vec![0f64; m * n];
            par_gemm_f64_with(t, &a, &b, &mut c, m, k, n);
            assert_eq!(c, serial, "f64 t={t}");
            par_gemm_quire_f64_with(t, &a, &b, &mut c, m, k, n);
            assert_eq!(c, serial_q, "quire t={t}");
            par_gemm_bp64_weights_with(t, &a_bits, &b, &mut c, m, k, n);
            assert_eq!(c, serial_w, "bp64 t={t}");
            par_gemm_bp64_weights_fast_with(t, &a_bits, &b, &mut c, m, k, n);
            assert_eq!(c, serial_wf, "bp64 fast t={t}");
        }
    }

    #[test]
    fn zero_sized_dimensions_are_noops_f64() {
        let mut c: Vec<f64> = Vec::new();
        gemm_f64(&[], &[], &mut c, 0, 0, 0);
        gemm_quire_f64(&[], &[], &mut c, 0, 5, 0);
        par_gemm_f64_with(4, &[], &[], &mut c, 0, 0, 0);
        let mut c1 = vec![7f64; 2];
        gemm_f64(&[], &[], &mut c1, 2, 0, 1);
        assert_eq!(c1, vec![0.0, 0.0], "k=0 zeroes C");
    }

    #[test]
    fn zero_sized_dimensions_are_noops() {
        let mut c: Vec<f32> = Vec::new();
        gemm_f32(&[], &[], &mut c, 0, 0, 0);
        gemm_quire_f32(&[], &[], &mut c, 0, 5, 0);
        par_gemm_f32_with(4, &[], &[], &mut c, 0, 0, 0);
        let mut c1 = vec![7f32; 2];
        gemm_f32(&[], &[], &mut c1, 2, 0, 1);
        assert_eq!(c1, vec![0.0, 0.0], "k=0 zeroes C");
    }
}
