//! 64-bit tier of the branch-free batched posit-family codec: the named
//! BP64/P64 fast paths and the u64/f64 slice drivers, as monomorphized
//! spec constants over the width-generic engine in [`super::lane`].
//!
//! This is the 64-bit rung of the paper's scalability claim ("even
//! greater advantages at 64-bit"): the bounded regime keeps the decode a
//! fixed mux at any width, so the datapath is the *same token stream* as
//! the 32-bit tier — `lane.rs` expands one macro body at both widths,
//! with u128 intermediates here (w_reg + es + 52 ≤ 123 bits).
//!
//! ## Contract (the f64 mirror of the 32-bit codec's contract)
//! - Encode: f64 subnormal inputs (|x| < 2^−1022) quantize to 0 (FTZ/DAZ
//!   end-to-end); NaN/Inf → NaR.
//! - Decode: values whose 52-bit-rounded scale falls below the f64
//!   normal range flush to ±0 (keeping the sign); above it, ±∞; NaR →
//!   canonical quiet NaN.
//!
//! Because ⟨64,6,5⟩ carries ≥ 52 fraction bits at every scale, **every
//! in-range f64 is exactly a b-posit64 value**: `bp64_encode` never
//! rounds and decode∘encode is the identity on |x| ∈ [2^−192, 2^192).
//!
//! Verified against the Python big-int oracle (python/compile/kernels/
//! scalar.py `lane_encode`/`lane_decode`) — see
//! python/tests/test_scalar_oracle64.py and rust/tests/vector_parity64.rs.

use super::lane::{self, LaneElem};
use crate::formats::posit::PositSpec;

/// True when the 64-bit lane codec supports this spec. Strict superset
/// of [`super::codec::spec_supported`]: everything that codec handles
/// plus widths 33..=64.
pub fn spec_supported(spec: &PositSpec) -> bool {
    <f64 as LaneElem>::spec_supported(spec)
}

// ---------------- b-posit⟨64,6,5⟩ (the 64-bit serving format) ----------------

/// Encode one f64 → b-posit64 word (branch-free lane form).
#[inline]
pub fn bp64_encode_lane(x: f64) -> u64 {
    <f64 as LaneElem>::bp_encode_lane(x)
}

/// Decode one b-posit64 word → f64 (branch-free lane form).
#[inline]
pub fn bp64_decode_lane(w: u64) -> f64 {
    <f64 as LaneElem>::bp_decode_lane(w)
}

/// Batched encode into a caller-owned buffer (`out.len() == xs.len()`).
pub fn bp64_encode_into(xs: &[f64], out: &mut [u64]) {
    lane::bp_encode_into::<f64>(xs, out);
}

/// Batched decode into a caller-owned buffer.
pub fn bp64_decode_into(ws: &[u64], out: &mut [f64]) {
    lane::bp_decode_into::<f64>(ws, out);
}

/// Allocating batched encode.
pub fn bp64_encode(xs: &[f64]) -> Vec<u64> {
    let mut out = vec![0u64; xs.len()];
    bp64_encode_into(xs, &mut out);
    out
}

/// Allocating batched decode.
pub fn bp64_decode(ws: &[u64]) -> Vec<f64> {
    let mut out = vec![0f64; ws.len()];
    bp64_decode_into(ws, &mut out);
    out
}

/// Fused quantize+dequantize of a buffer in place (no word buffer, no
/// allocation). For b-posit64 this is FTZ + NaR-canonicalization +
/// saturation only: in-range f64s are exactly representable.
pub fn bp64_roundtrip_in_place(xs: &mut [f64]) {
    lane::bp_roundtrip_in_place::<f64>(xs);
}

/// Fused roundtrip into a separate output buffer.
pub fn bp64_roundtrip_into(xs: &[f64], out: &mut [f64]) {
    assert_eq!(xs.len(), out.len(), "roundtrip64: input/output length mismatch");
    out.copy_from_slice(xs);
    bp64_roundtrip_in_place(out);
}

// ---------------- posit⟨64,2⟩ (standard-posit comparison) ----------------

/// Encode one f64 → posit⟨64,2⟩ word.
#[inline]
pub fn p64_encode_lane(x: f64) -> u64 {
    <f64 as LaneElem>::pstd_encode_lane(x)
}

/// Decode one posit⟨64,2⟩ word → f64.
#[inline]
pub fn p64_decode_lane(w: u64) -> f64 {
    <f64 as LaneElem>::pstd_decode_lane(w)
}

/// Batched posit⟨64,2⟩ encode into a caller-owned buffer.
pub fn p64_encode_into(xs: &[f64], out: &mut [u64]) {
    lane::pstd_encode_into::<f64>(xs, out);
}

/// Batched posit⟨64,2⟩ decode into a caller-owned buffer.
pub fn p64_decode_into(ws: &[u64], out: &mut [f64]) {
    lane::pstd_decode_into::<f64>(ws, out);
}

// ---------------- any supported spec ----------------

/// Encode one f64 under any supported spec (see [`spec_supported`]).
pub fn encode_word(spec: &PositSpec, x: f64) -> u64 {
    assert!(spec_supported(spec), "64-bit lane codec does not support {spec:?}");
    <f64 as LaneElem>::encode_lane(spec.n, spec.rs, spec.es, x)
}

/// Decode one word under any supported spec.
pub fn decode_word(spec: &PositSpec, w: u64) -> f64 {
    assert!(spec_supported(spec), "64-bit lane codec does not support {spec:?}");
    <f64 as LaneElem>::decode_lane(spec.n, spec.rs, spec.es, w)
}

/// Batched encode under any supported spec.
pub fn encode_slice_into(spec: &PositSpec, xs: &[f64], out: &mut [u64]) {
    assert!(spec_supported(spec), "64-bit lane codec does not support {spec:?}");
    lane::encode_slice::<f64>(spec.n, spec.rs, spec.es, xs, out);
}

/// Batched decode under any supported spec.
pub fn decode_slice_into(spec: &PositSpec, ws: &[u64], out: &mut [f64]) {
    assert!(spec_supported(spec), "64-bit lane codec does not support {spec:?}");
    lane::decode_slice::<f64>(spec.n, spec.rs, spec.es, ws, out);
}

// ---------------- f64 ⇄ bits (baseline lane for the bench sweep) ----------------

/// Batched f64 → raw bits (the no-op codec: memcpy-speed upper bound).
pub fn f64_to_bits_into(xs: &[f64], out: &mut [u64]) {
    assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = x.to_bits();
    }
}

/// Batched raw bits → f64.
pub fn bits_to_f64_into(ws: &[u64], out: &mut [f64]) {
    assert_eq!(ws.len(), out.len());
    for (o, &w) in out.iter_mut().zip(ws) {
        *o = f64::from_bits(w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{BP16, BP32, BP64, P16, P32, P64};
    use crate::formats::Decoded;

    #[test]
    fn bp64_known_patterns() {
        assert_eq!(bp64_encode_lane(1.0), 0x4000_0000_0000_0000);
        assert_eq!(bp64_encode_lane(-1.0), 0xC000_0000_0000_0000);
        assert_eq!(bp64_decode_lane(0x4000_0000_0000_0000), 1.0);
        assert_eq!(bp64_encode_lane(0.0), 0);
        assert_eq!(bp64_encode_lane(f64::NAN), 0x8000_0000_0000_0000);
        assert_eq!(bp64_encode_lane(f64::INFINITY), 0x8000_0000_0000_0000);
        assert!(bp64_decode_lane(0x8000_0000_0000_0000).is_nan());
        assert_eq!(bp64_decode_lane(0).to_bits(), 0.0f64.to_bits());
        assert_eq!(p64_encode_lane(1.0), 0x4000_0000_0000_0000);
        assert_eq!(p64_decode_lane(0x4000_0000_0000_0000), 1.0);
    }

    #[test]
    fn bp64_ftz_and_saturation_contract() {
        // Subnormal f64 inputs flush to the zero pattern.
        let sub = f64::from_bits(1); // 2^-1074
        assert_eq!(bp64_encode_lane(sub), 0);
        assert_eq!(bp64_encode_lane(-sub), 0);
        // Beyond the ⟨64,6,5⟩ range: saturate to ±maxpos, never NaR.
        assert_eq!(bp64_encode_lane(1e300), (1u64 << 63) - 1);
        assert_eq!(bp64_encode_lane(-1e300), (1u64 << 63) + 1);
        assert_eq!(bp64_encode_lane(1e-300), 1);
        assert_eq!(bp64_encode_lane(-1e-300), u64::MAX);
        // BP64 minpos (2^-192 scale) is within f64 range: no flush.
        assert!(bp64_decode_lane(1) > 0.0);
        // P64 minpos = 2^-248 exactly.
        assert_eq!(p64_decode_lane(1), f64::powi(2.0, -248));
        assert_eq!(p64_decode_lane(1u64.wrapping_neg()), -f64::powi(2.0, -248));
    }

    #[test]
    fn named_paths_match_general_codec_on_knowns() {
        for x in [1.0f64, -1.0, 0.5, 3.25, 1e30, -1e-30, 123456.78, 2.0f64.powi(150)] {
            assert_eq!(p64_encode_lane(x), P64.from_f64(x), "p64 encode {x}");
            assert_eq!(bp64_encode_lane(x), BP64.from_f64(x), "bp64 encode {x}");
        }
        for w in [0x4000_0000_0000_0000u64, 0xC000_0000_0000_0000, 12345, 1u64 << 62] {
            assert_eq!(p64_decode_lane(w), P64.to_f64(w), "p64 decode {w:#x}");
            assert_eq!(bp64_decode_lane(w), BP64.to_f64(w), "bp64 decode {w:#x}");
        }
    }

    #[test]
    fn bp64_in_range_f64_grid_is_exact() {
        // ⟨64,6,5⟩ carries ≥ 52 fraction bits at every scale, so every
        // in-range f64 roundtrips exactly (encode never rounds).
        let mut rng = crate::testutil::Rng::new(0x64f);
        let mut checked = 0u32;
        for _ in 0..200_000 {
            let x = f64::from_bits(rng.next_u64());
            if !x.is_finite() || x == 0.0 {
                continue;
            }
            let a = x.abs();
            if !(f64::powi(2.0, -192)..f64::powi(2.0, 191)).contains(&a) {
                continue;
            }
            let w = bp64_encode_lane(x);
            assert_eq!(bp64_decode_lane(w).to_bits(), x.to_bits(), "{x:e}");
            checked += 1;
        }
        // ~19% of random f64 bit patterns fall in the 2^±192 range.
        assert!(checked > 25_000, "only {checked} in-range samples");
    }

    #[test]
    fn generic_matches_named_fast_paths() {
        let mut rng = crate::testutil::Rng::new(0x9164);
        for _ in 0..50_000 {
            let w = rng.next_u64();
            let x = f64::from_bits(w);
            assert_eq!(encode_word(&BP64, x), bp64_encode_lane(x));
            assert_eq!(encode_word(&P64, x), p64_encode_lane(x));
            let (a, b) = (decode_word(&BP64, w), bp64_decode_lane(w));
            assert!(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()));
            let (a, b) = (decode_word(&P64, w), p64_decode_lane(w));
            assert!(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()));
        }
    }

    #[test]
    fn generic_agrees_with_32bit_lane_codec_on_narrow_specs() {
        // The 64-bit generic path is a superset: on n ≤ 32 specs it must
        // agree with the 32-bit lane codec (modulo the f32 vs f64 contract
        // window, so compare through the general codec on f64 inputs).
        for spec in [BP16, P16, BP32, P32] {
            for w in 0..=u16::MAX as u64 {
                let got = decode_word(&spec, w);
                let v = spec.decode(w & spec.mask());
                let want = if v.is_nan() {
                    f64::NAN
                } else {
                    let f = v.to_f64();
                    if f != 0.0 && f.abs() < f64::MIN_POSITIVE {
                        if f < 0.0 {
                            -0.0
                        } else {
                            0.0
                        }
                    } else {
                        f
                    }
                };
                assert!(
                    got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
                    "{spec:?} decode {w:#x}: {got} vs {want}"
                );
            }
            let mut rng = crate::testutil::Rng::new(spec.n as u64);
            for _ in 0..20_000 {
                let x = f64::from_bits(rng.next_u64());
                let want = if !x.is_finite() {
                    spec.nar()
                } else if x == 0.0 || x.abs() < f64::MIN_POSITIVE {
                    0
                } else {
                    spec.encode(&Decoded::from_f64(x))
                };
                assert_eq!(encode_word(&spec, x), want, "{spec:?} encode {x:e}");
            }
        }
    }

    #[test]
    fn slice_paths_match_lane_paths() {
        let xs: Vec<f64> = (0..37).map(|i| (i as f64 - 18.0) * 1.73).collect();
        let mut words = vec![0u64; xs.len()];
        bp64_encode_into(&xs, &mut words);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(words[i], bp64_encode_lane(x));
        }
        let mut back = vec![0f64; xs.len()];
        bp64_decode_into(&words, &mut back);
        assert_eq!(back, xs, "fovea values survive the roundtrip exactly");

        let mut rt = xs.clone();
        bp64_roundtrip_in_place(&mut rt);
        assert_eq!(rt, xs);
        let mut rt2 = vec![0f64; xs.len()];
        bp64_roundtrip_into(&xs, &mut rt2);
        assert_eq!(rt2, xs);

        assert_eq!(bp64_encode(&xs), words);
        assert_eq!(bp64_decode(&words), xs);

        let mut pw = vec![0u64; xs.len()];
        p64_encode_into(&xs, &mut pw);
        let mut pb = vec![0f64; xs.len()];
        p64_decode_into(&pw, &mut pb);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(pw[i], p64_encode_lane(x));
            assert_eq!(pb[i].to_bits(), p64_decode_lane(pw[i]).to_bits());
        }
    }

    #[test]
    fn supported_specs() {
        assert!(spec_supported(&BP64) && spec_supported(&P64));
        assert!(spec_supported(&BP32) && spec_supported(&P32) && spec_supported(&BP16));
        assert!(!spec_supported(&PositSpec { n: 64, rs: 63, es: 0 }));
        assert!(!spec_supported(&PositSpec { n: 2, rs: 1, es: 1 }));
    }

    #[test]
    fn f64_bits_roundtrip() {
        let xs = [0.0f64, -1.5, 3.25, f64::INFINITY];
        let mut w = [0u64; 4];
        let mut back = [0f64; 4];
        f64_to_bits_into(&xs, &mut w);
        bits_to_f64_into(&w, &mut back);
        assert_eq!(xs, back);
    }
}
