//! Branch-free batched 64-bit lane codec: posit-family words up to
//! `n = 64` over `&[f64]`/`&[u64]` streams with u128 intermediates.
//!
//! This is the 64-bit rung of the paper's scalability claim ("even
//! greater advantages at 64-bit"): the bounded regime keeps the decode a
//! fixed mux at any width, so the lane structure of [`super::codec`]
//! carries over unchanged — 8-lane chunks, pure value selects (both
//! arms of every `if` below are side-effect free, so LLVM lowers them to
//! cmov/blend, never control flow), `_into` variants for buffer reuse.
//! The only width-specific change is the intermediate stream: the
//! regime ‖ exponent ‖ fraction serialization and the pattern-space RNE
//! cut run in u128 (w_reg + es + 52 ≤ 123 bits).
//!
//! ## Contract (the f64 mirror of the 32-bit codec's contract)
//! - Encode: f64 subnormal inputs (|x| < 2^−1022) quantize to 0 (FTZ/DAZ
//!   end-to-end); NaN/Inf → NaR.
//! - Decode: values whose 52-bit-rounded scale falls below the f64
//!   normal range flush to ±0 (keeping the sign); above it, ±∞; NaR →
//!   canonical quiet NaN. For every supported spec the fraction width
//!   near the f64 range boundaries is ≤ 52 bits, so this is identical to
//!   "round the exact posit value to f64, then flush subnormals" — the
//!   form the big-int oracle checks.
//!
//! Two named fast paths: `bp64_*` for the paper's b-posit⟨64,6,5⟩ and
//! `p64_*` for the standard posit⟨64,2⟩. Because ⟨64,6,5⟩ carries ≥ 52
//! fraction bits at every scale, **every in-range f64 is exactly a
//! b-posit64 value**: `bp64_encode` never rounds and decode∘encode is
//! the identity on |x| ∈ [2^−192, 2^192).
//!
//! Verified against the Python big-int oracle (python/compile/kernels/
//! scalar.py `lane_encode`/`lane_decode`, themselves proven against the
//! Fraction-exact codec): exhaustive 16-bit sweeps across (rs, es)
//! corners, stratified 2^20-sample sweeps for BP64/P64, boundary and
//! RNE-tie strata — see python/tests/test_scalar_oracle64.py and
//! rust/tests/vector_parity64.rs.

use super::codec::LANES;
use crate::formats::posit::PositSpec;

const F64_NAN_BITS: u64 = 0x7ff8_0000_0000_0000;

/// True when the 64-bit lane codec supports this spec. Strict superset
/// of [`super::codec::spec_supported`]: everything that codec handles
/// plus widths 33..=64.
pub fn spec_supported(spec: &PositSpec) -> bool {
    (3..=64).contains(&spec.n)
        && spec.rs >= 2
        && spec.rs <= spec.n - 1
        && (1..=8).contains(&spec.es)
}

// ----------------------------------------------------------------------
// Lane primitives
// ----------------------------------------------------------------------

/// Encode one f64 into an n-bit posit/b-posit word (see module contract).
#[inline(always)]
fn encode_lane(n: u32, rs: u32, es: u32, x: f64) -> u64 {
    debug_assert!((3..=64).contains(&n) && rs >= 2 && rs <= n - 1 && (1..=8).contains(&es));
    let m = n - 1;
    let mask_n: u64 = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
    let nar: u64 = 1u64 << m;
    let maxpos: u128 = (1u128 << m) - 1;
    let bounded = rs < m;
    let r_max: i32 = rs as i32 - 1;
    let r_min: i32 = if bounded { -(rs as i32) } else { -(n as i32 - 2) };

    let bits = x.to_bits();
    let sign = bits >> 63;
    let biased = ((bits >> 52) & 0x7ff) as i32;
    let f52 = (bits & ((1u64 << 52) - 1)) as u128;
    let is_zero_or_sub = biased == 0; // zero and FTZ'd subnormals
    let is_special = biased == 0x7ff; // NaN/Inf → NaR
    let t = biased - 1023;
    let r = t >> es; // floor(t / 2^es)
    let e = (t & ((1i32 << es) - 1)) as u128; // t mod 2^es, in [0, 2^es)
    let sat_hi = r > r_max;
    let sat_lo = r < r_min;
    let rc = r.clamp(r_min, r_max); // keep shifts in range; sat masks win below
    let run: u32 = if rc >= 0 { (rc + 1) as u32 } else { (-rc) as u32 };
    let capped = run >= rs; // regime hits the bound: no terminator bit
    let w_reg = if capped { rs } else { run + 1 };
    let reg_ones = (1u128 << w_reg) - 1;
    let reg_val: u128 = if rc >= 0 { reg_ones - ((!capped) as u128) } else { (!capped) as u128 };
    // Serialize regime ‖ exponent ‖ fraction MSB-first into a u128 stream
    // (w_reg + es + 52 ≤ 63 + 8 + 52 = 123 bits: shifts never underflow).
    let sh_reg = 128 - w_reg;
    let sh_exp = sh_reg - es;
    let sh_frac = sh_exp - 52;
    let s = (reg_val << sh_reg) | (e << sh_exp) | (f52 << sh_frac);
    // Cut at m bits with round-to-nearest-even: rem+lsb>half ⟺ RNE up.
    let cut = 128 - m; // 65..=126
    let q = s >> cut;
    let rem = s & ((1u128 << cut) - 1);
    let half = 1u128 << (cut - 1);
    let up = (rem + (q & 1) > half) as u128;
    // Carry-out saturates to maxpos (never NaR); a nonzero real never
    // rounds to the zero pattern (min clamp to minpos).
    let body = (q + up).min(maxpos).max(1);
    let body = if sat_hi { maxpos } else { body };
    let body = if sat_lo { 1 } else { body };
    let body64 = body as u64;
    let word = (if sign == 1 { body64.wrapping_neg() } else { body64 }) & mask_n;
    let word = if is_zero_or_sub { 0 } else { word };
    if is_special {
        nar
    } else {
        word
    }
}

/// Decode one n-bit posit/b-posit word to f64 (see module contract).
#[inline(always)]
fn decode_lane(n: u32, rs: u32, es: u32, word: u64) -> f64 {
    debug_assert!((3..=64).contains(&n) && rs >= 2 && rs <= n - 1 && (1..=8).contains(&es));
    let m = n - 1;
    let mask_n: u64 = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
    let body_mask: u64 = (1u64 << m) - 1;
    let nar: u64 = 1u64 << m;

    let word = word & mask_n;
    let is_zero = word == 0;
    let is_nar = word == nar;
    let sign = (word >> m) & 1;
    let mag = (if sign == 1 { word.wrapping_neg() } else { word }) & body_mask;
    let b0 = (mag >> (m - 1)) & 1;
    // Leading-run length within the m-bit body, capped at rs.
    let probe = (if b0 == 1 { !mag } else { mag }) & body_mask;
    let lz = (probe << (64 - m)).leading_zeros(); // probe == 0 ⇒ 64 ≥ m
    let run = lz.min(m).min(rs);
    let reg_len = run + (run != rs) as u32; // +terminator unless capped
    let r: i32 = if b0 == 1 { run as i32 - 1 } else { -(run as i32) };
    // Align the first post-regime bit to bit 127 of a u128 (the two-step
    // shift keeps the amount ≤ 127 even when reg_len = m). Ghost exponent
    // bits and the empty fraction fall out as zeros automatically.
    let pay = ((mag as u128) << (127 - m + reg_len)) << 1;
    let e = (pay >> (128 - es)) as i32;
    let frac_top = pay << es; // fraction, MSB-aligned at bit 127
    let t = r * (1i32 << es) + e;
    // RNE the (≤ 60-bit) fraction to 52 f64 bits; guard/sticky live in
    // the low 76 bits of frac_top.
    let q = (frac_top >> 76) as u64;
    let rem = frac_top & ((1u128 << 76) - 1);
    let up = (rem + (q & 1) as u128 > (1u128 << 75)) as u64;
    let frac = q + up;
    let tt = t + (frac >> 52) as i32; // rounding carry bumps the scale
    let frac = frac & ((1u64 << 52) - 1);
    let underflow = tt < -1022; // FTZ contract (keeps the sign)
    let overflow = tt > 1023;
    let ttc = tt.clamp(-1022, 1023);
    let fbits = (sign << 63) | (((ttc + 1023) as u64) << 52) | frac;
    let fbits = if underflow { sign << 63 } else { fbits };
    let fbits = if overflow { (sign << 63) | (0x7ffu64 << 52) } else { fbits };
    let fbits = if is_zero { 0 } else { fbits };
    let fbits = if is_nar { F64_NAN_BITS } else { fbits };
    f64::from_bits(fbits)
}

// ----------------------------------------------------------------------
// Chunked slice drivers (monomorphized straight-line inner loops at every
// call site: the spec parameters are loop-invariant constants).
// ----------------------------------------------------------------------

#[inline(always)]
fn encode_slice(n: u32, rs: u32, es: u32, xs: &[f64], out: &mut [u64]) {
    assert_eq!(xs.len(), out.len(), "encode64: input/output length mismatch");
    let split = xs.len() - xs.len() % LANES;
    let (xh, xt) = xs.split_at(split);
    let (oh, ot) = out.split_at_mut(split);
    for (xc, oc) in xh.chunks_exact(LANES).zip(oh.chunks_exact_mut(LANES)) {
        for l in 0..LANES {
            oc[l] = encode_lane(n, rs, es, xc[l]);
        }
    }
    for (x, o) in xt.iter().zip(ot.iter_mut()) {
        *o = encode_lane(n, rs, es, *x);
    }
}

#[inline(always)]
fn decode_slice(n: u32, rs: u32, es: u32, ws: &[u64], out: &mut [f64]) {
    assert_eq!(ws.len(), out.len(), "decode64: input/output length mismatch");
    let split = ws.len() - ws.len() % LANES;
    let (wh, wt) = ws.split_at(split);
    let (oh, ot) = out.split_at_mut(split);
    for (wc, oc) in wh.chunks_exact(LANES).zip(oh.chunks_exact_mut(LANES)) {
        for l in 0..LANES {
            oc[l] = decode_lane(n, rs, es, wc[l]);
        }
    }
    for (w, o) in wt.iter().zip(ot.iter_mut()) {
        *o = decode_lane(n, rs, es, *w);
    }
}

// ---------------- b-posit⟨64,6,5⟩ (the 64-bit serving format) ----------------

/// Encode one f64 → b-posit64 word (branch-free lane form).
#[inline]
pub fn bp64_encode_lane(x: f64) -> u64 {
    encode_lane(64, 6, 5, x)
}

/// Decode one b-posit64 word → f64 (branch-free lane form).
#[inline]
pub fn bp64_decode_lane(w: u64) -> f64 {
    decode_lane(64, 6, 5, w)
}

/// Batched encode into a caller-owned buffer (`out.len() == xs.len()`).
pub fn bp64_encode_into(xs: &[f64], out: &mut [u64]) {
    encode_slice(64, 6, 5, xs, out);
}

/// Batched decode into a caller-owned buffer.
pub fn bp64_decode_into(ws: &[u64], out: &mut [f64]) {
    decode_slice(64, 6, 5, ws, out);
}

/// Allocating batched encode.
pub fn bp64_encode(xs: &[f64]) -> Vec<u64> {
    let mut out = vec![0u64; xs.len()];
    bp64_encode_into(xs, &mut out);
    out
}

/// Allocating batched decode.
pub fn bp64_decode(ws: &[u64]) -> Vec<f64> {
    let mut out = vec![0f64; ws.len()];
    bp64_decode_into(ws, &mut out);
    out
}

/// Fused quantize+dequantize of a buffer in place (no word buffer, no
/// allocation). For b-posit64 this is FTZ + NaR-canonicalization +
/// saturation only: in-range f64s are exactly representable.
pub fn bp64_roundtrip_in_place(xs: &mut [f64]) {
    let split = xs.len() - xs.len() % LANES;
    let (head, tail) = xs.split_at_mut(split);
    for c in head.chunks_exact_mut(LANES) {
        for l in 0..LANES {
            c[l] = decode_lane(64, 6, 5, encode_lane(64, 6, 5, c[l]));
        }
    }
    for x in tail.iter_mut() {
        *x = decode_lane(64, 6, 5, encode_lane(64, 6, 5, *x));
    }
}

/// Fused roundtrip into a separate output buffer.
pub fn bp64_roundtrip_into(xs: &[f64], out: &mut [f64]) {
    assert_eq!(xs.len(), out.len(), "roundtrip64: input/output length mismatch");
    out.copy_from_slice(xs);
    bp64_roundtrip_in_place(out);
}

// ---------------- posit⟨64,2⟩ (standard-posit comparison) ----------------

/// Encode one f64 → posit⟨64,2⟩ word.
#[inline]
pub fn p64_encode_lane(x: f64) -> u64 {
    encode_lane(64, 63, 2, x)
}

/// Decode one posit⟨64,2⟩ word → f64.
#[inline]
pub fn p64_decode_lane(w: u64) -> f64 {
    decode_lane(64, 63, 2, w)
}

/// Batched posit⟨64,2⟩ encode into a caller-owned buffer.
pub fn p64_encode_into(xs: &[f64], out: &mut [u64]) {
    encode_slice(64, 63, 2, xs, out);
}

/// Batched posit⟨64,2⟩ decode into a caller-owned buffer.
pub fn p64_decode_into(ws: &[u64], out: &mut [f64]) {
    decode_slice(64, 63, 2, ws, out);
}

// ---------------- any supported spec ----------------

/// Encode one f64 under any supported spec (see [`spec_supported`]).
pub fn encode_word(spec: &PositSpec, x: f64) -> u64 {
    assert!(spec_supported(spec), "64-bit lane codec does not support {spec:?}");
    encode_lane(spec.n, spec.rs, spec.es, x)
}

/// Decode one word under any supported spec.
pub fn decode_word(spec: &PositSpec, w: u64) -> f64 {
    assert!(spec_supported(spec), "64-bit lane codec does not support {spec:?}");
    decode_lane(spec.n, spec.rs, spec.es, w)
}

/// Batched encode under any supported spec.
pub fn encode_slice_into(spec: &PositSpec, xs: &[f64], out: &mut [u64]) {
    assert!(spec_supported(spec), "64-bit lane codec does not support {spec:?}");
    encode_slice(spec.n, spec.rs, spec.es, xs, out);
}

/// Batched decode under any supported spec.
pub fn decode_slice_into(spec: &PositSpec, ws: &[u64], out: &mut [f64]) {
    assert!(spec_supported(spec), "64-bit lane codec does not support {spec:?}");
    decode_slice(spec.n, spec.rs, spec.es, ws, out);
}

// ---------------- f64 ⇄ bits (baseline lane for the bench sweep) ----------------

/// Batched f64 → raw bits (the no-op codec: memcpy-speed upper bound).
pub fn f64_to_bits_into(xs: &[f64], out: &mut [u64]) {
    assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = x.to_bits();
    }
}

/// Batched raw bits → f64.
pub fn bits_to_f64_into(ws: &[u64], out: &mut [f64]) {
    assert_eq!(ws.len(), out.len());
    for (o, &w) in out.iter_mut().zip(ws) {
        *o = f64::from_bits(w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{BP16, BP32, BP64, P16, P32, P64};
    use crate::formats::Decoded;

    #[test]
    fn bp64_known_patterns() {
        assert_eq!(bp64_encode_lane(1.0), 0x4000_0000_0000_0000);
        assert_eq!(bp64_encode_lane(-1.0), 0xC000_0000_0000_0000);
        assert_eq!(bp64_decode_lane(0x4000_0000_0000_0000), 1.0);
        assert_eq!(bp64_encode_lane(0.0), 0);
        assert_eq!(bp64_encode_lane(f64::NAN), 0x8000_0000_0000_0000);
        assert_eq!(bp64_encode_lane(f64::INFINITY), 0x8000_0000_0000_0000);
        assert!(bp64_decode_lane(0x8000_0000_0000_0000).is_nan());
        assert_eq!(bp64_decode_lane(0).to_bits(), 0.0f64.to_bits());
        assert_eq!(p64_encode_lane(1.0), 0x4000_0000_0000_0000);
        assert_eq!(p64_decode_lane(0x4000_0000_0000_0000), 1.0);
    }

    #[test]
    fn bp64_ftz_and_saturation_contract() {
        // Subnormal f64 inputs flush to the zero pattern.
        let sub = f64::from_bits(1); // 2^-1074
        assert_eq!(bp64_encode_lane(sub), 0);
        assert_eq!(bp64_encode_lane(-sub), 0);
        // Beyond the ⟨64,6,5⟩ range: saturate to ±maxpos, never NaR.
        assert_eq!(bp64_encode_lane(1e300), (1u64 << 63) - 1);
        assert_eq!(bp64_encode_lane(-1e300), (1u64 << 63) + 1);
        assert_eq!(bp64_encode_lane(1e-300), 1);
        assert_eq!(bp64_encode_lane(-1e-300), u64::MAX);
        // BP64 minpos (2^-192 scale) is within f64 range: no flush.
        assert!(bp64_decode_lane(1) > 0.0);
        // P64 minpos = 2^-248 exactly.
        assert_eq!(p64_decode_lane(1), f64::powi(2.0, -248));
        assert_eq!(p64_decode_lane(1u64.wrapping_neg()), -f64::powi(2.0, -248));
    }

    #[test]
    fn named_paths_match_general_codec_on_knowns() {
        for x in [1.0f64, -1.0, 0.5, 3.25, 1e30, -1e-30, 123456.78, 2.0f64.powi(150)] {
            assert_eq!(p64_encode_lane(x), P64.from_f64(x), "p64 encode {x}");
            assert_eq!(bp64_encode_lane(x), BP64.from_f64(x), "bp64 encode {x}");
        }
        for w in [0x4000_0000_0000_0000u64, 0xC000_0000_0000_0000, 12345, 1u64 << 62] {
            assert_eq!(p64_decode_lane(w), P64.to_f64(w), "p64 decode {w:#x}");
            assert_eq!(bp64_decode_lane(w), BP64.to_f64(w), "bp64 decode {w:#x}");
        }
    }

    #[test]
    fn bp64_in_range_f64_grid_is_exact() {
        // ⟨64,6,5⟩ carries ≥ 52 fraction bits at every scale, so every
        // in-range f64 roundtrips exactly (encode never rounds).
        let mut rng = crate::testutil::Rng::new(0x64f);
        let mut checked = 0u32;
        for _ in 0..200_000 {
            let x = f64::from_bits(rng.next_u64());
            if !x.is_finite() || x == 0.0 {
                continue;
            }
            let a = x.abs();
            if !(f64::powi(2.0, -192)..f64::powi(2.0, 191)).contains(&a) {
                continue;
            }
            let w = bp64_encode_lane(x);
            assert_eq!(bp64_decode_lane(w).to_bits(), x.to_bits(), "{x:e}");
            checked += 1;
        }
        // ~19% of random f64 bit patterns fall in the 2^±192 range.
        assert!(checked > 25_000, "only {checked} in-range samples");
    }

    #[test]
    fn generic_matches_named_fast_paths() {
        let mut rng = crate::testutil::Rng::new(0x9164);
        for _ in 0..50_000 {
            let w = rng.next_u64();
            let x = f64::from_bits(w);
            assert_eq!(encode_word(&BP64, x), bp64_encode_lane(x));
            assert_eq!(encode_word(&P64, x), p64_encode_lane(x));
            let (a, b) = (decode_word(&BP64, w), bp64_decode_lane(w));
            assert!(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()));
            let (a, b) = (decode_word(&P64, w), p64_decode_lane(w));
            assert!(a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()));
        }
    }

    #[test]
    fn generic_agrees_with_32bit_lane_codec_on_narrow_specs() {
        // The 64-bit generic path is a superset: on n ≤ 32 specs it must
        // agree with the 32-bit lane codec (modulo the f32 vs f64 contract
        // window, so compare through the general codec on f64 inputs).
        for spec in [BP16, P16, BP32, P32] {
            for w in 0..=u16::MAX as u64 {
                let got = decode_word(&spec, w);
                let v = spec.decode(w & spec.mask());
                let want = if v.is_nan() {
                    f64::NAN
                } else {
                    let f = v.to_f64();
                    if f != 0.0 && f.abs() < f64::MIN_POSITIVE {
                        if f < 0.0 {
                            -0.0
                        } else {
                            0.0
                        }
                    } else {
                        f
                    }
                };
                assert!(
                    got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
                    "{spec:?} decode {w:#x}: {got} vs {want}"
                );
            }
            let mut rng = crate::testutil::Rng::new(spec.n as u64);
            for _ in 0..20_000 {
                let x = f64::from_bits(rng.next_u64());
                let want = if !x.is_finite() {
                    spec.nar()
                } else if x == 0.0 || x.abs() < f64::MIN_POSITIVE {
                    0
                } else {
                    spec.encode(&Decoded::from_f64(x))
                };
                assert_eq!(encode_word(&spec, x), want, "{spec:?} encode {x:e}");
            }
        }
    }

    #[test]
    fn slice_paths_match_lane_paths() {
        let xs: Vec<f64> = (0..37).map(|i| (i as f64 - 18.0) * 1.73).collect();
        let mut words = vec![0u64; xs.len()];
        bp64_encode_into(&xs, &mut words);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(words[i], bp64_encode_lane(x));
        }
        let mut back = vec![0f64; xs.len()];
        bp64_decode_into(&words, &mut back);
        assert_eq!(back, xs, "fovea values survive the roundtrip exactly");

        let mut rt = xs.clone();
        bp64_roundtrip_in_place(&mut rt);
        assert_eq!(rt, xs);
        let mut rt2 = vec![0f64; xs.len()];
        bp64_roundtrip_into(&xs, &mut rt2);
        assert_eq!(rt2, xs);

        assert_eq!(bp64_encode(&xs), words);
        assert_eq!(bp64_decode(&words), xs);

        let mut pw = vec![0u64; xs.len()];
        p64_encode_into(&xs, &mut pw);
        let mut pb = vec![0f64; xs.len()];
        p64_decode_into(&pw, &mut pb);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(pw[i], p64_encode_lane(x));
            assert_eq!(pb[i].to_bits(), p64_decode_lane(pw[i]).to_bits());
        }
    }

    #[test]
    fn supported_specs() {
        assert!(spec_supported(&BP64) && spec_supported(&P64));
        assert!(spec_supported(&BP32) && spec_supported(&P32) && spec_supported(&BP16));
        assert!(!spec_supported(&PositSpec { n: 64, rs: 63, es: 0 }));
        assert!(!spec_supported(&PositSpec { n: 2, rs: 1, es: 1 }));
    }

    #[test]
    fn f64_bits_roundtrip() {
        let xs = [0.0f64, -1.5, 3.25, f64::INFINITY];
        let mut w = [0u64; 4];
        let mut back = [0f64; 4];
        f64_to_bits_into(&xs, &mut w);
        bits_to_f64_into(&w, &mut back);
        assert_eq!(xs, back);
    }
}
