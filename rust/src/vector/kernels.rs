//! Batched linear-algebra kernels over the serving formats: `dot`, `axpy`,
//! and `gemv`, each in two flavors —
//! - a rounded **fast path** in plain f32 (8-lane accumulators, chunked,
//!   autovectorizer-friendly), and
//! - an **800-bit quire-exact path** ([`QuireDot`]) that accumulates every
//!   product exactly (Kulisch-style) and rounds once at readout, the
//!   fused-dot semantics the posit standard mandates and the paper's
//!   shared-quire sizing enables.
//!
//! The quire context owns its single 800-bit accumulator and is reused
//! across calls, so steady-state serving allocates nothing.

use super::codec;
use super::codec64;
use super::parallel;
use crate::formats::posit::{BP32, BP64};
use crate::formats::{Decoded, Quire};

/// Rounded f32 dot product (fast path): 8 independent accumulators keep
/// the loop free of a serial fadd chain.
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    let n = a.len();
    let chunks = n - n % 8;
    let mut acc = [0.0f32; 8];
    let mut i = 0;
    while i < chunks {
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Rounded f32 axpy: y ← y + α·x (elementwise, vectorizable).
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Rounded f32 gemv: y ← A·x with A row-major `y.len() × x.len()`.
pub fn gemv_f32(a: &[f32], x: &[f32], y: &mut [f32]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    for r in 0..rows {
        y[r] = dot_f32(&a[r * cols..(r + 1) * cols], x);
    }
}

/// Fast path over quantized weights: chunked lane-decode of b-posit32
/// words into a stack buffer fused with the f32 multiply-add — the
/// decode-then-dot serving kernel, with zero heap allocation.
pub fn dot_bp32_weights_fast(w_bits: &[u32], x: &[f32]) -> f32 {
    assert_eq!(w_bits.len(), x.len(), "dot: length mismatch");
    let n = x.len();
    let chunks = n - n % 8;
    let mut acc = [0.0f32; 8];
    let mut buf = [0.0f32; 8];
    let mut i = 0;
    while i < chunks {
        for l in 0..8 {
            buf[l] = codec::bp32_decode_lane(w_bits[i + l]);
        }
        for l in 0..8 {
            acc[l] += buf[l] * x[i + l];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while i < n {
        s += codec::bp32_decode_lane(w_bits[i]) * x[i];
        i += 1;
    }
    s
}

// ----------------------------------------------------------------------
// Row-sharded gemv (par_* entry points). Each shard covers a contiguous
// block of output rows and runs the serial kernel on it (quire shards own
// a private quire), so results are bit-identical to serial for any thread
// count.
// ----------------------------------------------------------------------

/// Sharded f32 gemv with an explicit thread count.
pub fn par_gemv_f32_with(threads: usize, a: &[f32], x: &[f32], y: &mut [f32]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    parallel::for_each_row_block(threads, rows, 1, y, |r0, yb| {
        gemv_f32(&a[r0 * cols..(r0 + yb.len()) * cols], x, yb);
    });
}

/// Sharded f32 gemv (auto thread count from `PALLAS_THREADS`).
pub fn par_gemv_f32(a: &[f32], x: &[f32], y: &mut [f32]) {
    par_gemv_f32_with(parallel::auto_shards(y.len(), parallel::ROWS_MIN_SHARD), a, x, y);
}

/// Sharded quire-exact gemv with an explicit thread count.
pub fn par_gemv_quire_f32_with(threads: usize, a: &[f32], x: &[f32], y: &mut [f32]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    parallel::for_each_row_block(threads, rows, 1, y, |r0, yb| {
        let mut q = QuireDot::new();
        q.gemv_f32(&a[r0 * cols..(r0 + yb.len()) * cols], x, yb);
    });
}

/// Sharded quire-exact gemv (auto thread count).
pub fn par_gemv_quire_f32(a: &[f32], x: &[f32], y: &mut [f32]) {
    par_gemv_quire_f32_with(parallel::auto_shards(y.len(), parallel::ROWS_MIN_SHARD), a, x, y);
}

/// Sharded quire-exact quantized-weight gemv with an explicit thread count.
pub fn par_gemv_bp32_weights_with(threads: usize, w_bits: &[u32], x: &[f32], y: &mut [f32]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(w_bits.len(), rows * cols, "gemv: shape mismatch");
    parallel::for_each_row_block(threads, rows, 1, y, |r0, yb| {
        let mut q = QuireDot::new();
        q.gemv_bp32_weights(&w_bits[r0 * cols..(r0 + yb.len()) * cols], x, yb);
    });
}

/// Sharded quire-exact quantized-weight gemv (auto thread count).
pub fn par_gemv_bp32_weights(w_bits: &[u32], x: &[f32], y: &mut [f32]) {
    let shards = parallel::auto_shards(y.len(), parallel::ROWS_MIN_SHARD);
    par_gemv_bp32_weights_with(shards, w_bits, x, y);
}

// ----------------------------------------------------------------------
// f64 kernels (the 64-bit lane stack: BP64/P64 words, f64 activations)
// ----------------------------------------------------------------------

/// Rounded f64 dot product (fast path): 8 independent accumulators keep
/// the loop free of a serial fadd chain.
pub fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    let n = a.len();
    let chunks = n - n % 8;
    let mut acc = [0.0f64; 8];
    let mut i = 0;
    while i < chunks {
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Rounded f64 axpy: y ← y + α·x (elementwise, vectorizable).
pub fn axpy_f64(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Rounded f64 gemv: y ← A·x with A row-major `y.len() × x.len()`.
pub fn gemv_f64(a: &[f64], x: &[f64], y: &mut [f64]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    for r in 0..rows {
        y[r] = dot_f64(&a[r * cols..(r + 1) * cols], x);
    }
}

/// Fast path over quantized weights: chunked lane-decode of b-posit64
/// words fused with the f64 multiply-add, zero heap allocation.
pub fn dot_bp64_weights_fast(w_bits: &[u64], x: &[f64]) -> f64 {
    assert_eq!(w_bits.len(), x.len(), "dot: length mismatch");
    let n = x.len();
    let chunks = n - n % 8;
    let mut acc = [0.0f64; 8];
    let mut buf = [0.0f64; 8];
    let mut i = 0;
    while i < chunks {
        for l in 0..8 {
            buf[l] = codec64::bp64_decode_lane(w_bits[i + l]);
        }
        for l in 0..8 {
            acc[l] += buf[l] * x[i + l];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while i < n {
        s += codec64::bp64_decode_lane(w_bits[i]) * x[i];
        i += 1;
    }
    s
}

/// Sharded f64 gemv with an explicit thread count.
pub fn par_gemv_f64_with(threads: usize, a: &[f64], x: &[f64], y: &mut [f64]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    parallel::for_each_row_block(threads, rows, 1, y, |r0, yb| {
        gemv_f64(&a[r0 * cols..(r0 + yb.len()) * cols], x, yb);
    });
}

/// Sharded f64 gemv (auto thread count from `PALLAS_THREADS`).
pub fn par_gemv_f64(a: &[f64], x: &[f64], y: &mut [f64]) {
    par_gemv_f64_with(parallel::auto_shards(y.len(), parallel::ROWS_MIN_SHARD), a, x, y);
}

/// Sharded quire-exact f64 gemv with an explicit thread count.
pub fn par_gemv_quire_f64_with(threads: usize, a: &[f64], x: &[f64], y: &mut [f64]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    parallel::for_each_row_block(threads, rows, 1, y, |r0, yb| {
        let mut q = QuireDotF64::new();
        q.gemv_f64(&a[r0 * cols..(r0 + yb.len()) * cols], x, yb);
    });
}

/// Sharded quire-exact f64 gemv (auto thread count).
pub fn par_gemv_quire_f64(a: &[f64], x: &[f64], y: &mut [f64]) {
    par_gemv_quire_f64_with(parallel::auto_shards(y.len(), parallel::ROWS_MIN_SHARD), a, x, y);
}

/// Sharded quire-exact bp64-quantized-weight gemv, explicit thread count.
pub fn par_gemv_bp64_weights_with(threads: usize, w_bits: &[u64], x: &[f64], y: &mut [f64]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(w_bits.len(), rows * cols, "gemv: shape mismatch");
    parallel::for_each_row_block(threads, rows, 1, y, |r0, yb| {
        let mut q = QuireDotF64::new();
        q.gemv_bp64_weights(&w_bits[r0 * cols..(r0 + yb.len()) * cols], x, yb);
    });
}

/// Sharded quire-exact bp64-quantized-weight gemv (auto thread count).
pub fn par_gemv_bp64_weights(w_bits: &[u64], x: &[f64], y: &mut [f64]) {
    let shards = parallel::auto_shards(y.len(), parallel::ROWS_MIN_SHARD);
    par_gemv_bp64_weights_with(shards, w_bits, x, y);
}

/// Reusable 800-bit quire context for exact dot/axpy/gemv. One allocation
/// at construction; every call clears and reuses it.
pub struct QuireDot {
    q: Quire,
}

impl Default for QuireDot {
    fn default() -> Self {
        QuireDot::new()
    }
}

impl QuireDot {
    /// Context sized per the paper: the 800-bit quire shared by every
    /// ⟨n,6,5⟩ precision.
    pub fn new() -> QuireDot {
        QuireDot { q: Quire::paper_800(&BP32) }
    }

    /// Exact dot of two f32 slices: each product accumulates exactly;
    /// a single rounding at readout (to f64, which is exact for results
    /// within f64 range).
    pub fn dot_f32(&mut self, a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len(), "dot: length mismatch");
        self.q.clear();
        for (&x, &y) in a.iter().zip(b) {
            self.q.add_product(&Decoded::from_f64(x as f64), &Decoded::from_f64(y as f64));
        }
        self.q.to_decoded().to_f64()
    }

    /// Exact dot over b-posit32 words, rounded once to a b-posit32 word —
    /// the posit standard's fused dot product.
    pub fn dot_bp32(&mut self, a_bits: &[u32], b_bits: &[u32]) -> u32 {
        assert_eq!(a_bits.len(), b_bits.len(), "dot: length mismatch");
        self.q.clear();
        for (&x, &y) in a_bits.iter().zip(b_bits) {
            self.q.add_product(&BP32.decode(x as u64), &BP32.decode(y as u64));
        }
        self.q.to_posit(&BP32) as u32
    }

    /// Quire-exact gemv: y ← A·x, one exact row-dot per output, each
    /// rounded once to f32.
    pub fn gemv_f32(&mut self, a: &[f32], x: &[f32], y: &mut [f32]) {
        let (rows, cols) = (y.len(), x.len());
        assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
        for r in 0..rows {
            y[r] = self.dot_f32(&a[r * cols..(r + 1) * cols], x) as f32;
        }
    }

    /// Quire-exact gemv over quantized weights (b-posit32 words) with f32
    /// activations — the serving layout's matmul row primitive.
    pub fn gemv_bp32_weights(&mut self, w_bits: &[u32], x: &[f32], y: &mut [f32]) {
        let (rows, cols) = (y.len(), x.len());
        assert_eq!(w_bits.len(), rows * cols, "gemv: shape mismatch");
        for r in 0..rows {
            self.q.clear();
            for c in 0..cols {
                self.q.add_product(
                    &BP32.decode(w_bits[r * cols + c] as u64),
                    &Decoded::from_f64(x[c] as f64),
                );
            }
            y[r] = self.q.to_decoded().to_f64() as f32;
        }
    }

    /// Elementwise exact FMA in b-posit32: yᵢ ← round_bp32(yᵢ + α·xᵢ) —
    /// one rounding per element instead of two.
    pub fn axpy_bp32(&mut self, alpha_bits: u32, x_bits: &[u32], y_bits: &mut [u32]) {
        assert_eq!(x_bits.len(), y_bits.len(), "axpy: length mismatch");
        let alpha = BP32.decode(alpha_bits as u64);
        for (yi, &xi) in y_bits.iter_mut().zip(x_bits) {
            self.q.clear();
            self.q.add(&BP32.decode(*yi as u64));
            self.q.add_product(&alpha, &BP32.decode(xi as u64));
            *yi = self.q.to_posit(&BP32) as u32;
        }
    }

    /// Exact dot over b-posit64 words, rounded once to a b-posit64 word.
    /// The same 800-bit quire serves every ⟨n,6,5⟩ precision — the
    /// paper's shared-quire sizing, exercised at its widest n here.
    pub fn dot_bp64(&mut self, a_bits: &[u64], b_bits: &[u64]) -> u64 {
        assert_eq!(a_bits.len(), b_bits.len(), "dot: length mismatch");
        self.q.clear();
        for (&x, &y) in a_bits.iter().zip(b_bits) {
            self.q.add_product(&BP64.decode(x), &BP64.decode(y));
        }
        self.q.to_posit(&BP64)
    }

    /// Elementwise exact FMA in b-posit64: yᵢ ← round_bp64(yᵢ + α·xᵢ).
    pub fn axpy_bp64(&mut self, alpha_bits: u64, x_bits: &[u64], y_bits: &mut [u64]) {
        assert_eq!(x_bits.len(), y_bits.len(), "axpy: length mismatch");
        let alpha = BP64.decode(alpha_bits);
        for (yi, &xi) in y_bits.iter_mut().zip(x_bits) {
            self.q.clear();
            self.q.add(&BP64.decode(*yi));
            self.q.add_product(&alpha, &BP64.decode(xi));
            *yi = self.q.to_posit(&BP64);
        }
    }
}

/// Reusable quire context for exact f64 dot/axpy/gemv. The accumulator is
/// [`Quire::exact_f64`]-sized (f64's 2^±1022 range overruns the 800-bit
/// posit quire), so every product of two f64 values — subnormals included
/// — accumulates exactly and the single readout rounding is the only
/// rounding in the whole reduction.
pub struct QuireDotF64 {
    q: Quire,
}

impl Default for QuireDotF64 {
    fn default() -> Self {
        QuireDotF64::new()
    }
}

impl QuireDotF64 {
    pub fn new() -> QuireDotF64 {
        QuireDotF64 { q: Quire::exact_f64() }
    }

    /// Exact dot of two f64 slices, rounded once (RNE) at readout.
    pub fn dot_f64(&mut self, a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len(), "dot: length mismatch");
        self.q.clear();
        for (&x, &y) in a.iter().zip(b) {
            self.q.add_product(&Decoded::from_f64(x), &Decoded::from_f64(y));
        }
        self.q.to_decoded().to_f64()
    }

    /// Exact f64 FMA per element: yᵢ ← round_f64(yᵢ + α·xᵢ) — fused
    /// multiply-add semantics without a hardware fma.
    pub fn axpy_f64(&mut self, alpha: f64, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), y.len(), "axpy: length mismatch");
        let da = Decoded::from_f64(alpha);
        for (yi, &xi) in y.iter_mut().zip(x) {
            self.q.clear();
            self.q.add(&Decoded::from_f64(*yi));
            self.q.add_product(&da, &Decoded::from_f64(xi));
            *yi = self.q.to_decoded().to_f64();
        }
    }

    /// Quire-exact f64 gemv: y ← A·x, one exact row-dot per output,
    /// each rounded once to f64.
    pub fn gemv_f64(&mut self, a: &[f64], x: &[f64], y: &mut [f64]) {
        let (rows, cols) = (y.len(), x.len());
        assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
        for r in 0..rows {
            y[r] = self.dot_f64(&a[r * cols..(r + 1) * cols], x);
        }
    }

    /// Quire-exact gemv over quantized weights (b-posit64 words) with
    /// f64 activations — the 64-bit serving layout's matmul row
    /// primitive.
    pub fn gemv_bp64_weights(&mut self, w_bits: &[u64], x: &[f64], y: &mut [f64]) {
        let (rows, cols) = (y.len(), x.len());
        assert_eq!(w_bits.len(), rows * cols, "gemv: shape mismatch");
        for r in 0..rows {
            self.q.clear();
            for c in 0..cols {
                self.q.add_product(&BP64.decode(w_bits[r * cols + c]), &Decoded::from_f64(x[c]));
            }
            y[r] = self.q.to_decoded().to_f64();
        }
    }
}

// ----------------------------------------------------------------------
// Dense-layer epilogues for the transposed serving layout (activations
// as a rows×cols block with one *neuron per row*): row-broadcast bias
// add, optionally fused with ReLU. The ReLU is written as an explicit
// `if v > 0` select — unlike `f32::max`, its treatment of −0.0 and NaN
// is the same on every platform, so backend and scalar-reference
// outputs stay bit-identical.
// ----------------------------------------------------------------------

/// `c[(i,j)] ← relu(c[(i,j)] + bias[i])` over a row-major rows×cols block.
pub fn bias_relu_rows(c: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    assert_eq!(c.len(), rows * cols, "bias_relu_rows: shape mismatch");
    assert_eq!(bias.len(), rows, "bias_relu_rows: bias must have one entry per row");
    for i in 0..rows {
        let b = bias[i];
        for v in &mut c[i * cols..(i + 1) * cols] {
            let s = *v + b;
            *v = if s > 0.0 { s } else { 0.0 };
        }
    }
}

/// `c[(i,j)] ← c[(i,j)] + bias[i]` over a row-major rows×cols block.
pub fn bias_rows(c: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    assert_eq!(c.len(), rows * cols, "bias_rows: shape mismatch");
    assert_eq!(bias.len(), rows, "bias_rows: bias must have one entry per row");
    for i in 0..rows {
        let b = bias[i];
        for v in &mut c[i * cols..(i + 1) * cols] {
            *v += b;
        }
    }
}

/// f64 variant of [`bias_relu_rows`] (the b-posit64 serving tier).
pub fn bias_relu_rows_f64(c: &mut [f64], bias: &[f64], rows: usize, cols: usize) {
    assert_eq!(c.len(), rows * cols, "bias_relu_rows_f64: shape mismatch");
    assert_eq!(bias.len(), rows, "bias_relu_rows_f64: bias must have one entry per row");
    for i in 0..rows {
        let b = bias[i];
        for v in &mut c[i * cols..(i + 1) * cols] {
            let s = *v + b;
            *v = if s > 0.0 { s } else { 0.0 };
        }
    }
}

/// f64 variant of [`bias_rows`] (the b-posit64 serving tier).
pub fn bias_rows_f64(c: &mut [f64], bias: &[f64], rows: usize, cols: usize) {
    assert_eq!(c.len(), rows * cols, "bias_rows_f64: shape mismatch");
    assert_eq!(bias.len(), rows, "bias_rows_f64: bias must have one entry per row");
    for i in 0..rows {
        let b = bias[i];
        for v in &mut c[i * cols..(i + 1) * cols] {
            *v += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_epilogues_broadcast_per_row() {
        let mut c = vec![1.0f32, -2.0, 3.0, -4.0, 5.0, -6.0]; // 2×3
        bias_rows(&mut c, &[10.0, -10.0], 2, 3);
        assert_eq!(c, vec![11.0, 8.0, 13.0, -14.0, -5.0, -16.0]);
        bias_relu_rows(&mut c, &[0.0, 14.5], 2, 3);
        assert_eq!(c, vec![11.0, 8.0, 13.0, 0.5, 9.5, 0.0]);
        // −0.0 sums select to +0.0 deterministically (explicit compare,
        // not f32::max); the f64 variants share the same contract.
        let mut z = vec![-0.0f32];
        bias_relu_rows(&mut z, &[0.0], 1, 1);
        assert_eq!(z[0].to_bits(), 0.0f32.to_bits());
        let mut c64 = vec![1.0f64, -3.0];
        bias_rows_f64(&mut c64, &[0.5], 1, 2);
        bias_relu_rows_f64(&mut c64, &[0.0], 1, 2);
        assert_eq!(c64, vec![1.5, 0.0]);
    }

    #[test]
    fn quire_dot_recovers_cancelled_term() {
        // 2^24·2^24 is exact; adding 1 then subtracting 2^24·2^24 leaves 1.
        // The rounded f32 path loses the 1 (2^48 + 1 isn't an f32); the
        // quire path keeps it.
        let a = [16777216.0f32, 1.0, -16777216.0];
        let b = [16777216.0f32, 1.0, 16777216.0];
        assert_eq!(dot_f32(&a, &b), 0.0);
        let mut q = QuireDot::new();
        assert_eq!(q.dot_f32(&a, &b), 1.0);
    }

    #[test]
    fn quire_dot_bp32_fused() {
        let a: Vec<u32> =
            [256.0f32, 1.0 / 256.0, -256.0].iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let b: Vec<u32> =
            [256.0f32, 1.0, 256.0].iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let mut q = QuireDot::new();
        let out = q.dot_bp32(&a, &b);
        assert_eq!(codec::bp32_decode_lane(out), 1.0 / 256.0);
    }

    #[test]
    fn gemv_consistent_with_dot() {
        let a: Vec<f32> = (0..20).map(|i| (i as f32 - 10.0) * 0.5).collect();
        let x: Vec<f32> = (0..5).map(|i| 1.0 + i as f32).collect();
        let mut y_fast = vec![0f32; 4];
        gemv_f32(&a, &x, &mut y_fast);
        for r in 0..4 {
            assert_eq!(y_fast[r], dot_f32(&a[r * 5..(r + 1) * 5], &x));
        }
        let mut q = QuireDot::new();
        let mut y_exact = vec![0f32; 4];
        q.gemv_f32(&a, &x, &mut y_exact);
        // Small exact-integer-ish data: both paths agree.
        assert_eq!(y_fast, y_exact);
    }

    #[test]
    fn gemv_bp32_weights_matches_fast_path_on_fovea_data() {
        let w: Vec<f32> = (0..24).map(|i| (i as f32 - 12.0) * 0.25).collect();
        let w_bits: Vec<u32> = w.iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let x: Vec<f32> = (0..6).map(|i| i as f32 * 0.5 - 1.0).collect();
        let mut q = QuireDot::new();
        let mut y = vec![0f32; 4];
        q.gemv_bp32_weights(&w_bits, &x, &mut y);
        for r in 0..4 {
            let fast = dot_bp32_weights_fast(&w_bits[r * 6..(r + 1) * 6], &x);
            assert_eq!(y[r], fast, "row {r}");
        }
    }

    #[test]
    fn par_gemv_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x9e37);
        let (rows, cols) = (19usize, 23usize);
        let a: Vec<f32> = (0..rows * cols).map(|_| (rng.f64() - 0.5) as f32 * 8.0).collect();
        let x: Vec<f32> = (0..cols).map(|_| (rng.f64() - 0.5) as f32 * 8.0).collect();
        let w_bits: Vec<u32> = a.iter().map(|&v| codec::bp32_encode_lane(v)).collect();
        let mut y_fast = vec![0f32; rows];
        gemv_f32(&a, &x, &mut y_fast);
        let mut q = QuireDot::new();
        let mut y_quire = vec![0f32; rows];
        q.gemv_f32(&a, &x, &mut y_quire);
        let mut y_w = vec![0f32; rows];
        q.gemv_bp32_weights(&w_bits, &x, &mut y_w);
        for t in [1usize, 2, 7] {
            let mut y = vec![0f32; rows];
            par_gemv_f32_with(t, &a, &x, &mut y);
            assert_eq!(y, y_fast, "f32 t={t}");
            par_gemv_quire_f32_with(t, &a, &x, &mut y);
            assert_eq!(y, y_quire, "quire t={t}");
            par_gemv_bp32_weights_with(t, &w_bits, &x, &mut y);
            assert_eq!(y, y_w, "bp32 t={t}");
        }
    }

    #[test]
    fn quire_dot_f64_recovers_cancelled_term() {
        // 2^53·2^53 = 2^106 is exact in the quire; the rounded f64 path
        // loses the +1 (2^106 + 1 isn't an f64), the quire keeps it.
        let big = f64::powi(2.0, 53);
        let a = [big, 1.0, -big];
        let b = [big, 1.0, big];
        assert_eq!(dot_f64(&a, &b), 0.0);
        let mut q = QuireDotF64::new();
        assert_eq!(q.dot_f64(&a, &b), 1.0);
    }

    #[test]
    fn quire_dot_f64_full_range() {
        // Products spanning max-f64 down to subnormal² in one reduction.
        let a = [f64::MAX, f64::from_bits(1), -f64::MAX];
        let b = [f64::MAX, f64::from_bits(1), f64::MAX];
        let mut q = QuireDotF64::new();
        let exact = q.dot_f64(&a, &b);
        // Exact value is 2^-2148, below f64 range: rounds to 0 at readout
        // — but crucially not NaR/Inf (no overflow in the accumulator).
        assert_eq!(exact, 0.0);
        // Without the cancellation the readout saturates cleanly.
        assert_eq!(q.dot_f64(&[f64::MAX, f64::MAX], &[f64::MAX, f64::MAX]), f64::INFINITY);
    }

    #[test]
    fn quire_dot_bp64_fused() {
        let a: Vec<u64> =
            [256.0f64, 1.0 / 256.0, -256.0].iter().map(|&x| codec64::bp64_encode_lane(x)).collect();
        let b: Vec<u64> =
            [256.0f64, 1.0, 256.0].iter().map(|&x| codec64::bp64_encode_lane(x)).collect();
        let mut q = QuireDot::new();
        let out = q.dot_bp64(&a, &b);
        assert_eq!(codec64::bp64_decode_lane(out), 1.0 / 256.0);
    }

    #[test]
    fn gemv_f64_consistent_with_dot_and_weights_fast_path() {
        let a: Vec<f64> = (0..20).map(|i| (i as f64 - 10.0) * 0.5).collect();
        let x: Vec<f64> = (0..5).map(|i| 1.0 + i as f64).collect();
        let mut y_fast = vec![0f64; 4];
        gemv_f64(&a, &x, &mut y_fast);
        for r in 0..4 {
            assert_eq!(y_fast[r], dot_f64(&a[r * 5..(r + 1) * 5], &x));
        }
        let mut q = QuireDotF64::new();
        let mut y_exact = vec![0f64; 4];
        q.gemv_f64(&a, &x, &mut y_exact);
        assert_eq!(y_fast, y_exact, "small exact-integer-ish data: both paths agree");

        let w_bits: Vec<u64> = a.iter().map(|&v| codec64::bp64_encode_lane(v)).collect();
        let mut y_w = vec![0f64; 4];
        q.gemv_bp64_weights(&w_bits, &x, &mut y_w);
        for r in 0..4 {
            let fast = dot_bp64_weights_fast(&w_bits[r * 5..(r + 1) * 5], &x);
            assert_eq!(y_w[r], fast, "row {r}");
        }
    }

    #[test]
    fn par_gemv_f64_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x9e64);
        let (rows, cols) = (19usize, 23usize);
        let a: Vec<f64> = (0..rows * cols).map(|_| (rng.f64() - 0.5) * 8.0).collect();
        let x: Vec<f64> = (0..cols).map(|_| (rng.f64() - 0.5) * 8.0).collect();
        let w_bits: Vec<u64> = a.iter().map(|&v| codec64::bp64_encode_lane(v)).collect();
        let mut y_fast = vec![0f64; rows];
        gemv_f64(&a, &x, &mut y_fast);
        let mut q = QuireDotF64::new();
        let mut y_quire = vec![0f64; rows];
        q.gemv_f64(&a, &x, &mut y_quire);
        let mut y_w = vec![0f64; rows];
        q.gemv_bp64_weights(&w_bits, &x, &mut y_w);
        for t in [1usize, 2, 7] {
            let mut y = vec![0f64; rows];
            par_gemv_f64_with(t, &a, &x, &mut y);
            assert_eq!(y, y_fast, "f64 t={t}");
            par_gemv_quire_f64_with(t, &a, &x, &mut y);
            assert_eq!(y, y_quire, "quire t={t}");
            par_gemv_bp64_weights_with(t, &w_bits, &x, &mut y);
            assert_eq!(y, y_w, "bp64 t={t}");
        }
    }

    #[test]
    fn axpy_f64_paths() {
        let x = [1.0f64, 2.0, 3.0];
        let mut y = [10.0f64, 20.0, 30.0];
        axpy_f64(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
        // Quire axpy fuses the rounding: (1 + 2^-60·2^7)·… — use a case
        // where two roundings differ from one. y + α·x with α·x exact:
        // 1.0 + 2^-53 + 2^-53 under two roundings stays 1.0 twice; the
        // fused add of (y=1.0, α=2.0, x=2^-53) gives the RNE of
        // 1 + 2^-52 = 1 + 2^-52 exactly.
        let mut q = QuireDotF64::new();
        let mut y2 = [1.0f64];
        q.axpy_f64(2.0, &[f64::powi(2.0, -53)], &mut y2);
        assert_eq!(y2[0], 1.0 + f64::powi(2.0, -52));

        let alpha = codec64::bp64_encode_lane(2.0);
        let xb: Vec<u64> =
            [3.0f64, -1.5, 0.0].iter().map(|&v| codec64::bp64_encode_lane(v)).collect();
        let mut yb: Vec<u64> =
            [1.0f64, 1.0, 7.0].iter().map(|&v| codec64::bp64_encode_lane(v)).collect();
        let mut qd = QuireDot::new();
        qd.axpy_bp64(alpha, &xb, &mut yb);
        let back: Vec<f64> = yb.iter().map(|&w| codec64::bp64_decode_lane(w)).collect();
        assert_eq!(back, vec![7.0, -2.0, 7.0]);
    }

    #[test]
    fn axpy_paths() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy_f32(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);

        let alpha = codec::bp32_encode_lane(2.0);
        let xb: Vec<u32> =
            [3.0f32, -1.5, 0.0].iter().map(|&v| codec::bp32_encode_lane(v)).collect();
        let mut yb: Vec<u32> =
            [1.0f32, 1.0, 7.0].iter().map(|&v| codec::bp32_encode_lane(v)).collect();
        let mut q = QuireDot::new();
        q.axpy_bp32(alpha, &xb, &mut yb);
        let back: Vec<f32> = yb.iter().map(|&w| codec::bp32_decode_lane(w)).collect();
        assert_eq!(back, vec![7.0, -2.0, 7.0]);
    }
}
