//! Batched linear-algebra kernels over the serving formats: **one
//! generic family** over any [`LaneElem`] width — `dot`, `axpy`, `gemv`,
//! the decode-fused quantized-weight dot, and row-sharded `par_gemv_*`
//! forms — each in two flavors:
//! - a rounded **fast path** in the plain float exchange type (8-lane
//!   accumulators, chunked, autovectorizer-friendly), and
//! - a **quire-exact path** that accumulates every product exactly
//!   (Kulisch-style; [`crate::formats::Quire`] — the paper's 800-bit
//!   shared quire for the f32 tier, the f64-range-exact sizing for the
//!   f64 tier via [`LaneElem::quire`]) and rounds once at readout, the
//!   fused-dot semantics the posit standard mandates.
//!
//! The historical `*_f32`/`*_f64`/`*_bp32_*`/`*_bp64_*` names are thin
//! monomorphized aliases (see docs/API.md). The [`QuireDot`] /
//! [`QuireDotF64`] contexts own their single quire allocation and are
//! reused across calls, so steady-state serving allocates nothing.

use super::lane::LaneElem;
use super::parallel;
use crate::formats::posit::{BP32, BP64};
use crate::formats::{Decoded, Quire};

// ----------------------------------------------------------------------
// Generic fast paths
// ----------------------------------------------------------------------

/// Rounded dot product (fast path): 8 independent accumulators keep the
/// loop free of a serial fadd chain.
pub fn dot<E: LaneElem>(a: &[E], b: &[E]) -> E {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    let n = a.len();
    let chunks = n - n % 8;
    let mut acc = [E::ZERO; 8];
    let mut i = 0;
    while i < chunks {
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Rounded axpy: y ← y + α·x (elementwise, vectorizable).
pub fn axpy<E: LaneElem>(alpha: E, x: &[E], y: &mut [E]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Rounded gemv: y ← A·x with A row-major `y.len() × x.len()`.
pub fn gemv<E: LaneElem>(a: &[E], x: &[E], y: &mut [E]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    for r in 0..rows {
        y[r] = dot(&a[r * cols..(r + 1) * cols], x);
    }
}

/// Fast path over quantized weights: chunked lane-decode of serving-spec
/// words into a stack buffer fused with the multiply-add — the
/// decode-then-dot serving kernel, with zero heap allocation.
pub fn dot_bp_weights_fast<E: LaneElem>(w_bits: &[E::Word], x: &[E]) -> E {
    assert_eq!(w_bits.len(), x.len(), "dot: length mismatch");
    let n = x.len();
    let chunks = n - n % 8;
    let mut acc = [E::ZERO; 8];
    let mut buf = [E::ZERO; 8];
    let mut i = 0;
    while i < chunks {
        for l in 0..8 {
            buf[l] = E::bp_decode_lane(w_bits[i + l]);
        }
        for l in 0..8 {
            acc[l] += buf[l] * x[i + l];
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while i < n {
        s += E::bp_decode_lane(w_bits[i]) * x[i];
        i += 1;
    }
    s
}

// ----------------------------------------------------------------------
// Generic quire-exact workers (shared by the QuireDot contexts, the
// par_gemv_* family, and vector::gemm's quire paths).
// ----------------------------------------------------------------------

/// Exact dot of two float slices through a caller-owned quire: each
/// product accumulates exactly; a single rounding at the f64 readout.
pub fn quire_dot<E: LaneElem>(q: &mut Quire, a: &[E], b: &[E]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    q.clear();
    for (&x, &y) in a.iter().zip(b) {
        q.add_product(&Decoded::from_f64(x.to_f64()), &Decoded::from_f64(y.to_f64()));
    }
    q.to_decoded().to_f64()
}

/// Quire-exact gemv worker: one exact row-dot per output, each rounded
/// once to `E`.
pub(crate) fn quire_gemv_rows<E: LaneElem>(q: &mut Quire, a: &[E], x: &[E], y: &mut [E]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    for r in 0..rows {
        y[r] = E::from_f64(quire_dot(q, &a[r * cols..(r + 1) * cols], x));
    }
}

/// Quire-exact gemv worker over serving-spec quantized weights.
pub(crate) fn quire_gemv_bp_rows<E: LaneElem>(
    q: &mut Quire,
    w_bits: &[E::Word],
    x: &[E],
    y: &mut [E],
) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(w_bits.len(), rows * cols, "gemv: shape mismatch");
    for r in 0..rows {
        q.clear();
        for c in 0..cols {
            q.add_product(
                &E::BP.decode(E::word_to_u64(w_bits[r * cols + c])),
                &Decoded::from_f64(x[c].to_f64()),
            );
        }
        y[r] = E::from_f64(q.to_decoded().to_f64());
    }
}

// ----------------------------------------------------------------------
// Row-sharded gemv (the unified par_* family). Each shard covers a
// contiguous block of output rows and runs the serial kernel on it
// (quire shards own a private quire), so results are bit-identical to
// serial for any thread count.
// ----------------------------------------------------------------------

/// Sharded fast gemv with an explicit thread count.
pub fn par_gemv_with<E: LaneElem>(threads: usize, a: &[E], x: &[E], y: &mut [E]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    parallel::for_each_row_block(threads, rows, 1, y, |r0, yb| {
        gemv(&a[r0 * cols..(r0 + yb.len()) * cols], x, yb);
    });
}

/// Sharded fast gemv (auto thread count from `PALLAS_THREADS`).
pub fn par_gemv<E: LaneElem>(a: &[E], x: &[E], y: &mut [E]) {
    par_gemv_with(parallel::auto_shards(y.len(), parallel::ROWS_MIN_SHARD), a, x, y);
}

/// Sharded quire-exact gemv with an explicit thread count.
pub fn par_gemv_quire_with<E: LaneElem>(threads: usize, a: &[E], x: &[E], y: &mut [E]) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(a.len(), rows * cols, "gemv: shape mismatch");
    parallel::for_each_row_block(threads, rows, 1, y, |r0, yb| {
        let mut q = E::quire();
        quire_gemv_rows(&mut q, &a[r0 * cols..(r0 + yb.len()) * cols], x, yb);
    });
}

/// Sharded quire-exact gemv (auto thread count).
pub fn par_gemv_quire<E: LaneElem>(a: &[E], x: &[E], y: &mut [E]) {
    par_gemv_quire_with(parallel::auto_shards(y.len(), parallel::ROWS_MIN_SHARD), a, x, y);
}

/// Sharded quire-exact quantized-weight gemv with an explicit thread
/// count.
pub fn par_gemv_bp_weights_with<E: LaneElem>(
    threads: usize,
    w_bits: &[E::Word],
    x: &[E],
    y: &mut [E],
) {
    let (rows, cols) = (y.len(), x.len());
    assert_eq!(w_bits.len(), rows * cols, "gemv: shape mismatch");
    parallel::for_each_row_block(threads, rows, 1, y, |r0, yb| {
        let mut q = E::quire();
        quire_gemv_bp_rows(&mut q, &w_bits[r0 * cols..(r0 + yb.len()) * cols], x, yb);
    });
}

/// Sharded quire-exact quantized-weight gemv (auto thread count).
pub fn par_gemv_bp_weights<E: LaneElem>(w_bits: &[E::Word], x: &[E], y: &mut [E]) {
    let shards = parallel::auto_shards(y.len(), parallel::ROWS_MIN_SHARD);
    par_gemv_bp_weights_with(shards, w_bits, x, y);
}

// ----------------------------------------------------------------------
// Historical per-width names — monomorphized aliases (docs/API.md).
// ----------------------------------------------------------------------

/// Rounded f32 dot product (fast path).
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    dot(a, b)
}

/// Rounded f32 axpy: y ← y + α·x.
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    axpy(alpha, x, y);
}

/// Rounded f32 gemv: y ← A·x with A row-major `y.len() × x.len()`.
pub fn gemv_f32(a: &[f32], x: &[f32], y: &mut [f32]) {
    gemv(a, x, y);
}

/// Decode-fused b-posit32 quantized-weight dot (fast path).
pub fn dot_bp32_weights_fast(w_bits: &[u32], x: &[f32]) -> f32 {
    dot_bp_weights_fast(w_bits, x)
}

/// Sharded f32 gemv with an explicit thread count.
pub fn par_gemv_f32_with(threads: usize, a: &[f32], x: &[f32], y: &mut [f32]) {
    par_gemv_with(threads, a, x, y);
}

/// Sharded f32 gemv (auto thread count from `PALLAS_THREADS`).
pub fn par_gemv_f32(a: &[f32], x: &[f32], y: &mut [f32]) {
    par_gemv(a, x, y);
}

/// Sharded quire-exact f32 gemv with an explicit thread count.
pub fn par_gemv_quire_f32_with(threads: usize, a: &[f32], x: &[f32], y: &mut [f32]) {
    par_gemv_quire_with(threads, a, x, y);
}

/// Sharded quire-exact f32 gemv (auto thread count).
pub fn par_gemv_quire_f32(a: &[f32], x: &[f32], y: &mut [f32]) {
    par_gemv_quire(a, x, y);
}

/// Sharded quire-exact bp32-quantized-weight gemv, explicit thread count.
pub fn par_gemv_bp32_weights_with(threads: usize, w_bits: &[u32], x: &[f32], y: &mut [f32]) {
    par_gemv_bp_weights_with(threads, w_bits, x, y);
}

/// Sharded quire-exact bp32-quantized-weight gemv (auto thread count).
pub fn par_gemv_bp32_weights(w_bits: &[u32], x: &[f32], y: &mut [f32]) {
    par_gemv_bp_weights(w_bits, x, y);
}

/// Rounded f64 dot product (fast path).
pub fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
    dot(a, b)
}

/// Rounded f64 axpy: y ← y + α·x.
pub fn axpy_f64(alpha: f64, x: &[f64], y: &mut [f64]) {
    axpy(alpha, x, y);
}

/// Rounded f64 gemv: y ← A·x with A row-major `y.len() × x.len()`.
pub fn gemv_f64(a: &[f64], x: &[f64], y: &mut [f64]) {
    gemv(a, x, y);
}

/// Decode-fused b-posit64 quantized-weight dot (fast path).
pub fn dot_bp64_weights_fast(w_bits: &[u64], x: &[f64]) -> f64 {
    dot_bp_weights_fast(w_bits, x)
}

/// Sharded f64 gemv with an explicit thread count.
pub fn par_gemv_f64_with(threads: usize, a: &[f64], x: &[f64], y: &mut [f64]) {
    par_gemv_with(threads, a, x, y);
}

/// Sharded f64 gemv (auto thread count from `PALLAS_THREADS`).
pub fn par_gemv_f64(a: &[f64], x: &[f64], y: &mut [f64]) {
    par_gemv(a, x, y);
}

/// Sharded quire-exact f64 gemv with an explicit thread count.
pub fn par_gemv_quire_f64_with(threads: usize, a: &[f64], x: &[f64], y: &mut [f64]) {
    par_gemv_quire_with(threads, a, x, y);
}

/// Sharded quire-exact f64 gemv (auto thread count).
pub fn par_gemv_quire_f64(a: &[f64], x: &[f64], y: &mut [f64]) {
    par_gemv_quire(a, x, y);
}

/// Sharded quire-exact bp64-quantized-weight gemv, explicit thread count.
pub fn par_gemv_bp64_weights_with(threads: usize, w_bits: &[u64], x: &[f64], y: &mut [f64]) {
    par_gemv_bp_weights_with(threads, w_bits, x, y);
}

/// Sharded quire-exact bp64-quantized-weight gemv (auto thread count).
pub fn par_gemv_bp64_weights(w_bits: &[u64], x: &[f64], y: &mut [f64]) {
    par_gemv_bp_weights(w_bits, x, y);
}

// ----------------------------------------------------------------------
// Reusable quire contexts
// ----------------------------------------------------------------------

/// Reusable 800-bit quire context for exact dot/axpy/gemv over the f32
/// tier (and the cross-width b-posit word forms — the paper's shared
/// quire serves every ⟨n,6,5⟩ precision). One allocation at
/// construction; every call clears and reuses it.
pub struct QuireDot {
    q: Quire,
}

impl Default for QuireDot {
    fn default() -> Self {
        QuireDot::new()
    }
}

impl QuireDot {
    /// Context sized per the paper: the 800-bit quire shared by every
    /// ⟨n,6,5⟩ precision.
    pub fn new() -> QuireDot {
        QuireDot { q: Quire::paper_800(&BP32) }
    }

    /// Exact dot of two f32 slices: each product accumulates exactly;
    /// a single rounding at readout (to f64, which is exact for results
    /// within f64 range).
    pub fn dot_f32(&mut self, a: &[f32], b: &[f32]) -> f64 {
        quire_dot(&mut self.q, a, b)
    }

    /// Exact dot over b-posit32 words, rounded once to a b-posit32 word —
    /// the posit standard's fused dot product.
    pub fn dot_bp32(&mut self, a_bits: &[u32], b_bits: &[u32]) -> u32 {
        assert_eq!(a_bits.len(), b_bits.len(), "dot: length mismatch");
        self.q.clear();
        for (&x, &y) in a_bits.iter().zip(b_bits) {
            self.q.add_product(&BP32.decode(x as u64), &BP32.decode(y as u64));
        }
        self.q.to_posit(&BP32) as u32
    }

    /// Quire-exact gemv: y ← A·x, one exact row-dot per output, each
    /// rounded once to f32.
    pub fn gemv_f32(&mut self, a: &[f32], x: &[f32], y: &mut [f32]) {
        quire_gemv_rows(&mut self.q, a, x, y);
    }

    /// Quire-exact gemv over quantized weights (b-posit32 words) with f32
    /// activations — the serving layout's matmul row primitive.
    pub fn gemv_bp32_weights(&mut self, w_bits: &[u32], x: &[f32], y: &mut [f32]) {
        quire_gemv_bp_rows(&mut self.q, w_bits, x, y);
    }

    /// Elementwise exact FMA in b-posit32: yᵢ ← round_bp32(yᵢ + α·xᵢ) —
    /// one rounding per element instead of two.
    pub fn axpy_bp32(&mut self, alpha_bits: u32, x_bits: &[u32], y_bits: &mut [u32]) {
        assert_eq!(x_bits.len(), y_bits.len(), "axpy: length mismatch");
        let alpha = BP32.decode(alpha_bits as u64);
        for (yi, &xi) in y_bits.iter_mut().zip(x_bits) {
            self.q.clear();
            self.q.add(&BP32.decode(*yi as u64));
            self.q.add_product(&alpha, &BP32.decode(xi as u64));
            *yi = self.q.to_posit(&BP32) as u32;
        }
    }

    /// Exact dot over b-posit64 words, rounded once to a b-posit64 word.
    /// The same 800-bit quire serves every ⟨n,6,5⟩ precision — the
    /// paper's shared-quire sizing, exercised at its widest n here.
    pub fn dot_bp64(&mut self, a_bits: &[u64], b_bits: &[u64]) -> u64 {
        assert_eq!(a_bits.len(), b_bits.len(), "dot: length mismatch");
        self.q.clear();
        for (&x, &y) in a_bits.iter().zip(b_bits) {
            self.q.add_product(&BP64.decode(x), &BP64.decode(y));
        }
        self.q.to_posit(&BP64)
    }

    /// Elementwise exact FMA in b-posit64: yᵢ ← round_bp64(yᵢ + α·xᵢ).
    pub fn axpy_bp64(&mut self, alpha_bits: u64, x_bits: &[u64], y_bits: &mut [u64]) {
        assert_eq!(x_bits.len(), y_bits.len(), "axpy: length mismatch");
        let alpha = BP64.decode(alpha_bits);
        for (yi, &xi) in y_bits.iter_mut().zip(x_bits) {
            self.q.clear();
            self.q.add(&BP64.decode(*yi));
            self.q.add_product(&alpha, &BP64.decode(xi));
            *yi = self.q.to_posit(&BP64);
        }
    }
}

/// Reusable quire context for exact f64 dot/axpy/gemv. The accumulator is
/// [`Quire::exact_f64`]-sized (f64's 2^±1022 range overruns the 800-bit
/// posit quire), so every product of two f64 values — subnormals included
/// — accumulates exactly and the single readout rounding is the only
/// rounding in the whole reduction.
pub struct QuireDotF64 {
    q: Quire,
}

impl Default for QuireDotF64 {
    fn default() -> Self {
        QuireDotF64::new()
    }
}

impl QuireDotF64 {
    /// Context with an f64-range-exact quire.
    pub fn new() -> QuireDotF64 {
        QuireDotF64 { q: Quire::exact_f64() }
    }

    /// Exact dot of two f64 slices, rounded once (RNE) at readout.
    pub fn dot_f64(&mut self, a: &[f64], b: &[f64]) -> f64 {
        quire_dot(&mut self.q, a, b)
    }

    /// Exact f64 FMA per element: yᵢ ← round_f64(yᵢ + α·xᵢ) — fused
    /// multiply-add semantics without a hardware fma.
    pub fn axpy_f64(&mut self, alpha: f64, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), y.len(), "axpy: length mismatch");
        let da = Decoded::from_f64(alpha);
        for (yi, &xi) in y.iter_mut().zip(x) {
            self.q.clear();
            self.q.add(&Decoded::from_f64(*yi));
            self.q.add_product(&da, &Decoded::from_f64(xi));
            *yi = self.q.to_decoded().to_f64();
        }
    }

    /// Quire-exact f64 gemv: y ← A·x, one exact row-dot per output,
    /// each rounded once to f64.
    pub fn gemv_f64(&mut self, a: &[f64], x: &[f64], y: &mut [f64]) {
        quire_gemv_rows(&mut self.q, a, x, y);
    }

    /// Quire-exact gemv over quantized weights (b-posit64 words) with
    /// f64 activations — the 64-bit serving layout's matmul row
    /// primitive.
    pub fn gemv_bp64_weights(&mut self, w_bits: &[u64], x: &[f64], y: &mut [f64]) {
        quire_gemv_bp_rows(&mut self.q, w_bits, x, y);
    }
}

// ----------------------------------------------------------------------
// Dense-layer epilogues for the transposed serving layout (activations
// as a rows×cols block with one *neuron per row*): row-broadcast bias
// add, optionally fused with ReLU. The ReLU is written as an explicit
// `if v > 0` select — unlike `max`, its treatment of −0.0 and NaN is the
// same on every platform, so backend and scalar-reference outputs stay
// bit-identical.
// ----------------------------------------------------------------------

/// `c[(i,j)] ← relu(c[(i,j)] + bias[i])` over a row-major rows×cols block.
pub fn bias_relu_rows<E: LaneElem>(c: &mut [E], bias: &[E], rows: usize, cols: usize) {
    assert_eq!(c.len(), rows * cols, "bias_relu_rows: shape mismatch");
    assert_eq!(bias.len(), rows, "bias_relu_rows: bias must have one entry per row");
    for i in 0..rows {
        let b = bias[i];
        for v in &mut c[i * cols..(i + 1) * cols] {
            let s = *v + b;
            *v = if s > E::ZERO { s } else { E::ZERO };
        }
    }
}

/// `c[(i,j)] ← c[(i,j)] + bias[i]` over a row-major rows×cols block.
pub fn bias_rows<E: LaneElem>(c: &mut [E], bias: &[E], rows: usize, cols: usize) {
    assert_eq!(c.len(), rows * cols, "bias_rows: shape mismatch");
    assert_eq!(bias.len(), rows, "bias_rows: bias must have one entry per row");
    for i in 0..rows {
        let b = bias[i];
        for v in &mut c[i * cols..(i + 1) * cols] {
            *v += b;
        }
    }
}

/// f64 alias of [`bias_relu_rows`] (kept for the historical name).
pub fn bias_relu_rows_f64(c: &mut [f64], bias: &[f64], rows: usize, cols: usize) {
    bias_relu_rows(c, bias, rows, cols);
}

/// f64 alias of [`bias_rows`] (kept for the historical name).
pub fn bias_rows_f64(c: &mut [f64], bias: &[f64], rows: usize, cols: usize) {
    bias_rows(c, bias, rows, cols);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vector::{codec, codec64};

    #[test]
    fn bias_epilogues_broadcast_per_row() {
        let mut c = vec![1.0f32, -2.0, 3.0, -4.0, 5.0, -6.0]; // 2×3
        bias_rows(&mut c, &[10.0, -10.0], 2, 3);
        assert_eq!(c, vec![11.0, 8.0, 13.0, -14.0, -5.0, -16.0]);
        bias_relu_rows(&mut c, &[0.0, 14.5], 2, 3);
        assert_eq!(c, vec![11.0, 8.0, 13.0, 0.5, 9.5, 0.0]);
        // −0.0 sums select to +0.0 deterministically (explicit compare,
        // not f32::max); the f64 variants share the same contract.
        let mut z = vec![-0.0f32];
        bias_relu_rows(&mut z, &[0.0], 1, 1);
        assert_eq!(z[0].to_bits(), 0.0f32.to_bits());
        let mut c64 = vec![1.0f64, -3.0];
        bias_rows_f64(&mut c64, &[0.5], 1, 2);
        bias_relu_rows_f64(&mut c64, &[0.0], 1, 2);
        assert_eq!(c64, vec![1.5, 0.0]);
    }

    #[test]
    fn quire_dot_recovers_cancelled_term() {
        // 2^24·2^24 is exact; adding 1 then subtracting 2^24·2^24 leaves 1.
        // The rounded f32 path loses the 1 (2^48 + 1 isn't an f32); the
        // quire path keeps it.
        let a = [16777216.0f32, 1.0, -16777216.0];
        let b = [16777216.0f32, 1.0, 16777216.0];
        assert_eq!(dot_f32(&a, &b), 0.0);
        let mut q = QuireDot::new();
        assert_eq!(q.dot_f32(&a, &b), 1.0);
    }

    #[test]
    fn quire_dot_bp32_fused() {
        let a: Vec<u32> =
            [256.0f32, 1.0 / 256.0, -256.0].iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let b: Vec<u32> =
            [256.0f32, 1.0, 256.0].iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let mut q = QuireDot::new();
        let out = q.dot_bp32(&a, &b);
        assert_eq!(codec::bp32_decode_lane(out), 1.0 / 256.0);
    }

    #[test]
    fn gemv_consistent_with_dot() {
        let a: Vec<f32> = (0..20).map(|i| (i as f32 - 10.0) * 0.5).collect();
        let x: Vec<f32> = (0..5).map(|i| 1.0 + i as f32).collect();
        let mut y_fast = vec![0f32; 4];
        gemv_f32(&a, &x, &mut y_fast);
        for r in 0..4 {
            assert_eq!(y_fast[r], dot_f32(&a[r * 5..(r + 1) * 5], &x));
        }
        let mut q = QuireDot::new();
        let mut y_exact = vec![0f32; 4];
        q.gemv_f32(&a, &x, &mut y_exact);
        // Small exact-integer-ish data: both paths agree.
        assert_eq!(y_fast, y_exact);
    }

    #[test]
    fn gemv_bp32_weights_matches_fast_path_on_fovea_data() {
        let w: Vec<f32> = (0..24).map(|i| (i as f32 - 12.0) * 0.25).collect();
        let w_bits: Vec<u32> = w.iter().map(|&x| codec::bp32_encode_lane(x)).collect();
        let x: Vec<f32> = (0..6).map(|i| i as f32 * 0.5 - 1.0).collect();
        let mut q = QuireDot::new();
        let mut y = vec![0f32; 4];
        q.gemv_bp32_weights(&w_bits, &x, &mut y);
        for r in 0..4 {
            let fast = dot_bp32_weights_fast(&w_bits[r * 6..(r + 1) * 6], &x);
            assert_eq!(y[r], fast, "row {r}");
        }
    }

    #[test]
    fn par_gemv_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x9e37);
        let (rows, cols) = (19usize, 23usize);
        let a: Vec<f32> = (0..rows * cols).map(|_| (rng.f64() - 0.5) as f32 * 8.0).collect();
        let x: Vec<f32> = (0..cols).map(|_| (rng.f64() - 0.5) as f32 * 8.0).collect();
        let w_bits: Vec<u32> = a.iter().map(|&v| codec::bp32_encode_lane(v)).collect();
        let mut y_fast = vec![0f32; rows];
        gemv_f32(&a, &x, &mut y_fast);
        let mut q = QuireDot::new();
        let mut y_quire = vec![0f32; rows];
        q.gemv_f32(&a, &x, &mut y_quire);
        let mut y_w = vec![0f32; rows];
        q.gemv_bp32_weights(&w_bits, &x, &mut y_w);
        for t in [1usize, 2, 7] {
            let mut y = vec![0f32; rows];
            par_gemv_f32_with(t, &a, &x, &mut y);
            assert_eq!(y, y_fast, "f32 t={t}");
            par_gemv_quire_f32_with(t, &a, &x, &mut y);
            assert_eq!(y, y_quire, "quire t={t}");
            par_gemv_bp32_weights_with(t, &w_bits, &x, &mut y);
            assert_eq!(y, y_w, "bp32 t={t}");
        }
    }

    #[test]
    fn quire_dot_f64_recovers_cancelled_term() {
        // 2^53·2^53 = 2^106 is exact in the quire; the rounded f64 path
        // loses the +1 (2^106 + 1 isn't an f64), the quire keeps it.
        let big = f64::powi(2.0, 53);
        let a = [big, 1.0, -big];
        let b = [big, 1.0, big];
        assert_eq!(dot_f64(&a, &b), 0.0);
        let mut q = QuireDotF64::new();
        assert_eq!(q.dot_f64(&a, &b), 1.0);
    }

    #[test]
    fn quire_dot_f64_full_range() {
        // Products spanning max-f64 down to subnormal² in one reduction.
        let a = [f64::MAX, f64::from_bits(1), -f64::MAX];
        let b = [f64::MAX, f64::from_bits(1), f64::MAX];
        let mut q = QuireDotF64::new();
        let exact = q.dot_f64(&a, &b);
        // Exact value is 2^-2148, below f64 range: rounds to 0 at readout
        // — but crucially not NaR/Inf (no overflow in the accumulator).
        assert_eq!(exact, 0.0);
        // Without the cancellation the readout saturates cleanly.
        assert_eq!(q.dot_f64(&[f64::MAX, f64::MAX], &[f64::MAX, f64::MAX]), f64::INFINITY);
    }

    #[test]
    fn quire_dot_bp64_fused() {
        let a: Vec<u64> =
            [256.0f64, 1.0 / 256.0, -256.0].iter().map(|&x| codec64::bp64_encode_lane(x)).collect();
        let b: Vec<u64> =
            [256.0f64, 1.0, 256.0].iter().map(|&x| codec64::bp64_encode_lane(x)).collect();
        let mut q = QuireDot::new();
        let out = q.dot_bp64(&a, &b);
        assert_eq!(codec64::bp64_decode_lane(out), 1.0 / 256.0);
    }

    #[test]
    fn gemv_f64_consistent_with_dot_and_weights_fast_path() {
        let a: Vec<f64> = (0..20).map(|i| (i as f64 - 10.0) * 0.5).collect();
        let x: Vec<f64> = (0..5).map(|i| 1.0 + i as f64).collect();
        let mut y_fast = vec![0f64; 4];
        gemv_f64(&a, &x, &mut y_fast);
        for r in 0..4 {
            assert_eq!(y_fast[r], dot_f64(&a[r * 5..(r + 1) * 5], &x));
        }
        let mut q = QuireDotF64::new();
        let mut y_exact = vec![0f64; 4];
        q.gemv_f64(&a, &x, &mut y_exact);
        assert_eq!(y_fast, y_exact, "small exact-integer-ish data: both paths agree");

        let w_bits: Vec<u64> = a.iter().map(|&v| codec64::bp64_encode_lane(v)).collect();
        let mut y_w = vec![0f64; 4];
        q.gemv_bp64_weights(&w_bits, &x, &mut y_w);
        for r in 0..4 {
            let fast = dot_bp64_weights_fast(&w_bits[r * 5..(r + 1) * 5], &x);
            assert_eq!(y_w[r], fast, "row {r}");
        }
    }

    #[test]
    fn par_gemv_f64_bit_identical_to_serial() {
        let mut rng = crate::testutil::Rng::new(0x9e64);
        let (rows, cols) = (19usize, 23usize);
        let a: Vec<f64> = (0..rows * cols).map(|_| (rng.f64() - 0.5) * 8.0).collect();
        let x: Vec<f64> = (0..cols).map(|_| (rng.f64() - 0.5) * 8.0).collect();
        let w_bits: Vec<u64> = a.iter().map(|&v| codec64::bp64_encode_lane(v)).collect();
        let mut y_fast = vec![0f64; rows];
        gemv_f64(&a, &x, &mut y_fast);
        let mut q = QuireDotF64::new();
        let mut y_quire = vec![0f64; rows];
        q.gemv_f64(&a, &x, &mut y_quire);
        let mut y_w = vec![0f64; rows];
        q.gemv_bp64_weights(&w_bits, &x, &mut y_w);
        for t in [1usize, 2, 7] {
            let mut y = vec![0f64; rows];
            par_gemv_f64_with(t, &a, &x, &mut y);
            assert_eq!(y, y_fast, "f64 t={t}");
            par_gemv_quire_f64_with(t, &a, &x, &mut y);
            assert_eq!(y, y_quire, "quire t={t}");
            par_gemv_bp64_weights_with(t, &w_bits, &x, &mut y);
            assert_eq!(y, y_w, "bp64 t={t}");
        }
    }

    #[test]
    fn generic_entry_points_match_named_aliases() {
        // The unified generic names and the historical per-width names
        // are the same monomorphizations.
        let a: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 3.0).collect();
        let x: Vec<f32> = (0..4).map(|i| i as f32 - 1.5).collect();
        assert_eq!(dot(&a[..4], &x), dot_f32(&a[..4], &x));
        let mut y1 = vec![0f32; 3];
        let mut y2 = vec![0f32; 3];
        gemv(&a, &x, &mut y1);
        gemv_f32(&a, &x, &mut y2);
        assert_eq!(y1, y2);
        par_gemv(&a, &x, &mut y1);
        assert_eq!(y1, y2);
        let a64: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let mut z1 = vec![0f64; 3];
        let mut z2 = vec![0f64; 3];
        par_gemv_quire_with(2, &a64, &x64, &mut z1);
        par_gemv_quire_f64_with(2, &a64, &x64, &mut z2);
        assert_eq!(z1, z2);
    }

    #[test]
    fn axpy_f64_paths() {
        let x = [1.0f64, 2.0, 3.0];
        let mut y = [10.0f64, 20.0, 30.0];
        axpy_f64(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
        // Quire axpy fuses the rounding: use a case where two roundings
        // differ from one. 1.0 + 2^-53 + 2^-53 under two roundings stays
        // 1.0 twice; the fused add of (y=1.0, α=2.0, x=2^-53) gives the
        // RNE of 1 + 2^-52 exactly.
        let mut q = QuireDotF64::new();
        let mut y2 = [1.0f64];
        q.axpy_f64(2.0, &[f64::powi(2.0, -53)], &mut y2);
        assert_eq!(y2[0], 1.0 + f64::powi(2.0, -52));

        let alpha = codec64::bp64_encode_lane(2.0);
        let xb: Vec<u64> =
            [3.0f64, -1.5, 0.0].iter().map(|&v| codec64::bp64_encode_lane(v)).collect();
        let mut yb: Vec<u64> =
            [1.0f64, 1.0, 7.0].iter().map(|&v| codec64::bp64_encode_lane(v)).collect();
        let mut qd = QuireDot::new();
        qd.axpy_bp64(alpha, &xb, &mut yb);
        let back: Vec<f64> = yb.iter().map(|&w| codec64::bp64_decode_lane(w)).collect();
        assert_eq!(back, vec![7.0, -2.0, 7.0]);
    }

    #[test]
    fn axpy_paths() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy_f32(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);

        let alpha = codec::bp32_encode_lane(2.0);
        let xb: Vec<u32> =
            [3.0f32, -1.5, 0.0].iter().map(|&v| codec::bp32_encode_lane(v)).collect();
        let mut yb: Vec<u32> =
            [1.0f32, 1.0, 7.0].iter().map(|&v| codec::bp32_encode_lane(v)).collect();
        let mut q = QuireDot::new();
        q.axpy_bp32(alpha, &xb, &mut yb);
        let back: Vec<f32> = yb.iter().map(|&w| codec::bp32_decode_lane(w)).collect();
        assert_eq!(back, vec![7.0, -2.0, 7.0]);
    }
}
