//! Branch-free batched posit-family codecs.
//!
//! The paper's core hardware insight — bounding the regime to `rs` bits
//! turns variable-shift/LZC decode into fixed mux selection — has a direct
//! software analogue: with the regime bounded, every lane of a batch runs
//! the *same* straight-line instruction sequence, so encode/decode over a
//! slice becomes branch-free, mispredict-free, and autovectorizer-friendly.
//! This module is that lane codec: chunked (8-lane) encode/decode for
//! b-posit⟨32,6,5⟩, posit⟨32,2⟩, any ⟨n≤32, rs, 1≤es≤8⟩ spec, and the
//! trivial f32⇄bits pair, over `&[f32]`/`&[u32]` slices with in-place
//! (`_into`) variants for buffer reuse on the serving hot path.
//!
//! ## Contract (identical to the scalar fast path in
//! [`crate::coordinator::quantizer`] and the Pallas kernel)
//! - Encode: f32 subnormal inputs (|x| < 2^−126) quantize to 0 (FTZ/DAZ
//!   end-to-end); NaN/Inf → NaR.
//! - Decode: values below the f32 normal range flush to ±0; above it,
//!   ±∞; NaR → canonical quiet NaN.
//!
//! Verified against the general pattern-space-RNE codec exhaustively for
//! 16-bit formats and by stratified 2^20 sweeps for BP32/P32 (see
//! rust/tests/vector_parity.rs), and bit-identical to the scalar
//! `fast_bp32_*` pair on all inputs.

use crate::formats::posit::PositSpec;

/// Lane width of the chunked loops. 8 × u32 = one AVX2 register; the inner
/// loops carry no cross-lane dependency, so narrower ISAs still profit via
/// unrolled ILP.
pub const LANES: usize = 8;

const F32_NAN_BITS: u32 = 0x7fc0_0000;

/// True when the branch-free 32-bit lane codec supports this spec.
/// Wider specs (32 < n ≤ 64) are served by [`super::codec64`]; the
/// general [`PositSpec`] codec in `formats::posit` covers the rest —
/// see [`super::route_spec`].
pub fn spec_supported(spec: &PositSpec) -> bool {
    (3..=32).contains(&spec.n)
        && spec.rs >= 2
        && spec.rs <= spec.n - 1
        && (1..=8).contains(&spec.es)
}

// ----------------------------------------------------------------------
// Lane primitives: straight-line, no data-dependent branches. The `if`
// expressions below are pure value selects (both arms side-effect free);
// LLVM lowers them to cmov/blend, never to control flow.
// ----------------------------------------------------------------------

/// Encode one f32 into an n-bit posit/b-posit word (see module contract).
#[inline(always)]
fn encode_lane(n: u32, rs: u32, es: u32, x: f32) -> u32 {
    debug_assert!((3..=32).contains(&n) && rs >= 2 && rs <= n - 1 && (1..=8).contains(&es));
    let m = n - 1;
    let mask_n: u32 = if n == 32 { u32::MAX } else { (1u32 << n) - 1 };
    let nar: u32 = 1u32 << m;
    let maxpos: u64 = (1u64 << m) - 1;
    let bounded = rs < m;
    let r_max: i32 = rs as i32 - 1;
    let r_min: i32 = if bounded { -(rs as i32) } else { -(n as i32 - 2) };

    let bits = x.to_bits();
    let sign = bits >> 31;
    let biased = ((bits >> 23) & 0xff) as i32;
    let f23 = (bits & 0x7f_ffff) as u64;
    let is_zero_or_sub = biased == 0; // zero and FTZ'd subnormals
    let is_special = biased == 0xff; // NaN/Inf → NaR
    let t = biased - 127;
    let r = t >> es; // floor(t / 2^es)
    let e = (t & ((1i32 << es) - 1)) as u64; // t mod 2^es, in [0, 2^es)
    let sat_hi = r > r_max;
    let sat_lo = r < r_min;
    let rc = r.clamp(r_min, r_max); // keep shifts in range; sat masks win below
    let run: u32 = if rc >= 0 { (rc + 1) as u32 } else { (-rc) as u32 };
    let capped = run >= rs; // regime hits the bound: no terminator bit
    let w_reg = if capped { rs } else { run + 1 };
    // Regime field value in w_reg bits: a run of ones/zeros plus the
    // terminator when not capped.
    let reg_ones = (1u64 << w_reg) - 1;
    let reg_val: u64 = if rc >= 0 { reg_ones - ((!capped) as u64) } else { (!capped) as u64 };
    // Serialize regime ‖ exponent ‖ fraction MSB-first into a u64 stream
    // (w_reg + es + 23 ≤ 31 + 8 + 23 ≤ 62 bits: shifts never underflow).
    let sh_reg = 64 - w_reg;
    let sh_exp = sh_reg - es;
    let sh_frac = sh_exp - 23;
    let s = (reg_val << sh_reg) | (e << sh_exp) | (f23 << sh_frac);
    // Cut at m bits with round-to-nearest-even: rem+lsb>half ⟺ RNE up.
    let cut = 64 - m; // 33..=61
    let q = s >> cut;
    let rem = s & ((1u64 << cut) - 1);
    let half = 1u64 << (cut - 1);
    let up = (rem + (q & 1) > half) as u64;
    // Carry-out saturates to maxpos (never NaR); a nonzero real never
    // rounds to the zero pattern (min clamp to minpos).
    let body = (q + up).min(maxpos).max(1);
    let body = if sat_hi { maxpos } else { body };
    let body = if sat_lo { 1 } else { body };
    let body32 = body as u32;
    let word = (if sign == 1 { body32.wrapping_neg() } else { body32 }) & mask_n;
    let word = if is_zero_or_sub { 0 } else { word };
    if is_special {
        nar
    } else {
        word
    }
}

/// Decode one n-bit posit/b-posit word to f32 (see module contract).
#[inline(always)]
fn decode_lane(n: u32, rs: u32, es: u32, word: u32) -> f32 {
    debug_assert!((3..=32).contains(&n) && rs >= 2 && rs <= n - 1 && (1..=8).contains(&es));
    let m = n - 1;
    let mask_n: u32 = if n == 32 { u32::MAX } else { (1u32 << n) - 1 };
    let body_mask: u32 = (1u32 << m) - 1;
    let nar: u32 = 1u32 << m;

    let word = word & mask_n;
    let is_zero = word == 0;
    let is_nar = word == nar;
    let sign = (word >> m) & 1;
    let mag = (if sign == 1 { word.wrapping_neg() } else { word }) & body_mask;
    let b0 = (mag >> (m - 1)) & 1;
    // Leading-run length within the m-bit body, capped at rs.
    let probe = (if b0 == 1 { !mag } else { mag }) & body_mask;
    let lz = (probe << (32 - m)).leading_zeros(); // probe == 0 ⇒ 32 ≥ m
    let run = lz.min(m).min(rs);
    let reg_len = run + (run != rs) as u32; // +terminator unless capped
    let r: i32 = if b0 == 1 { run as i32 - 1 } else { -(run as i32) };
    // Align the first post-regime bit to bit 63 of a u64 (the two-step
    // shift keeps the amount ≤ 63 even when reg_len = m). Ghost exponent
    // bits and the empty fraction fall out as zeros automatically.
    let pay = ((mag as u64) << (63 - m + reg_len)) << 1;
    let e = (pay >> (64 - es)) as i32;
    let frac_top = pay << es; // fraction, MSB-aligned at bit 63
    let t = r * (1i32 << es) + e;
    // RNE the (≤ 29-bit) fraction to 23 f32 bits; guard/sticky live in the
    // low 41 bits of frac_top.
    let q = (frac_top >> 41) as u32;
    let rem = frac_top & ((1u64 << 41) - 1);
    let up = (rem + (q & 1) as u64 > (1u64 << 40)) as u32;
    let frac = q + up;
    let tt = t + (frac >> 23) as i32; // rounding carry bumps the scale
    let frac = frac & 0x7f_ffff;
    let underflow = tt < -126; // FTZ contract (keeps the sign)
    let overflow = tt > 127;
    let ttc = tt.clamp(-126, 127);
    let fbits = (sign << 31) | (((ttc + 127) as u32) << 23) | frac;
    let fbits = if underflow { sign << 31 } else { fbits };
    let fbits = if overflow { (sign << 31) | 0x7f80_0000 } else { fbits };
    let fbits = if is_zero { 0 } else { fbits };
    let fbits = if is_nar { F32_NAN_BITS } else { fbits };
    f32::from_bits(fbits)
}

// ----------------------------------------------------------------------
// Chunked slice drivers. The spec parameters are loop-invariant constants
// at every call site below, so each wrapper monomorphizes to a dedicated
// straight-line inner loop.
// ----------------------------------------------------------------------

#[inline(always)]
fn encode_slice(n: u32, rs: u32, es: u32, xs: &[f32], out: &mut [u32]) {
    assert_eq!(xs.len(), out.len(), "encode: input/output length mismatch");
    let split = xs.len() - xs.len() % LANES;
    let (xh, xt) = xs.split_at(split);
    let (oh, ot) = out.split_at_mut(split);
    for (xc, oc) in xh.chunks_exact(LANES).zip(oh.chunks_exact_mut(LANES)) {
        for l in 0..LANES {
            oc[l] = encode_lane(n, rs, es, xc[l]);
        }
    }
    for (x, o) in xt.iter().zip(ot.iter_mut()) {
        *o = encode_lane(n, rs, es, *x);
    }
}

#[inline(always)]
fn decode_slice(n: u32, rs: u32, es: u32, ws: &[u32], out: &mut [f32]) {
    assert_eq!(ws.len(), out.len(), "decode: input/output length mismatch");
    let split = ws.len() - ws.len() % LANES;
    let (wh, wt) = ws.split_at(split);
    let (oh, ot) = out.split_at_mut(split);
    for (wc, oc) in wh.chunks_exact(LANES).zip(oh.chunks_exact_mut(LANES)) {
        for l in 0..LANES {
            oc[l] = decode_lane(n, rs, es, wc[l]);
        }
    }
    for (w, o) in wt.iter().zip(ot.iter_mut()) {
        *o = decode_lane(n, rs, es, *w);
    }
}

// ---------------- b-posit⟨32,6,5⟩ (the serving format) ----------------

/// Encode one f32 → b-posit32 word (branch-free lane form).
#[inline]
pub fn bp32_encode_lane(x: f32) -> u32 {
    encode_lane(32, 6, 5, x)
}

/// Decode one b-posit32 word → f32 (branch-free lane form).
#[inline]
pub fn bp32_decode_lane(w: u32) -> f32 {
    decode_lane(32, 6, 5, w)
}

/// Batched encode into a caller-owned buffer (`out.len() == xs.len()`).
pub fn bp32_encode_into(xs: &[f32], out: &mut [u32]) {
    encode_slice(32, 6, 5, xs, out);
}

/// Batched decode into a caller-owned buffer.
pub fn bp32_decode_into(ws: &[u32], out: &mut [f32]) {
    decode_slice(32, 6, 5, ws, out);
}

/// Allocating batched encode.
pub fn bp32_encode(xs: &[f32]) -> Vec<u32> {
    let mut out = vec![0u32; xs.len()];
    bp32_encode_into(xs, &mut out);
    out
}

/// Allocating batched decode.
pub fn bp32_decode(ws: &[u32]) -> Vec<f32> {
    let mut out = vec![0f32; ws.len()];
    bp32_decode_into(ws, &mut out);
    out
}

/// Fused quantize+dequantize of a buffer in place — what the server does
/// to a batch so the model sees exactly b-posit-representable values.
/// No intermediate word buffer, no allocation.
pub fn bp32_roundtrip_in_place(xs: &mut [f32]) {
    let split = xs.len() - xs.len() % LANES;
    let (head, tail) = xs.split_at_mut(split);
    for c in head.chunks_exact_mut(LANES) {
        for l in 0..LANES {
            c[l] = decode_lane(32, 6, 5, encode_lane(32, 6, 5, c[l]));
        }
    }
    for x in tail.iter_mut() {
        *x = decode_lane(32, 6, 5, encode_lane(32, 6, 5, *x));
    }
}

/// Fused roundtrip into a separate output buffer.
pub fn bp32_roundtrip_into(xs: &[f32], out: &mut [f32]) {
    assert_eq!(xs.len(), out.len(), "roundtrip: input/output length mismatch");
    out.copy_from_slice(xs);
    bp32_roundtrip_in_place(out);
}

// ---------------- posit⟨32,2⟩ (standard-posit comparison) ----------------

/// Encode one f32 → posit⟨32,2⟩ word.
#[inline]
pub fn p32_encode_lane(x: f32) -> u32 {
    encode_lane(32, 31, 2, x)
}

/// Decode one posit⟨32,2⟩ word → f32.
#[inline]
pub fn p32_decode_lane(w: u32) -> f32 {
    decode_lane(32, 31, 2, w)
}

/// Batched posit⟨32,2⟩ encode into a caller-owned buffer.
pub fn p32_encode_into(xs: &[f32], out: &mut [u32]) {
    encode_slice(32, 31, 2, xs, out);
}

/// Batched posit⟨32,2⟩ decode into a caller-owned buffer.
pub fn p32_decode_into(ws: &[u32], out: &mut [f32]) {
    decode_slice(32, 31, 2, ws, out);
}

// ---------------- any supported spec (parity + small formats) ----------------

/// Encode one f32 under any supported spec (see [`spec_supported`]).
pub fn encode_word(spec: &PositSpec, x: f32) -> u32 {
    assert!(spec_supported(spec), "lane codec does not support {spec:?}");
    encode_lane(spec.n, spec.rs, spec.es, x)
}

/// Decode one word under any supported spec.
pub fn decode_word(spec: &PositSpec, w: u32) -> f32 {
    assert!(spec_supported(spec), "lane codec does not support {spec:?}");
    decode_lane(spec.n, spec.rs, spec.es, w)
}

/// Batched encode under any supported spec.
pub fn encode_slice_into(spec: &PositSpec, xs: &[f32], out: &mut [u32]) {
    assert!(spec_supported(spec), "lane codec does not support {spec:?}");
    encode_slice(spec.n, spec.rs, spec.es, xs, out);
}

/// Batched decode under any supported spec.
pub fn decode_slice_into(spec: &PositSpec, ws: &[u32], out: &mut [f32]) {
    assert!(spec_supported(spec), "lane codec does not support {spec:?}");
    decode_slice(spec.n, spec.rs, spec.es, ws, out);
}

// ---------------- f32 ⇄ bits (baseline lane for the bench sweep) ----------------

/// Batched f32 → raw bits (the no-op codec: memcpy-speed upper bound).
pub fn f32_to_bits_into(xs: &[f32], out: &mut [u32]) {
    assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = x.to_bits();
    }
}

/// Batched raw bits → f32.
pub fn bits_to_f32_into(ws: &[u32], out: &mut [f32]) {
    assert_eq!(ws.len(), out.len());
    for (o, &w) in out.iter_mut().zip(ws) {
        *o = f32::from_bits(w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{BP32, P32};

    #[test]
    fn bp32_known_patterns() {
        assert_eq!(bp32_encode_lane(1.0), 0x4000_0000);
        assert_eq!(bp32_encode_lane(-1.0), 0xC000_0000);
        assert_eq!(bp32_decode_lane(0x4000_0000), 1.0);
        assert_eq!(bp32_encode_lane(0.0), 0);
        assert_eq!(bp32_encode_lane(f32::NAN), 0x8000_0000);
        assert_eq!(bp32_encode_lane(f32::INFINITY), 0x8000_0000);
        assert!(bp32_decode_lane(0x8000_0000).is_nan());
        assert_eq!(bp32_decode_lane(0).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn bp32_ftz_contract() {
        // Subnormal f32 inputs flush to the zero pattern.
        let sub = f32::from_bits(1); // 2^-149
        assert_eq!(bp32_encode_lane(sub), 0);
        assert_eq!(bp32_encode_lane(-sub), 0);
        // minpos (2^-192-scale) decodes below the f32 normal range → ±0.
        assert_eq!(bp32_decode_lane(1).to_bits(), 0.0f32.to_bits());
        assert_eq!(bp32_decode_lane(1u32.wrapping_neg()).to_bits(), (-0.0f32).to_bits());
        // maxpos (2^191-scale) overflows f32 → ±inf.
        assert_eq!(bp32_decode_lane(0x7fff_ffff), f32::INFINITY);
        assert_eq!(bp32_decode_lane(0x8000_0001), f32::NEG_INFINITY);
    }

    #[test]
    fn p32_matches_general_codec_on_knowns() {
        for x in [1.0f32, -1.0, 0.5, 3.25, 1e30, -1e-30, 123456.78] {
            assert_eq!(
                p32_encode_lane(x) as u64,
                P32.from_f64(x as f64),
                "p32 encode {x}"
            );
        }
        for w in [0x4000_0000u32, 0xC000_0000, 1, 0x7fff_ffff, 12345] {
            assert_eq!(p32_decode_lane(w), P32.to_f64(w as u64) as f32, "p32 decode {w:#x}");
        }
    }

    #[test]
    fn slice_paths_match_lane_paths() {
        let xs: Vec<f32> = (0..37).map(|i| (i as f32 - 18.0) * 1.73).collect();
        let mut words = vec![0u32; xs.len()];
        bp32_encode_into(&xs, &mut words);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(words[i], bp32_encode_lane(x));
        }
        let mut back = vec![0f32; xs.len()];
        bp32_decode_into(&words, &mut back);
        assert_eq!(back, xs, "fovea values survive the roundtrip exactly");

        let mut rt = xs.clone();
        bp32_roundtrip_in_place(&mut rt);
        assert_eq!(rt, xs);
        let mut rt2 = vec![0f32; xs.len()];
        bp32_roundtrip_into(&xs, &mut rt2);
        assert_eq!(rt2, xs);

        assert_eq!(bp32_encode(&xs), words);
        assert_eq!(bp32_decode(&words), xs);
    }

    #[test]
    fn generic_entry_points_agree_with_specialized() {
        let xs: Vec<f32> = (0..23).map(|i| (i as f32) * 0.37 - 4.0).collect();
        let mut a = vec![0u32; xs.len()];
        let mut b = vec![0u32; xs.len()];
        bp32_encode_into(&xs, &mut a);
        encode_slice_into(&BP32, &xs, &mut b);
        assert_eq!(a, b);
        let mut fa = vec![0f32; xs.len()];
        let mut fb = vec![0f32; xs.len()];
        bp32_decode_into(&a, &mut fa);
        decode_slice_into(&BP32, &a, &mut fb);
        assert_eq!(fa, fb);
        assert!(spec_supported(&BP32) && spec_supported(&P32));
    }

    #[test]
    fn wide_specs_route_to_the_64bit_codec() {
        // Formerly a dead end (`!spec_supported(&P64)` full stop); now the
        // 64-bit lane codec picks up everything this codec rejects for
        // width, and the router proves the dispatch.
        use crate::formats::posit::{BP64, P64};
        use crate::vector::{route_spec, CodecRoute};
        for spec in [P64, BP64, crate::formats::posit::PositSpec::bounded(48, 6, 5)] {
            assert!(!spec_supported(&spec), "{spec:?} is beyond the 32-bit lanes");
            assert!(crate::vector::codec64::spec_supported(&spec));
            assert_eq!(route_spec(&spec), CodecRoute::Lane64, "{spec:?}");
        }
        assert_eq!(route_spec(&BP32), CodecRoute::Lane32);
        assert_eq!(route_spec(&P32), CodecRoute::Lane32);
        // es = 0 stays on the general pattern-space codec.
        let es0 = crate::formats::posit::PositSpec { n: 16, rs: 15, es: 0 };
        assert_eq!(route_spec(&es0), CodecRoute::General);
    }

    #[test]
    fn f32_bits_roundtrip() {
        let xs = [0.0f32, -1.5, 3.25, f32::INFINITY];
        let mut w = [0u32; 4];
        let mut back = [0f32; 4];
        f32_to_bits_into(&xs, &mut w);
        bits_to_f32_into(&w, &mut back);
        assert_eq!(xs, back);
    }
}
