//! 32-bit tier of the branch-free batched posit-family codec: the named
//! BP32/P32 fast paths and the u32/f32 slice drivers, as monomorphized
//! spec constants over the width-generic engine in [`super::lane`].
//!
//! The decode/encode datapath itself lives in `lane.rs` and is written
//! **once** for both widths (the paper's structural-identity claim, as
//! code); this module only pins it to ⟨32,6,5⟩ / ⟨32,2⟩ and keeps the
//! historical entry-point names. See `docs/API.md` for the migration
//! table.
//!
//! ## Contract (identical to the scalar fast path in
//! [`crate::coordinator::quantizer`] and the Pallas kernel)
//! - Encode: f32 subnormal inputs (|x| < 2^−126) quantize to 0 (FTZ/DAZ
//!   end-to-end); NaN/Inf → NaR.
//! - Decode: values below the f32 normal range flush to ±0; above it,
//!   ±∞; NaR → canonical quiet NaN.
//!
//! Verified against the general pattern-space-RNE codec exhaustively for
//! 16-bit formats and by stratified 2^20 sweeps for BP32/P32 (see
//! rust/tests/vector_parity.rs), and bit-identical to the scalar
//! `fast_bp32_*` pair on all inputs.

use super::lane::{self, LaneElem};
use crate::formats::posit::PositSpec;

pub use super::lane::LANES;

/// True when the branch-free 32-bit lane codec supports this spec.
/// Wider specs (32 < n ≤ 64) are served by [`super::codec64`]; the
/// general [`PositSpec`] codec in `formats::posit` covers the rest —
/// see [`super::route_spec`] / [`super::dispatch_spec`].
pub fn spec_supported(spec: &PositSpec) -> bool {
    <f32 as LaneElem>::spec_supported(spec)
}

// ---------------- b-posit⟨32,6,5⟩ (the serving format) ----------------

/// Encode one f32 → b-posit32 word (branch-free lane form).
#[inline]
pub fn bp32_encode_lane(x: f32) -> u32 {
    <f32 as LaneElem>::bp_encode_lane(x)
}

/// Decode one b-posit32 word → f32 (branch-free lane form).
#[inline]
pub fn bp32_decode_lane(w: u32) -> f32 {
    <f32 as LaneElem>::bp_decode_lane(w)
}

/// Batched encode into a caller-owned buffer (`out.len() == xs.len()`).
pub fn bp32_encode_into(xs: &[f32], out: &mut [u32]) {
    lane::bp_encode_into::<f32>(xs, out);
}

/// Batched decode into a caller-owned buffer.
pub fn bp32_decode_into(ws: &[u32], out: &mut [f32]) {
    lane::bp_decode_into::<f32>(ws, out);
}

/// Allocating batched encode.
pub fn bp32_encode(xs: &[f32]) -> Vec<u32> {
    let mut out = vec![0u32; xs.len()];
    bp32_encode_into(xs, &mut out);
    out
}

/// Allocating batched decode.
pub fn bp32_decode(ws: &[u32]) -> Vec<f32> {
    let mut out = vec![0f32; ws.len()];
    bp32_decode_into(ws, &mut out);
    out
}

/// Fused quantize+dequantize of a buffer in place — what the server does
/// to a batch so the model sees exactly b-posit-representable values.
/// No intermediate word buffer, no allocation.
pub fn bp32_roundtrip_in_place(xs: &mut [f32]) {
    lane::bp_roundtrip_in_place::<f32>(xs);
}

/// Fused roundtrip into a separate output buffer.
pub fn bp32_roundtrip_into(xs: &[f32], out: &mut [f32]) {
    assert_eq!(xs.len(), out.len(), "roundtrip: input/output length mismatch");
    out.copy_from_slice(xs);
    bp32_roundtrip_in_place(out);
}

// ---------------- posit⟨32,2⟩ (standard-posit comparison) ----------------

/// Encode one f32 → posit⟨32,2⟩ word.
#[inline]
pub fn p32_encode_lane(x: f32) -> u32 {
    <f32 as LaneElem>::pstd_encode_lane(x)
}

/// Decode one posit⟨32,2⟩ word → f32.
#[inline]
pub fn p32_decode_lane(w: u32) -> f32 {
    <f32 as LaneElem>::pstd_decode_lane(w)
}

/// Batched posit⟨32,2⟩ encode into a caller-owned buffer.
pub fn p32_encode_into(xs: &[f32], out: &mut [u32]) {
    lane::pstd_encode_into::<f32>(xs, out);
}

/// Batched posit⟨32,2⟩ decode into a caller-owned buffer.
pub fn p32_decode_into(ws: &[u32], out: &mut [f32]) {
    lane::pstd_decode_into::<f32>(ws, out);
}

// ---------------- any supported spec (parity + small formats) ----------------

/// Encode one f32 under any supported spec (see [`spec_supported`]).
pub fn encode_word(spec: &PositSpec, x: f32) -> u32 {
    assert!(spec_supported(spec), "lane codec does not support {spec:?}");
    <f32 as LaneElem>::encode_lane(spec.n, spec.rs, spec.es, x)
}

/// Decode one word under any supported spec.
pub fn decode_word(spec: &PositSpec, w: u32) -> f32 {
    assert!(spec_supported(spec), "lane codec does not support {spec:?}");
    <f32 as LaneElem>::decode_lane(spec.n, spec.rs, spec.es, w)
}

/// Batched encode under any supported spec.
pub fn encode_slice_into(spec: &PositSpec, xs: &[f32], out: &mut [u32]) {
    assert!(spec_supported(spec), "lane codec does not support {spec:?}");
    lane::encode_slice::<f32>(spec.n, spec.rs, spec.es, xs, out);
}

/// Batched decode under any supported spec.
pub fn decode_slice_into(spec: &PositSpec, ws: &[u32], out: &mut [f32]) {
    assert!(spec_supported(spec), "lane codec does not support {spec:?}");
    lane::decode_slice::<f32>(spec.n, spec.rs, spec.es, ws, out);
}

// ---------------- f32 ⇄ bits (baseline lane for the bench sweep) ----------------

/// Batched f32 → raw bits (the no-op codec: memcpy-speed upper bound).
pub fn f32_to_bits_into(xs: &[f32], out: &mut [u32]) {
    assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = x.to_bits();
    }
}

/// Batched raw bits → f32.
pub fn bits_to_f32_into(ws: &[u32], out: &mut [f32]) {
    assert_eq!(ws.len(), out.len());
    for (o, &w) in out.iter_mut().zip(ws) {
        *o = f32::from_bits(w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::posit::{BP32, P32};

    #[test]
    fn bp32_known_patterns() {
        assert_eq!(bp32_encode_lane(1.0), 0x4000_0000);
        assert_eq!(bp32_encode_lane(-1.0), 0xC000_0000);
        assert_eq!(bp32_decode_lane(0x4000_0000), 1.0);
        assert_eq!(bp32_encode_lane(0.0), 0);
        assert_eq!(bp32_encode_lane(f32::NAN), 0x8000_0000);
        assert_eq!(bp32_encode_lane(f32::INFINITY), 0x8000_0000);
        assert!(bp32_decode_lane(0x8000_0000).is_nan());
        assert_eq!(bp32_decode_lane(0).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn bp32_ftz_contract() {
        // Subnormal f32 inputs flush to the zero pattern.
        let sub = f32::from_bits(1); // 2^-149
        assert_eq!(bp32_encode_lane(sub), 0);
        assert_eq!(bp32_encode_lane(-sub), 0);
        // minpos (2^-192-scale) decodes below the f32 normal range → ±0.
        assert_eq!(bp32_decode_lane(1).to_bits(), 0.0f32.to_bits());
        assert_eq!(bp32_decode_lane(1u32.wrapping_neg()).to_bits(), (-0.0f32).to_bits());
        // maxpos (2^191-scale) overflows f32 → ±inf.
        assert_eq!(bp32_decode_lane(0x7fff_ffff), f32::INFINITY);
        assert_eq!(bp32_decode_lane(0x8000_0001), f32::NEG_INFINITY);
    }

    #[test]
    fn p32_matches_general_codec_on_knowns() {
        for x in [1.0f32, -1.0, 0.5, 3.25, 1e30, -1e-30, 123456.78] {
            assert_eq!(
                p32_encode_lane(x) as u64,
                P32.from_f64(x as f64),
                "p32 encode {x}"
            );
        }
        for w in [0x4000_0000u32, 0xC000_0000, 1, 0x7fff_ffff, 12345] {
            assert_eq!(p32_decode_lane(w), P32.to_f64(w as u64) as f32, "p32 decode {w:#x}");
        }
    }

    #[test]
    fn slice_paths_match_lane_paths() {
        let xs: Vec<f32> = (0..37).map(|i| (i as f32 - 18.0) * 1.73).collect();
        let mut words = vec![0u32; xs.len()];
        bp32_encode_into(&xs, &mut words);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(words[i], bp32_encode_lane(x));
        }
        let mut back = vec![0f32; xs.len()];
        bp32_decode_into(&words, &mut back);
        assert_eq!(back, xs, "fovea values survive the roundtrip exactly");

        let mut rt = xs.clone();
        bp32_roundtrip_in_place(&mut rt);
        assert_eq!(rt, xs);
        let mut rt2 = vec![0f32; xs.len()];
        bp32_roundtrip_into(&xs, &mut rt2);
        assert_eq!(rt2, xs);

        assert_eq!(bp32_encode(&xs), words);
        assert_eq!(bp32_decode(&words), xs);
    }

    #[test]
    fn generic_entry_points_agree_with_specialized() {
        let xs: Vec<f32> = (0..23).map(|i| (i as f32) * 0.37 - 4.0).collect();
        let mut a = vec![0u32; xs.len()];
        let mut b = vec![0u32; xs.len()];
        bp32_encode_into(&xs, &mut a);
        encode_slice_into(&BP32, &xs, &mut b);
        assert_eq!(a, b);
        let mut fa = vec![0f32; xs.len()];
        let mut fb = vec![0f32; xs.len()];
        bp32_decode_into(&a, &mut fa);
        decode_slice_into(&BP32, &a, &mut fb);
        assert_eq!(fa, fb);
        assert!(spec_supported(&BP32) && spec_supported(&P32));
    }

    #[test]
    fn wide_specs_route_to_the_64bit_codec() {
        // Formerly a dead end (`!spec_supported(&P64)` full stop); now the
        // 64-bit lane codec picks up everything this codec rejects for
        // width, and the router proves the dispatch.
        use crate::formats::posit::{BP64, P64};
        use crate::vector::{route_spec, CodecRoute};
        for spec in [P64, BP64, crate::formats::posit::PositSpec::bounded(48, 6, 5)] {
            assert!(!spec_supported(&spec), "{spec:?} is beyond the 32-bit lanes");
            assert!(crate::vector::codec64::spec_supported(&spec));
            assert_eq!(route_spec(&spec), CodecRoute::Lane64, "{spec:?}");
        }
        assert_eq!(route_spec(&BP32), CodecRoute::Lane32);
        assert_eq!(route_spec(&P32), CodecRoute::Lane32);
        // es = 0 stays on the general pattern-space codec.
        let es0 = crate::formats::posit::PositSpec { n: 16, rs: 15, es: 0 };
        assert_eq!(route_spec(&es0), CodecRoute::General);
    }

    #[test]
    fn f32_bits_roundtrip() {
        let xs = [0.0f32, -1.5, 3.25, f32::INFINITY];
        let mut w = [0u32; 4];
        let mut back = [0f32; 4];
        f32_to_bits_into(&xs, &mut w);
        bits_to_f32_into(&w, &mut back);
        assert_eq!(xs, back);
    }
}
