//! Minimal JSON parser (recursive descent) — the vendored dependency set
//! has no serde_json, and the runtime only needs to read the build-time
//! artifacts (weights.json / manifest.json / vectors.json).

use std::collections::BTreeMap;

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Array of numbers as f32 (tolerates integer notation).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.as_arr()?.iter().filter_map(|v| v.as_f64()).map(|x| x as f32).collect())
    }

    /// Array of numbers as i64 (for bit patterns stored as integers).
    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        Some(self.as_arr()?.iter().filter_map(|v| v.as_f64()).map(|x| x as i64).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", e as char)),
                    }
                }
                Some(_) => {
                    // Copy a UTF-8 run.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    let run = std::str::from_utf8(&self.b[start..self.i]);
                    out.push_str(run.map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap().as_str(), Some("hi\n"));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, 3], "b": {"c": "x", "d": [true, null]}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(j.get("b").unwrap().get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap().as_str(), Some("Aé"));
    }

    #[test]
    fn big_array_roundtrip() {
        let src = format!("[{}]", (0..10000).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let j = Json::parse(&src).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 10000);
        assert_eq!(j.as_i64_vec().unwrap()[9999], 9999);
    }
}
