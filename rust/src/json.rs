//! Minimal JSON parser (recursive descent) — the vendored dependency set
//! has no serde_json. Besides the build-time artifacts (weights.json /
//! manifest.json / vectors.json) it parses **untrusted HTTP request
//! bodies**, so it must be total: any byte sequence returns `Ok` or
//! `Err`, never panics, and recursion is capped at [`MAX_DEPTH`] (a
//! 4 MiB body of `[` would otherwise overflow the stack and abort the
//! single-threaded event loop — a remote DoS). The corpus test in
//! `tests/json_corpus.rs` enforces the no-panic contract.

use std::collections::BTreeMap;

/// Maximum nesting depth (every array/object/scalar level counts one).
/// Deep enough for any artifact or API body the crate emits; shallow
/// enough that the recursive-descent parser cannot approach stack
/// exhaustion on hostile input. Exceeding it is a parse error (mapped to
/// the typed 400 `bad_request` body by the HTTP layer).
pub const MAX_DEPTH: usize = 64;

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Array of numbers as f32 (tolerates integer notation).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.as_arr()?.iter().filter_map(|v| v.as_f64()).map(|x| x as f32).collect())
    }

    /// Array of numbers as f64, full precision (the 64-bit activation
    /// tiers stage these losslessly).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        Some(self.as_arr()?.iter().filter_map(|v| v.as_f64()).collect())
    }

    /// Array of numbers as i64 (for bit patterns stored as integers).
    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        Some(self.as_arr()?.iter().filter_map(|v| v.as_f64()).map(|x| x as i64).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        if self.depth >= MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        self.depth += 1;
        let v = self.value_inner();
        self.depth -= 1;
        v
    }

    fn value_inner(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    /// Four bounds-checked hex digits of a `\u` escape (strict: exactly
    /// `[0-9a-fA-F]{4}`, no sign or whitespace). Total: every non-hex or
    /// truncated quad is a typed error, never a panic.
    fn hex4(&mut self) -> Result<u32, String> {
        let Some(quad) = self.b.get(self.i..self.i + 4) else {
            return Err(format!("truncated \\u escape at byte {}", self.i));
        };
        let mut code = 0u32;
        for &c in quad {
            let digit = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => return Err(format!("bad \\u escape at byte {}", self.i)),
            };
            code = code * 16 + u32::from(digit);
        }
        self.i += 4;
        Ok(code)
    }

    /// Decode a `\u` escape starting after the `u`, combining UTF-16
    /// surrogate pairs (high `D834` + low `DD1E` → 𝄞). Unpaired
    /// surrogates become U+FFFD without consuming the following escape.
    fn unicode_escape(&mut self) -> Result<char, String> {
        let code = self.hex4()?;
        if (0xD800..=0xDBFF).contains(&code) {
            // High surrogate: needs a following \uDC00..=\uDFFF.
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                let save = self.i;
                self.i += 2;
                let lo = self.hex4()?;
                if (0xDC00..=0xDFFF).contains(&lo) {
                    let c = 0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                    return Ok(char::from_u32(c).unwrap_or('\u{fffd}'));
                }
                // Not a low surrogate: rewind so it parses on its own.
                self.i = save;
            }
            return Ok('\u{fffd}');
        }
        if (0xDC00..=0xDFFF).contains(&code) {
            return Ok('\u{fffd}'); // lone low surrogate
        }
        Ok(char::from_u32(code).unwrap_or('\u{fffd}'))
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b.get(self.i..self.i + word.len()) == Some(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        // The matched bytes are all ASCII, so UTF-8 conversion cannot
        // fail — but stay total and answer a typed error regardless.
        let s = std::str::from_utf8(self.b.get(start..self.i).unwrap_or_default())
            .map_err(|_| format!("bad number at byte {start}"))?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => out.push(self.unicode_escape()?),
                        _ => return Err(format!("bad escape \\{}", e as char)),
                    }
                }
                Some(_) => {
                    // Copy a UTF-8 run.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    let run = std::str::from_utf8(self.b.get(start..self.i).unwrap_or_default());
                    out.push_str(run.map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap().as_str(), Some("hi\n"));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, 3], "b": {"c": "x", "d": [true, null]}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(j.get("b").unwrap().get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap().as_str(), Some("Aé"));
    }

    #[test]
    fn depth_cap_is_exact() {
        // MAX_DEPTH nested arrays = depth MAX_DEPTH: parses.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1));
        assert!(Json::parse(&ok).is_ok());
        // One deeper: typed error, no crash.
        let deep = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&deep).unwrap_err().contains("nesting"));
        // A hostile megabyte of '[' errors out instead of blowing the stack.
        assert!(Json::parse(&"[".repeat(1 << 20)).is_err());
    }

    #[test]
    fn truncated_unicode_escape_is_an_error() {
        for src in ["\"\\u12", "\"\\u", "\"\\u123\"", "\"\\u+123\"", "\"\\u12g4\""] {
            assert!(Json::parse(src).is_err(), "{src:?}");
        }
    }

    #[test]
    fn surrogate_pairs_combine() {
        // U+1D11E MUSICAL SYMBOL G CLEF via its UTF-16 pair.
        let j = Json::parse("\"\\uD834\\uDD1E\"").unwrap();
        assert_eq!(j.as_str(), Some("\u{1D11E}"));
        // Lone high / lone low / high followed by a non-surrogate escape:
        // U+FFFD, and the follower is kept.
        assert_eq!(Json::parse("\"\\uD834\"").unwrap().as_str(), Some("\u{fffd}"));
        assert_eq!(Json::parse("\"\\uDD1E\"").unwrap().as_str(), Some("\u{fffd}"));
        assert_eq!(Json::parse("\"\\uD834\\u0041\"").unwrap().as_str(), Some("\u{fffd}A"));
        // High surrogate then a truncated escape is still a clean error.
        assert!(Json::parse("\"\\uD834\\u12").is_err());
    }

    #[test]
    fn big_array_roundtrip() {
        let src = format!("[{}]", (0..10000).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let j = Json::parse(&src).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 10000);
        assert_eq!(j.as_i64_vec().unwrap()[9999], 9999);
    }
}
