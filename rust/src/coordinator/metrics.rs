//! Serving metrics: bounded latency reservoir + counters, cheap enough
//! for the request path. Quantize/dequantize (codec) time and model
//! execute time are tracked separately so `/metrics` output attributes
//! batch cost to the right stage.
//!
//! Latency quantiles come from **reservoir sampling** (Algorithm R with
//! a deterministic in-struct LCG — no `rand` dependency): once the
//! reservoir is full, sample *i* replaces a uniformly chosen slot with
//! probability `CAP/i`, so the reservoir stays a uniform sample of the
//! whole run. The previous implementation cleared the buffer at 1M
//! samples, silently resetting p50/p99/max mid-run; `max_us` is now a
//! separate monotone counter that never resets.
//!
//! Alongside the reservoir quantiles, four power-of-2 log-bucketed
//! [`LogHistogram`]s (end-to-end latency, queue wait, per-batch codec
//! and execute time) record wait-free on the hot path and render in
//! Prometheus `_bucket`/`_sum`/`_count` form — so scrapers get real
//! distribution shape, not just a sampled quantile triple. HTTP
//! connection/response counters live here too so the listener stays a
//! thin I/O layer. Every exported name is catalogued in
//! `docs/OBSERVABILITY.md`; an in-crate test and `tools/check_metrics_docs.py`
//! keep that catalogue from drifting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::trace::{HistSnapshot, LogHistogram};

/// Latency reservoir capacity: 64Ki samples ≈ 512 KiB, a uniform sample
/// of the full run regardless of its length.
pub const LATENCY_RESERVOIR_CAP: usize = 65_536;

/// Bounded uniform sample of every recorded latency (Algorithm R).
struct Reservoir {
    samples: Vec<u64>,
    /// Total samples ever offered (monotone).
    seen: u64,
    /// Deterministic LCG state for replacement-slot selection.
    lcg: u64,
}

/// Process-wide reservoir counter: each reservoir derives its LCG seed
/// from the next counter value, so two servers in one process (the
/// weight-cache integration test runs several) never sample identical
/// slot sequences.
static RESERVOIR_SEQ: AtomicU64 = AtomicU64::new(0);

impl Default for Reservoir {
    fn default() -> Self {
        let n = RESERVOIR_SEQ.fetch_add(1, Ordering::Relaxed);
        // Weyl-style spread of the sequence number over the golden-ratio
        // constant keeps consecutive seeds far apart in state space.
        Reservoir::with_seed(0x9e3779b97f4a7c15u64.wrapping_mul(n.wrapping_add(1)))
    }
}

impl Reservoir {
    /// Deterministic constructor for tests: a fixed seed reproduces the
    /// exact replacement sequence.
    fn with_seed(seed: u64) -> Reservoir {
        Reservoir { samples: Vec::new(), seen: 0, lcg: seed }
    }

    fn record(&mut self, v: u64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(v);
            return;
        }
        // Uniform j ∈ [0, seen): keep v iff j lands inside the reservoir.
        // Full-width Lemire reduction (lcg·seen ≫ 64), not a shifted
        // modulus — a 31-bit index would freeze the keep-probability at
        // CAP/2³¹ once `seen` passes 2³¹ and bias the sample recent.
        self.lcg = self.lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let j = ((self.lcg as u128 * self.seen as u128) >> 64) as u64;
        if (j as usize) < LATENCY_RESERVOIR_CAP {
            self.samples[j as usize] = v;
        }
    }
}

/// Shared metrics sink.
#[derive(Default)]
pub struct Metrics {
    requests: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    rejected: AtomicU64,
    /// Requests answered with a deadline error instead of a batch slot.
    deadline_expired: AtomicU64,
    /// Batches whose execution failed (every member got an error reply).
    batch_failures: AtomicU64,
    /// Total nanoseconds spent in the b-posit codec (quantize/dequantize).
    codec_ns: AtomicU64,
    /// Total nanoseconds spent executing the model.
    execute_ns: AtomicU64,
    /// Worker threads available to the sharded codec (0 = not reported).
    codec_threads: AtomicU64,
    /// Largest latency ever recorded — monotone, survives reservoir
    /// replacement.
    max_us: AtomicU64,
    latencies_us: Mutex<Reservoir>,
    /// Total nanoseconds copying rows into the staged batch + transposing
    /// into tier layout (the `Staging` trace stage, summed over batches).
    staging_ns: AtomicU64,
    /// Total nanoseconds transposing logits back request-major (the
    /// `Readout` trace stage, summed over batches).
    readout_ns: AtomicU64,
    /// Summed per-thread nanoseconds inside the sharded input codec —
    /// CPU cost, which exceeds the wall-clock `codec_ns` when shards run
    /// in parallel.
    codec_worker_ns: AtomicU64,
    /// HTTP connections ever accepted (monotone).
    http_connections: AtomicU64,
    /// HTTP connections currently open (gauge; open/close calls pair).
    http_active: AtomicU64,
    /// Responses by status class, `[1xx, 2xx, 3xx, 4xx, 5xx]`.
    http_responses: [AtomicU64; 5],
    /// Requests shed by admission control (fast 503 before body parse).
    http_shed: AtomicU64,
    /// Requests cancelled after batch assembly because their deadline
    /// expired while queued (distinct from `deadline_expired`, which
    /// counts pre-batch admission rejections).
    cancelled: AtomicU64,
    /// Open connections by state, `[idle, reading, inflight, writing]` —
    /// a partition of `http_active` recomputed by the event loop.
    conn_states: [AtomicU64; 4],
    /// Requests served per keep-alive connection (recorded at close).
    hist_keepalive: LogHistogram,
    /// End-to-end request latency distribution (µs buckets).
    hist_latency_us: LogHistogram,
    /// Submission → batch-seal wait distribution (µs buckets).
    hist_queue_us: LogHistogram,
    /// Per-batch input-codec wall time distribution (ns buckets).
    hist_codec_ns: LogHistogram,
    /// Per-batch execute wall time distribution (ns buckets).
    hist_execute_ns: LogHistogram,
    /// Requests certified through the interval twin (monotone).
    certified_requests: AtomicU64,
    /// Certified requests whose served logits fell OUTSIDE their
    /// certified bounds. Must stay 0 — CI gates on it.
    certify_violations: AtomicU64,
    /// Per-certified-request max bound width (femtounits: 1 = 1e-15 in
    /// logit units).
    hist_certify_max_fm: LogHistogram,
    /// Per-certified-request mean bound width (femtounits).
    hist_certify_mean_fm: LogHistogram,
}

/// Convert a certified bound width to histogram femtounits (1e-15 of a
/// logit unit): small enough that sub-quantization-noise widths still
/// land in distinct power-of-2 buckets, while +∞ (poisoned bounds)
/// saturates into the +Inf bucket.
fn width_femtos(w: f64) -> u64 {
    if !(w >= 0.0) {
        return u64::MAX; // NaN-defensive: fail into the +Inf bucket
    }
    let f = w * 1e15;
    if f >= u64::MAX as f64 {
        u64::MAX
    } else {
        f as u64
    }
}

/// Point-in-time view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    pub deadline_expired: u64,
    pub batch_failures: u64,
    /// Mean items per executed batch.
    pub mean_batch: f64,
    /// Total latencies ever recorded (the reservoir holds a uniform
    /// sample of them, capped at [`LATENCY_RESERVOIR_CAP`]).
    pub latency_samples: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Total codec (quantize/dequantize) nanoseconds across all batches.
    pub codec_ns: u64,
    /// Total model-execute nanoseconds across all batches.
    pub execute_ns: u64,
    /// Worker threads available to the sharded codec (0 = not reported).
    pub codec_threads: u64,
    /// Quantized-weight cache hits since process start (process-wide —
    /// the cache is shared by every server; monotone).
    pub weight_cache_hits: u64,
    /// Quantized-weight cache misses since process start (process-wide;
    /// monotone — a miss is the one-time encode/transpose of a tensor).
    pub weight_cache_misses: u64,
    /// Total staging (row copy + transpose-in) nanoseconds across batches.
    pub staging_ns: u64,
    /// Total readout (transpose-out) nanoseconds across batches.
    pub readout_ns: u64,
    /// Summed per-thread codec worker nanoseconds (CPU, not wall).
    pub codec_worker_ns: u64,
    /// HTTP connections ever accepted.
    pub http_connections: u64,
    /// HTTP connections open at snapshot time.
    pub http_active: u64,
    /// HTTP responses by status class, `[1xx, 2xx, 3xx, 4xx, 5xx]`.
    pub http_responses: [u64; 5],
    /// Requests shed by admission control (fast 503, pre-parse).
    pub http_shed: u64,
    /// Requests cancelled post-assembly because their deadline expired
    /// while queued.
    pub cancelled: u64,
    /// Open connections by state, `[idle, reading, inflight, writing]`.
    pub conn_states: [u64; 4],
    /// Requests-per-connection histogram (keep-alive reuse).
    pub hist_keepalive: HistSnapshot,
    /// End-to-end latency histogram (µs buckets).
    pub hist_latency_us: HistSnapshot,
    /// Queue-wait histogram (µs buckets).
    pub hist_queue_us: HistSnapshot,
    /// Per-batch codec wall-time histogram (ns buckets).
    pub hist_codec_ns: HistSnapshot,
    /// Per-batch execute wall-time histogram (ns buckets).
    pub hist_execute_ns: HistSnapshot,
    /// Requests certified through the interval twin.
    pub certified_requests: u64,
    /// Certified requests whose served logits escaped their bounds
    /// (must be 0).
    pub certify_violations: u64,
    /// Max certified bound width per certified request (femtounit
    /// buckets).
    pub hist_certify_max_fm: HistSnapshot,
    /// Mean certified bound width per certified request (femtounit
    /// buckets).
    pub hist_certify_mean_fm: HistSnapshot,
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch_failure(&self) {
        self.batch_failures.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Add one batch's codec (quantize/dequantize) wall time.
    pub fn record_codec(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.codec_ns.fetch_add(ns, Ordering::Relaxed);
        self.hist_codec_ns.record(ns);
    }

    /// Add one batch's model-execute wall time.
    pub fn record_execute(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.execute_ns.fetch_add(ns, Ordering::Relaxed);
        self.hist_execute_ns.record(ns);
    }

    /// Record one request's submission → batch-seal wait.
    pub fn record_queue_wait(&self, d: Duration) {
        self.hist_queue_us.record(d.as_micros() as u64);
    }

    /// Add one batch's staging (copy + transpose-in) and readout
    /// (transpose-out) nanoseconds, measured by the worker's stage timer.
    pub fn record_batch_stages(&self, staging_ns: u64, readout_ns: u64) {
        self.staging_ns.fetch_add(staging_ns, Ordering::Relaxed);
        self.readout_ns.fetch_add(readout_ns, Ordering::Relaxed);
    }

    /// Add one batch's summed per-thread codec worker nanoseconds.
    pub fn record_codec_worker(&self, ns: u64) {
        self.codec_worker_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Count an accepted HTTP connection (pairs with
    /// [`Metrics::record_http_conn_close`]).
    pub fn record_http_conn_open(&self) {
        self.http_connections.fetch_add(1, Ordering::Relaxed);
        self.http_active.fetch_add(1, Ordering::Relaxed);
    }

    /// Mark an HTTP connection closed.
    pub fn record_http_conn_close(&self) {
        self.http_active.fetch_sub(1, Ordering::Relaxed);
    }

    /// Count one HTTP response by status class (`2xx`, `4xx`, …).
    pub fn record_http_response(&self, status: u16) {
        let class = (status / 100).clamp(1, 5) as usize - 1;
        self.http_responses[class].fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request shed by admission control (fast 503 issued
    /// before the request body was parsed).
    pub fn record_http_shed(&self) {
        self.http_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request cancelled after batch assembly (its deadline
    /// expired between admission and execution).
    pub fn record_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish the event loop's connection-state partition
    /// (`[idle, reading, inflight, writing]` — gauges, not counters).
    pub fn set_conn_states(&self, states: [u64; 4]) {
        for (slot, v) in self.conn_states.iter().zip(states) {
            slot.store(v, Ordering::Relaxed);
        }
    }

    /// Record how many requests one connection served before closing
    /// (the keep-alive reuse distribution).
    pub fn record_keepalive_requests(&self, served: u64) {
        self.hist_keepalive.record(served);
    }

    /// Record the worker-thread count the sharded codec runs with (set
    /// once at server startup; a gauge, not a counter).
    pub fn set_codec_threads(&self, threads: usize) {
        self.codec_threads.store(threads as u64, Ordering::Relaxed);
    }

    /// Record one certified request: its max/mean certified bound widths
    /// (in logit units; converted to femtounit buckets) and whether the
    /// served logits escaped their bounds (a violation — never expected).
    pub fn record_certified(&self, max_width: f64, mean_width: f64, violation: bool) {
        self.certified_requests.fetch_add(1, Ordering::Relaxed);
        if violation {
            self.certify_violations.fetch_add(1, Ordering::Relaxed);
        }
        self.hist_certify_max_fm.record(width_femtos(max_width));
        self.hist_certify_mean_fm.record(width_femtos(mean_width));
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.max_us.fetch_max(us, Ordering::Relaxed);
        self.hist_latency_us.record(us);
        self.latencies_us.lock().unwrap().record(us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        // Snapshot the samples out of the lock; quantiles come from
        // `select_nth_unstable` (O(n) per quantile) instead of a full
        // sort of the 64Ki reservoir, so a scrape never holds the
        // request-path mutex for longer than one memcpy.
        let (mut lats, seen) = {
            let r = self.latencies_us.lock().unwrap();
            (r.samples.clone(), r.seen)
        };
        let p50 = quantile(&mut lats, 0.5);
        let p99 = quantile(&mut lats, 0.99);
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        let (weight_cache_hits, weight_cache_misses) = super::quantizer::weight_cache_stats();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches,
            rejected: self.rejected.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            batch_failures: self.batch_failures.load(Ordering::Relaxed),
            mean_batch: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            latency_samples: seen,
            p50_us: p50,
            p99_us: p99,
            max_us: self.max_us.load(Ordering::Relaxed),
            codec_ns: self.codec_ns.load(Ordering::Relaxed),
            execute_ns: self.execute_ns.load(Ordering::Relaxed),
            codec_threads: self.codec_threads.load(Ordering::Relaxed),
            weight_cache_hits,
            weight_cache_misses,
            staging_ns: self.staging_ns.load(Ordering::Relaxed),
            readout_ns: self.readout_ns.load(Ordering::Relaxed),
            codec_worker_ns: self.codec_worker_ns.load(Ordering::Relaxed),
            http_connections: self.http_connections.load(Ordering::Relaxed),
            http_active: self.http_active.load(Ordering::Relaxed),
            http_responses: std::array::from_fn(|i| self.http_responses[i].load(Ordering::Relaxed)),
            http_shed: self.http_shed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            conn_states: std::array::from_fn(|i| self.conn_states[i].load(Ordering::Relaxed)),
            hist_keepalive: self.hist_keepalive.snapshot(),
            hist_latency_us: self.hist_latency_us.snapshot(),
            hist_queue_us: self.hist_queue_us.snapshot(),
            hist_codec_ns: self.hist_codec_ns.snapshot(),
            hist_execute_ns: self.hist_execute_ns.snapshot(),
            certified_requests: self.certified_requests.load(Ordering::Relaxed),
            certify_violations: self.certify_violations.load(Ordering::Relaxed),
            hist_certify_max_fm: self.hist_certify_max_fm.snapshot(),
            hist_certify_mean_fm: self.hist_certify_mean_fm.snapshot(),
        }
    }
}

/// Index quantile over an unsorted sample via `select_nth_unstable`:
/// O(n) per call and no full sort, which matters at the 64Ki reservoir
/// cap on every `/metrics` scrape.
fn quantile(lats: &mut [u64], p: f64) -> u64 {
    if lats.is_empty() {
        return 0;
    }
    let idx = ((lats.len() - 1) as f64 * p) as usize;
    *lats.select_nth_unstable(idx).1
}

impl MetricsSnapshot {
    /// Mean codec nanoseconds per executed batch.
    pub fn codec_ns_per_batch(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.codec_ns as f64 / self.batches as f64 }
    }

    /// Mean execute nanoseconds per executed batch.
    pub fn execute_ns_per_batch(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.execute_ns as f64 / self.batches as f64 }
    }

    /// Render in a Prometheus-style text format — the body served by the
    /// HTTP listener's `GET /metrics`, with codec time attributed
    /// separately from execute time.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("positron_requests_total {}\n", self.requests));
        s.push_str(&format!("positron_rejected_total {}\n", self.rejected));
        s.push_str(&format!("positron_deadline_expired_total {}\n", self.deadline_expired));
        s.push_str(&format!("positron_batch_failures_total {}\n", self.batch_failures));
        s.push_str(&format!("positron_batches_total {}\n", self.batches));
        s.push_str(&format!("positron_batch_mean_items {:.3}\n", self.mean_batch));
        s.push_str(&format!("positron_latency_samples_total {}\n", self.latency_samples));
        s.push_str(&format!("positron_latency_p50_us {}\n", self.p50_us));
        s.push_str(&format!("positron_latency_p99_us {}\n", self.p99_us));
        s.push_str(&format!("positron_latency_max_us {}\n", self.max_us));
        s.push_str(&format!("positron_codec_threads {}\n", self.codec_threads));
        s.push_str(&format!("positron_codec_ns_total {}\n", self.codec_ns));
        s.push_str(&format!("positron_codec_ns_per_batch {:.0}\n", self.codec_ns_per_batch()));
        s.push_str(&format!("positron_execute_ns_total {}\n", self.execute_ns));
        s.push_str(&format!("positron_execute_ns_per_batch {:.0}\n", self.execute_ns_per_batch()));
        s.push_str(&format!("positron_weight_cache_hits_total {}\n", self.weight_cache_hits));
        s.push_str(&format!("positron_weight_cache_misses_total {}\n", self.weight_cache_misses));
        s.push_str(&format!("positron_staging_ns_total {}\n", self.staging_ns));
        s.push_str(&format!("positron_readout_ns_total {}\n", self.readout_ns));
        s.push_str(&format!("positron_codec_worker_ns_total {}\n", self.codec_worker_ns));
        s.push_str(&format!("positron_http_connections_total {}\n", self.http_connections));
        s.push_str(&format!("positron_http_connections_active {}\n", self.http_active));
        for (i, class) in ["1xx", "2xx", "3xx", "4xx", "5xx"].iter().enumerate() {
            s.push_str(&format!(
                "positron_http_responses_total{{class=\"{class}\"}} {}\n",
                self.http_responses[i]
            ));
        }
        s.push_str(&format!("positron_http_shed_total {}\n", self.http_shed));
        s.push_str(&format!("positron_cancelled_total {}\n", self.cancelled));
        for (i, state) in ["idle", "reading", "inflight", "writing"].iter().enumerate() {
            s.push_str(&format!(
                "positron_http_conn_state{{state=\"{state}\"}} {}\n",
                self.conn_states[i]
            ));
        }
        s.push_str(&format!("positron_certified_requests_total {}\n", self.certified_requests));
        s.push_str(&format!("positron_certify_violations_total {}\n", self.certify_violations));
        self.hist_keepalive.render_into(&mut s, "positron_keepalive_requests");
        self.hist_latency_us.render_into(&mut s, "positron_request_latency_us");
        self.hist_queue_us.render_into(&mut s, "positron_queue_wait_us");
        self.hist_codec_ns.render_into(&mut s, "positron_codec_batch_ns");
        self.hist_execute_ns.render_into(&mut s, "positron_execute_batch_ns");
        self.hist_certify_max_fm.render_into(&mut s, "positron_certify_bound_max_fm");
        self.hist_certify_mean_fm.render_into(&mut s, "positron_certify_bound_mean_fm");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_quantiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(Duration::from_micros(i));
            m.record_request();
        }
        m.record_batch(10);
        m.record_batch(20);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch, 15.0);
        assert_eq!(s.latency_samples, 100);
        assert!(s.p50_us >= 45 && s.p50_us <= 55, "p50 = {}", s.p50_us);
        assert!(s.p99_us >= 95, "p99 = {}", s.p99_us);
        assert_eq!(s.max_us, 100);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.latency_samples, 0);
        assert_eq!(s.deadline_expired, 0);
        assert_eq!(s.batch_failures, 0);
        assert_eq!(s.codec_ns, 0);
        assert_eq!(s.execute_ns, 0);
        assert_eq!(s.codec_threads, 0);
        assert_eq!(s.codec_ns_per_batch(), 0.0);
    }

    #[test]
    fn reservoir_is_bounded_and_max_never_resets() {
        // The bugfix contract: pushing far past the cap must keep memory
        // bounded, keep quantiles meaningful, and never lose the max.
        let m = Metrics::default();
        m.record_latency(Duration::from_micros(999_999)); // early spike
        for _ in 0..(3 * LATENCY_RESERVOIR_CAP) {
            m.record_latency(Duration::from_micros(10));
        }
        {
            let r = m.latencies_us.lock().unwrap();
            assert_eq!(r.samples.len(), LATENCY_RESERVOIR_CAP, "reservoir grew past cap");
            assert_eq!(r.seen, 3 * LATENCY_RESERVOIR_CAP as u64 + 1);
        }
        let s = m.snapshot();
        assert_eq!(s.latency_samples, 3 * LATENCY_RESERVOIR_CAP as u64 + 1);
        assert_eq!(s.max_us, 999_999, "max_us must survive reservoir replacement");
        assert_eq!(s.p50_us, 10, "uniform sample dominated by the steady value");
        let text = s.render();
        assert!(text.contains("positron_latency_max_us 999999"), "{text}");
        assert!(text.contains("positron_latency_samples_total"), "{text}");
    }

    #[test]
    fn failure_counters_render() {
        let m = Metrics::default();
        m.record_deadline_expired();
        m.record_deadline_expired();
        m.record_batch_failure();
        let s = m.snapshot();
        assert_eq!(s.deadline_expired, 2);
        assert_eq!(s.batch_failures, 1);
        let text = s.render();
        assert!(text.contains("positron_deadline_expired_total 2"), "{text}");
        assert!(text.contains("positron_batch_failures_total 1"), "{text}");
    }

    #[test]
    fn weight_cache_counters_render() {
        // The counters are process-wide (shared with every concurrently
        // running test), so assert presence + monotone lower bound, not
        // exact values.
        let (h0, m0) = super::super::quantizer::weight_cache_stats();
        let s = Metrics::default().snapshot();
        assert!(s.weight_cache_hits >= h0 && s.weight_cache_misses >= m0);
        let text = s.render();
        assert!(text.contains("positron_weight_cache_hits_total "), "{text}");
        assert!(text.contains("positron_weight_cache_misses_total "), "{text}");
    }

    #[test]
    fn reservoir_seeds_are_decorrelated_but_seedable() {
        // Two reservoirs created in one process must not replay the same
        // replacement sequence (the old hard-coded seed did exactly
        // that), while an explicit seed stays fully deterministic.
        let a = Reservoir::default();
        let b = Reservoir::default();
        assert_ne!(a.lcg, b.lcg, "process-wide counter must decorrelate default seeds");
        let mut c = Reservoir::with_seed(42);
        let mut d = Reservoir::with_seed(42);
        for v in 0..(LATENCY_RESERVOIR_CAP as u64 + 1_000) {
            c.record(v);
            d.record(v);
        }
        assert_eq!(c.samples, d.samples, "seeded reservoirs must replay identically");
        assert_eq!(c.lcg, d.lcg);
    }

    #[test]
    fn http_counters_render_by_class() {
        let m = Metrics::default();
        m.record_http_conn_open();
        m.record_http_conn_open();
        m.record_http_conn_close();
        m.record_http_response(200);
        m.record_http_response(204);
        m.record_http_response(404);
        m.record_http_response(503);
        let s = m.snapshot();
        assert_eq!(s.http_connections, 2);
        assert_eq!(s.http_active, 1);
        assert_eq!(s.http_responses, [0, 2, 0, 1, 1]);
        let text = s.render();
        assert!(text.contains("positron_http_connections_total 2"), "{text}");
        assert!(text.contains("positron_http_connections_active 1"), "{text}");
        assert!(text.contains("positron_http_responses_total{class=\"2xx\"} 2"), "{text}");
        assert!(text.contains("positron_http_responses_total{class=\"4xx\"} 1"), "{text}");
        assert!(text.contains("positron_http_responses_total{class=\"5xx\"} 1"), "{text}");
    }

    #[test]
    fn shed_cancel_and_conn_state_families_render() {
        let m = Metrics::default();
        m.record_http_shed();
        m.record_http_shed();
        m.record_cancelled();
        m.set_conn_states([5, 1, 2, 0]);
        m.record_keepalive_requests(8);
        m.record_keepalive_requests(1);
        let s = m.snapshot();
        assert_eq!(s.http_shed, 2);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.conn_states, [5, 1, 2, 0]);
        assert_eq!(s.hist_keepalive.count, 2);
        assert_eq!(s.hist_keepalive.sum, 9);
        let text = s.render();
        for line in [
            "positron_http_shed_total 2",
            "positron_cancelled_total 1",
            "positron_http_conn_state{state=\"idle\"} 5",
            "positron_http_conn_state{state=\"reading\"} 1",
            "positron_http_conn_state{state=\"inflight\"} 2",
            "positron_http_conn_state{state=\"writing\"} 0",
            "positron_keepalive_requests_count 2",
            "positron_keepalive_requests_sum 9",
        ] {
            assert!(text.contains(line), "missing `{line}` in:\n{text}");
        }
        // Gauges overwrite, not accumulate.
        m.set_conn_states([0, 0, 0, 3]);
        assert_eq!(m.snapshot().conn_states, [0, 0, 0, 3]);
    }

    #[test]
    fn histograms_feed_from_recorders_and_render() {
        let m = Metrics::default();
        m.record_latency(Duration::from_micros(100));
        m.record_queue_wait(Duration::from_micros(3));
        m.record_codec(Duration::from_nanos(1_000));
        m.record_execute(Duration::from_nanos(50_000));
        m.record_batch_stages(2_000, 700);
        m.record_codec_worker(4_000);
        let s = m.snapshot();
        assert_eq!(s.hist_latency_us.count, 1);
        assert_eq!(s.hist_queue_us.count, 1);
        assert_eq!(s.hist_codec_ns.sum, 1_000);
        assert_eq!(s.hist_execute_ns.sum, 50_000);
        assert_eq!(s.staging_ns, 2_000);
        assert_eq!(s.readout_ns, 700);
        assert_eq!(s.codec_worker_ns, 4_000);
        let text = s.render();
        for name in [
            "positron_request_latency_us_bucket{le=\"+Inf\"} 1",
            "positron_request_latency_us_sum 100",
            "positron_request_latency_us_count 1",
            "positron_queue_wait_us_count 1",
            "positron_codec_batch_ns_sum 1000",
            "positron_execute_batch_ns_sum 50000",
            "positron_staging_ns_total 2000",
            "positron_readout_ns_total 700",
            "positron_codec_worker_ns_total 4000",
        ] {
            assert!(text.contains(name), "missing `{name}` in:\n{text}");
        }
    }

    #[test]
    fn every_rendered_metric_is_documented() {
        // Drift gate (mirrored by tools/check_metrics_docs.py in CI):
        // every positron_* family name render() can emit must appear in
        // docs/OBSERVABILITY.md.
        let docs = include_str!("../../../docs/OBSERVABILITY.md");
        let m = Metrics::default();
        m.record_latency(Duration::from_micros(10));
        m.record_queue_wait(Duration::from_micros(1));
        m.record_codec(Duration::from_nanos(100));
        m.record_execute(Duration::from_nanos(100));
        m.record_http_conn_open();
        m.record_http_response(200);
        let text = m.snapshot().render();
        for line in text.lines() {
            let name = line.split(['{', ' ']).next().unwrap_or("");
            if name.starts_with("positron_") {
                assert!(docs.contains(name), "metric `{name}` missing from docs/OBSERVABILITY.md");
            }
        }
    }

    #[test]
    fn certify_counters_and_width_histograms_render() {
        let m = Metrics::default();
        // Two clean certifications plus one violation.
        m.record_certified(2e-6, 1e-6, false);
        m.record_certified(4e-6, 2e-6, false);
        m.record_certified(8e-6, 4e-6, true);
        let s = m.snapshot();
        assert_eq!(s.certified_requests, 3);
        assert_eq!(s.certify_violations, 1);
        assert_eq!(s.hist_certify_max_fm.count, 3);
        assert_eq!(s.hist_certify_mean_fm.count, 3);
        // femtounit conversion: 2e-6 → ~2e9 fm (float truncation may
        // shave the last unit, so bound rather than pin the sum).
        let sum = s.hist_certify_max_fm.sum;
        assert!((13_999_999_990..=14_000_000_010).contains(&sum), "sum = {sum}");
        let text = s.render();
        for line in [
            "positron_certified_requests_total 3",
            "positron_certify_violations_total 1",
            "positron_certify_bound_max_fm_count 3",
            "positron_certify_bound_mean_fm_count 3",
        ] {
            assert!(text.contains(line), "missing `{line}` in:\n{text}");
        }
        // Poisoned (infinite-width) bounds saturate, never panic.
        m.record_certified(f64::INFINITY, f64::INFINITY, true);
        assert_eq!(m.snapshot().certify_violations, 2);
        assert_eq!(super::width_femtos(f64::INFINITY), u64::MAX);
        assert_eq!(super::width_femtos(f64::NAN), u64::MAX);
        assert_eq!(super::width_femtos(0.0), 0);
    }

    #[test]
    fn codec_and_execute_time_split() {
        let m = Metrics::default();
        m.record_batch(4);
        m.record_codec(Duration::from_nanos(1_500));
        m.record_execute(Duration::from_nanos(40_000));
        m.record_batch(4);
        m.record_codec(Duration::from_nanos(2_500));
        m.record_execute(Duration::from_nanos(60_000));
        m.set_codec_threads(3);
        let s = m.snapshot();
        assert_eq!(s.codec_ns, 4_000);
        assert_eq!(s.execute_ns, 100_000);
        assert_eq!(s.codec_threads, 3);
        assert_eq!(s.codec_ns_per_batch(), 2_000.0);
        assert_eq!(s.execute_ns_per_batch(), 50_000.0);
        let text = s.render();
        assert!(text.contains("positron_codec_ns_total 4000"), "{text}");
        assert!(text.contains("positron_execute_ns_total 100000"), "{text}");
        assert!(text.contains("positron_codec_threads 3"), "{text}");
    }
}
