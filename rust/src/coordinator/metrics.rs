//! Serving metrics: lock-protected latency reservoir + counters, cheap
//! enough for the request path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink.
#[derive(Default)]
pub struct Metrics {
    requests: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    rejected: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

/// Point-in-time view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    /// Mean items per executed batch.
    pub mean_batch: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    pub fn record_latency(&self, d: Duration) {
        let mut v = self.latencies_us.lock().unwrap();
        // Reservoir cap: keep memory bounded on long runs.
        if v.len() >= 1_000_000 {
            v.clear();
        }
        v.push(d.as_micros() as u64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lats = self.latencies_us.lock().unwrap().clone();
        lats.sort_unstable();
        let q = |p: f64| -> u64 {
            if lats.is_empty() {
                0
            } else {
                lats[((lats.len() - 1) as f64 * p) as usize]
            }
        };
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches,
            rejected: self.rejected.load(Ordering::Relaxed),
            mean_batch: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            p50_us: q(0.5),
            p99_us: q(0.99),
            max_us: lats.last().copied().unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_quantiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(Duration::from_micros(i));
            m.record_request();
        }
        m.record_batch(10);
        m.record_batch(20);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch, 15.0);
        assert!(s.p50_us >= 45 && s.p50_us <= 55, "p50 = {}", s.p50_us);
        assert!(s.p99_us >= 95, "p99 = {}", s.p99_us);
        assert_eq!(s.max_us, 100);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.mean_batch, 0.0);
    }
}
