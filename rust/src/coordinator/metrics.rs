//! Serving metrics: bounded latency reservoir + counters, cheap enough
//! for the request path. Quantize/dequantize (codec) time and model
//! execute time are tracked separately so `/metrics` output attributes
//! batch cost to the right stage.
//!
//! Latency quantiles come from **reservoir sampling** (Algorithm R with
//! a deterministic in-struct LCG — no `rand` dependency): once the
//! reservoir is full, sample *i* replaces a uniformly chosen slot with
//! probability `CAP/i`, so the reservoir stays a uniform sample of the
//! whole run. The previous implementation cleared the buffer at 1M
//! samples, silently resetting p50/p99/max mid-run; `max_us` is now a
//! separate monotone counter that never resets.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Latency reservoir capacity: 64Ki samples ≈ 512 KiB, a uniform sample
/// of the full run regardless of its length.
pub const LATENCY_RESERVOIR_CAP: usize = 65_536;

/// Bounded uniform sample of every recorded latency (Algorithm R).
struct Reservoir {
    samples: Vec<u64>,
    /// Total samples ever offered (monotone).
    seen: u64,
    /// Deterministic LCG state for replacement-slot selection.
    lcg: u64,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir { samples: Vec::new(), seen: 0, lcg: 0x9e3779b97f4a7c15 }
    }
}

impl Reservoir {
    fn record(&mut self, v: u64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(v);
            return;
        }
        // Uniform j ∈ [0, seen): keep v iff j lands inside the reservoir.
        // Full-width Lemire reduction (lcg·seen ≫ 64), not a shifted
        // modulus — a 31-bit index would freeze the keep-probability at
        // CAP/2³¹ once `seen` passes 2³¹ and bias the sample recent.
        self.lcg = self.lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let j = ((self.lcg as u128 * self.seen as u128) >> 64) as u64;
        if (j as usize) < LATENCY_RESERVOIR_CAP {
            self.samples[j as usize] = v;
        }
    }
}

/// Shared metrics sink.
#[derive(Default)]
pub struct Metrics {
    requests: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    rejected: AtomicU64,
    /// Requests answered with a deadline error instead of a batch slot.
    deadline_expired: AtomicU64,
    /// Batches whose execution failed (every member got an error reply).
    batch_failures: AtomicU64,
    /// Total nanoseconds spent in the b-posit codec (quantize/dequantize).
    codec_ns: AtomicU64,
    /// Total nanoseconds spent executing the model.
    execute_ns: AtomicU64,
    /// Worker threads available to the sharded codec (0 = not reported).
    codec_threads: AtomicU64,
    /// Largest latency ever recorded — monotone, survives reservoir
    /// replacement.
    max_us: AtomicU64,
    latencies_us: Mutex<Reservoir>,
}

/// Point-in-time view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    pub deadline_expired: u64,
    pub batch_failures: u64,
    /// Mean items per executed batch.
    pub mean_batch: f64,
    /// Total latencies ever recorded (the reservoir holds a uniform
    /// sample of them, capped at [`LATENCY_RESERVOIR_CAP`]).
    pub latency_samples: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Total codec (quantize/dequantize) nanoseconds across all batches.
    pub codec_ns: u64,
    /// Total model-execute nanoseconds across all batches.
    pub execute_ns: u64,
    /// Worker threads available to the sharded codec (0 = not reported).
    pub codec_threads: u64,
    /// Quantized-weight cache hits since process start (process-wide —
    /// the cache is shared by every server; monotone).
    pub weight_cache_hits: u64,
    /// Quantized-weight cache misses since process start (process-wide;
    /// monotone — a miss is the one-time encode/transpose of a tensor).
    pub weight_cache_misses: u64,
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch_failure(&self) {
        self.batch_failures.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Add one batch's codec (quantize/dequantize) time.
    pub fn record_codec(&self, d: Duration) {
        self.codec_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Add one batch's model-execute time.
    pub fn record_execute(&self, d: Duration) {
        self.execute_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record the worker-thread count the sharded codec runs with (set
    /// once at server startup; a gauge, not a counter).
    pub fn set_codec_threads(&self, threads: usize) {
        self.codec_threads.store(threads as u64, Ordering::Relaxed);
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.max_us.fetch_max(us, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().record(us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let (mut lats, seen) = {
            let r = self.latencies_us.lock().unwrap();
            (r.samples.clone(), r.seen)
        };
        lats.sort_unstable();
        let q = |p: f64| -> u64 {
            if lats.is_empty() {
                0
            } else {
                lats[((lats.len() - 1) as f64 * p) as usize]
            }
        };
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        let (weight_cache_hits, weight_cache_misses) = super::quantizer::weight_cache_stats();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches,
            rejected: self.rejected.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            batch_failures: self.batch_failures.load(Ordering::Relaxed),
            mean_batch: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            latency_samples: seen,
            p50_us: q(0.5),
            p99_us: q(0.99),
            max_us: self.max_us.load(Ordering::Relaxed),
            codec_ns: self.codec_ns.load(Ordering::Relaxed),
            execute_ns: self.execute_ns.load(Ordering::Relaxed),
            codec_threads: self.codec_threads.load(Ordering::Relaxed),
            weight_cache_hits,
            weight_cache_misses,
        }
    }
}

impl MetricsSnapshot {
    /// Mean codec nanoseconds per executed batch.
    pub fn codec_ns_per_batch(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.codec_ns as f64 / self.batches as f64 }
    }

    /// Mean execute nanoseconds per executed batch.
    pub fn execute_ns_per_batch(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.execute_ns as f64 / self.batches as f64 }
    }

    /// Render in a Prometheus-style text format — the body served by the
    /// HTTP listener's `GET /metrics`, with codec time attributed
    /// separately from execute time.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("positron_requests_total {}\n", self.requests));
        s.push_str(&format!("positron_rejected_total {}\n", self.rejected));
        s.push_str(&format!("positron_deadline_expired_total {}\n", self.deadline_expired));
        s.push_str(&format!("positron_batch_failures_total {}\n", self.batch_failures));
        s.push_str(&format!("positron_batches_total {}\n", self.batches));
        s.push_str(&format!("positron_batch_mean_items {:.3}\n", self.mean_batch));
        s.push_str(&format!("positron_latency_samples_total {}\n", self.latency_samples));
        s.push_str(&format!("positron_latency_p50_us {}\n", self.p50_us));
        s.push_str(&format!("positron_latency_p99_us {}\n", self.p99_us));
        s.push_str(&format!("positron_latency_max_us {}\n", self.max_us));
        s.push_str(&format!("positron_codec_threads {}\n", self.codec_threads));
        s.push_str(&format!("positron_codec_ns_total {}\n", self.codec_ns));
        s.push_str(&format!("positron_codec_ns_per_batch {:.0}\n", self.codec_ns_per_batch()));
        s.push_str(&format!("positron_execute_ns_total {}\n", self.execute_ns));
        s.push_str(&format!("positron_execute_ns_per_batch {:.0}\n", self.execute_ns_per_batch()));
        s.push_str(&format!("positron_weight_cache_hits_total {}\n", self.weight_cache_hits));
        s.push_str(&format!("positron_weight_cache_misses_total {}\n", self.weight_cache_misses));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_quantiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(Duration::from_micros(i));
            m.record_request();
        }
        m.record_batch(10);
        m.record_batch(20);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch, 15.0);
        assert_eq!(s.latency_samples, 100);
        assert!(s.p50_us >= 45 && s.p50_us <= 55, "p50 = {}", s.p50_us);
        assert!(s.p99_us >= 95, "p99 = {}", s.p99_us);
        assert_eq!(s.max_us, 100);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.latency_samples, 0);
        assert_eq!(s.deadline_expired, 0);
        assert_eq!(s.batch_failures, 0);
        assert_eq!(s.codec_ns, 0);
        assert_eq!(s.execute_ns, 0);
        assert_eq!(s.codec_threads, 0);
        assert_eq!(s.codec_ns_per_batch(), 0.0);
    }

    #[test]
    fn reservoir_is_bounded_and_max_never_resets() {
        // The bugfix contract: pushing far past the cap must keep memory
        // bounded, keep quantiles meaningful, and never lose the max.
        let m = Metrics::default();
        m.record_latency(Duration::from_micros(999_999)); // early spike
        for _ in 0..(3 * LATENCY_RESERVOIR_CAP) {
            m.record_latency(Duration::from_micros(10));
        }
        {
            let r = m.latencies_us.lock().unwrap();
            assert_eq!(r.samples.len(), LATENCY_RESERVOIR_CAP, "reservoir grew past cap");
            assert_eq!(r.seen, 3 * LATENCY_RESERVOIR_CAP as u64 + 1);
        }
        let s = m.snapshot();
        assert_eq!(s.latency_samples, 3 * LATENCY_RESERVOIR_CAP as u64 + 1);
        assert_eq!(s.max_us, 999_999, "max_us must survive reservoir replacement");
        assert_eq!(s.p50_us, 10, "uniform sample dominated by the steady value");
        let text = s.render();
        assert!(text.contains("positron_latency_max_us 999999"), "{text}");
        assert!(text.contains("positron_latency_samples_total"), "{text}");
    }

    #[test]
    fn failure_counters_render() {
        let m = Metrics::default();
        m.record_deadline_expired();
        m.record_deadline_expired();
        m.record_batch_failure();
        let s = m.snapshot();
        assert_eq!(s.deadline_expired, 2);
        assert_eq!(s.batch_failures, 1);
        let text = s.render();
        assert!(text.contains("positron_deadline_expired_total 2"), "{text}");
        assert!(text.contains("positron_batch_failures_total 1"), "{text}");
    }

    #[test]
    fn weight_cache_counters_render() {
        // The counters are process-wide (shared with every concurrently
        // running test), so assert presence + monotone lower bound, not
        // exact values.
        let (h0, m0) = super::super::quantizer::weight_cache_stats();
        let s = Metrics::default().snapshot();
        assert!(s.weight_cache_hits >= h0 && s.weight_cache_misses >= m0);
        let text = s.render();
        assert!(text.contains("positron_weight_cache_hits_total "), "{text}");
        assert!(text.contains("positron_weight_cache_misses_total "), "{text}");
    }

    #[test]
    fn codec_and_execute_time_split() {
        let m = Metrics::default();
        m.record_batch(4);
        m.record_codec(Duration::from_nanos(1_500));
        m.record_execute(Duration::from_nanos(40_000));
        m.record_batch(4);
        m.record_codec(Duration::from_nanos(2_500));
        m.record_execute(Duration::from_nanos(60_000));
        m.set_codec_threads(3);
        let s = m.snapshot();
        assert_eq!(s.codec_ns, 4_000);
        assert_eq!(s.execute_ns, 100_000);
        assert_eq!(s.codec_threads, 3);
        assert_eq!(s.codec_ns_per_batch(), 2_000.0);
        assert_eq!(s.execute_ns_per_batch(), 50_000.0);
        let text = s.render();
        assert!(text.contains("positron_codec_ns_total 4000"), "{text}");
        assert!(text.contains("positron_execute_ns_total 100000"), "{text}");
        assert!(text.contains("positron_codec_threads 3"), "{text}");
    }
}
