//! Serving metrics: lock-protected latency reservoir + counters, cheap
//! enough for the request path. Quantize/dequantize (codec) time and model
//! execute time are tracked separately so `/metrics` output attributes
//! batch cost to the right stage.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink.
#[derive(Default)]
pub struct Metrics {
    requests: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    rejected: AtomicU64,
    /// Total nanoseconds spent in the b-posit codec (quantize/dequantize).
    codec_ns: AtomicU64,
    /// Total nanoseconds spent executing the model.
    execute_ns: AtomicU64,
    /// Worker threads available to the sharded codec (0 = not reported).
    codec_threads: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

/// Point-in-time view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    /// Mean items per executed batch.
    pub mean_batch: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Total codec (quantize/dequantize) nanoseconds across all batches.
    pub codec_ns: u64,
    /// Total model-execute nanoseconds across all batches.
    pub execute_ns: u64,
    /// Worker threads available to the sharded codec (0 = not reported).
    pub codec_threads: u64,
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Add one batch's codec (quantize/dequantize) time.
    pub fn record_codec(&self, d: Duration) {
        self.codec_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Add one batch's model-execute time.
    pub fn record_execute(&self, d: Duration) {
        self.execute_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record the worker-thread count the sharded codec runs with (set
    /// once at server startup; a gauge, not a counter).
    pub fn set_codec_threads(&self, threads: usize) {
        self.codec_threads.store(threads as u64, Ordering::Relaxed);
    }

    pub fn record_latency(&self, d: Duration) {
        let mut v = self.latencies_us.lock().unwrap();
        // Reservoir cap: keep memory bounded on long runs.
        if v.len() >= 1_000_000 {
            v.clear();
        }
        v.push(d.as_micros() as u64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lats = self.latencies_us.lock().unwrap().clone();
        lats.sort_unstable();
        let q = |p: f64| -> u64 {
            if lats.is_empty() {
                0
            } else {
                lats[((lats.len() - 1) as f64 * p) as usize]
            }
        };
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches,
            rejected: self.rejected.load(Ordering::Relaxed),
            mean_batch: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            p50_us: q(0.5),
            p99_us: q(0.99),
            max_us: lats.last().copied().unwrap_or(0),
            codec_ns: self.codec_ns.load(Ordering::Relaxed),
            execute_ns: self.execute_ns.load(Ordering::Relaxed),
            codec_threads: self.codec_threads.load(Ordering::Relaxed),
        }
    }
}

impl MetricsSnapshot {
    /// Mean codec nanoseconds per executed batch.
    pub fn codec_ns_per_batch(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.codec_ns as f64 / self.batches as f64 }
    }

    /// Mean execute nanoseconds per executed batch.
    pub fn execute_ns_per_batch(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.execute_ns as f64 / self.batches as f64 }
    }

    /// Render in a Prometheus-style text format — the server's `/metrics`
    /// output, with codec time attributed separately from execute time.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("positron_requests_total {}\n", self.requests));
        s.push_str(&format!("positron_rejected_total {}\n", self.rejected));
        s.push_str(&format!("positron_batches_total {}\n", self.batches));
        s.push_str(&format!("positron_batch_mean_items {:.3}\n", self.mean_batch));
        s.push_str(&format!("positron_latency_p50_us {}\n", self.p50_us));
        s.push_str(&format!("positron_latency_p99_us {}\n", self.p99_us));
        s.push_str(&format!("positron_latency_max_us {}\n", self.max_us));
        s.push_str(&format!("positron_codec_threads {}\n", self.codec_threads));
        s.push_str(&format!("positron_codec_ns_total {}\n", self.codec_ns));
        s.push_str(&format!("positron_codec_ns_per_batch {:.0}\n", self.codec_ns_per_batch()));
        s.push_str(&format!("positron_execute_ns_total {}\n", self.execute_ns));
        s.push_str(&format!("positron_execute_ns_per_batch {:.0}\n", self.execute_ns_per_batch()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_quantiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(Duration::from_micros(i));
            m.record_request();
        }
        m.record_batch(10);
        m.record_batch(20);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch, 15.0);
        assert!(s.p50_us >= 45 && s.p50_us <= 55, "p50 = {}", s.p50_us);
        assert!(s.p99_us >= 95, "p99 = {}", s.p99_us);
        assert_eq!(s.max_us, 100);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.codec_ns, 0);
        assert_eq!(s.execute_ns, 0);
        assert_eq!(s.codec_threads, 0);
        assert_eq!(s.codec_ns_per_batch(), 0.0);
    }

    #[test]
    fn codec_and_execute_time_split() {
        let m = Metrics::default();
        m.record_batch(4);
        m.record_codec(Duration::from_nanos(1_500));
        m.record_execute(Duration::from_nanos(40_000));
        m.record_batch(4);
        m.record_codec(Duration::from_nanos(2_500));
        m.record_execute(Duration::from_nanos(60_000));
        m.set_codec_threads(3);
        let s = m.snapshot();
        assert_eq!(s.codec_ns, 4_000);
        assert_eq!(s.execute_ns, 100_000);
        assert_eq!(s.codec_threads, 3);
        assert_eq!(s.codec_ns_per_batch(), 2_000.0);
        assert_eq!(s.execute_ns_per_batch(), 50_000.0);
        let text = s.render();
        assert!(text.contains("positron_codec_ns_total 4000"), "{text}");
        assert!(text.contains("positron_execute_ns_total 100000"), "{text}");
        assert!(text.contains("positron_codec_threads 3"), "{text}");
    }
}
