//! Batching inference server.
//!
//! PJRT handles are not `Send`, so the worker thread *creates* the runtime,
//! compiles the model, and owns every literal; clients only exchange plain
//! `Vec<f32>` through bounded channels. The worker assembles dynamic
//! batches (up to the model's static batch, or until `max_wait` expires),
//! rounds inputs through b-posit32 (the format under test), executes, and
//! fans results back out. A full queue rejects with `Busy` — backpressure.
//!
//! Steady-state allocation discipline: the batch staging buffer and the
//! input literal are built once and reused every iteration; quantization
//! runs through the vector codec *in place* on the staging buffer, and
//! batches past the fork-join threshold are sharded across worker threads
//! (`PALLAS_THREADS`, auto default) with bit-identical results. The codec
//! and model-execute stages are timed separately into [`Metrics`], which
//! also exports the sharded-codec thread count.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{anyhow, Result};

use super::metrics::Metrics;
use super::quantizer;
use crate::runtime::{lit_f32_2d, Literal, ModelWeights, Runtime};

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max requests per executed batch (≤ the model's static batch size).
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Bounded queue depth (backpressure beyond this).
    pub queue_depth: usize,
    /// Quantize inputs through b-posit32 before execution.
    pub quantize_inputs: bool,
    /// Which model artifact to serve.
    pub model_file: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
            quantize_inputs: true,
            model_file: "model_bposit.hlo.txt".into(),
        }
    }
}

/// One inference request (internal).
struct Request {
    features: Vec<f32>,
    submitted: Instant,
    resp: SyncSender<Response>,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub latency: Duration,
}

/// Handle to a running server.
pub struct InferenceServer {
    tx: SyncSender<Request>,
    metrics: Arc<Metrics>,
    worker: Option<JoinHandle<()>>,
    /// (features, classes) of the served model.
    pub dims: (usize, usize),
}

impl InferenceServer {
    /// Spawn the worker; it opens the PJRT runtime on `artifact_dir`,
    /// compiles `cfg.model_file`, and reports readiness before this
    /// returns. Without the `runtime` cargo feature this fails fast with
    /// the "runtime disabled" error.
    pub fn start(artifact_dir: PathBuf, cfg: ServerConfig) -> Result<InferenceServer> {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = sync_channel::<std::result::Result<(usize, usize), String>>(1);
        let worker = std::thread::spawn(move || {
            let setup = (|| -> Result<(Runtime, ModelWeights, crate::runtime::LoadedModel)> {
                let rt = Runtime::cpu(&artifact_dir)?;
                let weights = ModelWeights::load(&rt)?;
                let model = rt.load(&cfg.model_file)?;
                Ok((rt, weights, model))
            })();
            match setup {
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                }
                Ok((_rt, weights, model)) => {
                    let _ = ready_tx.send(Ok((weights.d, weights.c)));
                    worker_loop(model, weights, cfg, rx, m2);
                }
            }
        });
        let dims = ready_rx
            .recv()
            .map_err(|_| anyhow!("server worker died during startup"))?
            .map_err(|e| anyhow!("server startup failed: {e}"))?;
        Ok(InferenceServer { tx, metrics, worker: Some(worker), dims })
    }

    /// Blocking inference for one feature vector.
    pub fn infer(&self, features: Vec<f32>) -> Result<Response> {
        if features.len() != self.dims.0 {
            return Err(anyhow!("expected {} features, got {}", self.dims.0, features.len()));
        }
        let (rtx, rrx) = sync_channel(1);
        let req = Request { features, submitted: Instant::now(), resp: rtx };
        self.metrics.record_request();
        match self.tx.try_send(req) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                return Err(anyhow!("server busy (queue full)"));
            }
            Err(TrySendError::Disconnected(_)) => return Err(anyhow!("server stopped")),
        }
        rrx.recv().map_err(|_| anyhow!("server dropped request"))
    }

    /// Non-blocking submit returning a waiter.
    pub fn infer_async(&self, features: Vec<f32>) -> Result<Receiver<Response>> {
        if features.len() != self.dims.0 {
            return Err(anyhow!("expected {} features, got {}", self.dims.0, features.len()));
        }
        let (rtx, rrx) = sync_channel(1);
        let req = Request { features, submitted: Instant::now(), resp: rtx };
        self.metrics.record_request();
        match self.tx.try_send(req) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(anyhow!("server busy (queue full)"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("server stopped")),
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // Close the queue, then join the worker.
        let (dummy_tx, _dummy_rx) = sync_channel::<Request>(1);
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    model: crate::runtime::LoadedModel,
    weights: ModelWeights,
    cfg: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    let d = weights.d;
    let c = weights.c;
    let model_batch = weights.batch;
    let max_batch = cfg.max_batch.min(model_batch);
    metrics.set_codec_threads(crate::vector::parallel::num_threads());
    // Argument literals are built once and reused: execute() only borrows
    // them. Slot 0 (the batch input) is refreshed in place each iteration.
    let weight_lits = match if cfg.model_file.contains("f32") {
        weights.f32_arg_literals()
    } else {
        weights.bposit_arg_literals()
    } {
        Ok(w) => w,
        Err(e) => {
            eprintln!("weight literal construction failed: {e}");
            return;
        }
    };
    // Persistent staging buffer (model_batch × d) + input literal: the
    // steady-state loop below performs no per-request heap allocation on
    // the quantize path.
    let mut x = vec![0f32; model_batch * d];
    let mut args: Vec<Literal> = Vec::with_capacity(1 + weight_lits.len());
    match lit_f32_2d(&x, model_batch, d) {
        Ok(l) => args.push(l),
        Err(e) => {
            eprintln!("initial literal failed: {e}");
            return;
        }
    }
    args.extend(weight_lits);
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // channel closed: shut down
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        metrics.record_batch(batch.len());

        // Stage the (model_batch × d) input: fill the live prefix, zero the
        // padding rows, then quantize the prefix in place (vector codec).
        // Only the quantize pass counts as codec time — staging memcpys and
        // the literal refresh are batching overhead, not codec cost.
        for (i, r) in batch.iter().enumerate() {
            x[i * d..(i + 1) * d].copy_from_slice(&r.features);
        }
        x[batch.len() * d..].fill(0.0);
        if cfg.quantize_inputs {
            let t_codec = Instant::now();
            quantizer::roundtrip_in_place(&mut x[..batch.len() * d]);
            metrics.record_codec(t_codec.elapsed());
        }
        if let Err(e) = args[0].copy_from_f32(&x) {
            eprintln!("input literal refresh failed: {e}");
            continue;
        }

        let t_exec = Instant::now();
        let out = match model.run_f32(&args) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("batch execute failed: {e}");
                continue;
            }
        };
        metrics.record_execute(t_exec.elapsed());
        for (i, r) in batch.into_iter().enumerate() {
            let logits = out[i * c..(i + 1) * c].to_vec();
            let latency = r.submitted.elapsed();
            metrics.record_latency(latency);
            let _ = r.resp.send(Response { logits, latency });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The satellite contract for builds without libxla: starting the
    /// server fails fast with the documented "runtime disabled" error
    /// instead of panicking or hanging.
    #[test]
    #[cfg(not(feature = "runtime"))]
    fn start_without_runtime_feature_fails_with_clear_error() {
        let err = InferenceServer::start(PathBuf::from("artifacts"), ServerConfig::default())
            .unwrap_err();
        assert!(err.to_string().contains("runtime disabled"), "{err}");
    }
}

