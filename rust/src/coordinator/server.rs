//! Batching inference server.
//!
//! Clients exchange plain `Vec<f32>` with a single worker thread through
//! bounded channels; the worker *creates* its execution backend (see
//! [`super::backend`]) at startup — PJRT handles are not `Send`, and the
//! native backend's scratch is single-owner — assembles dynamic batches
//! (up to `max_batch`, or until `max_wait` expires), quantizes inputs
//! through the b-posit codec where the serving format calls for it,
//! executes, and fans results back out. A full queue rejects with a
//! `Busy` error — backpressure.
//!
//! Failure discipline: every admitted request gets an answer. Requests
//! that outlive `cfg.deadline` while queued are answered with
//! [`ServeError::DeadlineExceeded`] instead of occupying a batch slot;
//! a failed batch execution answers every member with
//! [`ServeError::BackendFailed`] and bumps
//! `positron_batch_failures_total` — never a silently dropped channel.
//!
//! Steady-state allocation discipline: the staging buffer is built once
//! and reused; quantization runs through the sharded vector codec in
//! place, and the backend returns logits borrowed from its own reused
//! scratch. The codec and execute stages are timed separately into
//! [`Metrics`].
//!
//! Observability: every request carries a process-unique trace id and a
//! [`StageTimer`]; the worker attributes queue-wait, staging, input
//! codec, execute, and readout time per batch (wall times at stage
//! boundaries — no timing inside lane loops) and each [`Response`]
//! carries the merged per-stage breakdown back to the caller. When
//! `cfg.tracing` is on, completed request and batch spans land in the
//! server's [`Tracer`] ring for `GET /debug/tracez`; when off, only the
//! span recording stops — stage timers, histograms, and counters stay
//! live, and the numeric path is identical either way (logits are
//! bit-identical with tracing on or off; tests gate on this).

use std::fmt;
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{anyhow, Result};

use super::backend;
use super::backend::{BackendKind, InferenceBackend, NativeBackend, PjrtBackend, WeightFormat};
use super::metrics::Metrics;
use super::trace::{self, SpanRecord, Stage, StageTimer, Tracer};
use crate::runtime::ModelWeights;

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max requests per executed batch (additionally capped by the
    /// backend's own limit, e.g. the PJRT model's static batch).
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Bounded queue depth (backpressure beyond this).
    pub queue_depth: usize,
    /// Quantize inputs through the serving format's codec before
    /// execution (b-posit32 roundtrip for the BP32 tier; a no-op for f32
    /// and for BP64, where every f32 input is exactly representable).
    pub quantize_inputs: bool,
    /// Which executor the worker builds ([`BackendKind::Native`] needs
    /// only `weights.json`; [`BackendKind::Pjrt`] needs the `runtime`
    /// feature plus compiled HLO artifacts).
    pub backend: BackendKind,
    /// How the model weights are stored and multiplied. Shared with the
    /// backend layer — this replaces the old
    /// `model_file.contains("f32")` string sniffing.
    pub weight_format: WeightFormat,
    /// HLO artifact for the PJRT backend (ignored by the native one).
    pub model_file: String,
    /// Per-request deadline: a request still *queued* this long after
    /// submission is answered with [`ServeError::DeadlineExceeded`]
    /// instead of occupying a batch slot. `None` disables.
    pub deadline: Option<Duration>,
    /// Record completed request/batch spans into the server's
    /// [`Tracer`] ring (`GET /debug/tracez`). Off switches span
    /// *retention* only — stage timing, histograms, and counters stay
    /// on, and logits are bit-identical either way.
    pub tracing: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
            quantize_inputs: true,
            backend: BackendKind::Native,
            weight_format: WeightFormat::Bp32,
            model_file: WeightFormat::Bp32.model_file().into(),
            deadline: None,
            tracing: true,
        }
    }
}

impl ServerConfig {
    /// A config serving `format`, with the PJRT artifact name kept in
    /// sync for builds that select the PJRT backend.
    pub fn for_format(format: WeightFormat) -> ServerConfig {
        ServerConfig {
            weight_format: format,
            model_file: format.model_file().into(),
            ..Default::default()
        }
    }
}

/// Why the worker answered a request with an error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request sat queued past `cfg.deadline`.
    DeadlineExceeded,
    /// The backend failed to execute the batch.
    BackendFailed(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            ServeError::BackendFailed(m) => write!(f, "batch execution failed: {m}"),
        }
    }
}

/// What the worker sends back per request.
pub type ServeResult = std::result::Result<Response, ServeError>;

/// Client-facing error classification (the HTTP layer maps these to
/// status codes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InferError {
    /// Malformed request (wrong feature count).
    BadRequest(String),
    /// Queue full — back off and retry.
    Busy,
    /// Server shut down.
    Stopped,
    /// The request's deadline passed while it was queued.
    DeadlineExceeded,
    /// The backend failed to execute the batch.
    Backend(String),
}

impl fmt::Display for InferError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InferError::BadRequest(m) => write!(f, "{m}"),
            InferError::Busy => write!(f, "server busy (queue full)"),
            InferError::Stopped => write!(f, "server stopped"),
            InferError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            InferError::Backend(m) => write!(f, "batch execution failed: {m}"),
        }
    }
}

/// One inference request (internal).
struct Request {
    features: Vec<f32>,
    submitted: Instant,
    resp: SyncSender<ServeResult>,
    /// Process-unique trace id, echoed back in the [`Response`].
    trace_id: u64,
    /// Stage time spent before submission (HTTP accept/parse; zero for
    /// in-process callers) — merged into the response's breakdown.
    pre: StageTimer,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// This request's process-unique trace id.
    pub trace_id: u64,
    /// Trace id of the batch span that executed this request.
    pub batch_id: u64,
    /// Rows in the executing batch.
    pub batch_rows: u32,
    /// Per-stage breakdown: the caller's pre-submit stages plus this
    /// request's queue wait plus the executing batch's shared stages.
    pub stages: StageTimer,
}

/// Handle to a running server.
pub struct InferenceServer {
    tx: SyncSender<Request>,
    metrics: Arc<Metrics>,
    tracer: Arc<Tracer>,
    worker: Option<JoinHandle<()>>,
    /// (features, classes) of the served model.
    pub dims: (usize, usize),
}

impl InferenceServer {
    /// Spawn the worker; it builds the configured backend (native by
    /// default — PJRT only when `cfg.backend` says so) and reports
    /// readiness before this returns.
    pub fn start(artifact_dir: PathBuf, cfg: ServerConfig) -> Result<InferenceServer> {
        let c = cfg.clone();
        Self::start_with_factory(
            move || -> Result<Box<dyn InferenceBackend>> {
                match c.backend {
                    BackendKind::Native => {
                        Ok(Box::new(NativeBackend::load(&artifact_dir, c.weight_format)?))
                    }
                    BackendKind::Pjrt => Ok(Box::new(PjrtBackend::load(
                        &artifact_dir,
                        &c.model_file,
                        c.weight_format,
                    )?)),
                }
            },
            cfg,
        )
    }

    /// Start a native server over already-loaded (or synthetic) weights
    /// — no artifact files at all. `cfg.weight_format` selects the GEMM
    /// family.
    pub fn start_native(weights: ModelWeights, cfg: ServerConfig) -> Result<InferenceServer> {
        let format = cfg.weight_format;
        Self::start_with_factory(
            move || -> Result<Box<dyn InferenceBackend>> {
                Ok(Box::new(NativeBackend::from_weights(&weights, format)?))
            },
            cfg,
        )
    }

    /// Start over an arbitrary backend factory. The factory runs *on the
    /// worker thread* (PJRT handles are not `Send`); startup errors are
    /// reported from here. Tests use this to inject slow or failing
    /// backends.
    pub fn start_with_factory<F>(factory: F, cfg: ServerConfig) -> Result<InferenceServer>
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let tracer = Arc::new(Tracer::new(cfg.tracing));
        let t2 = tracer.clone();
        let (ready_tx, ready_rx) = sync_channel::<std::result::Result<(usize, usize), String>>(1);
        let worker = std::thread::spawn(move || match factory() {
            Err(e) => {
                let _ = ready_tx.send(Err(format!("{e:#}")));
            }
            Ok(backend) => {
                let _ = ready_tx.send(Ok(backend.dims()));
                worker_loop(backend, cfg, rx, m2, t2);
            }
        });
        let dims = ready_rx
            .recv()
            .map_err(|_| anyhow!("server worker died during startup"))?
            .map_err(|e| anyhow!("server startup failed: {e}"))?;
        Ok(InferenceServer { tx, metrics, tracer, worker: Some(worker), dims })
    }

    /// Blocking inference with a typed error. Completes the request span
    /// here (submission-to-answer wall time; no HTTP stages), so
    /// in-process callers show up in `/debug/tracez` too.
    pub fn try_infer(&self, features: Vec<f32>) -> std::result::Result<Response, InferError> {
        let resp = self.try_infer_traced(features, StageTimer::default())?;
        if self.tracer.enabled() {
            self.tracer.push(SpanRecord::request(
                resp.trace_id,
                resp.batch_id,
                resp.batch_rows,
                resp.latency.as_nanos() as u64,
                resp.stages,
            ));
        }
        Ok(resp)
    }

    /// Blocking inference carrying pre-submit stage time (HTTP
    /// accept/parse). Does **not** push a request span — the caller owns
    /// the span's completion so post-response stages (serialize, write)
    /// can be included before it is retained.
    pub fn try_infer_traced(
        &self,
        features: Vec<f32>,
        pre: StageTimer,
    ) -> std::result::Result<Response, InferError> {
        if features.len() != self.dims.0 {
            return Err(InferError::BadRequest(format!(
                "expected {} features, got {}",
                self.dims.0,
                features.len()
            )));
        }
        let (rtx, rrx) = sync_channel(1);
        let req = Request {
            features,
            submitted: Instant::now(),
            resp: rtx,
            trace_id: trace::next_trace_id(),
            pre,
        };
        self.metrics.record_request();
        match self.tx.try_send(req) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                return Err(InferError::Busy);
            }
            Err(TrySendError::Disconnected(_)) => return Err(InferError::Stopped),
        }
        match rrx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(ServeError::DeadlineExceeded)) => Err(InferError::DeadlineExceeded),
            Ok(Err(ServeError::BackendFailed(m))) => Err(InferError::Backend(m)),
            Err(_) => Err(InferError::Stopped),
        }
    }

    /// Blocking inference for one feature vector.
    pub fn infer(&self, features: Vec<f32>) -> Result<Response> {
        self.try_infer(features).map_err(|e| anyhow!("{e}"))
    }

    /// Non-blocking submit returning a waiter for the worker's answer
    /// (response or per-request serve error).
    pub fn infer_async(&self, features: Vec<f32>) -> Result<Receiver<ServeResult>> {
        if features.len() != self.dims.0 {
            return Err(anyhow!("expected {} features, got {}", self.dims.0, features.len()));
        }
        let (rtx, rrx) = sync_channel(1);
        // Async submissions get a trace id (they appear in their batch
        // span's member list) but no request span — there is no single
        // completion point at which to stamp one.
        let req = Request {
            features,
            submitted: Instant::now(),
            resp: rtx,
            trace_id: trace::next_trace_id(),
            pre: StageTimer::default(),
        };
        self.metrics.record_request();
        match self.tx.try_send(req) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(anyhow!("server busy (queue full)"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("server stopped")),
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The server's span sink (the HTTP layer completes and pushes
    /// request spans through this, and `/debug/tracez` renders it).
    pub fn tracer(&self) -> Arc<Tracer> {
        self.tracer.clone()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // Close the queue, then join the worker.
        let (dummy_tx, _dummy_rx) = sync_channel::<Request>(1);
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Hard ceiling on rows staged per batch: the native backend accepts any
/// batch (`max_batch() == usize::MAX`), so an "unlimited" `cfg.max_batch`
/// must not translate into an unbounded up-front staging allocation.
pub const MAX_STAGED_BATCH: usize = 4096;

fn worker_loop(
    mut backend: Box<dyn InferenceBackend>,
    cfg: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    tracer: Arc<Tracer>,
) {
    let (d, c) = backend.dims();
    let max_batch = cfg.max_batch.min(backend.max_batch()).clamp(1, MAX_STAGED_BATCH);
    metrics.set_codec_threads(crate::vector::parallel::num_threads());
    // Persistent staging buffer: the steady-state loop performs no
    // per-request heap allocation on the quantize path.
    let mut x = vec![0f32; max_batch * d];
    // Deadline admission: a queued request past its deadline is answered
    // immediately and never occupies a batch slot.
    let admit = |r: Request, batch: &mut Vec<Request>| {
        if cfg.deadline.is_some_and(|dl| r.submitted.elapsed() > dl) {
            metrics.record_deadline_expired();
            let _ = r.resp.send(Err(ServeError::DeadlineExceeded));
        } else {
            batch.push(r);
        }
    };
    loop {
        // Block for the first admitted request of a batch.
        let mut batch: Vec<Request> = Vec::new();
        while batch.is_empty() {
            match rx.recv() {
                Ok(r) => admit(r, &mut batch),
                Err(_) => return, // channel closed: shut down
            }
        }
        let wait_until = Instant::now() + cfg.max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= wait_until {
                break;
            }
            match rx.recv_timeout(wait_until - now) {
                Ok(r) => admit(r, &mut batch),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        let rows = batch.len();
        metrics.record_batch(rows);
        // Everything before this instant is queue wait (including the
        // batch-fill wait above); everything after is attributed to a
        // named batch stage, so each member's stage sum tracks its
        // recorded latency.
        let t_batch = Instant::now();
        let mut bt = StageTimer::default();

        // Stage the rows×d input, then quantize in place when the
        // serving format calls for it (only the quantize pass counts as
        // codec time — staging memcpys are batching overhead). The
        // contract lives in `backend::stage_inputs_in_place`, shared
        // with the allocating test-facing wrappers; the staging buffer
        // is reused, so this path performs zero per-request allocation.
        let t_stage = Instant::now();
        for (i, r) in batch.iter().enumerate() {
            x[i * d..(i + 1) * d].copy_from_slice(&r.features);
        }
        bt.add_duration(Stage::Staging, t_stage.elapsed());
        let mut codec_worker_ns = 0u64;
        if cfg.quantize_inputs && cfg.weight_format.quantizes_inputs() {
            let t_codec = Instant::now();
            codec_worker_ns =
                backend::stage_inputs_in_place_timed(cfg.weight_format, &mut x[..rows * d]);
            let codec_wall = t_codec.elapsed();
            metrics.record_codec(codec_wall);
            metrics.record_codec_worker(codec_worker_ns);
            bt.add_duration(Stage::InputCodec, codec_wall);
        }

        let t_exec = Instant::now();
        match backend.run_traced(&x[..rows * d], rows, &mut bt) {
            Ok(out) => {
                let exec_wall = t_exec.elapsed();
                metrics.record_execute(exec_wall);
                if bt.get(Stage::Execute) == 0 && bt.get(Stage::Readout) == 0 {
                    // Backend without stage attribution (the run_traced
                    // default): charge the whole call to Execute.
                    bt.add_duration(Stage::Execute, exec_wall);
                }
                metrics.record_batch_stages(bt.get(Stage::Staging), bt.get(Stage::Readout));
                let tracing = tracer.enabled();
                let batch_id = trace::next_trace_id();
                let mut members = Vec::with_capacity(if tracing { rows } else { 0 });
                for (i, r) in batch.into_iter().enumerate() {
                    let logits = out[i * c..(i + 1) * c].to_vec();
                    let latency = r.submitted.elapsed();
                    metrics.record_latency(latency);
                    let queue_wait = t_batch.saturating_duration_since(r.submitted);
                    metrics.record_queue_wait(queue_wait);
                    let mut stages = r.pre;
                    stages.add_duration(Stage::QueueWait, queue_wait);
                    stages.merge(&bt);
                    if tracing {
                        members.push(r.trace_id);
                    }
                    let _ = r.resp.send(Ok(Response {
                        logits,
                        latency,
                        trace_id: r.trace_id,
                        batch_id,
                        batch_rows: rows as u32,
                        stages,
                    }));
                }
                if tracing {
                    tracer.push(SpanRecord::batch(
                        batch_id,
                        members,
                        rows as u32,
                        bt,
                        codec_worker_ns,
                    ));
                }
            }
            Err(e) => {
                // Answer every member explicitly — a failed batch must
                // not look like a dropped connection to clients.
                metrics.record_batch_failure();
                let msg = format!("{e:#}");
                eprintln!("batch execute failed ({rows} requests): {msg}");
                for r in batch {
                    let _ = r.resp.send(Err(ServeError::BackendFailed(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The contract for builds without libxla: *explicitly selecting* the
    /// PJRT backend fails fast with the documented "runtime disabled"
    /// error instead of panicking or hanging. (The default backend is
    /// native and needs no runtime feature at all.)
    #[test]
    #[cfg(not(feature = "runtime"))]
    fn pjrt_backend_without_runtime_feature_fails_with_clear_error() {
        let cfg = ServerConfig { backend: BackendKind::Pjrt, ..Default::default() };
        let err = InferenceServer::start(PathBuf::from("artifacts"), cfg).unwrap_err();
        assert!(err.to_string().contains("runtime disabled"), "{err}");
    }

    /// Native startup against a directory with no weights.json reports a
    /// clean error naming the file.
    #[test]
    fn native_backend_missing_weights_is_clean_error() {
        let cfg = ServerConfig::default();
        let err = InferenceServer::start(PathBuf::from("/nonexistent-dir-positron"), cfg)
            .unwrap_err();
        assert!(err.to_string().contains("weights.json"), "{err}");
    }
}
